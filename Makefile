# Developer entry points. `make verify` mirrors the tier-1 gate CI runs,
# so local runs and CI stay in lockstep.

CARGO_DIR := rust

.PHONY: verify build test fmt fmt-check clippy bench-build doc all

# Tier-1 gate: release build + full test suite.
verify:
	cd $(CARGO_DIR) && cargo build --release && cargo test -q

build:
	cd $(CARGO_DIR) && cargo build --release

test:
	cd $(CARGO_DIR) && cargo test -q

fmt:
	cd $(CARGO_DIR) && cargo fmt

fmt-check:
	cd $(CARGO_DIR) && cargo fmt --check

clippy:
	cd $(CARGO_DIR) && cargo clippy --all-targets -- -D warnings

bench-build:
	cd $(CARGO_DIR) && cargo bench --no-run

doc:
	cd $(CARGO_DIR) && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# Everything CI checks, in CI order.
all: verify clippy bench-build doc fmt-check
