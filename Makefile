# Developer entry points. `make verify` mirrors the tier-1 gate CI runs,
# so local runs and CI stay in lockstep.

CARGO_DIR := rust

.PHONY: verify build test fmt fmt-check clippy bench-build bench-hot bench-hot-smoke bench-dp bench-dp-smoke bench-check doc smoke scenarios inspect-smoke all

# Tier-1 gate: release build + full test suite.
verify:
	cd $(CARGO_DIR) && cargo build --release && cargo test -q

build:
	cd $(CARGO_DIR) && cargo build --release

test:
	cd $(CARGO_DIR) && cargo test -q

fmt:
	cd $(CARGO_DIR) && cargo fmt

fmt-check:
	cd $(CARGO_DIR) && cargo fmt --check

clippy:
	cd $(CARGO_DIR) && cargo clippy --all-targets -- -D warnings

bench-build:
	cd $(CARGO_DIR) && cargo bench --no-run

# Full hot-loop throughput run; appends one JSON record to the committed
# trajectory file at the repo root (see BENCH_hot_loop.json header line).
bench-hot:
	cd $(CARGO_DIR) && ADAOPER_BENCH_JSON=../BENCH_hot_loop.json cargo bench --bench engine_hot_loop

# Quick-mode smoke of the same bench (small calibration budget, no file
# append) — CI runs this so the bench and its JSON emitter cannot rot.
bench-hot-smoke:
	cd $(CARGO_DIR) && ADAOPER_BENCH_QUICK=1 cargo bench --bench engine_hot_loop

# DP-solver throughput (map reference vs flattened lattice); appends one
# JSON record to the committed trajectory file at the repo root (see
# BENCH_dp_solve.json header line). Each record carries both backends, so
# every line is its own before/after ratio.
bench-dp:
	cd $(CARGO_DIR) && ADAOPER_BENCH_JSON=../BENCH_dp_solve.json cargo bench --bench dp_solve

# Quick-mode smoke of the solver bench (also asserts the two backends
# still agree bit-for-bit before timing) — CI runs this.
bench-dp-smoke:
	cd $(CARGO_DIR) && ADAOPER_BENCH_QUICK=1 cargo bench --bench dp_solve

# Validate the committed bench trajectory files against the
# adaoper-bench-v2 schema (header line + required per-record stats). CI
# cannot re-measure bench-host appends, but it can prove the files still
# parse and match the schema their headers promise.
bench-check:
	cd $(CARGO_DIR) && cargo run --release --bin bench_check -- \
		../BENCH_hot_loop.json ../BENCH_dp_solve.json

doc:
	cd $(CARGO_DIR) && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# Run every scenario ablation end to end at a small budget, so the
# scenario binaries (`adaoper ablation …`) cannot silently rot. CI runs
# this after the tier-1 gate.
smoke:
	cd $(CARGO_DIR) && cargo run --release -- ablation cache --quick
	cd $(CARGO_DIR) && cargo run --release -- ablation scheduler --quick --duration 2.0
	cd $(CARGO_DIR) && cargo run --release -- ablation fleet --quick
	cd $(CARGO_DIR) && cargo run --release -- ablation batching --quick --duration 2.0

# Run every declarative scenario spec under scenarios/ and enforce its
# [expect] metric bounds (non-zero exit on any violation). CI runs this
# after `make smoke`.
scenarios:
	cd $(CARGO_DIR) && cargo run --release -- scenario run ../scenarios

# Telemetry round trip: record a short trace with the audit log and
# stage timers on, render the audit + stage tables, and export/validate
# the Perfetto timeline (the validate step runs inside `inspect
# --perfetto`: parse + per-track span nesting). CI runs this after
# `make scenarios`.
inspect-smoke:
	printf '[profiler]\ncalib_samples = 1500\ngbdt_trees = 40\n' > /tmp/adaoper_inspect_smoke.toml
	cd $(CARGO_DIR) && cargo run --release -- serve --config /tmp/adaoper_inspect_smoke.toml \
		--duration 1.0 --trace /tmp/adaoper_inspect_smoke.jsonl --telemetry --health
	cd $(CARGO_DIR) && cargo run --release -- inspect /tmp/adaoper_inspect_smoke.jsonl
	cd $(CARGO_DIR) && cargo run --release -- inspect /tmp/adaoper_inspect_smoke.jsonl --stages
	cd $(CARGO_DIR) && cargo run --release -- inspect /tmp/adaoper_inspect_smoke.jsonl --alerts
	cd $(CARGO_DIR) && cargo run --release -- inspect /tmp/adaoper_inspect_smoke.jsonl \
		--perfetto /tmp/adaoper_inspect_smoke_perfetto.json

# Everything CI checks, in CI order.
all: verify smoke scenarios inspect-smoke clippy bench-build bench-check doc fmt-check
