//! End-to-end driver (EXPERIMENTS.md §E2E): proves all three layers
//! compose on a real workload.
//!
//! 1. **Real numerics** — loads the AOT-compiled HLO artifacts (Pallas
//!    conv kernels → JAX blocks → HLO text, built by `make artifacts`),
//!    serves batched back-to-back requests of the executable model through
//!    per-processor worker threads (LiveSession + PJRT), validates the
//!    output against the JAX golden values, and reports latency/throughput.
//! 2. **Real GRU corrector** — wires `gru.hlo.txt` into the profiler and
//!    serves two concurrent app streams (video detection + classifier)
//!    through the virtual-time engine under the high condition.
//!
//! ```sh
//! make artifacts && cargo run --release --example concurrent_serving
//! ```

use std::path::PathBuf;

use adaoper::config::schema::{ConditionKind, PolicyKind};
use adaoper::coordinator::live::{ExecutorFactory, LiveSession};
use adaoper::coordinator::{Engine, EngineConfig, StreamSpec};
use adaoper::graph::zoo;
use adaoper::partition::dp::DpPartitioner;
use adaoper::partition::{Objective, Partitioner};
use adaoper::profiler::calibrate::{calibrate, CalibConfig};
use adaoper::profiler::corrector::GruCorrector;
use adaoper::profiler::EnergyProfiler;
use adaoper::runtime::session::{gru_infer_fn, ArtifactExecutor};
use adaoper::soc::device::{Device, DeviceConfig};
use adaoper::workload::{Arrival, WorkloadCondition};

fn artifacts_dir() -> anyhow::Result<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        dir.join("manifest.txt").exists(),
        "artifacts not found — run `make artifacts` first"
    );
    Ok(dir)
}

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir()?;

    // ---------------------------------------------------------------
    // Part 1: real HLO numerics through per-processor worker threads
    // ---------------------------------------------------------------
    println!("== part 1: PJRT serving of the executable model ==");
    let g = zoo::tiny_exec();
    let mut device = Device::new(DeviceConfig::snapdragon_855());
    device.apply_condition(&WorkloadCondition::moderate().spec);

    // plan with the AdaOper DP against the device oracle (quick demo)
    let snap = device.snapshot();
    let plan = DpPartitioner::new(Objective::MinEdp).partition(&g, &device, &snap)?;
    println!(
        "plan: {}",
        plan.placements
            .iter()
            .map(|p| p.label())
            .collect::<Vec<_>>()
            .join(" ")
    );

    // each worker thread builds its own PJRT executor from the artifacts
    let dir2 = dir.clone();
    let factory: ExecutorFactory = Box::new(move || {
        Box::new(ArtifactExecutor::new(&dir2).expect("artifacts load"))
    });
    let n_in: usize = g.input_shape.elems() as usize;
    let input: Vec<f32> = (0..n_in).map(|i| ((i % 97) as f32 - 48.0) / 97.0).collect();
    let n_requests = 24;
    let wall0 = std::time::Instant::now();
    let (report, output) =
        LiveSession::run(&g, &plan, &mut device, factory, n_requests, input)?;
    let wall = wall0.elapsed().as_secs_f64();
    print!("{}", report.pretty());
    println!(
        "real compute: {} requests in {:.2}s wall ({:.1} req/s host throughput)",
        n_requests,
        wall,
        n_requests as f64 / wall
    );

    // validate against the JAX golden values
    let golden = std::fs::read_to_string(dir.join("golden.txt"))?;
    let mut checked = 0;
    for line in golden.lines().filter(|l| !l.starts_with('#') && !l.trim().is_empty()) {
        let mut it = line.split_whitespace();
        let idx: usize = it.next().unwrap().parse()?;
        let want: f32 = it.next().unwrap().parse()?;
        let got = output[idx];
        anyhow::ensure!(
            (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
            "golden mismatch at {idx}: {got} vs {want}"
        );
        checked += 1;
    }
    println!("numerics: {checked} golden values match JAX ✓\n");

    // ---------------------------------------------------------------
    // Part 2: concurrent streams with the real GRU corrector
    // ---------------------------------------------------------------
    println!("== part 2: concurrent serving with the AOT GRU corrector ==");
    let calib = CalibConfig {
        samples: 3000,
        seed: 7,
        gbdt: adaoper::profiler::gbdt::GbdtParams {
            trees: 80,
            ..Default::default()
        },
    };
    let offline = calibrate(&calib);
    let dir3 = dir.clone();
    let profiler = EnergyProfiler::with_correctors(offline, || {
        let infer = gru_infer_fn(&dir3, 8).expect("gru artifact");
        Box::new(GruCorrector::new(8, infer))
    });
    let mut engine = Engine::with_profiler(
        EngineConfig {
            policy: PolicyKind::AdaOper,
            condition: ConditionKind::High,
            duration_s: 6.0,
            seed: 11,
            calib,
            ..Default::default()
        },
        profiler,
    );
    let streams = vec![
        StreamSpec::new(0, zoo::yolov2(), Arrival::Periodic { hz: 3.0, jitter: 0.02 }, 0.6),
        StreamSpec::new(1, zoo::mobilenet_v1(), Arrival::Poisson { hz: 5.0 }, 0.3),
    ];
    let report = engine.run(&streams)?;
    print!("{}", report.pretty());
    println!(
        "profiler corrector: {} (drift stat {:.3})",
        engine.profiler().corrector_name(),
        engine.profiler().drift_stat()
    );
    Ok(())
}
