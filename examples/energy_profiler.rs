//! Energy-profiler adaptation demo (ablation A1): drive the device through
//! idle → moderate → high → moderate and watch each predictor arm's error,
//! including the real AOT-compiled GRU corrector when artifacts exist.
//!
//! ```sh
//! make artifacts && cargo run --release --example energy_profiler
//! ```

use std::path::PathBuf;

use adaoper::experiments::ablations;
use adaoper::profiler::calibrate::CalibConfig;
use adaoper::profiler::corrector::{Corrector, GruCorrector};
use adaoper::profiler::gbdt::GbdtParams;
use adaoper::runtime::session::gru_infer_fn;

fn main() -> anyhow::Result<()> {
    let calib = CalibConfig {
        samples: 4000,
        seed: 3,
        gbdt: GbdtParams {
            trees: 100,
            ..Default::default()
        },
    };
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let gru: Option<Box<dyn FnMut() -> Box<dyn Corrector>>> =
        if dir.join("manifest.txt").exists() {
            let d = dir.clone();
            Some(Box::new(move || {
                let infer = gru_infer_fn(&d, 8).expect("gru artifact");
                Box::new(GruCorrector::new(8, infer))
            }))
        } else {
            adaoper::log_warn!("artifacts not built — skipping the GRU arm; run `make artifacts`");
            None
        };

    let rows = ablations::profiler_accuracy(&calib, 3.0, 11, gru)?;
    println!(
        "{:<12} {:>14} {:>14} {:>8}",
        "arm", "energy MAPE", "latency MAPE", "obs"
    );
    for r in &rows {
        println!(
            "{:<12} {:>13.1}% {:>13.1}% {:>8}",
            r.arm, r.energy_mape, r.latency_mape, r.observations
        );
    }
    println!("\n(the paper's profiler = offline GBDT + runtime GRU correction)");
    Ok(())
}
