//! Reproduce the paper's Figure 2: YOLOv2 on the simulated Xiaomi 9 under
//! moderate/high workload conditions, MACE-on-GPU vs CoDL vs AdaOper.
//!
//! ```sh
//! cargo run --release --example fig2_repro            # full budget
//! cargo run --release --example fig2_repro -- quick   # smaller budget
//! ```

use adaoper::experiments::fig2;
use adaoper::profiler::calibrate::CalibConfig;
use adaoper::profiler::gbdt::GbdtParams;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "quick");
    let cfg = fig2::Fig2Config {
        model: "yolov2".into(),
        n_requests: if quick { 15 } else { 40 },
        seed: 7,
        calib: if quick {
            CalibConfig {
                samples: 2500,
                seed: 42,
                gbdt: GbdtParams {
                    trees: 80,
                    ..Default::default()
                },
            }
        } else {
            CalibConfig::default()
        },
    };
    adaoper::log_info!(
        "running Figure 2 matrix ({} requests/cell, {} calibration samples) …",
        cfg.n_requests,
        cfg.calib.samples
    );
    let rows = fig2::run(&cfg)?;
    print!("{}", fig2::render(&rows));
    Ok(())
}
