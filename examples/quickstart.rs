//! Quickstart: plan an energy-aware partition for YOLOv2 on the simulated
//! Snapdragon 855 and serve a few inferences with it.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use adaoper::config::schema::{ConditionKind, PolicyKind};
use adaoper::coordinator::{Engine, EngineConfig, StreamSpec};
use adaoper::graph::zoo;
use adaoper::profiler::calibrate::CalibConfig;
use adaoper::profiler::gbdt::GbdtParams;
use adaoper::soc::Placement;
use adaoper::workload::Arrival;

fn main() -> anyhow::Result<()> {
    // 1. pick the workload: full YOLOv2 (the paper's Figure-2 model)
    let model = zoo::yolov2();
    println!(
        "model {}: {} ops, {:.1} GFLOPs",
        model.name,
        model.num_ops(),
        model.total_flops() as f64 / 1e9
    );

    // 2. build the serving engine: this calibrates the offline GBDT energy
    //    model on the simulated device (once), wires the runtime corrector,
    //    and selects the AdaOper DP partitioner.
    let mut engine = Engine::new(EngineConfig {
        policy: PolicyKind::AdaOper,
        condition: ConditionKind::Moderate,
        seed: 7,
        calib: CalibConfig {
            samples: 3000, // quick calibration for the demo
            seed: 7,
            gbdt: GbdtParams {
                trees: 80,
                ..Default::default()
            },
        },
        ..Default::default()
    });

    // 3. run 15 back-to-back inferences (closed loop)
    let spec = StreamSpec::new(0, model, Arrival::Poisson { hz: 10.0 }, 0.5);
    let report = engine.run_closed_loop(&spec, 15)?;
    print!("{}", report.pretty());

    // 4. peek at the kind of plan AdaOper chose
    let g = zoo::yolov2();
    let plan = adaoper::partition::dp::DpPartitioner::new(
        adaoper::partition::Objective::MinEdp,
    )
    .solve(&g, engine.profiler(), &engine.device().snapshot())?;
    let splits = plan
        .placements
        .iter()
        .filter(|p| matches!(p, Placement::Split { .. }))
        .count();
    println!(
        "\ncurrent plan: {} ops co-executed (split), {} GPU-only, {} CPU-only",
        splits,
        plan.placements.iter().filter(|&&p| p == Placement::GPU).count(),
        plan.placements.iter().filter(|&&p| p == Placement::CPU).count(),
    );
    Ok(())
}
