//! Policy × condition sweep: open-loop serving of YOLOv2, printing one
//! Figure-2-style row per combination — a quick scan of the whole design
//! space the paper's evaluation slices.
//!
//! ```sh
//! cargo run --release --example workload_sweep
//! ```

use adaoper::config::schema::{ConditionKind, PolicyKind};
use adaoper::coordinator::{Engine, EngineConfig, StreamSpec};
use adaoper::graph::zoo;
use adaoper::profiler::calibrate::CalibConfig;
use adaoper::profiler::gbdt::GbdtParams;
use adaoper::workload::Arrival;

fn main() -> anyhow::Result<()> {
    let calib = CalibConfig {
        samples: 3000,
        seed: 5,
        gbdt: GbdtParams {
            trees: 80,
            ..Default::default()
        },
    };
    for condition in [ConditionKind::Idle, ConditionKind::Moderate, ConditionKind::High] {
        for policy in [
            PolicyKind::AllCpu,
            PolicyKind::MaceGpu,
            PolicyKind::GreedyEnergy,
            PolicyKind::Codl,
            PolicyKind::AdaOper,
        ] {
            let mut engine = Engine::new(EngineConfig {
                policy,
                condition,
                duration_s: 5.0,
                seed: 13,
                calib: calib.clone(),
                ..Default::default()
            });
            let streams = vec![StreamSpec::new(
                0,
                zoo::yolov2(),
                Arrival::Poisson { hz: 2.0 },
                0.8,
            )];
            match engine.run(&streams) {
                Ok(r) => println!("{}", r.row()),
                Err(e) => println!("{:<14} {:<9} failed: {e}", policy.name(), condition.name()),
            }
        }
        println!();
    }
    Ok(())
}
