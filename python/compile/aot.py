"""AOT export: lower the L2/L1 computations to HLO *text* artifacts.

Interchange format is HLO text (NOT serialized HloModuleProto): jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 rust crate binds) rejects; the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example.

Outputs (under --out, default ../artifacts):
  tiny_exec_<op>.hlo.txt   one per operator of the executable model
  tiny_exec_full.hlo.txt   the whole model in one computation
  gru.hlo.txt              the trained GRU corrector (window -> scalar)
  manifest.txt             op -> artifact index with shapes (rust parses it)

Python runs ONCE at build time (`make artifacts`); the rust binary never
imports it.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: the default printer elides large constants as `{...}`,
    # which the consuming parser (xla_extension 0.5.1) silently reads as
    # zeros — every baked weight would vanish. Print with full constants.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # newer XLA emits metadata attributes (source_end_line, …) the 0.5.1
    # parser rejects — strip metadata entirely.
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def shape_str(shape) -> str:
    return "x".join(str(d) for d in shape)


def export(out_dir: str, gru_steps: int = 300, verbose: bool = True):
    os.makedirs(out_dir, exist_ok=True)
    params = model.tiny_exec_params()
    manifest = []

    # --- per-op artifacts
    x_shape = model.INPUT_SHAPE
    for name, in_shape, out_shape in model.op_shapes(params):
        fn = lambda x, _name=name: (model.op_forward(_name, params, x),)
        spec = jax.ShapeDtypeStruct(in_shape, jnp.float32)
        text = to_hlo_text(jax.jit(fn).lower(spec))
        fname = f"tiny_exec_{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest.append(
            f"tiny-exec/{name} {fname} {shape_str(in_shape)} {shape_str(out_shape)}"
        )
        if verbose:
            print(f"  wrote {fname} ({len(text)} chars)")

    # --- full model
    spec = jax.ShapeDtypeStruct(x_shape, jnp.float32)
    full = lambda x: (model.tiny_exec_forward(params, x),)
    text = to_hlo_text(jax.jit(full).lower(spec))
    with open(os.path.join(out_dir, "tiny_exec_full.hlo.txt"), "w") as f:
        f.write(text)
    out_shape = model.op_shapes(params)[-1][2]
    manifest.append(
        f"tiny-exec/full tiny_exec_full.hlo.txt {shape_str(x_shape)} {shape_str(out_shape)}"
    )
    if verbose:
        print(f"  wrote tiny_exec_full.hlo.txt ({len(text)} chars)")

    # --- GRU corrector (trained on synthetic drift traces)
    gparams, losses = model.gru_train(steps=gru_steps)
    if verbose:
        print(f"  gru train loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    gfn = lambda w: (model.gru_predict(gparams, w),)
    gspec = jax.ShapeDtypeStruct((model.GRU_WINDOW, model.GRU_IN_FEATURES), jnp.float32)
    text = to_hlo_text(jax.jit(gfn).lower(gspec))
    with open(os.path.join(out_dir, "gru.hlo.txt"), "w") as f:
        f.write(text)
    manifest.append(
        f"gru/predict gru.hlo.txt {model.GRU_WINDOW}x{model.GRU_IN_FEATURES} 1"
    )
    if verbose:
        print(f"  wrote gru.hlo.txt ({len(text)} chars)")

    # --- cross-language golden values: run the full model in python on a
    # deterministic input and record sampled outputs; the rust runtime
    # test replays the same input through the artifacts and compares.
    # (Guards against silent HLO-text corruption — e.g. elided constants.)
    import numpy as np
    n_in = 1
    for d in model.INPUT_SHAPE:
        n_in *= d
    golden_in = (np.arange(n_in) % 97 - 48.0).astype(np.float32) / 97.0
    golden_out = np.asarray(
        model.tiny_exec_forward(params, jnp.asarray(golden_in.reshape(model.INPUT_SHAPE)))
    ).reshape(-1)
    with open(os.path.join(out_dir, "golden.txt"), "w") as f:
        f.write("# idx value — tiny-exec/full outputs for the canonical input\n")
        for idx in range(0, golden_out.size, max(1, golden_out.size // 64)):
            f.write(f"{idx} {golden_out[idx]:.6e}\n")
    if verbose:
        print(f"  wrote golden.txt")

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("# name file in_shape out_shape\n")
        f.write("\n".join(manifest) + "\n")
    if verbose:
        print(f"  wrote manifest.txt ({len(manifest)} entries)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--gru-steps", type=int, default=300)
    args = ap.parse_args()
    export(args.out, gru_steps=args.gru_steps)


if __name__ == "__main__":
    main()
