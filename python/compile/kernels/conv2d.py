"""L1/L2 bridge: convolution as im2col (jnp) + Pallas GEMM (kernels.matmul).

`conv2d` is what the model's conv blocks call; its numerics are pinned to
`ref.conv2d_ref` by pytest. Depth of the Pallas path: the GEMM — which is
where ~99 % of the FLOPs live — runs inside the Pallas kernel.
"""

import jax.numpy as jnp

from . import matmul
from . import ref


def conv2d(x, w, b, stride=1, pad=1, act="leaky"):
    """NCHW convolution via im2col + Pallas GEMM.

    x: [N, C, H, W]; w: [O, C, kh, kw]; b: [O] → [N, O, OH, OW].
    """
    o, c, kh, kw = w.shape
    cols, (n, oh, ow) = ref.im2col(x, kh, kw, stride=stride, pad=pad)
    w2 = w.reshape(o, c * kh * kw).T  # [C*kh*kw, O]
    y = matmul.matmul_bias_act(cols, w2, b, act=act)  # [N*OH*OW, O]
    return y.reshape(n, oh, ow, o).transpose(0, 3, 1, 2)


def maxpool2x2(x):
    """2x2/2 max pool (L2 op — bandwidth-bound, no Pallas needed)."""
    return ref.maxpool2x2_ref(x)
