"""L1 Pallas kernel: fused GRU cell (the profiler's runtime corrector).

One Pallas program computes all three gates for a step: both input and
recurrent projections are issued as MXU-shaped dots on VMEM-resident
blocks, with the gate nonlinearities fused. The L2 sequence model
(`model.gru_predict`) scans this cell over the residual window.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cell_kernel(x_ref, h_ref, wx_ref, wh_ref, b_ref, o_ref):
    x = x_ref[...]  # [1, F]
    h = h_ref[...]  # [1, H]
    wx = wx_ref[...]  # [F, 3H]
    wh = wh_ref[...]  # [H, 3H]
    b = b_ref[...]  # [3H]
    hidden = h.shape[-1]
    gx = jnp.dot(x, wx, preferred_element_type=jnp.float32) + b[None, :]
    gh = jnp.dot(h, wh, preferred_element_type=jnp.float32)
    r = jax.nn.sigmoid(gx[:, :hidden] + gh[:, :hidden])
    z = jax.nn.sigmoid(gx[:, hidden : 2 * hidden] + gh[:, hidden : 2 * hidden])
    n = jnp.tanh(gx[:, 2 * hidden :] + r * gh[:, 2 * hidden :])
    o_ref[...] = ((1.0 - z) * n + z * h).astype(o_ref.dtype)


@jax.jit
def gru_cell(x, h, wx, wh, b):
    """One GRU step. x: [F], h: [H] → [H]. Weights as in ref.gru_cell_ref."""
    f = x.shape[0]
    hidden = h.shape[0]
    out = pl.pallas_call(
        _cell_kernel,
        out_shape=jax.ShapeDtypeStruct((1, hidden), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(
        x.reshape(1, f),
        h.reshape(1, hidden),
        wx,
        wh,
        b,
    )
    return out[0]


@functools.partial(jax.jit, static_argnames=())
def gru_sequence(window, wx, wh, b, wo, bo):
    """Scan the Pallas cell over a [K, F] window; dense head → scalar."""
    hidden = wh.shape[0]
    h0 = jnp.zeros((hidden,), jnp.float32)

    def step(h, x_t):
        return gru_cell(x_t, h, wx, wh, b), None

    h, _ = jax.lax.scan(step, h0, window)
    return h @ wo + bo
