"""L1 Pallas kernel: tiled matmul with fused bias + activation.

This is the compute hot-spot of the executable model: every convolution is
lowered to im2col (L2, jnp) followed by this GEMM kernel, so the Pallas
kernel sits on the path of every conv block artifact.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles M×N output
blocks; each program loads an (bm × K) LHS stripe and (K × bn) RHS stripe
into VMEM-like block memory and issues one MXU-shaped `dot`. On real TPU
hardware the same BlockSpec schedule double-buffers HBM→VMEM; under
`interpret=True` (mandatory on this CPU-only PJRT build) the schedule runs
as a grid loop with identical numerics.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block shape: multiples of the 128×128 MXU tile are ideal on TPU;
# the executable model's GEMMs are small, so blocks are modest.
BM, BN = 128, 128


def _kernel(a_ref, b_ref, bias_ref, o_ref, *, act: str, alpha: float):
    a = a_ref[...]
    b = b_ref[...]
    acc = jnp.dot(a, b, preferred_element_type=jnp.float32)
    acc = acc + bias_ref[...][None, :]
    if act == "leaky":
        acc = jnp.where(acc >= 0, acc, alpha * acc)
    elif act == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif act != "linear":
        raise ValueError(f"unknown act {act}")
    o_ref[...] = acc.astype(o_ref.dtype)


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("act", "alpha", "bm", "bn"))
def matmul_bias_act(a, b, bias, act="linear", alpha=0.1, bm=BM, bn=BN):
    """act(a @ b + bias) with a Pallas-tiled GEMM.

    a: [M, K] f32; b: [K, N] f32; bias: [N] f32 → [M, N] f32.
    Shapes are padded up to block multiples and sliced back, so any size
    works; K is kept whole per block (the model's K ≤ 1152 fits VMEM).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"K mismatch {k} vs {k2}"
    assert bias.shape == (n,)
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    a_p = jnp.pad(a, ((0, mp - m), (0, 0)))
    b_p = jnp.pad(b, ((0, 0), (0, np_ - n)))
    bias_p = jnp.pad(bias, (0, np_ - n))
    grid = (mp // bm, np_ // bn)
    out = pl.pallas_call(
        functools.partial(_kernel, act=act, alpha=alpha),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(a_p, b_p, bias_p)
    return out[:m, :n]


def vmem_bytes(bm: int, bn: int, k: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM footprint per program (perf analysis, DESIGN.md §Perf)."""
    return dtype_bytes * (bm * k + k * bn + bn + bm * bn)
