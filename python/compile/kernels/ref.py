"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every kernel in this package must match its oracle here to float32
tolerance under pytest (the CORE correctness signal of the build path).
"""

import jax
import jax.numpy as jnp


def leaky_relu(x, alpha=0.1):
    """YOLO's leaky ReLU."""
    return jnp.where(x >= 0, x, alpha * x)


def matmul_bias_act_ref(a, b, bias, act="linear", alpha=0.1):
    """C = act(A @ B + bias). a: [M, K], b: [K, N], bias: [N]."""
    c = a @ b + bias[None, :]
    if act == "leaky":
        return leaky_relu(c, alpha)
    if act == "relu":
        return jnp.maximum(c, 0.0)
    if act == "linear":
        return c
    raise ValueError(f"unknown act {act}")


def conv2d_ref(x, w, b, stride=1, pad=1, act="leaky"):
    """NCHW conv oracle via lax.conv_general_dilated.

    x: [N, C, H, W]; w: [O, C, kh, kw]; b: [O].
    """
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    y = y + b[None, :, None, None]
    if act == "leaky":
        return leaky_relu(y)
    if act == "relu":
        return jnp.maximum(y, 0.0)
    return y


def maxpool2x2_ref(x):
    """2x2/2 max pool, NCHW."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, 1, 2, 2),
        window_strides=(1, 1, 2, 2),
        padding="VALID",
    )


def im2col(x, kh, kw, stride=1, pad=1):
    """Unfold NCHW x into [N*OH*OW, C*kh*kw] patches (GEMM lowering of conv).

    Column order matches w.reshape(O, C*kh*kw).T - i.e. (C, kh, kw) row-major.
    """
    n, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    patches = []
    for i in range(kh):
        for j in range(kw):
            patches.append(
                jax.lax.slice(
                    xp,
                    (0, 0, i, j),
                    (n, c, i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1),
                    (1, 1, stride, stride),
                )
            )  # [N, C, OH, OW]
    # [kh*kw, N, C, OH, OW] -> [N, OH, OW, C, kh*kw] -> [N*OH*OW, C*kh*kw]
    stack = jnp.stack(patches, axis=0)
    stack = stack.transpose(1, 3, 4, 2, 0)
    return stack.reshape(n * oh * ow, c * kh * kw), (n, oh, ow)


def gru_cell_ref(x, h, wx, wh, b):
    """Standard GRU cell.

    x: [F], h: [H], wx: [F, 3H], wh: [H, 3H], b: [3H].
    Gate order: reset (r), update (z), candidate (n).
    """
    hidden = h.shape[-1]
    gx = x @ wx + b
    gh = h @ wh
    r = jax.nn.sigmoid(gx[:hidden] + gh[:hidden])
    z = jax.nn.sigmoid(gx[hidden : 2 * hidden] + gh[hidden : 2 * hidden])
    n = jnp.tanh(gx[2 * hidden :] + r * gh[2 * hidden :])
    return (1.0 - z) * n + z * h


def gru_seq_ref(window, wx, wh, b, wo, bo):
    """Run the GRU over a [K, F] window, then a dense head -> scalar."""
    h = jnp.zeros(wh.shape[0], window.dtype)
    for t in range(window.shape[0]):
        h = gru_cell_ref(window[t], h, wx, wh, b)
    return h @ wo + bo
