"""L2: the executable model (JAX forward functions calling the L1 Pallas
kernels) and the GRU runtime corrector.

The conv-block graph here MUST stay in sync with the rust zoo's
`tiny_exec()` (rust/src/graph/zoo.rs): `aot.py` exports one HLO artifact
per operator below and the rust runtime executes them per the partition
plan. Weights are deterministic (seeded) and baked into the artifacts as
constants, so the rust side only ever passes activations.
"""

import jax
import jax.numpy as jnp

from .kernels import conv2d as k_conv
from .kernels import gru as k_gru
from .kernels import ref as k_ref

# ---------------------------------------------------------------------------
# tiny-exec: the executable conv net (input 1x3x64x64, see zoo::tiny_exec)
# ---------------------------------------------------------------------------

INPUT_SHAPE = (1, 3, 64, 64)

# (name, kind, params) in topological order; must mirror rust zoo.
TINY_EXEC_OPS = [
    ("conv1", "conv", dict(out_c=8, k=3, stride=1, pad=1, act="leaky")),
    ("pool1", "pool", {}),
    ("conv2", "conv", dict(out_c=16, k=3, stride=1, pad=1, act="leaky")),
    ("pool2", "pool", {}),
    ("conv3", "conv", dict(out_c=32, k=3, stride=1, pad=1, act="leaky")),
    ("pool3", "pool", {}),
    ("conv4", "conv", dict(out_c=64, k=3, stride=1, pad=1, act="leaky")),
    ("conv5", "conv", dict(out_c=20, k=1, stride=1, pad=0, act="linear")),
]


def tiny_exec_params(seed: int = 0):
    """Deterministic He-style init for every conv op."""
    key = jax.random.PRNGKey(seed)
    params = {}
    in_c = INPUT_SHAPE[1]
    for name, kind, p in TINY_EXEC_OPS:
        if kind != "conv":
            continue
        key, kw, kb = jax.random.split(key, 3)
        fan_in = in_c * p["k"] * p["k"]
        w = jax.random.normal(kw, (p["out_c"], in_c, p["k"], p["k"]), jnp.float32)
        w = w * jnp.sqrt(2.0 / fan_in)
        b = 0.01 * jax.random.normal(kb, (p["out_c"],), jnp.float32)
        params[name] = (w, b)
        in_c = p["out_c"]
    return params


def op_forward(name: str, params, x):
    """Forward one named operator (artifact granularity)."""
    for n, kind, p in TINY_EXEC_OPS:
        if n != name:
            continue
        if kind == "conv":
            w, b = params[name]
            return k_conv.conv2d(x, w, b, stride=p["stride"], pad=p["pad"], act=p["act"])
        return k_conv.maxpool2x2(x)
    raise KeyError(f"unknown op {name}")


def op_shapes(params):
    """Input/output shape per op, in topo order (manifest generation)."""
    x = jnp.zeros(INPUT_SHAPE, jnp.float32)
    shapes = []
    for name, _, _ in TINY_EXEC_OPS:
        in_shape = x.shape
        x = op_forward(name, params, x)
        shapes.append((name, in_shape, x.shape))
    return shapes


def tiny_exec_forward(params, x):
    """Full model: chained ops (quickstart artifact + validation)."""
    for name, _, _ in TINY_EXEC_OPS:
        x = op_forward(name, params, x)
    return x


# ---------------------------------------------------------------------------
# GRU corrector (profiler runtime stage)
# ---------------------------------------------------------------------------

GRU_WINDOW = 8     # must match profiler::corrector usage in rust
GRU_IN_FEATURES = 4  # must match corrector::GRU_IN_FEATURES
GRU_HIDDEN = 16


def gru_init(seed: int = 1):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / jnp.sqrt(GRU_HIDDEN)
    return {
        "wx": s * jax.random.normal(k1, (GRU_IN_FEATURES, 3 * GRU_HIDDEN), jnp.float32),
        "wh": s * jax.random.normal(k2, (GRU_HIDDEN, 3 * GRU_HIDDEN), jnp.float32),
        "b": jnp.zeros((3 * GRU_HIDDEN,), jnp.float32),
        "wo": s * jax.random.normal(k3, (GRU_HIDDEN,), jnp.float32),
        "bo": 0.0 * jax.random.normal(k4, ()),
    }


def gru_predict(params, window):
    """Predicted next log-residual from a [K, F] residual window."""
    return k_gru.gru_sequence(
        window, params["wx"], params["wh"], params["b"], params["wo"], params["bo"]
    )


# --- offline training on synthetic drift traces -----------------------------
# The simulator's hidden drift is an OU process on the log factor plus
# bursty background; we train the GRU on exactly that family (the
# real-system analogue: traces recorded on the device fleet).


def _gen_traces(key, n_traces: int, length: int, theta=0.15, sigma=0.10, noise=0.05):
    """OU log-residual traces + synthetic monitor features. [T, L, F]."""
    def one(key):
        k1, k2, k3 = jax.random.split(key, 3)
        dt = 0.2
        eps = jax.random.normal(k1, (length,)) * sigma * jnp.sqrt(dt)

        def step(x, e):
            x = x + (-theta * x) * dt + e
            return x, x

        _, xs = jax.lax.scan(step, 0.0, eps)
        obs = xs + noise * jax.random.normal(k2, (length,))
        util = 0.4 + 0.1 * jax.random.normal(k3, (length,))
        feats = jnp.stack(
            [obs, util, 0.1 * jnp.ones_like(obs), 0.45 * jnp.ones_like(obs)], axis=-1
        )
        return feats, xs

    keys = jax.random.split(key, n_traces)
    feats, truth = jax.vmap(one)(keys)
    return feats, truth


def gru_train(seed: int = 2, n_traces: int = 96, length: int = 48,
              steps: int = 300, lr: float = 1e-2):
    """Fit the GRU to predict the next true log-residual from the window.

    Optimized with Adam (plain SGD underfits the gated recurrence badly).
    """
    params = gru_init(seed)
    key = jax.random.PRNGKey(seed + 100)
    feats, truth = _gen_traces(key, n_traces, length, sigma=0.16)

    # windows: [B, K, F] -> target next true residual [B]
    xs, ys = [], []
    for t in range(GRU_WINDOW, length - 1):
        xs.append(feats[:, t - GRU_WINDOW : t, :])
        ys.append(truth[:, t])
    x = jnp.concatenate(xs, axis=0)
    y = jnp.concatenate(ys, axis=0)

    # NOTE: training differentiates the pure-jnp reference (pallas_call has
    # no VJP under interpret mode); pytest pins the Pallas cell to the same
    # math, and the exported artifact uses the Pallas path.
    def loss_fn(p):
        pred = jax.vmap(
            lambda w: k_ref.gru_seq_ref(w, p["wx"], p["wh"], p["b"], p["wo"], p["bo"])
        )(x)
        return jnp.mean((pred - y) ** 2)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    # Adam
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    b1, b2, eps = 0.9, 0.999, 1e-8
    losses = []
    for t in range(1, steps + 1):
        l, g = grad_fn(params)
        losses.append(float(l))
        m = jax.tree.map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
        v = jax.tree.map(lambda a, b: b2 * a + (1 - b2) * b * b, v, g)
        mhat = jax.tree.map(lambda a: a / (1 - b1**t), m)
        vhat = jax.tree.map(lambda a: a / (1 - b2**t), v)
        params = jax.tree.map(
            lambda p, mm, vv: p - lr * mm / (jnp.sqrt(vv) + eps), params, mhat, vhat
        )
    return params, losses
