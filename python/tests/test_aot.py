"""AOT export tests: HLO text artifacts are produced, well-formed, and the
manifest is consistent. Uses a tmpdir and a tiny GRU training budget."""

import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.export(out, gru_steps=3, verbose=False)
    return out


def test_all_artifacts_exist(exported):
    names = [f"tiny_exec_{n}.hlo.txt" for n, _, _ in model.TINY_EXEC_OPS]
    names += ["tiny_exec_full.hlo.txt", "gru.hlo.txt", "manifest.txt"]
    for n in names:
        p = os.path.join(exported, n)
        assert os.path.exists(p), n
        assert os.path.getsize(p) > 0, n


def test_hlo_text_wellformed(exported):
    for n in os.listdir(exported):
        if not n.endswith(".hlo.txt"):
            continue
        text = open(os.path.join(exported, n)).read()
        assert "HloModule" in text, n
        assert "ENTRY" in text, n


def test_manifest_consistent(exported):
    lines = [
        l
        for l in open(os.path.join(exported, "manifest.txt")).read().splitlines()
        if l and not l.startswith("#")
    ]
    assert len(lines) == len(model.TINY_EXEC_OPS) + 2  # + full + gru
    for line in lines:
        name, fname, in_s, out_s = line.split()
        assert os.path.exists(os.path.join(exported, fname)), fname
        assert all(p.isdigit() for p in in_s.split("x"))
        assert all(p.isdigit() for p in out_s.split("x"))


def test_manifest_shapes_match_model(exported):
    params = model.tiny_exec_params()
    shapes = {f"tiny-exec/{n}": (i, o) for n, i, o in model.op_shapes(params)}
    for line in open(os.path.join(exported, "manifest.txt")).read().splitlines():
        if not line or line.startswith("#") or not line.startswith("tiny-exec/"):
            continue
        name, _, in_s, out_s = line.split()
        if name == "tiny-exec/full":
            continue
        want_in, want_out = shapes[name]
        assert in_s == "x".join(map(str, want_in))
        assert out_s == "x".join(map(str, want_out))
