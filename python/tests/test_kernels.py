"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes; assert_allclose against ref.py is the core
correctness signal for everything the artifacts contain.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d as k_conv
from compile.kernels import gru as k_gru
from compile.kernels import matmul as k_mm
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------- matmul ---

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 40),
    n=st.integers(1, 70),
    act=st.sampled_from(["linear", "relu", "leaky"]),
)
def test_matmul_matches_ref_swept(m, k, n, act):
    a = rand(m * 7 + 1, m, k)
    b = rand(n * 13 + 2, k, n)
    bias = rand(k * 3 + 5, n)
    got = k_mm.matmul_bias_act(a, b, bias, act=act, bm=32, bn=32)
    want = ref.matmul_bias_act_ref(a, b, bias, act=act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_matmul_multi_block_grid():
    # force a >1x1 grid so BlockSpec indexing is actually exercised
    a = rand(1, 300, 64)
    b = rand(2, 64, 260)
    bias = rand(3, 260)
    got = k_mm.matmul_bias_act(a, b, bias, act="leaky", bm=128, bn=128)
    want = ref.matmul_bias_act_ref(a, b, bias, act="leaky")
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_matmul_rejects_bad_k():
    a = rand(1, 4, 5)
    b = rand(2, 6, 3)
    bias = rand(3, 3)
    with pytest.raises(AssertionError):
        k_mm.matmul_bias_act(a, b, bias)


def test_vmem_estimate_positive():
    assert k_mm.vmem_bytes(128, 128, 1152) > 0


# ---------------------------------------------------------------- conv ----

@settings(max_examples=12, deadline=None)
@given(
    c=st.integers(1, 8),
    o=st.integers(1, 12),
    hw=st.sampled_from([6, 8, 12]),
    k=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    act=st.sampled_from(["leaky", "linear"]),
)
def test_conv2d_matches_lax_swept(c, o, hw, k, stride, act):
    pad = k // 2
    x = rand(c * 11 + o, 1, c, hw, hw)
    w = rand(o * 17 + 3, o, c, k, k)
    b = rand(5, o)
    got = k_conv.conv2d(x, w, b, stride=stride, pad=pad, act=act)
    want = ref.conv2d_ref(x, w, b, stride=stride, pad=pad, act=act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv2d_model_scale():
    # the heaviest tiny-exec conv: 32->64 @ 16x16
    x = rand(1, 1, 32, 16, 16)
    w = rand(2, 64, 32, 3, 3)
    b = rand(3, 64)
    got = k_conv.conv2d(x, w, b)
    want = ref.conv2d_ref(x, w, b)
    assert got.shape == (1, 64, 16, 16)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_maxpool_matches():
    x = rand(7, 2, 3, 8, 8)
    got = k_conv.maxpool2x2(x)
    assert got.shape == (2, 3, 4, 4)
    # identical op, but check against manual strided max
    want = ref.maxpool2x2_ref(x)
    np.testing.assert_allclose(got, want)


def test_im2col_reconstructs_conv():
    x = rand(1, 1, 3, 10, 10)
    w = rand(2, 5, 3, 3, 3)
    b = jnp.zeros((5,), jnp.float32)
    cols, (n, oh, ow) = ref.im2col(x, 3, 3, stride=1, pad=1)
    y = (cols @ w.reshape(5, -1).T).reshape(n, oh, ow, 5).transpose(0, 3, 1, 2)
    want = ref.conv2d_ref(x, w, b, act="linear")
    np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- gru -----

@settings(max_examples=15, deadline=None)
@given(f=st.integers(1, 8), h=st.integers(1, 24), seed=st.integers(0, 99))
def test_gru_cell_matches_ref_swept(f, h, seed):
    x = rand(seed, f)
    hh = rand(seed + 1, h)
    wx = rand(seed + 2, f, 3 * h)
    wh = rand(seed + 3, h, 3 * h)
    b = rand(seed + 4, 3 * h)
    got = k_gru.gru_cell(x, hh, wx, wh, b)
    want = ref.gru_cell_ref(x, hh, wx, wh, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_gru_sequence_matches_ref():
    k, f, h = 8, 4, 16
    window = rand(0, k, f)
    wx = rand(1, f, 3 * h)
    wh = rand(2, h, 3 * h)
    b = rand(3, 3 * h)
    wo = rand(4, h)
    bo = jnp.float32(0.3)
    got = k_gru.gru_sequence(window, wx, wh, b, wo, bo)
    want = ref.gru_seq_ref(window, wx, wh, b, wo, bo)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_gru_state_bounded():
    # GRU hidden state is a convex combo of tanh candidates: |h| <= 1
    k, f, h = 20, 4, 16
    window = 10.0 * rand(9, k, f)
    wx = rand(10, f, 3 * h)
    wh = rand(11, h, 3 * h)
    b = rand(12, 3 * h)
    hh = jnp.zeros((h,), jnp.float32)
    for t in range(k):
        hh = k_gru.gru_cell(window[t], hh, wx, wh, b)
        assert float(jnp.max(jnp.abs(hh))) <= 1.0 + 1e-5
