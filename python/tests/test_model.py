"""L2 model tests: shapes stay in sync with the rust zoo; full model =
chained ops; GRU training improves loss."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model

jax.config.update("jax_platform_name", "cpu")

# mirror of rust/src/graph/zoo.rs::tiny_exec expected shapes
EXPECTED_SHAPES = {
    "conv1": ((1, 3, 64, 64), (1, 8, 64, 64)),
    "pool1": ((1, 8, 64, 64), (1, 8, 32, 32)),
    "conv2": ((1, 8, 32, 32), (1, 16, 32, 32)),
    "pool2": ((1, 16, 32, 32), (1, 16, 16, 16)),
    "conv3": ((1, 16, 16, 16), (1, 32, 16, 16)),
    "pool3": ((1, 32, 16, 16), (1, 32, 8, 8)),
    "conv4": ((1, 32, 8, 8), (1, 64, 8, 8)),
    "conv5": ((1, 64, 8, 8), (1, 20, 8, 8)),
}


def test_op_shapes_match_rust_zoo():
    params = model.tiny_exec_params()
    for name, in_shape, out_shape in model.op_shapes(params):
        want_in, want_out = EXPECTED_SHAPES[name]
        assert in_shape == want_in, name
        assert out_shape == want_out, name


def test_full_equals_chained_ops():
    params = model.tiny_exec_params()
    x = jax.random.normal(jax.random.PRNGKey(3), model.INPUT_SHAPE, jnp.float32)
    full = model.tiny_exec_forward(params, x)
    y = x
    for name, _, _ in model.TINY_EXEC_OPS:
        y = model.op_forward(name, params, y)
    np.testing.assert_allclose(full, y, rtol=1e-6)


def test_params_deterministic():
    a = model.tiny_exec_params()
    b = model.tiny_exec_params()
    for k in a:
        np.testing.assert_array_equal(a[k][0], b[k][0])


def test_output_finite_and_nontrivial():
    params = model.tiny_exec_params()
    x = jax.random.normal(jax.random.PRNGKey(5), model.INPUT_SHAPE, jnp.float32)
    y = model.tiny_exec_forward(params, x)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(jnp.std(y)) > 1e-4


def test_gru_training_reduces_loss():
    _, losses = model.gru_train(steps=60, n_traces=16, length=24)
    assert losses[-1] < losses[0] * 0.9, (losses[0], losses[-1])


def test_gru_predict_shape():
    p = model.gru_init()
    w = jnp.zeros((model.GRU_WINDOW, model.GRU_IN_FEATURES), jnp.float32)
    out = model.gru_predict(p, w)
    assert out.shape == ()
