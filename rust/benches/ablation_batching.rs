//! Bench A9: batching sweep — energy-per-request and p95 latency vs batch
//! cap (none vs fixed vs deadline-aware slack formation) as bursty MMPP
//! load ramps through saturation.

use adaoper::experiments::batching_scenario::{self, BatchingSweepConfig};
use adaoper::profiler::calibrate::CalibConfig;
use adaoper::profiler::gbdt::GbdtParams;

fn main() {
    let quick = std::env::var("ADAOPER_BENCH_QUICK").is_ok();
    let calib = CalibConfig {
        samples: if quick { 2000 } else { 5000 },
        seed: 7,
        gbdt: GbdtParams {
            trees: if quick { 60 } else { 120 },
            ..Default::default()
        },
    };
    let cfg = BatchingSweepConfig {
        calib,
        duration_s: if quick { 3.0 } else { 5.0 },
        ..Default::default()
    };
    println!("== A9: batching sweep (bursty MMPP arrivals) ==");
    let res = batching_scenario::run(&cfg).unwrap();
    print!("{}", batching_scenario::render(&res));
}
