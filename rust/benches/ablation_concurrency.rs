//! Bench A5: concurrency scaling — 1–4 concurrent app streams per policy.

use adaoper::experiments::ablations;
use adaoper::profiler::calibrate::CalibConfig;
use adaoper::profiler::gbdt::GbdtParams;

fn main() {
    let quick = std::env::var("ADAOPER_BENCH_QUICK").is_ok();
    let calib = CalibConfig {
        samples: if quick { 2000 } else { 5000 },
        seed: 3,
        gbdt: GbdtParams { trees: if quick { 60 } else { 120 }, ..Default::default() },
    };
    println!("== A5: concurrent app streams (open loop, moderate) ==");
    let rows = ablations::concurrency_scaling(&calib, 7, if quick { 4.0 } else { 8.0 }).unwrap();
    println!(
        "{:<12} {:>8} {:>12} {:>10} {:>12} {:>8}",
        "policy", "streams", "req/s", "p90 ms", "mJ/inf", "miss%"
    );
    for r in rows {
        println!(
            "{:<12} {:>8} {:>12.2} {:>10.1} {:>12.1} {:>8.1}",
            r.policy.name(),
            r.streams,
            r.throughput_hz,
            r.p95_ms,
            r.mj_per_inf,
            r.miss_rate * 100.0
        );
    }
}
