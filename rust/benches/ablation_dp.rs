//! Bench A2/A6: DP optimality vs exhaustive + decision-time microbench
//! across the zoo and pruning resolutions.

use adaoper::experiments::ablations;
use adaoper::util::bench::{Bencher, black_box, print_table};
use adaoper::graph::zoo;
use adaoper::partition::dp::DpPartitioner;
use adaoper::partition::plan::Objective;
use adaoper::partition::Partitioner;
use adaoper::soc::device::{Device, DeviceConfig};
use adaoper::workload::WorkloadCondition;

fn main() {
    println!("== A2: optimality vs exhaustive (chain-8) + solve times ==");
    let rows = ablations::dp_comparison(5).unwrap();
    println!("{:<22} {:>14} {:>10} {:>12}", "case", "score", "rel", "solve µs");
    for r in &rows {
        println!("{:<22} {:>14.6} {:>10.4} {:>12.1}", r.case, r.score, r.relative, r.solve_us);
    }

    println!("\n== A6: DP solve-time microbench (oracle model, per graph) ==");
    let mut d = Device::new(DeviceConfig {
        noise_sigma: 0.0,
        drift_sigma: 0.0,
        ..DeviceConfig::snapdragon_855()
    });
    d.apply_condition(&WorkloadCondition::moderate().spec);
    let snap = d.snapshot();
    let b = Bencher::default();
    let mut results = Vec::new();
    for name in zoo::names() {
        let g = zoo::by_name(name).unwrap();
        let dp = DpPartitioner::new(Objective::MinEdp);
        results.push(b.run(&format!("dp-solve/{name}"), || {
            black_box(dp.partition(&g, &d, &snap).unwrap());
        }));
    }
    print_table("DP full-solve wall time", &results);
}
