//! Bench A8: fleet scale sweep — the sharded fleet simulator at growing
//! device counts (heterogeneous device-class zoo) under each dispatch
//! policy, reporting fleet-wide and budget-class tail latency, deadline
//! misses, and energy per request.

use adaoper::experiments::fleet_scenario::{self, FleetSweepConfig};
use adaoper::profiler::calibrate::CalibConfig;
use adaoper::profiler::gbdt::GbdtParams;

fn main() {
    let quick = std::env::var("ADAOPER_BENCH_QUICK").is_ok();
    let calib = CalibConfig {
        samples: if quick { 1500 } else { 4000 },
        seed: 7,
        gbdt: GbdtParams {
            trees: if quick { 40 } else { 100 },
            ..Default::default()
        },
    };
    let cfg = FleetSweepConfig {
        device_counts: if quick {
            vec![10, 50]
        } else {
            vec![10, 100, 1000]
        },
        duration_s: if quick { 1.0 } else { 1.5 },
        threads: 8,
        calib,
        ..Default::default()
    };
    println!("== A8: fleet scale sweep (device zoo × dispatch policy) ==");
    let rows = fleet_scenario::run(&cfg).unwrap();
    print!("{}", fleet_scenario::render(&rows));
}
