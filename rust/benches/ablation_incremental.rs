//! Bench A3: incremental (windowed) vs full repartitioning — decision time
//! and plan quality after a moderate→high condition switch.

use adaoper::experiments::ablations;

fn main() {
    println!("== A3: incremental vs full repartition (stale moderate plan, high device) ==");
    let rows = ablations::incremental_vs_full(&[2, 4, 8, 16]).unwrap();
    println!("{:<18} {:>14} {:>14}", "scheme", "decision µs", "EDP vs full");
    for r in rows {
        println!("{:<18} {:>14.1} {:>14.4}", r.scheme, r.decision_us, r.edp_vs_full);
    }
}
