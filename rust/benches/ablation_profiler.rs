//! Bench A1: profiler accuracy under dynamic conditions — static GBDT vs
//! GBDT+EWMA vs GBDT+GRU (real AOT artifact when built).

use std::path::PathBuf;

use adaoper::experiments::ablations;
use adaoper::profiler::calibrate::CalibConfig;
use adaoper::profiler::corrector::{Corrector, GruCorrector};
use adaoper::profiler::gbdt::GbdtParams;
use adaoper::runtime::session::gru_infer_fn;

fn main() {
    let quick = std::env::var("ADAOPER_BENCH_QUICK").is_ok();
    let calib = CalibConfig {
        samples: if quick { 2000 } else { 5000 },
        seed: 3,
        gbdt: GbdtParams { trees: if quick { 60 } else { 120 }, ..Default::default() },
    };
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let gru: Option<Box<dyn FnMut() -> Box<dyn Corrector>>> =
        if dir.join("manifest.txt").exists() {
            Some(Box::new(move || {
                let infer = gru_infer_fn(&dir, 8).expect("gru artifact");
                Box::new(GruCorrector::new(8, infer))
            }))
        } else {
            adaoper::log_warn!("artifacts missing — GRU arm skipped");
            None
        };
    let rows =
        ablations::profiler_accuracy(&calib, if quick { 2.0 } else { 4.0 }, 11, gru).unwrap();
    println!("== A1: profiler accuracy under idle→moderate→high→moderate ==");
    println!("{:<12} {:>14} {:>14} {:>8}", "arm", "energy MAPE", "latency MAPE", "obs");
    for r in rows {
        println!(
            "{:<12} {:>13.1}% {:>13.1}% {:>8}",
            r.arm, r.energy_mape, r.latency_mape, r.observations
        );
    }
}
