//! Bench A4: responsiveness across a moderate→high switch — post-switch
//! latency overshoot per policy.

use adaoper::experiments::ablations;
use adaoper::profiler::calibrate::CalibConfig;
use adaoper::profiler::gbdt::GbdtParams;

fn main() {
    let quick = std::env::var("ADAOPER_BENCH_QUICK").is_ok();
    let calib = CalibConfig {
        samples: if quick { 2000 } else { 5000 },
        seed: 3,
        gbdt: GbdtParams { trees: if quick { 60 } else { 120 }, ..Default::default() },
    };
    println!("== A4: adaptation to a moderate→high condition switch ==");
    let rows = ablations::responsiveness(&calib, 7).unwrap();
    println!(
        "{:<12} {:>15} {:>12} {:>10} {:>8}",
        "policy", "post-switch ms", "steady ms", "overshoot", "repart"
    );
    for r in rows {
        println!(
            "{:<12} {:>15.2} {:>12.2} {:>10.3} {:>8}",
            r.policy.name(),
            r.post_switch_ms,
            r.steady_high_ms,
            r.overshoot,
            r.repartitions
        );
    }
}
