//! Bench A7: scheduler overload sweep — FIFO vs EDF vs slack-reclaiming
//! EDF on deadline-miss rate and energy as offered load ramps through
//! saturation; the top load factor also runs drop-late admission.

use adaoper::experiments::scheduler_scenario::{self, SchedulerSweepConfig};
use adaoper::profiler::calibrate::CalibConfig;
use adaoper::profiler::gbdt::GbdtParams;

fn main() {
    let quick = std::env::var("ADAOPER_BENCH_QUICK").is_ok();
    let calib = CalibConfig {
        samples: if quick { 2000 } else { 5000 },
        seed: 7,
        gbdt: GbdtParams {
            trees: if quick { 60 } else { 120 },
            ..Default::default()
        },
    };
    let cfg = SchedulerSweepConfig {
        calib,
        duration_s: if quick { 3.0 } else { 5.0 },
        ..Default::default()
    };
    println!("== A7: scheduler overload sweep (heterogeneous SLOs) ==");
    let res = scheduler_scenario::run(&cfg).unwrap();
    print!("{}", scheduler_scenario::render(&res));
}
