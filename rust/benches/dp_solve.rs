//! DP-partitioner solve throughput: full-model solves/sec and
//! windowed-repair solves/sec on YOLOv2 at the default 64-bucket Pareto
//! lattice, measured for BOTH solver backends — the rolling-`BTreeMap`
//! reference ([`MapDpPartitioner`]) and the flattened-lattice core that
//! replaced it — so every recorded line carries its own before/after
//! ratio. The cost model is the calibrated GBDT profiler (as in serving),
//! whose [`CostModel::version`] lets the lattice backend memoize predict
//! calls per DP column.
//!
//! Before any timing, both backends solve once and the plans and
//! predicted costs are asserted bit-identical — a bench of two solvers
//! that disagree would be meaningless.
//!
//! `ADAOPER_BENCH_QUICK=1` shrinks calibration and the per-case budget.
//! The run always ends with one machine-readable JSON summary line on
//! stdout; set `ADAOPER_BENCH_JSON=<path>` to also append that line to a
//! file (the committed trajectory lives in `BENCH_dp_solve.json` at the
//! repo root — see `make bench-dp`).

use std::io::Write as _;
use std::time::Duration;

use adaoper::graph::zoo;
use adaoper::partition::dp::{DpPartitioner, DpScratch, MapDpPartitioner};
use adaoper::partition::plan::Objective;
use adaoper::profiler::calibrate::{calibrate_on, CalibConfig};
use adaoper::profiler::gbdt::GbdtParams;
use adaoper::profiler::{EnergyProfiler, EwmaCorrector};
use adaoper::soc::device::{Device, DeviceConfig};
use adaoper::util::bench::{black_box, print_table, Bencher};
use adaoper::workload::WorkloadCondition;

/// Only identifier-ish characters survive, so the value drops into the
/// JSON line unescaped.
fn sanitize(s: &str) -> String {
    s.trim()
        .chars()
        .filter(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'))
        .collect()
}

/// Short git revision of the working tree, `unknown` outside a checkout.
fn git_rev() -> String {
    let rev = std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| sanitize(&s))
        .unwrap_or_default();
    if rev.is_empty() { "unknown".to_string() } else { rev }
}

/// Hostname from the environment or /etc/hostname; bench records are
/// only comparable within one host, so the line must say which.
fn host_fingerprint() -> String {
    let host = std::env::var("HOSTNAME")
        .ok()
        .or_else(|| std::fs::read_to_string("/etc/hostname").ok())
        .map(|s| sanitize(&s))
        .unwrap_or_default();
    if host.is_empty() { "unknown".to_string() } else { host }
}

/// Noise-free device so both solvers see one frozen snapshot.
fn frozen_device() -> Device {
    let mut d = Device::new(DeviceConfig {
        noise_sigma: 0.0,
        drift_sigma: 0.0,
        seed: 7,
        ..DeviceConfig::snapdragon_855()
    });
    let mut c = WorkloadCondition::high().spec;
    c.cpu_bg_sigma = 0.0;
    c.cpu_burst = 0.0;
    c.gpu_bg_sigma = 0.0;
    c.gpu_burst = 0.0;
    c.drift_sigma = 0.0;
    d.apply_condition(&c);
    d
}

fn main() {
    let quick = std::env::var("ADAOPER_BENCH_QUICK").is_ok();
    let calib = CalibConfig {
        samples: if quick { 1200 } else { 2500 },
        seed: 7,
        gbdt: GbdtParams {
            trees: if quick { 30 } else { 60 },
            ..Default::default()
        },
    };

    println!("== dp_solve: partitioner solves/sec, map vs lattice (yolov2, 64 buckets) ==");
    println!("calibrating profiler ({} samples) …", calib.samples);
    let offline = calibrate_on(&calib, &DeviceConfig::snapdragon_855());
    let profiler = EnergyProfiler::with_correctors(offline, || {
        Box::new(EwmaCorrector::default())
    });

    let d = frozen_device();
    let snap = d.snapshot();
    let g = zoo::yolov2();
    let n = g.num_ops();

    let lat = DpPartitioner::new(Objective::MinEdp); // 64-bucket default
    let map = MapDpPartitioner::new(Objective::MinEdp);

    // sanity: a bench of two solvers that disagree measures nothing
    let a = lat.solve(&g, &profiler, &snap).expect("lattice solve");
    let b = map.solve(&g, &profiler, &snap).expect("map solve");
    assert_eq!(a.placements, b.placements, "backends diverged on full solve");
    assert_eq!(a.predicted.energy_j.to_bits(), b.predicted.energy_j.to_bits());
    assert_eq!(a.predicted.latency_s.to_bits(), b.predicted.latency_s.to_bits());

    let bencher = Bencher::new(
        Duration::from_millis(if quick { 100 } else { 300 }),
        Duration::from_millis(if quick { 400 } else { 1500 }),
    );
    let mut scratch = DpScratch::new();

    // full-model solves
    let r_full_map = bencher.run("full solve / map (yolov2)", || {
        black_box(map.solve(&g, &profiler, &snap).expect("map solve"));
    });
    let r_full_lat = bencher.run("full solve / lattice (yolov2)", || {
        black_box(
            lat.solve_in(&g, &profiler, &snap, &mut scratch)
                .expect("lattice solve"),
        );
    });

    // windowed repair: an 8-op window mid-model over the pinned full plan
    // (the repartition controller's steady-state call shape)
    let start = n / 3;
    let end = (start + 8).min(n);
    let pinned = &a.placements;
    let wa = lat
        .solve_range(&g, &profiler, &snap, start, end, pinned, None)
        .expect("lattice window");
    let wb = map
        .solve_range(&g, &profiler, &snap, start, end, pinned, None)
        .expect("map window");
    assert_eq!(wa.placements, wb.placements, "backends diverged on window");
    assert_eq!(wa.cost.energy_j.to_bits(), wb.cost.energy_j.to_bits());
    let r_win_map = bencher.run("window-8 solve / map (yolov2)", || {
        black_box(
            map.solve_range(&g, &profiler, &snap, start, end, pinned, None)
                .expect("map window"),
        );
    });
    let r_win_lat = bencher.run("window-8 solve / lattice (yolov2)", || {
        black_box(
            lat.solve_range_in(&g, &profiler, &snap, start, end, pinned, None, &mut scratch)
                .expect("lattice window"),
        );
    });

    print_table(
        "dp_solve",
        &[
            r_full_map.clone(),
            r_full_lat.clone(),
            r_win_map.clone(),
            r_win_lat.clone(),
        ],
    );

    let full_map = 1.0 / r_full_map.summary.mean;
    let full_lat = 1.0 / r_full_lat.summary.mean;
    let win_map = 1.0 / r_win_map.summary.mean;
    let win_lat = 1.0 / r_win_lat.summary.mean;
    println!(
        "full solves/sec: map {full_map:.0}, lattice {full_lat:.0} ({:.2}x); \
         window-8 solves/sec: map {win_map:.0}, lattice {win_lat:.0} ({:.2}x)",
        full_lat / full_map,
        win_lat / win_map
    );

    // One machine-readable line for the recorded trajectory. Plain
    // format! keeps this dependency-free; git_rev/host are sanitized to
    // identifier characters so no field needs escaping.
    let json = format!(
        "{{\"bench\":\"dp_solve\",\"mode\":\"{}\",\"seed\":7,\
         \"graph\":\"yolov2\",\"ops\":{n},\"buckets\":64,\"choices\":{},\
         \"window\":8,\
         \"solves_per_sec_map\":{full_map:.1},\
         \"solves_per_sec_lattice\":{full_lat:.1},\
         \"speedup_full\":{:.2},\
         \"window_solves_per_sec_map\":{win_map:.1},\
         \"window_solves_per_sec_lattice\":{win_lat:.1},\
         \"speedup_window\":{:.2},\
         \"git_rev\":\"{}\",\"host\":\"{}\",\"os\":\"{}\",\"arch\":\"{}\"}}",
        if quick { "quick" } else { "full" },
        lat.choices.len(),
        full_lat / full_map,
        win_lat / win_map,
        git_rev(),
        host_fingerprint(),
        std::env::consts::OS,
        std::env::consts::ARCH
    );
    println!("{json}");
    if let Ok(path) = std::env::var("ADAOPER_BENCH_JSON") {
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .unwrap_or_else(|e| panic!("open {path}: {e}"));
        writeln!(f, "{json}").expect("append bench record");
        println!("appended record to {path}");
    }
}
