//! Hot-loop throughput of the serving kernel: events per wall-clock
//! second on a two-stream overload mix (interactive YOLOv2-tiny +
//! background MobileNetV1, both past saturation so the active list — and
//! with it the per-dispatch candidate work — stays large).
//!
//! This pins the two hot-path fixes from the event-kernel refactor:
//! the executor borrows the stream's model instead of cloning a handle
//! per executed op, and the dispatch stage caches per-request
//! placement/remaining-work lookups between picks instead of rebuilding
//! the full candidate set from the plan tables on every loop iteration.
//!
//! Calendar-kernel regression note (PR 7): this bench also guards the
//! O(1) calendar event queue (vs the old binary heap), the arena-recycled
//! per-request `out_cpu` buffers, the removal of the per-dispatch
//! `Request` clone and per-completion `RequestOutcome` clone, and the
//! memoized latency-profile refresh in `PlanTable::refresh_profiles`.
//! Any of these sliding back shows up here first.
//!
//! `ADAOPER_BENCH_QUICK=1` shrinks the calibration budget. The run
//! always ends with one machine-readable JSON summary line on stdout;
//! set `ADAOPER_BENCH_JSON=<path>` to also append that line to a file
//! (the committed trajectory lives in `BENCH_hot_loop.json` at the repo
//! root — see `make bench-hot`).

use std::io::Write as _;
use std::time::Instant;

use adaoper::config::schema::{PolicyKind, SchedulerKind};
use adaoper::coordinator::{Engine, EngineConfig, StreamSpec};
use adaoper::graph::zoo;
use adaoper::profiler::calibrate::{calibrate_on, CalibConfig};
use adaoper::profiler::gbdt::GbdtParams;
use adaoper::profiler::{EnergyProfiler, EwmaCorrector};
use adaoper::sim::EventCounters;
use adaoper::soc::device::DeviceConfig;
use adaoper::workload::Arrival;

/// Only identifier-ish characters survive, so the value drops into the
/// JSON line unescaped.
fn sanitize(s: &str) -> String {
    s.trim()
        .chars()
        .filter(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'))
        .collect()
}

/// Short git revision of the working tree, `unknown` outside a checkout.
fn git_rev() -> String {
    let rev = std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| sanitize(&s))
        .unwrap_or_default();
    if rev.is_empty() { "unknown".to_string() } else { rev }
}

/// Hostname from the environment or /etc/hostname; bench records are
/// only comparable within one host, so the line must say which.
fn host_fingerprint() -> String {
    let host = std::env::var("HOSTNAME")
        .ok()
        .or_else(|| std::fs::read_to_string("/etc/hostname").ok())
        .map(|s| sanitize(&s))
        .unwrap_or_default();
    if host.is_empty() { "unknown".to_string() } else { host }
}

fn main() {
    let quick = std::env::var("ADAOPER_BENCH_QUICK").is_ok();
    let calib = CalibConfig {
        samples: if quick { 1500 } else { 4000 },
        seed: 7,
        gbdt: GbdtParams {
            trees: if quick { 40 } else { 100 },
            ..Default::default()
        },
    };
    let duration_s = if quick { 1.5 } else { 2.5 };
    let iters = if quick { 3 } else { 5 };

    println!("== engine_hot_loop: serving-kernel events/sec (2-stream overload) ==");
    println!("calibrating profiler ({} samples) …", calib.samples);
    let offline = calibrate_on(&calib, &DeviceConfig::snapdragon_855());

    let streams = vec![
        StreamSpec::new(0, zoo::yolov2_tiny(), Arrival::Poisson { hz: 120.0 }, 0.5),
        StreamSpec::new(1, zoo::mobilenet_v1(), Arrival::Poisson { hz: 80.0 }, 0.8),
    ];

    let mut rates = Vec::new();
    for i in 0..iters {
        let profiler = EnergyProfiler::with_correctors(offline.clone(), || {
            Box::new(EwmaCorrector::default())
        });
        let mut engine = Engine::with_profiler(
            EngineConfig {
                policy: PolicyKind::MaceGpu,
                scheduler: SchedulerKind::Edf,
                duration_s,
                seed: 7,
                calib: calib.clone(),
                ..Default::default()
            },
            profiler,
        );
        let mut counters = EventCounters::default();
        let t0 = Instant::now();
        let report = engine
            .run_observed(&streams, &mut [&mut counters])
            .expect("overload run");
        let wall = t0.elapsed().as_secs_f64();
        // every kernel event the run delivered: arrivals + dispatches +
        // completions + monitor ticks + re-plans
        let events = counters.offered
            + counters.op_dispatches
            + counters.op_completes
            + counters.monitor_ticks
            + counters.replans;
        let rate = events as f64 / wall;
        rates.push(rate);
        println!(
            "iter {i}: {events} events in {:.3} s wall -> {:.0} events/s  \
             ({} requests, {} ops)",
            wall, rate, report.requests, counters.op_dispatches
        );
    }
    rates.sort_by(|a, b| a.total_cmp(b));
    let mean = rates.iter().sum::<f64>() / rates.len() as f64;
    let min = rates.first().copied().unwrap_or(0.0);
    let max = rates.last().copied().unwrap_or(0.0);
    println!(
        "events/sec: mean {mean:.0}, min {min:.0}, max {max:.0} over {} iters",
        rates.len()
    );

    // One extra instrumented iteration for the stage self-profile — kept
    // out of the throughput stats, since the per-lap clock reads are
    // exactly the overhead the timed iterations must not carry.
    let profiler = EnergyProfiler::with_correctors(offline.clone(), || {
        Box::new(EwmaCorrector::default())
    });
    let mut engine = Engine::with_profiler(
        EngineConfig {
            policy: PolicyKind::MaceGpu,
            scheduler: SchedulerKind::Edf,
            duration_s,
            seed: 7,
            calib: calib.clone(),
            ..Default::default()
        },
        profiler,
    );
    engine.enable_stage_timers();
    engine.run(&streams).expect("instrumented run");
    let stages = engine
        .take_stage_timers()
        .map(|t| t.json_object())
        .unwrap_or_else(|| "{}".to_string());

    // One machine-readable line for the recorded trajectory. Plain
    // format! keeps this dependency-free; git_rev/host are sanitized to
    // identifier characters so no field needs escaping.
    let json = format!(
        "{{\"bench\":\"engine_hot_loop\",\"mode\":\"{}\",\"seed\":7,\
         \"iters\":{},\"duration_s\":{duration_s},\
         \"events_per_sec_mean\":{mean:.1},\"events_per_sec_min\":{min:.1},\
         \"events_per_sec_max\":{max:.1},\
         \"git_rev\":\"{}\",\"host\":\"{}\",\"os\":\"{}\",\"arch\":\"{}\",\
         \"stages\":{stages}}}",
        if quick { "quick" } else { "full" },
        rates.len(),
        git_rev(),
        host_fingerprint(),
        std::env::consts::OS,
        std::env::consts::ARCH
    );
    println!("{json}");
    if let Ok(path) = std::env::var("ADAOPER_BENCH_JSON") {
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .unwrap_or_else(|e| panic!("open {path}: {e}"));
        writeln!(f, "{json}").expect("append bench record");
        println!("appended record to {path}");
    }
}
