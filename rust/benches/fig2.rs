//! Bench: regenerate the paper's Figure 2 (both panels).
//! `cargo bench --bench fig2`; set ADAOPER_BENCH_QUICK=1 for a fast pass.

use adaoper::experiments::fig2;
use adaoper::profiler::calibrate::CalibConfig;
use adaoper::profiler::gbdt::GbdtParams;

fn main() {
    let quick = std::env::var("ADAOPER_BENCH_QUICK").is_ok();
    let cfg = fig2::Fig2Config {
        model: "yolov2".into(),
        n_requests: if quick { 15 } else { 40 },
        seed: 7,
        calib: if quick {
            CalibConfig {
                samples: 2500,
                seed: 42,
                gbdt: GbdtParams { trees: 80, ..Default::default() },
            }
        } else {
            CalibConfig::default()
        },
    };
    let rows = fig2::run(&cfg).expect("fig2 run");
    print!("{}", fig2::render(&rows));
}
