//! Batch formation over the engine's active list.
//!
//! The [`Batcher`] is per-run state the engine consults on every dispatch
//! decision when batching is enabled:
//!
//! 1. [`Batcher::form`] collects the co-dispatchable members of the picked
//!    request's frontier — same stream, same next op, inputs ready by the
//!    dispatch time — oldest first, capped at the policy's batch size.
//! 2. [`Batcher::decide`] asks the [`BatchPolicy`]: dispatch a (possibly
//!    trimmed) prefix now, or hold the frontier open. A held frontier is
//!    recorded in the hold table; [`Batcher::floor`] exposes its release
//!    time so [`crate::sim::stages::DispatchStage::pick_floored`] floors
//!    those candidates' earliest start — other streams keep dispatching in
//!    the meantime, and new same-stream arrivals admitted before the
//!    release join the batch.
//! 3. On close the batcher records the realized batch size and formation
//!    wait into the histograms that surface as
//!    [`crate::metrics::report::BatchStats`].
//!
//! Determinism: the hold table is only ever addressed by exact
//! `(stream, op)` key (never iterated), and member order is a total order
//! on `(arrival, request id)` — batched runs replay bit for bit under a
//! fixed seed exactly like unbatched ones.

use std::collections::HashMap;

use crate::metrics::histogram::LogHistogram;
use crate::metrics::report::BatchStats;
use crate::sim::stages::Active;

use super::policy::{by_kind, BatchDecision, BatchPolicy, BatchView};
use super::BatchConfig;

/// A forming batch: the frontier identity plus its dispatchable members.
#[derive(Debug, Clone)]
pub struct FormedBatch {
    /// Owning stream of every member.
    pub stream: usize,
    /// Frontier operator index (members' `next_op`).
    pub op: usize,
    /// Active-list indices of the members, oldest arrival first. Non-empty;
    /// after a [`BatchDecision::Dispatch`] verdict this is the exact set to
    /// execute together.
    pub members: Vec<usize>,
    /// When the frontier first became dispatchable, virtual seconds.
    pub formed_at_s: f64,
}

#[derive(Debug, Clone, Copy)]
struct Hold {
    formed_at_s: f64,
    until_s: f64,
}

/// Per-run batch-formation state: policy, hold table, statistics.
pub struct Batcher {
    policy: Box<dyn BatchPolicy + Send + Sync>,
    holds: HashMap<(usize, usize), Hold>,
    formed: usize,
    batched_dispatches: usize,
    batched_requests: usize,
    max_size: usize,
    size_hist: LogHistogram,
    wait_hist: LogHistogram,
}

impl Batcher {
    /// Build from the run's batch configuration; `None` when the
    /// configured policy is `none` (the engine then runs the legacy
    /// single-dispatch path untouched).
    pub fn from_config(cfg: &BatchConfig) -> Option<Batcher> {
        by_kind(cfg.policy, cfg.max.max(1), cfg.wait_s.max(0.0)).map(|policy| Batcher {
            policy,
            holds: HashMap::new(),
            formed: 0,
            batched_dispatches: 0,
            batched_requests: 0,
            max_size: 0,
            size_hist: LogHistogram::batch_sizes(),
            wait_hist: LogHistogram::latency(),
        })
    }

    /// The active policy's name (reports).
    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Earliest-start floor of a held frontier, if any — candidates of a
    /// frontier being held open may not dispatch before its release time.
    pub fn floor(&self, stream: usize, op: usize) -> Option<f64> {
        self.holds.get(&(stream, op)).map(|h| h.until_s)
    }

    /// Collect the co-dispatchable members of `picked`'s frontier at
    /// `start_s`: same stream, same next op, inputs ready. Oldest arrival
    /// first, capped at the policy's batch size (`picked` may be trimmed
    /// away when older members fill the cap — the frontier, not the pick,
    /// dispatches).
    pub fn form(&self, picked: usize, start_s: f64, active: &[Active]) -> FormedBatch {
        let stream = active[picked].model;
        let op = active[picked].next_op;
        let mut members: Vec<usize> = active
            .iter()
            .enumerate()
            .filter(|(_, a)| a.model == stream && a.next_op == op && a.data_ready_s <= start_s)
            .map(|(i, _)| i)
            .collect();
        if members.len() > 1 {
            // unstable sort is deterministic here: (arrival, id) is a
            // total order with unique ids
            members.sort_unstable_by(|&x, &y| {
                active[x]
                    .req
                    .arrival_s
                    .total_cmp(&active[y].req.arrival_s)
                    .then(active[x].req.id.cmp(&active[y].req.id))
            });
        }
        members.truncate(self.policy.max_batch());
        let formed_at_s = self
            .holds
            .get(&(stream, op))
            .map(|h| h.formed_at_s)
            .unwrap_or(start_s);
        FormedBatch {
            stream,
            op,
            members,
            formed_at_s,
        }
    }

    /// Ask the policy about `batch` at dispatch time `now_s`. Returns
    /// `true` when the batch closes — `batch.members` is then truncated to
    /// the dispatched size and the close is recorded; `false` records a
    /// hold (the frontier's candidates are floored to the release time).
    ///
    /// `remaining_s` is the single-request predicted remaining service
    /// time from the frontier op (plan latency profile); `min_deadline_s`
    /// the tightest member deadline.
    pub fn decide(
        &mut self,
        batch: &mut FormedBatch,
        now_s: f64,
        remaining_s: f64,
        min_deadline_s: f64,
    ) -> bool {
        let view = BatchView {
            op: batch.op,
            size: batch.members.len(),
            now_s,
            formed_at_s: batch.formed_at_s,
            min_deadline_s,
            remaining_s,
        };
        match self.policy.decide(&view) {
            BatchDecision::Hold { until_s } if until_s > now_s => {
                self.holds.insert(
                    (batch.stream, batch.op),
                    Hold {
                        formed_at_s: batch.formed_at_s,
                        until_s,
                    },
                );
                false
            }
            BatchDecision::Hold { .. } => {
                // degenerate hold (release already reached): close as-is
                self.close(batch, now_s);
                true
            }
            BatchDecision::Dispatch { size } => {
                batch.members.truncate(size.max(1));
                self.close(batch, now_s);
                true
            }
        }
    }

    /// Formation wait of a closing batch at `now_s`, seconds.
    pub fn wait_of(&self, batch: &FormedBatch, now_s: f64) -> f64 {
        (now_s - batch.formed_at_s).max(0.0)
    }

    fn close(&mut self, batch: &FormedBatch, now_s: f64) {
        self.holds.remove(&(batch.stream, batch.op));
        let size = batch.members.len();
        self.max_size = self.max_size.max(size);
        if batch.op == 0 {
            // formation statistics are per batch, recorded once where
            // batches form; later ops re-dispatch the same batch
            self.formed += 1;
            self.size_hist.record(size as f64);
            self.wait_hist.record(self.wait_of(batch, now_s));
        }
        if size > 1 {
            self.batched_dispatches += 1;
            self.batched_requests += size;
        }
    }

    /// Statistics snapshot for the serving report.
    pub fn stats(&self) -> BatchStats {
        BatchStats {
            policy: self.policy.name().to_string(),
            formed: self.formed,
            batched_dispatches: self.batched_dispatches,
            batched_requests: self.batched_requests,
            max_size: self.max_size,
            size_hist: self.size_hist.clone(),
            wait_hist: self.wait_hist.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::BatchPolicyKind;
    use crate::coordinator::request::Request;
    use crate::partition::plan::INPUT_CPU_FRAC;

    fn cfg(policy: BatchPolicyKind) -> BatchConfig {
        // binary-exact wait: `formed_at + wait` equals the literals below
        BatchConfig {
            policy,
            max: 3,
            wait_s: 0.5,
        }
    }

    fn active(id: usize, stream: usize, op: usize, ready: f64, deadline: f64) -> Active {
        Active {
            req: Request {
                id,
                stream,
                arrival_s: ready,
                deadline_s: deadline,
            },
            model: stream,
            next_op: op,
            data_ready_s: ready,
            start_s: None,
            energy_j: 0.0,
            out_cpu: vec![INPUT_CPU_FRAC; 4],
            prev_placement: None,
        }
    }

    #[test]
    fn none_policy_builds_no_batcher() {
        assert!(Batcher::from_config(&cfg(BatchPolicyKind::None)).is_none());
        assert!(Batcher::from_config(&cfg(BatchPolicyKind::Fixed)).is_some());
    }

    #[test]
    fn form_collects_frontier_oldest_first_capped() {
        let b = Batcher::from_config(&cfg(BatchPolicyKind::Fixed)).unwrap();
        let actives = vec![
            active(4, 0, 0, 0.40, 9.0),
            active(1, 0, 0, 0.10, 9.0),
            active(2, 1, 0, 0.05, 9.0), // other stream: excluded
            active(3, 0, 1, 0.05, 9.0), // other op: excluded
            active(5, 0, 0, 0.90, 9.0), // not ready by 0.5: excluded
            active(0, 0, 0, 0.02, 9.0),
        ];
        let f = b.form(0, 0.5, &actives);
        assert_eq!((f.stream, f.op), (0, 0));
        // oldest three of {id0@0.02, id1@0.10, id4@0.40} fill the cap of 3
        assert_eq!(f.members, vec![5, 1, 0]);
        assert_eq!(f.formed_at_s, 0.5);
    }

    #[test]
    fn hold_floors_frontier_then_close_clears() {
        let mut b = Batcher::from_config(&cfg(BatchPolicyKind::Fixed)).unwrap();
        let actives = vec![active(0, 0, 0, 0.0, 9.0), active(1, 0, 0, 0.0, 9.0)];
        let mut f = b.form(0, 1.0, &actives);
        // size 2 < cap 3, inside wait → hold until 1.5
        assert!(!b.decide(&mut f, 1.0, 0.05, 9.0));
        assert_eq!(b.floor(0, 0), Some(1.5));
        assert_eq!(b.floor(0, 1), None);
        // re-form at the release: formed_at survives the hold
        let mut f2 = b.form(0, 1.5, &actives);
        assert_eq!(f2.formed_at_s, 1.0);
        assert!(b.decide(&mut f2, 1.5, 0.05, 9.0), "timeout must close");
        assert_eq!(b.floor(0, 0), None);
        let st = b.stats();
        assert_eq!((st.formed, st.batched_dispatches, st.batched_requests), (1, 1, 2));
        assert_eq!(st.max_size, 2);
        assert_eq!(st.size_hist.count(), 1);
        // wait recorded ≈ 0.5 s (inside the log-bucket error bound)
        let w = st.wait_hist.quantile(0.5).unwrap();
        assert!((w - 0.5).abs() / 0.5 < 0.1, "wait {w}");
    }

    #[test]
    fn full_batch_closes_immediately_and_counts() {
        let mut b = Batcher::from_config(&cfg(BatchPolicyKind::Fixed)).unwrap();
        let actives: Vec<Active> =
            (0..4).map(|i| active(i, 0, 0, 0.0, 9.0)).collect();
        let mut f = b.form(0, 1.0, &actives);
        assert_eq!(f.members.len(), 3, "capped at max");
        assert!(b.decide(&mut f, 1.0, 0.05, 9.0));
        assert_eq!(b.stats().batched_requests, 3);
    }

    #[test]
    fn mid_flight_frontier_never_holds() {
        let mut b = Batcher::from_config(&cfg(BatchPolicyKind::Slack)).unwrap();
        let actives = vec![active(0, 0, 2, 0.0, 9.0), active(1, 0, 2, 0.0, 9.0)];
        let mut f = b.form(0, 1.0, &actives);
        assert_eq!(f.op, 2);
        assert!(b.decide(&mut f, 1.0, 0.05, 9.0), "op>0 must dispatch");
        assert_eq!(f.members.len(), 2);
        // mid-flight closes keep batching counters but not formation stats
        let st = b.stats();
        assert_eq!(st.formed, 0);
        assert_eq!(st.batched_dispatches, 1);
    }
}
