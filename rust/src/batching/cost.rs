//! The batch-aware cost model: analytic scaling between single-request and
//! batched operator costs, plus the [`BatchedCostModel`] adapter that lets
//! planning price a batch of B requests instead of B independent requests.
//!
//! The scaling mirrors the SoC ground truth
//! ([`crate::soc::device::Device::expected_cost_batch`]): transfer moves
//! every member's activations (× B), per-unit busy time grows by
//! [`crate::soc::latency::batch_compute_scale`] (sub-linear on the GPU,
//! near-linear on the CPU, with an over-batching penalty past the knee),
//! and cross-unit synchronization is paid once. Because an
//! [`OpCost`] folds dispatch overhead into the unit busy times, the
//! forward/inverse maps here treat dispatch as amortizing at the unit's
//! batch exponent — a deliberate, slightly conservative approximation of
//! the device's pay-once dispatch accounting.

use crate::graph::OpNode;
use crate::profiler::CostModel;
use crate::soc::device::{ExecCtx, OpCost, Snapshot};
use crate::soc::latency::batch_compute_scale;
use crate::soc::{Placement, Proc};

/// Scale a single-request operator cost to a batch of `batch` requests
/// dispatched together. Identity for `batch <= 1`.
///
/// Guarantees (property-tested in `rust/tests/batching.rs`): batched
/// latency is non-decreasing in the batch size, and per-request energy
/// (`energy_j / batch`) is non-increasing up to the unit's amortization
/// knee ([`crate::soc::latency::BatchScaling::knee`]).
pub fn scale_op_cost(c: &OpCost, batch: usize) -> OpCost {
    if batch <= 1 {
        return *c;
    }
    let b = batch as f64;
    let cpu_busy = c.cpu_busy_s * batch_compute_scale(Proc::Cpu, batch);
    let gpu_busy = c.gpu_busy_s * batch_compute_scale(Proc::Gpu, batch);
    let transfer_s = c.transfer_s * b;
    let transfer_j = c.transfer_j * b;
    // cross-unit sync (split join) is whatever latency the busy/transfer
    // terms do not explain — paid once per batch
    let sync = (c.latency_s - c.transfer_s - c.cpu_busy_s.max(c.gpu_busy_s)).max(0.0);
    let busy = c.cpu_busy_s + c.gpu_busy_s;
    let compute_j = (c.energy_j - c.transfer_j).max(0.0);
    let energy_j = transfer_j
        + if busy > 0.0 {
            // dynamic power is busy-time-proportional at a fixed activity
            compute_j * ((cpu_busy + gpu_busy) / busy)
        } else {
            compute_j * b
        };
    OpCost {
        latency_s: transfer_s + cpu_busy.max(gpu_busy) + sync,
        energy_j,
        cpu_busy_s: cpu_busy,
        gpu_busy_s: gpu_busy,
        transfer_s,
        transfer_j,
    }
}

/// Inverse of [`scale_op_cost`]: recover an (approximate) single-request
/// cost from a batched measurement. The execution stage feeds this to the
/// profiler so batched dispatches still train the drift corrector on
/// per-request residuals instead of starving it (or poisoning it with
/// B-times-larger observations).
pub fn debatch_op_cost(c: &OpCost, batch: usize) -> OpCost {
    if batch <= 1 {
        return *c;
    }
    let b = batch as f64;
    let cpu_busy = c.cpu_busy_s / batch_compute_scale(Proc::Cpu, batch);
    let gpu_busy = c.gpu_busy_s / batch_compute_scale(Proc::Gpu, batch);
    let transfer_s = c.transfer_s / b;
    let transfer_j = c.transfer_j / b;
    let sync = (c.latency_s - c.transfer_s - c.cpu_busy_s.max(c.gpu_busy_s)).max(0.0);
    let busy = c.cpu_busy_s + c.gpu_busy_s;
    let compute_j = (c.energy_j - c.transfer_j).max(0.0);
    let energy_j = transfer_j
        + if busy > 0.0 {
            compute_j * ((cpu_busy + gpu_busy) / busy)
        } else {
            compute_j / b
        };
    OpCost {
        latency_s: transfer_s + cpu_busy.max(gpu_busy) + sync,
        energy_j,
        cpu_busy_s: cpu_busy,
        gpu_busy_s: gpu_busy,
        transfer_s,
        transfer_j,
    }
}

/// Per-request view of a full-batch cost, the quantity planning objectives
/// score: every member experiences the *whole* batched latency (members
/// complete together), while energy amortizes across the batch.
pub fn per_request_cost(c: &OpCost, batch: usize) -> OpCost {
    if batch <= 1 {
        return *c;
    }
    let b = batch as f64;
    OpCost {
        latency_s: c.latency_s,
        energy_j: c.energy_j / b,
        cpu_busy_s: c.cpu_busy_s,
        gpu_busy_s: c.gpu_busy_s,
        transfer_s: c.transfer_s,
        transfer_j: c.transfer_j / b,
    }
}

/// Adapter that re-prices an inner [`CostModel`] at a fixed batch size:
/// `predict` returns the per-request cost of a batch-of-B dispatch
/// (full batched latency, amortized energy). Wrapping the planner's cost
/// model with this is what makes the DP place ops the way batched
/// execution will actually pay for them — fixed dispatch and transfer
/// setup amortize, so the GPU's high launch cost stops scaring the
/// planner off at high request rates.
pub struct BatchedCostModel<'a> {
    inner: &'a dyn CostModel,
    batch: usize,
}

impl<'a> BatchedCostModel<'a> {
    /// Wrap `inner`, pricing every op at a batch of `batch`.
    pub fn new(inner: &'a dyn CostModel, batch: usize) -> BatchedCostModel<'a> {
        BatchedCostModel {
            inner,
            batch: batch.max(1),
        }
    }

    /// The batch size this adapter prices at.
    pub fn batch(&self) -> usize {
        self.batch
    }
}

impl CostModel for BatchedCostModel<'_> {
    fn predict(
        &self,
        op: &OpNode,
        placement: Placement,
        ctx: &ExecCtx,
        snap: &Snapshot,
    ) -> OpCost {
        let full = self.inner.predict_batch(op, placement, ctx, snap, self.batch);
        per_request_cost(&full, self.batch)
    }

    fn predict_batch(
        &self,
        op: &OpNode,
        placement: Placement,
        ctx: &ExecCtx,
        snap: &Snapshot,
        batch: usize,
    ) -> OpCost {
        // explicit batch queries bypass the adapter's fixed size
        self.inner.predict_batch(op, placement, ctx, snap, batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost() -> OpCost {
        OpCost {
            latency_s: 1.3e-3,
            energy_j: 2.0e-3,
            cpu_busy_s: 0.0,
            gpu_busy_s: 1.0e-3,
            transfer_s: 0.3e-3,
            transfer_j: 0.2e-3,
        }
    }

    #[test]
    fn scale_identity_at_one_and_roundtrips() {
        let c = cost();
        let s1 = scale_op_cost(&c, 1);
        assert_eq!(s1.latency_s.to_bits(), c.latency_s.to_bits());
        for b in [2usize, 4, 8] {
            let s = scale_op_cost(&c, b);
            let back = debatch_op_cost(&s, b);
            assert!(
                (back.latency_s - c.latency_s).abs() < 1e-12,
                "b={b}: {} vs {}",
                back.latency_s,
                c.latency_s
            );
            assert!((back.energy_j - c.energy_j).abs() < 1e-12);
        }
    }

    #[test]
    fn batched_latency_grows_but_per_request_energy_falls() {
        let c = cost();
        let mut prev_lat = c.latency_s;
        let mut prev_e = c.energy_j;
        for b in 2..=8 {
            let s = scale_op_cost(&c, b);
            assert!(s.latency_s > prev_lat, "b={b}");
            let per_req = s.energy_j / b as f64;
            assert!(per_req < prev_e, "b={b}: {per_req} !< {prev_e}");
            prev_lat = s.latency_s;
            prev_e = per_req;
        }
    }

    #[test]
    fn per_request_keeps_latency_amortizes_energy() {
        let c = cost();
        let batched = scale_op_cost(&c, 4);
        let pr = per_request_cost(&batched, 4);
        assert_eq!(pr.latency_s.to_bits(), batched.latency_s.to_bits());
        assert!((pr.energy_j - batched.energy_j / 4.0).abs() < 1e-18);
        let id = per_request_cost(&c, 1);
        assert_eq!(id.energy_j.to_bits(), c.energy_j.to_bits());
    }
}
