//! Deadline-aware dynamic batching: the subsystem between admission and
//! dispatch.
//!
//! AdaOper's core observation is that fixed per-dispatch costs (kernel
//! launch, CPU↔GPU transfer setup, DVFS ramp) dominate small concurrent
//! requests. Batching is the same lever pointed at *co-resident* requests:
//! grouping B same-stream requests at the same operator frontier into one
//! dispatch amortizes those fixed costs per request (the energy win) while
//! delaying the earliest member of the batch (the responsiveness risk).
//! This module makes that trade-off explicit and policy-controlled:
//!
//! * [`policy`] — the [`policy::BatchPolicy`] trait and its
//!   implementations: `fixed` (close at size K or after a wait timeout) and
//!   `slack` (deadline-aware: hold a forming batch only while every
//!   member's SLO slack — computed from the per-stream plan latency
//!   profiles — exceeds the predicted batched service time, so batching
//!   never manufactures deadline misses). `none` disables the subsystem
//!   entirely: the engine runs the legacy single-dispatch path, bit for
//!   bit.
//! * [`batcher`] — [`batcher::Batcher`]: batch formation over the active
//!   list, hold bookkeeping (a held frontier floors its candidates'
//!   earliest start, so other streams run in the meantime), and the
//!   per-run batch statistics that surface in
//!   [`crate::metrics::report::BatchStats`].
//! * [`cost`] — the batch-aware cost model: analytic scaling of a
//!   single-request [`crate::soc::device::OpCost`] to a batch of B
//!   (sub-linear compute growth on the GPU, near-linear on the CPU,
//!   transfer per member, fixed dispatch once), the inverse used to feed
//!   the profiler per-request observations from batched measurements, and
//!   the [`cost::BatchedCostModel`] adapter that lets the DP partitioner
//!   and the `slack-reclaim` scheduler price a batch of B requests instead
//!   of B independent requests.
//!
//! Ground truth lives in the SoC layer
//! ([`crate::soc::device::Device::measure_batch`],
//! [`crate::soc::latency::batch_compute_scale`],
//! [`crate::soc::power::batched_activity`]); the engine wires formation
//! into [`crate::sim::stages::DispatchStage`] and batched execution into
//! [`crate::sim::stages::ExecStage`], and every close is broadcast as a
//! [`crate::sim::event::Event::BatchClose`]. Knobs:
//! `adaoper serve --batch-policy/--batch-max/--batch-wait-ms`, the
//! `[serve]` config keys of the same names, and the
//! `adaoper ablation batching` sweep.

pub mod batcher;
pub mod cost;
pub mod policy;

pub use batcher::{Batcher, FormedBatch};
pub use cost::BatchedCostModel;
pub use policy::{BatchDecision, BatchPolicy, BatchView};

use crate::config::schema::BatchPolicyKind;

/// Batching configuration of one serving run.
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Which formation policy runs (`none` = legacy single dispatch).
    pub policy: BatchPolicyKind,
    /// Maximum requests per batch.
    pub max: usize,
    /// Formation wait cap, seconds: a forming batch never holds longer
    /// than this past the moment it first became dispatchable.
    pub wait_s: f64,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            policy: BatchPolicyKind::None,
            max: 4,
            wait_s: 4e-3,
        }
    }
}

impl BatchConfig {
    /// The batch size planning prices ops at: 1 with batching disabled
    /// (the legacy plan-cache key), the configured cap otherwise — the DP
    /// then amortizes fixed dispatch/transfer costs the way execution
    /// will, and the plan cache keys the resulting plans under a batch
    /// bucket so batched and unbatched plans never alias.
    pub fn plan_hint(&self) -> usize {
        match self.policy {
            BatchPolicyKind::None => 1,
            _ => self.max.max(1),
        }
    }

    /// Whether the batching subsystem is engaged at all.
    pub fn enabled(&self) -> bool {
        self.policy != BatchPolicyKind::None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_disabled_with_hint_one() {
        let c = BatchConfig::default();
        assert!(!c.enabled());
        assert_eq!(c.plan_hint(), 1);
    }

    #[test]
    fn enabled_policies_hint_their_cap() {
        let c = BatchConfig {
            policy: BatchPolicyKind::Slack,
            max: 6,
            wait_s: 2e-3,
        };
        assert!(c.enabled());
        assert_eq!(c.plan_hint(), 6);
        let c = BatchConfig {
            policy: BatchPolicyKind::Fixed,
            max: 0,
            wait_s: 0.0,
        };
        assert_eq!(c.plan_hint(), 1, "zero cap clamps to 1");
    }
}
