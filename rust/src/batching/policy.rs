//! Batch-formation policies: when does a forming batch close?
//!
//! A policy sees one [`BatchView`] per dispatch decision — the frontier's
//! op index, current size, how long the batch has been dispatchable, the
//! tightest member deadline, and the single-request predicted remaining
//! service time from the per-stream plan latency profile — and returns a
//! [`BatchDecision`]: dispatch some prefix of the members now, or hold
//! until a future close time. Holding floors the frontier's earliest
//! start, so other streams keep running in the meantime; the engine
//! re-asks the policy whenever the frontier wins dispatch again (new
//! members may have joined).

use crate::config::schema::BatchPolicyKind;
use crate::soc::latency::batch_compute_scale;
use crate::soc::Proc;

/// Predicted latency multiplier of a batch of `batch` under the `slack`
/// policy's conservative planning model (`1.0` for `batch <= 1`).
///
/// Uses the **CPU's** calibrated batch-compute scale
/// ([`crate::soc::latency::BatchScaling`]): the CPU curve dominates the
/// GPU's for every batch size (larger exponent, earlier knee, steeper
/// over-batching penalty), so the factor is a ground-truth upper bound on
/// batched compute growth for any single-unit placement — which is what
/// lets the slack policy promise it never holds or sizes a batch past
/// real deadline headroom, even on CPU-resident plans.
pub fn slack_latency_factor(batch: usize) -> f64 {
    batch_compute_scale(Proc::Cpu, batch)
}

/// What a policy sees when asked about a forming batch.
#[derive(Debug, Clone, Copy)]
pub struct BatchView {
    /// Operator index of the frontier (0 = formation point; new arrivals
    /// can only join at op 0, so policies only hold there).
    pub op: usize,
    /// Members currently dispatchable at the frontier.
    pub size: usize,
    /// The dispatch time under consideration, virtual seconds.
    pub now_s: f64,
    /// When the frontier first became dispatchable (oldest member ready).
    pub formed_at_s: f64,
    /// Tightest absolute deadline among the members.
    pub min_deadline_s: f64,
    /// Single-request predicted remaining service time from this op
    /// (inclusive) to completion, from the stream's plan latency profile.
    pub remaining_s: f64,
}

/// A policy's verdict on a forming batch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BatchDecision {
    /// Close now and dispatch the oldest `size` members (the rest stay
    /// queued and form the next batch).
    Dispatch {
        /// How many members to dispatch (≥ 1).
        size: usize,
    },
    /// Keep the frontier open until `until_s` (exclusive): its candidates'
    /// earliest start is floored there so later arrivals can join.
    Hold {
        /// Virtual time at which the batch must close.
        until_s: f64,
    },
}

/// A batch-formation policy. Implementations must guarantee progress: a
/// `Hold` with `until_s <= now_s` is treated as `Dispatch` by the caller,
/// and any view with `now_s` at or past the policy's own close time must
/// yield `Dispatch`.
pub trait BatchPolicy: Send + Sync {
    /// Policy name as it appears in reports (`fixed`, `slack`).
    fn name(&self) -> &'static str;

    /// Maximum requests per batch.
    fn max_batch(&self) -> usize;

    /// Decide whether the forming batch closes now.
    fn decide(&self, v: &BatchView) -> BatchDecision;
}

/// Close at size K or after the wait cap — the classic dynamic-batching
/// baseline (deadline-blind: a tight request can be held the full wait).
#[derive(Debug, Clone, Copy)]
pub struct FixedPolicy {
    /// Batch-size cap.
    pub max: usize,
    /// Wait cap, seconds.
    pub wait_s: f64,
}

impl BatchPolicy for FixedPolicy {
    fn name(&self) -> &'static str {
        "fixed"
    }

    fn max_batch(&self) -> usize {
        self.max.max(1)
    }

    fn decide(&self, v: &BatchView) -> BatchDecision {
        if v.op != 0 || v.size >= self.max_batch() {
            return BatchDecision::Dispatch { size: v.size };
        }
        let close_at = v.formed_at_s + self.wait_s;
        if v.now_s >= close_at {
            BatchDecision::Dispatch { size: v.size }
        } else {
            BatchDecision::Hold { until_s: close_at }
        }
    }
}

/// Deadline-aware formation: hold a forming batch only while every member's
/// SLO slack exceeds the predicted batched service time, and trim the batch
/// so dispatching it never pushes a member past a deadline it would have
/// met unbatched.
///
/// Two rules, both driven by the plan latency profile:
///
/// * **Trim.** The dispatched size is the largest `B` whose predicted
///   batched remaining time (`remaining ×` [`slack_latency_factor`]`(B)`)
///   still meets
///   the tightest member deadline. Trimming keeps the *oldest* members,
///   which within one stream are also the tightest-deadline ones (a
///   stream has a single SLO, so deadlines are arrival-ordered) — the
///   member the trim was computed for is never the one trimmed away. A
///   member that is already predicted late
///   *unbatched* cannot be made worse by batching, so a doomed frontier
///   batches at full size (maximizing drain rate under overload — exactly
///   when batching's energy win is largest).
/// * **Hold.** The frontier stays open only until
///   `min(formed_at + wait, t_safe)`, where `t_safe` is the latest close
///   time at which a batch one larger than the current one would still
///   meet the tightest deadline. Holding therefore never converts a
///   predicted-feasible request into a predicted miss.
#[derive(Debug, Clone, Copy)]
pub struct SlackPolicy {
    /// Batch-size cap.
    pub max: usize,
    /// Wait cap, seconds.
    pub wait_s: f64,
}

impl SlackPolicy {
    /// Largest batch size (≤ `v.size`) the tightest member can absorb.
    fn safe_size(&self, v: &BatchView) -> usize {
        let budget = v.min_deadline_s - v.now_s;
        if budget <= v.remaining_s {
            // already predicted late unbatched: batching cannot manufacture
            // the miss, and draining faster helps everyone behind
            return v.size;
        }
        let mut best = 1;
        for b in 2..=v.size {
            if v.remaining_s * slack_latency_factor(b) <= budget {
                best = b;
            } else {
                break;
            }
        }
        best
    }
}

impl BatchPolicy for SlackPolicy {
    fn name(&self) -> &'static str {
        "slack"
    }

    fn max_batch(&self) -> usize {
        self.max.max(1)
    }

    fn decide(&self, v: &BatchView) -> BatchDecision {
        if v.op != 0 {
            // mid-flight batches stay intact: formation happens at op 0
            return BatchDecision::Dispatch { size: v.size };
        }
        let size = self.safe_size(v);
        if size < v.size || size >= self.max_batch() {
            // trimmed (waiting longer only erodes slack further) or full
            return BatchDecision::Dispatch { size };
        }
        let t_safe = v.min_deadline_s - v.remaining_s * slack_latency_factor(v.size + 1);
        let close_at = (v.formed_at_s + self.wait_s).min(t_safe);
        if v.now_s >= close_at {
            BatchDecision::Dispatch { size }
        } else {
            BatchDecision::Hold { until_s: close_at }
        }
    }
}

/// Build the policy for a configured [`BatchPolicyKind`]; `None` disables
/// batching (no policy object — the engine runs the legacy path).
pub fn by_kind(
    kind: BatchPolicyKind,
    max: usize,
    wait_s: f64,
) -> Option<Box<dyn BatchPolicy + Send + Sync>> {
    match kind {
        BatchPolicyKind::None => None,
        BatchPolicyKind::Fixed => Some(Box::new(FixedPolicy { max, wait_s })),
        BatchPolicyKind::Slack => Some(Box::new(SlackPolicy { max, wait_s })),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(op: usize, size: usize, now: f64, formed: f64, deadline: f64, rem: f64) -> BatchView {
        BatchView {
            op,
            size,
            now_s: now,
            formed_at_s: formed,
            min_deadline_s: deadline,
            remaining_s: rem,
        }
    }

    #[test]
    fn fixed_closes_at_cap_or_timeout() {
        // binary-exact wait so `formed_at + wait` equals the literal below
        let p = FixedPolicy { max: 4, wait_s: 0.5 };
        // below cap, inside the wait window → hold until the timeout
        assert_eq!(
            p.decide(&view(0, 2, 1.0, 1.0, 9.0, 0.05)),
            BatchDecision::Hold { until_s: 1.5 }
        );
        // at cap → dispatch everything
        assert_eq!(
            p.decide(&view(0, 4, 1.0, 1.0, 9.0, 0.05)),
            BatchDecision::Dispatch { size: 4 }
        );
        // timeout reached → dispatch what formed
        assert_eq!(
            p.decide(&view(0, 2, 1.5, 1.0, 9.0, 0.05)),
            BatchDecision::Dispatch { size: 2 }
        );
        // mid-flight ops never hold
        assert_eq!(
            p.decide(&view(3, 2, 1.0, 1.0, 9.0, 0.05)),
            BatchDecision::Dispatch { size: 2 }
        );
    }

    #[test]
    fn slack_holds_only_inside_deadline_headroom() {
        let p = SlackPolicy { max: 8, wait_s: 1.0 };
        // generous deadline: hold, but capped by t_safe, not the wait
        let v = view(0, 2, 1.0, 1.0, 1.5, 0.1);
        match p.decide(&v) {
            BatchDecision::Hold { until_s } => {
                let t_safe = 1.5 - 0.1 * slack_latency_factor(3);
                assert!((until_s - t_safe).abs() < 1e-12, "{until_s} vs {t_safe}");
                assert!(until_s > v.now_s);
            }
            d => panic!("expected hold, got {d:?}"),
        }
        // no headroom for even the current batch: trim to a safe size now
        let tight = view(0, 4, 1.0, 1.0, 1.14, 0.1);
        match p.decide(&tight) {
            BatchDecision::Dispatch { size } => {
                assert!(size < 4, "tight deadline must trim, got {size}");
                assert!(size >= 1);
            }
            d => panic!("expected dispatch, got {d:?}"),
        }
    }

    #[test]
    fn slack_batches_doomed_frontiers_at_full_size() {
        let p = SlackPolicy { max: 8, wait_s: 1.0 };
        // deadline already blown unbatched → full batch, no hold
        let v = view(0, 5, 2.0, 1.9, 2.05, 0.1);
        assert_eq!(p.decide(&v), BatchDecision::Dispatch { size: 5 });
    }

    #[test]
    fn slack_factor_monotone_identity_and_dominates_both_units() {
        use crate::soc::latency::batch_compute_scale;
        use crate::soc::Proc;
        assert_eq!(slack_latency_factor(0), 1.0);
        assert_eq!(slack_latency_factor(1), 1.0);
        let mut prev = 1.0;
        for b in 2..=16 {
            let f = slack_latency_factor(b);
            assert!(f > prev, "batch {b}: {f} !> {prev}");
            // upper-bounds the ground-truth growth of either unit, so the
            // policy's safety predicate is conservative everywhere
            assert!(f >= batch_compute_scale(Proc::Gpu, b));
            assert!(f >= batch_compute_scale(Proc::Cpu, b));
            prev = f;
        }
    }

    #[test]
    fn by_kind_maps() {
        assert!(by_kind(BatchPolicyKind::None, 4, 0.01).is_none());
        assert_eq!(by_kind(BatchPolicyKind::Fixed, 4, 0.01).unwrap().name(), "fixed");
        assert_eq!(by_kind(BatchPolicyKind::Slack, 4, 0.01).unwrap().name(), "slack");
    }
}
