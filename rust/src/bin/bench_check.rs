//! Validator for the committed bench trajectory files
//! (`BENCH_hot_loop.json`, `BENCH_dp_solve.json`) — `make bench-check`.
//!
//! The trajectories are append-only JSONL: a schema header line followed
//! by one record per bench-host run. Appends happen on developer
//! machines outside CI, so CI cannot re-measure them — but it *can*
//! prove the files still parse and every record carries the fields the
//! header promises. A hand-edited header, a torn append, or a bench
//! emitter that drifted from the recorded schema all fail here instead
//! of rotting silently until the next perf investigation.

use adaoper::util::json::Json;
use anyhow::{bail, ensure, Context, Result};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    ensure!(!args.is_empty(), "usage: bench_check <BENCH_*.json> […]");
    for path in &args {
        let (bench, records) =
            check_file(path).with_context(|| format!("validating {path}"))?;
        println!("{path}: ok ({bench}, {records} data record(s))");
    }
    Ok(())
}

/// Validate one trajectory file; returns the bench name and the number
/// of data records. Zero records is valid — a freshly seeded trajectory
/// is just its header.
fn check_file(path: &str) -> Result<(String, usize)> {
    let text = std::fs::read_to_string(path)?;
    let mut lines = text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty());

    let (_, header) = lines.next().context("empty file: missing schema header")?;
    let h = Json::parse(header).context("schema header is not valid JSON")?;
    let schema = h.need_str("schema")?;
    ensure!(schema == "adaoper-bench-v2", "unknown schema `{schema}` (want adaoper-bench-v2)");
    let bench = h.need_str("bench")?.to_string();
    ensure!(!h.need_str("note")?.is_empty(), "header `note` must describe the trajectory");

    // per-bench required numeric stats; provenance fields (git_rev,
    // host, os, arch) are v2-only and stay optional so committed v1
    // records keep validating
    let required: &[&str] = match bench.as_str() {
        "engine_hot_loop" => {
            &["events_per_sec_mean", "events_per_sec_min", "events_per_sec_max"]
        }
        "dp_solve" => &[
            "solves_per_sec_map",
            "solves_per_sec_lattice",
            "speedup_full",
            "window_solves_per_sec_map",
            "window_solves_per_sec_lattice",
            "speedup_window",
        ],
        other => bail!("header names unknown bench `{other}`"),
    };

    let mut records = 0usize;
    for (i, line) in lines {
        records += 1;
        let lineno = i + 1;
        let rec = Json::parse(line)
            .with_context(|| format!("data line {lineno} is not valid JSON"))?;
        let b = rec.need_str("bench").with_context(|| format!("data line {lineno}"))?;
        ensure!(b == bench, "data line {lineno}: bench `{b}` != header bench `{bench}`");
        let mode = rec.need_str("mode").with_context(|| format!("data line {lineno}"))?;
        ensure!(
            mode == "full" || mode == "quick",
            "data line {lineno}: unknown mode `{mode}`"
        );
        for key in required {
            let v = rec
                .get(key)
                .and_then(Json::as_f64)
                .with_context(|| format!("data line {lineno}: missing numeric `{key}`"))?;
            ensure!(
                v.is_finite() && v > 0.0,
                "data line {lineno}: `{key}` = {v} is not finite and positive"
            );
        }
        if bench == "engine_hot_loop" {
            let f = |k: &str| rec.get(k).and_then(Json::as_f64).unwrap_or(0.0);
            let (min, mean, max) = (
                f("events_per_sec_min"),
                f("events_per_sec_mean"),
                f("events_per_sec_max"),
            );
            ensure!(
                min <= mean && mean <= max,
                "data line {lineno}: events_per_sec min {min} / mean {mean} / max {max} \
                 out of order"
            );
        }
    }
    Ok((bench, records))
}
