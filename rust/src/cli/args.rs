//! Minimal argument parser: `--key value`, `--flag`, and positionals.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Positional arguments, in order.
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse raw argv (excluding the program name). `bool_flags` lists
    /// options that take no value.
    pub fn parse(argv: &[String], bool_flags: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if bool_flags.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    let v = argv
                        .get(i + 1)
                        .ok_or_else(|| anyhow!("--{name} needs a value"))?;
                    if v.starts_with("--") {
                        bail!("--{name} needs a value, got `{v}`");
                    }
                    out.options.insert(name.to_string(), v.clone());
                    i += 1;
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    /// Raw value of `--name`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// String value of `--name`, or `default`.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Float value of `--name`, or `default`; errors on a non-number.
    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name}: `{v}` is not a number")),
        }
    }

    /// Unsigned value of `--name`, or `default`; errors on a non-integer.
    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name}: `{v}` is not an integer")),
        }
    }

    /// u64 value of `--name`, or `default`; errors on a non-integer.
    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name}: `{v}` is not an integer")),
        }
    }

    /// Whether the boolean flag `--name` was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(&argv("serve --policy codl --seed 9 --verbose x"), &["verbose"])
            .unwrap();
        assert_eq!(a.positional, vec!["serve", "x"]);
        assert_eq!(a.get("policy"), Some("codl"));
        assert_eq!(a.u64_or("seed", 0).unwrap(), 9);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&argv("--policy"), &[]).is_err());
        assert!(Args::parse(&argv("--policy --seed 3"), &[]).is_err());
    }

    #[test]
    fn bad_number_errors() {
        let a = Args::parse(&argv("--seed abc"), &[]).unwrap();
        assert!(a.u64_or("seed", 0).is_err());
        assert!(a.f64_or("seed", 0.0).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&argv(""), &[]).unwrap();
        assert_eq!(a.f64_or("rate", 5.0).unwrap(), 5.0);
        assert_eq!(a.str_or("policy", "adaoper"), "adaoper");
    }
}
