//! `adaoper` subcommands.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::batching::BatchConfig;
use crate::config::schema::{
    AdmissionKind, AppConfig, BatchPolicyKind, ConditionKind, PolicyKind, SchedulerKind,
};
use crate::coordinator::scheduler::AdmissionPolicy;
use crate::coordinator::{Engine, EngineConfig, StreamSpec};
use crate::experiments::{ablations, fig2};
use crate::graph::zoo;
use crate::partition::baselines::by_policy;
use crate::partition::plan::Objective;
use crate::profiler::calibrate::{self, CalibConfig};
use crate::profiler::gbdt::GbdtParams;
use crate::soc::device::{Device, DeviceConfig};
use crate::util::json::Json;
use crate::workload::{Arrival, WorkloadCondition};

use super::args::Args;

/// CLI help text (`adaoper help`).
pub const USAGE: &str = "\
adaoper — energy-efficient concurrent DNN inference (AdaOper, MobiSys'24)

USAGE: adaoper <command> [options]

COMMANDS
  zoo [model]                 list zoo models / describe one
  partition --model M         plan a partition and print per-op placements
      [--policy P] [--condition C] [--objective O]
  serve                       run the concurrent serving engine
      [--config F] [--models a,b] [--policy P] [--condition C]
      [--rate HZ] [--duration S] [--slo-ms MS] [--seed N]
      [--arrival poisson|periodic|mmpp] [--arrival-jitter X]
      [--scheduler fifo|edf|slack-reclaim] (default fifo)
      [--admission admit-all|drop-late|bounded] [--queue-limit N]
      [--batch-policy none|fixed|slack] [--batch-max N]
      [--batch-wait-ms MS]    dynamic batching (default none = off)
      [--plan-cache-cap N] [--plan-cache-freq-bucket-mhz MHZ]
      [--plan-cache-util-bucket X]
      [--trace PATH]          write per-request JSONL timelines to PATH
      [--telemetry]           record the plan-decision audit log, kernel
                              event lines, and stage self-profiling
                              timers (off by default; with --trace the
                              audit + timer lines land in the trace)
      [--health]              run the streaming health monitor (windowed
                              SLO burn-rate, energy-budget, drift, and
                              queue-depth rules; alerts log at warn level
                              and land in the trace; also enabled by the
                              [health] config section)
  fleet                       simulate a heterogeneous device fleet
      [--config F] [--devices N] [--threads T] [--seed S] [--duration S]
      [--scheduler fifo|edf|slack-reclaim] [--policy P] [--quick]
      [--admission admit-all|drop-late|bounded] [--queue-limit N]
      [--batch-policy none|fixed|slack] [--batch-max N] [--batch-wait-ms MS]
  scenario run <spec|dir>     execute a scenario spec (or every *.toml in
                              a directory) and evaluate its [expect]
                              metric bounds; non-zero exit on violation
  scenario check <spec>       parse + validate a spec without running it
  replay <trace.jsonl>        re-run a recorded serve trace through the
                              sim kernel and verify the replayed report
                              row matches the recorded one byte for byte
  inspect <trace.jsonl>       render the telemetry recorded in a trace:
                              plan-decision audit table by default;
                              malformed lines (truncated writes) are
                              skipped with a warning, not fatal
      [--stages]              kernel stage self-profiling table
      [--alerts]              health-alert table (record with --health)
      [--perfetto OUT]        export a Chrome trace-event / Perfetto
                              JSON timeline to OUT (open at
                              ui.perfetto.dev or chrome://tracing)
  fig2 [--requests N]         reproduce the paper's Figure 2
  calibrate [--samples N]     run the offline calibration sweep and report
                              held-out accuracy
  ablation <a1|..|a9|cache|scheduler|fleet|batching>  run one ablation
                              (`cache`, alias `a6`: plan-cache hit rate on
                              the bursty recurring-condition trace;
                              `scheduler`, alias `a7`: overload sweep
                              comparing fifo/edf/slack-reclaim dispatch
                              [--duration S] [--seed N];
                              `fleet`, alias `a8`: scale sweep over device
                              counts × dispatch policy [--threads T];
                              `batching`, alias `a9`: energy-per-request
                              and p95 vs batch cap across load levels on
                              bursty MMPP arrivals [--duration S] [--seed N])
  help                        this text

COMMON OPTIONS
  --policy   adaoper|codl|mace-gpu|all-cpu|greedy   (default adaoper)
  --condition idle|moderate|high                    (default moderate)
  --seed N                                          (default 7)
  --quick                     smaller calibration budget (faster, rougher)
  --log-level L               error|warn|info|debug|trace (default info;
                              `--verbose` is shorthand for debug)
";

fn calib_of(args: &Args) -> Result<CalibConfig> {
    Ok(if args.flag("quick") {
        CalibConfig {
            samples: 2000,
            seed: args.u64_or("seed", 7)?,
            gbdt: GbdtParams {
                trees: 60,
                ..Default::default()
            },
        }
    } else {
        CalibConfig {
            seed: args.u64_or("seed", 7)?,
            ..CalibConfig::default()
        }
    })
}

/// Entry point used by `main.rs`.
pub fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(
        argv,
        &["quick", "verbose", "oracle", "telemetry", "stages", "health", "alerts"],
    )?;
    if args.flag("verbose") {
        crate::util::logger::set_level(crate::util::logger::Level::Debug);
    }
    if let Some(l) = args.get("log-level") {
        // explicit --log-level wins over --verbose
        match crate::util::logger::parse_level(l) {
            Some(lv) => crate::util::logger::set_level(lv),
            None => bail!("--log-level: unknown level `{l}` (error|warn|info|debug|trace)"),
        }
    }
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "zoo" => cmd_zoo(&args),
        "partition" => cmd_partition(&args),
        "serve" => cmd_serve(&args),
        "fleet" => cmd_fleet(&args),
        "scenario" => cmd_scenario(&args),
        "replay" => cmd_replay(&args),
        "inspect" => cmd_inspect(&args),
        "fig2" => cmd_fig2(&args),
        "calibrate" => cmd_calibrate(&args),
        "ablation" => cmd_ablation(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command `{other}`\n\n{USAGE}"),
    }
}

fn cmd_zoo(args: &Args) -> Result<()> {
    match args.positional.get(1) {
        None => {
            println!("{:<14} {:>7} {:>10} {:>12}", "model", "ops", "GFLOPs", "weights MB");
            for name in zoo::names() {
                let g = zoo::by_name(name).unwrap();
                println!(
                    "{:<14} {:>7} {:>10.2} {:>12.1}",
                    name,
                    g.num_ops(),
                    g.total_flops() as f64 / 1e9,
                    g.total_weight_bytes() as f64 / 1e6
                );
            }
        }
        Some(name) => match zoo::by_name(name) {
            Some(g) => print!("{}", g.describe()),
            None => bail!("unknown model `{name}` (see `adaoper zoo`)"),
        },
    }
    Ok(())
}

fn cmd_partition(args: &Args) -> Result<()> {
    let model = args.str_or("model", "yolov2");
    let g = zoo::by_name(&model).ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?;
    let policy = PolicyKind::parse(&args.str_or("policy", "adaoper"))?;
    let condition = ConditionKind::parse(&args.str_or("condition", "moderate"))?;
    let objective = match args.str_or("objective", "min-edp").as_str() {
        "min-edp" => Objective::MinEdp,
        "min-latency" => Objective::MinLatency,
        o => {
            if let Some(ms) = o.strip_prefix("slo:") {
                Objective::MinEnergyUnderSlo {
                    slo_s: ms.parse::<f64>()? / 1e3,
                }
            } else {
                bail!("unknown objective `{o}` (min-edp|min-latency|slo:<ms>)")
            }
        }
    };
    let mut device = Device::new(DeviceConfig::snapdragon_855());
    device.apply_condition(&WorkloadCondition::by_name(condition.name()).unwrap().spec);
    let snap = device.snapshot();

    let partitioner = by_policy(policy, objective);
    let plan = if args.flag("oracle") {
        partitioner.partition(&g, &device, &snap)?
    } else {
        println!("calibrating profiler …");
        let offline = calibrate::calibrate(&calib_of(args)?);
        let prof = crate::profiler::EnergyProfiler::offline_only(offline);
        partitioner.partition(&g, &prof, &snap)?
    };
    println!(
        "plan for {model} under {} by {} (objective {:?}):",
        condition.name(),
        plan.policy,
        objective
    );
    for (op, p) in g.ops.iter().zip(&plan.placements) {
        println!("  [{:>3}] {:<22} -> {}", op.id, op.name, p);
    }
    println!(
        "predicted: {:.2} ms, {:.2} mJ",
        plan.predicted.latency_s * 1e3,
        plan.predicted.energy_j * 1e3
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = AppConfig::load(args.get("config").map(Path::new))?;
    // CLI overrides
    if let Some(m) = args.get("models") {
        cfg.serve.models = m.split(',').map(str::to_string).collect();
    }
    if let Some(p) = args.get("policy") {
        cfg.serve.policy = PolicyKind::parse(p)?;
    }
    if let Some(c) = args.get("condition") {
        cfg.serve.condition = ConditionKind::parse(c)?;
    }
    if let Some(s) = args.get("scheduler") {
        cfg.serve.scheduler = SchedulerKind::parse(s)?;
    }
    if let Some(a) = args.get("admission") {
        cfg.serve.admission = AdmissionKind::parse(a)?;
    }
    cfg.serve.queue_limit = args.usize_or("queue-limit", cfg.serve.queue_limit)?;
    anyhow::ensure!(cfg.serve.queue_limit >= 1, "--queue-limit must be >= 1");
    if let Some(b) = args.get("batch-policy") {
        cfg.serve.batch_policy = BatchPolicyKind::parse(b)?;
    }
    cfg.serve.batch_max = args.usize_or("batch-max", cfg.serve.batch_max)?;
    anyhow::ensure!(cfg.serve.batch_max >= 1, "--batch-max must be >= 1");
    cfg.serve.batch_wait_ms = args.f64_or("batch-wait-ms", cfg.serve.batch_wait_ms)?;
    anyhow::ensure!(cfg.serve.batch_wait_ms >= 0.0, "--batch-wait-ms must be >= 0");
    if let Some(a) = args.get("arrival") {
        cfg.serve.arrival = a.to_string();
    }
    cfg.serve.arrival_jitter = args.f64_or("arrival-jitter", cfg.serve.arrival_jitter)?;
    anyhow::ensure!(
        (0.0..=1.0).contains(&cfg.serve.arrival_jitter),
        "--arrival-jitter must be in [0, 1]"
    );
    cfg.serve.rate_hz = args.f64_or("rate", cfg.serve.rate_hz)?;
    cfg.serve.duration_s = args.f64_or("duration", cfg.serve.duration_s)?;
    cfg.serve.slo_ms = args.f64_or("slo-ms", cfg.serve.slo_ms)?;
    cfg.serve.seed = args.u64_or("seed", cfg.serve.seed)?;
    cfg.partition.plan_cache_capacity =
        args.usize_or("plan-cache-cap", cfg.partition.plan_cache_capacity)?;
    cfg.partition.plan_cache_freq_bucket_mhz = args.f64_or(
        "plan-cache-freq-bucket-mhz",
        cfg.partition.plan_cache_freq_bucket_mhz,
    )?;
    cfg.partition.plan_cache_util_bucket =
        args.f64_or("plan-cache-util-bucket", cfg.partition.plan_cache_util_bucket)?;
    anyhow::ensure!(
        cfg.partition.plan_cache_freq_bucket_mhz > 0.0
            && cfg.partition.plan_cache_util_bucket > 0.0,
        "plan-cache bucket widths must be > 0"
    );

    // schema validation guarantees `min-edp` or `min-energy-slo`; the SLO
    // objective constrains against the serving deadline
    let objective = match cfg.partition.objective.as_str() {
        "min-energy-slo" => Objective::MinEnergyUnderSlo {
            slo_s: cfg.serve.slo_ms / 1e3,
        },
        _ => Objective::MinEdp,
    };
    let ecfg = EngineConfig {
        policy: cfg.serve.policy,
        objective,
        condition: cfg.serve.condition,
        duration_s: cfg.serve.duration_s,
        seed: cfg.serve.seed,
        window: cfg.partition.window,
        calib: CalibConfig {
            samples: cfg.profiler.calib_samples,
            seed: cfg.serve.seed,
            gbdt: GbdtParams {
                trees: cfg.profiler.gbdt_trees,
                max_depth: cfg.profiler.gbdt_depth,
                eta: cfg.profiler.gbdt_eta,
                subsample: cfg.profiler.gbdt_subsample,
                ..Default::default()
            },
        },
        use_corrector: cfg.profiler.use_gru,
        scheduler: cfg.serve.scheduler,
        admission: AdmissionPolicy::from_kind(cfg.serve.admission, cfg.serve.queue_limit),
        batching: BatchConfig {
            policy: cfg.serve.batch_policy,
            max: cfg.serve.batch_max,
            wait_s: cfg.serve.batch_wait_ms / 1e3,
        },
        plan_cache: crate::coordinator::PlanCacheConfig {
            capacity: cfg.partition.plan_cache_capacity,
            freq_bucket_hz: cfg.partition.plan_cache_freq_bucket_mhz * 1e6,
            util_bucket: cfg.partition.plan_cache_util_bucket,
            ..Default::default()
        },
        telemetry: args.flag("telemetry"),
        health: (args.flag("health") || cfg.health.enabled)
            .then(|| cfg.health.rules.clone()),
        ..Default::default()
    };
    let mut engine = Engine::new(ecfg.clone());
    if ecfg.telemetry {
        engine.enable_stage_timers();
    }

    let mut streams = Vec::new();
    for (i, m) in cfg.serve.models.iter().enumerate() {
        let g = zoo::by_name(m).ok_or_else(|| anyhow::anyhow!("unknown model {m}"))?;
        let arrival =
            Arrival::parse(&cfg.serve.arrival, cfg.serve.rate_hz, cfg.serve.arrival_jitter)
                .ok_or_else(|| anyhow::anyhow!("unknown arrival {}", cfg.serve.arrival))?;
        streams.push(StreamSpec::new(i, g, arrival, cfg.serve.slo_ms / 1e3));
    }
    println!(
        "serving {:?} for {:.1}s (policy {}, condition {}) …",
        cfg.serve.models,
        cfg.serve.duration_s,
        cfg.serve.policy.name(),
        cfg.serve.condition.name()
    );
    let trace_path = match args.get("trace") {
        Some(p) => Some(p.to_string()),
        None if !cfg.serve.trace.is_empty() => Some(cfg.serve.trace.clone()),
        None => None,
    };
    let report = match &trace_path {
        Some(path) => {
            // with_meta stamps a trace_header (full run config) so the
            // trace is replayable via `adaoper replay`; the report row
            // trailer gives replay a byte-identity target.
            let meta = crate::metrics::TraceMeta::of(&ecfg, &streams);
            let mut trace = crate::metrics::TraceObserver::with_meta(meta);
            if ecfg.telemetry {
                trace = trace.with_kernel_events();
            }
            let r = engine.run_observed(&streams, &mut [&mut trace])?;
            // audit + stage-timer lines precede the report trailer so
            // `adaoper inspect` sees them; replay skips them
            if let Some(audit) = engine.audit() {
                for line in audit.jsonl_lines() {
                    trace.push_line(line);
                }
            }
            if let Some(timers) = engine.take_stage_timers() {
                trace.push_line(timers.jsonl());
            }
            trace.push_report_row(&r.row());
            trace.write_to(Path::new(path))?;
            println!("trace: {} lines (header + requests + report) -> {path}", trace.len());
            r
        }
        None => engine.run(&streams)?,
    };
    print!("{}", report.pretty());
    Ok(())
}

fn cmd_scenario(args: &Args) -> Result<()> {
    let sub = args.positional.get(1).map(String::as_str).unwrap_or("");
    let target = args.positional.get(2).map(Path::new);
    match (sub, target) {
        ("check", Some(path)) => {
            let spec = crate::scenario::parse_spec_file(path)?;
            println!(
                "ok: scenario `{}` is valid ({} stream(s), {} [expect] bound(s))",
                spec.name,
                spec.stream_names.len(),
                spec.expect.len()
            );
            Ok(())
        }
        ("run", Some(path)) => {
            let files = if path.is_dir() {
                crate::scenario::runner::spec_files(path)?
            } else {
                vec![path.to_path_buf()]
            };
            anyhow::ensure!(!files.is_empty(), "no *.toml specs under {}", path.display());
            let mut failed = 0usize;
            for f in &files {
                let outcome = crate::scenario::run_path(f)?;
                print!("{}", outcome.render());
                if !outcome.passed() {
                    failed += 1;
                }
            }
            if failed > 0 {
                bail!("{failed}/{} scenario(s) failed their [expect] bounds", files.len());
            }
            println!("{} scenario(s) passed", files.len());
            Ok(())
        }
        _ => bail!("usage: adaoper scenario <run|check> <spec.toml|dir>"),
    }
}

fn cmd_replay(args: &Args) -> Result<()> {
    let Some(target) = args.positional.get(1) else {
        bail!("usage: adaoper replay <trace.jsonl>");
    };
    let outcome = crate::scenario::replay_path(Path::new(target))?;
    println!("replayed {} recorded arrival(s)", outcome.arrivals);
    println!("{}", outcome.row);
    match outcome.matches() {
        None => {
            println!("trace carries no recorded report row; nothing to compare");
            Ok(())
        }
        Some(true) => {
            println!("MATCH: replayed report row equals the recorded one");
            Ok(())
        }
        Some(false) => bail!(
            "replay MISMATCH\n  recorded: {}\n  replayed: {}",
            outcome.recorded_row.as_deref().unwrap_or(""),
            outcome.row
        ),
    }
}

/// Everything `adaoper inspect` extracts from a trace's JSONL body, plus
/// the count of malformed lines it skipped. Truncated or garbled lines
/// (interrupted writes, partial flushes on crash) are warned about and
/// counted rather than aborting the whole inspection — the tail of a
/// trace that died mid-write is exactly when inspection matters most.
#[derive(Debug, Default)]
pub struct TraceScan {
    /// `plan_decision` audit lines, in file order.
    pub decisions: Vec<Json>,
    /// Health `alert` transition lines, in file order.
    pub alerts: Vec<Json>,
    /// Kernel stage self-profiling totals, when recorded.
    pub timers: Option<crate::sim::StageTimers>,
    /// The recorded final report row, when present.
    pub report_row: Option<String>,
    /// Non-empty lines that failed to parse as JSON.
    pub skipped: usize,
}

/// Scan a trace's text into a [`TraceScan`]. Unparseable lines are
/// counted and logged at warn level; lines that parse but carry a
/// structurally wrong payload for a known event still error, since that
/// indicates a schema mismatch rather than a torn write.
pub fn scan_trace(text: &str) -> Result<TraceScan> {
    let mut scan = TraceScan::default();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let obj = match Json::parse(line) {
            Ok(obj) => obj,
            Err(e) => {
                scan.skipped += 1;
                crate::log_warn!("inspect: skipping malformed trace line {}: {e:#}", i + 1);
                continue;
            }
        };
        match obj.get("event").and_then(Json::as_str) {
            Some("plan_decision") => scan.decisions.push(obj),
            Some("alert") => scan.alerts.push(obj),
            Some("report") => scan.report_row = Some(obj.need_str("row")?.to_string()),
            Some("stage_timers") => {
                let stages = obj
                    .get("stages")
                    .ok_or_else(|| anyhow::anyhow!("stage_timers line missing `stages`"))?;
                let mut t = crate::sim::StageTimers::new();
                for stage in crate::sim::Stage::ALL {
                    if let Some(s) = stages.get(stage.name()) {
                        t.accumulate(stage, s.need_u64("calls")?, s.need_f64("secs")?);
                    }
                }
                scan.timers = Some(t);
            }
            _ => {}
        }
    }
    Ok(scan)
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let Some(target) = args.positional.get(1) else {
        bail!("usage: adaoper inspect <trace.jsonl> [--stages] [--alerts] [--perfetto out.json]");
    };
    let text = std::fs::read_to_string(target)
        .with_context(|| format!("reading trace {target}"))?;

    if let Some(out_path) = args.get("perfetto") {
        let json = crate::metrics::perfetto::export_str(&text)?;
        let n = crate::metrics::perfetto::validate(&json)?;
        std::fs::write(out_path, &json)
            .with_context(|| format!("writing perfetto export {out_path}"))?;
        println!("perfetto: {n} trace event(s) -> {out_path} (open at ui.perfetto.dev)");
        return Ok(());
    }

    let scan = scan_trace(&text)?;
    if scan.skipped > 0 {
        println!("warning: skipped {} malformed trace line(s)", scan.skipped);
    }
    let TraceScan { decisions, alerts, timers, report_row, .. } = scan;

    if args.flag("alerts") {
        if alerts.is_empty() {
            println!(
                "trace carries no health alerts — record one with \
                 `adaoper serve --trace … --health`"
            );
            return Ok(());
        }
        println!("health alerts: {} transition(s)", alerts.len());
        println!(
            "{:>10} {:<14} {:<7} {:<18} {:>10} {:>10}",
            "t ms", "rule", "target", "transition", "signal", "threshold"
        );
        for a in &alerts {
            let target = a
                .get("stream")
                .and_then(Json::as_usize)
                .map_or("global".to_string(), |s| format!("s{s}"));
            println!(
                "{:>10.3} {:<14} {:<7} {:<18} {:>10.4} {:>10.4}",
                a.need_f64("t_s")? * 1e3,
                a.need_str("rule")?,
                target,
                format!("{} -> {}", a.need_str("prev")?, a.need_str("state")?),
                a.need_f64("signal")?,
                a.need_f64("threshold")?,
            );
        }
        return Ok(());
    }

    if args.flag("stages") {
        match timers {
            Some(t) => print!("{}", t.render()),
            None => println!(
                "trace carries no stage_timers line — record one with \
                 `adaoper serve --trace … --telemetry`"
            ),
        }
        return Ok(());
    }

    if decisions.is_empty() {
        println!(
            "trace carries no plan-decision audit — record one with \
             `adaoper serve --trace … --telemetry`"
        );
    } else {
        let hits = decisions
            .iter()
            .filter(|d| d.get("cache_hit").and_then(Json::as_bool) == Some(true))
            .count();
        println!("plan-decision audit: {} decision(s), {hits} cache hit(s)", decisions.len());
        println!(
            "{:>10} {:>4} {:<14} {:>5} {:>9}    {:>9} {:>10} {:>10} {:>9}  {}",
            "t ms", "strm", "trigger", "cache", "lat ms", "-> lat ms", "resid cpu", "resid gpu",
            "solve µs", "plan fp old -> new"
        );
        for d in &decisions {
            let resid = |proc: &str| -> Result<String> {
                let r = d
                    .get("residuals")
                    .and_then(|r| r.get(proc))
                    .ok_or_else(|| anyhow::anyhow!("plan_decision missing residuals.{proc}"))?;
                Ok(if r.need_u64("ops")? == 0 {
                    "-".to_string()
                } else {
                    format!("{:+.3}", (r.need_f64("actual_s")? - r.need_f64("pred_s")?) * 1e3)
                })
            };
            println!(
                "{:>10.3} {:>4} {:<14} {:>5} {:>9.3}    {:>9.3} {:>10} {:>10} {:>9.1}  {} -> {}",
                d.need_f64("t_s")? * 1e3,
                d.need_usize("stream")?,
                d.need_str("trigger")?,
                if d.need_bool("cache_hit")? { "hit" } else { "miss" },
                d.get("pred_before").map_or(0.0, |p| {
                    p.get("latency_s").and_then(Json::as_f64).unwrap_or(0.0) * 1e3
                }),
                d.get("pred_after").map_or(0.0, |p| {
                    p.get("latency_s").and_then(Json::as_f64).unwrap_or(0.0) * 1e3
                }),
                resid("cpu")?,
                resid("gpu")?,
                d.need_f64("decision_s")? * 1e6,
                d.need_str("old_fp")?,
                d.need_str("new_fp")?,
            );
        }
    }
    if timers.is_some() {
        println!("(stage self-profiling recorded — render it with `--stages`)");
    }
    if !alerts.is_empty() {
        println!("({} health alert(s) recorded — render them with `--alerts`)", alerts.len());
    }
    if let Some(row) = report_row {
        println!("report: {row}");
    }
    Ok(())
}

fn cmd_fleet(args: &Args) -> Result<()> {
    let cfg = AppConfig::load(args.get("config").map(Path::new))?;
    let devices = args.usize_or("devices", cfg.fleet.devices)?;
    let threads = args.usize_or("threads", cfg.fleet.threads)?;
    let seed = args.u64_or("seed", cfg.fleet.seed)?;
    let duration_s = args.f64_or("duration", cfg.fleet.duration_s)?;
    let scheduler = match args.get("scheduler") {
        Some(s) => SchedulerKind::parse(s)?,
        None => cfg.fleet.scheduler,
    };
    let admission = match args.get("admission") {
        Some(a) => AdmissionKind::parse(a)?,
        None => cfg.fleet.admission,
    };
    let queue_limit = args.usize_or("queue-limit", cfg.fleet.queue_limit)?;
    anyhow::ensure!(queue_limit >= 1, "--queue-limit must be >= 1");
    let policy = match args.get("policy") {
        Some(p) => PolicyKind::parse(p)?,
        None => PolicyKind::AdaOper,
    };
    let batch_policy = match args.get("batch-policy") {
        Some(b) => BatchPolicyKind::parse(b)?,
        None => cfg.fleet.batch_policy,
    };
    let batch_max = args.usize_or("batch-max", cfg.fleet.batch_max)?;
    anyhow::ensure!(batch_max >= 1, "--batch-max must be >= 1");
    let batch_wait_ms = args.f64_or("batch-wait-ms", cfg.fleet.batch_wait_ms)?;
    anyhow::ensure!(batch_wait_ms >= 0.0, "--batch-wait-ms must be >= 0");
    let fcfg = crate::fleet::FleetRunConfig {
        devices,
        threads,
        seed,
        duration_s,
        policy,
        scheduler,
        admission: AdmissionPolicy::from_kind(admission, queue_limit),
        batching: BatchConfig {
            policy: batch_policy,
            max: batch_max,
            wait_s: batch_wait_ms / 1e3,
        },
        calib: calib_of(args)?,
        health: cfg.health.enabled.then(|| cfg.health.rules.clone()),
        ..Default::default()
    };
    println!(
        "simulating {devices} devices (seed {seed}, {duration_s:.1}s horizon; \
         calibrating per-class profilers) …"
    );
    let report = crate::fleet::run_fleet(&fcfg)?;
    print!("{}", report.render());
    Ok(())
}

fn cmd_fig2(args: &Args) -> Result<()> {
    let cfg = fig2::Fig2Config {
        model: args.str_or("model", "yolov2"),
        n_requests: args.usize_or("requests", 40)?,
        seed: args.u64_or("seed", 7)?,
        calib: calib_of(args)?,
    };
    println!("running Figure 2 matrix ({} requests per cell) …", cfg.n_requests);
    let rows = fig2::run(&cfg)?;
    print!("{}", fig2::render(&rows));
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let mut cfg = calib_of(args)?;
    cfg.samples = args.usize_or("samples", cfg.samples)?;
    println!("generating {} calibration samples …", cfg.samples);
    let samples = calibrate::generate(&cfg);
    let split = samples.len() * 4 / 5;
    let model = calibrate::fit(&samples[..split], &cfg.gbdt);
    for (name, proc) in [("cpu", crate::soc::Proc::Cpu), ("gpu", crate::soc::Proc::Gpu)] {
        let rows: Vec<&calibrate::Sample> = samples[split..]
            .iter()
            .filter(|s| s.proc == proc)
            .collect();
        let m = match proc {
            crate::soc::Proc::Cpu => &model.cpu,
            crate::soc::Proc::Gpu => &model.gpu,
        };
        let pe: Vec<f64> = rows.iter().map(|s| m.energy.predict(&s.features).exp()).collect();
        let te: Vec<f64> = rows.iter().map(|s| s.energy_j).collect();
        let pl: Vec<f64> = rows.iter().map(|s| m.latency.predict(&s.features).exp()).collect();
        let tl: Vec<f64> = rows.iter().map(|s| s.latency_s).collect();
        println!(
            "{name}: held-out energy MAPE {:>5.1}%  latency MAPE {:>5.1}%  ({} samples)",
            crate::util::stats::mape(&pe, &te),
            crate::util::stats::mape(&pl, &tl),
            rows.len()
        );
    }
    Ok(())
}

fn cmd_ablation(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("a1");
    let calib = calib_of(args)?;
    let seed = args.u64_or("seed", 7)?;
    match which {
        "a1" => {
            let rows = ablations::profiler_accuracy(&calib, 3.0, seed, None)?;
            println!("{:<12} {:>14} {:>14} {:>8}", "arm", "energy MAPE", "latency MAPE", "n");
            for r in rows {
                println!(
                    "{:<12} {:>13.1}% {:>13.1}% {:>8}",
                    r.arm, r.energy_mape, r.latency_mape, r.observations
                );
            }
        }
        "a2" => {
            let rows = ablations::dp_comparison(seed)?;
            println!("{:<22} {:>12} {:>10} {:>12}", "case", "score", "rel", "solve µs");
            for r in rows {
                println!(
                    "{:<22} {:>12.6} {:>10.4} {:>12.1}",
                    r.case, r.score, r.relative, r.solve_us
                );
            }
        }
        "a3" => {
            let rows = ablations::incremental_vs_full(&[2, 4, 8, 16])?;
            println!("{:<18} {:>14} {:>12}", "scheme", "decision µs", "EDP vs full");
            for r in rows {
                println!("{:<18} {:>14.1} {:>12.4}", r.scheme, r.decision_us, r.edp_vs_full);
            }
        }
        "a4" => {
            let rows = ablations::responsiveness(&calib, seed)?;
            println!(
                "{:<12} {:>14} {:>14} {:>10} {:>8}",
                "policy", "post-switch ms", "steady ms", "overshoot", "repart"
            );
            for r in rows {
                println!(
                    "{:<12} {:>14.2} {:>14.2} {:>10.3} {:>8}",
                    r.policy.name(),
                    r.post_switch_ms,
                    r.steady_high_ms,
                    r.overshoot,
                    r.repartitions
                );
            }
        }
        "a5" => {
            let rows = ablations::concurrency_scaling(&calib, seed, 6.0)?;
            println!(
                "{:<12} {:>8} {:>12} {:>10} {:>12} {:>8}",
                "policy", "streams", "req/s", "p90 ms", "mJ/inf", "miss%"
            );
            for r in rows {
                println!(
                    "{:<12} {:>8} {:>12.2} {:>10.1} {:>12.1} {:>8.1}",
                    r.policy.name(),
                    r.streams,
                    r.throughput_hz,
                    r.p95_ms,
                    r.mj_per_inf,
                    r.miss_rate * 100.0
                );
            }
        }
        "cache" | "a6" => {
            use crate::experiments::cache_scenario;
            let res = cache_scenario::run(&cache_scenario::CacheScenarioConfig {
                seed,
                calib,
                ..Default::default()
            })?;
            let st = res.stats;
            println!("== plan cache under the bursty recurring-condition trace ==");
            println!(
                "requests {}  repartitions {}  mean decision {:.1} µs",
                res.requests,
                res.repartitions,
                res.mean_decision_s * 1e6
            );
            println!(
                "cache: {} hits / {} misses ({:.1}% hit rate), {} evictions, {}/{} entries",
                st.hits,
                st.misses,
                res.hit_rate() * 100.0,
                st.evictions,
                st.entries,
                st.capacity
            );
        }
        "scheduler" | "a7" => {
            use crate::experiments::scheduler_scenario;
            let cfg = scheduler_scenario::SchedulerSweepConfig {
                seed,
                calib,
                duration_s: args.f64_or("duration", 4.0)?,
                ..Default::default()
            };
            println!("== scheduler overload sweep (fifo vs edf vs slack-reclaim) ==");
            let res = scheduler_scenario::run(&cfg)?;
            print!("{}", scheduler_scenario::render(&res));
        }
        "fleet" | "a8" => {
            use crate::experiments::fleet_scenario;
            let cfg = fleet_scenario::FleetSweepConfig {
                seed,
                calib,
                threads: args.usize_or("threads", 4)?,
                duration_s: args.f64_or("duration", 1.5)?,
                ..Default::default()
            };
            println!("== A8: fleet scale sweep (device classes × dispatch policy) ==");
            let rows = fleet_scenario::run(&cfg)?;
            print!("{}", fleet_scenario::render(&rows));
        }
        "batching" | "a9" => {
            use crate::experiments::batching_scenario;
            let cfg = batching_scenario::BatchingSweepConfig {
                seed,
                calib,
                duration_s: args.f64_or("duration", 4.0)?,
                ..Default::default()
            };
            println!("== A9: batching sweep (energy & p95 vs batch cap, bursty load) ==");
            let res = batching_scenario::run(&cfg)?;
            print!("{}", batching_scenario::render(&res));
        }
        other => bail!("unknown ablation `{other}` (a1..a9|cache|scheduler|fleet|batching)"),
    }
    Ok(())
}
