//! Hand-rolled CLI (no `clap` in the offline crate universe): a tiny
//! flag parser plus the subcommand implementations behind the `adaoper`
//! binary.

pub mod args;
pub mod commands;

pub use args::Args;
