//! Configuration system: a minimal TOML-subset parser ([`toml`]) plus the
//! typed application schema ([`schema`]). Built in-repo because the offline
//! crate universe has no `serde`/`toml`.

pub mod schema;
pub mod toml;

pub use schema::AppConfig;
pub use toml::{parse, Value};
