//! Typed application configuration, decoded from the TOML-subset [`Value`]
//! tree. Every field has a default so an empty file (or no file) yields a
//! runnable configuration; `adaoper serve --config serve.toml` overrides.

use std::path::Path;

use anyhow::{bail, Result};

use super::toml::{self, Value};

/// Which workload condition preset to start the device in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConditionKind {
    /// Nearly idle device (no foreground contention).
    Idle,
    /// The paper's moderate background workload (~35 % ambient CPU).
    Moderate,
    /// The paper's high background workload (bursty, ~55 % ambient CPU).
    High,
}

impl ConditionKind {
    /// Parse a CLI/TOML spelling.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "idle" => ConditionKind::Idle,
            "moderate" => ConditionKind::Moderate,
            "high" => ConditionKind::High,
            other => bail!("unknown workload condition `{other}` (idle|moderate|high)"),
        })
    }
    /// Canonical spelling.
    pub fn name(&self) -> &'static str {
        match self {
            ConditionKind::Idle => "idle",
            ConditionKind::Moderate => "moderate",
            ConditionKind::High => "high",
        }
    }
}

/// Partitioning policy selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// AdaOper: energy-aware DP (the paper's contribution).
    AdaOper,
    /// CoDL: latency-optimal CPU+GPU co-execution (baseline).
    Codl,
    /// MACE-style all-on-GPU (baseline).
    MaceGpu,
    /// Everything on CPU (baseline).
    AllCpu,
    /// Greedy per-op energy minimizer (ablation baseline).
    GreedyEnergy,
}

impl PolicyKind {
    /// Parse a CLI/TOML spelling.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "adaoper" => PolicyKind::AdaOper,
            "codl" => PolicyKind::Codl,
            "mace-gpu" | "mace_gpu" | "gpu" => PolicyKind::MaceGpu,
            "all-cpu" | "all_cpu" | "cpu" => PolicyKind::AllCpu,
            "greedy" | "greedy-energy" => PolicyKind::GreedyEnergy,
            other => bail!(
                "unknown policy `{other}` (adaoper|codl|mace-gpu|all-cpu|greedy)"
            ),
        })
    }
    /// Canonical spelling.
    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::AdaOper => "adaoper",
            PolicyKind::Codl => "codl",
            PolicyKind::MaceGpu => "mace-gpu",
            PolicyKind::AllCpu => "all-cpu",
            PolicyKind::GreedyEnergy => "greedy-energy",
        }
    }
    /// Every policy, in the order figures/tables print them.
    pub fn all() -> [PolicyKind; 5] {
        [
            PolicyKind::AdaOper,
            PolicyKind::Codl,
            PolicyKind::MaceGpu,
            PolicyKind::AllCpu,
            PolicyKind::GreedyEnergy,
        ]
    }
}

/// Dispatch-order policy for the serving engine's scheduler
/// (see [`crate::coordinator::scheduler`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    /// Arrival-order dispatch (the historical engine behavior).
    Fifo,
    /// Earliest-deadline-first over eligible ops.
    Edf,
    /// EDF ordering plus energy-biased placement when a request has
    /// latency slack relative to its SLO.
    SlackReclaim,
}

impl SchedulerKind {
    /// Parse a CLI/TOML spelling.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "fifo" => SchedulerKind::Fifo,
            "edf" => SchedulerKind::Edf,
            "slack-reclaim" | "slack_reclaim" | "slack" => SchedulerKind::SlackReclaim,
            other => bail!("unknown scheduler `{other}` (fifo|edf|slack-reclaim)"),
        })
    }

    /// Canonical spelling.
    pub fn name(&self) -> &'static str {
        match self {
            SchedulerKind::Fifo => "fifo",
            SchedulerKind::Edf => "edf",
            SchedulerKind::SlackReclaim => "slack-reclaim",
        }
    }

    /// Every scheduler, in the order ablation tables print them.
    pub fn all() -> [SchedulerKind; 3] {
        [
            SchedulerKind::Fifo,
            SchedulerKind::Edf,
            SchedulerKind::SlackReclaim,
        ]
    }
}

/// Admission-control policy selector (see
/// [`crate::coordinator::scheduler::AdmissionPolicy`] for semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionKind {
    /// Admit every generated request.
    AdmitAll,
    /// Shed requests whose deadline is already infeasible under the
    /// predicted backlog.
    DropLate,
    /// Bound admitted-but-unfinished requests per stream
    /// (`serve.queue_limit`).
    Bounded,
}

impl AdmissionKind {
    /// Parse a CLI/TOML spelling.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "admit-all" | "admit_all" | "all" => AdmissionKind::AdmitAll,
            "drop-late" | "drop_late" => AdmissionKind::DropLate,
            "bounded" => AdmissionKind::Bounded,
            other => bail!("unknown admission policy `{other}` (admit-all|drop-late|bounded)"),
        })
    }

    /// Canonical spelling.
    pub fn name(&self) -> &'static str {
        match self {
            AdmissionKind::AdmitAll => "admit-all",
            AdmissionKind::DropLate => "drop-late",
            AdmissionKind::Bounded => "bounded",
        }
    }

    /// Every admission policy.
    pub fn all() -> [AdmissionKind; 3] {
        [
            AdmissionKind::AdmitAll,
            AdmissionKind::DropLate,
            AdmissionKind::Bounded,
        ]
    }
}

/// Batch-formation policy selector (see [`crate::batching`] for the
/// subsystem and `crate::batching::policy` for semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchPolicyKind {
    /// Batching disabled: the engine runs the legacy single-dispatch path
    /// bit for bit (the default).
    None,
    /// Close a forming batch at size K or after the wait cap
    /// (deadline-blind baseline).
    Fixed,
    /// Deadline-aware formation: hold only while every member's SLO slack
    /// exceeds the predicted batched service time.
    Slack,
}

impl BatchPolicyKind {
    /// Parse a CLI/TOML spelling.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "none" | "off" => BatchPolicyKind::None,
            "fixed" => BatchPolicyKind::Fixed,
            "slack" => BatchPolicyKind::Slack,
            other => bail!("unknown batch policy `{other}` (none|fixed|slack)"),
        })
    }

    /// Canonical spelling.
    pub fn name(&self) -> &'static str {
        match self {
            BatchPolicyKind::None => "none",
            BatchPolicyKind::Fixed => "fixed",
            BatchPolicyKind::Slack => "slack",
        }
    }

    /// Every batch policy, in the order ablation tables print them.
    pub fn all() -> [BatchPolicyKind; 3] {
        [
            BatchPolicyKind::None,
            BatchPolicyKind::Fixed,
            BatchPolicyKind::Slack,
        ]
    }
}

/// Serving-engine configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Models served concurrently (zoo names, one stream per entry).
    pub models: Vec<String>,
    /// Mean request rate per stream (Hz) for Poisson arrivals; periodic
    /// streams use it as the frame rate.
    pub rate_hz: f64,
    /// `poisson`, `periodic`, or `mmpp` (two-state bursty) arrivals.
    pub arrival: String,
    /// Uniform jitter on periodic arrivals, as a fraction of the period
    /// (ignored by the other arrival kinds).
    pub arrival_jitter: f64,
    /// Per-request latency SLO in milliseconds.
    pub slo_ms: f64,
    /// Total simulated duration in seconds.
    pub duration_s: f64,
    /// Partition policy.
    pub policy: PolicyKind,
    /// Initial device condition.
    pub condition: ConditionKind,
    /// Dispatch-order policy for the engine's scheduler.
    pub scheduler: SchedulerKind,
    /// Admission-control policy in front of the queue.
    pub admission: AdmissionKind,
    /// Per-stream in-flight request bound (used by `admission = "bounded"`).
    pub queue_limit: usize,
    /// Batch-formation policy between admission and dispatch
    /// (see [`crate::batching`]).
    pub batch_policy: BatchPolicyKind,
    /// Maximum requests per batch.
    pub batch_max: usize,
    /// Batch formation wait cap, milliseconds.
    pub batch_wait_ms: f64,
    /// Random seed for workload + simulator noise.
    pub seed: u64,
    /// Execute real numerics through PJRT artifacts when available.
    pub execute_artifacts: bool,
    /// Per-request JSONL trace output path (empty = no trace). The CLI
    /// `--trace` flag overrides it; see `docs/ARCHITECTURE.md` for the
    /// line format.
    pub trace: String,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            models: vec!["yolov2".to_string()],
            rate_hz: 10.0,
            arrival: "poisson".to_string(),
            arrival_jitter: 0.02,
            slo_ms: 150.0,
            duration_s: 10.0,
            policy: PolicyKind::AdaOper,
            condition: ConditionKind::Moderate,
            scheduler: SchedulerKind::Fifo,
            admission: AdmissionKind::AdmitAll,
            queue_limit: 32,
            batch_policy: BatchPolicyKind::None,
            batch_max: 4,
            batch_wait_ms: 4.0,
            seed: 1,
            execute_artifacts: false,
            trace: String::new(),
        }
    }
}

/// Profiler configuration.
#[derive(Debug, Clone)]
pub struct ProfilerConfig {
    /// GBDT: number of boosting rounds.
    pub gbdt_trees: usize,
    /// GBDT: maximum tree depth.
    pub gbdt_depth: usize,
    /// GBDT: learning rate (shrinkage).
    pub gbdt_eta: f64,
    /// GBDT: per-tree row subsample fraction.
    pub gbdt_subsample: f64,
    /// Calibration sweep size (samples).
    pub calib_samples: usize,
    /// Residual window length fed to the GRU (must match the exported HLO).
    pub gru_window: usize,
    /// Drift threshold (relative) that triggers repartitioning.
    pub drift_threshold: f64,
    /// Use the GRU corrector (false → GBDT only, for ablation A1).
    pub use_gru: bool,
}

impl Default for ProfilerConfig {
    fn default() -> Self {
        ProfilerConfig {
            gbdt_trees: 120,
            gbdt_depth: 5,
            gbdt_eta: 0.1,
            gbdt_subsample: 0.8,
            calib_samples: 6000,
            gru_window: 8,
            drift_threshold: 0.07,
            use_gru: true,
        }
    }
}

/// Partitioner configuration.
#[derive(Debug, Clone)]
pub struct PartitionConfig {
    /// `min-edp` or `min-energy-slo`.
    pub objective: String,
    /// Latency buckets for the SLO-constrained DP lattice.
    pub latency_buckets: usize,
    /// Incremental repartition window (operators).
    pub window: usize,
    /// Partition-plan cache capacity (plans); 0 disables the cache.
    pub plan_cache_capacity: usize,
    /// Plan-cache condition quantization: frequency bucket width, MHz.
    pub plan_cache_freq_bucket_mhz: f64,
    /// Plan-cache condition quantization: utilization bucket width.
    pub plan_cache_util_bucket: f64,
}

impl Default for PartitionConfig {
    fn default() -> Self {
        PartitionConfig {
            objective: "min-edp".to_string(),
            latency_buckets: 64,
            window: 8,
            plan_cache_capacity: 32,
            plan_cache_freq_bucket_mhz: 50.0,
            plan_cache_util_bucket: 0.15,
        }
    }
}

/// Fleet-simulation configuration (`[fleet]`; see [`crate::fleet`]).
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of simulated devices.
    pub devices: usize,
    /// Worker threads the sharded runner uses (never affects results).
    pub threads: usize,
    /// Fleet seed; per-device seeds derive from it via splitmix64.
    pub seed: u64,
    /// Arrival horizon per device, virtual seconds.
    pub duration_s: f64,
    /// Dispatch policy every device's engine runs.
    pub scheduler: SchedulerKind,
    /// Admission-control policy in front of every device's queue.
    pub admission: AdmissionKind,
    /// Per-stream in-flight bound used by `admission = "bounded"` (owned
    /// here, not inherited from `[serve]`).
    pub queue_limit: usize,
    /// Batch-formation policy every device's engine runs.
    pub batch_policy: BatchPolicyKind,
    /// Maximum requests per batch (fleet-wide).
    pub batch_max: usize,
    /// Batch formation wait cap, milliseconds (fleet-wide).
    pub batch_wait_ms: f64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            devices: 50,
            threads: 4,
            seed: 7,
            duration_s: 2.0,
            scheduler: SchedulerKind::Edf,
            admission: AdmissionKind::AdmitAll,
            queue_limit: 32,
            batch_policy: BatchPolicyKind::None,
            batch_max: 4,
            batch_wait_ms: 4.0,
        }
    }
}

/// Health-monitor configuration (`[health]`; see
/// [`crate::metrics::health`]). Off by default: serving output stays
/// byte-identical until the monitor is opted into here or via
/// `serve --health`.
#[derive(Debug, Clone, Default)]
pub struct HealthAppConfig {
    /// Run the streaming health monitor during `adaoper serve`.
    pub enabled: bool,
    /// Rule thresholds handed to the monitor when enabled.
    pub rules: crate::metrics::HealthConfig,
}

/// Top-level application configuration.
#[derive(Debug, Clone, Default)]
pub struct AppConfig {
    /// Serving-engine section (`[serve]`).
    pub serve: ServeConfig,
    /// Profiler section (`[profiler]`).
    pub profiler: ProfilerConfig,
    /// Partitioner section (`[partition]`).
    pub partition: PartitionConfig,
    /// Fleet-simulation section (`[fleet]`).
    pub fleet: FleetConfig,
    /// Health-monitor section (`[health]`).
    pub health: HealthAppConfig,
    /// Directory holding `*.hlo.txt` artifacts.
    pub artifacts_dir: String,
}

impl AppConfig {
    /// Decode from a parsed TOML tree; missing keys fall back to defaults.
    pub fn from_value(v: &Value) -> Result<AppConfig> {
        let mut cfg = AppConfig {
            artifacts_dir: v.str_or("artifacts_dir", "artifacts"),
            ..AppConfig::default()
        };

        if let Some(models) = v.get("serve.models").and_then(|m| m.as_array()) {
            cfg.serve.models = models
                .iter()
                .filter_map(|m| m.as_str().map(str::to_string))
                .collect();
            if cfg.serve.models.is_empty() {
                bail!("serve.models must contain at least one model name");
            }
        }
        cfg.serve.rate_hz = v.float_or("serve.rate_hz", cfg.serve.rate_hz);
        cfg.serve.arrival = v.str_or("serve.arrival", &cfg.serve.arrival);
        cfg.serve.arrival_jitter =
            v.float_or("serve.arrival_jitter", cfg.serve.arrival_jitter);
        if !(0.0..=1.0).contains(&cfg.serve.arrival_jitter) {
            bail!("serve.arrival_jitter must be in [0, 1]");
        }
        cfg.serve.slo_ms = v.float_or("serve.slo_ms", cfg.serve.slo_ms);
        cfg.serve.duration_s = v.float_or("serve.duration_s", cfg.serve.duration_s);
        cfg.serve.policy = PolicyKind::parse(&v.str_or("serve.policy", "adaoper"))?;
        cfg.serve.condition =
            ConditionKind::parse(&v.str_or("serve.condition", "moderate"))?;
        cfg.serve.scheduler = SchedulerKind::parse(&v.str_or("serve.scheduler", "fifo"))?;
        cfg.serve.admission =
            AdmissionKind::parse(&v.str_or("serve.admission", "admit-all"))?;
        let limit = v.int_or("serve.queue_limit", cfg.serve.queue_limit as i64);
        if limit < 1 {
            bail!("serve.queue_limit must be >= 1");
        }
        cfg.serve.queue_limit = limit as usize;
        cfg.serve.batch_policy =
            BatchPolicyKind::parse(&v.str_or("serve.batch_policy", "none"))?;
        let batch_max = v.int_or("serve.batch_max", cfg.serve.batch_max as i64);
        if batch_max < 1 {
            bail!("serve.batch_max must be >= 1");
        }
        cfg.serve.batch_max = batch_max as usize;
        cfg.serve.batch_wait_ms =
            v.float_or("serve.batch_wait_ms", cfg.serve.batch_wait_ms);
        if cfg.serve.batch_wait_ms < 0.0 {
            bail!("serve.batch_wait_ms must be >= 0");
        }
        cfg.serve.seed = v.int_or("serve.seed", cfg.serve.seed as i64) as u64;
        cfg.serve.execute_artifacts =
            v.bool_or("serve.execute_artifacts", cfg.serve.execute_artifacts);
        cfg.serve.trace = v.str_or("serve.trace", &cfg.serve.trace);
        if cfg.serve.rate_hz <= 0.0 {
            bail!("serve.rate_hz must be > 0");
        }
        if cfg.serve.slo_ms <= 0.0 {
            bail!("serve.slo_ms must be > 0");
        }

        cfg.profiler.gbdt_trees =
            v.int_or("profiler.gbdt_trees", cfg.profiler.gbdt_trees as i64) as usize;
        cfg.profiler.gbdt_depth =
            v.int_or("profiler.gbdt_depth", cfg.profiler.gbdt_depth as i64) as usize;
        cfg.profiler.gbdt_eta = v.float_or("profiler.gbdt_eta", cfg.profiler.gbdt_eta);
        cfg.profiler.gbdt_subsample =
            v.float_or("profiler.gbdt_subsample", cfg.profiler.gbdt_subsample);
        cfg.profiler.calib_samples =
            v.int_or("profiler.calib_samples", cfg.profiler.calib_samples as i64) as usize;
        cfg.profiler.gru_window =
            v.int_or("profiler.gru_window", cfg.profiler.gru_window as i64) as usize;
        cfg.profiler.drift_threshold =
            v.float_or("profiler.drift_threshold", cfg.profiler.drift_threshold);
        cfg.profiler.use_gru = v.bool_or("profiler.use_gru", cfg.profiler.use_gru);
        if !(0.0..=1.0).contains(&cfg.profiler.gbdt_subsample) {
            bail!("profiler.gbdt_subsample must be in [0, 1]");
        }

        cfg.partition.objective = v.str_or("partition.objective", &cfg.partition.objective);
        if cfg.partition.objective != "min-edp" && cfg.partition.objective != "min-energy-slo"
        {
            bail!(
                "partition.objective must be `min-edp` or `min-energy-slo`, got `{}`",
                cfg.partition.objective
            );
        }
        cfg.partition.latency_buckets =
            v.int_or("partition.latency_buckets", cfg.partition.latency_buckets as i64)
                as usize;
        cfg.partition.window =
            v.int_or("partition.window", cfg.partition.window as i64) as usize;
        let cap = v.int_or(
            "partition.plan_cache_capacity",
            cfg.partition.plan_cache_capacity as i64,
        );
        if cap < 0 {
            bail!("partition.plan_cache_capacity must be >= 0 (0 disables the cache)");
        }
        cfg.partition.plan_cache_capacity = cap as usize;
        cfg.partition.plan_cache_freq_bucket_mhz = v.float_or(
            "partition.plan_cache_freq_bucket_mhz",
            cfg.partition.plan_cache_freq_bucket_mhz,
        );
        cfg.partition.plan_cache_util_bucket = v.float_or(
            "partition.plan_cache_util_bucket",
            cfg.partition.plan_cache_util_bucket,
        );
        if cfg.partition.plan_cache_freq_bucket_mhz <= 0.0 {
            bail!("partition.plan_cache_freq_bucket_mhz must be > 0");
        }
        if cfg.partition.plan_cache_util_bucket <= 0.0 {
            bail!("partition.plan_cache_util_bucket must be > 0");
        }

        let devices = v.int_or("fleet.devices", cfg.fleet.devices as i64);
        if devices < 1 {
            bail!("fleet.devices must be >= 1");
        }
        cfg.fleet.devices = devices as usize;
        let threads = v.int_or("fleet.threads", cfg.fleet.threads as i64);
        if !(1..=256).contains(&threads) {
            bail!("fleet.threads must be in 1..=256");
        }
        cfg.fleet.threads = threads as usize;
        cfg.fleet.seed = v.int_or("fleet.seed", cfg.fleet.seed as i64) as u64;
        cfg.fleet.duration_s = v.float_or("fleet.duration_s", cfg.fleet.duration_s);
        if cfg.fleet.duration_s <= 0.0 {
            bail!("fleet.duration_s must be > 0");
        }
        cfg.fleet.scheduler =
            SchedulerKind::parse(&v.str_or("fleet.scheduler", cfg.fleet.scheduler.name()))?;
        cfg.fleet.admission =
            AdmissionKind::parse(&v.str_or("fleet.admission", cfg.fleet.admission.name()))?;
        let fleet_limit = v.int_or("fleet.queue_limit", cfg.fleet.queue_limit as i64);
        if fleet_limit < 1 {
            bail!("fleet.queue_limit must be >= 1");
        }
        cfg.fleet.queue_limit = fleet_limit as usize;
        cfg.fleet.batch_policy =
            BatchPolicyKind::parse(&v.str_or("fleet.batch_policy", "none"))?;
        let fleet_batch_max = v.int_or("fleet.batch_max", cfg.fleet.batch_max as i64);
        if fleet_batch_max < 1 {
            bail!("fleet.batch_max must be >= 1");
        }
        cfg.fleet.batch_max = fleet_batch_max as usize;
        cfg.fleet.batch_wait_ms =
            v.float_or("fleet.batch_wait_ms", cfg.fleet.batch_wait_ms);
        if cfg.fleet.batch_wait_ms < 0.0 {
            bail!("fleet.batch_wait_ms must be >= 0");
        }

        cfg.health.enabled = v.bool_or("health.enabled", cfg.health.enabled);
        let h = &mut cfg.health.rules;
        h.fast_window_s = v.float_or("health.fast_window_s", h.fast_window_s);
        h.slow_window_s = v.float_or("health.slow_window_s", h.slow_window_s);
        h.slo_target = v.float_or("health.slo_target", h.slo_target);
        h.burn_warn = v.float_or("health.burn_warn", h.burn_warn);
        h.burn_critical = v.float_or("health.burn_critical", h.burn_critical);
        h.energy_budget_mj = v.float_or("health.energy_budget_mj", h.energy_budget_mj);
        h.drift_warn = v.float_or("health.drift_warn", h.drift_warn);
        h.drift_critical = v.float_or("health.drift_critical", h.drift_critical);
        let qw = v.int_or("health.queue_warn", h.queue_warn as i64);
        let qc = v.int_or("health.queue_critical", h.queue_critical as i64);
        if qw < 1 || qc <= qw {
            bail!("health.queue_warn must be >= 1 and health.queue_critical > queue_warn");
        }
        h.queue_warn = qw as usize;
        h.queue_critical = qc as usize;
        h.clear_ratio = v.float_or("health.clear_ratio", h.clear_ratio);
        let min_samples = v.int_or("health.min_samples", h.min_samples as i64);
        if min_samples < 1 {
            bail!("health.min_samples must be >= 1");
        }
        h.min_samples = min_samples as u64;
        if !(h.fast_window_s > 0.0 && h.fast_window_s < h.slow_window_s) {
            bail!("health.fast_window_s must be > 0 and < health.slow_window_s");
        }
        if !(h.slo_target > 0.0 && h.slo_target <= 1.0) {
            bail!("health.slo_target must be in (0, 1]");
        }
        if !(h.burn_warn > 0.0 && h.burn_critical > h.burn_warn) {
            bail!("health.burn_warn must be > 0 and health.burn_critical > burn_warn");
        }
        if h.energy_budget_mj < 0.0 {
            bail!("health.energy_budget_mj must be >= 0 (0 disables the energy rule)");
        }
        if !(h.drift_warn > 0.0 && h.drift_critical > h.drift_warn) {
            bail!("health.drift_warn must be > 0 and health.drift_critical > drift_warn");
        }
        if !(h.clear_ratio > 0.0 && h.clear_ratio < 1.0) {
            bail!("health.clear_ratio must be strictly within (0, 1)");
        }

        Ok(cfg)
    }

    /// Parse a config file; a missing path yields defaults.
    pub fn load(path: Option<&Path>) -> Result<AppConfig> {
        match path {
            None => Ok(AppConfig::default()),
            Some(p) => {
                let v = toml::parse_file(p)?;
                AppConfig::from_value(&v)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_from_empty() {
        let v = toml::parse("").unwrap();
        let cfg = AppConfig::from_value(&v).unwrap();
        assert_eq!(cfg.serve.models, vec!["yolov2".to_string()]);
        assert_eq!(cfg.serve.policy, PolicyKind::AdaOper);
        assert_eq!(cfg.serve.scheduler, SchedulerKind::Fifo);
        assert_eq!(cfg.serve.admission, AdmissionKind::AdmitAll);
        assert_eq!(cfg.serve.queue_limit, 32);
        assert_eq!(cfg.serve.batch_policy, BatchPolicyKind::None);
        assert_eq!(cfg.serve.batch_max, 4);
        assert_eq!(cfg.serve.batch_wait_ms, 4.0);
        assert_eq!(cfg.serve.arrival_jitter, 0.02);
        assert_eq!(cfg.serve.trace, "");
        assert_eq!(cfg.profiler.gbdt_trees, 120);
        assert_eq!(cfg.fleet.devices, 50);
        assert_eq!(cfg.fleet.threads, 4);
        assert_eq!(cfg.fleet.scheduler, SchedulerKind::Edf);
    }

    #[test]
    fn full_decode() {
        let v = toml::parse(
            r#"
            artifacts_dir = "my_artifacts"
            [serve]
            models = ["yolov2", "mobilenetv1"]
            rate_hz = 30.0
            arrival = "periodic"
            slo_ms = 80.0
            duration_s = 5.0
            policy = "codl"
            condition = "high"
            scheduler = "edf"
            admission = "bounded"
            queue_limit = 4
            seed = 99
            execute_artifacts = true
            trace = "out/trace.jsonl"
            [profiler]
            gbdt_trees = 10
            use_gru = false
            [partition]
            objective = "min-energy-slo"
            window = 4
            plan_cache_capacity = 8
            plan_cache_freq_bucket_mhz = 25.0
            plan_cache_util_bucket = 0.2
            "#,
        )
        .unwrap();
        let cfg = AppConfig::from_value(&v).unwrap();
        assert_eq!(cfg.artifacts_dir, "my_artifacts");
        assert_eq!(cfg.serve.models.len(), 2);
        assert_eq!(cfg.serve.policy, PolicyKind::Codl);
        assert_eq!(cfg.serve.condition, ConditionKind::High);
        assert_eq!(cfg.serve.scheduler, SchedulerKind::Edf);
        assert_eq!(cfg.serve.admission, AdmissionKind::Bounded);
        assert_eq!(cfg.serve.queue_limit, 4);
        assert!(cfg.serve.execute_artifacts);
        assert_eq!(cfg.serve.trace, "out/trace.jsonl");
        assert_eq!(cfg.profiler.gbdt_trees, 10);
        assert!(!cfg.profiler.use_gru);
        assert_eq!(cfg.partition.objective, "min-energy-slo");
        assert_eq!(cfg.partition.window, 4);
        assert_eq!(cfg.partition.plan_cache_capacity, 8);
        assert_eq!(cfg.partition.plan_cache_freq_bucket_mhz, 25.0);
        assert_eq!(cfg.partition.plan_cache_util_bucket, 0.2);
    }

    #[test]
    fn plan_cache_defaults_and_validation() {
        let cfg = AppConfig::from_value(&toml::parse("").unwrap()).unwrap();
        assert_eq!(cfg.partition.plan_cache_capacity, 32);
        assert_eq!(cfg.partition.plan_cache_freq_bucket_mhz, 50.0);
        assert_eq!(cfg.partition.plan_cache_util_bucket, 0.15);
        let bad = toml::parse("[partition]\nplan_cache_util_bucket = 0.0\n").unwrap();
        assert!(AppConfig::from_value(&bad).is_err());
        let bad = toml::parse("[partition]\nplan_cache_freq_bucket_mhz = -1.0\n").unwrap();
        assert!(AppConfig::from_value(&bad).is_err());
        let bad = toml::parse("[partition]\nplan_cache_capacity = -1\n").unwrap();
        assert!(AppConfig::from_value(&bad).is_err());
        // capacity 0 is a legal "disabled" setting
        let off = toml::parse("[partition]\nplan_cache_capacity = 0\n").unwrap();
        assert_eq!(
            AppConfig::from_value(&off).unwrap().partition.plan_cache_capacity,
            0
        );
    }

    #[test]
    fn fleet_section_decodes_and_validates() {
        let v = toml::parse(
            "[fleet]\ndevices = 200\nthreads = 8\nseed = 42\nduration_s = 1.5\nscheduler = \"fifo\"\nadmission = \"drop-late\"\n",
        )
        .unwrap();
        let cfg = AppConfig::from_value(&v).unwrap();
        assert_eq!(cfg.fleet.devices, 200);
        assert_eq!(cfg.fleet.threads, 8);
        assert_eq!(cfg.fleet.seed, 42);
        assert_eq!(cfg.fleet.duration_s, 1.5);
        assert_eq!(cfg.fleet.scheduler, SchedulerKind::Fifo);
        assert_eq!(cfg.fleet.admission, AdmissionKind::DropLate);
        assert_eq!(cfg.fleet.queue_limit, 32); // owned default, not [serve]'s
        for bad in [
            "[fleet]\ndevices = 0\n",
            "[fleet]\nthreads = 0\n",
            "[fleet]\nthreads = 9999\n",
            "[fleet]\nduration_s = 0.0\n",
            "[fleet]\nscheduler = \"lifo\"\n",
            "[fleet]\nadmission = \"maybe\"\n",
            "[fleet]\nqueue_limit = 0\n",
        ] {
            let v = toml::parse(bad).unwrap();
            assert!(AppConfig::from_value(&v).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn health_section_decodes_and_validates() {
        // off by default, with the monitor's documented thresholds
        let cfg = AppConfig::from_value(&toml::parse("").unwrap()).unwrap();
        assert!(!cfg.health.enabled);
        assert_eq!(cfg.health.rules, crate::metrics::HealthConfig::default());

        let v = toml::parse(
            "[health]\nenabled = true\nslo_target = 0.05\nburn_warn = 2.0\n\
             burn_critical = 6.0\nenergy_budget_mj = 40.0\nmin_samples = 3\n",
        )
        .unwrap();
        let cfg = AppConfig::from_value(&v).unwrap();
        assert!(cfg.health.enabled);
        assert_eq!(cfg.health.rules.slo_target, 0.05);
        assert_eq!(cfg.health.rules.burn_critical, 6.0);
        assert_eq!(cfg.health.rules.energy_budget_mj, 40.0);
        assert_eq!(cfg.health.rules.min_samples, 3);
        // untouched knobs keep their defaults
        assert_eq!(
            cfg.health.rules.drift_warn,
            crate::metrics::HealthConfig::default().drift_warn
        );

        for bad in [
            "[health]\nfast_window_s = 0.0\n",
            "[health]\nfast_window_s = 9.0\n", // >= slow_window_s
            "[health]\nslo_target = 0.0\n",
            "[health]\nslo_target = 1.5\n",
            "[health]\nburn_critical = 0.5\n", // <= burn_warn
            "[health]\nenergy_budget_mj = -1.0\n",
            "[health]\ndrift_critical = 0.01\n", // <= drift_warn
            "[health]\nqueue_warn = 0\n",
            "[health]\nqueue_critical = 2\n", // <= queue_warn
            "[health]\nclear_ratio = 1.0\n",
            "[health]\nmin_samples = 0\n",
        ] {
            let v = toml::parse(bad).unwrap();
            assert!(AppConfig::from_value(&v).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn invalid_policy_rejected() {
        let v = toml::parse("[serve]\npolicy = \"fastest\"\n").unwrap();
        assert!(AppConfig::from_value(&v).is_err());
    }

    #[test]
    fn invalid_objective_rejected() {
        let v = toml::parse("[partition]\nobjective = \"min-flops\"\n").unwrap();
        assert!(AppConfig::from_value(&v).is_err());
    }

    #[test]
    fn invalid_rate_rejected() {
        let v = toml::parse("[serve]\nrate_hz = 0.0\n").unwrap();
        assert!(AppConfig::from_value(&v).is_err());
    }

    #[test]
    fn policy_roundtrip_names() {
        for p in PolicyKind::all() {
            assert_eq!(PolicyKind::parse(p.name()).unwrap(), p);
        }
    }

    #[test]
    fn scheduler_and_admission_roundtrip_names() {
        for s in SchedulerKind::all() {
            assert_eq!(SchedulerKind::parse(s.name()).unwrap(), s);
        }
        for a in AdmissionKind::all() {
            assert_eq!(AdmissionKind::parse(a.name()).unwrap(), a);
        }
        assert!(SchedulerKind::parse("lifo").is_err());
        assert!(AdmissionKind::parse("shed-everything").is_err());
    }

    #[test]
    fn invalid_scheduler_knobs_rejected() {
        let v = toml::parse("[serve]\nscheduler = \"sjf\"\n").unwrap();
        assert!(AppConfig::from_value(&v).is_err());
        let v = toml::parse("[serve]\nadmission = \"maybe\"\n").unwrap();
        assert!(AppConfig::from_value(&v).is_err());
        let v = toml::parse("[serve]\nqueue_limit = 0\n").unwrap();
        assert!(AppConfig::from_value(&v).is_err());
    }

    #[test]
    fn batching_knobs_decode_and_validate() {
        let v = toml::parse(
            "[serve]\nbatch_policy = \"slack\"\nbatch_max = 8\nbatch_wait_ms = 2.5\n\
             arrival_jitter = 0.1\n[fleet]\nbatch_policy = \"fixed\"\nbatch_max = 2\n",
        )
        .unwrap();
        let cfg = AppConfig::from_value(&v).unwrap();
        assert_eq!(cfg.serve.batch_policy, BatchPolicyKind::Slack);
        assert_eq!(cfg.serve.batch_max, 8);
        assert_eq!(cfg.serve.batch_wait_ms, 2.5);
        assert_eq!(cfg.serve.arrival_jitter, 0.1);
        assert_eq!(cfg.fleet.batch_policy, BatchPolicyKind::Fixed);
        assert_eq!(cfg.fleet.batch_max, 2);
        assert_eq!(cfg.fleet.batch_wait_ms, 4.0);
        for bad in [
            "[serve]\nbatch_policy = \"auto\"\n",
            "[serve]\nbatch_max = 0\n",
            "[serve]\nbatch_wait_ms = -1.0\n",
            "[serve]\narrival_jitter = 1.5\n",
            "[fleet]\nbatch_max = 0\n",
            "[fleet]\nbatch_wait_ms = -0.5\n",
        ] {
            let v = toml::parse(bad).unwrap();
            assert!(AppConfig::from_value(&v).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn batch_policy_roundtrip_names() {
        for p in BatchPolicyKind::all() {
            assert_eq!(BatchPolicyKind::parse(p.name()).unwrap(), p);
        }
        assert!(BatchPolicyKind::parse("adaptive").is_err());
    }
}
