//! Minimal TOML-subset parser.
//!
//! Supported: `[table]` / `[table.sub]` headers, `key = value` pairs,
//! basic strings (`"..."` with `\n \t \\ \"` escapes), integers, floats,
//! booleans, homogeneous-or-not arrays (`[1, 2, 3]`, may span one line),
//! `#` comments, bare and quoted keys, dotted keys (`a.b = 1`).
//! Not supported (rejected with an error): multi-line strings, datetimes,
//! inline tables, array-of-tables (`[[x]]`).
//!
//! This is a substrate module (no `serde`/`toml` offline); see DESIGN.md.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// A quoted string.
    String(String),
    /// An integer literal.
    Integer(i64),
    /// A float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// An inline array `[a, b, c]`.
    Array(Vec<Value>),
    /// A table (section or inline table).
    Table(BTreeMap<String, Value>),
}

impl Value {
    /// The string payload, if this is a [`Value::String`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }
    /// The integer payload, if this is a [`Value::Integer`].
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Integer(i) => Some(*i),
            _ => None,
        }
    }
    /// Floats accept integer literals too (`x = 3` readable as 3.0).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Integer(i) => Some(*i as f64),
            _ => None,
        }
    }
    /// The boolean payload, if this is a [`Value::Bool`].
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The element slice, if this is a [`Value::Array`].
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
    /// The key/value map, if this is a [`Value::Table`].
    pub fn as_table(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Table(t) => Some(t),
            _ => None,
        }
    }

    /// Dotted-path lookup: `get("device.cpu.freq_mhz")`.
    pub fn get(&self, path: &str) -> Option<&Value> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.as_table()?.get(part)?;
        }
        Some(cur)
    }

    /// Typed lookups with defaults — the common pattern in schema.rs.
    pub fn float_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(|v| v.as_float()).unwrap_or(default)
    }
    /// Integer at a dotted path, or `default` when absent/mistyped.
    pub fn int_or(&self, path: &str, default: i64) -> i64 {
        self.get(path).and_then(|v| v.as_int()).unwrap_or(default)
    }
    /// Boolean at a dotted path, or `default` when absent/mistyped.
    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(|v| v.as_bool()).unwrap_or(default)
    }
    /// String at a dotted path, or `default` when absent/mistyped.
    pub fn str_or(&self, path: &str, default: &str) -> String {
        self.get(path)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::String(s) => write!(f, "{s:?}"),
            Value::Integer(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Array(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Table(t) => {
                write!(f, "{{")?;
                for (i, (k, v)) in t.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{k} = {v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Parse TOML-subset text into a root table.
pub fn parse(input: &str) -> Result<Value> {
    let mut root = BTreeMap::new();
    let mut current_path: Vec<String> = Vec::new();

    let mut lines = input.lines().enumerate().peekable();
    while let Some((lineno, raw)) = lines.next() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        let err_ctx = |m: &str| format!("line {}: {m}: `{raw}`", lineno + 1);

        if line.starts_with("[[") {
            bail!(err_ctx("array-of-tables `[[..]]` is not supported"));
        }
        if let Some(rest) = line.strip_prefix('[') {
            let inner = rest
                .strip_suffix(']')
                .ok_or_else(|| anyhow!(err_ctx("unterminated table header")))?;
            current_path = split_key_path(inner).context(err_ctx("bad table name"))?;
            // Ensure the table exists (and is a table).
            ensure_table(&mut root, &current_path).context(err_ctx("table conflict"))?;
            continue;
        }

        // key = value (value may continue over lines if an array is open)
        let eq = line
            .find('=')
            .ok_or_else(|| anyhow!(err_ctx("expected `key = value`")))?;
        let key_part = line[..eq].trim();
        let mut val_part = line[eq + 1..].trim().to_string();
        // Join continuation lines while an array literal is unbalanced.
        while unbalanced_array(&val_part) {
            let (_, next) = lines
                .next()
                .ok_or_else(|| anyhow!(err_ctx("unterminated array")))?;
            val_part.push(' ');
            val_part.push_str(strip_comment(next).trim());
        }

        let keys = split_key_path(key_part).context(err_ctx("bad key"))?;
        let value = parse_value(val_part.trim()).context(err_ctx("bad value"))?;

        let mut full = current_path.clone();
        full.extend_from_slice(&keys[..keys.len() - 1]);
        let table = ensure_table(&mut root, &full).context(err_ctx("table conflict"))?;
        let leaf = keys.last().unwrap().clone();
        if table.contains_key(&leaf) {
            bail!(err_ctx("duplicate key"));
        }
        table.insert(leaf, value);
    }
    Ok(Value::Table(root))
}

/// Parse a file from disk.
pub fn parse_file(path: &std::path::Path) -> Result<Value> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading config {}", path.display()))?;
    parse(&text).with_context(|| format!("parsing config {}", path.display()))
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a basic string.
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        match c {
            '\\' if in_str => escaped = !escaped,
            '"' if !escaped => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => escaped = false,
        }
    }
    line
}

fn unbalanced_array(s: &str) -> bool {
    let mut depth = 0i32;
    let mut in_str = false;
    let mut escaped = false;
    for c in s.chars() {
        match c {
            '\\' if in_str => escaped = !escaped,
            '"' if !escaped => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            _ => escaped = false,
        }
    }
    depth > 0
}

fn split_key_path(s: &str) -> Result<Vec<String>> {
    let mut parts = Vec::new();
    for part in s.split('.') {
        let part = part.trim();
        let key = if let Some(inner) = part.strip_prefix('"') {
            inner
                .strip_suffix('"')
                .ok_or_else(|| anyhow!("unterminated quoted key"))?
                .to_string()
        } else {
            if part.is_empty()
                || !part
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
            {
                bail!("invalid bare key `{part}`");
            }
            part.to_string()
        };
        parts.push(key);
    }
    Ok(parts)
}

fn ensure_table<'a>(
    root: &'a mut BTreeMap<String, Value>,
    path: &[String],
) -> Result<&'a mut BTreeMap<String, Value>> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| Value::Table(BTreeMap::new()));
        match entry {
            Value::Table(t) => cur = t,
            _ => bail!("`{part}` is not a table"),
        }
    }
    Ok(cur)
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(rest) = s.strip_prefix('"') {
        return parse_basic_string(rest);
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') {
        return parse_array(s);
    }
    // Number: integer if it parses as i64 and has no float markers.
    let clean = s.replace('_', "");
    let looks_float = clean.contains('.') || clean.contains('e') || clean.contains('E');
    if !looks_float {
        if let Ok(i) = clean.parse::<i64>() {
            return Ok(Value::Integer(i));
        }
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value `{s}`");
}

fn parse_basic_string(rest: &str) -> Result<Value> {
    let mut out = String::new();
    let mut chars = rest.chars();
    while let Some(c) = chars.next() {
        match c {
            '"' => {
                let trailing: String = chars.collect();
                if !trailing.trim().is_empty() {
                    bail!("trailing characters after string: `{trailing}`");
                }
                return Ok(Value::String(out));
            }
            '\\' => match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('r') => out.push('\r'),
                Some('\\') => out.push('\\'),
                Some('"') => out.push('"'),
                other => bail!("bad escape `\\{other:?}`"),
            },
            _ => out.push(c),
        }
    }
    bail!("unterminated string")
}

fn parse_array(s: &str) -> Result<Value> {
    let inner = s
        .strip_prefix('[')
        .and_then(|x| x.strip_suffix(']'))
        .ok_or_else(|| anyhow!("unterminated array `{s}`"))?;
    let mut items = Vec::new();
    for piece in split_top_level(inner) {
        let piece = piece.trim();
        if piece.is_empty() {
            continue; // trailing comma
        }
        items.push(parse_value(piece)?);
    }
    Ok(Value::Array(items))
}

/// Split on commas not inside strings or nested brackets.
fn split_top_level(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut escaped = false;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '\\' if in_str => {
                escaped = !escaped;
                cur.push(c);
                continue;
            }
            '"' if !escaped => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                out.push(std::mem::take(&mut cur));
                escaped = false;
                continue;
            }
            _ => {}
        }
        escaped = false;
        cur.push(c);
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        let v = parse(
            r#"
            name = "adaoper"
            iters = 42
            ratio = 0.75
            neg = -3
            sci = 1.5e3
            on = true
            off = false
            "#,
        )
        .unwrap();
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "adaoper");
        assert_eq!(v.get("iters").unwrap().as_int().unwrap(), 42);
        assert_eq!(v.get("ratio").unwrap().as_float().unwrap(), 0.75);
        assert_eq!(v.get("neg").unwrap().as_int().unwrap(), -3);
        assert_eq!(v.get("sci").unwrap().as_float().unwrap(), 1500.0);
        assert_eq!(v.get("on").unwrap().as_bool().unwrap(), true);
        assert_eq!(v.get("off").unwrap().as_bool().unwrap(), false);
    }

    #[test]
    fn parses_tables_and_nesting() {
        let v = parse(
            r#"
            [device]
            name = "sd855"
            [device.cpu]
            cores = 8
            [workload]
            kind = "poisson"
            "#,
        )
        .unwrap();
        assert_eq!(v.get("device.name").unwrap().as_str().unwrap(), "sd855");
        assert_eq!(v.get("device.cpu.cores").unwrap().as_int().unwrap(), 8);
        assert_eq!(v.get("workload.kind").unwrap().as_str().unwrap(), "poisson");
    }

    #[test]
    fn parses_dotted_keys() {
        let v = parse("a.b.c = 1").unwrap();
        assert_eq!(v.get("a.b.c").unwrap().as_int().unwrap(), 1);
    }

    #[test]
    fn parses_arrays_incl_nested_and_multiline() {
        let v = parse(
            "xs = [1, 2, 3]\nys = [[1, 2], [3, 4]]\nzs = [1.0,\n 2.0,\n 3.0]\n",
        )
        .unwrap();
        assert_eq!(v.get("xs").unwrap().as_array().unwrap().len(), 3);
        let ys = v.get("ys").unwrap().as_array().unwrap();
        assert_eq!(ys[1].as_array().unwrap()[0].as_int().unwrap(), 3);
        assert_eq!(v.get("zs").unwrap().as_array().unwrap().len(), 3);
    }

    #[test]
    fn comments_and_strings_with_hash() {
        let v = parse("a = 1 # comment\nb = \"x # y\" # more\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_int().unwrap(), 1);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "x # y");
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#"s = "line\nnext\t\"q\"""#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "line\nnext\t\"q\"");
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(parse("a = 1\na = 2\n").is_err());
    }

    #[test]
    fn array_of_tables_rejected() {
        assert!(parse("[[x]]\na = 1\n").is_err());
    }

    #[test]
    fn table_scalar_conflict_rejected() {
        assert!(parse("a = 1\n[a]\nb = 2\n").is_err());
    }

    #[test]
    fn typed_defaults() {
        let v = parse("[x]\ny = 2\n").unwrap();
        assert_eq!(v.float_or("x.y", 0.0), 2.0);
        assert_eq!(v.float_or("x.z", 7.5), 7.5);
        assert_eq!(v.str_or("x.name", "dflt"), "dflt");
        assert_eq!(v.bool_or("x.flag", true), true);
        assert_eq!(v.int_or("x.y", 0), 2);
    }

    #[test]
    fn unterminated_array_errors() {
        assert!(parse("xs = [1, 2").is_err());
    }

    #[test]
    fn int_float_coercion() {
        let v = parse("n = 3").unwrap();
        assert_eq!(v.get("n").unwrap().as_float().unwrap(), 3.0);
        assert_eq!(v.get("n").unwrap().as_int().unwrap(), 3);
    }

    #[test]
    fn underscore_numbers() {
        let v = parse("big = 1_000_000").unwrap();
        assert_eq!(v.get("big").unwrap().as_int().unwrap(), 1_000_000);
    }
}
