//! The virtual-time serving engine: a two-resource op-level list scheduler
//! over the simulated SoC, expressed as a thin driver over the
//! discrete-event kernel in [`crate::sim`].
//!
//! Multiple app streams issue requests; each request executes its model's
//! operators in topological order under the stream's current partition
//! plan. Ops from *different* requests interleave freely across the CPU
//! and GPU (that is the "concurrent DNN inference" of the title): an op
//! becomes eligible when its inputs are ready, starts when the processors
//! its placement needs are free, and occupies them for its measured
//! duration. Every measurement feeds the profiler; drift and regime
//! triggers flow through the [`super::repartition`] controller, and
//! decision time is charged to the CPU timeline (the partitioner runs on
//! the phone's CPU in real deployments).
//!
//! [`Engine::run`] composes the five [`crate::sim::stages`] — arrival
//! source, admission, dispatch, execution, monitor — over the event
//! queue, broadcasting every state change to
//! [`crate::sim::SimObserver`]s ([`Engine::run_observed`]). Scenarios,
//! traces, and the fleet layer extend the engine by observing it.

use anyhow::{bail, Result};

use crate::batching::{BatchConfig, Batcher, BatchedCostModel};
use crate::config::schema::{ConditionKind, PolicyKind, SchedulerKind};
use crate::graph::{ModelGraph, OpNode};
use crate::metrics::{
    plan_fingerprint, AuditLog, EnergyAccount, HealthConfig, HealthMonitor, LatencyRecorder,
    LogHistogram, PlanCacheStats, PlanDecision, SchedStats, ServingReport,
};
use crate::partition::baselines::by_policy;
use crate::partition::dp::{DpBackend, DpPartitioner};
use crate::partition::incremental::IncrementalRepartitioner;
use crate::partition::plan::{Objective, Partitioner, Plan, INPUT_CPU_FRAC};
use crate::profiler::calibrate::{calibrate_on, CalibConfig};
use crate::profiler::corrector::{Corrector, EwmaCorrector};
use crate::profiler::monitor::ResourceMonitor;
use crate::profiler::{CostModel, EnergyProfiler};
use crate::sim::arena::RequestArena;
use crate::sim::event::Event;
use crate::sim::observer::{emit, emit_alert, emit_done, SimObserver};
use crate::sim::queue::EventQueue;
use crate::sim::stages::{
    cost_model, AdmissionStage, ArrivalSource, DispatchStage, ExecStage, MonitorStage, PlanTable,
};
use crate::sim::timers::{Stage, StageTimers};
use crate::soc::device::{ConditionSpec, Device, DeviceConfig, ExecCtx};
use crate::soc::{Placement, Proc};
use crate::workload::WorkloadCondition;

use super::plan_cache::{PlanCache, PlanCacheConfig};
use super::repartition::{RepartitionController, Trigger, VIRTUAL_CACHE_HIT_S};
use super::request::{Request, RequestOutcome, StreamSpec};
use super::scheduler::AdmissionPolicy;

/// How the planner sees costs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlannerInfo {
    /// The runtime energy profiler (the AdaOper system).
    Profiler,
    /// Ground-truth oracle (upper bound; ablation only).
    Oracle,
}

/// Engine configuration.
#[derive(Clone)]
pub struct EngineConfig {
    /// Partitioning policy (AdaOper or a baseline).
    pub policy: PolicyKind,
    /// Planning objective for the partitioner.
    pub objective: Objective,
    /// Initial device workload condition.
    pub condition: ConditionKind,
    /// Arrival horizon for [`Engine::run`], virtual seconds.
    pub duration_s: f64,
    /// Seed for the workload and simulator noise.
    pub seed: u64,
    /// Incremental repartition window (ops).
    pub window: usize,
    /// Cooldown (ops) between drift repartitions.
    pub cooldown_ops: usize,
    /// Monitor sampling period (virtual seconds).
    pub monitor_period_s: f64,
    /// Whether planning sees profiler predictions or the oracle.
    pub planner_info: PlannerInfo,
    /// Use the GRU-style corrector (EWMA fallback when no artifact is
    /// wired); `false` = offline GBDT only (ablation A1).
    pub use_corrector: bool,
    /// Calibration sweep for the profiler (shared across runs via
    /// [`Engine::with_profiler`] to avoid refitting).
    pub calib: CalibConfig,
    /// Partition-plan cache sizing/quantization (capacity 0 disables).
    pub plan_cache: PlanCacheConfig,
    /// Dispatch-order policy (see [`super::scheduler`]).
    pub scheduler: SchedulerKind,
    /// Admission control in front of the queue.
    pub admission: AdmissionPolicy,
    /// Device parameterization the simulator runs (the fleet layer's
    /// device-class zoo overrides this; `cfg.seed` still controls noise).
    pub device_cfg: DeviceConfig,
    /// Explicit initial condition specification; when set it replaces the
    /// `condition` preset at construction (fleet runs pass class-scaled
    /// specs so a budget device never pins a flagship clock).
    pub condition_spec: Option<ConditionSpec>,
    /// Label identifying the simulated device in reports (fleet runs);
    /// `None` keeps single-device report output unchanged.
    pub device_label: Option<String>,
    /// Dynamic-batching subsystem configuration (see [`crate::batching`]).
    /// The default (`none`) runs the legacy single-dispatch path bit for
    /// bit.
    pub batching: BatchConfig,
    /// Mid-run condition switches: `(at_s, condition)` boundaries, sorted
    /// by time. When the virtual clock crosses a boundary the device
    /// adopts that condition preset (a thermal event, a background-load
    /// step). Empty (the default) leaves the legacy single-condition run
    /// byte-identical. The scenario layer lowers `[timeline.*]` tables
    /// into this field.
    pub condition_timeline: Vec<(f64, ConditionKind)>,
    /// Enable the telemetry spine: the plan-decision audit log (and the
    /// `telemetry` marker in trace headers). Off by default — disabled,
    /// no audit state exists and every report row and golden trace stays
    /// byte-identical. Telemetry never reads or advances virtual time.
    pub telemetry: bool,
    /// DP solver core for AdaOper planning (initial solves, regime
    /// re-plans, and drift window repairs). The two backends return
    /// bit-identical plans — this knob exists for A/B solve-time
    /// measurement; leave it at the default (lattice) otherwise.
    pub dp_backend: DpBackend,
    /// Streaming health monitor configuration (`--health`, `[health]`).
    /// `None` (the default) means no health state exists and every report
    /// row, trace, and golden stays byte-identical. Like telemetry, the
    /// monitor is strictly write-only observation: it never reads or
    /// advances virtual time and never perturbs planning.
    pub health: Option<HealthConfig>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: PolicyKind::AdaOper,
            objective: Objective::MinEdp,
            condition: ConditionKind::Moderate,
            duration_s: 10.0,
            seed: 1,
            window: 8,
            cooldown_ops: 12,
            monitor_period_s: 0.05,
            planner_info: PlannerInfo::Profiler,
            use_corrector: true,
            calib: CalibConfig::default(),
            plan_cache: PlanCacheConfig::default(),
            scheduler: SchedulerKind::Fifo,
            admission: AdmissionPolicy::AdmitAll,
            device_cfg: DeviceConfig::snapdragon_855(),
            condition_spec: None,
            device_label: None,
            batching: BatchConfig::default(),
            condition_timeline: Vec::new(),
            telemetry: false,
            dp_backend: DpBackend::default(),
            health: None,
        }
    }
}

/// Numerics hook: called once per executed operator with the request and
/// op; the e2e example wires the PJRT runtime in here.
pub type NumericsHook = Box<dyn FnMut(&Request, &OpNode) -> Result<()>>;

/// The serving engine.
pub struct Engine {
    /// The configuration the engine was built with.
    pub cfg: EngineConfig,
    device: Device,
    profiler: EnergyProfiler,
    policy: Box<dyn Partitioner + Send + Sync>,
    controller: RepartitionController,
    monitor: ResourceMonitor,
    plan_cache: PlanCache,
    numerics: Option<NumericsHook>,
    arena: RequestArena,
    /// Plan-decision audit log of the most recent run (`cfg.telemetry`).
    audit: Option<AuditLog>,
    /// Opt-in wall-clock stage timers ([`Engine::enable_stage_timers`]).
    stage_timers: Option<StageTimers>,
}

impl Engine {
    /// Build an engine, fitting a fresh profiler from `cfg.calib` against
    /// the device the engine will actually simulate (`cfg.device_cfg`).
    pub fn new(cfg: EngineConfig) -> Engine {
        let offline = calibrate_on(&cfg.calib, &cfg.device_cfg);
        let profiler = if cfg.use_corrector {
            EnergyProfiler::with_correctors(offline, || Box::new(EwmaCorrector::default()))
        } else {
            EnergyProfiler::offline_only(offline)
        };
        Engine::with_profiler(cfg, profiler)
    }

    /// Build with an existing profiler (avoids refitting the GBDT when
    /// sweeping configurations) .
    pub fn with_profiler(cfg: EngineConfig, profiler: EnergyProfiler) -> Engine {
        let mut device = Device::new(DeviceConfig {
            seed: cfg.seed ^ 0x5EED,
            ..cfg.device_cfg.clone()
        });
        let cond_spec = cfg.condition_spec.clone().unwrap_or_else(|| {
            WorkloadCondition::by_name(cfg.condition.name()).unwrap().spec
        });
        device.apply_condition(&cond_spec);
        let policy: Box<dyn Partitioner + Send + Sync> =
            if matches!(cfg.policy, PolicyKind::AdaOper) {
                Box::new(DpPartitioner::new(cfg.objective).with_backend(cfg.dp_backend))
            } else {
                by_policy(cfg.policy, cfg.objective)
            };
        let controller = RepartitionController::new(
            IncrementalRepartitioner::new(
                DpPartitioner::new(cfg.objective).with_backend(cfg.dp_backend),
                cfg.window,
            ),
            cfg.cooldown_ops,
        );
        let plan_cache = PlanCache::new(cfg.plan_cache.clone());
        Engine {
            cfg,
            device,
            profiler,
            policy,
            controller,
            monitor: ResourceMonitor::default(),
            plan_cache,
            numerics: None,
            arena: RequestArena::new(),
            audit: None,
            stage_timers: None,
        }
    }

    /// Replace the profiler's correctors (e.g. wiring real GRU artifacts).
    pub fn set_correctors<F: FnMut() -> Box<dyn Corrector>>(&mut self, make: F) {
        let offline = calibrate_on(&self.cfg.calib, &self.cfg.device_cfg);
        self.profiler = EnergyProfiler::with_correctors(offline, make);
    }

    /// Install the per-op numerics hook (real HLO execution).
    pub fn set_numerics_hook(&mut self, hook: NumericsHook) {
        self.numerics = Some(hook);
    }

    /// Install a (possibly warm) request-state arena. Reusing a prior
    /// engine's arena carries its buffer pool across engines — recycled
    /// buffers are fully overwritten on allocation, so results are
    /// byte-identical either way (pinned by `tests/arena_recycle.rs`).
    pub fn set_arena(&mut self, arena: RequestArena) {
        self.arena = arena;
    }

    /// Take the arena out of the engine (e.g. to transplant its warm
    /// buffer pool into the next engine), leaving an empty one behind.
    pub fn take_arena(&mut self) -> RequestArena {
        std::mem::take(&mut self.arena)
    }

    /// Arena lifetime counters: `(buffers handed out, of which recycled)`.
    pub fn arena_stats(&self) -> (usize, usize) {
        self.arena.stats()
    }

    /// Swap the device's workload condition mid-run-boundary (the
    /// responsiveness traces drive this between `run` calls).
    pub fn apply_condition(&mut self, cond: &WorkloadCondition) {
        self.device.apply_condition(&cond.spec);
    }

    /// The simulated device (ground truth; benches read utilization off it).
    pub fn device(&self) -> &Device {
        &self.device
    }

    /// The runtime energy profiler the engine feeds with measurements.
    pub fn profiler(&self) -> &EnergyProfiler {
        &self.profiler
    }

    /// Drift triggers that reached a re-solve (diagnostics).
    pub fn drift_evaluations(&self) -> usize {
        self.controller.evaluations()
    }

    /// The plan-decision audit log of the most recent run; `None` unless
    /// `cfg.telemetry` was enabled.
    pub fn audit(&self) -> Option<&AuditLog> {
        self.audit.as_ref()
    }

    /// Arm the opt-in wall-clock stage timers for subsequent runs. The
    /// timers measure host time only — they never touch virtual time, so
    /// simulated results are unchanged.
    pub fn enable_stage_timers(&mut self) {
        self.stage_timers = Some(StageTimers::new());
    }

    /// Take the accumulated stage timers out of the engine (`None` when
    /// never enabled), disarming them.
    pub fn take_stage_timers(&mut self) -> Option<StageTimers> {
        self.stage_timers.take()
    }

    /// Plan-cache counters, `None` when the cache is disabled (capacity 0).
    pub fn plan_cache_stats(&self) -> Option<PlanCacheStats> {
        if self.plan_cache.enabled() {
            Some(self.plan_cache.stats())
        } else {
            None
        }
    }

    /// The latency profile of `plan` (suffix sums of predicted per-op
    /// latencies) against the live device snapshot.
    fn plan_profile(&self, g: &ModelGraph, plan: &Plan) -> Vec<f64> {
        let snap = self.device.snapshot();
        let model = cost_model(self.cfg.planner_info, &self.profiler, &self.device);
        PlanTable::profile_of(g, plan, model, &snap)
    }

    fn plan_for(&mut self, g: &ModelGraph) -> Result<Plan> {
        let snap = self.device.snapshot();
        let hint = self.cfg.batching.plan_hint();
        if let Some(plan) = self.plan_cache.lookup(&g.name, &snap, self.cfg.objective, hint) {
            return Ok(plan);
        }
        let plan = {
            // with batching enabled, the DP prices a batch of `hint`
            // requests (amortized dispatch/transfer), not one request
            let base = cost_model(self.cfg.planner_info, &self.profiler, &self.device);
            let batched;
            let model: &dyn crate::profiler::CostModel = if hint > 1 {
                batched = BatchedCostModel::new(base, hint);
                &batched
            } else {
                base
            };
            self.policy.partition(g, model, &snap)?
        };
        self.plan_cache
            .insert(&g.name, &snap, self.cfg.objective, hint, plan.clone());
        Ok(plan)
    }

    /// Initial per-stream plans and latency profiles.
    fn build_plan_table(&mut self, streams: &[StreamSpec]) -> Result<PlanTable> {
        let mut plans = Vec::with_capacity(streams.len());
        let mut profiles = Vec::with_capacity(streams.len());
        for s in streams {
            let plan = self.plan_for(&s.model)?;
            profiles.push(self.plan_profile(&s.model, &plan));
            plans.push(plan);
        }
        Ok(PlanTable::new(plans, profiles))
    }

    /// Closed-loop run: `n_requests` back-to-back inferences of one model
    /// (the next request issues when the previous completes) — the
    /// measurement style of the paper's Figure 2 (continuous video
    /// detection), with no queueing by construction. Latency is pure
    /// service time; static energy amortizes over the busy run.
    pub fn run_closed_loop(
        &mut self,
        spec: &StreamSpec,
        n_requests: usize,
    ) -> Result<ServingReport> {
        self.run_closed_loop_observed(spec, n_requests, &mut [])
    }

    /// [`Engine::run_closed_loop`] with observers receiving the kernel
    /// events (op dispatch/complete, monitor ticks, re-plans, completions).
    pub fn run_closed_loop_observed(
        &mut self,
        spec: &StreamSpec,
        n_requests: usize,
        observers: &mut [&mut dyn SimObserver],
    ) -> Result<ServingReport> {
        let g = spec.model.clone();
        let mut plan = self.plan_for(&g)?;
        self.audit = self.cfg.telemetry.then(|| AuditLog::new(spec.id + 1));
        let mut latencies = LatencyRecorder::new();
        let mut energy = EnergyAccount::new();
        let mut cpu_busy_total = 0.0f64;
        let mut gpu_busy_total = 0.0f64;
        let mut last_monitor_s = 0.0f64;
        let t0 = self.device.time_s();

        for r in 0..n_requests {
            let arrival = self.device.time_s();
            let mut out_cpu = vec![INPUT_CPU_FRAC; g.num_ops()];
            let mut prev: Option<Placement> = None;
            let mut req_latency = 0.0;
            let mut req_energy = 0.0;
            for i in 0..g.num_ops() {
                let op = &g.ops[i];
                let placement = plan.placements[i];
                let input_cpu_fracs: Vec<f64> = if op.inputs.is_empty() {
                    vec![INPUT_CPU_FRAC; op.in_shapes.len()]
                } else {
                    op.inputs.iter().map(|&j| out_cpu[j]).collect()
                };
                let (new_run_cpu, new_run_gpu) = match prev {
                    None => (true, true),
                    Some(p) => (!p.uses(Proc::Cpu), !p.uses(Proc::Gpu)),
                };
                let ctx = ExecCtx {
                    input_cpu_fracs,
                    new_run_cpu,
                    new_run_gpu,
                    concurrent: false,
                };
                let snap = self.device.snapshot();
                let op_start = self.device.time_s();
                let measured = self.device.measure(op, placement, &ctx);
                self.profiler.observe(op, placement, &ctx, &snap, &measured);
                energy.add_op(&measured);
                cpu_busy_total += measured.cpu_busy_s;
                gpu_busy_total += measured.gpu_busy_s;
                req_latency += measured.latency_s;
                req_energy += measured.energy_j;
                out_cpu[i] = placement.frac_on(Proc::Cpu);
                prev = Some(placement);
                self.device.advance(
                    measured.latency_s,
                    if placement.uses(Proc::Cpu) { 1.0 } else { 0.0 },
                    if placement.uses(Proc::Gpu) { 1.0 } else { 0.0 },
                );
                self.controller.tick();
                emit(
                    observers,
                    &Event::OpDispatch {
                        request: r,
                        stream: spec.id,
                        op: i,
                        start_s: op_start,
                        placement,
                    },
                );
                emit(
                    observers,
                    &Event::OpComplete {
                        request: r,
                        stream: spec.id,
                        op: i,
                        end_s: op_start + measured.latency_s,
                        latency_s: measured.latency_s,
                        energy_j: measured.energy_j,
                    },
                );

                // monitor + regime detection
                if self.device.time_s() - last_monitor_s >= self.cfg.monitor_period_s {
                    last_monitor_s = self.device.time_s();
                    self.monitor_sample_closed_loop(
                        &g,
                        spec.id,
                        &mut plan,
                        &mut req_latency,
                        observers,
                    );
                }
                // drift-triggered incremental repartition (AdaOper only)
                if matches!(self.cfg.policy, PolicyKind::AdaOper) && self.profiler.drifted() {
                    let snap = self.device.snapshot();
                    let model =
                        cost_model(self.cfg.planner_info, &self.profiler, &self.device);
                    let pre = self
                        .audit
                        .as_ref()
                        .map(|_| (plan_fingerprint(&plan.placements), plan.predicted));
                    if let Some((p, dt)) = self.controller.on_drift(
                        &g,
                        &plan,
                        i + 1,
                        model,
                        &snap,
                        Some(&out_cpu),
                    ) {
                        plan = p;
                        req_latency += dt; // decision runs on the CPU path
                        self.device.advance(dt, 1.0, 0.0);
                        if let (Some((old_fp, pred_before)), Some(audit)) =
                            (pre, self.audit.as_mut())
                        {
                            audit.record(PlanDecision {
                                t_s: self.device.time_s(),
                                stream: spec.id,
                                trigger: Trigger::Drift.name(),
                                old_fingerprint: old_fp,
                                new_fingerprint: plan_fingerprint(&plan.placements),
                                pred_before,
                                pred_after: plan.predicted,
                                cache_hit: false,
                                corrector_version: self.profiler.version(),
                                decision_s: dt,
                                solve_wall_s: self.controller.last_solve_wall_s(),
                                pred_s: [0.0; 2],
                                actual_s: [0.0; 2],
                                ops: [0; 2],
                            });
                        }
                        emit(
                            observers,
                            &Event::RegimeReplan {
                                stream: spec.id,
                                t_s: self.device.time_s(),
                                trigger: Trigger::Drift,
                                decision_s: dt,
                            },
                        );
                    }
                }
            }
            let finish = self.device.time_s();
            let met = finish - arrival <= spec.slo_s;
            latencies.record(req_latency, 0.0, met);
            energy.finish_inference();
            emit_done(
                observers,
                &RequestOutcome {
                    request: Request {
                        id: r,
                        stream: spec.id,
                        arrival_s: arrival,
                        deadline_s: arrival + spec.slo_s,
                    },
                    start_s: arrival,
                    finish_s: finish,
                    energy_j: req_energy,
                },
                met,
            );
        }

        let wall = (self.device.time_s() - t0).max(1e-9);
        Ok(ServingReport {
            policy: self.policy.name().to_string(),
            condition: self.device.condition_name().to_string(),
            device: self.cfg.device_label.clone(),
            models: vec![g.name.clone()],
            duration_s: wall,
            requests: n_requests,
            throughput_hz: n_requests as f64 / wall,
            latency: latencies.summary(),
            latency_hist: Some(LogHistogram::latency_of(latencies.samples())),
            queue: None,
            miss_rate: latencies.miss_rate(),
            total_energy_j: energy.total_j(self.device.static_power_w(), wall),
            j_per_inference: energy.j_per_inference(self.device.static_power_w(), wall),
            inferences_per_j: energy.inferences_per_j(self.device.static_power_w(), wall),
            avg_cpu_util: self.device.avg_cpu_util(cpu_busy_total / wall),
            avg_gpu_util: (gpu_busy_total / wall).min(1.0),
            repartitions: self.controller.repartitions(),
            partition_overhead_s: self.controller.mean_decision_s(),
            plan_cache: self.plan_cache_stats(),
            sched: None,
            batch: None,
            telemetry: self.audit.as_ref().map(|a| a.summary()),
            // closed-loop runs have no monitor-tick event stream to
            // evaluate health rules on; the open-loop path owns health
            health: None,
        })
    }

    /// Closed-loop monitor sample: regime detection plus re-plan, with the
    /// virtual decision time charged to the in-flight request's latency.
    fn monitor_sample_closed_loop(
        &mut self,
        g: &ModelGraph,
        stream: usize,
        plan: &mut Plan,
        req_latency: &mut f64,
        observers: &mut [&mut dyn SimObserver],
    ) {
        self.monitor.sample(self.device.snapshot());
        let regime_changed = self.monitor.regime_changed();
        emit(
            observers,
            &Event::MonitorTick {
                t_s: self.device.time_s(),
                regime_changed,
            },
        );
        if !regime_changed {
            return;
        }
        self.profiler.reset_correction();
        let snap = self.device.snapshot();
        let hint = self.cfg.batching.plan_hint();
        let model = cost_model(self.cfg.planner_info, &self.profiler, &self.device);
        // price the re-plan at the same batch size its cache bucket is
        // keyed under (see plan_for) — caching a single-request-priced
        // plan under a batched bucket would alias the key space
        let batched;
        let planning: &dyn crate::profiler::CostModel = if hint > 1 {
            batched = BatchedCostModel::new(model, hint);
            &batched
        } else {
            model
        };
        let pre = self
            .audit
            .as_ref()
            .map(|_| (plan_fingerprint(&plan.placements), plan.predicted));
        if let Some((p, dt)) = self.controller.on_regime_change(
            g,
            self.policy.as_ref(),
            planning,
            &snap,
            self.cfg.objective,
            hint,
            Some(&mut self.plan_cache),
        ) {
            *plan = p;
            *req_latency += dt;
            self.device.advance(dt, 1.0, 0.0);
            if let (Some((old_fp, pred_before)), Some(audit)) = (pre, self.audit.as_mut()) {
                audit.record(PlanDecision {
                    t_s: self.device.time_s(),
                    stream,
                    trigger: Trigger::RegimeChange.name(),
                    old_fingerprint: old_fp,
                    new_fingerprint: plan_fingerprint(&plan.placements),
                    pred_before,
                    pred_after: plan.predicted,
                    cache_hit: dt == VIRTUAL_CACHE_HIT_S,
                    corrector_version: self.profiler.version(),
                    decision_s: dt,
                    solve_wall_s: self.controller.last_solve_wall_s(),
                    pred_s: [0.0; 2],
                    actual_s: [0.0; 2],
                    ops: [0; 2],
                });
            }
            emit(
                observers,
                &Event::RegimeReplan {
                    stream,
                    t_s: self.device.time_s(),
                    trigger: Trigger::RegimeChange,
                    decision_s: dt,
                },
            );
        }
    }

    /// Run the engine over `streams` for `cfg.duration_s` of virtual time
    /// (requests arriving before the horizon are all completed).
    pub fn run(&mut self, streams: &[StreamSpec]) -> Result<ServingReport> {
        self.run_observed(streams, &mut [])
    }

    /// [`Engine::run`], broadcasting every kernel event to `observers`.
    ///
    /// This is the thin driver over the [`crate::sim`] stages: seed the
    /// event queue with arrivals, then loop — admit, pick, advance,
    /// monitor, execute, drift, complete — with each concern delegated to
    /// its stage. Stream ids must equal their index in `streams`.
    pub fn run_observed(
        &mut self,
        streams: &[StreamSpec],
        observers: &mut [&mut dyn SimObserver],
    ) -> Result<ServingReport> {
        Self::check_streams(streams)?;
        let mut queue = EventQueue::new();
        let arrivals =
            ArrivalSource::seed(&mut queue, streams, self.cfg.duration_s, self.cfg.seed)?;
        self.run_events(streams, queue, arrivals.total(), observers)
    }

    /// Re-run a *recorded* arrival population through the kernel: the
    /// replay path behind `adaoper replay`. Arrivals (admitted and shed
    /// alike — admission re-decides) are pushed into the event queue in
    /// stream-major chronological order, exactly as
    /// [`ArrivalSource::seed`] would have produced them; everything else
    /// (device noise, planning, dispatch) re-derives deterministically
    /// from `cfg.seed`, so a faithful reconstruction reproduces the
    /// original [`ServingReport::row`] byte for byte.
    pub fn run_replay(
        &mut self,
        streams: &[StreamSpec],
        arrivals: &[Request],
        observers: &mut [&mut dyn SimObserver],
    ) -> Result<ServingReport> {
        Self::check_streams(streams)?;
        for a in arrivals {
            if a.stream >= streams.len() {
                bail!(
                    "recorded request {} references stream {} but only {} streams are declared",
                    a.id,
                    a.stream,
                    streams.len()
                );
            }
        }
        let mut sorted = arrivals.to_vec();
        sorted.sort_by(|a, b| (a.stream, a.id).cmp(&(b.stream, b.id)));
        let mut queue = EventQueue::new();
        let source = ArrivalSource::seed_recorded(&mut queue, &sorted)?;
        self.run_events(streams, queue, source.total(), observers)
    }

    fn check_streams(streams: &[StreamSpec]) -> Result<()> {
        if streams.is_empty() {
            bail!("no streams");
        }
        for (i, s) in streams.iter().enumerate() {
            if s.id != i {
                bail!("stream ids must equal their index (stream {} has id {})", i, s.id);
            }
        }
        Ok(())
    }

    /// The shared event loop behind [`Engine::run_observed`] and
    /// [`Engine::run_replay`]: the queue is already seeded with arrivals
    /// (`total` of them); admit, pick, advance, monitor, execute, drift,
    /// complete until the queue and the active set drain.
    fn run_events(
        &mut self,
        streams: &[StreamSpec],
        mut queue: EventQueue,
        total: usize,
        observers: &mut [&mut dyn SimObserver],
    ) -> Result<ServingReport> {
        let mut plans = self.build_plan_table(streams)?;
        // telemetry is strictly write-only observation: the audit log and
        // the wall-clock stage timers never read into the simulation, so
        // the virtual timeline is byte-identical with them on or off
        let mut audit = self.cfg.telemetry.then(|| AuditLog::new(streams.len()));
        // the health monitor is the same contract: windows and rule
        // machines only ever *receive* completions/residuals and are
        // evaluated at ticks — alerts ride the observer channel, so the
        // served timeline is byte-identical with health on or off
        let mut health = self
            .cfg
            .health
            .clone()
            .map(|h| HealthMonitor::new(h, streams.len()));
        let mut timers = self.stage_timers.take();
        let mut admission = AdmissionStage::new(self.cfg.admission);
        let mut dispatch = DispatchStage::new(self.cfg.scheduler);
        let mut exec = ExecStage::new();
        // borrow the engine-lifetime buffer pool for this run (restored
        // before returning so its warm buffers survive across runs)
        let mut arena = std::mem::take(&mut self.arena);
        let mut monitor = MonitorStage::new(self.cfg.monitor_period_s);
        // `None` with batching disabled: the legacy single-dispatch path
        // below then runs statement-for-statement unchanged
        let mut batcher = Batcher::from_config(&self.cfg.batching);
        let batch_hint = self.cfg.batching.plan_hint();
        let timeline = self.cfg.condition_timeline.clone();
        let mut next_boundary = 0usize;

        loop {
            // adopt any condition boundary the virtual clock has crossed
            // (a thermal event or background-load step from the scenario
            // timeline); cached dispatch candidates are priced against the
            // old condition, so invalidate them
            while next_boundary < timeline.len()
                && self.device.time_s() >= timeline[next_boundary].0
            {
                let (_, kind) = timeline[next_boundary];
                self.device
                    .apply_condition(&WorkloadCondition::by_name(kind.name()).unwrap().spec);
                dispatch.invalidate_all();
                next_boundary += 1;
            }
            // admit arrivals until one is active (shed arrivals pop the next)
            while !exec.has_active() {
                let lap = StageTimers::start(&timers);
                let popped = queue.pop();
                StageTimers::stop(&mut timers, Stage::Arrival, lap);
                match popped {
                    Some((_, Event::Arrival { req, .. })) => {
                        let now = self.device.time_s();
                        let lap = StageTimers::start(&timers);
                        self.admit_one(req, streams, &plans, &mut admission, &mut exec,
                            &mut dispatch, now, &mut arena, observers);
                        StageTimers::stop(&mut timers, Stage::Admission, lap);
                    }
                    _ => break,
                }
            }
            if !exec.has_active() {
                break; // all done
            }

            // the dispatch policy picks which request runs its next op
            // (held batch frontiers floor their candidates' start)
            let lap = StageTimers::start(&timers);
            let d = match batcher.as_ref() {
                Some(b) => dispatch.pick_floored(exec.active(), &plans, exec.avail(), b),
                None => dispatch.pick(exec.active(), &plans, exec.avail()),
            };
            StageTimers::stop(&mut timers, Stage::Dispatch, lap);

            // a strictly earlier queued arrival preempts the decision
            if queue.peek_arrival_time().is_some_and(|t| t < d.start_s) {
                let lap = StageTimers::start(&timers);
                let popped = queue.pop();
                StageTimers::stop(&mut timers, Stage::Arrival, lap);
                if let Some((_, Event::Arrival { req, .. })) = popped {
                    let now = self.device.time_s();
                    let lap = StageTimers::start(&timers);
                    self.admit_one(req, streams, &plans, &mut admission, &mut exec,
                        &mut dispatch, now, &mut arena, observers);
                    StageTimers::stop(&mut timers, Stage::Admission, lap);
                }
                continue; // re-evaluate (with the newcomer, or the next arrival)
            }

            // batch formation: collect the picked frontier's co-dispatchable
            // members and ask the policy to close or hold
            let lap = StageTimers::start(&timers);
            let batch = match batcher.as_mut() {
                Some(b) => {
                    let mut formed = b.form(d.active_idx, d.start_s, exec.active());
                    let remaining = plans.profile(formed.stream)[formed.op];
                    let min_deadline = formed
                        .members
                        .iter()
                        .map(|&ai| exec.active()[ai].req.deadline_s)
                        .fold(f64::INFINITY, f64::min);
                    if !b.decide(&mut formed, d.start_s, remaining, min_deadline) {
                        continue; // frontier held open; its start is floored
                    }
                    Some(formed)
                }
                None => None,
            };
            StageTimers::stop(&mut timers, Stage::Queue, lap);

            // advance virtual time, then deliver a due monitor tick
            let start_s = exec.advance_to(&mut self.device, d.start_s);
            let lap = StageTimers::start(&timers);
            // snapshot every stream's plan identity before the tick: a
            // regime change re-plans streams in bulk, and the audit wants
            // the old→new pair per adopted plan
            let pre_tick = audit.as_ref().map(|_| {
                (0..streams.len())
                    .map(|s| {
                        let p = plans.plan(s);
                        (plan_fingerprint(&p.placements), p.predicted)
                    })
                    .collect::<Vec<_>>()
            });
            if let Some(tick) = monitor.maybe_tick(
                &mut self.monitor, &self.device, &mut self.profiler, self.policy.as_ref(),
                &mut self.controller, &mut self.plan_cache, &mut plans, streams,
                self.cfg.planner_info, self.cfg.objective, batch_hint,
            ) {
                emit(observers, &Event::MonitorTick {
                    t_s: self.device.time_s(), regime_changed: tick.regime_changed,
                });
                for (stream, dt, wall) in &tick.replans {
                    exec.charge_cpu_decision(*dt); // decision runs on CPU
                    if let (Some(a), Some(pre)) = (audit.as_mut(), pre_tick.as_ref()) {
                        let (old_fp, pred_before) = pre[*stream];
                        let newp = plans.plan(*stream);
                        a.record(PlanDecision {
                            t_s: self.device.time_s(),
                            stream: *stream,
                            trigger: Trigger::RegimeChange.name(),
                            old_fingerprint: old_fp,
                            new_fingerprint: plan_fingerprint(&newp.placements),
                            pred_before,
                            pred_after: newp.predicted,
                            cache_hit: *dt == VIRTUAL_CACHE_HIT_S,
                            corrector_version: self.profiler.version(),
                            decision_s: *dt,
                            solve_wall_s: *wall,
                            pred_s: [0.0; 2],
                            actual_s: [0.0; 2],
                            ops: [0; 2],
                        });
                    }
                    emit(observers, &Event::RegimeReplan {
                        stream: *stream, t_s: self.device.time_s(),
                        trigger: Trigger::RegimeChange, decision_s: *dt,
                    });
                }
                dispatch.invalidate_all();
                // evaluate health rules on the tick the monitor just took
                if let Some(h) = health.as_mut() {
                    let t_s = self.device.time_s();
                    for alert in h.on_tick(t_s, exec.active().len()) {
                        crate::log_warn!(
                            "health alert t={:.3}s rule={} stream={} {}→{} signal={:.3} threshold={:.3}",
                            alert.t_s,
                            alert.rule,
                            alert.stream.map_or("-".to_string(), |s| s.to_string()),
                            alert.prev.name(),
                            alert.state.name(),
                            alert.signal,
                            alert.threshold,
                        );
                        emit(observers, &Event::Alert { alert });
                        emit_alert(observers, &alert);
                    }
                }
            }
            StageTimers::stop(&mut timers, Stage::Monitor, lap);

            if let Some(formed) = batch {
                // batched dispatch: one measurement for every member
                let lap = StageTimers::start(&timers);
                let recs = exec.execute_batch(
                    &formed.members, start_s, streams, &plans, &mut self.device,
                    &mut self.profiler, dispatch.scheduler(), self.cfg.planner_info,
                    &mut self.numerics,
                )?;
                StageTimers::stop(&mut timers, Stage::Exec, lap);
                for _ in &recs {
                    self.controller.tick();
                }
                for &ai in &formed.members {
                    dispatch.note_op_executed(ai);
                }
                for rec in &recs {
                    if audit.is_some() || health.is_some() {
                        let prof = plans.profile(rec.stream);
                        let pred = prof[rec.op] - prof[rec.op + 1];
                        if let Some(a) = audit.as_mut() {
                            a.observe_op(rec.stream, rec.placement, pred, rec.latency_s);
                        }
                        if let Some(h) = health.as_mut() {
                            h.on_op(rec.stream, rec.end_s, pred, rec.latency_s);
                        }
                    }
                    emit(observers, &Event::OpDispatch {
                        request: rec.request, stream: rec.stream, op: rec.op,
                        start_s: rec.start_s, placement: rec.placement,
                    });
                    emit(observers, &Event::OpComplete {
                        request: rec.request, stream: rec.stream, op: rec.op,
                        end_s: rec.end_s, latency_s: rec.latency_s, energy_j: rec.energy_j,
                    });
                }
                // formation wait is measured at the *decision* time: the
                // clamped execution start can sit far past d.start_s when
                // another stream advanced the device clock, and that gap
                // is resource wait, not batch-hold wait
                let wait_s = (d.start_s - formed.formed_at_s).max(0.0);
                if recs.len() > 1 || wait_s > 0.0 {
                    emit(observers, &Event::BatchClose {
                        stream: formed.stream, op: formed.op, t_s: start_s,
                        size: recs.len(), wait_s,
                    });
                    crate::sim::observer::emit_batch(
                        observers, formed.stream, formed.op, recs.len(), wait_s,
                    );
                }

                // drift fast path (AdaOper only), anchored at the batch lead
                let lap = StageTimers::start(&timers);
                let pre_drift = audit.as_ref().map(|_| {
                    let s = exec.active()[formed.members[0]].model;
                    let p = plans.plan(s);
                    (plan_fingerprint(&p.placements), p.predicted)
                });
                if let Some((stream, dt)) = monitor.maybe_drift(
                    formed.members[0], exec.active(), streams, &self.device,
                    &self.profiler, &mut self.controller, &mut plans, self.cfg.policy,
                    self.cfg.planner_info, batch_hint,
                ) {
                    exec.charge_cpu_decision(dt);
                    dispatch.invalidate_all();
                    if let (Some((old_fp, pred_before)), Some(a)) = (pre_drift, audit.as_mut()) {
                        let newp = plans.plan(stream);
                        a.record(PlanDecision {
                            t_s: self.device.time_s(),
                            stream,
                            trigger: Trigger::Drift.name(),
                            old_fingerprint: old_fp,
                            new_fingerprint: plan_fingerprint(&newp.placements),
                            pred_before,
                            pred_after: newp.predicted,
                            cache_hit: false,
                            corrector_version: self.profiler.version(),
                            decision_s: dt,
                            solve_wall_s: self.controller.last_solve_wall_s(),
                            pred_s: [0.0; 2],
                            actual_s: [0.0; 2],
                            ops: [0; 2],
                        });
                    }
                    emit(observers, &Event::RegimeReplan {
                        stream, t_s: self.device.time_s(),
                        trigger: Trigger::Drift, decision_s: dt,
                    });
                }
                StageTimers::stop(&mut timers, Stage::Monitor, lap);

                // completions in descending index order: swap_remove moves
                // the tail, so lower member indices stay valid
                let lap = StageTimers::start(&timers);
                let mut done = formed.members.clone();
                done.sort_unstable_by(|a, b| b.cmp(a));
                for ai in done {
                    if let Some(outcome) = exec.complete_if_done(ai, &mut arena) {
                        dispatch.note_removed(ai);
                        let met = outcome.met_deadline();
                        if let Some(h) = health.as_mut() {
                            h.on_done(outcome.request.stream, outcome.finish_s, met,
                                outcome.energy_j);
                        }
                        emit_done(observers, &outcome, met);
                    }
                }
                StageTimers::stop(&mut timers, Stage::Queue, lap);
                continue;
            }

            // execute the chosen op and account for it
            let lap = StageTimers::start(&timers);
            let rec = exec.execute(
                d.active_idx, start_s, streams, &plans, &mut self.device,
                &mut self.profiler, dispatch.scheduler(), self.cfg.planner_info,
                &mut self.numerics,
            )?;
            StageTimers::stop(&mut timers, Stage::Exec, lap);
            self.controller.tick();
            dispatch.note_op_executed(d.active_idx);
            if audit.is_some() || health.is_some() {
                let prof = plans.profile(rec.stream);
                let pred = prof[rec.op] - prof[rec.op + 1];
                if let Some(a) = audit.as_mut() {
                    a.observe_op(rec.stream, rec.placement, pred, rec.latency_s);
                }
                if let Some(h) = health.as_mut() {
                    h.on_op(rec.stream, rec.end_s, pred, rec.latency_s);
                }
            }
            emit(observers, &Event::OpDispatch {
                request: rec.request, stream: rec.stream, op: rec.op,
                start_s: rec.start_s, placement: rec.placement,
            });
            emit(observers, &Event::OpComplete {
                request: rec.request, stream: rec.stream, op: rec.op,
                end_s: rec.end_s, latency_s: rec.latency_s, energy_j: rec.energy_j,
            });

            // drift fast path (AdaOper only)
            let lap = StageTimers::start(&timers);
            let pre_drift = audit.as_ref().map(|_| {
                let s = exec.active()[d.active_idx].model;
                let p = plans.plan(s);
                (plan_fingerprint(&p.placements), p.predicted)
            });
            if let Some((stream, dt)) = monitor.maybe_drift(
                d.active_idx, exec.active(), streams, &self.device, &self.profiler,
                &mut self.controller, &mut plans, self.cfg.policy, self.cfg.planner_info,
                batch_hint,
            ) {
                exec.charge_cpu_decision(dt);
                dispatch.invalidate_all();
                if let (Some((old_fp, pred_before)), Some(a)) = (pre_drift, audit.as_mut()) {
                    let newp = plans.plan(stream);
                    a.record(PlanDecision {
                        t_s: self.device.time_s(),
                        stream,
                        trigger: Trigger::Drift.name(),
                        old_fingerprint: old_fp,
                        new_fingerprint: plan_fingerprint(&newp.placements),
                        pred_before,
                        pred_after: newp.predicted,
                        cache_hit: false,
                        corrector_version: self.profiler.version(),
                        decision_s: dt,
                        solve_wall_s: self.controller.last_solve_wall_s(),
                        pred_s: [0.0; 2],
                        actual_s: [0.0; 2],
                        ops: [0; 2],
                    });
                }
                emit(observers, &Event::RegimeReplan {
                    stream, t_s: self.device.time_s(),
                    trigger: Trigger::Drift, decision_s: dt,
                });
            }
            StageTimers::stop(&mut timers, Stage::Monitor, lap);

            // completion
            let lap = StageTimers::start(&timers);
            if let Some(outcome) = exec.complete_if_done(d.active_idx, &mut arena) {
                dispatch.note_removed(d.active_idx);
                let met = outcome.met_deadline();
                if let Some(h) = health.as_mut() {
                    h.on_done(outcome.request.stream, outcome.finish_s, met, outcome.energy_j);
                }
                emit_done(observers, &outcome, met);
            }
            StageTimers::stop(&mut timers, Stage::Queue, lap);
        }
        let batch_stats = batcher.as_ref().map(|b| b.stats());
        self.arena = arena;
        self.stage_timers = timers;
        let mut report = self.assemble_report(
            streams, &exec, &admission, dispatch.name(), total, batch_stats,
        );
        report.telemetry = audit.as_ref().map(|a| a.summary());
        report.health = health.as_ref().map(|h| h.summary());
        self.audit = audit;
        Ok(report)
    }

    /// One admission: run the controller, activate on success, and
    /// broadcast the arrival (with its verdict) to observers.
    #[allow(clippy::too_many_arguments)]
    fn admit_one(
        &self,
        req: Request,
        streams: &[StreamSpec],
        plans: &PlanTable,
        admission: &mut AdmissionStage,
        exec: &mut ExecStage,
        dispatch: &mut DispatchStage,
        now_s: f64,
        arena: &mut RequestArena,
        observers: &mut [&mut dyn SimObserver],
    ) {
        let admitted = match admission.try_admit(
            req,
            streams,
            plans,
            exec.active(),
            exec.avail(),
            now_s,
            arena,
        ) {
            Some(a) => {
                exec.admit(a);
                dispatch.note_admitted();
                true
            }
            None => false,
        };
        emit(observers, &Event::Arrival { req, admitted });
    }

    /// Fold the stages' final state into the serving report.
    fn assemble_report(
        &self,
        streams: &[StreamSpec],
        exec: &ExecStage,
        admission: &AdmissionStage,
        scheduler_name: &str,
        total_requests: usize,
        batch: Option<crate::metrics::BatchStats>,
    ) -> ServingReport {
        let wall = self.device.time_s().max(self.cfg.duration_s);
        let counters = admission.counters();
        let latencies = exec.latencies();
        let energy = exec.energy();
        let sched = SchedStats {
            scheduler: scheduler_name.to_string(),
            admission: admission.policy().name().to_string(),
            offered: counters.offered,
            admitted: counters.admitted,
            shed_late: counters.shed_late,
            dropped_capacity: counters.dropped_capacity,
            deadline_misses: latencies.misses(),
        };
        debug_assert_eq!(counters.offered, total_requests);
        debug_assert_eq!(
            exec.outcomes().len() + counters.shed_late + counters.dropped_capacity,
            total_requests
        );
        ServingReport {
            policy: self.policy.name().to_string(),
            condition: self.device.condition_name().to_string(),
            device: self.cfg.device_label.clone(),
            models: streams.iter().map(|s| s.model.name.clone()).collect(),
            duration_s: wall,
            requests: exec.outcomes().len(),
            throughput_hz: exec.outcomes().len() as f64 / wall,
            latency: latencies.summary(),
            latency_hist: Some(LogHistogram::latency_of(latencies.samples())),
            queue: latencies.queue_summary(),
            miss_rate: latencies.miss_rate(),
            total_energy_j: energy.total_j(self.device.static_power_w(), wall),
            j_per_inference: energy.j_per_inference(self.device.static_power_w(), wall),
            inferences_per_j: energy.inferences_per_j(self.device.static_power_w(), wall),
            avg_cpu_util: self.device.avg_cpu_util(exec.cpu_busy_total() / wall),
            avg_gpu_util: (exec.gpu_busy_total() / wall).min(1.0),
            repartitions: self.controller.repartitions(),
            partition_overhead_s: self.controller.mean_decision_s(),
            plan_cache: self.plan_cache_stats(),
            sched: Some(sched),
            batch,
            telemetry: None,
            health: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::profiler::gbdt::GbdtParams;
    use crate::sim::EventCounters;
    use crate::workload::Arrival;

    fn quick_calib() -> CalibConfig {
        CalibConfig {
            samples: 1200,
            seed: 5,
            gbdt: GbdtParams {
                trees: 40,
                ..Default::default()
            },
        }
    }

    fn stream(rate: f64, slo: f64) -> Vec<StreamSpec> {
        vec![StreamSpec::new(
            0,
            zoo::yolov2_tiny(),
            Arrival::Poisson { hz: rate },
            slo,
        )]
    }

    #[test]
    fn engine_completes_all_requests() {
        let mut e = Engine::new(EngineConfig {
            duration_s: 3.0,
            calib: quick_calib(),
            ..Default::default()
        });
        let r = e.run(&stream(5.0, 0.5)).unwrap();
        assert!(r.requests > 5, "only {} requests", r.requests);
        assert!(r.latency.is_some());
        assert!(r.j_per_inference > 0.0);
        assert!(r.throughput_hz > 0.0);
    }

    #[test]
    fn concurrent_streams_complete() {
        let mut e = Engine::new(EngineConfig {
            duration_s: 2.0,
            policy: PolicyKind::MaceGpu,
            calib: quick_calib(),
            ..Default::default()
        });
        let periodic = Arrival::Periodic {
            hz: 10.0,
            jitter: 0.0,
        };
        let streams = vec![
            StreamSpec::new(0, zoo::yolov2_tiny(), periodic, 0.5),
            StreamSpec::new(1, zoo::mobilenet_v1(), Arrival::Poisson { hz: 8.0 }, 0.5),
        ];
        let r = e.run(&streams).unwrap();
        assert!(r.requests >= 20, "{} requests", r.requests);
        assert_eq!(r.models.len(), 2);
    }

    #[test]
    fn deterministic_given_seed_bit_identical() {
        let mk = || {
            let mut e = Engine::new(EngineConfig {
                duration_s: 1.5,
                seed: 42,
                policy: PolicyKind::MaceGpu,
                calib: quick_calib(),
                ..Default::default()
            });
            e.run(&stream(8.0, 0.5)).unwrap()
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.requests, b.requests);
        // decision time is virtualized, so the whole timeline — and the
        // rendered report row — is reproducible bit for bit
        assert_eq!(a.row(), b.row());
        assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
    }

    #[test]
    fn non_contiguous_stream_ids_rejected() {
        let mut e = Engine::new(EngineConfig {
            duration_s: 1.0,
            policy: PolicyKind::MaceGpu,
            calib: quick_calib(),
            ..Default::default()
        });
        let bad = vec![StreamSpec::new(
            3,
            zoo::yolov2_tiny(),
            Arrival::Poisson { hz: 5.0 },
            0.5,
        )];
        assert!(e.run(&bad).is_err());
    }

    #[test]
    fn observers_see_consistent_event_counts() {
        let mut e = Engine::new(EngineConfig {
            duration_s: 1.5,
            policy: PolicyKind::MaceGpu,
            calib: quick_calib(),
            ..Default::default()
        });
        let mut c = EventCounters::default();
        let r = e.run_observed(&stream(8.0, 0.5), &mut [&mut c]).unwrap();
        let sc = r.sched.clone().unwrap();
        // the observer's tallies and the report's counters are two views
        // of the same kernel events
        assert_eq!(c.offered, sc.offered);
        assert_eq!(c.admitted, sc.admitted);
        assert_eq!(c.shed, sc.shed());
        assert_eq!(c.completed, r.requests);
        assert_eq!(c.deadline_misses, sc.deadline_misses);
        assert_eq!(c.op_dispatches, c.op_completes);
        let g = zoo::yolov2_tiny();
        assert_eq!(c.op_dispatches, r.requests * g.num_ops());
        assert!(c.monitor_ticks > 0, "no monitor ticks in 1.5 s");
    }

    #[test]
    fn high_condition_worse_than_moderate() {
        let run = |cond| {
            let mut e = Engine::new(EngineConfig {
                duration_s: 3.0,
                condition: cond,
                policy: PolicyKind::MaceGpu,
                calib: quick_calib(),
                ..Default::default()
            });
            e.run(&stream(5.0, 1.0)).unwrap()
        };
        let m = run(ConditionKind::Moderate);
        let h = run(ConditionKind::High);
        let lm = m.latency.unwrap().p50;
        let lh = h.latency.unwrap().p50;
        assert!(lh > lm, "high p50 {lh} ≤ moderate {lm}");
    }

    #[test]
    fn adaoper_repartitions_under_drift() {
        let mut e = Engine::new(EngineConfig {
            duration_s: 4.0,
            policy: PolicyKind::AdaOper,
            cooldown_ops: 10,
            calib: quick_calib(),
            condition: ConditionKind::High,
            ..Default::default()
        });
        let _r = e.run(&stream(6.0, 1.0)).unwrap();
        // under the bursty high condition the drift trigger must at least
        // evaluate re-plans in 4 s (adoption is hysteresis-gated)
        assert!(e.drift_evaluations() > 0, "drift never evaluated a re-plan");
    }

    #[test]
    fn plan_cache_cold_miss_then_warm_hit() {
        let mut e = Engine::new(EngineConfig {
            duration_s: 1.0,
            policy: PolicyKind::MaceGpu,
            calib: quick_calib(),
            ..Default::default()
        });
        let spec = StreamSpec::new(0, zoo::yolov2_tiny(), Arrival::Poisson { hz: 5.0 }, 0.5);
        // zero requests → no virtual time passes, so the second planning
        // lookup sees the identical snapshot: guaranteed warm hit
        let r0 = e.run_closed_loop(&spec, 0).unwrap();
        let s0 = r0.plan_cache.unwrap();
        assert_eq!((s0.hits, s0.misses), (0, 1), "{s0:?}");
        let r1 = e.run_closed_loop(&spec, 0).unwrap();
        let s1 = r1.plan_cache.unwrap();
        assert_eq!((s1.hits, s1.misses), (1, 1), "{s1:?}");
        assert_eq!(s1.entries, 1);
    }

    #[test]
    fn plan_cache_capacity_zero_reports_none() {
        use crate::coordinator::plan_cache::PlanCacheConfig;
        let mut e = Engine::new(EngineConfig {
            duration_s: 1.0,
            policy: PolicyKind::MaceGpu,
            calib: quick_calib(),
            plan_cache: PlanCacheConfig {
                capacity: 0,
                ..Default::default()
            },
            ..Default::default()
        });
        let spec = StreamSpec::new(0, zoo::yolov2_tiny(), Arrival::Poisson { hz: 5.0 }, 0.5);
        let r = e.run_closed_loop(&spec, 1).unwrap();
        assert!(r.plan_cache.is_none());
    }

    #[test]
    fn default_config_reports_fifo_admit_all() {
        let mut e = Engine::new(EngineConfig {
            duration_s: 1.5,
            policy: PolicyKind::MaceGpu,
            calib: quick_calib(),
            ..Default::default()
        });
        let r = e.run(&stream(6.0, 0.5)).unwrap();
        let sc = r.sched.unwrap();
        assert_eq!(sc.scheduler, "fifo");
        assert_eq!(sc.admission, "admit-all");
        assert_eq!(sc.offered, sc.admitted);
        assert_eq!(sc.shed(), 0);
        assert_eq!(r.requests, sc.admitted);
    }

    #[test]
    fn drop_late_sheds_at_overload_and_accounts() {
        let mut e = Engine::new(EngineConfig {
            duration_s: 2.0,
            policy: PolicyKind::MaceGpu,
            planner_info: PlannerInfo::Oracle,
            admission: AdmissionPolicy::DropLate,
            calib: quick_calib(),
            ..Default::default()
        });
        // far past saturation with a moderate SLO: shedding must kick in
        let r = e.run(&stream(300.0, 0.3)).unwrap();
        let sc = r.sched.unwrap();
        assert_eq!(sc.admission, "drop-late");
        assert!(sc.shed_late > 0, "{sc:?}");
        assert_eq!(sc.offered, sc.admitted + sc.shed_late);
        assert_eq!(r.requests, sc.admitted);
    }

    #[test]
    fn bounded_admission_caps_in_flight() {
        use crate::config::schema::SchedulerKind;
        let mut e = Engine::new(EngineConfig {
            duration_s: 2.0,
            policy: PolicyKind::MaceGpu,
            scheduler: SchedulerKind::Edf,
            admission: AdmissionPolicy::Bounded { per_stream: 1 },
            calib: quick_calib(),
            ..Default::default()
        });
        let r = e.run(&stream(200.0, 0.5)).unwrap();
        let sc = r.sched.unwrap();
        assert_eq!(sc.scheduler, "edf");
        assert!(sc.dropped_capacity > 0, "{sc:?}");
        assert_eq!(sc.offered, sc.admitted + sc.dropped_capacity);
        assert_eq!(r.requests, sc.admitted);
    }

    #[test]
    fn batched_run_completes_everything_and_reports_stats() {
        use crate::config::schema::BatchPolicyKind;
        let mut e = Engine::new(EngineConfig {
            duration_s: 2.0,
            policy: PolicyKind::MaceGpu,
            scheduler: SchedulerKind::Edf,
            calib: quick_calib(),
            batching: BatchConfig {
                policy: BatchPolicyKind::Fixed,
                max: 4,
                wait_s: 4e-3,
            },
            ..Default::default()
        });
        let mut c = EventCounters::default();
        // past saturation: queues form, so same-stream frontiers co-reside
        let r = e.run_observed(&stream(60.0, 1.5), &mut [&mut c]).unwrap();
        let b = r.batch.clone().expect("batching run must report stats");
        assert_eq!(b.policy, "fixed");
        assert!(b.formed > 0, "{b:?}");
        assert!(b.batched_dispatches > 0, "overload formed no batches: {b:?}");
        assert!(b.max_size >= 2 && b.max_size <= 4, "{b:?}");
        // every admitted request still completes, and the per-member event
        // stream keeps the op-count invariant intact
        let sc = r.sched.clone().unwrap();
        assert_eq!(r.requests, sc.admitted);
        assert_eq!(c.op_dispatches, c.op_completes);
        let g = zoo::yolov2_tiny();
        assert_eq!(c.op_dispatches, r.requests * g.num_ops());
        // observer tallies and report stats are two views of the same
        // batched dispatches (singleton closes are excluded from both)
        assert_eq!(c.batch_closes, b.batched_dispatches, "{c:?} vs {b:?}");
        assert_eq!(c.batched_requests, b.batched_requests);
    }

    #[test]
    fn numerics_hook_called_per_op() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let count = Arc::new(AtomicUsize::new(0));
        let c2 = count.clone();
        let mut e = Engine::new(EngineConfig {
            duration_s: 1.0,
            policy: PolicyKind::MaceGpu,
            calib: quick_calib(),
            ..Default::default()
        });
        e.set_numerics_hook(Box::new(move |_req, _op| {
            c2.fetch_add(1, Ordering::SeqCst);
            Ok(())
        }));
        let r = e.run(&stream(4.0, 1.0)).unwrap();
        let g = zoo::yolov2_tiny();
        assert_eq!(count.load(Ordering::SeqCst), r.requests * g.num_ops());
    }
}
