//! The virtual-time serving engine: a two-resource op-level list scheduler
//! over the simulated SoC.
//!
//! Multiple app streams issue requests; each request executes its model's
//! operators in topological order under the stream's current partition
//! plan. Ops from *different* requests interleave freely across the CPU
//! and GPU (that is the "concurrent DNN inference" of the title): an op
//! becomes eligible when its inputs are ready, starts when the processors
//! its placement needs are free, and occupies them for its measured
//! duration. Every measurement feeds the profiler; drift and regime
//! triggers flow through the [`super::repartition`] controller, and
//! decision time is charged to the CPU timeline (the partitioner runs on
//! the phone's CPU in real deployments).

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::config::schema::{ConditionKind, PolicyKind, SchedulerKind};
use crate::graph::{ModelGraph, OpNode};
use crate::metrics::{
    EnergyAccount, LatencyRecorder, LogHistogram, PlanCacheStats, SchedStats, ServingReport,
};
use crate::partition::baselines::by_policy;
use crate::partition::dp::DpPartitioner;
use crate::partition::incremental::IncrementalRepartitioner;
use crate::partition::plan::{Objective, Partitioner, Plan, INPUT_CPU_FRAC};
use crate::profiler::calibrate::{calibrate_on, CalibConfig};
use crate::profiler::corrector::{Corrector, EwmaCorrector};
use crate::profiler::monitor::ResourceMonitor;
use crate::profiler::{CostModel, EnergyProfiler};
use crate::soc::device::{ConditionSpec, Device, DeviceConfig, ExecCtx};
use crate::soc::{Placement, Proc};
use crate::util::Prng;
use crate::workload::WorkloadCondition;

use super::plan_cache::{PlanCache, PlanCacheConfig};
use super::repartition::RepartitionController;
use super::request::{Request, RequestOutcome, StreamSpec};
use super::scheduler::{self, AdmissionCtrl, AdmissionPolicy, Candidate};

/// How the planner sees costs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlannerInfo {
    /// The runtime energy profiler (the AdaOper system).
    Profiler,
    /// Ground-truth oracle (upper bound; ablation only).
    Oracle,
}

/// Engine configuration.
#[derive(Clone)]
pub struct EngineConfig {
    /// Partitioning policy (AdaOper or a baseline).
    pub policy: PolicyKind,
    /// Planning objective for the partitioner.
    pub objective: Objective,
    /// Initial device workload condition.
    pub condition: ConditionKind,
    /// Arrival horizon for [`Engine::run`], virtual seconds.
    pub duration_s: f64,
    /// Seed for the workload and simulator noise.
    pub seed: u64,
    /// Incremental repartition window (ops).
    pub window: usize,
    /// Cooldown (ops) between drift repartitions.
    pub cooldown_ops: usize,
    /// Monitor sampling period (virtual seconds).
    pub monitor_period_s: f64,
    /// Whether planning sees profiler predictions or the oracle.
    pub planner_info: PlannerInfo,
    /// Use the GRU-style corrector (EWMA fallback when no artifact is
    /// wired); `false` = offline GBDT only (ablation A1).
    pub use_corrector: bool,
    /// Calibration sweep for the profiler (shared across runs via
    /// [`Engine::with_profiler`] to avoid refitting).
    pub calib: CalibConfig,
    /// Partition-plan cache sizing/quantization (capacity 0 disables).
    pub plan_cache: PlanCacheConfig,
    /// Dispatch-order policy (see [`super::scheduler`]).
    pub scheduler: SchedulerKind,
    /// Admission control in front of the queue.
    pub admission: AdmissionPolicy,
    /// Device parameterization the simulator runs (the fleet layer's
    /// device-class zoo overrides this; `cfg.seed` still controls noise).
    pub device_cfg: DeviceConfig,
    /// Explicit initial condition specification; when set it replaces the
    /// `condition` preset at construction (fleet runs pass class-scaled
    /// specs so a budget device never pins a flagship clock).
    pub condition_spec: Option<ConditionSpec>,
    /// Label identifying the simulated device in reports (fleet runs);
    /// `None` keeps single-device report output unchanged.
    pub device_label: Option<String>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: PolicyKind::AdaOper,
            objective: Objective::MinEdp,
            condition: ConditionKind::Moderate,
            duration_s: 10.0,
            seed: 1,
            window: 8,
            cooldown_ops: 12,
            monitor_period_s: 0.05,
            planner_info: PlannerInfo::Profiler,
            use_corrector: true,
            calib: CalibConfig::default(),
            plan_cache: PlanCacheConfig::default(),
            scheduler: SchedulerKind::Fifo,
            admission: AdmissionPolicy::AdmitAll,
            device_cfg: DeviceConfig::snapdragon_855(),
            condition_spec: None,
            device_label: None,
        }
    }
}

/// Numerics hook: called once per executed operator with the request and
/// op; the e2e example wires the PJRT runtime in here.
pub type NumericsHook = Box<dyn FnMut(&Request, &OpNode) -> Result<()>>;

/// Per-request execution state.
struct Active {
    req: Request,
    model: usize, // stream index
    next_op: usize,
    data_ready_s: f64,
    start_s: Option<f64>,
    energy_j: f64,
    /// CPU-resident fraction of each op output produced so far.
    out_cpu: Vec<f64>,
    prev_placement: Option<Placement>,
}

/// Admission decision shared by both admit sites of [`Engine::run`]:
/// computes the controller's inputs (earliest start, predicted backlog of
/// admitted work, the request's predicted service time, same-stream
/// in-flight count) and returns the ready-to-queue state for an admitted
/// request, or `None` when the request is shed.
fn try_admit(
    admission: &mut AdmissionCtrl,
    req: Request,
    streams: &[StreamSpec],
    profiles: &HashMap<usize, Vec<f64>>,
    active: &[Active],
    avail: &[f64; 2],
    now_s: f64,
) -> Option<Active> {
    let est_start = req.arrival_s.max(now_s).max(avail[0]).max(avail[1]);
    let backlog: f64 = active.iter().map(|a| profiles[&a.model][a.next_op]).sum();
    let service = profiles[&req.stream][0];
    let in_stream = active.iter().filter(|a| a.req.stream == req.stream).count();
    if !admission.admit(&req, est_start, backlog, service, in_stream) {
        return None;
    }
    let g = &streams[req.stream].model;
    Some(Active {
        model: req.stream,
        next_op: 0,
        data_ready_s: req.arrival_s,
        start_s: None,
        energy_j: 0.0,
        out_cpu: vec![INPUT_CPU_FRAC; g.num_ops()],
        prev_placement: None,
        req,
    })
}

/// The serving engine.
pub struct Engine {
    /// The configuration the engine was built with.
    pub cfg: EngineConfig,
    device: Device,
    profiler: EnergyProfiler,
    policy: Box<dyn Partitioner + Send + Sync>,
    controller: RepartitionController,
    monitor: ResourceMonitor,
    plan_cache: PlanCache,
    numerics: Option<NumericsHook>,
}

impl Engine {
    /// Build an engine, fitting a fresh profiler from `cfg.calib` against
    /// the device the engine will actually simulate (`cfg.device_cfg`).
    pub fn new(cfg: EngineConfig) -> Engine {
        let offline = calibrate_on(&cfg.calib, &cfg.device_cfg);
        let profiler = if cfg.use_corrector {
            EnergyProfiler::with_correctors(offline, || Box::new(EwmaCorrector::default()))
        } else {
            EnergyProfiler::offline_only(offline)
        };
        Engine::with_profiler(cfg, profiler)
    }

    /// Build with an existing profiler (avoids refitting the GBDT when
    /// sweeping configurations) .
    pub fn with_profiler(cfg: EngineConfig, profiler: EnergyProfiler) -> Engine {
        let mut device = Device::new(DeviceConfig {
            seed: cfg.seed ^ 0x5EED,
            ..cfg.device_cfg.clone()
        });
        let cond_spec = cfg.condition_spec.clone().unwrap_or_else(|| {
            WorkloadCondition::by_name(cfg.condition.name()).unwrap().spec
        });
        device.apply_condition(&cond_spec);
        let policy = by_policy(cfg.policy, cfg.objective);
        let controller = RepartitionController::new(
            IncrementalRepartitioner::new(
                DpPartitioner::new(cfg.objective),
                cfg.window,
            ),
            cfg.cooldown_ops,
        );
        let plan_cache = PlanCache::new(cfg.plan_cache.clone());
        Engine {
            cfg,
            device,
            profiler,
            policy,
            controller,
            monitor: ResourceMonitor::default(),
            plan_cache,
            numerics: None,
        }
    }

    /// Replace the profiler's correctors (e.g. wiring real GRU artifacts).
    pub fn set_correctors<F: FnMut() -> Box<dyn Corrector>>(&mut self, make: F) {
        let offline = calibrate_on(&self.cfg.calib, &self.cfg.device_cfg);
        self.profiler = EnergyProfiler::with_correctors(offline, make);
    }

    /// Install the per-op numerics hook (real HLO execution).
    pub fn set_numerics_hook(&mut self, hook: NumericsHook) {
        self.numerics = Some(hook);
    }

    /// Swap the device's workload condition mid-run-boundary (the
    /// responsiveness traces drive this between `run` calls).
    pub fn apply_condition(&mut self, cond: &WorkloadCondition) {
        self.device.apply_condition(&cond.spec);
    }

    /// The simulated device (ground truth; benches read utilization off it).
    pub fn device(&self) -> &Device {
        &self.device
    }

    /// The runtime energy profiler the engine feeds with measurements.
    pub fn profiler(&self) -> &EnergyProfiler {
        &self.profiler
    }

    /// Drift triggers that reached a re-solve (diagnostics).
    pub fn drift_evaluations(&self) -> usize {
        self.controller.evaluations()
    }

    /// Plan-cache counters, `None` when the cache is disabled (capacity 0).
    pub fn plan_cache_stats(&self) -> Option<PlanCacheStats> {
        if self.plan_cache.enabled() {
            Some(self.plan_cache.stats())
        } else {
            None
        }
    }

    /// Suffix sums of the plan's predicted per-op latencies: entry `i` is
    /// the predicted service time from op `i` (inclusive) to completion,
    /// entry `num_ops` is 0. The scheduler's slack estimates and the
    /// admission controller's backlog bound both read these, so they are
    /// recomputed whenever a stream's plan changes.
    fn plan_profile(&self, g: &ModelGraph, plan: &Plan) -> Vec<f64> {
        let snap = self.device.snapshot();
        let model: &dyn CostModel = match self.cfg.planner_info {
            PlannerInfo::Profiler => &self.profiler as &dyn CostModel,
            PlannerInfo::Oracle => &self.device as &dyn CostModel,
        };
        let lat =
            crate::partition::plan::per_op_latencies(g, &plan.placements, model, &snap);
        let mut suffix = vec![0.0; lat.len() + 1];
        for i in (0..lat.len()).rev() {
            suffix[i] = suffix[i + 1] + lat[i];
        }
        suffix
    }

    fn plan_for(&mut self, g: &ModelGraph) -> Result<Plan> {
        let snap = self.device.snapshot();
        if let Some(plan) = self.plan_cache.lookup(&g.name, &snap, self.cfg.objective) {
            return Ok(plan);
        }
        let plan = match self.cfg.planner_info {
            PlannerInfo::Profiler => self.policy.partition(g, &self.profiler, &snap),
            PlannerInfo::Oracle => self.policy.partition(g, &self.device, &snap),
        }?;
        self.plan_cache
            .insert(&g.name, &snap, self.cfg.objective, plan.clone());
        Ok(plan)
    }

    /// Closed-loop run: `n_requests` back-to-back inferences of one model
    /// (the next request issues when the previous completes) — the
    /// measurement style of the paper's Figure 2 (continuous video
    /// detection), with no queueing by construction. Latency is pure
    /// service time; static energy amortizes over the busy run.
    pub fn run_closed_loop(
        &mut self,
        spec: &StreamSpec,
        n_requests: usize,
    ) -> Result<ServingReport> {
        let g = spec.model.clone();
        let mut plan = self.plan_for(&g)?;
        let mut latencies = LatencyRecorder::new();
        let mut energy = EnergyAccount::new();
        let mut cpu_busy_total = 0.0f64;
        let mut gpu_busy_total = 0.0f64;
        let mut last_monitor_s = 0.0f64;
        let t0 = self.device.time_s();

        for _ in 0..n_requests {
            let arrival = self.device.time_s();
            let mut out_cpu = vec![INPUT_CPU_FRAC; g.num_ops()];
            let mut prev: Option<Placement> = None;
            let mut req_latency = 0.0;
            for i in 0..g.num_ops() {
                let op = &g.ops[i];
                let placement = plan.placements[i];
                let input_cpu_fracs: Vec<f64> = if op.inputs.is_empty() {
                    vec![INPUT_CPU_FRAC; op.in_shapes.len()]
                } else {
                    op.inputs.iter().map(|&j| out_cpu[j]).collect()
                };
                let (new_run_cpu, new_run_gpu) = match prev {
                    None => (true, true),
                    Some(p) => (!p.uses(Proc::Cpu), !p.uses(Proc::Gpu)),
                };
                let ctx = ExecCtx {
                    input_cpu_fracs,
                    new_run_cpu,
                    new_run_gpu,
                    concurrent: false,
                };
                let snap = self.device.snapshot();
                let measured = self.device.measure(op, placement, &ctx);
                self.profiler.observe(op, placement, &ctx, &snap, &measured);
                energy.add_op(&measured);
                cpu_busy_total += measured.cpu_busy_s;
                gpu_busy_total += measured.gpu_busy_s;
                req_latency += measured.latency_s;
                out_cpu[i] = placement.frac_on(Proc::Cpu);
                prev = Some(placement);
                self.device.advance(
                    measured.latency_s,
                    if placement.uses(Proc::Cpu) { 1.0 } else { 0.0 },
                    if placement.uses(Proc::Gpu) { 1.0 } else { 0.0 },
                );
                self.controller.tick();

                // monitor + regime detection
                if self.device.time_s() - last_monitor_s >= self.cfg.monitor_period_s {
                    last_monitor_s = self.device.time_s();
                    self.monitor.sample(self.device.snapshot());
                    if self.monitor.regime_changed() {
                        self.profiler.reset_correction();
                        let snap = self.device.snapshot();
                        let model = match self.cfg.planner_info {
                            PlannerInfo::Profiler => &self.profiler as &dyn CostModel,
                            PlannerInfo::Oracle => &self.device as &dyn CostModel,
                        };
                        if let Some((p, dt)) = self.controller.on_regime_change(
                            &g,
                            self.policy.as_ref(),
                            model,
                            &snap,
                            self.cfg.objective,
                            Some(&mut self.plan_cache),
                        ) {
                            plan = p;
                            req_latency += dt;
                            self.device.advance(dt, 1.0, 0.0);
                        }
                    }
                }
                // drift-triggered incremental repartition (AdaOper only)
                if matches!(self.cfg.policy, PolicyKind::AdaOper) && self.profiler.drifted() {
                    let snap = self.device.snapshot();
                    let model = match self.cfg.planner_info {
                        PlannerInfo::Profiler => &self.profiler as &dyn CostModel,
                        PlannerInfo::Oracle => &self.device as &dyn CostModel,
                    };
                    if let Some((p, dt)) = self.controller.on_drift(
                        &g,
                        &plan,
                        i + 1,
                        model,
                        &snap,
                        Some(&out_cpu),
                    ) {
                        plan = p;
                        req_latency += dt; // decision runs on the CPU path
                        self.device.advance(dt, 1.0, 0.0);
                    }
                }
            }
            let finish = self.device.time_s();
            latencies.record(req_latency, 0.0, finish - arrival <= spec.slo_s);
            energy.finish_inference();
        }

        let wall = (self.device.time_s() - t0).max(1e-9);
        Ok(ServingReport {
            policy: self.policy.name().to_string(),
            condition: self.device.condition_name().to_string(),
            device: self.cfg.device_label.clone(),
            models: vec![g.name.clone()],
            duration_s: wall,
            requests: n_requests,
            throughput_hz: n_requests as f64 / wall,
            latency: latencies.summary(),
            latency_hist: Some(LogHistogram::latency_of(latencies.samples())),
            queue: None,
            miss_rate: latencies.miss_rate(),
            total_energy_j: energy.total_j(self.device.static_power_w(), wall),
            j_per_inference: energy.j_per_inference(self.device.static_power_w(), wall),
            inferences_per_j: energy.inferences_per_j(self.device.static_power_w(), wall),
            avg_cpu_util: self.device.avg_cpu_util(cpu_busy_total / wall),
            avg_gpu_util: (gpu_busy_total / wall).min(1.0),
            repartitions: self.controller.repartitions(),
            partition_overhead_s: self.controller.mean_decision_s(),
            plan_cache: self.plan_cache_stats(),
            sched: None,
        })
    }

    /// Run the engine over `streams` for `cfg.duration_s` of virtual time
    /// (requests arriving before the horizon are all completed).
    pub fn run(&mut self, streams: &[StreamSpec]) -> Result<ServingReport> {
        if streams.is_empty() {
            bail!("no streams");
        }
        let mut rng = Prng::new(self.cfg.seed);

        // --- arrivals
        let mut requests: Vec<Request> = Vec::new();
        for s in streams {
            let mut r = rng.split();
            for (k, t) in s.arrival.timestamps(self.cfg.duration_s, &mut r).iter().enumerate()
            {
                requests.push(Request {
                    id: k * streams.len() + s.id,
                    stream: s.id,
                    arrival_s: *t,
                    deadline_s: *t + s.slo_s,
                });
            }
        }
        // total_cmp: a NaN arrival must not panic the engine mid-run (it
        // sorts last instead and fails the deadline like any late request)
        requests.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        let total_requests = requests.len();
        if total_requests == 0 {
            bail!("duration too short: no requests generated");
        }

        // --- initial plans (and their latency profiles) per stream
        let mut plans: HashMap<usize, Plan> = HashMap::new();
        let mut profiles: HashMap<usize, Vec<f64>> = HashMap::new();
        for s in streams {
            let plan = self.plan_for(&s.model)?;
            profiles.insert(s.id, self.plan_profile(&s.model, &plan));
            plans.insert(s.id, plan);
        }

        // --- scheduling state
        let scheduler = scheduler::by_kind(self.cfg.scheduler);
        let mut admission = AdmissionCtrl::new(self.cfg.admission);
        let mut avail = [0.0f64; 2]; // per-proc availability time
        let mut busy_acc = [0.0f64; 2]; // busy seconds since last advance
        let mut latencies = LatencyRecorder::new();
        let mut energy = EnergyAccount::new();
        let mut outcomes: Vec<RequestOutcome> = Vec::new();
        let mut active: Vec<Active> = Vec::new();
        let mut next_arrival = 0usize;
        let mut last_monitor_s = 0.0f64;
        let mut cpu_busy_total = 0.0f64;
        let mut gpu_busy_total = 0.0f64;

        loop {
            // admit arrivals that occurred up to the earliest runnable time
            while next_arrival < requests.len() && active.is_empty() {
                let req = requests[next_arrival].clone();
                next_arrival += 1;
                let now = self.device.time_s();
                if let Some(a) =
                    try_admit(&mut admission, req, streams, &profiles, &active, &avail, now)
                {
                    active.push(a);
                } // else: shed; try the next queued arrival
            }
            if active.is_empty() {
                break; // all done
            }

            // the dispatch policy picks which request runs its next op
            let candidates: Vec<Candidate> = active
                .iter()
                .enumerate()
                .map(|(ai, a)| {
                    let placement = plans[&a.model].placements[a.next_op];
                    let mut start = a.data_ready_s;
                    for p in Proc::ALL {
                        if placement.uses(p) {
                            start = start.max(avail[p.index()]);
                        }
                    }
                    Candidate {
                        active_idx: ai,
                        start_s: start,
                        arrival_s: a.req.arrival_s,
                        deadline_s: a.req.deadline_s,
                        remaining_s: profiles[&a.model][a.next_op],
                    }
                })
                .collect();
            let chosen = candidates[scheduler.pick(&candidates)];
            let (ai, mut start) = (chosen.active_idx, chosen.start_s);

            // if a queued arrival could begin before `start`, admit it
            if next_arrival < requests.len() && requests[next_arrival].arrival_s < start {
                let req = requests[next_arrival].clone();
                next_arrival += 1;
                let now = self.device.time_s();
                if let Some(a) =
                    try_admit(&mut admission, req, streams, &profiles, &active, &avail, now)
                {
                    active.push(a);
                }
                continue; // re-evaluate (with the newcomer, or the next arrival)
            }

            // --- advance virtual time to `start`
            let now = self.device.time_s();
            if start > now {
                let dt = start - now;
                let u_cpu = (busy_acc[0] / dt).min(1.0);
                let u_gpu = (busy_acc[1] / dt).min(1.0);
                busy_acc = [0.0, 0.0];
                self.device.advance(dt, u_cpu, u_gpu);
            } else {
                start = now;
            }

            // periodic monitor sampling + regime detection; latency
            // profiles refresh against the live snapshot every sample so
            // the scheduler's slack and the admission controller's backlog
            // estimates track device dynamics (drift, background load)
            if self.device.time_s() - last_monitor_s >= self.cfg.monitor_period_s {
                last_monitor_s = self.device.time_s();
                self.monitor.sample(self.device.snapshot());
                if self.monitor.regime_changed() {
                    self.profiler.reset_correction();
                    let snap = self.device.snapshot();
                    for s in streams {
                        let model = match self.cfg.planner_info {
                            PlannerInfo::Profiler => &self.profiler as &dyn CostModel,
                            PlannerInfo::Oracle => &self.device as &dyn CostModel,
                        };
                        if let Some((plan, dt)) = self.controller.on_regime_change(
                            &s.model,
                            self.policy.as_ref(),
                            model,
                            &snap,
                            self.cfg.objective,
                            Some(&mut self.plan_cache),
                        ) {
                            plans.insert(s.id, plan);
                            avail[Proc::Cpu.index()] += dt; // decision runs on CPU
                        }
                    }
                }
                // refresh after any regime re-plan so profiles match the
                // adopted plans and the live snapshot (drift, background)
                for s in streams {
                    profiles.insert(s.id, self.plan_profile(&s.model, &plans[&s.id]));
                }
            }

            // --- execute the chosen op
            let a = &mut active[ai];
            let g = streams[a.model].model.clone();
            let op = &g.ops[a.next_op];
            let planned = plans[&a.model].placements[a.next_op];
            let input_cpu_fracs: Vec<f64> = if op.inputs.is_empty() {
                vec![INPUT_CPU_FRAC; op.in_shapes.len()]
            } else {
                op.inputs.iter().map(|&j| a.out_cpu[j]).collect()
            };
            let (new_run_cpu, new_run_gpu) = match a.prev_placement {
                None => (true, true),
                Some(p) => (!p.uses(Proc::Cpu), !p.uses(Proc::Gpu)),
            };
            // slack if the op starts now: time to spare before the deadline
            // after the predicted remaining work (this op inclusive)
            let slack_s = a.req.deadline_s - (start + profiles[&a.model][a.next_op]);
            let others_running = active.len() > 1;
            let ctx = ExecCtx {
                input_cpu_fracs,
                new_run_cpu,
                new_run_gpu,
                concurrent: others_running,
            };
            let snap = self.device.snapshot();
            let placement = {
                let model: &dyn CostModel = match self.cfg.planner_info {
                    PlannerInfo::Profiler => &self.profiler as &dyn CostModel,
                    PlannerInfo::Oracle => &self.device as &dyn CostModel,
                };
                let wanted = scheduler.place(planned, op, &ctx, &snap, model, slack_s);
                // `start` was clamped against the *planned* placement's
                // processors only; an override may not claim a processor
                // that is still busy at `start` (it would double-book and
                // rewind `avail`) — fall back to the plan in that case
                let feasible = Proc::ALL
                    .iter()
                    .all(|&p| !wanted.uses(p) || avail[p.index()] <= start);
                if feasible {
                    wanted
                } else {
                    planned
                }
            };
            let measured = self.device.measure(op, placement, &ctx);
            self.profiler.observe(op, placement, &ctx, &snap, &measured);
            energy.add_op(&measured);
            let a = &mut active[ai];
            a.energy_j += measured.energy_j;
            if a.start_s.is_none() {
                a.start_s = Some(start);
            }
            a.out_cpu[a.next_op] = placement.frac_on(Proc::Cpu);
            a.prev_placement = Some(placement);
            a.data_ready_s = start + measured.latency_s;
            for p in Proc::ALL {
                if placement.uses(p) {
                    avail[p.index()] = start + measured.latency_s;
                    busy_acc[p.index()] += measured.latency_s;
                }
            }
            cpu_busy_total += measured.cpu_busy_s;
            gpu_busy_total += measured.gpu_busy_s;
            if let Some(hook) = &mut self.numerics {
                hook(&a.req, op)?;
            }
            a.next_op += 1;
            self.controller.tick();

            // --- drift-triggered incremental repartition (AdaOper only)
            if matches!(self.cfg.policy, PolicyKind::AdaOper) && self.profiler.drifted() {
                let frontier = active[ai].next_op;
                let stream_id = active[ai].model;
                let out_cpu = active[ai].out_cpu.clone();
                let snap = self.device.snapshot();
                let model = match self.cfg.planner_info {
                    PlannerInfo::Profiler => &self.profiler as &dyn CostModel,
                    PlannerInfo::Oracle => &self.device as &dyn CostModel,
                };
                if let Some((plan, dt)) = self.controller.on_drift(
                    &g,
                    &plans[&stream_id],
                    frontier,
                    model,
                    &snap,
                    Some(&out_cpu),
                ) {
                    profiles.insert(stream_id, self.plan_profile(&g, &plan));
                    plans.insert(stream_id, plan);
                    avail[Proc::Cpu.index()] += dt;
                }
            }

            // --- completion
            if active[ai].next_op == g.num_ops() {
                let a = active.swap_remove(ai);
                let outcome = RequestOutcome {
                    start_s: a.start_s.unwrap(),
                    finish_s: a.data_ready_s,
                    energy_j: a.energy_j,
                    request: a.req,
                };
                latencies.record(
                    outcome.latency_s(),
                    outcome.queue_s(),
                    outcome.met_deadline(),
                );
                energy.finish_inference();
                outcomes.push(outcome);
            }
        }

        // --- report
        let wall = self.device.time_s().max(self.cfg.duration_s);
        let counters = admission.counters();
        let sched = SchedStats {
            scheduler: scheduler.name().to_string(),
            admission: admission.policy().name().to_string(),
            offered: counters.offered,
            admitted: counters.admitted,
            shed_late: counters.shed_late,
            dropped_capacity: counters.dropped_capacity,
            deadline_misses: latencies.misses(),
        };
        let report = ServingReport {
            policy: self.policy.name().to_string(),
            condition: self.device.condition_name().to_string(),
            device: self.cfg.device_label.clone(),
            models: streams.iter().map(|s| s.model.name.clone()).collect(),
            duration_s: wall,
            requests: outcomes.len(),
            throughput_hz: outcomes.len() as f64 / wall,
            latency: latencies.summary(),
            latency_hist: Some(LogHistogram::latency_of(latencies.samples())),
            queue: latencies.queue_summary(),
            miss_rate: latencies.miss_rate(),
            total_energy_j: energy.total_j(self.device.static_power_w(), wall),
            j_per_inference: energy.j_per_inference(self.device.static_power_w(), wall),
            inferences_per_j: energy.inferences_per_j(self.device.static_power_w(), wall),
            avg_cpu_util: self.device.avg_cpu_util(cpu_busy_total / wall),
            avg_gpu_util: (gpu_busy_total / wall).min(1.0),
            repartitions: self.controller.repartitions(),
            partition_overhead_s: self.controller.mean_decision_s(),
            plan_cache: self.plan_cache_stats(),
            sched: Some(sched),
        };
        debug_assert_eq!(counters.offered, total_requests);
        debug_assert_eq!(
            outcomes.len() + counters.shed_late + counters.dropped_capacity,
            total_requests
        );
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::profiler::gbdt::GbdtParams;
    use crate::workload::Arrival;

    fn quick_calib() -> CalibConfig {
        CalibConfig {
            samples: 1200,
            seed: 5,
            gbdt: GbdtParams {
                trees: 40,
                ..Default::default()
            },
        }
    }

    fn stream(rate: f64, slo: f64) -> Vec<StreamSpec> {
        vec![StreamSpec::new(
            0,
            zoo::yolov2_tiny(),
            Arrival::Poisson { hz: rate },
            slo,
        )]
    }

    #[test]
    fn engine_completes_all_requests() {
        let mut e = Engine::new(EngineConfig {
            duration_s: 3.0,
            calib: quick_calib(),
            ..Default::default()
        });
        let r = e.run(&stream(5.0, 0.5)).unwrap();
        assert!(r.requests > 5, "only {} requests", r.requests);
        assert!(r.latency.is_some());
        assert!(r.j_per_inference > 0.0);
        assert!(r.throughput_hz > 0.0);
    }

    #[test]
    fn concurrent_streams_complete() {
        let mut e = Engine::new(EngineConfig {
            duration_s: 2.0,
            policy: PolicyKind::MaceGpu,
            calib: quick_calib(),
            ..Default::default()
        });
        let streams = vec![
            StreamSpec::new(0, zoo::yolov2_tiny(), Arrival::Periodic { hz: 10.0, jitter: 0.0 }, 0.5),
            StreamSpec::new(1, zoo::mobilenet_v1(), Arrival::Poisson { hz: 8.0 }, 0.5),
        ];
        let r = e.run(&streams).unwrap();
        assert!(r.requests >= 20, "{} requests", r.requests);
        assert_eq!(r.models.len(), 2);
    }

    #[test]
    fn deterministic_given_seed() {
        let mk = || {
            let mut e = Engine::new(EngineConfig {
                duration_s: 1.5,
                seed: 42,
                policy: PolicyKind::MaceGpu,
                calib: quick_calib(),
                ..Default::default()
            });
            e.run(&stream(8.0, 0.5)).unwrap()
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.requests, b.requests);
        assert!((a.total_energy_j - b.total_energy_j).abs() < 1e-9);
    }

    #[test]
    fn high_condition_worse_than_moderate() {
        let run = |cond| {
            let mut e = Engine::new(EngineConfig {
                duration_s: 3.0,
                condition: cond,
                policy: PolicyKind::MaceGpu,
                calib: quick_calib(),
                ..Default::default()
            });
            e.run(&stream(5.0, 1.0)).unwrap()
        };
        let m = run(ConditionKind::Moderate);
        let h = run(ConditionKind::High);
        let lm = m.latency.unwrap().p50;
        let lh = h.latency.unwrap().p50;
        assert!(lh > lm, "high p50 {lh} ≤ moderate {lm}");
    }

    #[test]
    fn adaoper_repartitions_under_drift() {
        let mut e = Engine::new(EngineConfig {
            duration_s: 4.0,
            policy: PolicyKind::AdaOper,
            cooldown_ops: 10,
            calib: quick_calib(),
            condition: ConditionKind::High,
            ..Default::default()
        });
        let _r = e.run(&stream(6.0, 1.0)).unwrap();
        // under the bursty high condition the drift trigger must at least
        // evaluate re-plans in 4 s (adoption is hysteresis-gated)
        assert!(e.drift_evaluations() > 0, "drift never evaluated a re-plan");
    }

    #[test]
    fn plan_cache_cold_miss_then_warm_hit() {
        let mut e = Engine::new(EngineConfig {
            duration_s: 1.0,
            policy: PolicyKind::MaceGpu,
            calib: quick_calib(),
            ..Default::default()
        });
        let spec = StreamSpec::new(0, zoo::yolov2_tiny(), Arrival::Poisson { hz: 5.0 }, 0.5);
        // zero requests → no virtual time passes, so the second planning
        // lookup sees the identical snapshot: guaranteed warm hit
        let r0 = e.run_closed_loop(&spec, 0).unwrap();
        let s0 = r0.plan_cache.unwrap();
        assert_eq!((s0.hits, s0.misses), (0, 1), "{s0:?}");
        let r1 = e.run_closed_loop(&spec, 0).unwrap();
        let s1 = r1.plan_cache.unwrap();
        assert_eq!((s1.hits, s1.misses), (1, 1), "{s1:?}");
        assert_eq!(s1.entries, 1);
    }

    #[test]
    fn plan_cache_capacity_zero_reports_none() {
        use crate::coordinator::plan_cache::PlanCacheConfig;
        let mut e = Engine::new(EngineConfig {
            duration_s: 1.0,
            policy: PolicyKind::MaceGpu,
            calib: quick_calib(),
            plan_cache: PlanCacheConfig {
                capacity: 0,
                ..Default::default()
            },
            ..Default::default()
        });
        let spec = StreamSpec::new(0, zoo::yolov2_tiny(), Arrival::Poisson { hz: 5.0 }, 0.5);
        let r = e.run_closed_loop(&spec, 1).unwrap();
        assert!(r.plan_cache.is_none());
    }

    #[test]
    fn default_config_reports_fifo_admit_all() {
        let mut e = Engine::new(EngineConfig {
            duration_s: 1.5,
            policy: PolicyKind::MaceGpu,
            calib: quick_calib(),
            ..Default::default()
        });
        let r = e.run(&stream(6.0, 0.5)).unwrap();
        let sc = r.sched.unwrap();
        assert_eq!(sc.scheduler, "fifo");
        assert_eq!(sc.admission, "admit-all");
        assert_eq!(sc.offered, sc.admitted);
        assert_eq!(sc.shed(), 0);
        assert_eq!(r.requests, sc.admitted);
    }

    #[test]
    fn drop_late_sheds_at_overload_and_accounts() {
        let mut e = Engine::new(EngineConfig {
            duration_s: 2.0,
            policy: PolicyKind::MaceGpu,
            planner_info: PlannerInfo::Oracle,
            admission: AdmissionPolicy::DropLate,
            calib: quick_calib(),
            ..Default::default()
        });
        // far past saturation with a moderate SLO: shedding must kick in
        let r = e.run(&stream(300.0, 0.3)).unwrap();
        let sc = r.sched.unwrap();
        assert_eq!(sc.admission, "drop-late");
        assert!(sc.shed_late > 0, "{sc:?}");
        assert_eq!(sc.offered, sc.admitted + sc.shed_late);
        assert_eq!(r.requests, sc.admitted);
    }

    #[test]
    fn bounded_admission_caps_in_flight() {
        use crate::config::schema::SchedulerKind;
        let mut e = Engine::new(EngineConfig {
            duration_s: 2.0,
            policy: PolicyKind::MaceGpu,
            scheduler: SchedulerKind::Edf,
            admission: AdmissionPolicy::Bounded { per_stream: 1 },
            calib: quick_calib(),
            ..Default::default()
        });
        let r = e.run(&stream(200.0, 0.5)).unwrap();
        let sc = r.sched.unwrap();
        assert_eq!(sc.scheduler, "edf");
        assert!(sc.dropped_capacity > 0, "{sc:?}");
        assert_eq!(sc.offered, sc.admitted + sc.dropped_capacity);
        assert_eq!(r.requests, sc.admitted);
    }

    #[test]
    fn numerics_hook_called_per_op() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let count = Arc::new(AtomicUsize::new(0));
        let c2 = count.clone();
        let mut e = Engine::new(EngineConfig {
            duration_s: 1.0,
            policy: PolicyKind::MaceGpu,
            calib: quick_calib(),
            ..Default::default()
        });
        e.set_numerics_hook(Box::new(move |_req, _op| {
            c2.fetch_add(1, Ordering::SeqCst);
            Ok(())
        }));
        let r = e.run(&stream(4.0, 1.0)).unwrap();
        let g = zoo::yolov2_tiny();
        assert_eq!(count.load(Ordering::SeqCst), r.requests * g.num_ops());
    }
}
