//! Threaded serving mode: one executor thread per processor, fed through
//! channels by the coordinator thread — the process topology a real
//! deployment has (MACE/CoDL worker pools), demonstrated with real AOT
//! numerics when an [`OpExecutor`] factory is installed.
//!
//! Timing/energy still come from the simulated device (the substitute for
//! the phone); the worker threads do the *actual tensor compute* for the
//! executable model via PJRT. Each worker constructs its own executor
//! inside the thread (PJRT clients are not assumed `Send`), so the factory
//! closure crosses the thread boundary, not the client.

use std::collections::HashMap;
use std::sync::mpsc;
use std::thread;

use anyhow::{anyhow, Result};

use crate::graph::ModelGraph;
use crate::metrics::{EnergyAccount, LatencyRecorder, ServingReport};
use crate::partition::plan::{Plan, INPUT_CPU_FRAC};
use crate::soc::device::{Device, ExecCtx};
use crate::soc::{Placement, Proc};

/// Executes the numeric work of one operator (e.g. a PJRT HLO block).
pub trait OpExecutor {
    /// Run op `op_name` of `model` on `inputs`; returns the output tensor.
    fn execute(&mut self, model: &str, op_name: &str, inputs: &[Vec<f32>]) -> Result<Vec<f32>>;
}

/// No-op executor (timing-only liveness).
pub struct NoopExecutor;

impl OpExecutor for NoopExecutor {
    fn execute(&mut self, _m: &str, _o: &str, _i: &[Vec<f32>]) -> Result<Vec<f32>> {
        Ok(Vec::new())
    }
}

/// Factory building an executor *inside* the worker thread.
pub type ExecutorFactory = Box<dyn Fn() -> Box<dyn OpExecutor> + Send + Sync>;

enum WorkerMsg {
    Run {
        model: String,
        op_name: String,
        inputs: Vec<Vec<f32>>,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    Stop,
}

/// A live (threaded) serving session over one model.
pub struct LiveSession;

impl LiveSession {
    /// Run `n_requests` back-to-back inferences of `g` under `plan`,
    /// executing real numerics via `factory`-built executors in the
    /// per-processor worker threads. Returns the serving report plus the
    /// final output tensor of the last request (for validation).
    pub fn run(
        g: &ModelGraph,
        plan: &Plan,
        device: &mut Device,
        factory: ExecutorFactory,
        n_requests: usize,
        input: Vec<f32>,
    ) -> Result<(ServingReport, Vec<f32>)> {
        let factory = std::sync::Arc::new(factory);
        // one worker per processor, each owning its own executor
        let mut workers: HashMap<usize, (mpsc::Sender<WorkerMsg>, thread::JoinHandle<()>)> =
            HashMap::new();
        for p in Proc::ALL {
            let (tx, rx) = mpsc::channel::<WorkerMsg>();
            let f = factory.clone();
            let handle = thread::Builder::new()
                .name(format!("adaoper-exec-{}", p.name()))
                .spawn(move || {
                    let mut exec = f();
                    while let Ok(msg) = rx.recv() {
                        match msg {
                            WorkerMsg::Stop => break,
                            WorkerMsg::Run {
                                model,
                                op_name,
                                inputs,
                                reply,
                            } => {
                                let r = exec.execute(&model, &op_name, &inputs);
                                let _ = reply.send(r);
                            }
                        }
                    }
                })
                .map_err(|e| anyhow!("spawn worker: {e}"))?;
            workers.insert(p.index(), (tx, handle));
        }

        let mut latencies = LatencyRecorder::new();
        let mut energy = EnergyAccount::new();
        let mut last_output = Vec::new();
        let t_start = device.time_s();

        for _req in 0..n_requests {
            let mut outputs: Vec<Vec<f32>> = vec![Vec::new(); g.num_ops()];
            let mut out_cpu = vec![INPUT_CPU_FRAC; g.num_ops()];
            let mut prev: Option<Placement> = None;
            let mut req_latency = 0.0;
            for (i, op) in g.ops.iter().enumerate() {
                let placement = plan.placements[i];
                let input_cpu_fracs: Vec<f64> = if op.inputs.is_empty() {
                    vec![INPUT_CPU_FRAC; op.in_shapes.len()]
                } else {
                    op.inputs.iter().map(|&j| out_cpu[j]).collect()
                };
                let (new_run_cpu, new_run_gpu) = match prev {
                    None => (true, true),
                    Some(p) => (!p.uses(Proc::Cpu), !p.uses(Proc::Gpu)),
                };
                let ctx = ExecCtx {
                    input_cpu_fracs,
                    new_run_cpu,
                    new_run_gpu,
                    concurrent: false,
                };
                // virtual cost from the device model
                let cost = device.measure(op, placement, &ctx);
                req_latency += cost.latency_s;
                energy.add_op(&cost);
                device.advance(
                    cost.latency_s,
                    if placement.uses(Proc::Cpu) { 1.0 } else { 0.0 },
                    if placement.uses(Proc::Gpu) { 1.0 } else { 0.0 },
                );

                // real numerics on the owning worker thread (split ops run
                // on the unit holding the larger share — the numeric result
                // is identical, the split is a timing construct)
                let owner = if placement.frac_on(Proc::Cpu) >= 0.5 {
                    Proc::Cpu
                } else {
                    Proc::Gpu
                };
                let inputs: Vec<Vec<f32>> = if op.inputs.is_empty() {
                    vec![input.clone()]
                } else {
                    op.inputs.iter().map(|&j| outputs[j].clone()).collect()
                };
                let (reply_tx, reply_rx) = mpsc::channel();
                workers[&owner.index()]
                    .0
                    .send(WorkerMsg::Run {
                        model: g.name.clone(),
                        op_name: op.name.clone(),
                        inputs,
                        reply: reply_tx,
                    })
                    .map_err(|_| anyhow!("worker died"))?;
                outputs[i] = reply_rx.recv().map_err(|_| anyhow!("worker died"))??;
                out_cpu[i] = placement.frac_on(Proc::Cpu);
                prev = Some(placement);
            }
            latencies.record(req_latency, 0.0, true);
            energy.finish_inference();
            if let Some(&out_id) = g.outputs().first() {
                last_output = outputs[out_id].clone();
            }
        }

        for (_, (tx, handle)) in workers {
            let _ = tx.send(WorkerMsg::Stop);
            let _ = handle.join();
        }

        let wall = device.time_s() - t_start;
        let report = ServingReport {
            policy: plan.policy.clone(),
            condition: device.condition_name().to_string(),
            device: None,
            models: vec![g.name.clone()],
            duration_s: wall,
            requests: n_requests,
            throughput_hz: n_requests as f64 / wall.max(1e-9),
            latency: latencies.summary(),
            latency_hist: Some(crate::metrics::LogHistogram::latency_of(
                latencies.samples(),
            )),
            queue: latencies.queue_summary(),
            miss_rate: 0.0,
            total_energy_j: energy.total_j(device.static_power_w(), wall),
            j_per_inference: energy.j_per_inference(device.static_power_w(), wall),
            inferences_per_j: energy.inferences_per_j(device.static_power_w(), wall),
            avg_cpu_util: device.avg_cpu_util(energy.cpu_busy_s() / wall.max(1e-9)),
            avg_gpu_util: (energy.gpu_busy_s() / wall.max(1e-9)).min(1.0),
            repartitions: 0,
            partition_overhead_s: 0.0,
            plan_cache: None,
            sched: None,
            batch: None,
            telemetry: None,
            health: None,
        };
        Ok((report, last_output))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::soc::device::DeviceConfig;
    use crate::workload::WorkloadCondition;

    /// Executor that tags outputs so the test can verify data flowed
    /// through worker threads in topological order.
    struct CountingExecutor {
        calls: usize,
    }

    impl OpExecutor for CountingExecutor {
        fn execute(&mut self, _m: &str, _o: &str, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
            self.calls += 1;
            let sum: f32 = inputs.iter().flat_map(|v| v.iter()).sum();
            Ok(vec![sum + 1.0])
        }
    }

    #[test]
    fn live_session_runs_through_worker_threads() {
        let g = zoo::tiny_exec();
        let mut d = Device::new(DeviceConfig::snapdragon_855());
        d.apply_condition(&WorkloadCondition::moderate().spec);
        let plan = Plan {
            placements: vec![Placement::GPU; g.num_ops()],
            predicted: Default::default(),
            policy: "mace-gpu".into(),
        };
        let factory: ExecutorFactory =
            Box::new(|| Box::new(CountingExecutor { calls: 0 }));
        let (report, out) =
            LiveSession::run(&g, &plan, &mut d, factory, 3, vec![1.0, 2.0]).unwrap();
        assert_eq!(report.requests, 3);
        assert!(report.throughput_hz > 0.0);
        // chain of +1's over the sum: output well-defined and non-empty
        assert_eq!(out.len(), 1);
        assert!(out[0] >= 1.0);
    }

    #[test]
    fn mixed_placement_routes_to_both_workers() {
        let g = zoo::tiny_exec();
        let mut d = Device::new(DeviceConfig::snapdragon_855());
        d.apply_condition(&WorkloadCondition::moderate().spec);
        let placements: Vec<Placement> = (0..g.num_ops())
            .map(|i| if i % 2 == 0 { Placement::CPU } else { Placement::GPU })
            .collect();
        let plan = Plan {
            placements,
            predicted: Default::default(),
            policy: "alt".into(),
        };
        let factory: ExecutorFactory =
            Box::new(|| Box::new(CountingExecutor { calls: 0 }));
        let (report, _) =
            LiveSession::run(&g, &plan, &mut d, factory, 1, vec![0.5]).unwrap();
        assert_eq!(report.requests, 1);
    }
}
