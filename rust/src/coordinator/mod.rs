//! L3 coordinator: the concurrent serving engine.
//!
//! * [`request`] — app streams (one per concurrently-served model) and
//!   request lifecycle types.
//! * [`engine`] — the virtual-time engine: a two-resource (CPU/GPU)
//!   op-level list scheduler that executes partition plans on the
//!   simulated device, feeds measurements back to the profiler, and
//!   triggers repartitioning. All benches and figures run through it.
//!   Since the event-kernel refactor it is a thin driver over the
//!   [`crate::sim`] stages, broadcasting every state change to
//!   [`crate::sim::SimObserver`]s.
//! * [`repartition`] — drift/regime-triggered repartition controller
//!   (incremental window or full re-solve), with decision-time accounting
//!   charged to the CPU.
//! * [`scheduler`] — pluggable SLO-aware dispatch: the [`Scheduler`] trait
//!   with FIFO / EDF / slack-reclaiming implementations, plus admission
//!   control ([`AdmissionPolicy`]) that can shed infeasible requests
//!   before they enter the queue.
//! * [`plan_cache`] — LRU partition-plan cache keyed by (model, quantized
//!   device condition, objective) so repartition events under recurring
//!   conditions reuse plans instead of re-running the DP.
//! * [`live`] — the threaded serving mode: per-processor executor threads
//!   behind channels, with an optional numerics hook that runs the real
//!   AOT-compiled HLO blocks per operator (the e2e example wires PJRT in).

pub mod engine;
pub mod live;
pub mod plan_cache;
pub mod repartition;
pub mod request;
pub mod scheduler;

pub use engine::{Engine, EngineConfig};
pub use plan_cache::{PlanCache, PlanCacheConfig};
pub use request::{Request, StreamSpec};
pub use scheduler::{AdmissionPolicy, Scheduler};
