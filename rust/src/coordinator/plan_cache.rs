//! Partition-plan cache: memoizes full DP solves keyed by (model id,
//! quantized device-condition bucket, objective, quantized batch size).
//!
//! Per-request planning cost dominates at high request rates: every
//! repartition trigger re-runs the DP from scratch even when the device has
//! merely returned to a condition it has been in before (the bursty
//! background processes of [`crate::soc::background`] revisit the same
//! regimes constantly). Observable device state is continuous, so exact
//! snapshots never recur — instead the snapshot is *quantized* into
//! condition buckets (frequency / utilization / temperature / bandwidth,
//! widths configurable via [`PlanCacheConfig`]) and plans are reused within
//! a bucket. The DP re-planned for such a recurring bucket would see nearly
//! identical inputs and produce a nearly identical plan; the coordinator's
//! adoption hysteresis already tolerates far larger model error than the
//! within-bucket variation, so serving quality is unaffected while the
//! repartition fast path drops from a full DP solve to a hash lookup.
//!
//! Eviction is LRU with a fixed capacity; hit/miss/eviction counters are
//! surfaced through [`crate::metrics::report::PlanCacheStats`] so serving
//! reports (and the CLI) show the realized hit rate. A capacity of 0
//! disables the cache entirely (every lookup misses without counting, so
//! ablations can flip it off without touching call sites).

use std::collections::HashMap;

use crate::metrics::report::PlanCacheStats;
use crate::partition::plan::{Objective, Plan};
use crate::soc::device::Snapshot;

/// Cache sizing and condition-quantization knobs.
#[derive(Debug, Clone)]
pub struct PlanCacheConfig {
    /// Maximum number of cached plans (LRU-evicted beyond this); 0 disables.
    pub capacity: usize,
    /// Frequency bucket width, Hz (applied to CPU and GPU frequency).
    pub freq_bucket_hz: f64,
    /// Utilization bucket width (applied to CPU and GPU utilization).
    pub util_bucket: f64,
    /// Temperature bucket width, °C. The default is coarse enough that
    /// temperature effectively never splits buckets (energy sensitivity to
    /// temperature is already folded into the throttled frequencies).
    pub temp_bucket_c: f64,
    /// Ambient-bandwidth-factor bucket width.
    pub bw_bucket: f64,
}

impl Default for PlanCacheConfig {
    fn default() -> Self {
        PlanCacheConfig {
            capacity: 32,
            freq_bucket_hz: 50e6,
            util_bucket: 0.15,
            temp_bucket_c: 100.0,
            bw_bucket: 0.05,
        }
    }
}

/// Cache key: model identity × quantized condition × objective × quantized
/// batch size.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct CacheKey {
    model: String,
    cpu_freq: i64,
    gpu_freq: i64,
    cpu_util: i64,
    gpu_util: i64,
    temp: i64,
    bw: i64,
    objective: (u8, u64),
    batch: u32,
}

/// Quantized batch-size dimension of the cache key: log₂ buckets
/// (1 → 1, 2 → 2, 3–4 → 3, 5–8 → 4, …). Plans priced for nearby batch
/// sizes are interchangeable (the batch-aware cost model is smooth in B),
/// while batched and unbatched plans never alias — an unbatched run keeps
/// exactly the legacy key space.
pub fn batch_bucket(batch: usize) -> u32 {
    usize::BITS - batch.max(1).leading_zeros()
}

/// Stable key for an [`Objective`] (f64 SLOs keyed by their bit pattern).
fn objective_key(o: Objective) -> (u8, u64) {
    match o {
        Objective::MinEdp => (0, 0),
        Objective::MinLatency => (1, 0),
        Objective::MinEnergyUnderSlo { slo_s } => (2, slo_s.to_bits()),
    }
}

fn bucket(v: f64, width: f64) -> i64 {
    debug_assert!(width > 0.0, "bucket width must be positive");
    (v / width).floor() as i64
}

struct Entry {
    plan: Plan,
    last_used: u64,
}

/// LRU plan cache with hit/miss accounting.
pub struct PlanCache {
    cfg: PlanCacheConfig,
    entries: HashMap<CacheKey, Entry>,
    tick: u64,
    hits: usize,
    misses: usize,
    evictions: usize,
}

impl PlanCache {
    /// Build an empty cache with the given sizing/quantization.
    pub fn new(cfg: PlanCacheConfig) -> PlanCache {
        PlanCache {
            cfg,
            entries: HashMap::new(),
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// True when lookups can ever hit (capacity > 0).
    pub fn enabled(&self) -> bool {
        self.cfg.capacity > 0
    }

    /// The sizing/quantization configuration.
    pub fn config(&self) -> &PlanCacheConfig {
        &self.cfg
    }

    fn key(
        &self,
        model: &str,
        snap: &Snapshot,
        objective: Objective,
        batch: usize,
    ) -> CacheKey {
        CacheKey {
            model: model.to_string(),
            cpu_freq: bucket(snap.cpu_freq_hz, self.cfg.freq_bucket_hz),
            gpu_freq: bucket(snap.gpu_freq_hz, self.cfg.freq_bucket_hz),
            cpu_util: bucket(snap.cpu_util, self.cfg.util_bucket),
            gpu_util: bucket(snap.gpu_util, self.cfg.util_bucket),
            temp: bucket(snap.temp_c, self.cfg.temp_bucket_c),
            bw: bucket(snap.bw_factor, self.cfg.bw_bucket),
            objective: objective_key(objective),
            batch: batch_bucket(batch),
        }
    }

    /// Look a plan up for (model, quantized condition, objective, batch
    /// bucket). `batch` is the size planning priced ops at (1 on the
    /// unbatched path). Counts a hit or a miss; disabled caches return
    /// `None` without counting.
    pub fn lookup(
        &mut self,
        model: &str,
        snap: &Snapshot,
        objective: Objective,
        batch: usize,
    ) -> Option<Plan> {
        if !self.enabled() {
            return None;
        }
        let key = self.key(model, snap, objective, batch);
        self.tick += 1;
        match self.entries.get_mut(&key) {
            Some(e) => {
                e.last_used = self.tick;
                self.hits += 1;
                Some(e.plan.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or refresh) the plan for (model, quantized condition,
    /// objective, batch bucket), evicting the least-recently-used entry at
    /// capacity.
    pub fn insert(
        &mut self,
        model: &str,
        snap: &Snapshot,
        objective: Objective,
        batch: usize,
        plan: Plan,
    ) {
        if !self.enabled() {
            return;
        }
        let key = self.key(model, snap, objective, batch);
        self.tick += 1;
        if let Some(e) = self.entries.get_mut(&key) {
            e.plan = plan;
            e.last_used = self.tick;
            return;
        }
        if self.entries.len() >= self.cfg.capacity {
            if let Some(victim) = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
            {
                self.entries.remove(&victim);
                self.evictions += 1;
            }
        }
        self.entries.insert(
            key,
            Entry {
                plan,
                last_used: self.tick,
            },
        );
    }

    /// Number of resident plans.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no plans are resident.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Drop every cached plan (counters are preserved).
    pub fn clear(&mut self) {
        self.entries.clear();
    }

    /// Counter snapshot for the metrics report.
    pub fn stats(&self) -> PlanCacheStats {
        PlanCacheStats {
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
            entries: self.entries.len(),
            capacity: self.cfg.capacity,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::Placement;

    fn snap(cpu_freq: f64, cpu_util: f64) -> Snapshot {
        Snapshot {
            time_s: 0.0,
            cpu_freq_hz: cpu_freq,
            gpu_freq_hz: 499e6,
            cpu_util,
            gpu_util: 0.08,
            temp_c: 42.0,
            bw_factor: 0.92,
        }
    }

    fn plan(tag: &str) -> Plan {
        Plan {
            placements: vec![Placement::GPU, Placement::CPU],
            predicted: Default::default(),
            policy: tag.to_string(),
        }
    }

    #[test]
    fn cold_miss_then_warm_hit() {
        let mut c = PlanCache::new(PlanCacheConfig::default());
        let s = snap(1.497e9, 0.35);
        assert!(c.lookup("yolov2", &s, Objective::MinEdp, 1).is_none());
        c.insert("yolov2", &s, Objective::MinEdp, 1, plan("a"));
        let got = c.lookup("yolov2", &s, Objective::MinEdp, 1).unwrap();
        assert_eq!(got.policy, "a");
        let st = c.stats();
        assert_eq!((st.hits, st.misses), (1, 1));
        assert_eq!(st.entries, 1);
    }

    #[test]
    fn nearby_snapshots_share_a_bucket_distant_ones_do_not() {
        let mut c = PlanCache::new(PlanCacheConfig::default());
        c.insert("m", &snap(1.497e9, 0.35), Objective::MinEdp, 1, plan("a"));
        // same OPP, utilization wobble inside one 0.15-wide bucket
        assert!(c.lookup("m", &snap(1.497e9, 0.38), Objective::MinEdp, 1).is_some());
        // repinned frequency → different bucket
        assert!(c.lookup("m", &snap(0.883e9, 0.35), Objective::MinEdp, 1).is_none());
        // utilization regime shift → different bucket
        assert!(c.lookup("m", &snap(1.497e9, 0.65), Objective::MinEdp, 1).is_none());
    }

    #[test]
    fn keys_distinguish_model_and_objective() {
        let mut c = PlanCache::new(PlanCacheConfig::default());
        let s = snap(1.497e9, 0.35);
        c.insert("a", &s, Objective::MinEdp, 1, plan("a"));
        assert!(c.lookup("b", &s, Objective::MinEdp, 1).is_none());
        assert!(c.lookup("a", &s, Objective::MinLatency, 1).is_none());
        assert!(c
            .lookup("a", &s, Objective::MinEnergyUnderSlo { slo_s: 0.1 }, 1)
            .is_none());
        assert!(c.lookup("a", &s, Objective::MinEdp, 1).is_some());
        // distinct SLOs are distinct keys
        c.insert("a", &s, Objective::MinEnergyUnderSlo { slo_s: 0.1 }, 1, plan("s1"));
        assert!(c
            .lookup("a", &s, Objective::MinEnergyUnderSlo { slo_s: 0.2 }, 1)
            .is_none());
    }

    #[test]
    fn batch_buckets_are_log2_and_key_the_cache() {
        assert_eq!(batch_bucket(0), 1);
        assert_eq!(batch_bucket(1), 1);
        assert_eq!(batch_bucket(2), 2);
        assert_eq!(batch_bucket(3), 3);
        assert_eq!(batch_bucket(4), 3);
        assert_eq!(batch_bucket(5), 4);
        assert_eq!(batch_bucket(8), 4);
        assert_eq!(batch_bucket(9), 5);

        let mut c = PlanCache::new(PlanCacheConfig::default());
        let s = snap(1.497e9, 0.35);
        c.insert("m", &s, Objective::MinEdp, 1, plan("unbatched"));
        // a batched lookup must not alias the unbatched plan …
        assert!(c.lookup("m", &s, Objective::MinEdp, 4).is_none());
        c.insert("m", &s, Objective::MinEdp, 4, plan("b4"));
        // … sizes inside one log₂ bucket share a plan …
        assert_eq!(c.lookup("m", &s, Objective::MinEdp, 3).unwrap().policy, "b4");
        // … and the unbatched entry is untouched
        assert_eq!(
            c.lookup("m", &s, Objective::MinEdp, 1).unwrap().policy,
            "unbatched"
        );
        assert!(c.lookup("m", &s, Objective::MinEdp, 8).is_none());
    }

    #[test]
    fn lru_eviction_at_capacity() {
        let mut c = PlanCache::new(PlanCacheConfig {
            capacity: 2,
            ..Default::default()
        });
        let s1 = snap(0.883e9, 0.1);
        let s2 = snap(1.497e9, 0.1);
        let s3 = snap(2.419e9, 0.1);
        c.insert("m", &s1, Objective::MinEdp, 1, plan("1"));
        c.insert("m", &s2, Objective::MinEdp, 1, plan("2"));
        // touch s1 so s2 becomes the LRU victim
        assert!(c.lookup("m", &s1, Objective::MinEdp, 1).is_some());
        c.insert("m", &s3, Objective::MinEdp, 1, plan("3"));
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats().evictions, 1);
        assert!(c.lookup("m", &s1, Objective::MinEdp, 1).is_some(), "LRU kept");
        assert!(c.lookup("m", &s2, Objective::MinEdp, 1).is_none(), "LRU evicted");
        assert!(c.lookup("m", &s3, Objective::MinEdp, 1).is_some());
    }

    #[test]
    fn reinsert_refreshes_in_place() {
        let mut c = PlanCache::new(PlanCacheConfig {
            capacity: 2,
            ..Default::default()
        });
        let s = snap(1.497e9, 0.35);
        c.insert("m", &s, Objective::MinEdp, 1, plan("old"));
        c.insert("m", &s, Objective::MinEdp, 1, plan("new"));
        assert_eq!(c.len(), 1);
        assert_eq!(c.stats().evictions, 0);
        assert_eq!(c.lookup("m", &s, Objective::MinEdp, 1).unwrap().policy, "new");
    }

    #[test]
    fn capacity_zero_disables() {
        let mut c = PlanCache::new(PlanCacheConfig {
            capacity: 0,
            ..Default::default()
        });
        let s = snap(1.497e9, 0.35);
        c.insert("m", &s, Objective::MinEdp, 1, plan("a"));
        assert!(c.lookup("m", &s, Objective::MinEdp, 1).is_none());
        let st = c.stats();
        assert_eq!((st.hits, st.misses, st.entries), (0, 0, 0));
        assert!(!c.enabled());
    }

    #[test]
    fn clear_preserves_counters() {
        let mut c = PlanCache::new(PlanCacheConfig::default());
        let s = snap(1.497e9, 0.35);
        c.insert("m", &s, Objective::MinEdp, 1, plan("a"));
        let _ = c.lookup("m", &s, Objective::MinEdp, 1);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.stats().hits, 1);
        assert!(c.lookup("m", &s, Objective::MinEdp, 1).is_none());
    }
}
