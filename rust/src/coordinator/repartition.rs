//! Repartition controller: decides *when* to re-plan and *how much* —
//! incremental windows on energy drift (the paper's fast path), full
//! re-solves on regime changes (frequency repin / utilization level
//! shift), with cooldowns and decision-time accounting.

use std::time::Instant;

use crate::graph::ModelGraph;
use crate::partition::incremental::IncrementalRepartitioner;
use crate::partition::{DpScratch, Objective, Plan, Partitioner};
use crate::profiler::CostModel;
use crate::soc::device::Snapshot;

use super::plan_cache::PlanCache;

/// Deterministic virtual decision cost charged to the simulated CPU
/// timeline per operator (re-)solved, seconds. The controller used to
/// charge the *measured wall-clock* solve time into virtual time, which
/// made runs that adopt a re-plan irreproducible across hosts (and across
/// `--threads` values in fleet runs). The timeline now pays this modeled
/// cost — calibrated to the DP's per-op order of magnitude — while the
/// measured wall clock still feeds the reported decision-overhead
/// statistic ([`RepartitionController::mean_decision_s`]).
pub const VIRTUAL_SOLVE_S_PER_OP: f64 = 12e-6;

/// Virtual cost of adopting a cached plan on a regime change (a hash
/// lookup instead of a DP solve), seconds.
pub const VIRTUAL_CACHE_HIT_S: f64 = 2e-6;

/// Why a repartition happened (statistics/logging).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Trigger {
    /// Sustained profiler-residual drift (incremental window re-solve).
    Drift,
    /// Frequency repin / utilization level shift (full re-solve).
    RegimeChange,
}

impl Trigger {
    /// Stable lowercase label used in trace lines and the audit log.
    pub fn name(self) -> &'static str {
        match self {
            Trigger::Drift => "drift",
            Trigger::RegimeChange => "regime-change",
        }
    }
}

/// Controller state + statistics.
pub struct RepartitionController {
    /// Windowed re-solver used on the drift fast path.
    pub incremental: IncrementalRepartitioner,
    /// Minimum ops executed between drift-triggered repartitions.
    pub cooldown_ops: usize,
    /// Minimum predicted relative EDP improvement to adopt a re-plan.
    pub hysteresis: f64,
    ops_since_last: usize,
    evaluations: usize,
    repartitions: usize,
    full_solves: usize,
    decision_time_s: f64,
    last_solve_wall_s: f64,
    // long-lived lattice-DP scratch: steady-state replans allocate nothing
    scratch: DpScratch,
}

impl RepartitionController {
    /// Build a controller around an incremental re-solver.
    pub fn new(incremental: IncrementalRepartitioner, cooldown_ops: usize) -> Self {
        RepartitionController {
            incremental,
            cooldown_ops,
            hysteresis: 0.03,
            ops_since_last: 0,
            evaluations: 0,
            repartitions: 0,
            full_solves: 0,
            decision_time_s: 0.0,
            last_solve_wall_s: 0.0,
            scratch: DpScratch::new(),
        }
    }

    /// Note one executed op (cooldown bookkeeping).
    pub fn tick(&mut self) {
        self.ops_since_last += 1;
    }

    /// Drift fast path: windowed re-solve at the execution frontier.
    /// Returns the patched plan and the deterministic *virtual* decision
    /// time (window ops × [`VIRTUAL_SOLVE_S_PER_OP`]) to charge to the CPU
    /// timeline, or None while cooling down or when the re-solve does not
    /// beat the current plan by at least `hysteresis` (plan-flapping
    /// guard: corrections are noisy, and oscillating placements pay real
    /// transfer costs).
    pub fn on_drift(
        &mut self,
        g: &ModelGraph,
        plan: &Plan,
        frontier: usize,
        model: &dyn CostModel,
        snap: &Snapshot,
        out_cpu: Option<&[f64]>,
    ) -> Option<(Plan, f64)> {
        if self.ops_since_last < self.cooldown_ops {
            return None;
        }
        self.evaluations += 1;
        let t0 = Instant::now();
        let current = self
            .incremental
            .remaining_cost_in(g, plan, frontier, model, snap, out_cpu, &mut self.scratch)
            .ok()?;
        let patched = self
            .incremental
            .repartition_in(g, plan, frontier, model, snap, out_cpu, &mut self.scratch)
            .ok()?;
        self.ops_since_last = 0;
        let wall = t0.elapsed().as_secs_f64();
        self.last_solve_wall_s = wall;
        self.decision_time_s += wall;
        let cur_score = current.energy_j * current.latency_s;
        let new_score = patched.predicted.energy_j * patched.predicted.latency_s;
        if new_score > cur_score * (1.0 - self.hysteresis) {
            return None; // not worth switching
        }
        self.repartitions += 1;
        let solved = self
            .incremental
            .window
            .min(g.num_ops().saturating_sub(frontier))
            .max(1);
        Some((patched, solved as f64 * VIRTUAL_SOLVE_S_PER_OP))
    }

    /// Regime change: adopt a plan for the stream's new condition. With a
    /// [`PlanCache`] wired in, a recurring (model, condition-bucket,
    /// objective) is served from cache — a hash lookup instead of a full DP
    /// solve; a cold condition falls through to the full re-solve and the
    /// result is cached for the next recurrence. The returned seconds are
    /// the deterministic virtual decision cost ([`VIRTUAL_CACHE_HIT_S`]
    /// for a cache hit, model size × [`VIRTUAL_SOLVE_S_PER_OP`] for a full
    /// solve) to charge to the CPU timeline.
    /// `batch_hint` is the batch size planning prices ops at (1 on the
    /// unbatched path); it selects the plan-cache batch bucket so batched
    /// and unbatched plans never alias.
    #[allow(clippy::too_many_arguments)]
    pub fn on_regime_change(
        &mut self,
        g: &ModelGraph,
        policy: &dyn Partitioner,
        model: &dyn CostModel,
        snap: &Snapshot,
        objective: Objective,
        batch_hint: usize,
        mut cache: Option<&mut PlanCache>,
    ) -> Option<(Plan, f64)> {
        let t0 = Instant::now();
        if let Some(cache) = cache.as_deref_mut() {
            if let Some(plan) = cache.lookup(&g.name, snap, objective, batch_hint) {
                self.repartitions += 1;
                let wall = t0.elapsed().as_secs_f64();
                self.last_solve_wall_s = wall;
                self.decision_time_s += wall;
                self.ops_since_last = 0;
                return Some((plan, VIRTUAL_CACHE_HIT_S));
            }
        }
        let plan = policy.partition_in(g, model, snap, &mut self.scratch).ok()?;
        if let Some(cache) = cache {
            cache.insert(&g.name, snap, objective, batch_hint, plan.clone());
        }
        self.full_solves += 1;
        self.repartitions += 1;
        let wall = t0.elapsed().as_secs_f64();
        self.last_solve_wall_s = wall;
        self.decision_time_s += wall;
        self.ops_since_last = 0;
        Some((plan, g.num_ops() as f64 * VIRTUAL_SOLVE_S_PER_OP))
    }

    /// Total adopted re-plans (drift + regime, cached or solved).
    pub fn repartitions(&self) -> usize {
        self.repartitions
    }

    /// Drift triggers that reached a re-solve (adopted or rejected).
    pub fn evaluations(&self) -> usize {
        self.evaluations
    }

    /// Full (non-cached) regime-change solves.
    pub fn full_solves(&self) -> usize {
        self.full_solves
    }

    /// Measured wall-clock time of the most recent decision (drift
    /// evaluation or regime-change solve/lookup), seconds. Telemetry
    /// only — the simulated timeline is always charged the deterministic
    /// virtual cost, never this value.
    pub fn last_solve_wall_s(&self) -> f64 {
        self.last_solve_wall_s
    }

    /// Mean decision time per repartition.
    pub fn mean_decision_s(&self) -> f64 {
        if self.repartitions == 0 {
            0.0
        } else {
            self.decision_time_s / self.repartitions as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::partition::dp::DpPartitioner;
    use crate::partition::plan::Objective;
    use crate::soc::device::{Device, DeviceConfig};
    use crate::soc::Placement;
    use crate::workload::WorkloadCondition;

    fn dev() -> Device {
        let mut d = Device::new(DeviceConfig {
            noise_sigma: 0.0,
            drift_sigma: 0.0,
            ..DeviceConfig::snapdragon_855()
        });
        d.apply_condition(&WorkloadCondition::moderate().spec);
        d
    }

    fn controller(window: usize, cooldown: usize) -> RepartitionController {
        RepartitionController::new(
            IncrementalRepartitioner::new(DpPartitioner::new(Objective::MinEdp), window),
            cooldown,
        )
    }

    #[test]
    fn cooldown_blocks_until_ticks() {
        let g = zoo::yolov2_tiny();
        let d = dev();
        let snap = d.snapshot();
        // an all-CPU plan is far from optimal → the re-solve clears the
        // adoption hysteresis
        let plan = Plan {
            placements: vec![Placement::CPU; g.num_ops()],
            predicted: Default::default(),
            policy: "t".into(),
        };
        let mut c = controller(4, 3);
        assert!(c.on_drift(&g, &plan, 0, &d, &snap, None).is_none());
        c.tick();
        c.tick();
        assert!(c.on_drift(&g, &plan, 0, &d, &snap, None).is_none());
        c.tick();
        assert!(c.on_drift(&g, &plan, 0, &d, &snap, None).is_some());
        assert_eq!(c.repartitions(), 1);
        // cooldown resets
        assert!(c.on_drift(&g, &plan, 0, &d, &snap, None).is_none());
    }

    #[test]
    fn hysteresis_rejects_marginal_replans() {
        let g = zoo::yolov2_tiny();
        let d = dev();
        let snap = d.snapshot();
        // start from the solver's own optimum: the re-solve cannot beat it
        // by the hysteresis margin → no adoption
        let dp = DpPartitioner::new(Objective::MinEdp);
        let opt = dp.solve(&g, &d, &snap).unwrap();
        let mut c = controller(g.num_ops(), 0);
        assert!(c.on_drift(&g, &opt, 0, &d, &snap, None).is_none());
        assert_eq!(c.repartitions(), 0);
    }

    #[test]
    fn regime_change_full_solve_counts() {
        let g = zoo::yolov2_tiny();
        let d = dev();
        let snap = d.snapshot();
        let policy = DpPartitioner::new(Objective::MinEdp);
        let mut c = controller(4, 3);
        let (plan, dt) = c
            .on_regime_change(&g, &policy, &d, &snap, Objective::MinEdp, 1, None)
            .unwrap();
        assert_eq!(plan.placements.len(), g.num_ops());
        // virtual decision cost is deterministic: per-op constant × model
        assert_eq!(dt, g.num_ops() as f64 * VIRTUAL_SOLVE_S_PER_OP);
        assert_eq!(c.full_solves(), 1);
        assert!(c.mean_decision_s() >= 0.0);
    }

    #[test]
    fn regime_change_reuses_cached_plan_for_recurring_condition() {
        use crate::coordinator::plan_cache::{PlanCache, PlanCacheConfig};
        let g = zoo::yolov2_tiny();
        let d = dev();
        let snap = d.snapshot();
        let policy = DpPartitioner::new(Objective::MinEdp);
        let mut c = controller(4, 0);
        let mut cache = PlanCache::new(PlanCacheConfig::default());
        let (first, _) = c
            .on_regime_change(&g, &policy, &d, &snap, Objective::MinEdp, 1, Some(&mut cache))
            .unwrap();
        assert_eq!(c.full_solves(), 1);
        assert_eq!(cache.stats().misses, 1);
        // same condition again: served from cache, no second full solve
        let (second, dt2) = c
            .on_regime_change(&g, &policy, &d, &snap, Objective::MinEdp, 1, Some(&mut cache))
            .unwrap();
        assert_eq!(c.full_solves(), 1, "cache hit must not re-run the DP");
        assert_eq!(dt2, VIRTUAL_CACHE_HIT_S, "cache hits charge the hit cost");
        assert_eq!(c.repartitions(), 2);
        assert_eq!(cache.stats().hits, 1);
        assert_eq!(first.placements, second.placements);
    }
}
