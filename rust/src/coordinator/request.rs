//! Request streams and request lifecycle types.

use std::sync::Arc;

use crate::graph::ModelGraph;
use crate::workload::Arrival;

/// One concurrently-served app: a model plus its arrival process and SLO.
#[derive(Clone)]
pub struct StreamSpec {
    pub id: usize,
    pub model: Arc<ModelGraph>,
    pub arrival: Arrival,
    /// Per-request latency SLO (deadline = arrival + slo).
    pub slo_s: f64,
}

impl StreamSpec {
    pub fn new(id: usize, model: ModelGraph, arrival: Arrival, slo_s: f64) -> Self {
        StreamSpec {
            id,
            model: Arc::new(model),
            arrival,
            slo_s,
        }
    }
}

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: usize,
    pub stream: usize,
    pub arrival_s: f64,
    pub deadline_s: f64,
}

/// Completed-request record.
#[derive(Debug, Clone)]
pub struct RequestOutcome {
    pub request: Request,
    pub start_s: f64,
    pub finish_s: f64,
    pub energy_j: f64,
}

impl RequestOutcome {
    pub fn latency_s(&self) -> f64 {
        self.finish_s - self.request.arrival_s
    }

    pub fn queue_s(&self) -> f64 {
        self.start_s - self.request.arrival_s
    }

    pub fn met_deadline(&self) -> bool {
        self.finish_s <= self.request.deadline_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_math() {
        let o = RequestOutcome {
            request: Request {
                id: 0,
                stream: 0,
                arrival_s: 1.0,
                deadline_s: 1.2,
            },
            start_s: 1.05,
            finish_s: 1.15,
            energy_j: 0.1,
        };
        assert!((o.latency_s() - 0.15).abs() < 1e-12);
        assert!((o.queue_s() - 0.05).abs() < 1e-12);
        assert!(o.met_deadline());
    }
}
