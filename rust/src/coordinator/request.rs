//! Request streams and request lifecycle types.

use std::sync::Arc;

use crate::graph::ModelGraph;
use crate::workload::Arrival;

/// One concurrently-served app: a model plus its arrival process and SLO.
#[derive(Clone)]
pub struct StreamSpec {
    /// Stream identifier (index into the engine's stream list).
    pub id: usize,
    /// The model every request of this stream executes.
    pub model: Arc<ModelGraph>,
    /// Arrival process generating this stream's requests.
    pub arrival: Arrival,
    /// Per-request latency SLO (deadline = arrival + slo).
    pub slo_s: f64,
}

impl StreamSpec {
    /// Build a stream spec, wrapping the model in an [`Arc`].
    pub fn new(id: usize, model: ModelGraph, arrival: Arrival, slo_s: f64) -> Self {
        StreamSpec {
            id,
            model: Arc::new(model),
            arrival,
            slo_s,
        }
    }
}

/// One inference request.
#[derive(Debug, Clone, Copy)]
pub struct Request {
    /// Globally unique request id.
    pub id: usize,
    /// Owning stream id.
    pub stream: usize,
    /// Arrival time (virtual seconds).
    pub arrival_s: f64,
    /// Absolute deadline: arrival + the stream's SLO.
    pub deadline_s: f64,
}

/// Completed-request record.
#[derive(Debug, Clone, Copy)]
pub struct RequestOutcome {
    /// The request this outcome belongs to.
    pub request: Request,
    /// When its first op started executing.
    pub start_s: f64,
    /// When its last op finished.
    pub finish_s: f64,
    /// Dynamic energy attributed to its ops, joules.
    pub energy_j: f64,
}

impl RequestOutcome {
    /// End-to-end latency: finish minus arrival.
    pub fn latency_s(&self) -> f64 {
        self.finish_s - self.request.arrival_s
    }

    /// Queueing delay: time between arrival and first op start.
    pub fn queue_s(&self) -> f64 {
        self.start_s - self.request.arrival_s
    }

    /// Whether the request finished by its deadline.
    pub fn met_deadline(&self) -> bool {
        self.finish_s <= self.request.deadline_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_math() {
        let o = RequestOutcome {
            request: Request {
                id: 0,
                stream: 0,
                arrival_s: 1.0,
                deadline_s: 1.2,
            },
            start_s: 1.05,
            finish_s: 1.15,
            energy_j: 0.1,
        };
        assert!((o.latency_s() - 0.15).abs() < 1e-12);
        assert!((o.queue_s() - 0.05).abs() < 1e-12);
        assert!(o.met_deadline());
    }
}
