//! Pluggable SLO-aware dispatch: scheduler policies and admission control.
//!
//! The serving engine ([`super::engine`]) used to hard-code its dispatch
//! rule (earliest-feasible-start, ties by arrival order — plain FIFO) and
//! admitted every generated request. This module turns both decisions into
//! swappable policies:
//!
//! * [`Scheduler`] — *which* eligible op runs next, and (optionally)
//!   *where* it runs. Implementations: [`Fifo`] (the historical baseline),
//!   [`Edf`] (earliest-deadline-first over the requests that could start
//!   at the earliest feasible time), and [`SlackReclaim`] (EDF ordering
//!   plus an energy-biased placement override that spends a request's
//!   latency slack on the lower-energy processor choice — the paper's
//!   energy/latency decoupling insight applied at dispatch time).
//! * [`AdmissionCtrl`] — *whether* a freshly arrived request enters the
//!   queue at all, per an [`AdmissionPolicy`]: admit everything, shed
//!   requests whose deadline is already infeasible (`drop-late`), or bound
//!   the number of in-flight requests per stream.
//!
//! Adding a policy is two steps: implement [`Scheduler`] (one method,
//! `pick`; override `place` only if the policy moves ops between
//! processors), then add a variant to
//! [`SchedulerKind`](crate::config::schema::SchedulerKind) and map it in
//! [`by_kind`]. `docs/ARCHITECTURE.md` walks through the full lifecycle.

use crate::config::schema::{AdmissionKind, SchedulerKind};
use crate::graph::OpNode;
use crate::profiler::CostModel;
use crate::soc::device::{ExecCtx, Snapshot};
use crate::soc::Placement;

use super::request::Request;

/// Tolerance when comparing candidate start times: candidates within this
/// window of the earliest feasible start are considered simultaneous, so a
/// deadline-driven policy may prefer any of them without idling a
/// processor for a measurable amount of time.
pub const START_EPS_S: f64 = 1e-9;

/// Safety factor applied by [`AdmissionPolicy::DropLate`] on top of its
/// serialized backlog estimate. Predicted per-op costs assume an
/// uncontended device (`ExecCtx::concurrent = false`), carry measurement
/// noise, and chase the hidden drift process only as fast as the engine
/// refreshes its latency profiles (once per monitor period), so the
/// realized finish time of an admitted request can exceed the estimate;
/// inflating the estimate by this fraction keeps the shed decision
/// conservative (admitted requests should meet their deadlines; see
/// `rust/tests/scheduler_admission.rs`).
pub const DROP_LATE_SAFETY: f64 = 0.25;

/// Predicted backlog of admitted work that will still remain when a
/// request actually arrives.
///
/// The admission controller's backlog estimate sums the remaining
/// predicted service time of every admitted-but-unfinished request *as of
/// now*. For a request whose `arrival_s` lies in the future (the engine
/// admits arrivals ahead of the device clock), part of that backlog will
/// have drained before the request shows up; charging the full backlog
/// against its deadline spuriously sheds feasible requests. This
/// discounts the backlog by the work the device can retire between the
/// moment both processors are free (`max(now, max(avail))` — the same
/// serialized bound `est_start` uses, so the credit stays conservative)
/// and the arrival. For a request arriving at or before `now`, or while
/// any processor is still busy past the arrival, the discount is zero
/// and the estimate is unchanged — only genuine idle gaps ahead of a
/// future arrival drain the backlog.
pub fn remaining_backlog_at(
    backlog_s: f64,
    now_s: f64,
    arrival_s: f64,
    avail: &[f64; 2],
) -> f64 {
    let drain_start = now_s.max(avail[0]).max(avail[1]);
    let drained = (now_s.max(arrival_s) - drain_start).max(0.0);
    (backlog_s - drained).max(0.0)
}

/// One dispatchable request as the scheduler sees it: the earliest time
/// its next operator could start, plus the request-level facts
/// (arrival, deadline, predicted remaining work) policies order by.
#[derive(Debug, Clone, Copy)]
pub struct Candidate {
    /// Index into the engine's active-request list.
    pub active_idx: usize,
    /// Earliest feasible start of the request's next op (virtual seconds):
    /// its input-ready time pushed past the availability of every
    /// processor the planned placement touches.
    pub start_s: f64,
    /// Owning request's arrival time.
    pub arrival_s: f64,
    /// Owning request's absolute deadline (arrival + stream SLO).
    pub deadline_s: f64,
    /// Predicted remaining service time under the current plan, from the
    /// next op (inclusive) to the end of the model.
    pub remaining_s: f64,
}

impl Candidate {
    /// Latency slack if the next op starts at `start_s`: time to spare
    /// before the deadline after the predicted remaining work completes.
    /// Negative once the request is predicted to miss.
    pub fn slack_s(&self) -> f64 {
        self.deadline_s - (self.start_s + self.remaining_s)
    }
}

/// A dispatch policy: decides which eligible request runs its next
/// operator, and optionally overrides the plan's placement for that op.
pub trait Scheduler: Send {
    /// Policy name as it appears in reports (`fifo`, `edf`, …).
    fn name(&self) -> &'static str;

    /// Choose the next candidate to dispatch. `candidates` is non-empty;
    /// the returned value is an index into `candidates` (not into the
    /// engine's active list — use [`Candidate::active_idx`] for that).
    fn pick(&self, candidates: &[Candidate]) -> usize;

    /// Placement override hook, called once per dispatched op with the
    /// plan's placement and the owning request's current slack. The
    /// default keeps the plan's choice; [`SlackReclaim`] trades positive
    /// slack for predicted energy savings here. The engine validates the
    /// returned placement against processor availability — an override
    /// that needs a processor still busy at the dispatch time falls back
    /// to the plan's placement instead of double-booking it.
    fn place(
        &self,
        planned: Placement,
        _op: &OpNode,
        _ctx: &ExecCtx,
        _snap: &Snapshot,
        _model: &dyn CostModel,
        _slack_s: f64,
    ) -> Placement {
        planned
    }
}

/// Arrival-order dispatch — the engine's historical behavior: the
/// candidate with the earliest feasible start wins, ties broken by
/// arrival time.
#[derive(Debug, Clone, Copy, Default)]
pub struct Fifo;

impl Scheduler for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn pick(&self, candidates: &[Candidate]) -> usize {
        let mut best = 0;
        for (i, c) in candidates.iter().enumerate().skip(1) {
            let b = &candidates[best];
            if c.start_s < b.start_s
                || (c.start_s == b.start_s && c.arrival_s < b.arrival_s)
            {
                best = i;
            }
        }
        best
    }
}

/// Non-idling earliest-deadline-first pick: among the candidates that can
/// start at the earliest feasible time (within [`START_EPS_S`]), choose
/// the tightest deadline; ties fall back to arrival order. Restricting
/// the deadline comparison to earliest-start candidates keeps processors
/// from idling while an urgent request waits on its inputs.
fn edf_pick(candidates: &[Candidate]) -> usize {
    let min_start = candidates
        .iter()
        .map(|c| c.start_s)
        .fold(f64::INFINITY, f64::min);
    let mut best: Option<usize> = None;
    for (i, c) in candidates.iter().enumerate() {
        if c.start_s > min_start + START_EPS_S {
            continue;
        }
        let better = match best {
            None => true,
            Some(b) => {
                let bb = &candidates[b];
                c.deadline_s < bb.deadline_s
                    || (c.deadline_s == bb.deadline_s && c.arrival_s < bb.arrival_s)
            }
        };
        if better {
            best = Some(i);
        }
    }
    best.unwrap_or(0)
}

/// Earliest-deadline-first dispatch over eligible ops, keyed by the owning
/// request's absolute deadline. Under contention (several requests waiting
/// on the same processor) the tightest deadline runs first; placement
/// follows the partition plan unchanged.
#[derive(Debug, Clone, Copy, Default)]
pub struct Edf;

impl Scheduler for Edf {
    fn name(&self) -> &'static str {
        "edf"
    }

    fn pick(&self, candidates: &[Candidate]) -> usize {
        edf_pick(candidates)
    }
}

/// EDF ordering plus energy slack reclamation: when the owning request has
/// latency slack relative to its SLO, the op may move from the plan's
/// placement to a single-processor placement the cost model predicts to be
/// cheaper in energy, as long as the added latency fits inside a bounded
/// fraction of the slack. Requests with no slack execute exactly like
/// [`Edf`], so responsiveness is never traded away — only surplus latency
/// headroom is converted back into energy savings.
#[derive(Debug, Clone, Copy)]
pub struct SlackReclaim {
    /// Fraction of the current slack a single op may spend on a slower,
    /// lower-energy placement. Keeping this below 1 leaves headroom for
    /// later ops of the same request (and for prediction error).
    pub slack_budget_frac: f64,
    /// Minimum relative predicted-energy saving that justifies deviating
    /// from the plan; filters noise-level "wins" that would churn
    /// placements (and pay real transfer costs) for nothing.
    pub min_energy_gain: f64,
}

impl Default for SlackReclaim {
    fn default() -> Self {
        SlackReclaim {
            slack_budget_frac: 0.5,
            min_energy_gain: 0.02,
        }
    }
}

impl Scheduler for SlackReclaim {
    fn name(&self) -> &'static str {
        "slack-reclaim"
    }

    fn pick(&self, candidates: &[Candidate]) -> usize {
        edf_pick(candidates)
    }

    fn place(
        &self,
        planned: Placement,
        op: &OpNode,
        ctx: &ExecCtx,
        snap: &Snapshot,
        model: &dyn CostModel,
        slack_s: f64,
    ) -> Placement {
        if slack_s <= 0.0 {
            return planned;
        }
        let base = model.predict(op, planned, ctx, snap);
        let budget_s = slack_s * self.slack_budget_frac;
        let mut best = planned;
        let mut best_e = base.energy_j * (1.0 - self.min_energy_gain);
        for alt in [Placement::CPU, Placement::GPU] {
            if alt == planned {
                continue;
            }
            let c = model.predict(op, alt, ctx, snap);
            if c.latency_s - base.latency_s <= budget_s && c.energy_j < best_e {
                best = alt;
                best_e = c.energy_j;
            }
        }
        best
    }
}

/// Build the scheduler implementation for a configured
/// [`SchedulerKind`].
pub fn by_kind(kind: SchedulerKind) -> Box<dyn Scheduler + Send + Sync> {
    match kind {
        SchedulerKind::Fifo => Box::new(Fifo),
        SchedulerKind::Edf => Box::new(Edf),
        SchedulerKind::SlackReclaim => Box::new(SlackReclaim::default()),
    }
}

/// Admission policy applied in front of the engine's queue, deciding per
/// arrival whether the request enters the system at all.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Admit every generated request (the baseline; queues grow without
    /// bound past saturation).
    AdmitAll,
    /// Shed requests whose deadline is already infeasible: a request is
    /// rejected when its earliest start plus the predicted backlog of
    /// admitted work plus its own predicted service time — inflated by
    /// [`DROP_LATE_SAFETY`] — lands past its deadline. Conservative by
    /// construction: the backlog estimate serializes work that actually
    /// overlaps across CPU and GPU.
    DropLate,
    /// Bound the number of admitted-but-unfinished requests per stream;
    /// arrivals beyond the bound are dropped.
    Bounded {
        /// Maximum in-flight (queued + executing) requests per stream.
        per_stream: usize,
    },
}

impl AdmissionPolicy {
    /// Build the policy for a configured [`AdmissionKind`] plus the
    /// per-stream queue bound (only meaningful for `Bounded`).
    pub fn from_kind(kind: AdmissionKind, queue_limit: usize) -> AdmissionPolicy {
        match kind {
            AdmissionKind::AdmitAll => AdmissionPolicy::AdmitAll,
            AdmissionKind::DropLate => AdmissionPolicy::DropLate,
            AdmissionKind::Bounded => AdmissionPolicy::Bounded {
                per_stream: queue_limit.max(1),
            },
        }
    }

    /// Policy name as it appears in reports.
    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::AdmitAll => "admit-all",
            AdmissionPolicy::DropLate => "drop-late",
            AdmissionPolicy::Bounded { .. } => "bounded",
        }
    }
}

/// Counters the admission controller accumulates over one serving run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AdmissionCounters {
    /// Requests the arrival processes generated.
    pub offered: usize,
    /// Requests accepted into the queue.
    pub admitted: usize,
    /// Requests shed because their deadline was predicted infeasible.
    pub shed_late: usize,
    /// Requests dropped because the per-stream bound was full.
    pub dropped_capacity: usize,
}

/// Stateful admission controller: one per serving run, applying an
/// [`AdmissionPolicy`] and counting outcomes.
#[derive(Debug, Clone)]
pub struct AdmissionCtrl {
    policy: AdmissionPolicy,
    counters: AdmissionCounters,
}

impl AdmissionCtrl {
    /// Create a controller with zeroed counters.
    pub fn new(policy: AdmissionPolicy) -> AdmissionCtrl {
        AdmissionCtrl {
            policy,
            counters: AdmissionCounters::default(),
        }
    }

    /// The policy this controller applies.
    pub fn policy(&self) -> AdmissionPolicy {
        self.policy
    }

    /// Counter snapshot.
    pub fn counters(&self) -> AdmissionCounters {
        self.counters
    }

    /// Decide admission for one arrival. `est_start_s` is the earliest
    /// time the request's first op could start (arrival pushed past the
    /// current processor availability), `backlog_s` the predicted
    /// remaining service time summed over every admitted-but-unfinished
    /// request, `service_s` the request's own predicted end-to-end service
    /// time under its stream's current plan, and `in_stream` the number of
    /// admitted-but-unfinished requests of the same stream.
    pub fn admit(
        &mut self,
        req: &Request,
        est_start_s: f64,
        backlog_s: f64,
        service_s: f64,
        in_stream: usize,
    ) -> bool {
        self.counters.offered += 1;
        let ok = match self.policy {
            AdmissionPolicy::AdmitAll => true,
            AdmissionPolicy::DropLate => {
                let predicted_finish =
                    est_start_s + (backlog_s + service_s) * (1.0 + DROP_LATE_SAFETY);
                if predicted_finish > req.deadline_s {
                    self.counters.shed_late += 1;
                    false
                } else {
                    true
                }
            }
            AdmissionPolicy::Bounded { per_stream } => {
                if in_stream >= per_stream {
                    self.counters.dropped_capacity += 1;
                    false
                } else {
                    true
                }
            }
        };
        if ok {
            self.counters.admitted += 1;
        }
        ok
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(idx: usize, start: f64, arrival: f64, deadline: f64) -> Candidate {
        Candidate {
            active_idx: idx,
            start_s: start,
            arrival_s: arrival,
            deadline_s: deadline,
            remaining_s: 0.05,
        }
    }

    fn req(arrival: f64, deadline: f64) -> Request {
        Request {
            id: 0,
            stream: 0,
            arrival_s: arrival,
            deadline_s: deadline,
        }
    }

    #[test]
    fn fifo_preserves_arrival_order_under_contention() {
        // both requests blocked on the same processor → same start
        let c = vec![cand(0, 1.0, 0.9, 1.2), cand(1, 1.0, 0.2, 5.0)];
        assert_eq!(Fifo.pick(&c), 1, "earlier arrival wins the tie");
        // a strictly earlier start always wins regardless of arrival
        let c = vec![cand(0, 0.5, 0.9, 1.2), cand(1, 1.0, 0.2, 5.0)];
        assert_eq!(Fifo.pick(&c), 0);
    }

    #[test]
    fn edf_picks_tighter_deadline_under_contention() {
        // same start (contended processor): the later arrival with the
        // tighter deadline must win under EDF, and lose under FIFO
        let c = vec![cand(0, 1.0, 0.2, 5.0), cand(1, 1.0, 0.9, 1.2)];
        assert_eq!(Edf.pick(&c), 1);
        assert_eq!(Fifo.pick(&c), 0);
    }

    #[test]
    fn edf_does_not_idle_for_a_tight_deadline() {
        // the tight-deadline request cannot start until 2.0; the loose one
        // can run now — EDF must not hold the processor idle
        let c = vec![cand(0, 0.5, 0.1, 9.0), cand(1, 2.0, 0.2, 2.5)];
        assert_eq!(Edf.pick(&c), 0);
    }

    #[test]
    fn edf_ties_fall_back_to_arrival() {
        let c = vec![cand(0, 1.0, 0.4, 2.0), cand(1, 1.0, 0.3, 2.0)];
        assert_eq!(Edf.pick(&c), 1);
    }

    #[test]
    fn slack_reclaim_picks_like_edf() {
        let c = vec![cand(0, 1.0, 0.2, 5.0), cand(1, 1.0, 0.9, 1.2)];
        assert_eq!(SlackReclaim::default().pick(&c), Edf.pick(&c));
    }

    #[test]
    fn candidate_slack() {
        let c = cand(0, 1.0, 0.5, 1.2);
        // deadline 1.2 - (start 1.0 + remaining 0.05)
        assert!((c.slack_s() - 0.15).abs() < 1e-12);
    }

    #[test]
    fn admit_all_admits_everything() {
        let mut ctrl = AdmissionCtrl::new(AdmissionPolicy::AdmitAll);
        for i in 0..5 {
            assert!(ctrl.admit(&req(i as f64, i as f64 + 0.1), i as f64, 10.0, 1.0, i));
        }
        let c = ctrl.counters();
        assert_eq!((c.offered, c.admitted), (5, 5));
        assert_eq!(c.shed_late + c.dropped_capacity, 0);
    }

    #[test]
    fn drop_late_sheds_infeasible_deadlines() {
        let mut ctrl = AdmissionCtrl::new(AdmissionPolicy::DropLate);
        // plenty of headroom → admitted
        assert!(ctrl.admit(&req(0.0, 10.0), 0.0, 0.5, 0.1, 0));
        // backlog alone already passes the deadline → shed
        assert!(!ctrl.admit(&req(1.0, 1.2), 1.0, 5.0, 0.1, 1));
        // the safety inflation matters near the edge:
        // 1.0 + (0.9 + 0.1) * (1 + DROP_LATE_SAFETY) = 2.25 > 2.1
        assert!(!ctrl.admit(&req(1.0, 2.1), 1.0, 0.9, 0.1, 1));
        let c = ctrl.counters();
        assert_eq!((c.offered, c.admitted, c.shed_late), (3, 1, 2));
    }

    #[test]
    fn bounded_enforces_per_stream_limit() {
        let mut ctrl = AdmissionCtrl::new(AdmissionPolicy::Bounded { per_stream: 2 });
        assert!(ctrl.admit(&req(0.0, 1.0), 0.0, 0.0, 0.1, 0));
        assert!(ctrl.admit(&req(0.1, 1.1), 0.1, 0.1, 0.1, 1));
        assert!(!ctrl.admit(&req(0.2, 1.2), 0.2, 0.2, 0.1, 2));
        let c = ctrl.counters();
        assert_eq!((c.admitted, c.dropped_capacity), (2, 1));
    }

    #[test]
    fn future_arrival_backlog_drains_before_it() {
        // 0.5 s of backlog at now = 1.0 with both processors free at 1.0;
        // the request arrives at 10.0 — the backlog is long gone by then
        let raw = 0.5;
        let avail = [1.0, 1.0];
        assert_eq!(remaining_backlog_at(raw, 1.0, 10.0, &avail), 0.0);
        // partially drained: only 0.2 s fits before a 1.2 s arrival
        let drained = remaining_backlog_at(raw, 1.0, 1.2, &avail);
        assert!((drained - 0.3).abs() < 1e-12, "{drained}");
        // arrival at or before now: estimate unchanged (no time to drain)
        assert_eq!(remaining_backlog_at(raw, 1.0, 1.0, &avail), raw);
        assert_eq!(remaining_backlog_at(raw, 1.0, 0.5, &avail), raw);
        // drain only starts once a processor frees up
        assert_eq!(remaining_backlog_at(raw, 1.0, 1.2, &[1.2, 1.3]), raw);
    }

    #[test]
    fn future_arrival_not_spuriously_shed_regression() {
        // regression for the drop-late skew: a future-arriving request
        // whose backlog fully drains before its arrival must be admitted
        let mut ctrl = AdmissionCtrl::new(AdmissionPolicy::DropLate);
        let raw_backlog = 0.5;
        let avail = [1.0, 1.0];
        let backlog = remaining_backlog_at(raw_backlog, 1.0, 10.0, &avail);
        let est_start = 10.0; // arrival dominates now and avail
        assert!(
            ctrl.admit(&req(10.0, 10.5), est_start, backlog, 0.2, 1),
            "drained backlog must not shed a feasible future request"
        );
        // the pre-fix inputs (undrained backlog) shed the same request:
        // 10.0 + (0.5 + 0.2) * 1.25 = 10.875 > 10.5
        assert!(!ctrl.admit(&req(10.0, 10.5), est_start, raw_backlog, 0.2, 1));
        let c = ctrl.counters();
        assert_eq!((c.admitted, c.shed_late), (1, 1));
    }

    #[test]
    fn from_kind_maps_and_clamps() {
        use crate::config::schema::AdmissionKind;
        assert_eq!(
            AdmissionPolicy::from_kind(AdmissionKind::AdmitAll, 0),
            AdmissionPolicy::AdmitAll
        );
        assert_eq!(
            AdmissionPolicy::from_kind(AdmissionKind::DropLate, 0),
            AdmissionPolicy::DropLate
        );
        assert_eq!(
            AdmissionPolicy::from_kind(AdmissionKind::Bounded, 0),
            AdmissionPolicy::Bounded { per_stream: 1 }
        );
    }

    #[test]
    fn by_kind_names_round_trip() {
        use crate::config::schema::SchedulerKind;
        for k in SchedulerKind::all() {
            assert_eq!(by_kind(k).name(), k.name());
        }
    }
}
