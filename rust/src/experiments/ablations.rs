//! Ablation experiments A1–A5 (DESIGN.md §7): each design decision the
//! poster calls out gets a bench that isolates it.

use std::time::Instant;

use anyhow::Result;

use crate::config::schema::{ConditionKind, PolicyKind};
use crate::coordinator::{Engine, EngineConfig, StreamSpec};
use crate::graph::graph::{GraphBuilder, Src};
use crate::graph::op::{ActKind, OpKind};
use crate::graph::{zoo, ModelGraph, Shape};
use crate::partition::baselines::GreedyEnergyPartitioner;
use crate::partition::codl::CodlPartitioner;
use crate::partition::dp::DpPartitioner;
use crate::partition::exhaustive::ExhaustivePartitioner;
use crate::partition::incremental::IncrementalRepartitioner;
use crate::partition::plan::{evaluate, Objective, Partitioner, INPUT_CPU_FRAC};
use crate::profiler::calibrate::{calibrate, CalibConfig};
use crate::profiler::corrector::{Corrector, EwmaCorrector};
use crate::profiler::{CostModel, EnergyProfiler};
use crate::soc::device::{Device, DeviceConfig, ExecCtx};
use crate::soc::Placement;
use crate::workload::trace::ConditionTrace;
use crate::workload::{Arrival, WorkloadCondition};

// ---------------------------------------------------------------------------
// A1 — profiler accuracy under dynamic conditions
// ---------------------------------------------------------------------------

/// One predictor arm's accuracy over the drift trace.
#[derive(Debug, Clone)]
pub struct ProfilerAccuracyRow {
    /// Predictor arm name (`gbdt-only`, `gbdt+ewma`, `gbdt+gru`).
    pub arm: String,
    /// Mean absolute percentage error of per-op energy predictions.
    pub energy_mape: f64,
    /// Mean absolute percentage error of per-op latency predictions.
    pub latency_mape: f64,
    /// Observations in the trace.
    pub observations: usize,
}

/// A1: drive the device through idle→moderate→high→moderate and compare
/// predictor arms on per-op energy/latency error. `gru` optionally wires a
/// corrector factory (the real AOT artifact when present).
pub fn profiler_accuracy(
    calib: &CalibConfig,
    seg_s: f64,
    seed: u64,
    gru: Option<Box<dyn FnMut() -> Box<dyn Corrector>>>,
) -> Result<Vec<ProfilerAccuracyRow>> {
    let offline = calibrate(calib);
    let mut arms: Vec<(String, EnergyProfiler)> = vec![
        (
            "gbdt-only".into(),
            EnergyProfiler::offline_only(offline.clone()),
        ),
        (
            "gbdt+ewma".into(),
            EnergyProfiler::with_correctors(offline.clone(), || {
                Box::new(EwmaCorrector::default())
            }),
        ),
    ];
    if let Some(mut make) = gru {
        arms.push((
            "gbdt+gru".into(),
            EnergyProfiler::with_correctors(offline.clone(), &mut *make),
        ));
    }

    let trace = ConditionTrace::stairs(seg_s);
    let g = zoo::yolov2();
    let mut rows = Vec::new();
    for (name, mut prof) in arms {
        let mut dev = Device::new(DeviceConfig {
            seed,
            ..DeviceConfig::snapdragon_855()
        });
        let mut phase = usize::MAX;
        let mut abs_e = Vec::new();
        let mut abs_l = Vec::new();
        let mut op_i = 0usize;
        while dev.time_s() < trace.total_duration_s() {
            // apply the trace's condition when the phase changes
            let want = trace.at(dev.time_s());
            let cur = trace
                .phases
                .iter()
                .position(|p| std::ptr::eq(&p.condition, want))
                .unwrap_or(0);
            if cur != phase {
                dev.apply_condition(&want.spec);
                phase = cur;
            }
            let op = &g.ops[op_i % g.num_ops()];
            op_i += 1;
            let mut ctx = ExecCtx::fresh(vec![0.0; op.in_shapes.len()]);
            ctx.new_run_cpu = false;
            ctx.new_run_gpu = false;
            let snap = dev.snapshot();
            let pred = prof.predict(op, Placement::GPU, &ctx, &snap);
            let truth = dev.measure(op, Placement::GPU, &ctx);
            abs_e.push(((pred.energy_j - truth.energy_j) / truth.energy_j).abs());
            abs_l.push(((pred.latency_s - truth.latency_s) / truth.latency_s).abs());
            prof.observe(op, Placement::GPU, &ctx, &snap, &truth);
            dev.advance(truth.latency_s, 0.0, 1.0);
        }
        rows.push(ProfilerAccuracyRow {
            arm: name,
            energy_mape: abs_e.iter().sum::<f64>() / abs_e.len() as f64 * 100.0,
            latency_mape: abs_l.iter().sum::<f64>() / abs_l.len() as f64 * 100.0,
            observations: abs_e.len(),
        });
    }
    Ok(rows)
}

// ---------------------------------------------------------------------------
// A2 — DP optimality + decision runtime
// ---------------------------------------------------------------------------

/// One (case, policy) cell of the DP-vs-exhaustive comparison.
#[derive(Debug, Clone)]
pub struct DpComparisonRow {
    /// Case label (`<graph>/<policy>`).
    pub case: String,
    /// Objective score achieved (lower = better).
    pub score: f64,
    /// Score relative to the best policy in the case (1.0 = optimal).
    pub relative: f64,
    /// Solve time, microseconds.
    pub solve_us: f64,
}

/// A small random conv chain for exhaustive-vs-DP checks.
pub fn random_chain(n: usize, seed: u64) -> ModelGraph {
    let mut rng = crate::util::Prng::new(seed);
    let mut b = GraphBuilder::new("chain", Shape::nchw(1, 8, 32, 32));
    let mut prev = Src::Input;
    for i in 0..n {
        let oc = [8usize, 16, 24, 32][rng.below(4)];
        let k = if rng.chance(0.3) { 1 } else { 3 };
        let id = b.push(
            &format!("c{i}"),
            OpKind::Conv2d {
                kernel: k,
                stride: 1,
                pad: k / 2,
                out_c: oc,
                groups: 1,
                act: ActKind::Relu,
            },
            &[prev],
        );
        prev = Src::Op(id);
    }
    b.build()
}

/// A2: exhaustive vs DP vs greedy vs CoDL on a small chain (exact check),
/// plus DP runtime on the full zoo.
pub fn dp_comparison(seed: u64) -> Result<Vec<DpComparisonRow>> {
    let mut dev = Device::new(DeviceConfig {
        noise_sigma: 0.0,
        drift_sigma: 0.0,
        ..DeviceConfig::snapdragon_855()
    });
    let mut spec = WorkloadCondition::moderate().spec;
    spec.cpu_bg_sigma = 0.0;
    spec.cpu_burst = 0.0;
    spec.gpu_bg_sigma = 0.0;
    spec.gpu_burst = 0.0;
    spec.drift_sigma = 0.0;
    dev.apply_condition(&spec);
    let snap = dev.snapshot();
    let obj = Objective::MinEdp;
    let choices = vec![
        Placement::CPU,
        Placement::GPU,
        Placement::Split { cpu_frac: 0.15 },
    ];

    let mut rows = Vec::new();
    let g = random_chain(8, seed);

    let mut run = |name: &str, plan: Result<crate::partition::Plan>, t_us: f64| {
        if let Ok(plan) = plan {
            let c = evaluate(&g, &plan.placements, &dev, &snap);
            rows.push(DpComparisonRow {
                case: format!("chain8/{name}"),
                score: obj.score(c.energy_j, c.latency_s),
                relative: 0.0, // filled below
                solve_us: t_us,
            });
        }
    };

    let t0 = Instant::now();
    let ex = ExhaustivePartitioner::new(obj, choices.clone()).partition(&g, &dev, &snap);
    run("exhaustive", ex, t0.elapsed().as_secs_f64() * 1e6);
    let t0 = Instant::now();
    let dp = DpPartitioner::new(obj)
        .with_choices(choices.clone())
        .partition(&g, &dev, &snap);
    run("dp", dp, t0.elapsed().as_secs_f64() * 1e6);
    let t0 = Instant::now();
    let gr = GreedyEnergyPartitioner::default().partition(&g, &dev, &snap);
    run("greedy", gr, t0.elapsed().as_secs_f64() * 1e6);
    let t0 = Instant::now();
    let cd = CodlPartitioner::default().partition(&g, &dev, &snap);
    run("codl", cd, t0.elapsed().as_secs_f64() * 1e6);

    let best = rows
        .iter()
        .map(|r| r.score)
        .fold(f64::INFINITY, f64::min);
    for r in &mut rows {
        r.relative = r.score / best;
    }

    // DP runtime across the zoo + latency-bucket pruning ablation (A6)
    for name in zoo::names() {
        let g = zoo::by_name(name).unwrap();
        for buckets in [4usize, 64, 256] {
            let dp = DpPartitioner::new(obj).with_buckets(buckets);
            let t0 = Instant::now();
            let plan = dp.partition(&g, &dev, &snap)?;
            let us = t0.elapsed().as_secs_f64() * 1e6;
            let c = evaluate(&g, &plan.placements, &dev, &snap);
            rows.push(DpComparisonRow {
                case: format!("{name}/dp-b{buckets}"),
                score: obj.score(c.energy_j, c.latency_s),
                relative: 1.0,
                solve_us: us,
            });
        }
    }
    Ok(rows)
}

// ---------------------------------------------------------------------------
// A3 — incremental vs full repartitioning
// ---------------------------------------------------------------------------

/// One windowed-vs-full repartition scheme's cost/quality point.
#[derive(Debug, Clone)]
pub struct IncrementalRow {
    /// Scheme label (`full` or `window=N`).
    pub scheme: String,
    /// Decision time, microseconds.
    pub decision_us: f64,
    /// EDP of the repaired plan over the remaining ops, relative to the
    /// full re-solve (1.0 = matches full quality).
    pub edp_vs_full: f64,
}

/// A3: a plan made under moderate goes stale when the device switches to
/// high; compare full re-solve vs windowed repairs at frontier 10.
pub fn incremental_vs_full(windows: &[usize]) -> Result<Vec<IncrementalRow>> {
    let frozen = |cond: WorkloadCondition| {
        let mut d = Device::new(DeviceConfig {
            noise_sigma: 0.0,
            drift_sigma: 0.0,
            ..DeviceConfig::snapdragon_855()
        });
        let mut c = cond.spec;
        c.cpu_bg_sigma = 0.0;
        c.cpu_burst = 0.0;
        c.gpu_bg_sigma = 0.0;
        c.gpu_burst = 0.0;
        c.drift_sigma = 0.0;
        d.apply_condition(&c);
        d
    };
    let g = zoo::yolov2();
    let frontier = 10usize;
    let dp = DpPartitioner::new(Objective::MinEdp);
    let d_mod = frozen(WorkloadCondition::moderate());
    let stale = dp.solve(&g, &d_mod, &d_mod.snapshot())?;
    let d_high = frozen(WorkloadCondition::high());
    let snap = d_high.snapshot();

    // tail-only evaluator (cost from `frontier` on)
    let tail_cost = |placements: &[Placement]| {
        let inc = IncrementalRepartitioner::new(dp.clone(), 1);
        let plan = crate::partition::Plan {
            placements: placements.to_vec(),
            predicted: Default::default(),
            policy: "eval".into(),
        };
        inc.remaining_cost(&g, &plan, frontier, &d_high, &snap, None)
            .unwrap()
    };

    // full re-solve of everything from the frontier
    let t0 = Instant::now();
    let full = dp.solve_range(&g, &d_high, &snap, frontier, g.num_ops(), &stale.placements, None)?;
    let full_us = t0.elapsed().as_secs_f64() * 1e6;
    let full_edp = {
        let c = tail_cost(&full.placements);
        c.energy_j * c.latency_s
    };

    let mut rows = vec![IncrementalRow {
        scheme: "full".into(),
        decision_us: full_us,
        edp_vs_full: 1.0,
    }];
    let stale_c = tail_cost(&stale.placements);
    rows.push(IncrementalRow {
        scheme: "stale (no repair)".into(),
        decision_us: 0.0,
        edp_vs_full: stale_c.energy_j * stale_c.latency_s / full_edp,
    });
    for &w in windows {
        let inc = IncrementalRepartitioner::new(dp.clone(), w);
        let t0 = Instant::now();
        let patched = inc.repartition(&g, &stale, frontier, &d_high, &snap, None)?;
        let us = t0.elapsed().as_secs_f64() * 1e6;
        let c = tail_cost(&patched.placements);
        rows.push(IncrementalRow {
            scheme: format!("window-{w}"),
            decision_us: us,
            edp_vs_full: c.energy_j * c.latency_s / full_edp,
        });
    }
    Ok(rows)
}

// ---------------------------------------------------------------------------
// A4 — responsiveness across a condition switch
// ---------------------------------------------------------------------------

/// One policy's adaptation behaviour across the condition switch.
#[derive(Debug, Clone)]
pub struct ResponsivenessRow {
    /// Policy under test.
    pub policy: PolicyKind,
    /// Mean latency in the 2 s after the moderate→high switch.
    pub post_switch_ms: f64,
    /// Steady-state mean latency in high (after adaptation).
    pub steady_high_ms: f64,
    /// Adaptation overshoot: post-switch / steady.
    pub overshoot: f64,
    /// Repartitions adopted during the run.
    pub repartitions: usize,
}

/// A4: closed-loop serving across a moderate→high switch; how fast does
/// each policy's latency settle to its steady-state-high level?
pub fn responsiveness(calib: &CalibConfig, seed: u64) -> Result<Vec<ResponsivenessRow>> {
    let mut rows = Vec::new();
    for policy in [PolicyKind::MaceGpu, PolicyKind::Codl, PolicyKind::AdaOper] {
        let mut engine = Engine::new(EngineConfig {
            policy,
            condition: ConditionKind::Moderate,
            seed,
            calib: calib.clone(),
            ..Default::default()
        });
        let spec = StreamSpec::new(
            0,
            zoo::yolov2(),
            Arrival::Periodic { hz: 30.0, jitter: 0.0 },
            0.5,
        );
        // phase 1: settle under moderate
        let _ = engine.run_closed_loop(&spec, 10)?;
        // switch — the monitor must notice and the controller re-plan
        engine.apply_condition(&WorkloadCondition::high());
        let r_post = engine.run_closed_loop(&spec, 8)?;
        let r_steady = engine.run_closed_loop(&spec, 20)?;
        let post = r_post.latency.as_ref().map(|l| l.mean).unwrap_or(f64::NAN);
        let steady = r_steady.latency.as_ref().map(|l| l.mean).unwrap_or(f64::NAN);
        rows.push(ResponsivenessRow {
            policy,
            post_switch_ms: post * 1e3,
            steady_high_ms: steady * 1e3,
            overshoot: post / steady,
            repartitions: r_post.repartitions + r_steady.repartitions,
        });
    }
    Ok(rows)
}

// ---------------------------------------------------------------------------
// A5 — concurrency scaling
// ---------------------------------------------------------------------------

/// One (policy, stream-count) cell of the concurrency scaling sweep.
#[derive(Debug, Clone)]
pub struct ConcurrencyRow {
    /// Policy under test.
    pub policy: PolicyKind,
    /// Concurrent app streams served.
    pub streams: usize,
    /// Completed requests per second.
    pub throughput_hz: f64,
    /// 95th-percentile latency, milliseconds.
    pub p95_ms: f64,
    /// Energy per inference, millijoules.
    pub mj_per_inf: f64,
    /// Deadline-miss rate.
    pub miss_rate: f64,
}

/// A5: 1–4 concurrent app streams (different models), open loop.
pub fn concurrency_scaling(
    calib: &CalibConfig,
    seed: u64,
    duration_s: f64,
) -> Result<Vec<ConcurrencyRow>> {
    let zoo_mix: [&str; 4] = ["yolov2-tiny", "mobilenetv1", "resnet18", "yolov2"];
    let mut rows = Vec::new();
    for policy in [PolicyKind::MaceGpu, PolicyKind::Codl, PolicyKind::AdaOper] {
        for k in 1..=4usize {
            let mut engine = Engine::new(EngineConfig {
                policy,
                condition: ConditionKind::Moderate,
                duration_s,
                seed,
                calib: calib.clone(),
                ..Default::default()
            });
            let streams: Vec<StreamSpec> = (0..k)
                .map(|i| {
                    StreamSpec::new(
                        i,
                        zoo::by_name(zoo_mix[i]).unwrap(),
                        Arrival::Poisson { hz: 3.0 },
                        0.6,
                    )
                })
                .collect();
            let r = engine.run(&streams)?;
            rows.push(ConcurrencyRow {
                policy,
                streams: k,
                throughput_hz: r.throughput_hz,
                p95_ms: r
                    .latency
                    .as_ref()
                    .map(|l| l.p90 * 1e3)
                    .unwrap_or(f64::NAN),
                mj_per_inf: r.j_per_inference * 1e3,
                miss_rate: r.miss_rate,
            });
        }
    }
    Ok(rows)
}

// shared: initial residency helper referenced by doc examples
#[allow(dead_code)]
fn input_residency() -> f64 {
    INPUT_CPU_FRAC
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::gbdt::GbdtParams;

    fn small_calib() -> CalibConfig {
        CalibConfig {
            samples: 1500,
            seed: 3,
            gbdt: GbdtParams {
                trees: 50,
                ..Default::default()
            },
        }
    }

    #[test]
    fn a1_correction_beats_gbdt_only() {
        let rows = profiler_accuracy(&small_calib(), 2.0, 11, None).unwrap();
        let gbdt = rows.iter().find(|r| r.arm == "gbdt-only").unwrap();
        let ewma = rows.iter().find(|r| r.arm == "gbdt+ewma").unwrap();
        assert!(gbdt.observations > 50);
        assert!(
            ewma.energy_mape < gbdt.energy_mape,
            "ewma {} vs gbdt {}",
            ewma.energy_mape,
            gbdt.energy_mape
        );
    }

    #[test]
    fn a2_dp_matches_exhaustive() {
        let rows = dp_comparison(5).unwrap();
        let dp = rows.iter().find(|r| r.case == "chain8/dp").unwrap();
        let ex = rows.iter().find(|r| r.case == "chain8/exhaustive").unwrap();
        assert!(
            dp.score <= ex.score * 1.0001,
            "dp {} vs exhaustive {}",
            dp.score,
            ex.score
        );
        // and the DP is orders of magnitude faster
        assert!(dp.solve_us < ex.solve_us);
    }

    #[test]
    fn a3_window_quality_improves_with_size() {
        let rows = incremental_vs_full(&[4, 16]).unwrap();
        let stale = rows.iter().find(|r| r.scheme.starts_with("stale")).unwrap();
        let w16 = rows.iter().find(|r| r.scheme == "window-16").unwrap();
        // repairing must not be worse than doing nothing
        assert!(w16.edp_vs_full <= stale.edp_vs_full * 1.0001);
        // windowed decisions are cheaper than the full solve
        let full = rows.iter().find(|r| r.scheme == "full").unwrap();
        let w4 = rows.iter().find(|r| r.scheme == "window-4").unwrap();
        assert!(w4.decision_us < full.decision_us);
    }
}
