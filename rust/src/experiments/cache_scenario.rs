//! Plan-cache scenario: a bursty multi-model trace with *recurring* device
//! conditions — the workload shape the partition-plan cache exists for.
//!
//! Two app streams (YOLOv2-tiny video detection + MobileNetV1
//! classification) are served closed-loop while the device bounces between
//! the paper's moderate and high workload conditions, cycle after cycle.
//! Every condition switch triggers a regime-change re-plan; without the
//! cache each one re-runs the DP from scratch even though only four
//! (model × condition) combinations ever occur. With the cache, the first
//! cycle populates those buckets and every later repartition is a hash
//! lookup — the measured hit rate under the default knobs exceeds 80 %.

use anyhow::Result;

use crate::config::schema::{ConditionKind, PolicyKind};
use crate::coordinator::plan_cache::PlanCacheConfig;
use crate::coordinator::{Engine, EngineConfig, StreamSpec};
use crate::graph::zoo;
use crate::metrics::PlanCacheStats;
use crate::profiler::calibrate::CalibConfig;
use crate::sim::EventCounters;
use crate::workload::{Arrival, WorkloadCondition};

/// Scenario parameters.
#[derive(Debug, Clone)]
pub struct CacheScenarioConfig {
    /// Number of moderate→high cycles.
    pub cycles: usize,
    /// Closed-loop requests per (phase, model).
    pub requests_per_phase: usize,
    /// Workload/simulator seed.
    pub seed: u64,
    /// Profiler calibration.
    pub calib: CalibConfig,
    /// Plan-cache knobs under test.
    pub plan_cache: PlanCacheConfig,
}

impl Default for CacheScenarioConfig {
    fn default() -> Self {
        CacheScenarioConfig {
            cycles: 8,
            requests_per_phase: 2,
            seed: 7,
            calib: CalibConfig::default(),
            plan_cache: PlanCacheConfig {
                // The trace's two conditions are already separated by their
                // pinned frequencies and ambient-bandwidth factors, so a
                // coarse utilization bucket avoids needless misses when the
                // OU background level wobbles around its per-condition mean
                // (the high condition's 0.55 mean sits near the edge of a
                // 0.15-wide bucket).
                util_bucket: 0.5,
                ..PlanCacheConfig::default()
            },
        }
    }
}

/// Scenario outcome.
#[derive(Debug, Clone)]
pub struct CacheScenarioResult {
    /// Final cache counters (all phases).
    pub stats: PlanCacheStats,
    /// Total requests served across every phase.
    pub requests: usize,
    /// Total repartitions adopted (cached + full solves).
    pub repartitions: usize,
    /// Mean partitioning-decision time, seconds.
    pub mean_decision_s: f64,
}

impl CacheScenarioResult {
    /// Fraction of planning lookups served from cache.
    pub fn hit_rate(&self) -> f64 {
        self.stats.hit_rate()
    }
}

/// Run the bursty recurring-condition trace and report the realized cache
/// hit rate.
pub fn run(cfg: &CacheScenarioConfig) -> Result<CacheScenarioResult> {
    let mut engine = Engine::new(EngineConfig {
        policy: PolicyKind::AdaOper,
        condition: ConditionKind::Moderate,
        seed: cfg.seed,
        calib: cfg.calib.clone(),
        plan_cache: cfg.plan_cache.clone(),
        ..Default::default()
    });
    let specs = vec![
        StreamSpec::new(0, zoo::yolov2_tiny(), Arrival::Poisson { hz: 10.0 }, 0.5),
        StreamSpec::new(1, zoo::mobilenet_v1(), Arrival::Poisson { hz: 10.0 }, 0.5),
    ];
    let conditions = [WorkloadCondition::moderate(), WorkloadCondition::high()];

    // one observer rides every phase: adopted re-plans arrive as
    // `RegimeReplan` events, so the scenario counts them directly instead
    // of reading back cumulative report counters
    let mut counters = EventCounters::default();
    let mut requests = 0;
    let mut mean_decision_s = 0.0;
    for _cycle in 0..cfg.cycles {
        for cond in &conditions {
            engine.apply_condition(cond);
            for spec in &specs {
                let r = engine.run_closed_loop_observed(
                    spec,
                    cfg.requests_per_phase,
                    &mut [&mut counters],
                )?;
                requests += r.requests;
                mean_decision_s = r.partition_overhead_s;
            }
        }
    }
    Ok(CacheScenarioResult {
        stats: engine.plan_cache_stats().unwrap_or_default(),
        requests,
        repartitions: counters.replans,
        mean_decision_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::gbdt::GbdtParams;

    #[test]
    fn recurring_conditions_mostly_hit() {
        let cfg = CacheScenarioConfig {
            cycles: 6,
            requests_per_phase: 2,
            seed: 11,
            calib: CalibConfig {
                samples: 1500,
                seed: 11,
                gbdt: GbdtParams {
                    trees: 40,
                    ..Default::default()
                },
            },
            ..Default::default()
        };
        let res = run(&cfg).unwrap();
        assert!(res.requests >= 6 * 2 * 2 * 2 - 1);
        let st = res.stats;
        // at minimum: one planning lookup per (cycle, condition, model)
        assert!(st.lookups() >= 24, "{st:?}");
        // only four (model × condition) combos recur → warm after cycle 1
        assert!(
            res.hit_rate() >= 0.6,
            "hit rate {:.2} too low: {st:?}",
            res.hit_rate()
        );
        assert!(st.entries >= 4, "{st:?}");
    }
}
