//! Figure 2 reproduction: YOLOv2 on the simulated Xiaomi 9 under the
//! paper's two workload conditions, {MACE-on-GPU, CoDL, AdaOper},
//! closed-loop (back-to-back inference — the paper's measurement style).

use anyhow::Result;

use crate::config::schema::{ConditionKind, PolicyKind};
use crate::coordinator::{Engine, EngineConfig, StreamSpec};
use crate::graph::zoo;
use crate::metrics::ServingReport;
use crate::profiler::calibrate::CalibConfig;
use crate::workload::Arrival;

/// One cell of the figure.
#[derive(Debug, Clone)]
pub struct Fig2Row {
    /// Policy of this cell.
    pub policy: PolicyKind,
    /// Condition of this cell.
    pub condition: ConditionKind,
    /// The closed-loop serving report.
    pub report: ServingReport,
}

/// Experiment parameters.
#[derive(Debug, Clone)]
pub struct Fig2Config {
    /// Zoo model to serve.
    pub model: String,
    /// Closed-loop requests per cell.
    pub n_requests: usize,
    /// Workload/simulator seed.
    pub seed: u64,
    /// Profiler calibration (fit once, shared).
    pub calib: CalibConfig,
}

impl Default for Fig2Config {
    fn default() -> Self {
        Fig2Config {
            model: "yolov2".into(),
            n_requests: 40,
            seed: 7,
            calib: CalibConfig::default(),
        }
    }
}

/// Run the full matrix.
pub fn run(cfg: &Fig2Config) -> Result<Vec<Fig2Row>> {
    let mut rows = Vec::new();
    let model = zoo::by_name(&cfg.model)
        .ok_or_else(|| anyhow::anyhow!("unknown model {}", cfg.model))?;
    for condition in [ConditionKind::Moderate, ConditionKind::High] {
        for policy in [PolicyKind::MaceGpu, PolicyKind::Codl, PolicyKind::AdaOper] {
            let mut engine = Engine::new(EngineConfig {
                policy,
                condition,
                seed: cfg.seed,
                calib: cfg.calib.clone(),
                ..Default::default()
            });
            let spec = StreamSpec::new(
                0,
                model.clone(),
                Arrival::Periodic { hz: 30.0, jitter: 0.0 }, // unused in closed loop
                0.5,
            );
            let report = engine.run_closed_loop(&spec, cfg.n_requests)?;
            rows.push(Fig2Row {
                policy,
                condition,
                report,
            });
        }
    }
    Ok(rows)
}

fn find<'a>(
    rows: &'a [Fig2Row],
    p: PolicyKind,
    c: ConditionKind,
) -> Option<&'a Fig2Row> {
    rows.iter().find(|r| r.policy == p && r.condition == c)
}

/// Render the two panels plus the AdaOper-vs-CoDL deltas the paper quotes.
pub fn render(rows: &[Fig2Row]) -> String {
    let mut s = String::new();
    s.push_str("== Figure 2 — YOLOv2 on simulated SD855 (closed-loop) ==\n\n");
    s.push_str("-- panel (a): latency, ms (mean per inference) --\n");
    s.push_str(&format!(
        "{:<12} {:>12} {:>12}\n",
        "policy", "moderate", "high"
    ));
    for p in [PolicyKind::MaceGpu, PolicyKind::Codl, PolicyKind::AdaOper] {
        let m = find(rows, p, ConditionKind::Moderate);
        let h = find(rows, p, ConditionKind::High);
        s.push_str(&format!(
            "{:<12} {:>12.2} {:>12.2}\n",
            p.name(),
            m.and_then(|r| r.report.latency.as_ref().map(|l| l.mean * 1e3))
                .unwrap_or(f64::NAN),
            h.and_then(|r| r.report.latency.as_ref().map(|l| l.mean * 1e3))
                .unwrap_or(f64::NAN),
        ));
    }
    s.push_str("\n-- panel (b): energy efficiency, inferences/J --\n");
    s.push_str(&format!(
        "{:<12} {:>12} {:>12}\n",
        "policy", "moderate", "high"
    ));
    for p in [PolicyKind::MaceGpu, PolicyKind::Codl, PolicyKind::AdaOper] {
        let m = find(rows, p, ConditionKind::Moderate);
        let h = find(rows, p, ConditionKind::High);
        s.push_str(&format!(
            "{:<12} {:>12.2} {:>12.2}\n",
            p.name(),
            m.map(|r| r.report.inferences_per_j).unwrap_or(f64::NAN),
            h.map(|r| r.report.inferences_per_j).unwrap_or(f64::NAN),
        ));
    }
    s.push_str("\n-- AdaOper vs CoDL (the paper's headline deltas) --\n");
    s.push_str(&format!(
        "{:<12} {:>18} {:>22}\n",
        "condition", "latency reduction", "energy-eff improvement"
    ));
    for (c, paper_lat, paper_eff) in [
        (ConditionKind::Moderate, 3.94, 4.06),
        (ConditionKind::High, 12.97, 16.88),
    ] {
        let (Some(a), Some(d)) = (
            find(rows, PolicyKind::AdaOper, c),
            find(rows, PolicyKind::Codl, c),
        ) else {
            continue;
        };
        let lat_a = a.report.latency.as_ref().map(|l| l.mean).unwrap_or(f64::NAN);
        let lat_c = d.report.latency.as_ref().map(|l| l.mean).unwrap_or(f64::NAN);
        let dl = (1.0 - lat_a / lat_c) * 100.0;
        let de = (a.report.inferences_per_j / d.report.inferences_per_j - 1.0) * 100.0;
        s.push_str(&format!(
            "{:<12} {:>11.2}% ({:>5.2}%) {:>15.2}% ({:>5.2}%)\n",
            c.name(),
            dl,
            paper_lat,
            de,
            paper_eff
        ));
    }
    s.push_str("(paper-reported values in parentheses)\n");
    s.push_str("\n-- measured average CPU utilization (AdaOper serving) --\n");
    for c in [ConditionKind::Moderate, ConditionKind::High] {
        if let Some(r) = find(rows, PolicyKind::AdaOper, c) {
            s.push_str(&format!(
                "{:<12} {:>6.1}%  (paper setup: {})\n",
                c.name(),
                r.report.avg_cpu_util * 100.0,
                if c == ConditionKind::Moderate { "78.8%" } else { "91.3%" }
            ));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::gbdt::GbdtParams;

    #[test]
    fn fig2_shape_holds_on_small_run() {
        // Small-budget end-to-end check of the headline *shape*:
        // AdaOper ≤ CoDL latency and ≥ CoDL efficiency in both conditions.
        let cfg = Fig2Config {
            model: "yolov2".into(),
            n_requests: 12,
            seed: 7,
            calib: CalibConfig {
                samples: 2500,
                seed: 42,
                gbdt: GbdtParams {
                    trees: 80,
                    ..Default::default()
                },
            },
        };
        let rows = run(&cfg).unwrap();
        assert_eq!(rows.len(), 6);
        for c in [ConditionKind::Moderate, ConditionKind::High] {
            let a = find(&rows, PolicyKind::AdaOper, c).unwrap();
            let d = find(&rows, PolicyKind::Codl, c).unwrap();
            let lat_a = a.report.latency.as_ref().unwrap().mean;
            let lat_c = d.report.latency.as_ref().unwrap().mean;
            assert!(
                lat_a < lat_c * 1.02,
                "{}: adaoper {lat_a} vs codl {lat_c}",
                c.name()
            );
            assert!(
                a.report.inferences_per_j > d.report.inferences_per_j * 0.98,
                "{}: adaoper eff {} vs codl {}",
                c.name(),
                a.report.inferences_per_j,
                d.report.inferences_per_j
            );
        }
        let txt = render(&rows);
        assert!(txt.contains("panel (a)"));
        assert!(txt.contains("AdaOper vs CoDL"));
    }
}
