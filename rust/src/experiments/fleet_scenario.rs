//! Fleet scale sweep (A8): run the sharded fleet simulator at increasing
//! device counts under each dispatch policy and compare fleet-wide and
//! per-class tail latency, deadline misses, and energy per request.
//!
//! The fleet sampler is prefix-stable (device `i` is identical at every
//! fleet size), so larger cells strictly extend smaller ones, and all
//! cells at the same device count share the same offered request
//! population across schedulers — comparisons are like-for-like. The
//! per-class offline profiler models are calibrated once against each
//! class's own hardware and shared across all cells.

use anyhow::Result;

use crate::config::schema::SchedulerKind;
use crate::fleet::runner::{
    calibrate_classes, ms_or_dash, run_fleet_with, FleetReport, FleetRunConfig,
};
use crate::fleet::zoo::DeviceClass;
use crate::profiler::calibrate::CalibConfig;

/// Sweep parameters.
#[derive(Debug, Clone)]
pub struct FleetSweepConfig {
    /// Fleet sizes to run (e.g. `[10, 100, 1000]`).
    pub device_counts: Vec<usize>,
    /// Dispatch policies to compare at every size.
    pub schedulers: Vec<SchedulerKind>,
    /// Runner worker threads (never affects results).
    pub threads: usize,
    /// Arrival horizon per device, virtual seconds.
    pub duration_s: f64,
    /// Fleet seed shared by every cell (paired populations).
    pub seed: u64,
    /// Per-class profiler calibration budget (fit once, shared).
    pub calib: CalibConfig,
}

impl Default for FleetSweepConfig {
    fn default() -> Self {
        FleetSweepConfig {
            device_counts: vec![10, 100],
            schedulers: SchedulerKind::all().to_vec(),
            threads: 4,
            duration_s: 1.5,
            seed: 7,
            calib: CalibConfig::default(),
        }
    }
}

/// One (devices, scheduler) cell of the sweep.
#[derive(Debug, Clone)]
pub struct FleetSweepRow {
    /// Fleet size of this cell.
    pub devices: usize,
    /// Dispatch policy of this cell.
    pub scheduler: SchedulerKind,
    /// The merged fleet report.
    pub report: FleetReport,
}

/// Run the sweep: calibrate each device class once, then every
/// `device_counts × schedulers` cell.
pub fn run(cfg: &FleetSweepConfig) -> Result<Vec<FleetSweepRow>> {
    let offline = calibrate_classes(&cfg.calib, &DeviceClass::all(), cfg.threads);
    let mut rows = Vec::new();
    for &devices in &cfg.device_counts {
        for &scheduler in &cfg.schedulers {
            let fcfg = FleetRunConfig {
                devices,
                threads: cfg.threads,
                seed: cfg.seed,
                duration_s: cfg.duration_s,
                scheduler,
                calib: cfg.calib.clone(),
                ..Default::default()
            };
            let report = run_fleet_with(&fcfg, &offline)?;
            rows.push(FleetSweepRow {
                devices,
                scheduler,
                report,
            });
        }
    }
    Ok(rows)
}

/// Format the sweep as the table the CLI and bench print.
pub fn render(rows: &[FleetSweepRow]) -> String {
    let mut s = format!(
        "{:<8} {:<14} {:>8} {:>8} {:>7} {:>9} {:>9} {:>9} {:>9} {:>10}\n",
        "devices", "scheduler", "offered", "done", "miss%", "p50 ms", "p95 ms", "p99 ms",
        "mJ/req", "budget p95"
    );
    for r in rows {
        let fleet = &r.report.fleet;
        let budget = r.report.class(DeviceClass::Budget);
        s.push_str(&format!(
            "{:<8} {:<14} {:>8} {:>8} {:>6.1}% {:>9} {:>9} {:>9} {:>9.1} {:>10}\n",
            r.devices,
            r.scheduler.name(),
            fleet.offered,
            fleet.completed,
            fleet.miss_rate() * 100.0,
            ms_or_dash(fleet, 0.50),
            ms_or_dash(fleet, 0.95),
            ms_or_dash(fleet, 0.99),
            fleet.j_per_request() * 1e3,
            ms_or_dash(budget, 0.95),
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::gbdt::GbdtParams;

    #[test]
    fn tiny_sweep_runs_and_pairs_offered_load() {
        let cfg = FleetSweepConfig {
            device_counts: vec![6],
            schedulers: vec![SchedulerKind::Fifo, SchedulerKind::Edf],
            threads: 2,
            duration_s: 1.0,
            seed: 11,
            calib: CalibConfig {
                samples: 900,
                seed: 11,
                gbdt: GbdtParams {
                    trees: 25,
                    ..Default::default()
                },
            },
        };
        let rows = run(&cfg).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert_eq!(r.report.devices, 6);
            assert!(r.report.fleet.completed > 0, "nothing completed: {r:?}");
        }
        // same seed + prefix-stable sampler → identical offered population
        assert_eq!(rows[0].report.fleet.offered, rows[1].report.fleet.offered);
        let out = render(&rows);
        assert!(out.contains("fifo") && out.contains("edf"));
    }
}
