//! Experiment runners — the code that regenerates every figure of the
//! paper plus this repo's ablations. The CLI (`adaoper fig2`, …), the
//! examples and the `cargo bench` targets are all thin wrappers over these
//! functions, so numbers are reproducible from any entry point.
//!
//! | id  | runner                          | reproduces                         |
//! |-----|---------------------------------|------------------------------------|
//! | Fig2| [`fig2::run`]                   | Figure 2 (latency + energy eff.)   |
//! | A1  | [`ablations::profiler_accuracy`]| profiler-stage accuracy under drift|
//! | A2  | [`ablations::dp_comparison`]    | DP optimality + decision runtime   |
//! | A3  | [`ablations::incremental_vs_full`]| windowed vs full re-solve        |
//! | A4  | [`ablations::responsiveness`]   | adaptation across condition switch |
//! | A5  | [`ablations::concurrency_scaling`]| 1–4 concurrent model streams    |
//! | A6  | [`cache_scenario::run`]         | plan-cache hit rate, bursty trace  |
//! | A7  | [`scheduler_scenario::run`]     | scheduler overload sweep (SLOs)    |
//! | A8  | [`fleet_scenario::run`]         | fleet scale sweep (device classes) |
//! | A9  | [`batching_scenario::run`]      | batching sweep (energy vs batch cap)|

pub mod ablations;
pub mod batching_scenario;
pub mod cache_scenario;
pub mod fig2;
pub mod fleet_scenario;
pub mod scheduler_scenario;
