//! Fleet-scale serving simulation: evaluate the planner, scheduler, and
//! plan cache across a *population* of heterogeneous devices instead of a
//! single Snapdragon 855.
//!
//! * [`zoo`] — the device-class zoo (flagship / midrange / budget
//!   [`crate::soc::device::DeviceConfig`] tiers) and the seeded sampler
//!   that assigns each simulated device a class, workload condition, and
//!   stream/SLO profile.
//! * [`runner`] — the sharded runner: partitions N devices across
//!   [`crate::util::pool::ThreadPool`] workers (per-device seeds derived
//!   via splitmix64 from one fleet seed, so results are bit-identical
//!   regardless of thread count) and merges per-device
//!   [`crate::metrics::ServingReport`]s into a [`FleetReport`] using the
//!   mergeable histograms in [`crate::metrics::histogram`].
//!
//! Entry points: `adaoper fleet --devices N --threads T --seed S`, the
//! `[fleet]` config section, and the scale sweep in
//! [`crate::experiments::fleet_scenario`] (`adaoper ablation fleet`).

pub mod runner;
pub mod zoo;

pub use runner::{run_fleet, ClassAgg, FleetReport, FleetRunConfig};
pub use zoo::{device_seed, sample_fleet, DeviceClass, DeviceSpec, FleetMix};
