//! Sharded fleet runner: drive many independent device+engine simulations
//! in parallel and merge their reports into a [`FleetReport`].
//!
//! Determinism contract: the fleet is sampled up front
//! ([`super::zoo::sample_fleet`], per-device seeds via a splitmix64 jump),
//! every device simulation is self-contained (own `Device`, own
//! `EnergyProfiler` corrector state, own engine), and
//! [`crate::util::pool::ThreadPool::map`] returns results in input order —
//! so the merged report is **bit-identical for any `threads` value**. The
//! only cross-device sharing is the per-class offline GBDT
//! ([`crate::profiler::calibrate::calibrate_on`]), which is immutable
//! after fitting and fitted before the pool starts.

use std::sync::Arc;

use anyhow::{anyhow, ensure, Result};

use crate::batching::BatchConfig;

use crate::config::schema::{PolicyKind, SchedulerKind};
use crate::coordinator::request::RequestOutcome;
use crate::coordinator::{AdmissionPolicy, Engine, EngineConfig, StreamSpec};
use crate::graph::zoo as model_zoo;
use crate::metrics::{HealthConfig, LogHistogram, ServingReport, TelemetryRegistry};
use crate::profiler::calibrate::{calibrate_on, CalibConfig, OfflineModel};
use crate::profiler::{EnergyProfiler, EwmaCorrector};
use crate::sim::{EventCounters, SimObserver};
use crate::util::pool::ThreadPool;
use crate::workload::Arrival;

use super::zoo::{mix_is_valid, sample_fleet, DeviceClass, DeviceSpec, FleetMix};

/// Fleet-run parameters.
#[derive(Debug, Clone)]
pub struct FleetRunConfig {
    /// Number of simulated devices.
    pub devices: usize,
    /// Worker threads the sharded runner uses (does not affect results).
    pub threads: usize,
    /// Fleet seed; per-device seeds derive from it.
    pub seed: u64,
    /// Arrival horizon per device, virtual seconds.
    pub duration_s: f64,
    /// Partitioning policy every device's engine runs.
    pub policy: PolicyKind,
    /// Dispatch policy every device's engine runs.
    pub scheduler: SchedulerKind,
    /// Admission control in front of every device's queue (`AdmitAll`
    /// keeps the shed counters at zero by construction).
    pub admission: AdmissionPolicy,
    /// Dynamic-batching configuration every device's engine runs (`none`
    /// keeps fleet output byte-identical to the pre-batching format).
    pub batching: BatchConfig,
    /// Population mix the sampler draws devices from.
    pub mix: FleetMix,
    /// Per-class profiler calibration budget.
    pub calib: CalibConfig,
    /// Build a fleet-wide [`TelemetryRegistry`] from the per-device probes
    /// (merged in device order, so it is bit-identical for any `threads`
    /// value). Off by default: `FleetReport::render` never changes.
    pub telemetry: bool,
    /// Health-monitor config every device's engine runs (`None` keeps the
    /// engines alert-free and the fleet table byte-identical to before).
    /// Per-class alert counts merge in device order, so they are
    /// bit-identical for any `threads` value.
    pub health: Option<HealthConfig>,
}

impl Default for FleetRunConfig {
    fn default() -> Self {
        FleetRunConfig {
            devices: 50,
            threads: 4,
            seed: 7,
            duration_s: 2.0,
            policy: PolicyKind::AdaOper,
            scheduler: SchedulerKind::Edf,
            admission: AdmissionPolicy::AdmitAll,
            batching: BatchConfig::default(),
            mix: FleetMix::default(),
            calib: CalibConfig::default(),
            telemetry: false,
            health: None,
        }
    }
}

/// Per-device observer riding the serving kernel: event tallies plus the
/// per-request latency histogram, recorded straight from the kernel's
/// completion hook (the [`FleetReport`] merge consumes these instead of
/// re-deriving them from `ServingReport` internals).
#[derive(Debug, Clone)]
pub struct DeviceProbe {
    /// Kernel event tallies (offered / admitted / shed / completions …).
    pub counters: EventCounters,
    /// Per-request end-to-end latency histogram (standard latency
    /// boundaries, so per-class merges stay exact).
    pub latency: LogHistogram,
}

impl DeviceProbe {
    /// Empty probe.
    pub fn new() -> DeviceProbe {
        DeviceProbe {
            counters: EventCounters::default(),
            latency: LogHistogram::latency(),
        }
    }
}

impl Default for DeviceProbe {
    fn default() -> Self {
        DeviceProbe::new()
    }
}

impl SimObserver for DeviceProbe {
    fn on_event(&mut self, event: &crate::sim::Event) {
        self.counters.on_event(event);
    }

    fn on_request_done(&mut self, outcome: &RequestOutcome, met_deadline: bool) {
        self.counters.on_request_done(outcome, met_deadline);
        self.latency.record(outcome.latency_s());
    }
}

/// Mergeable aggregate over a set of device reports (one per class, plus
/// one fleet-wide).
#[derive(Debug, Clone)]
pub struct ClassAgg {
    /// Devices that contributed.
    pub devices: usize,
    /// Requests generated by the arrival processes.
    pub offered: usize,
    /// Requests that completed.
    pub completed: usize,
    /// Requests rejected at admission.
    pub shed: usize,
    /// Completed requests that missed their deadline.
    pub deadline_misses: usize,
    /// Total energy (dynamic + static) across devices, joules.
    pub total_energy_j: f64,
    /// Plan-cache hits across devices.
    pub cache_hits: usize,
    /// Plan-cache lookups across devices.
    pub cache_lookups: usize,
    /// Batch closes across devices (0 when batching is disabled).
    pub batches: usize,
    /// Requests dispatched inside those batches.
    pub batched_requests: usize,
    /// Health alerts (state transitions) across devices — 0 when the
    /// health monitor is off.
    pub alerts: u64,
    /// Alerts whose target state was `warn`.
    pub warn_alerts: u64,
    /// Alerts whose target state was `critical`.
    pub critical_alerts: u64,
    /// Profiler-drift escalations across devices.
    pub drift_alerts: u64,
    /// Merged per-request latency histogram.
    pub latency: LogHistogram,
}

impl ClassAgg {
    /// Empty aggregate.
    pub fn empty() -> ClassAgg {
        ClassAgg {
            devices: 0,
            offered: 0,
            completed: 0,
            shed: 0,
            deadline_misses: 0,
            total_energy_j: 0.0,
            cache_hits: 0,
            cache_lookups: 0,
            batches: 0,
            batched_requests: 0,
            alerts: 0,
            warn_alerts: 0,
            critical_alerts: 0,
            drift_alerts: 0,
            latency: LogHistogram::latency(),
        }
    }

    /// Fold one device's serving report into the aggregate (report-only
    /// fallback; the runner path uses [`ClassAgg::absorb_observed`]).
    pub fn absorb(&mut self, r: &ServingReport) {
        self.devices += 1;
        self.completed += r.requests;
        match &r.sched {
            Some(sc) => {
                self.offered += sc.offered;
                self.shed += sc.shed();
                self.deadline_misses += sc.deadline_misses;
            }
            None => self.offered += r.requests,
        }
        self.total_energy_j += r.total_energy_j;
        if let Some(pc) = &r.plan_cache {
            self.cache_hits += pc.hits;
            self.cache_lookups += pc.lookups();
        }
        if let Some(b) = &r.batch {
            self.batches += b.batched_dispatches;
            self.batched_requests += b.batched_requests;
        }
        self.absorb_health(r);
        if let Some(h) = &r.latency_hist {
            self.latency.merge(h);
        }
    }

    /// Fold the report's health summary (no-op when the monitor was off);
    /// u64 sums, so the merge is exact and order-independent.
    fn absorb_health(&mut self, r: &ServingReport) {
        if let Some(h) = &r.health {
            self.alerts += h.alerts;
            self.warn_alerts += h.warn;
            self.critical_alerts += h.critical;
            self.drift_alerts += h.drift_alerts;
        }
    }

    /// Fold one device into the aggregate from its kernel observer:
    /// request counters and the latency histogram come from the
    /// [`DeviceProbe`], energy and plan-cache counters from the report
    /// (they are accounting the kernel does not re-expose per event).
    pub fn absorb_observed(&mut self, r: &ServingReport, probe: &DeviceProbe) {
        self.devices += 1;
        self.completed += probe.counters.completed;
        self.offered += probe.counters.offered;
        self.shed += probe.counters.shed;
        self.deadline_misses += probe.counters.deadline_misses;
        self.total_energy_j += r.total_energy_j;
        if let Some(pc) = &r.plan_cache {
            self.cache_hits += pc.hits;
            self.cache_lookups += pc.lookups();
        }
        self.batches += probe.counters.batch_closes;
        self.batched_requests += probe.counters.batched_requests;
        self.absorb_health(r);
        self.latency.merge(&probe.latency);
        debug_assert_eq!(probe.counters.completed, r.requests);
        debug_assert_eq!(
            probe.counters.alerts as u64,
            r.health.map_or(0, |h| h.alerts)
        );
    }

    /// Deadline-miss rate over completed requests (0 when none completed).
    pub fn miss_rate(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.deadline_misses as f64 / self.completed as f64
        }
    }

    /// Fraction of offered requests shed at admission.
    pub fn shed_rate(&self) -> f64 {
        if self.offered == 0 {
            0.0
        } else {
            self.shed as f64 / self.offered as f64
        }
    }

    /// Plan-cache hit rate across the aggregate (0 when no lookups).
    pub fn cache_hit_rate(&self) -> f64 {
        if self.cache_lookups == 0 {
            0.0
        } else {
            self.cache_hits as f64 / self.cache_lookups as f64
        }
    }

    /// Mean dispatched batch size over batched dispatches (0 when the
    /// aggregate saw no batches — e.g. batching disabled).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }

    /// Mean energy per completed request, joules (0 when none completed).
    pub fn j_per_request(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.total_energy_j / self.completed as f64
        }
    }

    /// Latency quantile in milliseconds (NaN when nothing completed).
    pub fn latency_ms(&self, q: f64) -> f64 {
        self.latency.quantile(q).map_or(f64::NAN, |v| v * 1e3)
    }
}

/// Merged outcome of a fleet run.
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Devices simulated.
    pub devices: usize,
    /// Fleet seed the run derived everything from.
    pub seed: u64,
    /// Arrival horizon per device, virtual seconds.
    pub duration_s: f64,
    /// Partitioning policy name.
    pub policy: String,
    /// Dispatch policy name.
    pub scheduler: String,
    /// Per-class aggregates, in [`DeviceClass::all`] order (classes the
    /// sampler never drew stay empty).
    pub per_class: Vec<(DeviceClass, ClassAgg)>,
    /// Fleet-wide aggregate.
    pub fleet: ClassAgg,
    /// Merged telemetry registry (None unless
    /// [`FleetRunConfig::telemetry`] was enabled).
    pub telemetry: Option<TelemetryRegistry>,
}

impl FleetReport {
    /// The aggregate for one device class.
    pub fn class(&self, c: DeviceClass) -> &ClassAgg {
        &self
            .per_class
            .iter()
            .find(|(cc, _)| *cc == c)
            .expect("every class has an aggregate")
            .1
    }

    /// Human-readable fleet table (deterministic for a given run: every
    /// number derives from the order-independent merge). The per-class
    /// batching column only appears when the run actually batched, so
    /// batching-disabled fleet output stays byte-identical to the
    /// pre-batching format.
    pub fn render(&self) -> String {
        let show_batch = self.fleet.batches > 0;
        let mut s = format!(
            "fleet: {} devices, seed {}, horizon {:.1}s, policy {}, scheduler {}\n",
            self.devices, self.seed, self.duration_s, self.policy, self.scheduler
        );
        s.push_str(&format!(
            "{:<10} {:>5} {:>8} {:>8} {:>6} {:>7} {:>9} {:>9} {:>9} {:>9} {:>7}",
            "class", "dev", "offered", "done", "shed", "miss%", "p50 ms", "p95 ms", "p99 ms",
            "mJ/req", "cache%"
        ));
        if show_batch {
            s.push_str(&format!(" {:>7}", "avgB"));
        }
        s.push('\n');
        let mut row = |name: &str, a: &ClassAgg| {
            s.push_str(&format!(
                "{:<10} {:>5} {:>8} {:>8} {:>6} {:>6.1}% {:>9} {:>9} {:>9} {:>9.1} {:>6.1}%",
                name,
                a.devices,
                a.offered,
                a.completed,
                a.shed,
                a.miss_rate() * 100.0,
                ms_or_dash(a, 0.50),
                ms_or_dash(a, 0.95),
                ms_or_dash(a, 0.99),
                a.j_per_request() * 1e3,
                a.cache_hit_rate() * 100.0,
            ));
            if show_batch {
                s.push_str(&format!(" {:>7.2}", a.mean_batch_size()));
            }
            s.push('\n');
        };
        for (class, agg) in &self.per_class {
            if agg.devices > 0 {
                row(class.name(), agg);
            }
        }
        row("fleet", &self.fleet);
        // health rollup only when the run actually alerted, so
        // monitor-off (and alert-free) fleet output stays byte-identical
        if self.fleet.alerts > 0 {
            s.push_str("health alerts:\n");
            let mut alert_row = |name: &str, a: &ClassAgg| {
                s.push_str(&format!(
                    "  {:<10} {:>6} alerts ({} warn / {} critical, {} drift)\n",
                    name, a.alerts, a.warn_alerts, a.critical_alerts, a.drift_alerts
                ));
            };
            for (class, agg) in &self.per_class {
                if agg.devices > 0 {
                    alert_row(class.name(), agg);
                }
            }
            alert_row("fleet", &self.fleet);
        }
        s
    }
}

/// Latency quantile formatted for a table cell: `-` when the aggregate
/// recorded no requests (instead of `NaN`).
pub fn ms_or_dash(a: &ClassAgg, q: f64) -> String {
    if a.latency.is_empty() {
        "-".to_string()
    } else {
        format!("{:.1}", a.latency_ms(q))
    }
}

/// Validate the run parameters and sample the fleet (exactly once per
/// run; both entry points share this).
fn validate_and_sample(cfg: &FleetRunConfig) -> Result<Vec<DeviceSpec>> {
    ensure!(cfg.devices >= 1, "fleet devices must be >= 1");
    ensure!(
        (1..=256).contains(&cfg.threads),
        "fleet threads must be in 1..=256"
    );
    ensure!(
        cfg.duration_s * cfg.mix.rate_hz.0 >= 1.05,
        "fleet duration_s {} too short: the slowest stream ({} Hz) needs at \
         least one arrival inside the horizon",
        cfg.duration_s,
        cfg.mix.rate_hz.0
    );
    ensure!(
        mix_is_valid(&cfg.mix),
        "fleet mix invalid: needs >= 1 model and non-negative weight vectors \
         with positive sums"
    );
    Ok(sample_fleet(cfg.seed, cfg.devices, &cfg.mix))
}

/// Fit the offline model of every class in `classes`, fanned across up to
/// `threads` workers — the fits are independent (immutable inputs, each
/// seeded by `calib.seed`), so results are identical to serial fitting.
pub fn calibrate_classes(
    calib: &CalibConfig,
    classes: &[DeviceClass],
    threads: usize,
) -> [Option<OfflineModel>; 3] {
    let mut out: [Option<OfflineModel>; 3] = [None, None, None];
    if classes.is_empty() {
        return out;
    }
    let pool = ThreadPool::new(threads.clamp(1, classes.len()));
    let calib = calib.clone();
    let fitted = pool.map(classes.to_vec(), move |class| {
        (class, calibrate_on(&calib, &class.device_config()))
    });
    for (class, model) in fitted {
        out[class.index()] = Some(model);
    }
    out
}

/// Calibrate one offline model per device class present in the fleet, then
/// run the sharded simulation.
pub fn run_fleet(cfg: &FleetRunConfig) -> Result<FleetReport> {
    let specs = validate_and_sample(cfg)?;
    let present: Vec<DeviceClass> = DeviceClass::all()
        .into_iter()
        .filter(|c| specs.iter().any(|sp| sp.class == *c))
        .collect();
    let offline = calibrate_classes(&cfg.calib, &present, cfg.threads);
    run_sharded(cfg, specs, &offline)
}

/// Run the sharded simulation with pre-fitted per-class offline models
/// (the scale sweep calibrates once and reuses across cells). Models may
/// be `None` only for classes the sampler never draws.
pub fn run_fleet_with(
    cfg: &FleetRunConfig,
    offline: &[Option<OfflineModel>; 3],
) -> Result<FleetReport> {
    let specs = validate_and_sample(cfg)?;
    run_sharded(cfg, specs, offline)
}

/// The shared sharded execution + merge path behind both entry points.
fn run_sharded(
    cfg: &FleetRunConfig,
    specs: Vec<DeviceSpec>,
    offline: &[Option<OfflineModel>; 3],
) -> Result<FleetReport> {
    for class in DeviceClass::all() {
        ensure!(
            offline[class.index()].is_some() || !specs.iter().any(|sp| sp.class == class),
            "missing offline model for device class `{}`",
            class.name()
        );
    }

    let pool = ThreadPool::new(cfg.threads);
    let shared: Arc<[Option<OfflineModel>; 3]> = Arc::new(offline.clone());
    let (duration_s, policy, scheduler, admission) =
        (cfg.duration_s, cfg.policy, cfg.scheduler, cfg.admission);
    let batching = cfg.batching.clone();
    let health = cfg.health.clone();
    let results: Vec<Result<(ServingReport, DeviceProbe)>> =
        pool.map(specs.clone(), move |spec| {
            let off = shared[spec.class.index()]
                .as_ref()
                .expect("offline model present for sampled class");
            run_device(
                &spec, off, duration_s, policy, scheduler, admission, &batching, &health,
            )
        });

    // merge in device order (ThreadPool::map preserves it), so float sums
    // are identical for every thread count
    let mut per_class: Vec<(DeviceClass, ClassAgg)> = DeviceClass::all()
        .iter()
        .map(|&c| (c, ClassAgg::empty()))
        .collect();
    let mut fleet = ClassAgg::empty();
    let mut registry = cfg.telemetry.then(TelemetryRegistry::new);
    for (spec, res) in specs.iter().zip(results) {
        let (report, probe) = res?;
        per_class[spec.class.index()]
            .1
            .absorb_observed(&report, &probe);
        fleet.absorb_observed(&report, &probe);
        if let Some(reg) = registry.as_mut() {
            reg.absorb_counters(&probe.counters);
            reg.merge_histogram("latency_s", &probe.latency);
            reg.add_gauge("fleet.energy_j", report.total_energy_j);
        }
    }
    Ok(FleetReport {
        devices: cfg.devices,
        seed: cfg.seed,
        duration_s: cfg.duration_s,
        policy: cfg.policy.name().to_string(),
        scheduler: cfg.scheduler.name().to_string(),
        per_class,
        fleet,
        telemetry: registry,
    })
}

/// Simulate one device end to end: class hardware, class-scaled condition,
/// its own engine seeded from the spec, and a [`DeviceProbe`] observer
/// riding the kernel for the merge stage.
#[allow(clippy::too_many_arguments)]
fn run_device(
    spec: &DeviceSpec,
    offline: &OfflineModel,
    duration_s: f64,
    policy: PolicyKind,
    scheduler: SchedulerKind,
    admission: AdmissionPolicy,
    batching: &BatchConfig,
    health: &Option<HealthConfig>,
) -> Result<(ServingReport, DeviceProbe)> {
    let model = model_zoo::by_name(&spec.model)
        .ok_or_else(|| anyhow!("unknown fleet model `{}`", spec.model))?;
    let profiler =
        EnergyProfiler::with_correctors(offline.clone(), || Box::new(EwmaCorrector::default()));
    let mut engine = Engine::with_profiler(
        EngineConfig {
            policy,
            scheduler,
            admission,
            batching: batching.clone(),
            health: health.clone(),
            condition: spec.condition,
            condition_spec: Some(spec.class.condition(spec.condition)),
            duration_s,
            seed: spec.seed,
            device_cfg: spec.class.device_config(),
            device_label: Some(format!("{}#{:04}", spec.class.name(), spec.index)),
            ..Default::default()
        },
        profiler,
    );
    let stream = StreamSpec::new(
        0,
        model,
        Arrival::Periodic {
            hz: spec.rate_hz,
            jitter: 0.02,
        },
        spec.slo_s,
    );
    let mut probe = DeviceProbe::new();
    let report = engine.run_observed(&[stream], &mut [&mut probe])?;
    Ok((report, probe))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::SchedStats;
    use crate::util::stats::Summary;

    fn fake_report(requests: usize, energy_j: f64, lat_s: f64) -> ServingReport {
        ServingReport {
            policy: "adaoper".into(),
            condition: "moderate".into(),
            device: Some("budget#0001".into()),
            models: vec!["yolov2_tiny".into()],
            duration_s: 1.0,
            requests,
            throughput_hz: requests as f64,
            latency: Summary::of(&vec![lat_s; requests.max(1)]),
            latency_hist: Some(LogHistogram::latency_of(&vec![lat_s; requests])),
            queue: None,
            miss_rate: 0.0,
            total_energy_j: energy_j,
            j_per_inference: energy_j / requests.max(1) as f64,
            inferences_per_j: 1.0,
            avg_cpu_util: 0.5,
            avg_gpu_util: 0.5,
            repartitions: 0,
            partition_overhead_s: 0.0,
            plan_cache: None,
            sched: Some(SchedStats {
                scheduler: "edf".into(),
                admission: "admit-all".into(),
                offered: requests + 2,
                admitted: requests,
                shed_late: 2,
                dropped_capacity: 0,
                deadline_misses: 1,
            }),
            batch: None,
            telemetry: None,
            health: None,
        }
    }

    #[test]
    fn class_agg_merges_counters_and_latency() {
        let mut agg = ClassAgg::empty();
        agg.absorb(&fake_report(10, 2.0, 0.050));
        agg.absorb(&fake_report(6, 1.0, 0.200));
        assert_eq!(agg.devices, 2);
        assert_eq!(agg.completed, 16);
        assert_eq!(agg.offered, 20);
        assert_eq!(agg.shed, 4);
        assert_eq!(agg.deadline_misses, 2);
        assert!((agg.shed_rate() - 0.2).abs() < 1e-12);
        assert!((agg.miss_rate() - 2.0 / 16.0).abs() < 1e-12);
        assert!((agg.j_per_request() - 3.0 / 16.0).abs() < 1e-12);
        assert_eq!(agg.latency.count(), 16);
        // p95 of (10×50 ms, 6×200 ms) sits in the 200 ms bucket
        let p95 = agg.latency_ms(0.95);
        assert!((p95 - 200.0).abs() / 200.0 < 0.05, "p95 {p95}");
    }

    fn fake_probe(requests: usize, lat_s: f64) -> DeviceProbe {
        let mut probe = DeviceProbe::new();
        probe.counters.offered = requests + 2;
        probe.counters.admitted = requests;
        probe.counters.shed = 2;
        probe.counters.completed = requests;
        probe.counters.deadline_misses = 1;
        for _ in 0..requests {
            probe.latency.record(lat_s);
        }
        probe
    }

    #[test]
    fn absorb_observed_matches_report_only_absorb() {
        // a (report, probe) pair describing the same run must fold to the
        // same aggregate through either path
        let mut via_report = ClassAgg::empty();
        via_report.absorb(&fake_report(10, 2.0, 0.050));
        let mut via_probe = ClassAgg::empty();
        via_probe.absorb_observed(&fake_report(10, 2.0, 0.050), &fake_probe(10, 0.050));
        assert_eq!(via_report.offered, via_probe.offered);
        assert_eq!(via_report.completed, via_probe.completed);
        assert_eq!(via_report.shed, via_probe.shed);
        assert_eq!(via_report.deadline_misses, via_probe.deadline_misses);
        assert_eq!(via_report.latency.counts(), via_probe.latency.counts());
        assert_eq!(
            via_report.total_energy_j.to_bits(),
            via_probe.total_energy_j.to_bits()
        );
    }

    #[test]
    fn empty_agg_rates_are_zero() {
        let agg = ClassAgg::empty();
        assert_eq!(agg.miss_rate(), 0.0);
        assert_eq!(agg.shed_rate(), 0.0);
        assert_eq!(agg.cache_hit_rate(), 0.0);
        assert_eq!(agg.j_per_request(), 0.0);
        assert!(agg.latency_ms(0.5).is_nan());
    }

    #[test]
    fn render_lists_classes_and_fleet_row() {
        let mut per_class: Vec<(DeviceClass, ClassAgg)> = DeviceClass::all()
            .iter()
            .map(|&c| (c, ClassAgg::empty()))
            .collect();
        let mut fleet = ClassAgg::empty();
        let r = fake_report(5, 1.0, 0.1);
        per_class[DeviceClass::Budget.index()].1.absorb(&r);
        fleet.absorb(&r);
        let report = FleetReport {
            devices: 1,
            seed: 42,
            duration_s: 1.0,
            policy: "adaoper".into(),
            scheduler: "edf".into(),
            per_class,
            fleet,
            telemetry: None,
        };
        let out = report.render();
        assert!(out.contains("budget"));
        assert!(out.contains("fleet"));
        // empty classes are omitted from the table
        assert!(!out.contains("flagship"));
        assert_eq!(report.class(DeviceClass::Budget).completed, 5);
        assert_eq!(report.class(DeviceClass::Flagship).devices, 0);
    }

    #[test]
    fn batching_column_gated_on_activity() {
        let per_class: Vec<(DeviceClass, ClassAgg)> = DeviceClass::all()
            .iter()
            .map(|&c| (c, ClassAgg::empty()))
            .collect();
        let mut fleet = ClassAgg::empty();
        fleet.absorb(&fake_report(5, 1.0, 0.1));
        let mut report = FleetReport {
            devices: 1,
            seed: 42,
            duration_s: 1.0,
            policy: "adaoper".into(),
            scheduler: "edf".into(),
            per_class,
            fleet,
            telemetry: None,
        };
        // no batches anywhere → legacy table, no avgB column
        assert!(!report.render().contains("avgB"));
        report.fleet.batches = 10;
        report.fleet.batched_requests = 25;
        assert!((report.fleet.mean_batch_size() - 2.5).abs() < 1e-12);
        assert!(report.render().contains("avgB"));
        assert_eq!(ClassAgg::empty().mean_batch_size(), 0.0);
    }

    #[test]
    fn health_rollup_sums_summaries_and_gates_render() {
        use crate::metrics::HealthSummary;
        let mut with_alerts = fake_report(5, 1.0, 0.1);
        with_alerts.health = Some(HealthSummary {
            ticks: 20,
            alerts: 3,
            warn: 2,
            critical: 1,
            drift_alerts: 1,
        });
        let mut agg = ClassAgg::empty();
        agg.absorb(&fake_report(5, 1.0, 0.1)); // monitor off: no-op
        agg.absorb(&with_alerts);
        agg.absorb(&with_alerts);
        assert_eq!(agg.alerts, 6);
        assert_eq!(agg.warn_alerts, 4);
        assert_eq!(agg.critical_alerts, 2);
        assert_eq!(agg.drift_alerts, 2);

        let per_class: Vec<(DeviceClass, ClassAgg)> = DeviceClass::all()
            .iter()
            .map(|&c| (c, ClassAgg::empty()))
            .collect();
        let mut report = FleetReport {
            devices: 3,
            seed: 42,
            duration_s: 1.0,
            policy: "adaoper".into(),
            scheduler: "edf".into(),
            per_class,
            fleet: ClassAgg::empty(),
            telemetry: None,
        };
        report.fleet.absorb(&fake_report(5, 1.0, 0.1));
        // alert-free run: table unchanged
        assert!(!report.render().contains("health alerts"));
        report.fleet = agg;
        let out = report.render();
        assert!(out.contains("health alerts:"), "{out}");
        assert!(out.contains("6 alerts (4 warn / 2 critical, 2 drift)"), "{out}");
    }

    #[test]
    fn config_validation_rejects_nonsense() {
        let cfg = FleetRunConfig {
            devices: 0,
            ..Default::default()
        };
        let offline: [Option<OfflineModel>; 3] = [None, None, None];
        assert!(run_fleet_with(&cfg, &offline).is_err());
        let cfg = FleetRunConfig {
            threads: 0,
            ..Default::default()
        };
        assert!(run_fleet_with(&cfg, &offline).is_err());
        let cfg = FleetRunConfig {
            duration_s: 0.01,
            ..Default::default()
        };
        assert!(run_fleet_with(&cfg, &offline).is_err());
        // valid shape but missing offline models for sampled classes
        let cfg = FleetRunConfig::default();
        assert!(run_fleet_with(&cfg, &offline).is_err());
    }
}
