//! Device-class zoo: the hardware tiers a production fleet actually spans,
//! plus the seeded sampler that assigns each simulated device a class, a
//! workload condition, and a stream/SLO profile.
//!
//! Real deployments are not a lab full of Snapdragon 855s: SoC tiers,
//! thermal envelopes and background-load regimes vary wildly across the
//! installed base ("Smart at what cost?", Almeida et al.), and the
//! energy/latency trade-offs the planner exploits invert across hardware
//! (Liu et al.). Three calibrated tiers cover that spread:
//!
//! * **flagship** — the paper's Snapdragon-855 parameterization, verbatim.
//! * **midrange** — SD7-series class: ~0.8× clocks, half the NEON width,
//!   a much narrower GPU, slower shared-memory path.
//! * **budget** — entry class: ~0.6× clocks, quarter-width SIMD, a small
//!   GPU that barely beats the CPU, contended DRAM.
//!
//! Determinism contract: every per-device quantity is derived from the
//! fleet seed through [`device_seed`] (a `splitmix64` jump to the device's
//! index), so a fleet sample is reproducible from `(seed, index)` alone,
//! independent of device count prefixes or runner thread count.

use anyhow::{bail, Result};

use crate::config::schema::ConditionKind;
use crate::soc::device::{ConditionSpec, DeviceConfig};
use crate::soc::latency::ComputeParams;
use crate::soc::opp::{Opp, OppTable};
use crate::soc::power::PowerParams;
use crate::soc::transfer::TransferParams;
use crate::util::prng::{splitmix64, Prng, SPLITMIX64_GAMMA};
use crate::workload::WorkloadCondition;

/// Hardware tier of a simulated device.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceClass {
    /// Snapdragon-855 class (the paper's testbed).
    Flagship,
    /// SD7-series class: scaled clocks, narrower compute.
    MidRange,
    /// Entry class: slow clocks, small GPU, contended memory.
    Budget,
}

impl DeviceClass {
    /// Every class, in the fixed order reports print them.
    pub fn all() -> [DeviceClass; 3] {
        [DeviceClass::Flagship, DeviceClass::MidRange, DeviceClass::Budget]
    }

    /// Canonical spelling.
    pub fn name(&self) -> &'static str {
        match self {
            DeviceClass::Flagship => "flagship",
            DeviceClass::MidRange => "midrange",
            DeviceClass::Budget => "budget",
        }
    }

    /// Parse a CLI/TOML spelling.
    pub fn parse(s: &str) -> Result<DeviceClass> {
        Ok(match s {
            "flagship" => DeviceClass::Flagship,
            "midrange" | "mid-range" | "mid" => DeviceClass::MidRange,
            "budget" => DeviceClass::Budget,
            other => bail!("unknown device class `{other}` (flagship|midrange|budget)"),
        })
    }

    /// Stable index (flagship 0, midrange 1, budget 2) for array-keyed
    /// per-class state (offline models, aggregates).
    pub fn index(&self) -> usize {
        match self {
            DeviceClass::Flagship => 0,
            DeviceClass::MidRange => 1,
            DeviceClass::Budget => 2,
        }
    }

    /// CPU frequency scale relative to the flagship OPP table.
    fn cpu_freq_scale(&self) -> f64 {
        match self {
            DeviceClass::Flagship => 1.0,
            DeviceClass::MidRange => 0.80,
            DeviceClass::Budget => 0.60,
        }
    }

    /// GPU frequency scale relative to the flagship OPP table.
    fn gpu_freq_scale(&self) -> f64 {
        match self {
            DeviceClass::Flagship => 1.0,
            DeviceClass::MidRange => 0.75,
            DeviceClass::Budget => 0.55,
        }
    }

    /// The class's full device parameterization.
    pub fn device_config(&self) -> DeviceConfig {
        let base = DeviceConfig::snapdragon_855();
        match self {
            DeviceClass::Flagship => base,
            DeviceClass::MidRange => DeviceConfig {
                cpu_opps: scale_opps(&base.cpu_opps, self.cpu_freq_scale()),
                gpu_opps: scale_opps(&base.gpu_opps, self.gpu_freq_scale()),
                cpu_power: PowerParams {
                    c_eff: 0.70e-9,
                    p_static: 0.12,
                },
                gpu_power: PowerParams {
                    c_eff: 5.5e-9,
                    p_static: 0.08,
                },
                cpu_compute: ComputeParams {
                    flops_per_cycle: 32.0,
                    mem_bw: 10.0e9,
                    dispatch_first: 30e-6,
                    dispatch_next: 10e-6,
                },
                gpu_compute: ComputeParams {
                    flops_per_cycle: 768.0,
                    mem_bw: 14.0e9,
                    dispatch_first: 130e-6,
                    dispatch_next: 22e-6,
                },
                transfer: TransferParams {
                    map_overhead_s: 100e-6,
                    bw: 8.0e9,
                    energy_per_byte: 0.26e-9,
                    map_energy_j: 0.14e-3,
                },
                noise_sigma: 0.05,
                drift_sigma: 0.06,
                thrash: 0.55,
                split_sync_s: 40e-6,
                seed: 0xAD40_0E58,
            },
            DeviceClass::Budget => DeviceConfig {
                cpu_opps: scale_opps(&base.cpu_opps, self.cpu_freq_scale()),
                gpu_opps: scale_opps(&base.gpu_opps, self.gpu_freq_scale()),
                cpu_power: PowerParams {
                    c_eff: 0.55e-9,
                    p_static: 0.10,
                },
                gpu_power: PowerParams {
                    c_eff: 3.2e-9,
                    p_static: 0.07,
                },
                cpu_compute: ComputeParams {
                    flops_per_cycle: 16.0,
                    mem_bw: 6.5e9,
                    dispatch_first: 40e-6,
                    dispatch_next: 14e-6,
                },
                gpu_compute: ComputeParams {
                    flops_per_cycle: 256.0,
                    mem_bw: 9.0e9,
                    dispatch_first: 160e-6,
                    dispatch_next: 30e-6,
                },
                transfer: TransferParams {
                    map_overhead_s: 140e-6,
                    bw: 5.5e9,
                    energy_per_byte: 0.30e-9,
                    map_energy_j: 0.16e-3,
                },
                noise_sigma: 0.06,
                drift_sigma: 0.07,
                thrash: 0.60,
                split_sync_s: 50e-6,
                seed: 0xAD40_0E59,
            },
        }
    }

    /// The paper's condition preset rescaled to this class's OPP tables:
    /// pinned frequencies scale with the class (a budget phone's "high"
    /// condition pins a budget clock, not a flagship one); background-load
    /// statistics are tier-independent.
    pub fn condition(&self, kind: ConditionKind) -> ConditionSpec {
        let mut spec = WorkloadCondition::by_name(kind.name())
            .expect("every ConditionKind has a preset")
            .spec;
        spec.cpu_freq_hz = spec.cpu_freq_hz.map(|f| f * self.cpu_freq_scale());
        spec.gpu_freq_hz = spec.gpu_freq_hz.map(|f| f * self.gpu_freq_scale());
        spec
    }
}

/// Scale an OPP table's frequencies, preserving the voltage ramp (ordering
/// invariants hold because scaling is monotone).
fn scale_opps(base: &OppTable, scale: f64) -> OppTable {
    OppTable::new(
        base.points
            .iter()
            .map(|p| Opp {
                freq_hz: p.freq_hz * scale,
                volt: p.volt,
            })
            .collect(),
    )
}

/// The `index`-th seed of the splitmix64 stream rooted at `fleet_seed` —
/// an O(1) jump (state advances by the golden gamma per step), so
/// per-device seeds are independent of how many devices precede them and
/// of runner thread count.
pub fn device_seed(fleet_seed: u64, index: u64) -> u64 {
    let mut state = fleet_seed.wrapping_add(index.wrapping_mul(SPLITMIX64_GAMMA));
    splitmix64(&mut state)
}

/// Population mix the sampler draws each device from.
#[derive(Debug, Clone)]
pub struct FleetMix {
    /// Class weights, parallel to [`DeviceClass::all`] (need not sum to 1).
    pub class_weights: [f64; 3],
    /// Condition weights for `[idle, moderate, high]`.
    pub condition_weights: [f64; 3],
    /// Model-zoo names each device's stream is drawn from uniformly.
    pub models: Vec<String>,
    /// Per-stream frame rate, sampled uniformly from this range (Hz).
    pub rate_hz: (f64, f64),
    /// Per-request SLO, sampled uniformly from this range (milliseconds).
    pub slo_ms: (f64, f64),
}

impl Default for FleetMix {
    fn default() -> Self {
        FleetMix {
            // the installed base skews mid/budget, not flagship
            class_weights: [0.2, 0.5, 0.3],
            condition_weights: [0.25, 0.5, 0.25],
            models: vec!["yolov2_tiny".to_string(), "mobilenetv1".to_string()],
            rate_hz: (2.0, 6.0),
            slo_ms: (150.0, 400.0),
        }
    }
}

/// One simulated device, fully specified: everything its engine run needs.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    /// Position in the fleet (also the seed-derivation index).
    pub index: usize,
    /// Hardware tier.
    pub class: DeviceClass,
    /// Workload condition the device serves under.
    pub condition: ConditionKind,
    /// Model-zoo name of the device's stream.
    pub model: String,
    /// Stream frame rate, Hz.
    pub rate_hz: f64,
    /// Per-request SLO, seconds.
    pub slo_s: f64,
    /// Engine seed (workload arrivals + simulator noise).
    pub seed: u64,
}

fn pick_weighted(rng: &mut Prng, weights: &[f64]) -> usize {
    let total: f64 = weights.iter().sum();
    assert!(
        total > 0.0 && weights.iter().all(|w| *w >= 0.0),
        "sampling weights must be non-negative with a positive sum, got {weights:?}"
    );
    let mut x = rng.f64() * total;
    for (i, w) in weights.iter().enumerate() {
        x -= w;
        if x < 0.0 {
            return i;
        }
    }
    weights.len() - 1
}

/// Whether a mix is samplable: at least one model, and both weight vectors
/// non-negative with positive sums. [`sample_fleet`] asserts this; the
/// runner turns it into a user-facing error first.
pub fn mix_is_valid(mix: &FleetMix) -> bool {
    let ok = |w: &[f64]| w.iter().all(|x| *x >= 0.0) && w.iter().sum::<f64>() > 0.0;
    !mix.models.is_empty() && ok(&mix.class_weights) && ok(&mix.condition_weights)
}

/// Sample `n` device specs from `mix`, deterministically from `fleet_seed`.
/// Prefix-stable: device `i` is identical for any fleet size > `i`.
pub fn sample_fleet(fleet_seed: u64, n: usize, mix: &FleetMix) -> Vec<DeviceSpec> {
    assert!(mix_is_valid(mix), "invalid fleet mix (models/weights)");
    let conditions = [ConditionKind::Idle, ConditionKind::Moderate, ConditionKind::High];
    (0..n)
        .map(|i| {
            let mut rng = Prng::new(device_seed(fleet_seed, i as u64));
            let class = DeviceClass::all()[pick_weighted(&mut rng, &mix.class_weights)];
            let condition = conditions[pick_weighted(&mut rng, &mix.condition_weights)];
            let model = rng.choose(&mix.models).clone();
            let rate_hz = rng.range(mix.rate_hz.0, mix.rate_hz.1);
            let slo_s = rng.range(mix.slo_ms.0, mix.slo_ms.1) / 1e3;
            let seed = rng.next_u64();
            DeviceSpec {
                index: i,
                class,
                condition,
                model,
                rate_hz,
                slo_s,
                seed,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo as model_zoo;
    use crate::soc::device::{Device, ExecCtx};
    use crate::soc::Placement;

    #[test]
    fn class_roundtrip_and_indices() {
        for (i, c) in DeviceClass::all().iter().enumerate() {
            assert_eq!(DeviceClass::parse(c.name()).unwrap(), *c);
            assert_eq!(c.index(), i);
        }
        assert!(DeviceClass::parse("ultra").is_err());
    }

    #[test]
    fn device_configs_are_valid_and_ordered() {
        // OppTable::new asserts ordering invariants at construction
        let f = DeviceClass::Flagship.device_config();
        let m = DeviceClass::MidRange.device_config();
        let b = DeviceClass::Budget.device_config();
        assert!(f.cpu_opps.max().freq_hz > m.cpu_opps.max().freq_hz);
        assert!(m.cpu_opps.max().freq_hz > b.cpu_opps.max().freq_hz);
        assert!(f.cpu_compute.flops_per_cycle > m.cpu_compute.flops_per_cycle);
        assert!(m.cpu_compute.flops_per_cycle > b.cpu_compute.flops_per_cycle);
        assert!(f.gpu_compute.flops_per_cycle > b.gpu_compute.flops_per_cycle);
    }

    #[test]
    fn conditions_scale_with_class() {
        let f = DeviceClass::Flagship.condition(ConditionKind::Moderate);
        let b = DeviceClass::Budget.condition(ConditionKind::Moderate);
        assert_eq!(f.cpu_freq_hz, Some(1.49e9));
        assert!(b.cpu_freq_hz.unwrap() < f.cpu_freq_hz.unwrap());
        assert!(b.gpu_freq_hz.unwrap() < f.gpu_freq_hz.unwrap());
        // background statistics are tier-independent
        assert_eq!(f.cpu_bg_mean, b.cpu_bg_mean);
    }

    #[test]
    fn budget_slower_than_flagship_on_heavy_conv() {
        let g = model_zoo::yolov2();
        let op = &g.ops[2];
        let run = |class: DeviceClass| {
            let mut d = Device::new(class.device_config());
            d.apply_condition(&class.condition(ConditionKind::Moderate));
            let cpu = d
                .expected_cost(op, Placement::CPU, &ExecCtx::fresh(vec![1.0]))
                .latency_s;
            let gpu = d
                .expected_cost(op, Placement::GPU, &ExecCtx::fresh(vec![0.0]))
                .latency_s;
            (cpu, gpu)
        };
        let (fc, fg) = run(DeviceClass::Flagship);
        let (bc, bg) = run(DeviceClass::Budget);
        assert!(bc > 2.0 * fc, "budget cpu {bc} vs flagship {fc}");
        assert!(bg > 2.0 * fg, "budget gpu {bg} vs flagship {fg}");
    }

    #[test]
    fn device_seed_is_a_splitmix_jump() {
        // walking the stream step by step must agree with the O(1) jump
        let mut state = 42u64;
        for i in 0..16u64 {
            let walked = splitmix64(&mut state);
            assert_eq!(walked, device_seed(42, i), "index {i}");
        }
    }

    #[test]
    fn sampling_is_deterministic_and_prefix_stable() {
        let mix = FleetMix::default();
        let a = sample_fleet(7, 50, &mix);
        let b = sample_fleet(7, 50, &mix);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.seed, y.seed);
            assert_eq!(x.class, y.class);
            assert_eq!(x.model, y.model);
            assert_eq!(x.rate_hz, y.rate_hz);
        }
        // prefix stability: the first 20 of 50 equal a 20-device fleet
        let small = sample_fleet(7, 20, &mix);
        for (x, y) in small.iter().zip(&a) {
            assert_eq!(x.seed, y.seed);
            assert_eq!(x.class, y.class);
        }
        // a different seed yields a different fleet
        let c = sample_fleet(8, 50, &mix);
        assert!(a.iter().zip(&c).any(|(x, y)| x.seed != y.seed));
    }

    #[test]
    fn degenerate_mixes_are_rejected() {
        assert!(mix_is_valid(&FleetMix::default()));
        let mut no_models = FleetMix::default();
        no_models.models.clear();
        assert!(!mix_is_valid(&no_models));
        let zero_weights = FleetMix {
            class_weights: [0.0, 0.0, 0.0],
            ..FleetMix::default()
        };
        assert!(!mix_is_valid(&zero_weights));
        let negative = FleetMix {
            condition_weights: [0.5, -0.1, 0.6],
            ..FleetMix::default()
        };
        assert!(!mix_is_valid(&negative));
    }

    #[test]
    fn sampled_mix_tracks_weights_and_ranges() {
        let mix = FleetMix::default();
        let specs = sample_fleet(123, 3000, &mix);
        let frac = |class| {
            specs.iter().filter(|s| s.class == class).count() as f64 / specs.len() as f64
        };
        assert!((frac(DeviceClass::Flagship) - 0.2).abs() < 0.05);
        assert!((frac(DeviceClass::MidRange) - 0.5).abs() < 0.05);
        assert!((frac(DeviceClass::Budget) - 0.3).abs() < 0.05);
        for s in &specs {
            assert!(s.rate_hz >= mix.rate_hz.0 && s.rate_hz < mix.rate_hz.1);
            assert!(s.slo_s >= mix.slo_ms.0 / 1e3 && s.slo_s < mix.slo_ms.1 / 1e3);
            assert!(model_zoo::by_name(&s.model).is_some(), "unknown {}", s.model);
        }
    }
}
