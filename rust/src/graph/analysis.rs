//! Graph analytics consumed by the partitioner and the benches: live-value
//! frontiers (sizing the DP state space), chain segmentation (CoDL's
//! grouping granularity), and FLOP/byte distributions.

use super::graph::{ModelGraph, OpId};

/// For every op index i, the set of ops whose outputs are still *live*
/// (needed by some op ≥ i) just before executing op i, **excluding** the
/// linear predecessor i−1. These are the extra assignments the frontier DP
/// must remember. Empty everywhere for pure chains.
pub fn live_extras(g: &ModelGraph) -> Vec<Vec<OpId>> {
    let last = g.last_use();
    let n = g.num_ops();
    let mut out = vec![Vec::new(); n];
    for i in 0..n {
        for j in 0..i {
            // j is live at i if some consumer of j executes at or after i.
            if last[j] >= i && j + 1 != i {
                // exclude the immediate predecessor (tracked by the DP
                // chain state itself)
                if g.ops[i].inputs.contains(&j) || last[j] > i {
                    out[i].push(j);
                }
            }
        }
    }
    out
}

/// Maximum number of simultaneously live op outputs across the graph
/// (the DP's frontier width). Chains → 1.
pub fn max_frontier(g: &ModelGraph) -> usize {
    let last = g.last_use();
    let n = g.num_ops();
    let mut max_live = 1;
    for i in 0..n {
        let live = (0..i).filter(|&j| last[j] >= i).count();
        max_live = max_live.max(live.max(1));
    }
    max_live
}

/// A maximal straight-line run of ops (no fan-in/fan-out inside). CoDL
/// groups these into co-execution "chains" to amortize map/unmap overhead.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Segment {
    /// Member ops, in topological order.
    pub ops: Vec<OpId>,
}

/// Split the (topologically ordered) op list into straight-line segments.
/// A segment breaks after op i when op i has ≠1 consumers or its consumer
/// is not i+1, and before op i when op i has ≠1 inputs.
pub fn segments(g: &ModelGraph) -> Vec<Segment> {
    let mut segs = Vec::new();
    let mut cur: Vec<OpId> = Vec::new();
    for i in 0..g.num_ops() {
        let op = &g.ops[i];
        let starts_new = op.inputs.len() != 1 || op.inputs[0] + 1 != i;
        if starts_new && !cur.is_empty() {
            segs.push(Segment {
                ops: std::mem::take(&mut cur),
            });
        }
        cur.push(i);
        let ends = g.consumers[i].len() != 1 || g.consumers[i][0] != i + 1;
        if ends {
            segs.push(Segment {
                ops: std::mem::take(&mut cur),
            });
        }
    }
    if !cur.is_empty() {
        segs.push(Segment { ops: cur });
    }
    segs
}

/// FLOP share of the top-k heaviest operators (perf reporting).
pub fn flop_concentration(g: &ModelGraph, k: usize) -> f64 {
    let mut fl: Vec<u64> = g.ops.iter().map(|o| o.flops).collect();
    fl.sort_unstable_by(|a, b| b.cmp(a));
    let total: u64 = fl.iter().sum();
    if total == 0 {
        return 0.0;
    }
    fl.iter().take(k).sum::<u64>() as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;

    #[test]
    fn chain_has_frontier_one_and_one_segment_per_run() {
        let g = zoo::yolov2_tiny();
        assert_eq!(max_frontier(&g), 1);
        let segs = segments(&g);
        let total: usize = segs.iter().map(|s| s.ops.len()).sum();
        assert_eq!(total, g.num_ops());
        // pure chain → a single maximal segment
        assert_eq!(segs.len(), 1);
    }

    #[test]
    fn yolov2_frontier_two() {
        // conv13's output stays live from pool5 through the route concat
        let g = zoo::yolov2();
        assert_eq!(max_frontier(&g), 2);
    }

    #[test]
    fn resnet_frontier_two() {
        let g = zoo::resnet18();
        assert_eq!(max_frontier(&g), 2);
    }

    #[test]
    fn segments_cover_all_ops_once() {
        for name in zoo::names() {
            let g = zoo::by_name(name).unwrap();
            let segs = segments(&g);
            let mut seen = vec![false; g.num_ops()];
            for s in &segs {
                for &i in &s.ops {
                    assert!(!seen[i], "{name}: op {i} in two segments");
                    seen[i] = true;
                }
                // segment interior must be straight-line
                for w in s.ops.windows(2) {
                    assert_eq!(g.ops[w[1]].inputs, vec![w[0]], "{name}: non-chain interior");
                }
            }
            assert!(seen.iter().all(|&x| x), "{name}: op missing from segments");
        }
    }

    #[test]
    fn live_extras_empty_for_chains() {
        let g = zoo::yolov2_tiny();
        assert!(live_extras(&g).iter().all(|v| v.is_empty()));
    }

    #[test]
    fn live_extras_nonempty_for_yolov2() {
        let g = zoo::yolov2();
        let extras = live_extras(&g);
        assert!(extras.iter().any(|v| !v.is_empty()));
    }

    #[test]
    fn flop_concentration_monotone() {
        let g = zoo::yolov2();
        let c1 = flop_concentration(&g, 1);
        let c5 = flop_concentration(&g, 5);
        let call = flop_concentration(&g, g.num_ops());
        assert!(c1 <= c5 && c5 <= call);
        assert!((call - 1.0).abs() < 1e-12);
    }
}
