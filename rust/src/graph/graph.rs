//! Model graph: a DAG of operator nodes in topological order, plus the
//! builder the zoo uses. The node list is *always* stored topologically
//! sorted (the builder can only reference existing nodes), which the
//! partitioner's bottom-up DP relies on.

use std::collections::HashMap;

use anyhow::{bail, Result};

use super::op::OpKind;
use super::tensor::Shape;

/// Index of an operator node within its graph.
pub type OpId = usize;

/// One operator instance.
#[derive(Debug, Clone)]
pub struct OpNode {
    /// Topological index in the graph.
    pub id: OpId,
    /// Unique op name (e.g. `conv3`).
    pub name: String,
    /// Operator kind with its parameters.
    pub kind: OpKind,
    /// Producer ops (empty → consumes the model input).
    pub inputs: Vec<OpId>,
    /// Shape of each input tensor (parallel to `inputs`).
    pub in_shapes: Vec<Shape>,
    /// Output tensor shape.
    pub out_shape: Shape,
    /// Multiply-accumulate work, FLOPs.
    pub flops: u64,
    /// Parameter bytes read per execution.
    pub weight_bytes: u64,
    /// Activation bytes moved per execution.
    pub activation_bytes: u64,
}

impl OpNode {
    /// Arithmetic intensity: FLOPs per byte of activation traffic.
    pub fn arithmetic_intensity(&self) -> f64 {
        if self.activation_bytes == 0 {
            0.0
        } else {
            self.flops as f64 / self.activation_bytes as f64
        }
    }
}

/// A DNN model as a topologically ordered operator DAG.
#[derive(Debug, Clone)]
pub struct ModelGraph {
    /// Model name (zoo key).
    pub name: String,
    /// Model input tensor shape.
    pub input_shape: Shape,
    /// Operators in topological order (`ops[i].id == i`).
    pub ops: Vec<OpNode>,
    /// consumers[i] = ops that read op i's output.
    pub consumers: Vec<Vec<OpId>>,
}

impl ModelGraph {
    /// Number of operators.
    pub fn num_ops(&self) -> usize {
        self.ops.len()
    }

    /// Ids of ops whose output is the model output (no consumers).
    pub fn outputs(&self) -> Vec<OpId> {
        (0..self.ops.len())
            .filter(|&i| self.consumers[i].is_empty())
            .collect()
    }

    /// Total FLOPs over all ops.
    pub fn total_flops(&self) -> u64 {
        self.ops.iter().map(|o| o.flops).sum()
    }

    /// Total parameter bytes.
    pub fn total_weight_bytes(&self) -> u64 {
        self.ops.iter().map(|o| o.weight_bytes).sum()
    }

    /// Validate topological order and shape consistency.
    pub fn validate(&self) -> Result<()> {
        if self.ops.is_empty() {
            bail!("graph `{}` has no operators", self.name);
        }
        for (i, op) in self.ops.iter().enumerate() {
            if op.id != i {
                bail!("op {} has id {} (must equal index)", i, op.id);
            }
            if op.inputs.len() != op.kind.arity() && !op.inputs.is_empty() {
                bail!(
                    "op {} `{}` has {} inputs, kind arity {}",
                    i,
                    op.name,
                    op.inputs.len(),
                    op.kind.arity()
                );
            }
            for &j in &op.inputs {
                if j >= i {
                    bail!("op {} reads op {} — not topologically ordered", i, j);
                }
            }
            let expect = op.kind.out_shape(&op.in_shapes);
            if expect != op.out_shape {
                bail!(
                    "op {} `{}` out shape {} != computed {}",
                    i,
                    op.name,
                    op.out_shape,
                    expect
                );
            }
        }
        Ok(())
    }

    /// For each op, the id of the last op that reads its output (used by
    /// the frontier DP to know when an assignment can be dropped). Output
    /// ops get `num_ops` (live until the end).
    pub fn last_use(&self) -> Vec<usize> {
        let n = self.ops.len();
        (0..n)
            .map(|i| self.consumers[i].iter().copied().max().unwrap_or(n))
            .collect()
    }

    /// Human-readable per-op table (CLI `zoo` subcommand).
    pub fn describe(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "model {} input {} ops {} GFLOPs {:.2} weights {:.1} MB\n",
            self.name,
            self.input_shape,
            self.ops.len(),
            self.total_flops() as f64 / 1e9,
            self.total_weight_bytes() as f64 / 1e6
        ));
        for op in &self.ops {
            s.push_str(&format!(
                "  [{:>3}] {:<22} {:<16} out {:<16} {:>10.1} MFLOP {:>8.2} MB act\n",
                op.id,
                op.name,
                op.kind.to_string(),
                op.out_shape.to_string(),
                op.flops as f64 / 1e6,
                op.activation_bytes as f64 / 1e6,
            ));
        }
        s
    }
}

/// Incremental graph builder. Ops must reference already-built nodes, so
/// the result is topologically sorted by construction.
pub struct GraphBuilder {
    name: String,
    input_shape: Shape,
    ops: Vec<OpNode>,
    names: HashMap<String, OpId>,
}

/// Source of an op's input: the model input or a previous op.
#[derive(Debug, Clone, Copy)]
pub enum Src {
    /// The model input tensor.
    Input,
    /// The output of a previous op.
    Op(OpId),
}

impl GraphBuilder {
    /// Start an empty graph for a model.
    pub fn new(name: &str, input_shape: Shape) -> Self {
        GraphBuilder {
            name: name.to_string(),
            input_shape,
            ops: Vec::new(),
            names: HashMap::new(),
        }
    }

    fn shape_of(&self, src: Src) -> Shape {
        match src {
            Src::Input => self.input_shape,
            Src::Op(id) => self.ops[id].out_shape,
        }
    }

    /// Append an operator; returns its id.
    pub fn push(&mut self, name: &str, kind: OpKind, srcs: &[Src]) -> OpId {
        assert_eq!(
            srcs.len(),
            kind.arity(),
            "op `{name}` arity mismatch"
        );
        let in_shapes: Vec<Shape> = srcs.iter().map(|&s| self.shape_of(s)).collect();
        let inputs: Vec<OpId> = srcs
            .iter()
            .filter_map(|s| match s {
                Src::Op(id) => Some(*id),
                Src::Input => None,
            })
            .collect();
        let out_shape = kind.out_shape(&in_shapes);
        let id = self.ops.len();
        assert!(
            self.names.insert(name.to_string(), id).is_none(),
            "duplicate op name `{name}`"
        );
        self.ops.push(OpNode {
            id,
            name: name.to_string(),
            kind,
            inputs,
            in_shapes: in_shapes.clone(),
            out_shape,
            flops: kind.flops(&in_shapes, out_shape),
            weight_bytes: kind.weight_bytes(&in_shapes),
            activation_bytes: kind.activation_bytes(&in_shapes, out_shape),
        });
        id
    }

    /// Finish: compute consumer lists and validate.
    pub fn build(self) -> ModelGraph {
        let mut consumers = vec![Vec::new(); self.ops.len()];
        for op in &self.ops {
            for &j in &op.inputs {
                consumers[j].push(op.id);
            }
        }
        let g = ModelGraph {
            name: self.name,
            input_shape: self.input_shape,
            ops: self.ops,
            consumers,
        };
        g.validate().expect("builder produced invalid graph");
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::op::ActKind;

    fn conv(oc: usize) -> OpKind {
        OpKind::Conv2d {
            kernel: 3,
            stride: 1,
            pad: 1,
            out_c: oc,
            groups: 1,
            act: ActKind::Leaky,
        }
    }

    #[test]
    fn chain_builds_and_validates() {
        let mut b = GraphBuilder::new("t", Shape::nchw(1, 3, 32, 32));
        let c1 = b.push("c1", conv(8), &[Src::Input]);
        let p1 = b.push("p1", OpKind::MaxPool { kernel: 2, stride: 2 }, &[Src::Op(c1)]);
        let c2 = b.push("c2", conv(16), &[Src::Op(p1)]);
        let g = b.build();
        assert_eq!(g.num_ops(), 3);
        assert_eq!(g.outputs(), vec![c2]);
        assert_eq!(g.consumers[c1], vec![p1]);
        g.validate().unwrap();
    }

    #[test]
    fn dag_with_skip_connection() {
        let mut b = GraphBuilder::new("skip", Shape::nchw(1, 8, 16, 16));
        let c1 = b.push("c1", conv(8), &[Src::Input]);
        let c2 = b.push("c2", conv(8), &[Src::Op(c1)]);
        let add = b.push("add", OpKind::Add, &[Src::Op(c1), Src::Op(c2)]);
        let g = b.build();
        assert_eq!(g.outputs(), vec![add]);
        // c1 feeds both c2 and add
        assert_eq!(g.consumers[c1], vec![c2, add]);
        let lu = g.last_use();
        assert_eq!(lu[c1], add);
        assert_eq!(lu[add], g.num_ops());
    }

    #[test]
    fn total_flops_sums() {
        let mut b = GraphBuilder::new("t", Shape::nchw(1, 3, 8, 8));
        b.push("c1", conv(4), &[Src::Input]);
        let g = b.build();
        assert_eq!(g.total_flops(), g.ops[0].flops);
    }

    #[test]
    #[should_panic]
    fn duplicate_name_panics() {
        let mut b = GraphBuilder::new("t", Shape::nchw(1, 3, 8, 8));
        b.push("x", conv(4), &[Src::Input]);
        b.push("x", conv(4), &[Src::Input]);
    }

    #[test]
    fn describe_contains_ops() {
        let mut b = GraphBuilder::new("t", Shape::nchw(1, 3, 8, 8));
        b.push("c1", conv(4), &[Src::Input]);
        let g = b.build();
        let d = g.describe();
        assert!(d.contains("c1"));
        assert!(d.contains("model t"));
    }
}
