//! DNN graph intermediate representation.
//!
//! The partitioner, profiler and SoC simulator all operate on this IR: a
//! DAG of operators with NCHW tensor shapes and exact FLOP / byte
//! analytics. The zoo ([`zoo`]) provides the paper's workload (YOLOv2) and
//! companions (YOLOv2-tiny, MobileNetV1, ResNet-18) plus the small
//! executable model whose blocks are AOT-compiled to HLO artifacts.

pub mod analysis;
pub mod graph;
pub mod op;
pub mod tensor;
pub mod zoo;

pub use graph::{GraphBuilder, ModelGraph, OpId, OpNode};
pub use op::{ActKind, OpKind};
pub use tensor::Shape;
