//! Operator kinds and their compute / memory cost formulas.
//!
//! The cost formulas (FLOPs, weight bytes, activation traffic) are exact
//! functions of the operator parameters and input shape — they are what the
//! SoC latency/energy model and the profiler features consume. BatchNorm is
//! assumed folded into the preceding convolution (standard for mobile
//! inference engines like MACE/TFLite); a standalone `BatchNorm` kind exists
//! for un-fused graphs.

use std::fmt;

use super::tensor::Shape;

/// Fused activation functions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ActKind {
    /// No fused activation.
    None,
    /// Standard ReLU.
    Relu,
    /// Leaky ReLU (YOLO uses slope 0.1).
    Leaky,
    /// Linear output (detection heads).
    Linear,
}

/// Operator kind with compile-time parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// 2-D convolution (+ folded BN + fused activation).
    /// `groups == in_c` expresses a depthwise convolution.
    Conv2d {
        /// Square kernel size.
        kernel: usize,
        /// Stride in both spatial dims.
        stride: usize,
        /// Symmetric zero padding.
        pad: usize,
        /// Output channels.
        out_c: usize,
        /// Channel groups (`groups == in_c` → depthwise).
        groups: usize,
        /// Fused activation.
        act: ActKind,
    },
    /// Max pooling.
    MaxPool {
        /// Square window size.
        kernel: usize,
        /// Stride in both spatial dims.
        stride: usize,
    },
    /// Global average pool to 1×1.
    AvgPoolGlobal,
    /// Dense layer.
    FullyConnected {
        /// Output feature count.
        out_features: usize,
    },
    /// Standalone activation (un-fused graphs only).
    Activation(ActKind),
    /// Standalone batch normalization (un-fused graphs only).
    BatchNorm,
    /// Elementwise sum of two equal-shape inputs (residual add).
    Add,
    /// Channel concatenation of two inputs with equal spatial dims.
    Concat,
    /// Space-to-depth (YOLOv2 "reorg"): H,W ↓ stride, C × stride².
    Reorg {
        /// Spatial downscale factor.
        stride: usize,
    },
    /// Nearest-neighbour upsample.
    Upsample {
        /// Spatial upscale factor.
        factor: usize,
    },
    /// Channel softmax (classifier heads).
    Softmax,
}

impl OpKind {
    /// Short kind label (profiler feature + display).
    pub fn label(&self) -> &'static str {
        match self {
            OpKind::Conv2d { groups, kernel, .. } => {
                if *groups > 1 {
                    "dwconv"
                } else if *kernel == 1 {
                    "conv1x1"
                } else {
                    "conv"
                }
            }
            OpKind::MaxPool { .. } => "maxpool",
            OpKind::AvgPoolGlobal => "avgpool",
            OpKind::FullyConnected { .. } => "fc",
            OpKind::Activation(_) => "act",
            OpKind::BatchNorm => "bn",
            OpKind::Add => "add",
            OpKind::Concat => "concat",
            OpKind::Reorg { .. } => "reorg",
            OpKind::Upsample { .. } => "upsample",
            OpKind::Softmax => "softmax",
        }
    }

    /// Stable small integer id of the kind (profiler one-hot feature).
    pub fn kind_id(&self) -> usize {
        match self {
            OpKind::Conv2d { groups, kernel, .. } => {
                if *groups > 1 {
                    1
                } else if *kernel == 1 {
                    2
                } else {
                    0
                }
            }
            OpKind::MaxPool { .. } => 3,
            OpKind::AvgPoolGlobal => 4,
            OpKind::FullyConnected { .. } => 5,
            OpKind::Activation(_) => 6,
            OpKind::BatchNorm => 7,
            OpKind::Add => 8,
            OpKind::Concat => 9,
            OpKind::Reorg { .. } => 10,
            OpKind::Upsample { .. } => 11,
            OpKind::Softmax => 12,
        }
    }

    /// Number of distinct `kind_id` values.
    pub const NUM_KINDS: usize = 13;

    /// Output shape given the input shapes (1 or 2 inputs).
    pub fn out_shape(&self, inputs: &[Shape]) -> Shape {
        match *self {
            OpKind::Conv2d {
                kernel,
                stride,
                pad,
                out_c,
                groups,
                ..
            } => {
                let x = inputs[0];
                assert!(
                    x.c % groups == 0,
                    "groups {groups} must divide in_c {}",
                    x.c
                );
                x.conv_out(out_c, kernel, stride, pad)
            }
            OpKind::MaxPool { kernel, stride } => inputs[0].pool_out(kernel, stride),
            OpKind::AvgPoolGlobal => Shape::vec(inputs[0].n, inputs[0].c),
            OpKind::FullyConnected { out_features } => Shape::vec(inputs[0].n, out_features),
            OpKind::Activation(_) | OpKind::BatchNorm | OpKind::Softmax => inputs[0],
            OpKind::Add => {
                assert_eq!(inputs[0], inputs[1], "Add requires equal shapes");
                inputs[0]
            }
            OpKind::Concat => {
                let (a, b) = (inputs[0], inputs[1]);
                assert_eq!((a.n, a.h, a.w), (b.n, b.h, b.w), "Concat spatial mismatch");
                Shape::nchw(a.n, a.c + b.c, a.h, a.w)
            }
            OpKind::Reorg { stride } => {
                let x = inputs[0];
                assert!(x.h % stride == 0 && x.w % stride == 0);
                Shape::nchw(x.n, x.c * stride * stride, x.h / stride, x.w / stride)
            }
            OpKind::Upsample { factor } => {
                let x = inputs[0];
                Shape::nchw(x.n, x.c, x.h * factor, x.w * factor)
            }
        }
    }

    /// Floating-point operations for this operator (multiply-accumulate
    /// counted as 2 FLOPs, the convention MACE/CoDL use).
    pub fn flops(&self, inputs: &[Shape], out: Shape) -> u64 {
        match *self {
            OpKind::Conv2d {
                kernel, groups, ..
            } => {
                let in_c = inputs[0].c as u64;
                let macs = out.elems() * (kernel as u64 * kernel as u64 * in_c / groups as u64);
                2 * macs + out.elems() // +bias/act
            }
            OpKind::MaxPool { kernel, .. } => out.elems() * (kernel as u64 * kernel as u64),
            OpKind::AvgPoolGlobal => inputs[0].elems(),
            OpKind::FullyConnected { out_features } => {
                2 * inputs[0].elems() * out_features as u64 + out_features as u64
            }
            OpKind::Activation(_) => out.elems(),
            OpKind::BatchNorm => 2 * out.elems(),
            OpKind::Add => out.elems(),
            OpKind::Concat => 0,
            OpKind::Reorg { .. } => 0,
            OpKind::Upsample { .. } => out.elems(),
            OpKind::Softmax => 5 * out.elems(),
        }
    }

    /// Parameter (weight) bytes resident for this operator.
    pub fn weight_bytes(&self, inputs: &[Shape]) -> u64 {
        match *self {
            OpKind::Conv2d {
                kernel,
                out_c,
                groups,
                ..
            } => {
                let in_c = inputs[0].c as u64;
                let w = kernel as u64 * kernel as u64 * (in_c / groups as u64) * out_c as u64;
                (w + out_c as u64) * 4
            }
            OpKind::FullyConnected { out_features } => {
                (inputs[0].elems() * out_features as u64 + out_features as u64) * 4
            }
            OpKind::BatchNorm => inputs[0].c as u64 * 4 * 4, // scale/shift/mean/var
            _ => 0,
        }
    }

    /// Activation memory traffic: bytes read + bytes written (weights are
    /// accounted separately — on repeated inference they stay resident).
    pub fn activation_bytes(&self, inputs: &[Shape], out: Shape) -> u64 {
        let read: u64 = inputs.iter().map(|s| s.bytes()).sum();
        read + out.bytes()
    }

    /// Number of inputs this op consumes (1 or 2).
    pub fn arity(&self) -> usize {
        match self {
            OpKind::Add | OpKind::Concat => 2,
            _ => 1,
        }
    }
}

impl fmt::Display for OpKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            OpKind::Conv2d {
                kernel,
                stride,
                out_c,
                groups,
                ..
            } => {
                if groups > 1 {
                    write!(f, "dwconv{kernel}x{kernel}/{stride}")
                } else {
                    write!(f, "conv{kernel}x{kernel}/{stride}x{out_c}")
                }
            }
            OpKind::MaxPool { kernel, stride } => write!(f, "maxpool{kernel}/{stride}"),
            OpKind::AvgPoolGlobal => write!(f, "avgpool-g"),
            OpKind::FullyConnected { out_features } => write!(f, "fc{out_features}"),
            OpKind::Activation(_) => write!(f, "act"),
            OpKind::BatchNorm => write!(f, "bn"),
            OpKind::Add => write!(f, "add"),
            OpKind::Concat => write!(f, "concat"),
            OpKind::Reorg { stride } => write!(f, "reorg/{stride}"),
            OpKind::Upsample { factor } => write!(f, "up x{factor}"),
            OpKind::Softmax => write!(f, "softmax"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv(k: usize, s: usize, p: usize, oc: usize) -> OpKind {
        OpKind::Conv2d {
            kernel: k,
            stride: s,
            pad: p,
            out_c: oc,
            groups: 1,
            act: ActKind::Leaky,
        }
    }

    #[test]
    fn conv_flops_formula() {
        // 3x3x3→32 over 416² : 2 * 416*416*32 * 9*3 + out
        let x = Shape::nchw(1, 3, 416, 416);
        let k = conv(3, 1, 1, 32);
        let out = k.out_shape(&[x]);
        let macs = 416u64 * 416 * 32 * 9 * 3;
        assert_eq!(k.flops(&[x], out), 2 * macs + out.elems());
    }

    #[test]
    fn depthwise_flops_divide_by_groups() {
        let x = Shape::nchw(1, 32, 112, 112);
        let dw = OpKind::Conv2d {
            kernel: 3,
            stride: 1,
            pad: 1,
            out_c: 32,
            groups: 32,
            act: ActKind::Relu,
        };
        let out = dw.out_shape(&[x]);
        let macs = 112u64 * 112 * 32 * 9; // in_c/groups = 1
        assert_eq!(dw.flops(&[x], out), 2 * macs + out.elems());
    }

    #[test]
    fn conv_weight_bytes() {
        let x = Shape::nchw(1, 3, 416, 416);
        let k = conv(3, 1, 1, 32);
        assert_eq!(k.weight_bytes(&[x]), (9 * 3 * 32 + 32) * 4);
    }

    #[test]
    fn reorg_shape() {
        let x = Shape::nchw(1, 64, 26, 26);
        let out = OpKind::Reorg { stride: 2 }.out_shape(&[x]);
        assert_eq!(out, Shape::nchw(1, 256, 13, 13));
    }

    #[test]
    fn concat_shape() {
        let a = Shape::nchw(1, 256, 13, 13);
        let b = Shape::nchw(1, 1024, 13, 13);
        assert_eq!(
            OpKind::Concat.out_shape(&[a, b]),
            Shape::nchw(1, 1280, 13, 13)
        );
    }

    #[test]
    fn fc_shapes_and_flops() {
        let x = Shape::vec(1, 512);
        let fc = OpKind::FullyConnected { out_features: 1000 };
        let out = fc.out_shape(&[x]);
        assert_eq!(out, Shape::vec(1, 1000));
        assert_eq!(fc.flops(&[x], out), 2 * 512 * 1000 + 1000);
    }

    #[test]
    fn arity() {
        assert_eq!(OpKind::Add.arity(), 2);
        assert_eq!(OpKind::Concat.arity(), 2);
        assert_eq!(OpKind::Softmax.arity(), 1);
    }

    #[test]
    fn kind_ids_distinct_categories() {
        assert_ne!(conv(3, 1, 1, 8).kind_id(), conv(1, 1, 0, 8).kind_id());
        let dw = OpKind::Conv2d {
            kernel: 3,
            stride: 1,
            pad: 1,
            out_c: 8,
            groups: 8,
            act: ActKind::Relu,
        };
        assert_ne!(dw.kind_id(), conv(3, 1, 1, 8).kind_id());
        assert!(dw.kind_id() < OpKind::NUM_KINDS);
    }
}
