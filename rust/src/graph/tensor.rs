//! NCHW tensor shapes and size arithmetic.

use std::fmt;

/// Bytes per element (the zoo uses f32 activations; mobile frameworks often
/// run f16 on GPU — the transfer model accounts for that separately).
pub const F32_BYTES: u64 = 4;

/// An NCHW activation shape. Fully-connected tensors use `h = w = 1`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Shape {
    /// Batch size.
    pub n: usize,
    /// Channels.
    pub c: usize,
    /// Height.
    pub h: usize,
    /// Width.
    pub w: usize,
}

impl Shape {
    /// Full NCHW shape.
    pub const fn nchw(n: usize, c: usize, h: usize, w: usize) -> Shape {
        Shape { n, c, h, w }
    }

    /// 1-D feature vector (e.g. FC activations).
    pub const fn vec(n: usize, c: usize) -> Shape {
        Shape { n, c, h: 1, w: 1 }
    }

    /// Element count.
    pub fn elems(&self) -> u64 {
        self.n as u64 * self.c as u64 * self.h as u64 * self.w as u64
    }

    /// Size in bytes at f32 precision.
    pub fn bytes(&self) -> u64 {
        self.elems() * F32_BYTES
    }

    /// Output spatial size of a convolution/pool with `kernel`, `stride`,
    /// `pad` applied to this shape.
    pub fn conv_out(&self, out_c: usize, kernel: usize, stride: usize, pad: usize) -> Shape {
        assert!(stride > 0);
        assert!(
            self.h + 2 * pad >= kernel && self.w + 2 * pad >= kernel,
            "kernel {kernel} larger than padded input {}x{}",
            self.h + 2 * pad,
            self.w + 2 * pad
        );
        Shape {
            n: self.n,
            c: out_c,
            h: (self.h + 2 * pad - kernel) / stride + 1,
            w: (self.w + 2 * pad - kernel) / stride + 1,
        }
    }

    /// "Same"-padded pooling with ceil semantics (darknet maxpool
    /// stride-1 keeps the spatial size).
    pub fn pool_out(&self, kernel: usize, stride: usize) -> Shape {
        assert!(stride > 0);
        let _ = kernel; // size preserved via ceil/same-padding semantics
        if stride == 1 {
            // darknet pads to keep size for stride-1 pools
            return Shape { ..*self };
        }
        Shape {
            n: self.n,
            c: self.c,
            h: self.h.div_ceil(stride),
            w: self.w.div_ceil(stride),
        }
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}x{}x{}x{}", self.n, self.c, self.h, self.w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elems_and_bytes() {
        let s = Shape::nchw(1, 3, 416, 416);
        assert_eq!(s.elems(), 3 * 416 * 416);
        assert_eq!(s.bytes(), 3 * 416 * 416 * 4);
    }

    #[test]
    fn conv_out_same_padding() {
        let s = Shape::nchw(1, 3, 416, 416);
        let o = s.conv_out(32, 3, 1, 1);
        assert_eq!(o, Shape::nchw(1, 32, 416, 416));
    }

    #[test]
    fn conv_out_stride2() {
        let s = Shape::nchw(1, 32, 224, 224);
        let o = s.conv_out(64, 3, 2, 1);
        assert_eq!(o, Shape::nchw(1, 64, 112, 112));
    }

    #[test]
    fn conv_out_7x7_stride2_pad3() {
        // ResNet stem: 224 → 112
        let s = Shape::nchw(1, 3, 224, 224);
        let o = s.conv_out(64, 7, 2, 3);
        assert_eq!(o, Shape::nchw(1, 64, 112, 112));
    }

    #[test]
    fn pool_halves() {
        let s = Shape::nchw(1, 16, 416, 416);
        assert_eq!(s.pool_out(2, 2), Shape::nchw(1, 16, 208, 208));
    }

    #[test]
    fn pool_stride1_keeps_size() {
        let s = Shape::nchw(1, 512, 13, 13);
        assert_eq!(s.pool_out(2, 1), s);
    }

    #[test]
    fn pool_ceil_mode() {
        // ResNet maxpool 3x3/2 on 112 → 56 (with pad handled as ceil)
        let s = Shape::nchw(1, 64, 112, 112);
        assert_eq!(s.pool_out(3, 2).h, 56);
    }

    #[test]
    #[should_panic]
    fn conv_kernel_too_large_panics() {
        let s = Shape::nchw(1, 3, 2, 2);
        let _ = s.conv_out(8, 5, 1, 0);
    }
}
