//! Model zoo: graph-IR builders for the paper's workload (YOLOv2) and the
//! companion models used in the ablation/concurrency benches, plus the
//! small *executable* network whose per-block HLO artifacts `aot.py`
//! exports (`tiny_exec`, which must stay in sync with
//! `python/compile/model.py`).
//!
//! Layer lists follow the published darknet / paper configurations;
//! BatchNorm is folded into convolutions (see [`super::op`]).

use super::graph::{GraphBuilder, ModelGraph, OpId, Src};
use super::op::{ActKind, OpKind};
use super::tensor::Shape;

fn conv(oc: usize, k: usize, s: usize, act: ActKind) -> OpKind {
    OpKind::Conv2d {
        kernel: k,
        stride: s,
        pad: k / 2,
        out_c: oc,
        groups: 1,
        act,
    }
}

fn dwconv(c: usize, s: usize) -> OpKind {
    OpKind::Conv2d {
        kernel: 3,
        stride: s,
        pad: 1,
        out_c: c,
        groups: c,
        act: ActKind::Relu,
    }
}

fn mp(k: usize, s: usize) -> OpKind {
    OpKind::MaxPool { kernel: k, stride: s }
}

/// Full YOLOv2 (darknet-19 backbone + passthrough/reorg head), 416×416.
/// 23 conv layers, ~29.5 GFLOP total — the paper's Figure 2 workload.
pub fn yolov2() -> ModelGraph {
    let mut b = GraphBuilder::new("yolov2", Shape::nchw(1, 3, 416, 416));
    let l = ActKind::Leaky;
    let mut prev: Src = Src::Input;
    let push = |b: &mut GraphBuilder, name: &str, kind: OpKind, prev: Src| -> Src {
        Src::Op(b.push(name, kind, &[prev]))
    };

    prev = push(&mut b, "conv1", conv(32, 3, 1, l), prev);
    prev = push(&mut b, "pool1", mp(2, 2), prev); // 208
    prev = push(&mut b, "conv2", conv(64, 3, 1, l), prev);
    prev = push(&mut b, "pool2", mp(2, 2), prev); // 104
    prev = push(&mut b, "conv3", conv(128, 3, 1, l), prev);
    prev = push(&mut b, "conv4", conv(64, 1, 1, l), prev);
    prev = push(&mut b, "conv5", conv(128, 3, 1, l), prev);
    prev = push(&mut b, "pool3", mp(2, 2), prev); // 52
    prev = push(&mut b, "conv6", conv(256, 3, 1, l), prev);
    prev = push(&mut b, "conv7", conv(128, 1, 1, l), prev);
    prev = push(&mut b, "conv8", conv(256, 3, 1, l), prev);
    prev = push(&mut b, "pool4", mp(2, 2), prev); // 26
    prev = push(&mut b, "conv9", conv(512, 3, 1, l), prev);
    prev = push(&mut b, "conv10", conv(256, 1, 1, l), prev);
    prev = push(&mut b, "conv11", conv(512, 3, 1, l), prev);
    prev = push(&mut b, "conv12", conv(256, 1, 1, l), prev);
    let conv13 = b.push("conv13", conv(512, 3, 1, l), &[prev]); // passthrough source, 26×26×512
    prev = push(&mut b, "pool5", mp(2, 2), Src::Op(conv13)); // 13
    prev = push(&mut b, "conv14", conv(1024, 3, 1, l), prev);
    prev = push(&mut b, "conv15", conv(512, 1, 1, l), prev);
    prev = push(&mut b, "conv16", conv(1024, 3, 1, l), prev);
    prev = push(&mut b, "conv17", conv(512, 1, 1, l), prev);
    prev = push(&mut b, "conv18", conv(1024, 3, 1, l), prev);
    // detection head
    prev = push(&mut b, "conv19", conv(1024, 3, 1, l), prev);
    let conv20 = b.push("conv20", conv(1024, 3, 1, l), &[prev]);
    // passthrough branch: 26×26×512 → 1×1×64 → reorg/2 → 13×13×256
    let conv21 = b.push("conv21", conv(64, 1, 1, l), &[Src::Op(conv13)]);
    let reorg = b.push("reorg", OpKind::Reorg { stride: 2 }, &[Src::Op(conv21)]);
    let cat = b.push("route", OpKind::Concat, &[Src::Op(reorg), Src::Op(conv20)]);
    let conv22 = b.push("conv22", conv(1024, 3, 1, l), &[Src::Op(cat)]);
    b.push(
        "conv23",
        conv(425, 1, 1, ActKind::Linear), // 5 anchors × (80 classes + 5)
        &[Src::Op(conv22)],
    );
    b.build()
}

/// YOLOv2-tiny (416×416): 9 convolutions, ~7 GFLOP.
pub fn yolov2_tiny() -> ModelGraph {
    let mut b = GraphBuilder::new("yolov2-tiny", Shape::nchw(1, 3, 416, 416));
    let l = ActKind::Leaky;
    let mut prev: Src = Src::Input;
    let push = |b: &mut GraphBuilder, name: &str, kind: OpKind, prev: Src| -> Src {
        Src::Op(b.push(name, kind, &[prev]))
    };
    for (i, c) in [16usize, 32, 64, 128, 256].iter().enumerate() {
        prev = push(&mut b, &format!("conv{}", i + 1), conv(*c, 3, 1, l), prev);
        prev = push(&mut b, &format!("pool{}", i + 1), mp(2, 2), prev);
    }
    prev = push(&mut b, "conv6", conv(512, 3, 1, l), prev);
    prev = push(&mut b, "pool6", mp(2, 1), prev); // stride-1 pool keeps 13×13
    prev = push(&mut b, "conv7", conv(1024, 3, 1, l), prev);
    prev = push(&mut b, "conv8", conv(1024, 3, 1, l), prev);
    push(&mut b, "conv9", conv(425, 1, 1, ActKind::Linear), prev);
    b.build()
}

/// MobileNetV1 (224×224, width 1.0): 13 depthwise-separable blocks.
pub fn mobilenet_v1() -> ModelGraph {
    let mut b = GraphBuilder::new("mobilenetv1", Shape::nchw(1, 3, 224, 224));
    let mut prev = Src::Op(b.push(
        "conv1",
        OpKind::Conv2d {
            kernel: 3,
            stride: 2,
            pad: 1,
            out_c: 32,
            groups: 1,
            act: ActKind::Relu,
        },
        &[Src::Input],
    ));
    // (out_channels, stride) per separable block
    let blocks: [(usize, usize); 13] = [
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    let mut in_c = 32;
    for (i, (oc, s)) in blocks.iter().enumerate() {
        let dw = b.push(&format!("dw{}", i + 1), dwconv(in_c, *s), &[prev]);
        let pw = b.push(
            &format!("pw{}", i + 1),
            conv(*oc, 1, 1, ActKind::Relu),
            &[Src::Op(dw)],
        );
        prev = Src::Op(pw);
        in_c = *oc;
    }
    let gap = b.push("avgpool", OpKind::AvgPoolGlobal, &[prev]);
    let fc = b.push(
        "fc",
        OpKind::FullyConnected { out_features: 1000 },
        &[Src::Op(gap)],
    );
    b.push("softmax", OpKind::Softmax, &[Src::Op(fc)]);
    b.build()
}

/// ResNet-18 (224×224) with residual Adds — exercises the DAG frontier of
/// the partitioner.
pub fn resnet18() -> ModelGraph {
    let mut b = GraphBuilder::new("resnet18", Shape::nchw(1, 3, 224, 224));
    let r = ActKind::Relu;
    let stem = b.push(
        "conv1",
        OpKind::Conv2d {
            kernel: 7,
            stride: 2,
            pad: 3,
            out_c: 64,
            groups: 1,
            act: r,
        },
        &[Src::Input],
    );
    let mut prev = b.push("pool1", mp(3, 2), &[Src::Op(stem)]);

    let stages: [(usize, usize); 4] = [(64, 1), (128, 2), (256, 2), (512, 2)];
    for (si, (c, first_stride)) in stages.iter().enumerate() {
        for blk in 0..2 {
            let stride = if blk == 0 { *first_stride } else { 1 };
            let tag = format!("s{}b{}", si + 1, blk + 1);
            let c1 = b.push(&format!("{tag}_conv1"), conv(*c, 3, stride, r), &[Src::Op(prev)]);
            let c2 = b.push(
                &format!("{tag}_conv2"),
                conv(*c, 3, 1, ActKind::None),
                &[Src::Op(c1)],
            );
            // identity or 1×1 projection shortcut
            let shortcut: OpId = if stride != 1 || blk == 0 && si != 0 {
                b.push(
                    &format!("{tag}_proj"),
                    conv(*c, 1, stride, ActKind::None),
                    &[Src::Op(prev)],
                )
            } else if si == 0 && blk == 0 {
                // stage-1 first block: channels already match (64) — identity
                prev
            } else {
                prev
            };
            let add = b.push(&format!("{tag}_add"), OpKind::Add, &[Src::Op(c2), Src::Op(shortcut)]);
            prev = b.push(&format!("{tag}_relu"), OpKind::Activation(r), &[Src::Op(add)]);
        }
    }
    let gap = b.push("avgpool", OpKind::AvgPoolGlobal, &[Src::Op(prev)]);
    let fc = b.push(
        "fc",
        OpKind::FullyConnected { out_features: 1000 },
        &[Src::Op(gap)],
    );
    b.push("softmax", OpKind::Softmax, &[Src::Op(fc)]);
    b.build()
}

/// The small *executable* network matching `python/compile/model.py`.
/// Every conv block below is AOT-exported as `artifacts/tiny_exec_bN.hlo.txt`
/// and executed for real by the rust runtime; keep in sync with aot.py.
/// Input 64×64 so interpret-mode Pallas stays fast.
pub fn tiny_exec() -> ModelGraph {
    let mut b = GraphBuilder::new("tiny-exec", Shape::nchw(1, 3, 64, 64));
    let l = ActKind::Leaky;
    let mut prev: Src = Src::Input;
    let push = |b: &mut GraphBuilder, name: &str, kind: OpKind, prev: Src| -> Src {
        Src::Op(b.push(name, kind, &[prev]))
    };
    prev = push(&mut b, "conv1", conv(8, 3, 1, l), prev);
    prev = push(&mut b, "pool1", mp(2, 2), prev); // 32
    prev = push(&mut b, "conv2", conv(16, 3, 1, l), prev);
    prev = push(&mut b, "pool2", mp(2, 2), prev); // 16
    prev = push(&mut b, "conv3", conv(32, 3, 1, l), prev);
    prev = push(&mut b, "pool3", mp(2, 2), prev); // 8
    prev = push(&mut b, "conv4", conv(64, 3, 1, l), prev);
    push(&mut b, "conv5", conv(20, 1, 1, ActKind::Linear), prev);
    b.build()
}

/// Look a model up by zoo name.
pub fn by_name(name: &str) -> Option<ModelGraph> {
    match name {
        "yolov2" => Some(yolov2()),
        "yolov2-tiny" | "yolov2_tiny" => Some(yolov2_tiny()),
        "mobilenetv1" | "mobilenet_v1" => Some(mobilenet_v1()),
        "resnet18" => Some(resnet18()),
        "tiny-exec" | "tiny_exec" => Some(tiny_exec()),
        _ => None,
    }
}

/// All zoo model names.
pub fn names() -> &'static [&'static str] {
    &["yolov2", "yolov2-tiny", "mobilenetv1", "resnet18", "tiny-exec"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn yolov2_structure() {
        let g = yolov2();
        g.validate().unwrap();
        // 23 convs + 5 pools + reorg + concat = 30 ops
        assert_eq!(g.num_ops(), 30);
        let gf = g.total_flops() as f64 / 1e9;
        // darknet reports 29.47 BFLOPs for yolov2.cfg @416 — we land ~29.49
        assert!((28.0..31.0).contains(&gf), "GFLOPs = {gf}");
        // final feature map 13×13×425
        let out = g.ops[g.outputs()[0]].out_shape;
        assert_eq!((out.c, out.h, out.w), (425, 13, 13));
    }

    #[test]
    fn yolov2_passthrough_shapes() {
        let g = yolov2();
        let route = g.ops.iter().find(|o| o.name == "route").unwrap();
        assert_eq!(route.out_shape.c, 1024 + 256);
        assert_eq!(route.out_shape.h, 13);
    }

    #[test]
    fn yolov2_tiny_structure() {
        let g = yolov2_tiny();
        g.validate().unwrap();
        let gf = g.total_flops() as f64 / 1e9;
        assert!((4.0..9.0).contains(&gf), "GFLOPs = {gf}");
        let out = g.ops[g.outputs()[0]].out_shape;
        assert_eq!((out.c, out.h, out.w), (425, 13, 13));
    }

    #[test]
    fn mobilenet_structure() {
        let g = mobilenet_v1();
        g.validate().unwrap();
        // 1 stem + 13×2 separable + gap + fc + softmax = 30
        assert_eq!(g.num_ops(), 30);
        let gf = g.total_flops() as f64 / 1e9;
        // published ~0.57 GMAC → ~1.14 GFLOP
        assert!((0.9..1.4).contains(&gf), "GFLOPs = {gf}");
        // params ~4.2M → ~17 MB f32
        let mb = g.total_weight_bytes() as f64 / 1e6;
        assert!((14.0..20.0).contains(&mb), "weights MB = {mb}");
    }

    #[test]
    fn resnet18_structure() {
        let g = resnet18();
        g.validate().unwrap();
        let gf = g.total_flops() as f64 / 1e9;
        // published ~1.8 GMAC → ~3.6 GFLOP
        assert!((3.0..4.5).contains(&gf), "GFLOPs = {gf}");
        // 8 residual adds
        let adds = g.ops.iter().filter(|o| matches!(o.kind, OpKind::Add)).count();
        assert_eq!(adds, 8);
        // params ~11.7M
        let mb = g.total_weight_bytes() as f64 / 1e6;
        assert!((42.0..50.0).contains(&mb), "weights MB = {mb}");
    }

    #[test]
    fn resnet18_fc_shape() {
        let g = resnet18();
        let fc = g.ops.iter().find(|o| o.name == "fc").unwrap();
        assert_eq!(fc.in_shapes[0], Shape::vec(1, 512));
        assert_eq!(fc.out_shape, Shape::vec(1, 1000));
    }

    #[test]
    fn tiny_exec_structure() {
        let g = tiny_exec();
        g.validate().unwrap();
        assert_eq!(g.num_ops(), 8);
        let out = g.ops[g.outputs()[0]].out_shape;
        assert_eq!((out.c, out.h, out.w), (20, 8, 8));
    }

    #[test]
    fn by_name_finds_all() {
        for n in names() {
            assert!(by_name(n).is_some(), "missing {n}");
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn all_graphs_topologically_valid() {
        for n in names() {
            by_name(n).unwrap().validate().unwrap();
        }
    }
}
