//! # AdaOper — energy-efficient, responsive concurrent DNN inference
//!
//! Reproduction of *AdaOper: Energy-efficient and Responsive Concurrent DNN
//! Inference on Mobile Devices* (ACM MobiSys '24) as a three-layer
//! rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: energy-aware operator
//!   partitioning ([`partition`]), the runtime energy profiler
//!   ([`profiler`]), and the concurrent serving engine ([`coordinator`]),
//!   running against a calibrated Snapdragon-855 SoC simulator ([`soc`]).
//! * **L2 (python/compile/model.py, build time)** — JAX forward functions
//!   for the executable model blocks and the GRU corrector.
//! * **L1 (python/compile/kernels/, build time)** — Pallas kernels
//!   (conv-as-im2col-matmul, GRU cell), lowered with `interpret=True` and
//!   exported as HLO text consumed by [`runtime`].
//!
//! Python never runs on the request path: `make artifacts` AOT-compiles all
//! HLO once; the rust binary is self-contained afterwards.
//!
//! A layer-by-layer walk of the request lifecycle lives in
//! `docs/ARCHITECTURE.md`.
//!
//! ## Quick tour
//!
//! ```no_run
//! use adaoper::graph::zoo;
//! use adaoper::partition::{dp::DpPartitioner, Objective, Partitioner};
//! use adaoper::soc::{Device, DeviceConfig};
//! use adaoper::workload::WorkloadCondition;
//!
//! let model = zoo::yolov2();
//! let mut device = Device::new(DeviceConfig::snapdragon_855());
//! device.apply_condition(&WorkloadCondition::high().spec);
//! // plan against the device oracle (real systems plan via the profiler)
//! let plan = DpPartitioner::new(Objective::MinEdp)
//!     .partition(&model, &device, &device.snapshot())
//!     .unwrap();
//! println!("predicted energy: {:.1} mJ", plan.predicted.energy_j * 1e3);
//! ```

#![warn(missing_docs)]

pub mod batching;
pub mod cli;
pub mod config;
pub mod experiments;
pub mod coordinator;
pub mod fleet;
pub mod graph;
pub mod metrics;
pub mod partition;
pub mod profiler;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod soc;
pub mod util;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
