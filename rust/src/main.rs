//! `adaoper` binary: the leader entrypoint. See `adaoper help`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = adaoper::cli::commands::run(&argv) {
        adaoper::log_error!("{e:#}");
        std::process::exit(1);
    }
}
