//! Plan-decision audit log.
//!
//! Every repartition the coordinator adopts — a drift correction or a
//! monitor-tick regime change — is recorded as a [`PlanDecision`]: what
//! triggered it, the old→new plan fingerprints, the planner's predicted
//! latency/energy before and after, whether the plan cache served it, and
//! the corrector version that priced it. Once the new plan runs, the
//! engine feeds per-op predicted-vs-actual latencies back through
//! [`AuditLog::observe_op`], attributing them to processors by placement
//! fraction, so each decision accumulates per-processor residuals.
//!
//! The log is emitted as `plan_decision` JSONL lines alongside the
//! [`crate::metrics::TraceObserver`] stream and summarized (decision
//! count, median residual, worst regression) as the optional `audit`
//! section of [`crate::metrics::ServingReport`]. It is entirely opt-in:
//! with telemetry disabled no `AuditLog` exists and every report row stays
//! byte-identical.

use crate::partition::plan::PlanCost;
use crate::soc::{Placement, Proc};

/// FNV-1a fingerprint of a placement vector — a compact, stable identity
/// for "which plan is this" across the audit stream. Split fractions hash
/// by their exact bits, so any placement change changes the fingerprint.
pub fn plan_fingerprint(placements: &[Placement]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut mix = |x: u64| {
        for shift in [0, 8, 16, 24, 32, 40, 48, 56] {
            h ^= (x >> shift) & 0xff;
            h = h.wrapping_mul(PRIME);
        }
    };
    for p in placements {
        match *p {
            Placement::Single(Proc::Cpu) => mix(0),
            Placement::Single(Proc::Gpu) => mix(1),
            Placement::Split { cpu_frac } => {
                mix(2);
                mix(cpu_frac.to_bits());
            }
        }
    }
    h
}

/// One adopted repartition, with its post-hoc residual accumulators.
#[derive(Debug, Clone)]
pub struct PlanDecision {
    /// Virtual time the decision was adopted, seconds.
    pub t_s: f64,
    /// Stream whose plan changed.
    pub stream: usize,
    /// What triggered it (`"drift"` | `"regime-change"`).
    pub trigger: &'static str,
    /// Fingerprint of the plan being replaced.
    pub old_fingerprint: u64,
    /// Fingerprint of the adopted plan.
    pub new_fingerprint: u64,
    /// Planner prediction for the old plan (as of its own adoption).
    pub pred_before: PlanCost,
    /// Planner prediction for the new plan.
    pub pred_after: PlanCost,
    /// Whether the plan cache served the decision (no DP solve).
    pub cache_hit: bool,
    /// Online-corrector version that priced the solve (`None` when the
    /// cost model carries no corrector, e.g. the device oracle).
    pub corrector_version: Option<u64>,
    /// Virtual decision time charged for the solve/lookup, seconds.
    pub decision_s: f64,
    /// Measured wall-clock time of the solve/lookup, seconds. Telemetry
    /// only (the timeline is charged `decision_s`); host-dependent, so it
    /// is reported in the JSONL stream but never folded into the
    /// [`AuditSummary`] or any rendered/golden output.
    pub solve_wall_s: f64,
    /// Per-processor predicted op seconds accumulated under this plan
    /// (CPU = index 0, GPU = 1), weighted by placement fraction.
    pub pred_s: [f64; 2],
    /// Per-processor observed op seconds under this plan.
    pub actual_s: [f64; 2],
    /// Ops that contributed to each processor's accumulators.
    pub ops: [u64; 2],
}

impl PlanDecision {
    /// Residual (actual − predicted, seconds) on one processor; `None`
    /// when no op touched it under this plan.
    pub fn residual_s(&self, p: Proc) -> Option<f64> {
        let i = p.index();
        (self.ops[i] > 0).then(|| self.actual_s[i] - self.pred_s[i])
    }

    /// The decision as a `plan_decision` JSONL line (fingerprints as hex
    /// strings: u64 identities must not round-trip through f64).
    pub fn jsonl(&self) -> String {
        let proc_obj = |i: usize| {
            format!(
                "{{\"ops\":{},\"pred_s\":{},\"actual_s\":{}}}",
                self.ops[i],
                num(self.pred_s[i]),
                num(self.actual_s[i])
            )
        };
        format!(
            "{{\"event\":\"plan_decision\",\"t_s\":{},\"stream\":{},\"trigger\":\"{}\",\
             \"old_fp\":\"{:016x}\",\"new_fp\":\"{:016x}\",\
             \"pred_before\":{{\"latency_s\":{},\"energy_j\":{}}},\
             \"pred_after\":{{\"latency_s\":{},\"energy_j\":{}}},\
             \"cache_hit\":{},\"corrector_version\":{},\"decision_s\":{},\
             \"solve_wall_s\":{},\"residuals\":{{\"cpu\":{},\"gpu\":{}}}}}",
            num(self.t_s),
            self.stream,
            self.trigger,
            self.old_fingerprint,
            self.new_fingerprint,
            num(self.pred_before.latency_s),
            num(self.pred_before.energy_j),
            num(self.pred_after.latency_s),
            num(self.pred_after.energy_j),
            self.cache_hit,
            match self.corrector_version {
                Some(v) => v.to_string(),
                None => "null".to_string(),
            },
            num(self.decision_s),
            num(self.solve_wall_s),
            proc_obj(0),
            proc_obj(1),
        )
    }
}

/// JSON number formatting matching the trace writer: finite floats print
/// shortest-round-trip via `Display`, non-finite become `null`.
fn num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

/// The audit log one serving run accumulates.
#[derive(Debug, Clone)]
pub struct AuditLog {
    decisions: Vec<PlanDecision>,
    /// Per stream, index of the decision currently accumulating residuals
    /// (the most recently adopted plan).
    open: Vec<Option<usize>>,
}

impl AuditLog {
    /// Empty log for `streams` streams.
    pub fn new(streams: usize) -> AuditLog {
        AuditLog { decisions: Vec::new(), open: vec![None; streams] }
    }

    /// Record one adopted repartition; subsequent
    /// [`AuditLog::observe_op`] calls for its stream accrue to it.
    pub fn record(&mut self, d: PlanDecision) {
        let stream = d.stream;
        self.decisions.push(d);
        if stream < self.open.len() {
            self.open[stream] = Some(self.decisions.len() - 1);
        }
    }

    /// Feed one executed op's predicted and observed latency back into the
    /// stream's open decision, split across processors by placement
    /// fraction. A no-op for streams that never repartitioned.
    pub fn observe_op(&mut self, stream: usize, placement: Placement, pred_s: f64, actual_s: f64) {
        let Some(&Some(idx)) = self.open.get(stream) else {
            return;
        };
        let d = &mut self.decisions[idx];
        for p in Proc::ALL {
            let frac = placement.frac_on(p);
            if frac > 0.0 {
                let i = p.index();
                d.pred_s[i] += pred_s * frac;
                d.actual_s[i] += actual_s * frac;
                d.ops[i] += 1;
            }
        }
    }

    /// Every recorded decision, in adoption order.
    pub fn decisions(&self) -> &[PlanDecision] {
        &self.decisions
    }

    /// One `plan_decision` JSONL line per decision.
    pub fn jsonl_lines(&self) -> Vec<String> {
        self.decisions.iter().map(PlanDecision::jsonl).collect()
    }

    /// Aggregate summary for the serving report.
    pub fn summary(&self) -> AuditSummary {
        let mut residuals_ms: Vec<f64> = Vec::new();
        for d in &self.decisions {
            for p in Proc::ALL {
                if let Some(r) = d.residual_s(p) {
                    residuals_ms.push(r * 1e3);
                }
            }
        }
        residuals_ms.sort_by(f64::total_cmp);
        let median_residual_ms = if residuals_ms.is_empty() {
            None
        } else {
            let n = residuals_ms.len();
            Some(if n % 2 == 1 {
                residuals_ms[n / 2]
            } else {
                0.5 * (residuals_ms[n / 2 - 1] + residuals_ms[n / 2])
            })
        };
        AuditSummary {
            decisions: self.decisions.len(),
            drift: self.decisions.iter().filter(|d| d.trigger == "drift").count(),
            regime: self.decisions.iter().filter(|d| d.trigger == "regime-change").count(),
            cache_hits: self.decisions.iter().filter(|d| d.cache_hit).count(),
            median_residual_ms,
            worst_regression_ms: residuals_ms.last().copied(),
        }
    }
}

/// Compressed audit outcome carried by
/// [`crate::metrics::ServingReport::telemetry`].
#[derive(Debug, Clone, PartialEq)]
pub struct AuditSummary {
    /// Repartitions recorded.
    pub decisions: usize,
    /// … of which drift-triggered.
    pub drift: usize,
    /// … of which regime-change-triggered.
    pub regime: usize,
    /// … of which served from the plan cache.
    pub cache_hits: usize,
    /// Median per-processor residual (actual − predicted op-seconds under
    /// the adopted plan), milliseconds; `None` when no plan ran.
    pub median_residual_ms: Option<f64>,
    /// Worst (most positive) residual — the largest under-prediction,
    /// milliseconds; `None` when no plan ran.
    pub worst_regression_ms: Option<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn decision(stream: usize, trigger: &'static str, cache_hit: bool) -> PlanDecision {
        PlanDecision {
            t_s: 0.5,
            stream,
            trigger,
            old_fingerprint: plan_fingerprint(&[Placement::CPU, Placement::GPU]),
            new_fingerprint: plan_fingerprint(&[Placement::GPU, Placement::GPU]),
            pred_before: PlanCost { latency_s: 0.040, energy_j: 0.2, ..Default::default() },
            pred_after: PlanCost { latency_s: 0.030, energy_j: 0.15, ..Default::default() },
            cache_hit,
            corrector_version: Some(3),
            decision_s: 1e-5,
            solve_wall_s: 3e-6,
            pred_s: [0.0; 2],
            actual_s: [0.0; 2],
            ops: [0; 2],
        }
    }

    #[test]
    fn fingerprint_distinguishes_plans() {
        let a = plan_fingerprint(&[Placement::CPU, Placement::GPU]);
        let b = plan_fingerprint(&[Placement::GPU, Placement::CPU]);
        let c = plan_fingerprint(&[Placement::CPU, Placement::GPU]);
        assert_ne!(a, b);
        assert_eq!(a, c);
        let s1 = plan_fingerprint(&[Placement::Split { cpu_frac: 0.25 }]);
        let s2 = plan_fingerprint(&[Placement::Split { cpu_frac: 0.30 }]);
        assert_ne!(s1, s2);
    }

    #[test]
    fn observe_op_attributes_by_placement_fraction() {
        let mut log = AuditLog::new(2);
        log.record(decision(0, "drift", false));
        // whole-op on GPU: everything lands on proc 1
        log.observe_op(0, Placement::GPU, 0.010, 0.012);
        // split 0.25: quarter to CPU, three quarters to GPU
        log.observe_op(0, Placement::Split { cpu_frac: 0.25 }, 0.008, 0.008);
        // stream 1 never repartitioned: silently ignored
        log.observe_op(1, Placement::CPU, 1.0, 2.0);
        let d = &log.decisions()[0];
        assert_eq!(d.ops, [1, 2]);
        assert!((d.pred_s[0] - 0.002).abs() < 1e-12);
        assert!((d.actual_s[0] - 0.002).abs() < 1e-12);
        assert!((d.pred_s[1] - 0.016).abs() < 1e-12);
        assert!((d.actual_s[1] - 0.018).abs() < 1e-12);
        assert!((d.residual_s(Proc::Gpu).unwrap() - 0.002).abs() < 1e-12);
        assert_eq!(log.decisions().len(), 1);
    }

    #[test]
    fn summary_matches_hand_computed_oracle() {
        // two decisions; residuals (ms): GPU +2.0 (d0), CPU -1.0 and
        // GPU +0.5 (d1) → sorted [-1.0, +0.5, +2.0], median +0.5, worst +2.0
        let mut log = AuditLog::new(1);
        log.record(decision(0, "drift", false));
        log.observe_op(0, Placement::GPU, 0.010, 0.012);
        log.record(decision(0, "regime-change", true));
        log.observe_op(0, Placement::CPU, 0.005, 0.004);
        log.observe_op(0, Placement::GPU, 0.0100, 0.0105);
        let s = log.summary();
        assert_eq!(s.decisions, 2);
        assert_eq!(s.drift, 1);
        assert_eq!(s.regime, 1);
        assert_eq!(s.cache_hits, 1);
        assert!((s.median_residual_ms.unwrap() - 0.5).abs() < 1e-9);
        assert!((s.worst_regression_ms.unwrap() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn empty_summary_has_no_residuals() {
        let log = AuditLog::new(1);
        let s = log.summary();
        assert_eq!(s.decisions, 0);
        assert_eq!(s.median_residual_ms, None);
        assert_eq!(s.worst_regression_ms, None);
    }

    #[test]
    fn jsonl_round_trips_through_the_parser() {
        let mut log = AuditLog::new(1);
        log.record(decision(0, "drift", false));
        log.observe_op(0, Placement::GPU, 0.010, 0.012);
        let lines = log.jsonl_lines();
        assert_eq!(lines.len(), 1);
        let v = crate::util::json::Json::parse(&lines[0]).unwrap();
        assert_eq!(v.need_str("event").unwrap(), "plan_decision");
        assert_eq!(v.need_str("trigger").unwrap(), "drift");
        assert!(!v.need_bool("cache_hit").unwrap());
        assert_eq!(v.get("corrector_version").unwrap().as_u64(), Some(3));
        assert_eq!(v.need_f64("solve_wall_s").unwrap(), 3e-6);
        let gpu = v.get("residuals").unwrap().get("gpu").unwrap();
        assert_eq!(gpu.need_u64("ops").unwrap(), 1);
        assert_eq!(gpu.need_f64("actual_s").unwrap(), 0.012);
        // fingerprints travel as 16-digit hex strings
        assert_eq!(v.need_str("old_fp").unwrap().len(), 16);
    }
}
