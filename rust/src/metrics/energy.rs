//! Energy accounting: dynamic per-op energy plus static power over wall
//! time, split per processor — the quantities behind "energy per
//! inference" and "inferences per joule" (the paper's energy-efficiency
//! metric).

/// Accumulates energy over a serving run.
#[derive(Debug, Clone, Default)]
pub struct EnergyAccount {
    dynamic_j: f64,
    transfer_j: f64,
    cpu_busy_s: f64,
    gpu_busy_s: f64,
    inferences: usize,
}

impl EnergyAccount {
    /// Empty account.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one op execution's measured cost.
    pub fn add_op(&mut self, cost: &crate::soc::OpCost) {
        self.dynamic_j += cost.energy_j;
        self.transfer_j += cost.transfer_j;
        self.cpu_busy_s += cost.cpu_busy_s;
        self.gpu_busy_s += cost.gpu_busy_s;
    }

    /// Mark one inference complete (for per-inference averages).
    pub fn finish_inference(&mut self) {
        self.inferences += 1;
    }

    /// Accumulated dynamic energy, joules.
    pub fn dynamic_j(&self) -> f64 {
        self.dynamic_j
    }

    /// Transfer share of the dynamic energy, joules.
    pub fn transfer_j(&self) -> f64 {
        self.transfer_j
    }

    /// Completed inferences.
    pub fn inferences(&self) -> usize {
        self.inferences
    }

    /// Accumulated CPU busy time, seconds.
    pub fn cpu_busy_s(&self) -> f64 {
        self.cpu_busy_s
    }

    /// Accumulated GPU busy time, seconds.
    pub fn gpu_busy_s(&self) -> f64 {
        self.gpu_busy_s
    }

    /// Total energy including static draw over `wall_s`.
    pub fn total_j(&self, static_power_w: f64, wall_s: f64) -> f64 {
        self.dynamic_j + static_power_w * wall_s
    }

    /// Joules per inference (the paper reports this and its inverse).
    pub fn j_per_inference(&self, static_power_w: f64, wall_s: f64) -> f64 {
        if self.inferences == 0 {
            return f64::NAN;
        }
        self.total_j(static_power_w, wall_s) / self.inferences as f64
    }

    /// Inferences per joule — the paper's "energy efficiency".
    pub fn inferences_per_j(&self, static_power_w: f64, wall_s: f64) -> f64 {
        let t = self.total_j(static_power_w, wall_s);
        if t <= 0.0 {
            return f64::NAN;
        }
        self.inferences as f64 / t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::OpCost;

    #[test]
    fn accounting_adds_up() {
        let mut a = EnergyAccount::new();
        for _ in 0..10 {
            a.add_op(&OpCost {
                energy_j: 0.01,
                transfer_j: 0.002,
                cpu_busy_s: 0.001,
                gpu_busy_s: 0.004,
                latency_s: 0.005,
                transfer_s: 0.0005,
            });
        }
        a.finish_inference();
        assert!((a.dynamic_j() - 0.1).abs() < 1e-12);
        assert!((a.transfer_j() - 0.02).abs() < 1e-12);
        // static 0.25 W over 2 s → 0.5 J
        assert!((a.total_j(0.25, 2.0) - 0.6).abs() < 1e-12);
        assert!((a.j_per_inference(0.25, 2.0) - 0.6).abs() < 1e-12);
        assert!((a.inferences_per_j(0.25, 2.0) - 1.0 / 0.6).abs() < 1e-9);
    }

    #[test]
    fn zero_inferences_is_nan() {
        let a = EnergyAccount::new();
        assert!(a.j_per_inference(0.1, 1.0).is_nan());
    }
}
