//! Streaming health rules: windowed SLO burn-rate, energy budget,
//! profiler drift, and queue saturation — evaluated at monitor ticks,
//! entirely opt-in, and strictly write-only observation.
//!
//! AdaOper's adaptation story presumes something can *notice*, while
//! serving, that a stream is burning its SLO budget or that the
//! profiler's predictions have gone stale. The PR 8 telemetry spine is
//! retrospective; this module closes the sensing loop:
//!
//! * [`HealthMonitor`] is fed request completions
//!   ([`on_done`](HealthMonitor::on_done)) and per-op prediction
//!   residuals ([`on_op`](HealthMonitor::on_op)) as the kernel delivers
//!   them, accumulating into the deterministic sliding windows of
//!   [`crate::metrics::window`];
//! * at each `MonitorTick` the engine calls
//!   [`on_tick`](HealthMonitor::on_tick), which evaluates every rule
//!   and returns the state *transitions* as [`Alert`]s (streams in
//!   ascending order, rules in a fixed order — fully deterministic);
//! * each rule is a hysteresis state machine
//!   ([`Ok`](HealthState::Ok) → [`Warn`](HealthState::Warn) →
//!   [`Critical`](HealthState::Critical)) with distinct trip and clear
//!   thresholds (clear = trip × [`clear_ratio`](HealthConfig::clear_ratio)),
//!   so a signal hovering at a boundary cannot flap alerts.
//!
//! Rules (signals are dimensionless, thresholds compare directly):
//!
//! | rule | signal | default trips |
//! |------|--------|---------------|
//! | `slo_burn` | `min(fast, slow)` burn rate, where burn = windowed miss-rate / `slo_target` (SRE multi-window: both must burn) | warn 1, critical 4 |
//! | `energy_budget` | windowed mJ/request ÷ `energy_budget_mj` (rule off when budget = 0) | warn 1, critical 1.5 |
//! | `drift` | windowed mean relative residual \|actual − pred\| / pred | warn 0.15, critical 0.35 |
//! | `queue_depth` | in-flight requests at the tick (global) | warn 8, critical 32 |
//!
//! The monitor never reads or advances virtual time and never touches
//! the planner: with `[health]` absent nothing here runs, and with it
//! present the served timeline is byte-identical — alerts ride the
//! observer channel only.

use crate::metrics::window::{WindowCounter, WindowStat};

/// Number of ring buckets per window (fixed: windows stay mergeable
/// across shards because every monitor uses the same shape).
const BUCKETS: usize = 16;

/// Knobs for the streaming health monitor. All windows are in virtual
/// seconds; presence of the config (CLI `--health`, `[health]` in a
/// config file or scenario spec) is what enables the layer.
#[derive(Debug, Clone, PartialEq)]
pub struct HealthConfig {
    /// Fast burn-rate window (seconds).
    pub fast_window_s: f64,
    /// Slow burn-rate window (seconds); also the drift window.
    pub slow_window_s: f64,
    /// SLO error budget: the tolerated deadline-miss fraction. Burn
    /// rate = windowed miss-rate / this.
    pub slo_target: f64,
    /// `slo_burn` Warn trip threshold (burn-rate units).
    pub burn_warn: f64,
    /// `slo_burn` Critical trip threshold.
    pub burn_critical: f64,
    /// Energy budget per request in millijoules; `0` disables the
    /// `energy_budget` rule.
    pub energy_budget_mj: f64,
    /// `drift` Warn trip (mean relative residual).
    pub drift_warn: f64,
    /// `drift` Critical trip.
    pub drift_critical: f64,
    /// `queue_depth` Warn trip (in-flight requests).
    pub queue_warn: usize,
    /// `queue_depth` Critical trip.
    pub queue_critical: usize,
    /// Hysteresis: a tripped state clears only once its signal falls
    /// below `trip × clear_ratio`.
    pub clear_ratio: f64,
    /// Minimum in-window samples before a windowed rule is evaluated
    /// (cold windows stay `Ok`).
    pub min_samples: u64,
}

impl Default for HealthConfig {
    fn default() -> HealthConfig {
        HealthConfig {
            fast_window_s: 1.0,
            slow_window_s: 5.0,
            slo_target: 0.01,
            burn_warn: 1.0,
            burn_critical: 4.0,
            energy_budget_mj: 0.0,
            drift_warn: 0.15,
            drift_critical: 0.35,
            queue_warn: 8,
            queue_critical: 32,
            clear_ratio: 0.8,
            min_samples: 5,
        }
    }
}

/// A rule's severity level.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum HealthState {
    /// Signal below every trip threshold (or window still cold).
    #[default]
    Ok,
    /// Warn tripped, Critical not.
    Warn,
    /// Critical tripped.
    Critical,
}

impl HealthState {
    /// Stable lowercase name used in trace lines and reports.
    pub fn name(&self) -> &'static str {
        match self {
            HealthState::Ok => "ok",
            HealthState::Warn => "warn",
            HealthState::Critical => "critical",
        }
    }
}

/// One health-rule state transition, emitted as an `Event::Alert`
/// through the observer channel and as an `{"event":"alert",...}` trace
/// line.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Alert {
    /// Virtual time of the monitor tick that evaluated the rule.
    pub t_s: f64,
    /// Rule name: `slo_burn` | `energy_budget` | `drift` | `queue_depth`.
    pub rule: &'static str,
    /// Stream the rule watches; `None` for global rules (`queue_depth`).
    pub stream: Option<usize>,
    /// State before the transition.
    pub prev: HealthState,
    /// State after the transition.
    pub state: HealthState,
    /// The signal value that drove the transition.
    pub signal: f64,
    /// The threshold crossed: the trip for escalations, the clear
    /// boundary for de-escalations to `Ok`.
    pub threshold: f64,
}

/// Hysteresis state machine shared by every rule.
///
/// Escalation uses the trip thresholds directly; de-escalation requires
/// the signal to fall below `trip × clear_ratio` of the level being
/// left, so a signal oscillating around a trip cannot flap.
#[derive(Debug, Clone, Copy, Default)]
pub struct RuleFsm {
    state: HealthState,
}

impl RuleFsm {
    /// Current state.
    pub fn state(&self) -> HealthState {
        self.state
    }

    /// Advance on one observation of `signal` against the `(warn, crit)`
    /// trips with hysteresis `clear_ratio`; returns the transition
    /// `(prev, new, threshold)` when the state changed.
    pub fn step(
        &mut self,
        signal: f64,
        warn: f64,
        crit: f64,
        clear_ratio: f64,
    ) -> Option<(HealthState, HealthState, f64)> {
        use HealthState::{Critical, Ok, Warn};
        let prev = self.state;
        let next = match prev {
            Ok => {
                if signal >= crit {
                    Critical
                } else if signal >= warn {
                    Warn
                } else {
                    Ok
                }
            }
            Warn => {
                if signal >= crit {
                    Critical
                } else if signal < warn * clear_ratio {
                    Ok
                } else {
                    Warn
                }
            }
            Critical => {
                if signal >= crit * clear_ratio {
                    Critical
                } else if signal >= warn {
                    Warn
                } else if signal < warn * clear_ratio {
                    Ok
                } else {
                    Warn
                }
            }
        };
        if next == prev {
            return None;
        }
        self.state = next;
        let threshold = match next {
            Critical => crit,
            Warn => {
                if next > prev {
                    warn
                } else {
                    crit * clear_ratio
                }
            }
            Ok => warn * clear_ratio,
        };
        Some((prev, next, threshold))
    }
}

/// Windowed accumulators + rule machines for one stream.
#[derive(Debug, Clone)]
struct StreamHealth {
    done_fast: WindowCounter,
    miss_fast: WindowCounter,
    done_slow: WindowCounter,
    miss_slow: WindowCounter,
    /// mJ per completed request over the fast window.
    energy_mj: WindowStat,
    /// Relative per-op residual |actual − pred| / pred over the slow
    /// window.
    residual: WindowStat,
    burn: RuleFsm,
    energy: RuleFsm,
    drift: RuleFsm,
}

impl StreamHealth {
    fn new(cfg: &HealthConfig) -> StreamHealth {
        StreamHealth {
            done_fast: WindowCounter::new(cfg.fast_window_s, BUCKETS),
            miss_fast: WindowCounter::new(cfg.fast_window_s, BUCKETS),
            done_slow: WindowCounter::new(cfg.slow_window_s, BUCKETS),
            miss_slow: WindowCounter::new(cfg.slow_window_s, BUCKETS),
            energy_mj: WindowStat::new(cfg.fast_window_s, BUCKETS),
            residual: WindowStat::new(cfg.slow_window_s, BUCKETS),
            burn: RuleFsm::default(),
            energy: RuleFsm::default(),
            drift: RuleFsm::default(),
        }
    }
}

/// Counts of health activity over a run, appended to
/// [`ServingReport`](crate::metrics::ServingReport) strictly after the
/// telemetry section. All-`u64` so fleet rollups merge exactly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct HealthSummary {
    /// Monitor ticks the rules were evaluated on.
    pub ticks: u64,
    /// Total state transitions (alerts) emitted.
    pub alerts: u64,
    /// Transitions *into* `Warn`.
    pub warn: u64,
    /// Transitions *into* `Critical`.
    pub critical: u64,
    /// `drift`-rule transitions into `Warn` or `Critical`.
    pub drift_alerts: u64,
}

impl HealthSummary {
    /// Fold `other` into `self` (plain u64 sums — exact, associative).
    pub fn absorb(&mut self, other: &HealthSummary) {
        self.ticks += other.ticks;
        self.alerts += other.alerts;
        self.warn += other.warn;
        self.critical += other.critical;
        self.drift_alerts += other.drift_alerts;
    }
}

/// The streaming health monitor: one per engine run when `[health]` is
/// configured. Fed from observer-adjacent call sites in the engine's
/// event loop; evaluated on monitor ticks.
#[derive(Debug, Clone)]
pub struct HealthMonitor {
    cfg: HealthConfig,
    streams: Vec<StreamHealth>,
    queue: RuleFsm,
    summary: HealthSummary,
}

impl HealthMonitor {
    /// Monitor for `streams` concurrent streams under `cfg`.
    pub fn new(cfg: HealthConfig, streams: usize) -> HealthMonitor {
        let streams = (0..streams).map(|_| StreamHealth::new(&cfg)).collect();
        HealthMonitor {
            cfg,
            streams,
            queue: RuleFsm::default(),
            summary: HealthSummary::default(),
        }
    }

    /// The active configuration.
    pub fn config(&self) -> &HealthConfig {
        &self.cfg
    }

    /// Observe one completed request on `stream` at virtual time `t_s`.
    pub fn on_done(&mut self, stream: usize, t_s: f64, met_deadline: bool, energy_j: f64) {
        let Some(s) = self.streams.get_mut(stream) else {
            return;
        };
        s.done_fast.record(t_s, 1);
        s.done_slow.record(t_s, 1);
        if !met_deadline {
            s.miss_fast.record(t_s, 1);
            s.miss_slow.record(t_s, 1);
        }
        s.energy_mj.record(t_s, energy_j * 1e3);
    }

    /// Observe one executed operator's prediction residual on `stream`:
    /// `pred_s` from the profiler's latency profile, `actual_s` as
    /// measured. Non-positive predictions are skipped (no meaningful
    /// relative residual).
    pub fn on_op(&mut self, stream: usize, t_s: f64, pred_s: f64, actual_s: f64) {
        let Some(s) = self.streams.get_mut(stream) else {
            return;
        };
        if pred_s > 0.0 && actual_s.is_finite() {
            s.residual.record(t_s, (actual_s - pred_s).abs() / pred_s);
        }
    }

    /// Evaluate every rule at a monitor tick: `t_s` is the tick's
    /// virtual time, `queue_depth` the number of in-flight requests.
    /// Returns the state transitions in deterministic order (streams
    /// ascending; per stream `slo_burn`, `energy_budget`, `drift`; the
    /// global `queue_depth` rule last).
    pub fn on_tick(&mut self, t_s: f64, queue_depth: usize) -> Vec<Alert> {
        let mut alerts = Vec::new();
        self.summary.ticks += 1;
        let cfg = self.cfg.clone();
        for (i, s) in self.streams.iter_mut().enumerate() {
            // slo_burn: SRE multi-window — both the fast and the slow
            // window must be burning, so the signal is the min.
            let done_f = s.done_fast.total(t_s);
            if done_f >= cfg.min_samples {
                let burn_f = miss_rate(s.miss_fast.total(t_s), done_f) / cfg.slo_target;
                let done_s = s.done_slow.total(t_s);
                let burn_s = miss_rate(s.miss_slow.total(t_s), done_s) / cfg.slo_target;
                let signal = burn_f.min(burn_s);
                if let Some((prev, state, threshold)) =
                    s.burn
                        .step(signal, cfg.burn_warn, cfg.burn_critical, cfg.clear_ratio)
                {
                    alerts.push(Alert {
                        t_s,
                        rule: "slo_burn",
                        stream: Some(i),
                        prev,
                        state,
                        signal,
                        threshold,
                    });
                }
            }

            // energy_budget: windowed mJ/request vs the target.
            if cfg.energy_budget_mj > 0.0 && s.energy_mj.count(t_s) >= cfg.min_samples {
                if let Some(mean_mj) = s.energy_mj.mean(t_s) {
                    let signal = mean_mj / cfg.energy_budget_mj;
                    if let Some((prev, state, threshold)) =
                        s.energy.step(signal, 1.0, 1.5, cfg.clear_ratio)
                    {
                        alerts.push(Alert {
                            t_s,
                            rule: "energy_budget",
                            stream: Some(i),
                            prev,
                            state,
                            signal,
                            threshold,
                        });
                    }
                }
            }

            // drift: windowed mean relative residual of the profiler's
            // per-op latency predictions.
            if s.residual.count(t_s) >= cfg.min_samples {
                if let Some(signal) = s.residual.mean(t_s) {
                    if let Some((prev, state, threshold)) =
                        s.drift
                            .step(signal, cfg.drift_warn, cfg.drift_critical, cfg.clear_ratio)
                    {
                        alerts.push(Alert {
                            t_s,
                            rule: "drift",
                            stream: Some(i),
                            prev,
                            state,
                            signal,
                            threshold,
                        });
                    }
                }
            }
        }

        // queue_depth: global, instantaneous.
        let signal = queue_depth as f64;
        if let Some((prev, state, threshold)) = self.queue.step(
            signal,
            self.cfg.queue_warn as f64,
            self.cfg.queue_critical as f64,
            self.cfg.clear_ratio,
        ) {
            alerts.push(Alert {
                t_s,
                rule: "queue_depth",
                stream: None,
                prev,
                state,
                signal,
                threshold,
            });
        }

        for a in &alerts {
            self.summary.alerts += 1;
            match a.state {
                HealthState::Warn => self.summary.warn += 1,
                HealthState::Critical => self.summary.critical += 1,
                HealthState::Ok => {}
            }
            if a.rule == "drift" && a.state > a.prev {
                self.summary.drift_alerts += 1;
            }
        }
        alerts
    }

    /// The run's health rollup.
    pub fn summary(&self) -> HealthSummary {
        self.summary
    }
}

fn miss_rate(miss: u64, done: u64) -> f64 {
    if done == 0 {
        0.0
    } else {
        miss as f64 / done as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fsm_trips_and_clears_with_hysteresis() {
        let mut f = RuleFsm::default();
        // below warn: stays Ok, no transition
        assert!(f.step(0.5, 1.0, 4.0, 0.8).is_none());
        // trips Warn at the warn threshold
        let (prev, next, thr) = f.step(1.2, 1.0, 4.0, 0.8).expect("warn trip");
        assert_eq!((prev, next), (HealthState::Ok, HealthState::Warn));
        assert_eq!(thr, 1.0);
        // hovering between clear (0.8) and trip (1.0): no flap
        assert!(f.step(0.9, 1.0, 4.0, 0.8).is_none());
        assert!(f.step(0.95, 1.0, 4.0, 0.8).is_none());
        // escalates straight to Critical
        let (prev, next, thr) = f.step(5.0, 1.0, 4.0, 0.8).expect("critical trip");
        assert_eq!((prev, next), (HealthState::Warn, HealthState::Critical));
        assert_eq!(thr, 4.0);
        // stays Critical down to the clear boundary (4.0 * 0.8 = 3.2)
        assert!(f.step(3.5, 1.0, 4.0, 0.8).is_none());
        // drops to Warn below the critical clear but above warn trip
        let (prev, next, _) = f.step(2.0, 1.0, 4.0, 0.8).expect("de-escalate");
        assert_eq!((prev, next), (HealthState::Critical, HealthState::Warn));
        // clears to Ok below warn * clear_ratio
        let (prev, next, thr) = f.step(0.1, 1.0, 4.0, 0.8).expect("clear");
        assert_eq!((prev, next), (HealthState::Warn, HealthState::Ok));
        assert!((thr - 0.8).abs() < 1e-12);
    }

    #[test]
    fn fsm_ok_jumps_straight_to_critical() {
        let mut f = RuleFsm::default();
        let (prev, next, _) = f.step(10.0, 1.0, 4.0, 0.8).expect("trip");
        assert_eq!((prev, next), (HealthState::Ok, HealthState::Critical));
        // and can fall straight back to Ok when the signal collapses
        let (prev, next, _) = f.step(0.0, 1.0, 4.0, 0.8).expect("clear");
        assert_eq!((prev, next), (HealthState::Critical, HealthState::Ok));
    }

    fn cfg() -> HealthConfig {
        HealthConfig {
            fast_window_s: 1.0,
            slow_window_s: 2.0,
            min_samples: 3,
            ..HealthConfig::default()
        }
    }

    #[test]
    fn cold_windows_stay_silent() {
        let mut m = HealthMonitor::new(cfg(), 1);
        // fewer than min_samples completions: no burn evaluation even
        // though everything missed
        m.on_done(0, 0.1, false, 0.001);
        m.on_done(0, 0.2, false, 0.001);
        assert!(m.on_tick(0.3, 0).is_empty());
        assert_eq!(m.summary().alerts, 0);
        assert_eq!(m.summary().ticks, 1);
    }

    #[test]
    fn burn_rule_trips_critical_on_sustained_misses() {
        let mut m = HealthMonitor::new(cfg(), 1);
        for k in 0..10 {
            m.on_done(0, 0.05 * k as f64, false, 0.001);
        }
        let alerts = m.on_tick(0.5, 0);
        assert_eq!(alerts.len(), 1, "{alerts:?}");
        let a = alerts[0];
        assert_eq!(a.rule, "slo_burn");
        assert_eq!(a.stream, Some(0));
        assert_eq!(a.state, HealthState::Critical);
        // 100% miss-rate over a 1% budget = burn 100
        assert!((a.signal - 100.0).abs() < 1e-9, "signal {}", a.signal);
        // clears once the window drains (all completions roll out)
        let cleared = m.on_tick(5.0, 0);
        assert!(cleared.is_empty(), "cold window must not evaluate: {cleared:?}");
        assert_eq!(m.summary().critical, 1);
    }

    #[test]
    fn drift_rule_counts_into_summary() {
        let mut m = HealthMonitor::new(cfg(), 1);
        for k in 0..5 {
            // predictions off by 50%
            m.on_op(0, 0.1 * k as f64, 0.010, 0.015);
        }
        let alerts = m.on_tick(0.5, 0);
        assert_eq!(alerts.len(), 1);
        assert_eq!(alerts[0].rule, "drift");
        assert_eq!(alerts[0].state, HealthState::Critical);
        assert_eq!(m.summary().drift_alerts, 1);
        assert_eq!(m.summary().alerts, 1);
    }

    #[test]
    fn energy_rule_is_off_without_budget() {
        let mut m = HealthMonitor::new(cfg(), 1);
        for k in 0..10 {
            m.on_done(0, 0.05 * k as f64, true, 10.0); // absurd 10 J/req
        }
        assert!(m.on_tick(0.5, 0).is_empty(), "budget 0 disables the rule");

        let mut on = HealthMonitor::new(
            HealthConfig { energy_budget_mj: 5.0, ..cfg() },
            1,
        );
        for k in 0..10 {
            on.on_done(0, 0.05 * k as f64, true, 0.010); // 10 mJ vs 5 mJ budget
        }
        let alerts = on.on_tick(0.5, 0);
        assert_eq!(alerts.len(), 1, "{alerts:?}");
        assert_eq!(alerts[0].rule, "energy_budget");
        assert_eq!(alerts[0].state, HealthState::Critical);
        assert!((alerts[0].signal - 2.0).abs() < 1e-9);
    }

    #[test]
    fn queue_rule_is_global_and_last() {
        let mut m = HealthMonitor::new(cfg(), 2);
        for k in 0..10 {
            m.on_done(0, 0.05 * k as f64, false, 0.001);
        }
        let alerts = m.on_tick(0.5, 100);
        assert_eq!(alerts.len(), 2);
        assert_eq!(alerts[0].rule, "slo_burn");
        let q = alerts[1];
        assert_eq!(q.rule, "queue_depth");
        assert_eq!(q.stream, None);
        assert_eq!(q.state, HealthState::Critical);
        assert_eq!(q.signal, 100.0);
        // queue drains: de-escalates deterministically
        let cleared = m.on_tick(6.0, 0);
        let q = cleared.iter().find(|a| a.rule == "queue_depth").expect("clear");
        assert_eq!(q.state, HealthState::Ok);
    }

    #[test]
    fn alerts_count_transitions_not_ticks() {
        let mut m = HealthMonitor::new(cfg(), 1);
        for k in 0..20 {
            m.on_done(0, 0.02 * k as f64, false, 0.001);
        }
        assert_eq!(m.on_tick(0.4, 0).len(), 1);
        // still critical on the next tick: no new alert
        for k in 0..20 {
            m.on_done(0, 0.4 + 0.02 * k as f64, false, 0.001);
        }
        assert!(m.on_tick(0.8, 0).is_empty());
        assert_eq!(m.summary().alerts, 1);
        assert_eq!(m.summary().ticks, 2);
    }

    #[test]
    fn summary_absorb_is_plain_sums() {
        let a = HealthSummary { ticks: 2, alerts: 3, warn: 1, critical: 2, drift_alerts: 1 };
        let b = HealthSummary { ticks: 5, alerts: 1, warn: 1, critical: 0, drift_alerts: 0 };
        let mut m = a;
        m.absorb(&b);
        assert_eq!(
            m,
            HealthSummary { ticks: 7, alerts: 4, warn: 2, critical: 2, drift_alerts: 1 }
        );
    }
}
