//! Mergeable fixed-boundary log-bucket histogram.
//!
//! The fleet layer aggregates per-device latency distributions across
//! thousands of independent simulations; raw samples would not scale and
//! per-device [`crate::util::stats::Summary`] percentiles are not
//! mergeable. This histogram is: bucket boundaries are *fixed* at
//! construction (`lo · growth^k`), so two histograms built with the same
//! parameters merge by adding counts, and quantiles of the merge equal the
//! quantiles of the histogram built from the concatenated samples.
//!
//! **Error bound.** Each bucket spans a `growth` ratio and the estimator
//! returns the geometric midpoint of the bucket holding the requested
//! order statistic (linearly interpolated between adjacent ranks, the same
//! convention as [`crate::util::stats::percentile`]), clamped to the exact
//! recorded min/max. For samples inside `[lo, hi)` the estimate `q̂` of a
//! true quantile `q` therefore satisfies
//! `q̂ / q ∈ [1/√growth, √growth]`, i.e. a relative error of at most
//! `√growth − 1` ([`LogHistogram::rel_error_bound`]). Samples below `lo`
//! or above `hi` are clamped into the under/overflow buckets and only the
//! min/max anchors stay exact for them.

/// Fixed-boundary log-bucket histogram with exact merge semantics.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    lo: f64,
    growth: f64,
    /// `[underflow, core buckets …, overflow]`.
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl LogHistogram {
    /// Build a histogram covering `[lo, hi)` with buckets growing by
    /// `growth` per step. Panics unless `0 < lo < hi` and `growth > 1`.
    pub fn new(lo: f64, hi: f64, growth: f64) -> LogHistogram {
        assert!(lo > 0.0 && lo.is_finite(), "lo must be positive");
        assert!(hi > lo && hi.is_finite(), "hi must exceed lo");
        assert!(growth > 1.0 && growth.is_finite(), "growth must exceed 1");
        let core = ((hi / lo).ln() / growth.ln()).ceil() as usize;
        LogHistogram {
            lo,
            growth,
            counts: vec![0; core + 2],
            total: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// The latency configuration every serving report uses: 1 µs – 10⁴ s
    /// in 5 % buckets (quantile relative error ≤ √1.05 − 1 ≈ 2.5 %).
    pub fn latency() -> LogHistogram {
        LogHistogram::new(1e-6, 1e4, 1.05)
    }

    /// Build the standard latency histogram from raw samples (seconds).
    pub fn latency_of(samples: &[f64]) -> LogHistogram {
        let mut h = LogHistogram::latency();
        for &x in samples {
            h.record(x);
        }
        h
    }

    /// The batch-size configuration every serving report uses: 1 – 4096
    /// in ~25 % buckets (sizes are small integers, so the mean stays exact
    /// via the sum and the quantiles land within one size step).
    pub fn batch_sizes() -> LogHistogram {
        LogHistogram::new(1.0, 4096.0, 1.25)
    }

    fn core_buckets(&self) -> usize {
        self.counts.len() - 2
    }

    fn bucket_idx(&self, x: f64) -> usize {
        if x < self.lo {
            return 0;
        }
        let k = ((x / self.lo).ln() / self.growth.ln()).floor() as isize;
        if k < 0 {
            0
        } else if k as usize >= self.core_buckets() {
            self.counts.len() - 1
        } else {
            k as usize + 1
        }
    }

    /// Record one sample (finite, non-negative).
    pub fn record(&mut self, x: f64) {
        debug_assert!(x.is_finite() && x >= 0.0, "histogram sample {x}");
        let i = self.bucket_idx(x);
        self.counts[i] += 1;
        self.total += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Whether anything was recorded.
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Exact arithmetic mean (`None` when empty).
    pub fn mean(&self) -> Option<f64> {
        if self.total == 0 {
            None
        } else {
            Some(self.sum / self.total as f64)
        }
    }

    /// Exact smallest recorded sample (`None` when empty).
    pub fn min(&self) -> Option<f64> {
        (self.total > 0).then_some(self.min)
    }

    /// Exact largest recorded sample (`None` when empty).
    pub fn max(&self) -> Option<f64> {
        (self.total > 0).then_some(self.max)
    }

    /// Raw bucket counts (`[underflow, core…, overflow]`) — test
    /// introspection and exact-merge assertions.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Documented quantile relative-error bound: `√growth − 1`.
    pub fn rel_error_bound(&self) -> f64 {
        self.growth.sqrt() - 1.0
    }

    /// Whether two histograms share boundaries (and can merge).
    pub fn compatible(&self, other: &LogHistogram) -> bool {
        self.lo == other.lo
            && self.growth == other.growth
            && self.counts.len() == other.counts.len()
    }

    /// Fold `other` into `self`. Quantiles of the result are exactly the
    /// quantiles of the histogram built from the concatenated samples
    /// (counts and min/max merge losslessly). Panics on incompatible
    /// boundaries.
    pub fn merge(&mut self, other: &LogHistogram) {
        assert!(
            self.compatible(other),
            "merging histograms with different boundaries"
        );
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Representative value of a bucket: its geometric midpoint, clamped
    /// to the exact recorded min/max; the under/overflow buckets anchor to
    /// the exact extremes (callers guarantee non-empty).
    fn representative(&self, bucket: usize) -> f64 {
        let v = if bucket == 0 {
            self.min
        } else if bucket == self.counts.len() - 1 {
            self.max
        } else {
            self.lo * self.growth.powi(bucket as i32 - 1) * self.growth.sqrt()
        };
        v.clamp(self.min, self.max)
    }

    fn value_at_rank(&self, rank: u64) -> f64 {
        debug_assert!(rank < self.total);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if rank < seen {
                return self.representative(i);
            }
        }
        self.representative(self.counts.len() - 1)
    }

    /// Quantile estimate for `q ∈ [0, 1]`, `None` when empty. Uses the
    /// same rank convention as [`crate::util::stats::percentile`]
    /// (linear interpolation at rank `q · (n − 1)`); see the module docs
    /// for the relative-error bound.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        assert!((0.0..=1.0).contains(&q), "quantile q out of range: {q}");
        if self.total == 0 {
            return None;
        }
        if self.total == 1 {
            return Some(self.min);
        }
        let rank = q * (self.total - 1) as f64;
        let lo_r = rank.floor() as u64;
        let hi_r = rank.ceil() as u64;
        let frac = rank - lo_r as f64;
        let a = self.value_at_rank(lo_r);
        let b = self.value_at_rank(hi_r);
        Some(a + (b - a) * frac)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::percentile;
    use crate::util::Prng;

    /// Random positive samples comfortably inside the default range.
    fn samples(seed: u64, n: usize) -> Vec<f64> {
        let mut rng = Prng::new(seed);
        (0..n)
            .map(|i| {
                if i % 3 == 0 {
                    rng.exponential(20.0) + 1e-4
                } else {
                    rng.range(1e-4, 5.0)
                }
            })
            .collect()
    }

    #[test]
    fn empty_and_single_sample_edges() {
        let mut h = LogHistogram::latency();
        assert!(h.is_empty());
        assert_eq!(h.quantile(0.5), None);
        assert_eq!(h.mean(), None);
        h.record(0.0123);
        for q in [0.0, 0.5, 0.95, 1.0] {
            assert_eq!(h.quantile(q), Some(0.0123), "q={q}");
        }
        assert_eq!(h.count(), 1);
        assert_eq!((h.min(), h.max()), (Some(0.0123), Some(0.0123)));
    }

    #[test]
    fn extremes_clamp_to_exact_min_max() {
        let mut h = LogHistogram::latency();
        h.record(0.0); // below lo → underflow bucket
        h.record(5e4); // above hi → overflow bucket
        assert_eq!(h.quantile(0.0), Some(0.0));
        assert_eq!(h.quantile(1.0), Some(5e4));
    }

    #[test]
    fn quantiles_within_documented_bound_of_exact_percentile() {
        for seed in [1u64, 7, 42, 1234] {
            let xs = samples(seed, 500);
            let h = LogHistogram::latency_of(&xs);
            let bound = h.rel_error_bound();
            for p in [0.0, 5.0, 25.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
                let exact = percentile(&xs, p);
                let est = h.quantile(p / 100.0).unwrap();
                let rel = (est - exact).abs() / exact;
                assert!(
                    rel <= bound + 1e-9,
                    "seed {seed} p{p}: est {est} vs exact {exact} (rel {rel:.4} > {bound:.4})"
                );
            }
        }
    }

    #[test]
    fn merge_quantiles_equal_concatenated_histogram() {
        for seed in [3u64, 99, 2024] {
            let xs = samples(seed, 257);
            let ys = samples(seed ^ 0xDEAD, 83);
            let mut merged = LogHistogram::latency_of(&xs);
            merged.merge(&LogHistogram::latency_of(&ys));
            let concat: Vec<f64> = xs.iter().chain(ys.iter()).copied().collect();
            let whole = LogHistogram::latency_of(&concat);
            assert_eq!(merged.counts(), whole.counts());
            assert_eq!(merged.count(), whole.count());
            assert_eq!(merged.min(), whole.min());
            assert_eq!(merged.max(), whole.max());
            for q in [0.0, 0.1, 0.5, 0.9, 0.95, 0.99, 1.0] {
                assert_eq!(merged.quantile(q), whole.quantile(q), "seed {seed} q={q}");
            }
            let (ma, mb) = (merged.mean().unwrap(), whole.mean().unwrap());
            assert!((ma - mb).abs() < 1e-12 * mb.abs().max(1.0));
        }
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let xs = samples(11, 64);
        let mut h = LogHistogram::latency_of(&xs);
        let before = h.clone();
        h.merge(&LogHistogram::latency());
        assert_eq!(h.counts(), before.counts());
        assert_eq!(h.quantile(0.5), before.quantile(0.5));
        // and the mirror: empty absorbing a populated histogram
        let mut e = LogHistogram::latency();
        e.merge(&before);
        assert_eq!(e.quantile(0.95), before.quantile(0.95));
    }

    #[test]
    #[should_panic]
    fn incompatible_merge_panics() {
        let mut a = LogHistogram::new(1e-6, 1e4, 1.05);
        let b = LogHistogram::new(1e-6, 1e4, 1.10);
        a.merge(&b);
    }

    #[test]
    fn mean_is_exact() {
        let xs = [0.25, 0.5, 1.0, 2.0];
        let h = LogHistogram::latency_of(&xs);
        assert!((h.mean().unwrap() - 0.9375).abs() < 1e-12);
    }

    #[test]
    fn two_sample_interpolation_monotone() {
        let mut h = LogHistogram::latency();
        h.record(0.010);
        h.record(0.100);
        let q25 = h.quantile(0.25).unwrap();
        let q75 = h.quantile(0.75).unwrap();
        assert!(h.quantile(0.0).unwrap() <= q25);
        assert!(q25 <= q75);
        assert!(q75 <= h.quantile(1.0).unwrap());
    }
}
