//! Per-request latency recording with deadline tracking.

use crate::util::stats::Summary;

/// Records end-to-end request latencies and SLO outcomes.
#[derive(Debug, Clone, Default)]
pub struct LatencyRecorder {
    samples_s: Vec<f64>,
    misses: usize,
    /// Queueing delay components (time between arrival and start).
    queue_s: Vec<f64>,
}

impl LatencyRecorder {
    /// Empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one request: end-to-end latency, queueing component, and
    /// whether it met its deadline.
    pub fn record(&mut self, latency_s: f64, queue_s: f64, met_deadline: bool) {
        self.samples_s.push(latency_s);
        self.queue_s.push(queue_s);
        if !met_deadline {
            self.misses += 1;
        }
    }

    /// Requests recorded.
    pub fn count(&self) -> usize {
        self.samples_s.len()
    }

    /// Number of recorded requests that missed their deadline.
    pub fn misses(&self) -> usize {
        self.misses
    }

    /// Fraction of recorded requests that missed their deadline.
    pub fn miss_rate(&self) -> f64 {
        if self.samples_s.is_empty() {
            0.0
        } else {
            self.misses as f64 / self.samples_s.len() as f64
        }
    }

    /// Latency distribution (None when nothing recorded).
    pub fn summary(&self) -> Option<Summary> {
        Summary::of(&self.samples_s)
    }

    /// Queueing-delay distribution (None when nothing recorded).
    pub fn queue_summary(&self) -> Option<Summary> {
        Summary::of(&self.queue_s)
    }

    /// Raw latency samples, in record order.
    pub fn samples(&self) -> &[f64] {
        &self.samples_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut r = LatencyRecorder::new();
        for i in 1..=100 {
            r.record(i as f64 / 1000.0, 0.0, i <= 90);
        }
        assert_eq!(r.count(), 100);
        assert!((r.miss_rate() - 0.10).abs() < 1e-12);
        let s = r.summary().unwrap();
        assert!((s.p50 - 0.0505).abs() < 0.001);
    }

    #[test]
    fn empty_recorder() {
        let r = LatencyRecorder::new();
        assert_eq!(r.count(), 0);
        assert_eq!(r.miss_rate(), 0.0);
        assert!(r.summary().is_none());
    }
}
