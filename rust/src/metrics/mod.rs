//! Serving metrics: latency distributions, energy accounting and the
//! aggregate report the benches and CLI print.

pub mod energy;
pub mod latency;
pub mod report;

pub use energy::EnergyAccount;
pub use latency::LatencyRecorder;
pub use report::{PlanCacheStats, SchedStats, ServingReport};
