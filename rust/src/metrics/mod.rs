//! Serving metrics: latency distributions, energy accounting, mergeable
//! histograms for fleet-scale aggregation, per-request JSONL traces, and
//! the aggregate report the benches and CLI print.

pub mod energy;
pub mod histogram;
pub mod latency;
pub mod report;
pub mod trace;

pub use energy::EnergyAccount;
pub use histogram::LogHistogram;
pub use latency::LatencyRecorder;
pub use report::{BatchStats, PlanCacheStats, SchedStats, ServingReport};
pub use trace::{TraceMeta, TraceObserver};
