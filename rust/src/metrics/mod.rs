//! Serving metrics: latency distributions, energy accounting, mergeable
//! histograms for fleet-scale aggregation, per-request JSONL traces,
//! the plan-decision audit log, the telemetry registry, the Perfetto
//! trace-event exporter, and the aggregate report the benches and CLI
//! print.

pub mod audit;
pub mod energy;
pub mod histogram;
pub mod latency;
pub mod perfetto;
pub mod registry;
pub mod report;
pub mod trace;

pub use audit::{plan_fingerprint, AuditLog, AuditSummary, PlanDecision};
pub use energy::EnergyAccount;
pub use histogram::LogHistogram;
pub use latency::LatencyRecorder;
pub use registry::TelemetryRegistry;
pub use report::{BatchStats, PlanCacheStats, SchedStats, ServingReport};
pub use trace::{TraceMeta, TraceObserver};
