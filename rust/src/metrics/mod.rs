//! Serving metrics: latency distributions, energy accounting, mergeable
//! histograms for fleet-scale aggregation, per-request JSONL traces,
//! the plan-decision audit log, the telemetry registry, the streaming
//! health monitor with its sliding-window primitives, the Perfetto
//! trace-event exporter, and the aggregate report the benches and CLI
//! print.

pub mod audit;
pub mod energy;
pub mod health;
pub mod histogram;
pub mod latency;
pub mod perfetto;
pub mod registry;
pub mod report;
pub mod trace;
pub mod window;

pub use audit::{plan_fingerprint, AuditLog, AuditSummary, PlanDecision};
pub use energy::EnergyAccount;
pub use health::{Alert, HealthConfig, HealthMonitor, HealthState, HealthSummary};
pub use histogram::LogHistogram;
pub use latency::LatencyRecorder;
pub use registry::TelemetryRegistry;
pub use report::{BatchStats, PlanCacheStats, SchedStats, ServingReport};
pub use trace::{TraceMeta, TraceObserver};
pub use window::{WindowCounter, WindowHistogram, WindowStat};
