//! Chrome trace-event (Perfetto) export of a recorded serving trace.
//!
//! `adaoper inspect <trace.jsonl> --perfetto out.json` turns the JSONL
//! stream [`crate::metrics::TraceObserver`] writes into the Chrome
//! trace-event JSON format (`{"traceEvents":[…]}`) that
//! `chrome://tracing` and [ui.perfetto.dev](https://ui.perfetto.dev)
//! open directly:
//!
//! * one **track per processor** (tid 1 = `cpu`, tid 2 = `gpu`) carrying
//!   complete (`"ph":"X"`) spans for every executed operator — a `split`
//!   op draws a span on both tracks;
//! * instant (`"ph":"i"`) markers for **batch closes** (tid 10),
//!   **monitor ticks** (tid 11), **plan switches** (tid 12, from
//!   `replan` / `plan_decision` lines), and **health alerts** (tid 13,
//!   from `alert` lines — the track metadata is only emitted when the
//!   trace actually carries alerts, keeping alert-free exports
//!   byte-identical);
//! * metadata (`"ph":"M"`) naming the process and every track.
//!
//! Timestamps are virtual seconds scaled to microseconds (the trace-event
//! unit). The export is deterministic: events are emitted in trace line
//! order, so a fixed-seed trace produces a byte-identical export (pinned
//! by `rust/tests/golden_perfetto.rs`). [`validate`] re-parses an export
//! and checks that every span nests correctly per track — the
//! `make inspect-smoke` gate.

use anyhow::{bail, ensure, Context, Result};

use crate::util::json::Json;

const PID: u64 = 1;
const TID_CPU: u64 = 1;
const TID_GPU: u64 = 2;
const TID_BATCH: u64 = 10;
const TID_MONITOR: u64 = 11;
const TID_PLAN: u64 = 12;
const TID_HEALTH: u64 = 13;

/// Span-nesting tolerance, microseconds (floating-point scale slop).
const NEST_EPS_US: f64 = 1e-6;

fn us(x: f64) -> String {
    let v = x * 1e6;
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".to_string()
    }
}

fn meta_event(tid: Option<u64>, key: &str, name: &str) -> String {
    match tid {
        Some(t) => format!(
            "{{\"ph\":\"M\",\"pid\":{PID},\"tid\":{t},\"name\":\"{key}\",\
             \"args\":{{\"name\":\"{name}\"}}}}"
        ),
        None => format!(
            "{{\"ph\":\"M\",\"pid\":{PID},\"name\":\"{key}\",\
             \"args\":{{\"name\":\"{name}\"}}}}"
        ),
    }
}

/// Which processor tracks a placement label draws on.
fn tids_of(placement: &str) -> Vec<u64> {
    if placement == "cpu" {
        vec![TID_CPU]
    } else if placement == "gpu" {
        vec![TID_GPU]
    } else {
        // split(0.xx) co-executes on both
        vec![TID_CPU, TID_GPU]
    }
}

/// Convert a JSONL trace (as text) to Chrome trace-event JSON.
pub fn export_str(jsonl: &str) -> Result<String> {
    let mut events: Vec<String> = vec![
        meta_event(None, "process_name", "adaoper"),
        meta_event(Some(TID_CPU), "thread_name", "cpu"),
        meta_event(Some(TID_GPU), "thread_name", "gpu"),
        meta_event(Some(TID_BATCH), "thread_name", "batches"),
        meta_event(Some(TID_MONITOR), "thread_name", "monitor"),
        meta_event(Some(TID_PLAN), "thread_name", "plans"),
    ];
    let mut requests = 0usize;
    // the health track's metadata is pushed lazily on the first alert
    // line, so alert-free traces export byte-identically to before
    let mut health_track = false;
    for (i, line) in jsonl.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let obj = Json::parse(line).with_context(|| format!("trace line {}", i + 1))?;
        match obj.get("event").and_then(Json::as_str) {
            Some("trace_header") | Some("report") | Some("stage_timers") => {}
            Some("batch_close") => {
                events.push(format!(
                    "{{\"ph\":\"i\",\"pid\":{PID},\"tid\":{TID_BATCH},\"s\":\"t\",\
                     \"cat\":\"batch\",\"name\":\"batch s{} op{} x{}\",\"ts\":{},\
                     \"args\":{{\"size\":{},\"wait_us\":{}}}}}",
                    obj.need_usize("stream")?,
                    obj.need_usize("op")?,
                    obj.need_usize("size")?,
                    us(obj.need_f64("t_s")?),
                    obj.need_usize("size")?,
                    us(obj.need_f64("wait_s")?),
                ));
            }
            Some("monitor_tick") => {
                let changed = obj.need_bool("regime_changed")?;
                events.push(format!(
                    "{{\"ph\":\"i\",\"pid\":{PID},\"tid\":{TID_MONITOR},\"s\":\"t\",\
                     \"cat\":\"monitor\",\"name\":\"{}\",\"ts\":{}}}",
                    if changed { "regime change" } else { "monitor tick" },
                    us(obj.need_f64("t_s")?),
                ));
            }
            Some("replan") => {
                events.push(format!(
                    "{{\"ph\":\"i\",\"pid\":{PID},\"tid\":{TID_PLAN},\"s\":\"t\",\
                     \"cat\":\"plan\",\"name\":\"replan {} s{}\",\"ts\":{},\
                     \"args\":{{\"decision_us\":{}}}}}",
                    obj.need_str("trigger")?,
                    obj.need_usize("stream")?,
                    us(obj.need_f64("t_s")?),
                    us(obj.need_f64("decision_s")?),
                ));
            }
            Some("plan_decision") => {
                events.push(format!(
                    "{{\"ph\":\"i\",\"pid\":{PID},\"tid\":{TID_PLAN},\"s\":\"t\",\
                     \"cat\":\"plan\",\"name\":\"plan-switch {} s{}\",\"ts\":{},\
                     \"args\":{{\"old_fp\":\"{}\",\"new_fp\":\"{}\",\"cache_hit\":{}}}}}",
                    obj.need_str("trigger")?,
                    obj.need_usize("stream")?,
                    us(obj.need_f64("t_s")?),
                    obj.need_str("old_fp")?,
                    obj.need_str("new_fp")?,
                    obj.need_bool("cache_hit")?,
                ));
            }
            Some("alert") => {
                if !health_track {
                    health_track = true;
                    events.push(meta_event(Some(TID_HEALTH), "thread_name", "health"));
                }
                let stream = obj
                    .get("stream")
                    .and_then(Json::as_usize)
                    .map_or("global".to_string(), |s| format!("s{s}"));
                events.push(format!(
                    "{{\"ph\":\"i\",\"pid\":{PID},\"tid\":{TID_HEALTH},\"s\":\"t\",\
                     \"cat\":\"health\",\"name\":\"{} {} {}\",\"ts\":{},\
                     \"args\":{{\"prev\":\"{}\",\"signal\":{},\"threshold\":{}}}}}",
                    obj.need_str("rule")?,
                    stream,
                    obj.need_str("state")?,
                    us(obj.need_f64("t_s")?),
                    obj.need_str("prev")?,
                    obj.need_f64("signal")?,
                    obj.need_f64("threshold")?,
                ));
            }
            Some(other) => bail!("trace line {}: unknown event `{other}`", i + 1),
            None => {
                // a request line; shed ones carry no ops
                if obj.need_bool("shed")? {
                    continue;
                }
                requests += 1;
                let id = obj.need_usize("id")?;
                let stream = obj.need_usize("stream")?;
                for op in obj.need_arr("ops")? {
                    let placement = op.need_str("placement")?;
                    let k = op.need_usize("op")?;
                    for tid in tids_of(placement) {
                        events.push(format!(
                            "{{\"ph\":\"X\",\"pid\":{PID},\"tid\":{tid},\
                             \"cat\":\"op\",\"name\":\"s{stream}:op{k}\",\"ts\":{},\"dur\":{},\
                             \"args\":{{\"request\":{id},\"placement\":\"{placement}\"}}}}",
                            us(op.need_f64("start_s")?),
                            us(op.need_f64("latency_s")?),
                        ));
                    }
                }
            }
        }
    }
    ensure!(
        requests > 0 || events.len() > 6,
        "trace carries no completed requests or kernel events to export"
    );
    let mut out = String::from("{\"traceEvents\":[\n");
    for (i, e) in events.iter().enumerate() {
        if i > 0 {
            out.push_str(",\n");
        }
        out.push_str(e);
    }
    out.push_str("\n]}\n");
    Ok(out)
}

/// Validate a Chrome trace-event export: it parses, every event carries a
/// phase and timestamp, and complete spans nest correctly within each
/// `(pid, tid)` track (identical and contained spans allowed — batched
/// requests draw identical spans). Returns the number of events checked.
pub fn validate(json: &str) -> Result<usize> {
    let v = Json::parse(json).context("parsing trace-event JSON")?;
    let events = v.need_arr("traceEvents")?;
    // (pid, tid) -> [(ts, dur)]
    let mut tracks: std::collections::BTreeMap<(u64, u64), Vec<(f64, f64)>> =
        std::collections::BTreeMap::new();
    for (i, e) in events.iter().enumerate() {
        let ph = e.need_str("ph").with_context(|| format!("event {i}"))?;
        match ph {
            "M" => {}
            "i" => {
                e.need_f64("ts").with_context(|| format!("instant event {i}"))?;
            }
            "X" => {
                let ts = e.need_f64("ts").with_context(|| format!("span event {i}"))?;
                let dur = e.need_f64("dur").with_context(|| format!("span event {i}"))?;
                ensure!(dur >= 0.0, "span event {i} has negative duration {dur}");
                let pid = e.need_u64("pid")?;
                let tid = e.need_u64("tid")?;
                tracks.entry((pid, tid)).or_default().push((ts, dur));
            }
            other => bail!("event {i} has unsupported phase `{other}`"),
        }
    }
    for ((pid, tid), spans) in &mut tracks {
        // sort by start time, longer span first on ties, so a containing
        // span precedes its children
        spans.sort_by(|a, b| a.0.total_cmp(&b.0).then(b.1.total_cmp(&a.1)));
        let mut stack: Vec<f64> = Vec::new();
        for &(ts, dur) in spans.iter() {
            while let Some(&top) = stack.last() {
                if ts >= top - NEST_EPS_US {
                    stack.pop();
                } else {
                    break;
                }
            }
            let end = ts + dur;
            if let Some(&top) = stack.last() {
                ensure!(
                    end <= top + NEST_EPS_US,
                    "track pid={pid} tid={tid}: span [{ts}, {end}] overlaps the \
                     enclosing span ending at {top} without nesting"
                );
            }
            stack.push(end);
        }
    }
    Ok(events.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> String {
        [
            r#"{"id":0,"stream":0,"arrival_s":0.01,"deadline_s":0.26,"shed":false,"start_s":0.012,"finish_s":0.05,"latency_s":0.04,"queue_s":0.002,"energy_j":0.02,"met_deadline":true,"ops":[{"op":0,"start_s":0.012,"latency_s":0.01,"energy_j":0.004,"placement":"gpu"},{"op":1,"start_s":0.022,"latency_s":0.008,"energy_j":0.003,"placement":"split(0.30)"}]}"#,
            r#"{"id":1,"stream":0,"arrival_s":0.30,"deadline_s":0.55,"shed":true}"#,
            r#"{"event":"batch_close","stream":0,"op":0,"t_s":0.4,"size":3,"wait_s":0.002}"#,
            r#"{"event":"monitor_tick","t_s":0.5,"regime_changed":true}"#,
            r#"{"event":"replan","stream":0,"t_s":0.5,"trigger":"regime-change","decision_s":0.000002}"#,
            r#"{"event":"plan_decision","t_s":0.5,"stream":0,"trigger":"regime-change","old_fp":"00000000000000aa","new_fp":"00000000000000bb","pred_before":{"latency_s":0.04,"energy_j":0.2},"pred_after":{"latency_s":0.03,"energy_j":0.15},"cache_hit":true,"corrector_version":1,"decision_s":0.000002,"residuals":{"cpu":{"ops":0,"pred_s":0,"actual_s":0},"gpu":{"ops":0,"pred_s":0,"actual_s":0}}}"#,
        ]
        .join("\n")
    }

    #[test]
    fn export_draws_processor_tracks_and_plan_instants() {
        let out = export_str(&sample_trace()).unwrap();
        assert!(out.contains("\"thread_name\""));
        // split op lands on both tracks: one cpu span + two gpu spans
        assert_eq!(out.matches("\"tid\":1,\"cat\":\"op\"").count(), 1, "{out}");
        assert_eq!(out.matches("\"tid\":2,\"cat\":\"op\"").count(), 2, "{out}");
        assert!(out.contains("plan-switch regime-change s0"));
        assert!(out.contains("replan regime-change s0"));
        assert!(out.contains("regime change"));
        assert!(out.contains("batch s0 op0 x3"));
        // shed request draws nothing
        assert!(!out.contains("\"request\":1"));
    }

    #[test]
    fn export_validates() {
        let out = export_str(&sample_trace()).unwrap();
        let n = validate(&out).unwrap();
        assert!(n >= 9, "{n}");
    }

    #[test]
    fn alert_lines_draw_health_instants_on_lazy_track() {
        let trace = format!(
            "{}\n{}\n{}",
            sample_trace(),
            r#"{"event":"alert","t_s":0.6,"rule":"slo_burn","stream":0,"prev":"ok","state":"warn","signal":2.5,"threshold":1}"#,
            r#"{"event":"alert","t_s":0.7,"rule":"queue_depth","stream":null,"prev":"warn","state":"ok","signal":3,"threshold":6.4}"#,
        );
        let out = export_str(&trace).unwrap();
        assert_eq!(out.matches("\"name\":\"health\"").count(), 1, "{out}");
        assert!(out.contains("slo_burn s0 warn"), "{out}");
        assert!(out.contains("queue_depth global ok"), "{out}");
        assert!(out.contains("\"tid\":13,\"s\":\"t\",\"cat\":\"health\""), "{out}");
        validate(&out).unwrap();
        // alert-free traces carry no health track at all
        let plain = export_str(&sample_trace()).unwrap();
        assert!(!plain.contains("health"), "{plain}");
    }

    #[test]
    fn validate_allows_identical_and_nested_spans() {
        let ok = r#"{"traceEvents":[
            {"ph":"X","pid":1,"tid":1,"name":"a","ts":0,"dur":10},
            {"ph":"X","pid":1,"tid":1,"name":"a","ts":0,"dur":10},
            {"ph":"X","pid":1,"tid":1,"name":"b","ts":2,"dur":3},
            {"ph":"X","pid":1,"tid":1,"name":"c","ts":12,"dur":1}
        ]}"#;
        assert_eq!(validate(ok).unwrap(), 4);
    }

    #[test]
    fn validate_rejects_partial_overlap() {
        let bad = r#"{"traceEvents":[
            {"ph":"X","pid":1,"tid":1,"name":"a","ts":0,"dur":10},
            {"ph":"X","pid":1,"tid":1,"name":"b","ts":5,"dur":10}
        ]}"#;
        let err = validate(bad).unwrap_err().to_string();
        assert!(err.contains("without nesting"), "{err}");
    }

    #[test]
    fn validate_rejects_negative_duration_and_bad_phase() {
        let neg = r#"{"traceEvents":[{"ph":"X","pid":1,"tid":1,"ts":0,"dur":-1}]}"#;
        assert!(validate(neg).is_err());
        let ph = r#"{"traceEvents":[{"ph":"Z","ts":0}]}"#;
        assert!(validate(ph).is_err());
    }

    #[test]
    fn export_rejects_empty_traces() {
        assert!(export_str("").is_err());
        let header_only = r#"{"event":"report","row":"x"}"#;
        assert!(export_str(header_only).is_err());
    }
}
