//! Named telemetry registry: counters, gauges, and log-bucket histograms.
//!
//! The registry is the fleet-facing half of the observability layer. A
//! per-device run populates one (typically from its
//! [`crate::sim::EventCounters`] and latency histogram), and the sharded
//! fleet runner folds them the same way [`crate::fleet::FleetReport`]
//! merges class aggregates: **in device order**, through
//! [`TelemetryRegistry::merge`]. All three stores are `BTreeMap`-keyed, so
//! iteration order — and therefore every rendered line and every float
//! summation order — is independent of thread count, making the merged
//! registry bit-identical for any sharding (pinned by
//! `rust/tests/telemetry.rs`).
//!
//! Everything here is zero-dependency and off by default: nothing in the
//! engine touches a registry unless telemetry was explicitly enabled.

use std::collections::BTreeMap;

use crate::sim::EventCounters;

use super::histogram::LogHistogram;

/// A named bag of counters, gauges, and mergeable histograms.
#[derive(Debug, Clone, Default)]
pub struct TelemetryRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, LogHistogram>,
}

impl TelemetryRegistry {
    /// Empty registry.
    pub fn new() -> TelemetryRegistry {
        TelemetryRegistry::default()
    }

    /// Add `by` to the named counter (created at zero on first touch).
    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Current value of a counter (0 when never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Add `v` to the named gauge (gauges merge additively across shards,
    /// so totals like energy or busy-seconds stay exact).
    pub fn add_gauge(&mut self, name: &str, v: f64) {
        *self.gauges.entry(name.to_string()).or_insert(0.0) += v;
    }

    /// Current value of a gauge (`None` when never touched).
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Record one sample into the named histogram, creating it with the
    /// standard latency boundaries ([`LogHistogram::latency`]) on first
    /// touch so cross-shard merges are always compatible.
    pub fn record(&mut self, name: &str, x: f64) {
        self.histograms
            .entry(name.to_string())
            .or_insert_with(LogHistogram::latency)
            .record(x);
    }

    /// Fold a pre-built histogram into the named slot (merging when the
    /// slot exists; panics on incompatible boundaries, same as
    /// [`LogHistogram::merge`]).
    pub fn merge_histogram(&mut self, name: &str, h: &LogHistogram) {
        match self.histograms.get_mut(name) {
            Some(mine) => mine.merge(h),
            None => {
                self.histograms.insert(name.to_string(), h.clone());
            }
        }
    }

    /// The named histogram (`None` when never touched).
    pub fn histogram(&self, name: &str) -> Option<&LogHistogram> {
        self.histograms.get(name)
    }

    /// True when nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.histograms.is_empty()
    }

    /// Fold `other` into `self`: counters and gauges add, histograms
    /// merge. Both sides iterate in key order, so folding a fixed sequence
    /// of registries is associative and bit-identical regardless of how
    /// the sequence was sharded (as long as fold order is preserved —
    /// which the fleet runner guarantees by merging in device order).
    pub fn merge(&mut self, other: &TelemetryRegistry) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            *self.gauges.entry(k.clone()).or_insert(0.0) += v;
        }
        for (k, h) in &other.histograms {
            self.merge_histogram(k, h);
        }
    }

    /// Populate the standard `sim.*` counters from kernel event tallies.
    pub fn absorb_counters(&mut self, c: &EventCounters) {
        for (name, v) in [
            ("sim.offered", c.offered),
            ("sim.admitted", c.admitted),
            ("sim.shed", c.shed),
            ("sim.op_dispatches", c.op_dispatches),
            ("sim.op_completes", c.op_completes),
            ("sim.monitor_ticks", c.monitor_ticks),
            ("sim.regime_changes", c.regime_changes),
            ("sim.replans", c.replans),
            ("sim.completed", c.completed),
            ("sim.deadline_misses", c.deadline_misses),
            ("sim.batch_closes", c.batch_closes),
            ("sim.batched_requests", c.batched_requests),
            ("sim.alerts", c.alerts),
        ] {
            self.inc(name, v as u64);
        }
    }

    /// Deterministic human-readable listing (also the bit-identity probe
    /// the tests compare: two registries render identically iff their
    /// contents are identical to the displayed precision, and counters
    /// and histogram counts compare exactly).
    pub fn render(&self) -> String {
        let mut s = String::new();
        for (k, v) in &self.counters {
            s.push_str(&format!("counter {k} = {v}\n"));
        }
        for (k, v) in &self.gauges {
            s.push_str(&format!("gauge   {k} = {v}\n"));
        }
        for (k, h) in &self.histograms {
            s.push_str(&format!(
                "hist    {k}: n={} mean={:?} p50={:?} p95={:?} max={:?}\n",
                h.count(),
                h.mean(),
                h.quantile(0.50),
                h.quantile(0.95),
                h.max()
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(seed: u64) -> TelemetryRegistry {
        let mut r = TelemetryRegistry::new();
        r.inc("sim.offered", seed + 3);
        r.inc("sim.completed", seed);
        r.add_gauge("energy_j", seed as f64 * 0.125);
        for i in 0..seed {
            r.record("latency_s", 1e-3 * (i + 1) as f64);
        }
        r
    }

    #[test]
    fn counters_gauges_histograms_roundtrip() {
        let mut r = TelemetryRegistry::new();
        assert!(r.is_empty());
        r.inc("a", 2);
        r.inc("a", 3);
        r.add_gauge("g", 1.5);
        r.record("h", 0.01);
        assert_eq!(r.counter("a"), 5);
        assert_eq!(r.counter("missing"), 0);
        assert_eq!(r.gauge("g"), Some(1.5));
        assert_eq!(r.gauge("missing"), None);
        assert_eq!(r.histogram("h").unwrap().count(), 1);
        assert!(!r.is_empty());
    }

    #[test]
    fn merge_is_grouping_independent() {
        // fold [r0, r1, r2, r3] serially vs. in two pre-merged halves:
        // counters, gauge bits, and histogram counts must match exactly
        let parts: Vec<TelemetryRegistry> = (0..4).map(|i| sample(i * 7 + 1)).collect();
        let mut serial = TelemetryRegistry::new();
        for p in &parts {
            serial.merge(p);
        }
        let mut left = TelemetryRegistry::new();
        left.merge(&parts[0]);
        left.merge(&parts[1]);
        let mut right = TelemetryRegistry::new();
        right.merge(&parts[2]);
        right.merge(&parts[3]);
        let mut halves = TelemetryRegistry::new();
        halves.merge(&left);
        halves.merge(&right);
        assert_eq!(serial.render(), halves.render());
        assert_eq!(
            serial.gauge("energy_j").unwrap().to_bits(),
            halves.gauge("energy_j").unwrap().to_bits()
        );
        assert_eq!(
            serial.histogram("latency_s").unwrap().counts(),
            halves.histogram("latency_s").unwrap().counts()
        );
    }

    #[test]
    fn absorb_counters_populates_standard_keys() {
        let c = EventCounters {
            offered: 10,
            completed: 8,
            shed: 2,
            ..Default::default()
        };
        let mut r = TelemetryRegistry::new();
        r.absorb_counters(&c);
        assert_eq!(r.counter("sim.offered"), 10);
        assert_eq!(r.counter("sim.completed"), 8);
        assert_eq!(r.counter("sim.shed"), 2);
        assert_eq!(r.counter("sim.replans"), 0);
    }

    #[test]
    fn render_lists_in_key_order() {
        let mut r = TelemetryRegistry::new();
        r.inc("zeta", 1);
        r.inc("alpha", 1);
        let out = r.render();
        let a = out.find("alpha").unwrap();
        let z = out.find("zeta").unwrap();
        assert!(a < z, "{out}");
    }
}
