//! Aggregate serving report: one row of the paper's Figure-2-style output.

use crate::util::stats::Summary;

/// Counter snapshot of the coordinator's partition-plan cache
/// ([`crate::coordinator::plan_cache`]): how often planning lookups — both
/// the initial per-run plan construction and regime-change repartitions —
/// were served from cache instead of re-running the DP. Lookups therefore
/// exceed `repartitions` in the same report whenever initial planning went
/// through the cache.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PlanCacheStats {
    pub hits: usize,
    pub misses: usize,
    pub evictions: usize,
    /// Plans currently resident.
    pub entries: usize,
    pub capacity: usize,
}

impl PlanCacheStats {
    pub fn lookups(&self) -> usize {
        self.hits + self.misses
    }

    /// Fraction of lookups served from cache (0 when no lookups happened).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }
}

/// Everything a serving run produces, ready to print or compare.
#[derive(Debug, Clone)]
pub struct ServingReport {
    pub policy: String,
    pub condition: String,
    pub models: Vec<String>,
    pub duration_s: f64,
    pub requests: usize,
    pub throughput_hz: f64,
    pub latency: Option<Summary>,
    pub queue: Option<Summary>,
    pub miss_rate: f64,
    pub total_energy_j: f64,
    pub j_per_inference: f64,
    pub inferences_per_j: f64,
    /// Measured average CPU utilization (background + task) — the paper
    /// quotes this per condition (78.8 % moderate, 91.3 % high).
    pub avg_cpu_util: f64,
    pub avg_gpu_util: f64,
    /// Number of (incremental) repartitions triggered.
    pub repartitions: usize,
    /// Mean time spent per partitioning decision.
    pub partition_overhead_s: f64,
    /// Partition-plan cache counters (None when the cache is disabled).
    pub plan_cache: Option<PlanCacheStats>,
}

impl ServingReport {
    /// One-line row (bench tables).
    pub fn row(&self) -> String {
        let l = self.latency.as_ref();
        let mut s = format!(
            "{:<14} {:<9} {:>6} req {:>7.2} req/s  p50 {:>7.2} ms  p99 {:>7.2} ms  miss {:>5.1}%  {:>8.2} mJ/inf  {:>6.2} inf/J  cpu {:>5.1}%  repart {:>3}",
            self.policy,
            self.condition,
            self.requests,
            self.throughput_hz,
            l.map_or(f64::NAN, |s| s.p50 * 1e3),
            l.map_or(f64::NAN, |s| s.p99 * 1e3),
            self.miss_rate * 100.0,
            self.j_per_inference * 1e3,
            self.inferences_per_j,
            self.avg_cpu_util * 100.0,
            self.repartitions,
        );
        if let Some(pc) = &self.plan_cache {
            s.push_str(&format!("  cache {}/{}", pc.hits, pc.lookups()));
        }
        s
    }

    /// Multi-line human report (CLI `serve`).
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "policy={} condition={} models={:?} duration={:.1}s\n",
            self.policy, self.condition, self.models, self.duration_s
        ));
        s.push_str(&format!(
            "  requests           {} ({:.2} req/s)\n",
            self.requests, self.throughput_hz
        ));
        if let Some(l) = &self.latency {
            s.push_str(&format!(
                "  latency            mean {:.2} ms  p50 {:.2} ms  p90 {:.2} ms  p99 {:.2} ms\n",
                l.mean * 1e3,
                l.p50 * 1e3,
                l.p90 * 1e3,
                l.p99 * 1e3
            ));
        }
        if let Some(q) = &self.queue {
            s.push_str(&format!("  queueing           mean {:.2} ms\n", q.mean * 1e3));
        }
        s.push_str(&format!(
            "  deadline misses    {:.2}%\n",
            self.miss_rate * 100.0
        ));
        s.push_str(&format!(
            "  energy             total {:.3} J  {:.2} mJ/inf  {:.2} inf/J\n",
            self.total_energy_j,
            self.j_per_inference * 1e3,
            self.inferences_per_j
        ));
        s.push_str(&format!(
            "  utilization        cpu {:.1}%  gpu {:.1}%\n",
            self.avg_cpu_util * 100.0,
            self.avg_gpu_util * 100.0
        ));
        s.push_str(&format!(
            "  repartitions       {} (mean decision {:.1} µs)\n",
            self.repartitions,
            self.partition_overhead_s * 1e6
        ));
        if let Some(pc) = &self.plan_cache {
            s.push_str(&format!(
                "  plan cache         {} hits / {} misses ({:.1}% hit rate, {} evictions, {}/{} entries)\n",
                pc.hits,
                pc.misses,
                pc.hit_rate() * 100.0,
                pc.evictions,
                pc.entries,
                pc.capacity
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> ServingReport {
        ServingReport {
            policy: "adaoper".into(),
            condition: "high".into(),
            models: vec!["yolov2".into()],
            duration_s: 10.0,
            requests: 100,
            throughput_hz: 10.0,
            latency: Summary::of(&[0.08, 0.09, 0.1]),
            queue: Summary::of(&[0.001]),
            miss_rate: 0.05,
            total_energy_j: 12.0,
            j_per_inference: 0.12,
            inferences_per_j: 8.33,
            avg_cpu_util: 0.913,
            avg_gpu_util: 0.6,
            repartitions: 3,
            partition_overhead_s: 150e-6,
            plan_cache: Some(PlanCacheStats {
                hits: 8,
                misses: 2,
                evictions: 1,
                entries: 2,
                capacity: 32,
            }),
        }
    }

    #[test]
    fn row_contains_key_fields() {
        let r = report().row();
        assert!(r.contains("adaoper"));
        assert!(r.contains("high"));
        assert!(r.contains("inf/J"));
        assert!(r.contains("cache 8/10"));
    }

    #[test]
    fn pretty_contains_sections() {
        let p = report().pretty();
        assert!(p.contains("latency"));
        assert!(p.contains("energy"));
        assert!(p.contains("repartitions"));
        assert!(p.contains("91.3%"));
        assert!(p.contains("plan cache"));
        assert!(p.contains("80.0% hit rate"));
    }

    #[test]
    fn cache_stats_rates() {
        let pc = PlanCacheStats {
            hits: 3,
            misses: 1,
            evictions: 0,
            entries: 4,
            capacity: 8,
        };
        assert_eq!(pc.lookups(), 4);
        assert!((pc.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(PlanCacheStats::default().hit_rate(), 0.0);
    }

    #[test]
    fn no_cache_omits_section() {
        let mut r = report();
        r.plan_cache = None;
        assert!(!r.pretty().contains("plan cache"));
        assert!(!r.row().contains("cache"));
    }
}
