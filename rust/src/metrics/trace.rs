//! Per-request JSONL timelines, recorded through the simulation kernel's
//! observer hooks.
//!
//! [`TraceObserver`] implements [`SimObserver`] and emits one JSON object
//! per request, in completion order (shed requests emit at admission):
//!
//! ```json
//! {"id":12,"stream":0,"arrival_s":0.8421,"deadline_s":0.9921,"shed":false,
//!  "start_s":0.8510,"finish_s":0.9402,"latency_s":0.0981,"queue_s":0.0089,
//!  "energy_j":0.0214,"met_deadline":true,
//!  "ops":[{"op":0,"start_s":0.8510,"latency_s":0.0041,"energy_j":0.0011,
//!          "placement":"gpu"}, ...]}
//! ```
//!
//! Shed requests carry `"shed":true` and omit the execution fields. When
//! dynamic batching is enabled, every batch close additionally emits a
//! standalone event line (interleaved with request lines in close order):
//!
//! ```json
//! {"event":"batch_close","stream":0,"op":0,"t_s":1.2345,"size":4,
//!  "wait_s":0.0031}
//! ```
//!
//! A trace built with [`TraceObserver::with_meta`] additionally opens
//! with a `{"event":"trace_header",...}` line capturing the full run
//! configuration (seed, policies, calibration, streams, condition
//! timeline), stamps every request line with `"seed"` and the condition
//! `"regime"` in force at its arrival, and can close with a
//! `{"event":"report","row":...}` trailer — together these make the file
//! self-contained for `adaoper replay`. [`TraceObserver::new`] keeps the
//! legacy headerless format byte-identical.
//!
//! The CLI wires this behind `adaoper serve --trace <path>` (or the
//! `[serve] trace` config key); every line is standalone JSON, so the
//! file streams into `jq`/pandas without a wrapper.

use std::collections::HashMap;
use std::fmt::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::engine::EngineConfig;
use crate::coordinator::request::{RequestOutcome, StreamSpec};
use crate::metrics::health::HealthConfig;
use crate::partition::plan::Objective;
use crate::sim::event::Event;
use crate::sim::observer::SimObserver;
use crate::workload::Arrival;

/// One executed operator in a request's timeline.
#[derive(Debug, Clone)]
struct OpTrace {
    op: usize,
    start_s: f64,
    latency_s: f64,
    energy_j: f64,
    placement: String,
}

/// Accumulating state of an in-flight request.
#[derive(Debug, Clone)]
struct ReqTrace {
    stream: usize,
    arrival_s: f64,
    deadline_s: f64,
    ops: Vec<OpTrace>,
}

/// [`SimObserver`] that renders per-request JSONL timelines.
#[derive(Debug, Default)]
pub struct TraceObserver {
    pending: HashMap<usize, ReqTrace>,
    lines: Vec<String>,
    meta: Option<TraceMeta>,
    /// Opt-in: also emit `monitor_tick` / `replan` event lines (the
    /// telemetry path; legacy traces stay byte-identical when off).
    kernel_events: bool,
}

/// JSON-safe float: finite values print via `Display`, everything else
/// becomes `null` (JSON has no NaN/Inf).
fn json_f64(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

/// Escape a string for a JSON literal (quotes, backslashes, control).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Run-level metadata carried in a trace's header line: everything
/// `adaoper replay` needs to reconstruct the recording engine — the full
/// [`EngineConfig`] plus the model/arrival/SLO of each stream. Captured
/// with [`TraceMeta::of`] and serialized as the first JSONL line
/// (`"event":"trace_header"`) by [`TraceObserver::with_meta`].
///
/// The device parameterization is assumed to be the default
/// (Snapdragon 855); traces recorded against fleet device classes are
/// not replayable through the CLI path.
#[derive(Debug, Clone)]
pub struct TraceMeta {
    /// The engine configuration of the recording run.
    pub cfg: EngineConfig,
    /// Per-stream `(model name, arrival process, slo_s)` in stream order.
    pub streams: Vec<(String, Arrival, f64)>,
}

/// Render an [`Objective`] as the string the trace header (and the
/// scenario spec) uses: `min-edp` | `min-latency` |
/// `min-energy-slo:<slo_s>`.
pub fn objective_str(o: &Objective) -> String {
    match o {
        Objective::MinEdp => "min-edp".to_string(),
        Objective::MinLatency => "min-latency".to_string(),
        Objective::MinEnergyUnderSlo { slo_s } => format!("min-energy-slo:{slo_s}"),
    }
}

/// Render an [`Arrival`] as a JSON object carrying its exact parameters
/// (MMPP keeps all four, not just the stationary mean, so replay
/// reconstructs non-canonical shapes too).
fn arrival_json(a: &Arrival) -> String {
    match a {
        Arrival::Poisson { hz } => {
            format!("{{\"kind\":\"poisson\",\"hz\":{}}}", json_f64(*hz))
        }
        Arrival::Periodic { hz, jitter } => format!(
            "{{\"kind\":\"periodic\",\"hz\":{},\"jitter\":{}}}",
            json_f64(*hz),
            json_f64(*jitter)
        ),
        Arrival::Mmpp {
            hz_low,
            hz_high,
            dwell_low_s,
            dwell_high_s,
        } => format!(
            "{{\"kind\":\"mmpp\",\"hz_low\":{},\"hz_high\":{},\
             \"dwell_low_s\":{},\"dwell_high_s\":{}}}",
            json_f64(*hz_low),
            json_f64(*hz_high),
            json_f64(*dwell_low_s),
            json_f64(*dwell_high_s)
        ),
    }
}

/// Render a [`HealthConfig`] as the JSON object the trace header carries
/// (and `adaoper replay` reconstructs) when the health monitor is on.
fn health_json(h: &HealthConfig) -> String {
    format!(
        "{{\"fast_window_s\":{},\"slow_window_s\":{},\"slo_target\":{},\
         \"burn_warn\":{},\"burn_critical\":{},\"energy_budget_mj\":{},\
         \"drift_warn\":{},\"drift_critical\":{},\"queue_warn\":{},\
         \"queue_critical\":{},\"clear_ratio\":{},\"min_samples\":{}}}",
        json_f64(h.fast_window_s),
        json_f64(h.slow_window_s),
        json_f64(h.slo_target),
        json_f64(h.burn_warn),
        json_f64(h.burn_critical),
        json_f64(h.energy_budget_mj),
        json_f64(h.drift_warn),
        json_f64(h.drift_critical),
        h.queue_warn,
        h.queue_critical,
        json_f64(h.clear_ratio),
        h.min_samples,
    )
}

impl TraceMeta {
    /// Capture the metadata of a run about to execute under `cfg` over
    /// `streams`.
    pub fn of(cfg: &EngineConfig, streams: &[StreamSpec]) -> TraceMeta {
        TraceMeta {
            cfg: cfg.clone(),
            streams: streams
                .iter()
                .map(|s| (s.model.name.clone(), s.arrival.clone(), s.slo_s))
                .collect(),
        }
    }

    /// The condition-regime name in force at virtual time `t`: the
    /// initial condition, overridden by the last timeline boundary at or
    /// before `t`.
    pub fn regime_at(&self, t: f64) -> &'static str {
        let mut name = self.cfg.condition.name();
        for (at_s, kind) in &self.cfg.condition_timeline {
            if *at_s <= t {
                name = kind.name();
            } else {
                break;
            }
        }
        name
    }

    /// The JSON header line (no trailing newline).
    pub fn header_line(&self) -> String {
        let queue_limit = match self.cfg.admission {
            crate::coordinator::AdmissionPolicy::Bounded { per_stream } => per_stream,
            _ => 0,
        };
        let mut streams = String::new();
        for (i, (model, arrival, slo_s)) in self.streams.iter().enumerate() {
            if i > 0 {
                streams.push(',');
            }
            let _ = write!(
                streams,
                "{{\"id\":{},\"model\":\"{}\",\"slo_s\":{},\"arrival\":{}}}",
                i,
                json_escape(model),
                json_f64(*slo_s),
                arrival_json(arrival),
            );
        }
        let mut timeline = String::new();
        for (i, (at_s, kind)) in self.cfg.condition_timeline.iter().enumerate() {
            if i > 0 {
                timeline.push(',');
            }
            let _ = write!(
                timeline,
                "{{\"at_s\":{},\"condition\":\"{}\"}}",
                json_f64(*at_s),
                kind.name(),
            );
        }
        let g = &self.cfg.calib.gbdt;
        let pc = &self.cfg.plan_cache;
        format!(
            "{{\"event\":\"trace_header\",\"version\":1,\
             \"seed\":{},\"duration_s\":{},\
             \"policy\":\"{}\",\"objective\":\"{}\",\"condition\":\"{}\",\
             \"scheduler\":\"{}\",\"admission\":\"{}\",\"queue_limit\":{},\
             \"batch_policy\":\"{}\",\"batch_max\":{},\"batch_wait_s\":{},\
             \"window\":{},\"cooldown_ops\":{},\"monitor_period_s\":{},\
             \"planner_info\":\"{}\",\"use_corrector\":{},\
             \"calib\":{{\"samples\":{},\"seed\":{},\"trees\":{},\"max_depth\":{},\
             \"eta\":{},\"subsample\":{},\"min_leaf\":{},\"bins\":{},\"gbdt_seed\":{}}},\
             \"plan_cache\":{{\"capacity\":{},\"freq_bucket_hz\":{},\"util_bucket\":{},\
             \"temp_bucket_c\":{},\"bw_bucket\":{}}},\
             \"streams\":[{}],\"timeline\":[{}]{}{}}}",
            self.cfg.seed,
            json_f64(self.cfg.duration_s),
            self.cfg.policy.name(),
            objective_str(&self.cfg.objective),
            self.cfg.condition.name(),
            self.cfg.scheduler.name(),
            self.cfg.admission.name(),
            queue_limit,
            self.cfg.batching.policy.name(),
            self.cfg.batching.max,
            json_f64(self.cfg.batching.wait_s),
            self.cfg.window,
            self.cfg.cooldown_ops,
            json_f64(self.cfg.monitor_period_s),
            match self.cfg.planner_info {
                crate::coordinator::engine::PlannerInfo::Profiler => "profiler",
                crate::coordinator::engine::PlannerInfo::Oracle => "oracle",
            },
            self.cfg.use_corrector,
            self.cfg.calib.samples,
            self.cfg.calib.seed,
            g.trees,
            g.max_depth,
            json_f64(g.eta),
            json_f64(g.subsample),
            g.min_leaf,
            g.bins,
            g.seed,
            pc.capacity,
            json_f64(pc.freq_bucket_hz),
            json_f64(pc.util_bucket),
            json_f64(pc.temp_bucket_c),
            json_f64(pc.bw_bucket),
            streams,
            timeline,
            // off-path headers keep their exact pre-telemetry bytes
            if self.cfg.telemetry { ",\"telemetry\":true" } else { "" },
            // likewise: the health object only appears when configured,
            // strictly after the telemetry marker
            match &self.cfg.health {
                Some(h) => format!(",\"health\":{}", health_json(h)),
                None => String::new(),
            },
        )
    }
}

impl TraceObserver {
    /// Empty trace.
    pub fn new() -> TraceObserver {
        TraceObserver::default()
    }

    /// Trace that opens with a `trace_header` line built from `meta` and
    /// stamps every request line with the run seed and the condition
    /// regime in force at its arrival — the fields replay needs without
    /// reaching into engine internals.
    pub fn with_meta(meta: TraceMeta) -> TraceObserver {
        TraceObserver {
            pending: HashMap::new(),
            lines: vec![meta.header_line()],
            meta: Some(meta),
            kernel_events: false,
        }
    }

    /// Builder: also emit standalone `monitor_tick` and `replan` event
    /// lines as the kernel delivers them (the `--telemetry` trace shape;
    /// the Perfetto exporter turns these into instant markers).
    pub fn with_kernel_events(mut self) -> TraceObserver {
        self.kernel_events = true;
        self
    }

    /// Append one pre-rendered JSONL line (the engine uses this to attach
    /// `plan_decision` and `stage_timers` telemetry lines to the stream).
    pub fn push_line(&mut self, line: String) {
        self.lines.push(line);
    }

    /// Append a `{"event":"report","row":...}` trailer carrying the
    /// finished run's [`ServingReport::row`](crate::metrics::ServingReport::row)
    /// so replay can assert byte-identity against the recorded report.
    pub fn push_report_row(&mut self, row: &str) {
        self.lines.push(format!(
            "{{\"event\":\"report\",\"row\":\"{}\"}}",
            json_escape(row)
        ));
    }

    /// `,"seed":…,"regime":…` suffix for a request line, empty without
    /// metadata (legacy traces stay byte-identical).
    fn req_extra(&self, arrival_s: f64) -> String {
        match &self.meta {
            Some(m) => format!(
                ",\"seed\":{},\"regime\":\"{}\"",
                m.cfg.seed,
                m.regime_at(arrival_s)
            ),
            None => String::new(),
        }
    }

    /// Finished JSONL lines, in emission order.
    pub fn lines(&self) -> &[String] {
        &self.lines
    }

    /// Number of finished lines.
    pub fn len(&self) -> usize {
        self.lines.len()
    }

    /// Whether no lines were produced yet.
    pub fn is_empty(&self) -> bool {
        self.lines.is_empty()
    }

    /// The whole trace as one JSONL string (trailing newline included
    /// when non-empty).
    pub fn to_jsonl(&self) -> String {
        if self.lines.is_empty() {
            String::new()
        } else {
            let mut s = self.lines.join("\n");
            s.push('\n');
            s
        }
    }

    /// Write the trace to `path`.
    pub fn write_to(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_jsonl())
            .with_context(|| format!("writing trace to {}", path.display()))
    }
}

impl SimObserver for TraceObserver {
    fn on_event(&mut self, event: &Event) {
        match event {
            Event::Arrival { req, admitted } => {
                if *admitted {
                    self.pending.insert(
                        req.id,
                        ReqTrace {
                            stream: req.stream,
                            arrival_s: req.arrival_s,
                            deadline_s: req.deadline_s,
                            ops: Vec::new(),
                        },
                    );
                } else {
                    let extra = self.req_extra(req.arrival_s);
                    self.lines.push(format!(
                        "{{\"id\":{},\"stream\":{},\"arrival_s\":{},\
                         \"deadline_s\":{},\"shed\":true{}}}",
                        req.id,
                        req.stream,
                        json_f64(req.arrival_s),
                        json_f64(req.deadline_s),
                        extra,
                    ));
                }
            }
            Event::OpDispatch {
                request,
                op,
                start_s,
                placement,
                ..
            } => {
                if let Some(t) = self.pending.get_mut(request) {
                    t.ops.push(OpTrace {
                        op: *op,
                        start_s: *start_s,
                        latency_s: 0.0,
                        energy_j: 0.0,
                        placement: placement.to_string(),
                    });
                }
            }
            Event::OpComplete {
                request,
                latency_s,
                energy_j,
                ..
            } => {
                if let Some(t) = self.pending.get_mut(request) {
                    if let Some(last) = t.ops.last_mut() {
                        last.latency_s = *latency_s;
                        last.energy_j = *energy_j;
                    }
                }
            }
            Event::BatchClose {
                stream,
                op,
                t_s,
                size,
                wait_s,
            } => {
                self.lines.push(format!(
                    "{{\"event\":\"batch_close\",\"stream\":{},\"op\":{},\"t_s\":{},\
                     \"size\":{},\"wait_s\":{}}}",
                    stream,
                    op,
                    json_f64(*t_s),
                    size,
                    json_f64(*wait_s),
                ));
            }
            Event::MonitorTick { t_s, regime_changed } => {
                if self.kernel_events {
                    self.lines.push(format!(
                        "{{\"event\":\"monitor_tick\",\"t_s\":{},\"regime_changed\":{}}}",
                        json_f64(*t_s),
                        regime_changed,
                    ));
                }
            }
            Event::RegimeReplan { stream, t_s, trigger, decision_s } => {
                if self.kernel_events {
                    self.lines.push(format!(
                        "{{\"event\":\"replan\",\"stream\":{},\"t_s\":{},\
                         \"trigger\":\"{}\",\"decision_s\":{}}}",
                        stream,
                        json_f64(*t_s),
                        trigger.name(),
                        json_f64(*decision_s),
                    ));
                }
            }
            // alerts only exist on runs with the health monitor on, so
            // no gating is needed: legacy traces never see them
            Event::Alert { alert } => {
                let stream = alert
                    .stream
                    .map_or("null".to_string(), |s| s.to_string());
                self.lines.push(format!(
                    "{{\"event\":\"alert\",\"t_s\":{},\"rule\":\"{}\",\"stream\":{},\
                     \"prev\":\"{}\",\"state\":\"{}\",\"signal\":{},\"threshold\":{}}}",
                    json_f64(alert.t_s),
                    alert.rule,
                    stream,
                    alert.prev.name(),
                    alert.state.name(),
                    json_f64(alert.signal),
                    json_f64(alert.threshold),
                ));
            }
        }
    }

    fn on_request_done(&mut self, outcome: &RequestOutcome, met_deadline: bool) {
        let id = outcome.request.id;
        let t = self.pending.remove(&id).unwrap_or(ReqTrace {
            stream: outcome.request.stream,
            arrival_s: outcome.request.arrival_s,
            deadline_s: outcome.request.deadline_s,
            ops: Vec::new(),
        });
        let mut ops = String::new();
        for (i, o) in t.ops.iter().enumerate() {
            if i > 0 {
                ops.push(',');
            }
            let _ = write!(
                ops,
                "{{\"op\":{},\"start_s\":{},\"latency_s\":{},\"energy_j\":{},\
                 \"placement\":\"{}\"}}",
                o.op,
                json_f64(o.start_s),
                json_f64(o.latency_s),
                json_f64(o.energy_j),
                json_escape(&o.placement),
            );
        }
        let extra = self.req_extra(t.arrival_s);
        self.lines.push(format!(
            "{{\"id\":{},\"stream\":{},\"arrival_s\":{},\"deadline_s\":{},\"shed\":false,\
             \"start_s\":{},\"finish_s\":{},\"latency_s\":{},\"queue_s\":{},\"energy_j\":{},\
             \"met_deadline\":{}{},\"ops\":[{}]}}",
            id,
            t.stream,
            json_f64(t.arrival_s),
            json_f64(t.deadline_s),
            json_f64(outcome.start_s),
            json_f64(outcome.finish_s),
            json_f64(outcome.latency_s()),
            json_f64(outcome.queue_s()),
            json_f64(outcome.energy_j),
            met_deadline,
            extra,
            ops,
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Request;
    use crate::soc::Placement;

    fn req(id: usize, arrival: f64) -> Request {
        Request {
            id,
            stream: 0,
            arrival_s: arrival,
            deadline_s: arrival + 0.5,
        }
    }

    #[test]
    fn records_one_line_per_request_in_completion_order() {
        let mut tr = TraceObserver::new();
        tr.on_event(&Event::Arrival {
            req: req(0, 0.0),
            admitted: true,
        });
        tr.on_event(&Event::OpDispatch {
            request: 0,
            stream: 0,
            op: 0,
            start_s: 0.01,
            placement: Placement::GPU,
        });
        tr.on_event(&Event::OpComplete {
            request: 0,
            stream: 0,
            op: 0,
            end_s: 0.02,
            latency_s: 0.01,
            energy_j: 0.001,
        });
        tr.on_request_done(
            &RequestOutcome {
                request: req(0, 0.0),
                start_s: 0.01,
                finish_s: 0.02,
                energy_j: 0.001,
            },
            true,
        );
        assert_eq!(tr.len(), 1);
        let line = &tr.lines()[0];
        assert!(line.starts_with('{') && line.ends_with('}'), "{line}");
        assert!(line.contains("\"id\":0"));
        assert!(line.contains("\"shed\":false"));
        assert!(line.contains("\"met_deadline\":true"));
        assert!(line.contains("\"ops\":[{"));
        assert!(line.contains("\"placement\":\""));
        assert!(tr.to_jsonl().ends_with('\n'));
    }

    #[test]
    fn shed_requests_emit_immediately() {
        let mut tr = TraceObserver::new();
        tr.on_event(&Event::Arrival {
            req: req(7, 1.25),
            admitted: false,
        });
        assert_eq!(tr.len(), 1);
        assert!(tr.lines()[0].contains("\"shed\":true"));
        assert!(tr.lines()[0].contains("\"id\":7"));
    }

    #[test]
    fn batch_close_emits_standalone_event_line() {
        let mut tr = TraceObserver::new();
        tr.on_event(&Event::BatchClose {
            stream: 1,
            op: 0,
            t_s: 2.5,
            size: 4,
            wait_s: 0.003,
        });
        assert_eq!(tr.len(), 1);
        let line = &tr.lines()[0];
        assert!(line.starts_with('{') && line.ends_with('}'), "{line}");
        assert!(line.contains("\"event\":\"batch_close\""));
        assert!(line.contains("\"size\":4"));
        assert!(line.contains("\"wait_s\":0.003"));
    }

    #[test]
    fn kernel_events_are_opt_in() {
        use crate::coordinator::repartition::Trigger;
        let tick = Event::MonitorTick { t_s: 1.0, regime_changed: true };
        let replan = Event::RegimeReplan {
            stream: 0,
            t_s: 1.0,
            trigger: Trigger::Drift,
            decision_s: 1e-5,
        };
        let mut off = TraceObserver::new();
        off.on_event(&tick);
        off.on_event(&replan);
        assert!(off.is_empty(), "kernel events must stay silent by default");
        let mut on = TraceObserver::new().with_kernel_events();
        on.on_event(&tick);
        on.on_event(&replan);
        assert_eq!(on.len(), 2);
        assert!(on.lines()[0].contains("\"event\":\"monitor_tick\""));
        assert!(on.lines()[0].contains("\"regime_changed\":true"));
        assert!(on.lines()[1].contains("\"event\":\"replan\""));
        assert!(on.lines()[1].contains("\"trigger\":\"drift\""));
    }

    #[test]
    fn json_helpers() {
        assert_eq!(json_f64(1.5), "1.5");
        assert_eq!(json_f64(f64::NAN), "null");
        assert_eq!(json_f64(f64::INFINITY), "null");
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(json_escape("\n"), "\\u000a");
    }

    #[test]
    fn empty_trace_renders_empty() {
        let tr = TraceObserver::new();
        assert!(tr.is_empty());
        assert_eq!(tr.to_jsonl(), "");
    }

    #[test]
    fn meta_header_carries_run_config_and_stamps_request_lines() {
        use crate::config::schema::ConditionKind;
        use crate::coordinator::EngineConfig;
        use crate::workload::Arrival;

        let cfg = EngineConfig {
            seed: 17,
            duration_s: 1.2,
            condition_timeline: vec![(0.5, ConditionKind::High)],
            ..Default::default()
        };
        let meta = TraceMeta {
            cfg,
            streams: vec![("yolov2-tiny".to_string(), Arrival::Poisson { hz: 30.0 }, 0.25)],
        };
        assert_eq!(meta.regime_at(0.0), "moderate");
        assert_eq!(meta.regime_at(0.5), "high");

        let mut tr = TraceObserver::with_meta(meta);
        assert_eq!(tr.len(), 1, "header line present");
        let header = &tr.lines()[0];
        assert!(header.contains("\"event\":\"trace_header\""), "{header}");
        assert!(header.contains("\"seed\":17"));
        assert!(header.contains("\"model\":\"yolov2-tiny\""));
        assert!(header.contains("\"kind\":\"poisson\""));
        assert!(header.contains("\"at_s\":0.5"));

        // shed before the boundary: moderate regime stamped
        tr.on_event(&Event::Arrival {
            req: req(3, 0.1),
            admitted: false,
        });
        assert!(tr.lines()[1].contains("\"seed\":17"));
        assert!(tr.lines()[1].contains("\"regime\":\"moderate\""));

        // completed after the boundary: high regime stamped
        tr.on_event(&Event::Arrival {
            req: req(4, 0.9),
            admitted: true,
        });
        tr.on_request_done(
            &RequestOutcome {
                request: req(4, 0.9),
                start_s: 0.91,
                finish_s: 0.95,
                energy_j: 0.001,
            },
            true,
        );
        assert!(tr.lines()[2].contains("\"regime\":\"high\""));

        tr.push_report_row("row text");
        assert!(tr.lines()[3].contains("\"event\":\"report\""));
        assert!(tr.lines()[3].contains("\"row\":\"row text\""));
    }

    #[test]
    fn header_telemetry_field_is_conditional() {
        use crate::coordinator::EngineConfig;
        let plain = TraceMeta { cfg: EngineConfig::default(), streams: vec![] };
        assert!(!plain.header_line().contains("telemetry"));
        let cfg = EngineConfig { telemetry: true, ..Default::default() };
        let on = TraceMeta { cfg, streams: vec![] };
        assert!(on.header_line().ends_with(",\"telemetry\":true}"));
    }

    #[test]
    fn header_health_field_is_conditional() {
        use crate::coordinator::EngineConfig;
        use crate::metrics::health::HealthConfig;
        let plain = TraceMeta { cfg: EngineConfig::default(), streams: vec![] };
        assert!(!plain.header_line().contains("health"));
        let cfg = EngineConfig {
            telemetry: true,
            health: Some(HealthConfig::default()),
            ..Default::default()
        };
        let on = TraceMeta { cfg, streams: vec![] };
        let h = on.header_line();
        // health renders strictly after the telemetry marker
        assert!(h.contains(",\"telemetry\":true,\"health\":{"), "{h}");
        assert!(h.contains("\"slo_target\":0.01"), "{h}");
        assert!(h.contains("\"min_samples\":5"), "{h}");
        assert!(h.ends_with("}}"), "{h}");
    }

    #[test]
    fn alert_lines_render_rule_and_states() {
        use crate::metrics::health::{Alert, HealthState};
        let mut tr = TraceObserver::new();
        tr.on_event(&Event::Alert {
            alert: Alert {
                t_s: 1.25,
                rule: "slo_burn",
                stream: Some(1),
                prev: HealthState::Ok,
                state: HealthState::Critical,
                signal: 12.5,
                threshold: 4.0,
            },
        });
        tr.on_event(&Event::Alert {
            alert: Alert {
                t_s: 2.5,
                rule: "queue_depth",
                stream: None,
                prev: HealthState::Warn,
                state: HealthState::Ok,
                signal: 2.0,
                threshold: 6.4,
            },
        });
        assert_eq!(tr.len(), 2);
        let l = &tr.lines()[0];
        assert!(l.starts_with('{') && l.ends_with('}'), "{l}");
        assert!(l.contains("\"event\":\"alert\""));
        assert!(l.contains("\"rule\":\"slo_burn\""));
        assert!(l.contains("\"stream\":1"));
        assert!(l.contains("\"prev\":\"ok\""));
        assert!(l.contains("\"state\":\"critical\""));
        assert!(tr.lines()[1].contains("\"stream\":null"));
        assert!(tr.lines()[1].contains("\"state\":\"ok\""));
    }

    #[test]
    fn headerless_trace_format_is_unchanged() {
        let mut tr = TraceObserver::new();
        tr.on_event(&Event::Arrival {
            req: req(7, 1.25),
            admitted: false,
        });
        assert!(!tr.lines()[0].contains("\"seed\""));
        assert!(!tr.lines()[0].contains("\"regime\""));
    }
}
