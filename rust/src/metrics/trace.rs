//! Per-request JSONL timelines, recorded through the simulation kernel's
//! observer hooks.
//!
//! [`TraceObserver`] implements [`SimObserver`] and emits one JSON object
//! per request, in completion order (shed requests emit at admission):
//!
//! ```json
//! {"id":12,"stream":0,"arrival_s":0.8421,"deadline_s":0.9921,"shed":false,
//!  "start_s":0.8510,"finish_s":0.9402,"latency_s":0.0981,"queue_s":0.0089,
//!  "energy_j":0.0214,"met_deadline":true,
//!  "ops":[{"op":0,"start_s":0.8510,"latency_s":0.0041,"energy_j":0.0011,
//!          "placement":"gpu"}, ...]}
//! ```
//!
//! Shed requests carry `"shed":true` and omit the execution fields. When
//! dynamic batching is enabled, every batch close additionally emits a
//! standalone event line (interleaved with request lines in close order):
//!
//! ```json
//! {"event":"batch_close","stream":0,"op":0,"t_s":1.2345,"size":4,
//!  "wait_s":0.0031}
//! ```
//!
//! The CLI wires this behind `adaoper serve --trace <path>` (or the
//! `[serve] trace` config key); every line is standalone JSON, so the
//! file streams into `jq`/pandas without a wrapper.

use std::collections::HashMap;
use std::fmt::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::request::RequestOutcome;
use crate::sim::event::Event;
use crate::sim::observer::SimObserver;

/// One executed operator in a request's timeline.
#[derive(Debug, Clone)]
struct OpTrace {
    op: usize,
    start_s: f64,
    latency_s: f64,
    energy_j: f64,
    placement: String,
}

/// Accumulating state of an in-flight request.
#[derive(Debug, Clone)]
struct ReqTrace {
    stream: usize,
    arrival_s: f64,
    deadline_s: f64,
    ops: Vec<OpTrace>,
}

/// [`SimObserver`] that renders per-request JSONL timelines.
#[derive(Debug, Default)]
pub struct TraceObserver {
    pending: HashMap<usize, ReqTrace>,
    lines: Vec<String>,
}

/// JSON-safe float: finite values print via `Display`, everything else
/// becomes `null` (JSON has no NaN/Inf).
fn json_f64(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

/// Escape a string for a JSON literal (quotes, backslashes, control).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

impl TraceObserver {
    /// Empty trace.
    pub fn new() -> TraceObserver {
        TraceObserver::default()
    }

    /// Finished JSONL lines, in emission order.
    pub fn lines(&self) -> &[String] {
        &self.lines
    }

    /// Number of finished lines.
    pub fn len(&self) -> usize {
        self.lines.len()
    }

    /// Whether no lines were produced yet.
    pub fn is_empty(&self) -> bool {
        self.lines.is_empty()
    }

    /// The whole trace as one JSONL string (trailing newline included
    /// when non-empty).
    pub fn to_jsonl(&self) -> String {
        if self.lines.is_empty() {
            String::new()
        } else {
            let mut s = self.lines.join("\n");
            s.push('\n');
            s
        }
    }

    /// Write the trace to `path`.
    pub fn write_to(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_jsonl())
            .with_context(|| format!("writing trace to {}", path.display()))
    }
}

impl SimObserver for TraceObserver {
    fn on_event(&mut self, event: &Event) {
        match event {
            Event::Arrival { req, admitted } => {
                if *admitted {
                    self.pending.insert(
                        req.id,
                        ReqTrace {
                            stream: req.stream,
                            arrival_s: req.arrival_s,
                            deadline_s: req.deadline_s,
                            ops: Vec::new(),
                        },
                    );
                } else {
                    self.lines.push(format!(
                        "{{\"id\":{},\"stream\":{},\"arrival_s\":{},\
                         \"deadline_s\":{},\"shed\":true}}",
                        req.id,
                        req.stream,
                        json_f64(req.arrival_s),
                        json_f64(req.deadline_s),
                    ));
                }
            }
            Event::OpDispatch {
                request,
                op,
                start_s,
                placement,
                ..
            } => {
                if let Some(t) = self.pending.get_mut(request) {
                    t.ops.push(OpTrace {
                        op: *op,
                        start_s: *start_s,
                        latency_s: 0.0,
                        energy_j: 0.0,
                        placement: placement.to_string(),
                    });
                }
            }
            Event::OpComplete {
                request,
                latency_s,
                energy_j,
                ..
            } => {
                if let Some(t) = self.pending.get_mut(request) {
                    if let Some(last) = t.ops.last_mut() {
                        last.latency_s = *latency_s;
                        last.energy_j = *energy_j;
                    }
                }
            }
            Event::BatchClose {
                stream,
                op,
                t_s,
                size,
                wait_s,
            } => {
                self.lines.push(format!(
                    "{{\"event\":\"batch_close\",\"stream\":{},\"op\":{},\"t_s\":{},\
                     \"size\":{},\"wait_s\":{}}}",
                    stream,
                    op,
                    json_f64(*t_s),
                    size,
                    json_f64(*wait_s),
                ));
            }
            Event::MonitorTick { .. } | Event::RegimeReplan { .. } => {}
        }
    }

    fn on_request_done(&mut self, outcome: &RequestOutcome, met_deadline: bool) {
        let id = outcome.request.id;
        let t = self.pending.remove(&id).unwrap_or(ReqTrace {
            stream: outcome.request.stream,
            arrival_s: outcome.request.arrival_s,
            deadline_s: outcome.request.deadline_s,
            ops: Vec::new(),
        });
        let mut ops = String::new();
        for (i, o) in t.ops.iter().enumerate() {
            if i > 0 {
                ops.push(',');
            }
            let _ = write!(
                ops,
                "{{\"op\":{},\"start_s\":{},\"latency_s\":{},\"energy_j\":{},\
                 \"placement\":\"{}\"}}",
                o.op,
                json_f64(o.start_s),
                json_f64(o.latency_s),
                json_f64(o.energy_j),
                json_escape(&o.placement),
            );
        }
        self.lines.push(format!(
            "{{\"id\":{},\"stream\":{},\"arrival_s\":{},\"deadline_s\":{},\"shed\":false,\
             \"start_s\":{},\"finish_s\":{},\"latency_s\":{},\"queue_s\":{},\"energy_j\":{},\
             \"met_deadline\":{},\"ops\":[{}]}}",
            id,
            t.stream,
            json_f64(t.arrival_s),
            json_f64(t.deadline_s),
            json_f64(outcome.start_s),
            json_f64(outcome.finish_s),
            json_f64(outcome.latency_s()),
            json_f64(outcome.queue_s()),
            json_f64(outcome.energy_j),
            met_deadline,
            ops,
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Request;
    use crate::soc::Placement;

    fn req(id: usize, arrival: f64) -> Request {
        Request {
            id,
            stream: 0,
            arrival_s: arrival,
            deadline_s: arrival + 0.5,
        }
    }

    #[test]
    fn records_one_line_per_request_in_completion_order() {
        let mut tr = TraceObserver::new();
        tr.on_event(&Event::Arrival {
            req: req(0, 0.0),
            admitted: true,
        });
        tr.on_event(&Event::OpDispatch {
            request: 0,
            stream: 0,
            op: 0,
            start_s: 0.01,
            placement: Placement::GPU,
        });
        tr.on_event(&Event::OpComplete {
            request: 0,
            stream: 0,
            op: 0,
            end_s: 0.02,
            latency_s: 0.01,
            energy_j: 0.001,
        });
        tr.on_request_done(
            &RequestOutcome {
                request: req(0, 0.0),
                start_s: 0.01,
                finish_s: 0.02,
                energy_j: 0.001,
            },
            true,
        );
        assert_eq!(tr.len(), 1);
        let line = &tr.lines()[0];
        assert!(line.starts_with('{') && line.ends_with('}'), "{line}");
        assert!(line.contains("\"id\":0"));
        assert!(line.contains("\"shed\":false"));
        assert!(line.contains("\"met_deadline\":true"));
        assert!(line.contains("\"ops\":[{"));
        assert!(line.contains("\"placement\":\""));
        assert!(tr.to_jsonl().ends_with('\n'));
    }

    #[test]
    fn shed_requests_emit_immediately() {
        let mut tr = TraceObserver::new();
        tr.on_event(&Event::Arrival {
            req: req(7, 1.25),
            admitted: false,
        });
        assert_eq!(tr.len(), 1);
        assert!(tr.lines()[0].contains("\"shed\":true"));
        assert!(tr.lines()[0].contains("\"id\":7"));
    }

    #[test]
    fn batch_close_emits_standalone_event_line() {
        let mut tr = TraceObserver::new();
        tr.on_event(&Event::BatchClose {
            stream: 1,
            op: 0,
            t_s: 2.5,
            size: 4,
            wait_s: 0.003,
        });
        assert_eq!(tr.len(), 1);
        let line = &tr.lines()[0];
        assert!(line.starts_with('{') && line.ends_with('}'), "{line}");
        assert!(line.contains("\"event\":\"batch_close\""));
        assert!(line.contains("\"size\":4"));
        assert!(line.contains("\"wait_s\":0.003"));
    }

    #[test]
    fn json_helpers() {
        assert_eq!(json_f64(1.5), "1.5");
        assert_eq!(json_f64(f64::NAN), "null");
        assert_eq!(json_f64(f64::INFINITY), "null");
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(json_escape("\n"), "\\u000a");
    }

    #[test]
    fn empty_trace_renders_empty() {
        let tr = TraceObserver::new();
        assert!(tr.is_empty());
        assert_eq!(tr.to_jsonl(), "");
    }
}
