//! Deterministic sliding-window statistics over virtual time.
//!
//! The health layer ([`crate::metrics::health`]) needs "what happened in
//! the last N seconds" views of the serving stream — miss rates, energy
//! per request, profiler residuals — evaluated *inside* the simulation
//! at monitor ticks. These primitives provide that as time-bucketed
//! rings keyed by the **absolute bucket index** `floor(t / bucket_s)`:
//!
//! * [`WindowCounter`] — a ring of `u64` counters (windowed counts and
//!   rates, exact under merge);
//! * [`WindowStat`] — a paired count/sum ring (windowed means);
//! * [`WindowHistogram`] — a ring of [`LogHistogram`] slots (windowed
//!   quantiles via the mergeable log-bucket sketch).
//!
//! Determinism contract (same as the rest of the metrics layer):
//!
//! * all state advances on *virtual* time handed in by the caller —
//!   nothing here reads a clock;
//! * advancing to bucket `i` zeroes every slot between the old head and
//!   `i`, so a window's contents depend only on the recorded events,
//!   never on how often it was polled;
//! * [`merge`](WindowCounter::merge) aligns two rings on their absolute
//!   bucket indices and adds slot-wise. Counter merges are exact and
//!   associative; float sums are merged in caller order, so shard-order
//!   merging (device order in the fleet runner) gives bit-identical
//!   results for any thread count.
//!
//! Events may arrive slightly out of order (the kernel delivers in
//! causal, not time-sorted, order): a record older than the window is
//! dropped, one inside the window lands in its own bucket.

use crate::metrics::histogram::LogHistogram;

/// Shared ring bookkeeping: bucket width, head index, primed flag.
#[derive(Debug, Clone, PartialEq)]
struct Ring {
    bucket_s: f64,
    /// Absolute bucket index of the newest slot (valid once `primed`).
    head: u64,
    primed: bool,
}

impl Ring {
    fn new(window_s: f64, buckets: usize) -> Ring {
        assert!(
            window_s.is_finite() && window_s > 0.0,
            "window_s must be positive"
        );
        assert!(buckets > 0, "need at least one bucket");
        Ring {
            bucket_s: window_s / buckets as f64,
            head: 0,
            primed: false,
        }
    }

    fn index(&self, t_s: f64) -> u64 {
        let t = if t_s.is_finite() && t_s > 0.0 { t_s } else { 0.0 };
        (t / self.bucket_s) as u64
    }

    /// Advance the head to `idx`, returning the range of slot positions
    /// (ring offsets) that must be reset. Returns `None` when nothing
    /// needs clearing.
    fn advance(&mut self, idx: u64, n: u64) -> AdvanceClear {
        if !self.primed {
            self.primed = true;
            self.head = idx;
            return AdvanceClear::None;
        }
        if idx <= self.head {
            return AdvanceClear::None;
        }
        let clear = if idx - self.head >= n {
            AdvanceClear::All
        } else {
            AdvanceClear::Span(self.head + 1, idx)
        };
        self.head = idx;
        clear
    }

    fn compatible(&self, other: &Ring, n: usize, n_other: usize) -> bool {
        self.bucket_s == other.bucket_s && n == n_other
    }
}

/// What [`Ring::advance`] asks the owner to reset.
enum AdvanceClear {
    None,
    /// Every slot.
    All,
    /// Absolute bucket indices `lo..=hi`.
    Span(u64, u64),
}

/// Time-bucketed ring of `u64` counters over a fixed look-back window.
#[derive(Debug, Clone, PartialEq)]
pub struct WindowCounter {
    ring: Ring,
    window_s: f64,
    slots: Vec<u64>,
}

impl WindowCounter {
    /// Ring covering the trailing `window_s` seconds with `buckets`
    /// equal slots. Panics unless `window_s > 0` and `buckets > 0`.
    pub fn new(window_s: f64, buckets: usize) -> WindowCounter {
        WindowCounter {
            ring: Ring::new(window_s, buckets),
            window_s,
            slots: vec![0; buckets],
        }
    }

    /// The configured look-back span in seconds.
    pub fn window_s(&self) -> f64 {
        self.window_s
    }

    fn apply(&mut self, clear: AdvanceClear) {
        let n = self.slots.len() as u64;
        match clear {
            AdvanceClear::None => {}
            AdvanceClear::All => self.slots.iter_mut().for_each(|s| *s = 0),
            AdvanceClear::Span(lo, hi) => {
                for i in lo..=hi {
                    self.slots[(i % n) as usize] = 0;
                }
            }
        }
    }

    /// Move the window forward to virtual time `t_s` (no-op when `t_s`
    /// is not ahead of the head bucket).
    pub fn advance(&mut self, t_s: f64) {
        let idx = self.ring.index(t_s);
        let n = self.slots.len() as u64;
        let clear = self.ring.advance(idx, n);
        self.apply(clear);
    }

    /// Count `n` events at virtual time `t_s`. Events older than the
    /// window (after any forward motion already seen) are dropped.
    pub fn record(&mut self, t_s: f64, n: u64) {
        self.advance(t_s);
        let idx = self.ring.index(t_s);
        let len = self.slots.len() as u64;
        if self.ring.head - idx < len {
            self.slots[(idx % len) as usize] += n;
        }
    }

    /// Total count inside the window as of `t_s`.
    pub fn total(&mut self, t_s: f64) -> u64 {
        self.advance(t_s);
        self.slots.iter().sum()
    }

    /// Windowed event rate in Hz as of `t_s`.
    pub fn rate_hz(&mut self, t_s: f64) -> f64 {
        self.total(t_s) as f64 / self.window_s
    }

    /// Fold `other` into `self` (slot-wise addition aligned on absolute
    /// bucket indices; the head advances to the later of the two).
    /// Exact and associative. Panics on shape mismatch.
    pub fn merge(&mut self, other: &WindowCounter) {
        assert!(
            self.ring
                .compatible(&other.ring, self.slots.len(), other.slots.len()),
            "merging incompatible windows"
        );
        if !other.ring.primed {
            return;
        }
        if !self.ring.primed {
            *self = other.clone();
            return;
        }
        let n = self.slots.len() as u64;
        let head = self.ring.head.max(other.ring.head);
        let clear = self.ring.advance(head, n);
        self.apply(clear);
        for k in 0..n {
            if k > other.ring.head {
                break;
            }
            let idx = other.ring.head - k;
            if head - idx < n {
                self.slots[(idx % n) as usize] += other.slots[(idx % n) as usize];
            }
        }
    }
}

/// Paired count/sum ring: windowed means of a float-valued series.
#[derive(Debug, Clone, PartialEq)]
pub struct WindowStat {
    counts: WindowCounter,
    sums: Vec<f64>,
}

impl WindowStat {
    /// Stat window covering the trailing `window_s` seconds with
    /// `buckets` equal slots.
    pub fn new(window_s: f64, buckets: usize) -> WindowStat {
        WindowStat {
            counts: WindowCounter::new(window_s, buckets),
            sums: vec![0.0; buckets],
        }
    }

    fn advance(&mut self, t_s: f64) {
        let idx = self.counts.ring.index(t_s);
        let n = self.sums.len() as u64;
        match self.counts.ring.advance(idx, n) {
            AdvanceClear::None => {}
            AdvanceClear::All => {
                self.counts.slots.iter_mut().for_each(|s| *s = 0);
                self.sums.iter_mut().for_each(|s| *s = 0.0);
            }
            AdvanceClear::Span(lo, hi) => {
                for i in lo..=hi {
                    self.counts.slots[(i % n) as usize] = 0;
                    self.sums[(i % n) as usize] = 0.0;
                }
            }
        }
    }

    /// Record one sample `x` at virtual time `t_s` (dropped when older
    /// than the window).
    pub fn record(&mut self, t_s: f64, x: f64) {
        self.advance(t_s);
        let idx = self.counts.ring.index(t_s);
        let n = self.sums.len() as u64;
        if self.counts.ring.head - idx < n {
            self.counts.slots[(idx % n) as usize] += 1;
            self.sums[(idx % n) as usize] += x;
        }
    }

    /// Number of in-window samples as of `t_s`.
    pub fn count(&mut self, t_s: f64) -> u64 {
        self.advance(t_s);
        self.counts.slots.iter().sum()
    }

    /// Sum of in-window samples as of `t_s`.
    pub fn sum(&mut self, t_s: f64) -> f64 {
        self.advance(t_s);
        self.sums.iter().sum()
    }

    /// Mean of in-window samples as of `t_s`; `None` when empty.
    pub fn mean(&mut self, t_s: f64) -> Option<f64> {
        let n = self.count(t_s);
        if n == 0 {
            None
        } else {
            Some(self.sums.iter().sum::<f64>() / n as f64)
        }
    }

    /// Fold `other` into `self` (counts exactly, sums in caller order —
    /// merge shards in a fixed order for bit-identical results).
    pub fn merge(&mut self, other: &WindowStat) {
        assert!(
            self.counts
                .ring
                .compatible(&other.counts.ring, self.sums.len(), other.sums.len()),
            "merging incompatible windows"
        );
        if !other.counts.ring.primed {
            return;
        }
        if !self.counts.ring.primed {
            *self = other.clone();
            return;
        }
        let n = self.sums.len() as u64;
        let head = self.counts.ring.head.max(other.counts.ring.head);
        match self.counts.ring.advance(head, n) {
            AdvanceClear::None => {}
            AdvanceClear::All => {
                self.counts.slots.iter_mut().for_each(|s| *s = 0);
                self.sums.iter_mut().for_each(|s| *s = 0.0);
            }
            AdvanceClear::Span(lo, hi) => {
                for i in lo..=hi {
                    self.counts.slots[(i % n) as usize] = 0;
                    self.sums[(i % n) as usize] = 0.0;
                }
            }
        }
        for k in 0..n {
            if k > other.counts.ring.head {
                break;
            }
            let idx = other.counts.ring.head - k;
            if head - idx < n {
                let p = (idx % n) as usize;
                self.counts.slots[p] += other.counts.slots[p];
                self.sums[p] += other.sums[p];
            }
        }
    }
}

/// Ring of [`LogHistogram`] slots: windowed quantiles with the same
/// mergeable log-bucket sketch the fleet layer uses.
#[derive(Debug, Clone)]
pub struct WindowHistogram {
    ring: Ring,
    lo: f64,
    hi: f64,
    growth: f64,
    slots: Vec<LogHistogram>,
}

impl WindowHistogram {
    /// Windowed histogram over the trailing `window_s` seconds, each
    /// slot a `LogHistogram::new(lo, hi, growth)`.
    pub fn new(window_s: f64, buckets: usize, lo: f64, hi: f64, growth: f64) -> WindowHistogram {
        WindowHistogram {
            ring: Ring::new(window_s, buckets),
            lo,
            hi,
            growth,
            slots: (0..buckets).map(|_| LogHistogram::new(lo, hi, growth)).collect(),
        }
    }

    /// Windowed latency histogram with the standard serving shape.
    pub fn latency(window_s: f64, buckets: usize) -> WindowHistogram {
        WindowHistogram::new(window_s, buckets, 1e-6, 1e4, 1.05)
    }

    fn fresh(&self) -> LogHistogram {
        LogHistogram::new(self.lo, self.hi, self.growth)
    }

    fn advance(&mut self, t_s: f64) {
        let idx = self.ring.index(t_s);
        let n = self.slots.len() as u64;
        match self.ring.advance(idx, n) {
            AdvanceClear::None => {}
            AdvanceClear::All => {
                let blank = self.fresh();
                self.slots.iter_mut().for_each(|s| *s = blank.clone());
            }
            AdvanceClear::Span(lo, hi) => {
                for i in lo..=hi {
                    let blank = self.fresh();
                    self.slots[(i % n) as usize] = blank;
                }
            }
        }
    }

    /// Record one sample at virtual time `t_s` (dropped when older than
    /// the window).
    pub fn record(&mut self, t_s: f64, x: f64) {
        self.advance(t_s);
        let idx = self.ring.index(t_s);
        let n = self.slots.len() as u64;
        if self.ring.head - idx < n {
            self.slots[(idx % n) as usize].record(x);
        }
    }

    /// Merge of every in-window slot as of `t_s` — quantiles/means read
    /// off the returned sketch.
    pub fn snapshot(&mut self, t_s: f64) -> LogHistogram {
        self.advance(t_s);
        let mut out = self.fresh();
        for s in &self.slots {
            out.merge(s);
        }
        out
    }

    /// Fold `other` into `self`, slot-wise, aligned on absolute bucket
    /// indices. Panics on shape mismatch.
    pub fn merge(&mut self, other: &WindowHistogram) {
        assert!(
            self.ring
                .compatible(&other.ring, self.slots.len(), other.slots.len()),
            "merging incompatible windows"
        );
        if !other.ring.primed {
            return;
        }
        if !self.ring.primed {
            *self = other.clone();
            return;
        }
        let n = self.slots.len() as u64;
        let head = self.ring.head.max(other.ring.head);
        match self.ring.advance(head, n) {
            AdvanceClear::None => {}
            AdvanceClear::All => {
                let blank = self.fresh();
                self.slots.iter_mut().for_each(|s| *s = blank.clone());
            }
            AdvanceClear::Span(lo, hi) => {
                for i in lo..=hi {
                    let blank = self.fresh();
                    self.slots[(i % n) as usize] = blank;
                }
            }
        }
        for k in 0..n {
            if k > other.ring.head {
                break;
            }
            let idx = other.ring.head - k;
            if head - idx < n {
                let p = (idx % n) as usize;
                self.slots[p].merge(&other.slots[p]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny deterministic LCG for the property suites (no external rng
    /// deps, stable across hosts).
    struct Lcg(u64);
    impl Lcg {
        fn next_u64(&mut self) -> u64 {
            self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            self.0
        }
        fn f64_01(&mut self) -> f64 {
            (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
        }
    }

    #[test]
    fn counts_inside_window_only() {
        let mut w = WindowCounter::new(1.0, 4); // bucket_s = 0.25
        w.record(0.1, 1);
        w.record(0.3, 1);
        w.record(0.9, 1);
        assert_eq!(w.total(0.9), 3);
        // advance past the first two buckets: only t=0.9 survives
        assert_eq!(w.total(1.6), 1);
        // advance far past everything
        assert_eq!(w.total(10.0), 0);
    }

    #[test]
    fn polling_frequency_does_not_change_contents() {
        let mut a = WindowCounter::new(2.0, 8);
        let mut b = WindowCounter::new(2.0, 8);
        for (t, n) in [(0.2, 3u64), (0.9, 1), (1.7, 2), (2.4, 5)] {
            a.record(t, n);
            b.record(t, n);
            // poll `b` obsessively between records
            for k in 0..10 {
                b.advance(t + k as f64 * 0.01);
            }
        }
        assert_eq!(a.total(2.5), b.total(2.5));
    }

    #[test]
    fn late_events_in_window_land_old_events_drop() {
        let mut w = WindowCounter::new(1.0, 4);
        w.record(2.0, 1);
        w.record(1.9, 1); // slightly late but inside window: kept
        assert_eq!(w.total(2.0), 2);
        w.record(0.1, 7); // far older than the window: dropped
        assert_eq!(w.total(2.0), 2);
    }

    #[test]
    fn rate_is_total_over_span() {
        let mut w = WindowCounter::new(2.0, 4);
        w.record(0.1, 4);
        w.record(0.9, 4);
        assert!((w.rate_hz(1.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn counter_matches_bruteforce_over_random_streams() {
        let mut rng = Lcg(0xADA0_9E17);
        for _case in 0..50 {
            let buckets = 2 + (rng.next_u64() % 14) as usize;
            let window_s = 0.5 + rng.f64_01() * 4.0;
            let mut w = WindowCounter::new(window_s, buckets);
            let bucket_s = window_s / buckets as f64;
            let mut events: Vec<(f64, u64)> = Vec::new();
            let mut t = 0.0;
            for _ in 0..200 {
                t += rng.f64_01() * 0.3;
                let n = rng.next_u64() % 4;
                events.push((t, n));
                w.record(t, n);
            }
            let head = (t / bucket_s) as u64;
            let brute: u64 = events
                .iter()
                .filter(|(et, _)| {
                    let idx = (*et / bucket_s) as u64;
                    head - idx < buckets as u64
                })
                .map(|(_, n)| *n)
                .sum();
            assert_eq!(w.total(t), brute, "window vs brute force diverged");
        }
    }

    #[test]
    fn stat_mean_matches_bruteforce() {
        let mut rng = Lcg(42);
        for _case in 0..30 {
            let buckets = 2 + (rng.next_u64() % 10) as usize;
            let window_s = 1.0 + rng.f64_01() * 3.0;
            let bucket_s = window_s / buckets as f64;
            let mut w = WindowStat::new(window_s, buckets);
            let mut events: Vec<(f64, f64)> = Vec::new();
            let mut t = 0.0;
            for _ in 0..150 {
                t += rng.f64_01() * 0.2;
                let x = rng.f64_01() * 10.0;
                events.push((t, x));
                w.record(t, x);
            }
            let head = (t / bucket_s) as u64;
            let inside: Vec<f64> = events
                .iter()
                .filter(|(et, _)| head - (*et / bucket_s) as u64 < buckets as u64)
                .map(|(_, x)| *x)
                .collect();
            assert_eq!(w.count(t), inside.len() as u64);
            let brute = inside.iter().sum::<f64>() / inside.len() as f64;
            let got = w.mean(t).expect("non-empty window");
            assert!((got - brute).abs() < 1e-9, "mean {got} vs brute {brute}");
        }
    }

    #[test]
    fn counter_merge_is_associative_and_matches_union() {
        let mut rng = Lcg(7);
        for _case in 0..40 {
            let buckets = 3 + (rng.next_u64() % 8) as usize;
            let window_s = 1.0 + rng.f64_01() * 2.0;
            let mut shards: Vec<WindowCounter> = Vec::new();
            let mut union = WindowCounter::new(window_s, buckets);
            let mut t_max: f64 = 0.0;
            for _ in 0..3 {
                let mut w = WindowCounter::new(window_s, buckets);
                let mut t = rng.f64_01();
                for _ in 0..60 {
                    t += rng.f64_01() * 0.15;
                    let n = rng.next_u64() % 3;
                    w.record(t, n);
                    union.record(t, n);
                }
                t_max = t_max.max(t);
                shards.push(w);
            }
            // ((a ⊕ b) ⊕ c)
            let mut left = shards[0].clone();
            left.merge(&shards[1]);
            left.merge(&shards[2]);
            // (a ⊕ (b ⊕ c))
            let mut bc = shards[1].clone();
            bc.merge(&shards[2]);
            let mut right = shards[0].clone();
            right.merge(&bc);
            assert_eq!(left, right, "merge not associative");
            // the merged ring sees the union of all shards' events that
            // are still inside the latest head's window
            assert_eq!(left.total(t_max), union.total(t_max));
        }
    }

    #[test]
    fn merge_with_unprimed_sides() {
        let empty = WindowCounter::new(1.0, 4);
        let mut w = WindowCounter::new(1.0, 4);
        w.record(0.5, 2);
        let mut a = w.clone();
        a.merge(&empty);
        assert_eq!(a.total(0.5), 2);
        let mut b = empty.clone();
        b.merge(&w);
        assert_eq!(b.total(0.5), 2);
    }

    #[test]
    fn histogram_snapshot_windows_out_old_samples() {
        let mut w = WindowHistogram::latency(1.0, 4);
        w.record(0.1, 0.010);
        w.record(0.9, 0.020);
        assert_eq!(w.snapshot(0.9).count(), 2);
        let snap = w.snapshot(1.6); // first bucket rolled out
        assert_eq!(snap.count(), 1);
        let m = snap.mean().expect("one sample");
        assert!((m - 0.020).abs() < 0.002, "mean {m}");
    }

    #[test]
    fn histogram_merge_counts_union() {
        let mut a = WindowHistogram::latency(1.0, 4);
        let mut b = WindowHistogram::latency(1.0, 4);
        a.record(0.2, 0.010);
        b.record(0.3, 0.030);
        b.record(0.8, 0.050);
        a.merge(&b);
        assert_eq!(a.snapshot(0.8).count(), 3);
    }

    #[test]
    #[should_panic(expected = "incompatible")]
    fn merge_shape_mismatch_panics() {
        let mut a = WindowCounter::new(1.0, 4);
        let b = WindowCounter::new(1.0, 8);
        a.merge(&b);
    }
}
