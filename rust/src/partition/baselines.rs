//! Simple comparator policies: MACE-on-GPU, all-CPU, greedy-energy and
//! random (test fodder).

use anyhow::Result;

use crate::graph::ModelGraph;
use crate::profiler::CostModel;
use crate::soc::device::Snapshot;
use crate::soc::Placement;
use crate::util::Prng;

use super::plan::{evaluate, CtxWalker, Partitioner, Plan};

/// MACE's GPU runtime: every operator on the GPU (the paper's first
/// comparator, "MACE on GPU").
#[derive(Debug, Clone, Default)]
pub struct MaceGpuPartitioner;

impl Partitioner for MaceGpuPartitioner {
    fn name(&self) -> &str {
        "mace-gpu"
    }

    fn partition(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
    ) -> Result<Plan> {
        let placements = vec![Placement::GPU; g.num_ops()];
        let predicted = evaluate(g, &placements, model, snap);
        Ok(Plan {
            placements,
            predicted,
            policy: "mace-gpu".into(),
        })
    }
}

/// Everything on the CPU cluster (TFLite-CPU-style floor baseline).
#[derive(Debug, Clone, Default)]
pub struct AllCpuPartitioner;

impl Partitioner for AllCpuPartitioner {
    fn name(&self) -> &str {
        "all-cpu"
    }

    fn partition(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
    ) -> Result<Plan> {
        let placements = vec![Placement::CPU; g.num_ops()];
        let predicted = evaluate(g, &placements, model, snap);
        Ok(Plan {
            placements,
            predicted,
            policy: "all-cpu".into(),
        })
    }
}

/// Greedy per-op energy minimizer (ablation baseline): walks the graph
/// front to back, picking the placement with the lowest *marginal* energy
/// given choices already made. No lookahead — the gap to the DP is
/// exactly what the DP's transfer-aware planning buys.
#[derive(Debug, Clone)]
pub struct GreedyEnergyPartitioner {
    /// Candidate placements considered per op.
    pub choices: Vec<Placement>,
}

impl Default for GreedyEnergyPartitioner {
    fn default() -> Self {
        GreedyEnergyPartitioner {
            choices: vec![
                Placement::CPU,
                Placement::GPU,
                Placement::Split { cpu_frac: 0.15 },
                Placement::Split { cpu_frac: 0.25 },
            ],
        }
    }
}

impl Partitioner for GreedyEnergyPartitioner {
    fn name(&self) -> &str {
        "greedy-energy"
    }

    fn partition(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
    ) -> Result<Plan> {
        let mut placements = Vec::with_capacity(g.num_ops());
        // walker clones per candidate would desync; instead rebuild the
        // walker prefix each step (n² but n ≤ ~70)
        for i in 0..g.num_ops() {
            let mut best: Option<(Placement, f64)> = None;
            for &cand in &self.choices {
                let mut w = CtxWalker::new(g);
                for (j, &p) in placements.iter().enumerate() {
                    let _ = w.step(j, p);
                }
                let ctx = w.step(i, cand);
                let c = model.predict(&g.ops[i], cand, &ctx, snap);
                if best.as_ref().map_or(true, |&(_, e)| c.energy_j < e) {
                    best = Some((cand, c.energy_j));
                }
            }
            let (p, _) = best.unwrap();
            placements.push(p);
        }
        // final pass for the aggregate prediction
        let predicted = evaluate(g, &placements, model, snap);
        Ok(Plan {
            placements,
            predicted,
            policy: "greedy-energy".into(),
        })
    }
}

/// Uniformly random placements (property-test fodder; any real policy must
/// beat it).
#[derive(Debug, Clone)]
pub struct RandomPartitioner {
    /// Seed for the placement draw.
    pub seed: u64,
    /// Candidate placements drawn from.
    pub choices: Vec<Placement>,
}

impl RandomPartitioner {
    /// Build with the default candidate set.
    pub fn new(seed: u64) -> Self {
        RandomPartitioner {
            seed,
            choices: vec![
                Placement::CPU,
                Placement::GPU,
                Placement::Split { cpu_frac: 0.2 },
                Placement::Split { cpu_frac: 0.4 },
            ],
        }
    }
}

impl Partitioner for RandomPartitioner {
    fn name(&self) -> &str {
        "random"
    }

    fn partition(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
    ) -> Result<Plan> {
        let mut rng = Prng::new(self.seed);
        let placements: Vec<Placement> =
            (0..g.num_ops()).map(|_| *rng.choose(&self.choices)).collect();
        let predicted = evaluate(g, &placements, model, snap);
        Ok(Plan {
            placements,
            predicted,
            policy: "random".into(),
        })
    }
}

/// Instantiate a policy by config name.
pub fn by_policy(
    kind: crate::config::schema::PolicyKind,
    objective: super::plan::Objective,
) -> Box<dyn Partitioner + Send + Sync> {
    use crate::config::schema::PolicyKind;
    match kind {
        PolicyKind::AdaOper => Box::new(super::dp::DpPartitioner::new(objective)),
        PolicyKind::Codl => Box::new(super::codl::CodlPartitioner::default()),
        PolicyKind::MaceGpu => Box::new(MaceGpuPartitioner),
        PolicyKind::AllCpu => Box::new(AllCpuPartitioner),
        PolicyKind::GreedyEnergy => Box::new(GreedyEnergyPartitioner::default()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::soc::device::{Device, DeviceConfig};
    use crate::workload::WorkloadCondition;

    fn frozen() -> Device {
        let mut d = Device::new(DeviceConfig {
            noise_sigma: 0.0,
            drift_sigma: 0.0,
            ..DeviceConfig::snapdragon_855()
        });
        let mut c = WorkloadCondition::moderate().spec;
        c.cpu_bg_sigma = 0.0;
        c.cpu_burst = 0.0;
        c.gpu_bg_sigma = 0.0;
        c.gpu_burst = 0.0;
        c.drift_sigma = 0.0;
        d.apply_condition(&c);
        d
    }

    #[test]
    fn mace_gpu_is_uniform() {
        let g = zoo::yolov2_tiny();
        let d = frozen();
        let p = MaceGpuPartitioner.partition(&g, &d, &d.snapshot()).unwrap();
        assert!(p.placements.iter().all(|&x| x == Placement::GPU));
        assert!(p.predicted.latency_s > 0.0);
    }

    #[test]
    fn greedy_energy_not_worse_than_worst_uniform() {
        let g = zoo::yolov2_tiny();
        let d = frozen();
        let snap = d.snapshot();
        let greedy = GreedyEnergyPartitioner::default()
            .partition(&g, &d, &snap)
            .unwrap();
        let cpu = AllCpuPartitioner.partition(&g, &d, &snap).unwrap();
        assert!(greedy.predicted.energy_j <= cpu.predicted.energy_j);
    }

    #[test]
    fn random_deterministic_per_seed() {
        let g = zoo::yolov2_tiny();
        let d = frozen();
        let snap = d.snapshot();
        let a = RandomPartitioner::new(5).partition(&g, &d, &snap).unwrap();
        let b = RandomPartitioner::new(5).partition(&g, &d, &snap).unwrap();
        assert_eq!(a.placements, b.placements);
    }

    #[test]
    fn by_policy_builds_all() {
        use crate::config::schema::PolicyKind;
        for k in PolicyKind::all() {
            let p = by_policy(k, super::super::plan::Objective::MinEdp);
            assert!(!p.name().is_empty());
        }
    }
}
