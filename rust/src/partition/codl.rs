//! CoDL baseline (Jia et al., MobiSys '22) — latency-optimal CPU+GPU
//! co-execution, reimplemented from the published policy:
//!
//! 1. **Per-operator intra-op splitting**: each operator's work is divided
//!    between CPU and GPU along output channels/rows; the ratio balances
//!    the two units' *predicted* latencies.
//! 2. **Latency predictors**: analytical per-unit models calibrated
//!    offline — frequency-aware (CoDL reads the current OPP), but blind to
//!    instantaneous background bursts, cache-thrash nonlinearity, and
//!    thermal/contention drift (those need the runtime feedback loop that
//!    is AdaOper's contribution). The observable *smoothed* utilization is
//!    granted to the baseline (a generous reading of their design).
//! 3. **Co-execution-aware thresholds**: ops where co-execution gains less
//!    than `min_gain` over the best single unit (sync + transfer overhead
//!    dominating, e.g. depthwise convs, tiny head ops) run on the faster
//!    single unit instead — CoDL's "operator chain" fallback.
//!
//! Energy never enters the decision — that obliviousness under loaded
//! conditions is precisely what Figure 2 measures.

use anyhow::Result;

use crate::graph::{ModelGraph, OpNode};
use crate::profiler::CostModel;
use crate::soc::device::{ExecCtx, OpCost, Snapshot};
use crate::soc::latency::{compute_time, ComputeParams, UnitCondition};
use crate::soc::transfer::{boundary_bytes, TransferParams};
use crate::soc::{Placement, Proc};

use super::plan::{Partitioner, Plan, PlanCost, INPUT_CPU_FRAC};

/// CoDL's offline-calibrated analytical latency model.
#[derive(Debug, Clone)]
pub struct CodlLatencyModel {
    cpu: ComputeParams,
    gpu: ComputeParams,
    transfer: TransferParams,
    split_sync_s: f64,
}

impl Default for CodlLatencyModel {
    fn default() -> Self {
        CodlLatencyModel {
            cpu: ComputeParams::sd855_cpu(),
            gpu: ComputeParams::sd855_gpu(),
            transfer: TransferParams::sd855(),
            split_sync_s: 30e-6,
        }
    }
}

impl CodlLatencyModel {
    fn unit_condition(&self, p: Proc, snap: &Snapshot) -> UnitCondition {
        // Frequency + smoothed utilization from the snapshot; no burst,
        // no thrash correction, no drift — the baseline's blind spots.
        let (freq, util) = match p {
            Proc::Cpu => (snap.cpu_freq_hz, snap.cpu_util),
            Proc::Gpu => (snap.gpu_freq_hz, snap.gpu_util),
        };
        UnitCondition {
            freq_hz: freq,
            bg_util: util,
            bw_factor: snap.bw_factor,
        }
    }

    /// Predicted latency of `frac` of `op` on unit `p`.
    pub fn unit_latency(&self, op: &OpNode, p: Proc, frac: f64, snap: &Snapshot) -> f64 {
        let params = match p {
            Proc::Cpu => &self.cpu,
            Proc::Gpu => &self.gpu,
        };
        compute_time(op, p, params, self.unit_condition(p, snap), frac)
    }

    /// Predicted op latency under a placement (transfer from `ctx`,
    /// dispatch at run boundaries — same structure the evaluator uses).
    pub fn placement_latency(
        &self,
        op: &OpNode,
        placement: Placement,
        ctx: &ExecCtx,
        snap: &Snapshot,
    ) -> f64 {
        let need_cpu = placement.frac_on(Proc::Cpu);
        let mut t = 0.0;
        for (shape, &have) in op.in_shapes.iter().zip(&ctx.input_cpu_fracs) {
            t += self.transfer.time(boundary_bytes(shape.bytes(), have, need_cpu));
        }
        let mut busy: f64 = 0.0;
        for p in Proc::ALL {
            let frac = placement.frac_on(p);
            if frac == 0.0 {
                continue;
            }
            let params = match p {
                Proc::Cpu => &self.cpu,
                Proc::Gpu => &self.gpu,
            };
            let dispatch = match (p, placement) {
                (Proc::Cpu, _) if ctx.new_run_cpu => params.dispatch_first,
                (Proc::Cpu, _) => params.dispatch_next,
                (Proc::Gpu, _) if ctx.new_run_gpu => params.dispatch_first,
                (Proc::Gpu, _) => params.dispatch_next,
            };
            busy = busy.max(self.unit_latency(op, p, frac, snap) + dispatch);
        }
        if matches!(placement, Placement::Split { .. }) {
            busy += self.split_sync_s;
        }
        t + busy
    }
}

/// The CoDL partitioner.
#[derive(Debug, Clone)]
pub struct CodlPartitioner {
    /// The lightweight latency model CoDL plans with.
    pub model: CodlLatencyModel,
    /// Minimum relative latency gain for co-execution to be worth it.
    pub min_gain: f64,
    /// Split-ratio search grid resolution.
    pub ratio_steps: usize,
}

impl Default for CodlPartitioner {
    fn default() -> Self {
        CodlPartitioner {
            model: CodlLatencyModel::default(),
            min_gain: 0.03,
            ratio_steps: 20,
        }
    }
}

impl CodlPartitioner {
    /// CoDL's balance ratio for one op: equalize predicted unit latencies.
    pub fn balance_ratio(&self, op: &OpNode, snap: &Snapshot) -> f64 {
        // latency_cpu(r) = r / thr_cpu ; latency_gpu = (1-r) / thr_gpu
        // balance: r* = thr_cpu / (thr_cpu + thr_gpu); estimate thr via
        // full-op latencies.
        let t_cpu = self.model.unit_latency(op, Proc::Cpu, 1.0, snap);
        let t_gpu = self.model.unit_latency(op, Proc::Gpu, 1.0, snap);
        if !t_cpu.is_finite() || !t_gpu.is_finite() || t_cpu <= 0.0 || t_gpu <= 0.0 {
            return 0.0;
        }
        let thr_cpu = 1.0 / t_cpu;
        let thr_gpu = 1.0 / t_gpu;
        thr_cpu / (thr_cpu + thr_gpu)
    }

    /// Choose the placement for one op: best of {CPU, GPU, split grid
    /// around the balance ratio}, judged purely on predicted latency.
    fn choose(&self, op: &OpNode, ctx: &ExecCtx, snap: &Snapshot) -> Placement {
        let t_cpu = self.model.placement_latency(op, Placement::CPU, ctx, snap);
        let t_gpu = self.model.placement_latency(op, Placement::GPU, ctx, snap);
        let (mut best_single, single_t) = if t_cpu < t_gpu {
            (Placement::CPU, t_cpu)
        } else {
            (Placement::GPU, t_gpu)
        };
        let r_star = self.balance_ratio(op, snap);
        let mut best_split: Option<(Placement, f64)> = None;
        for k in 0..=self.ratio_steps {
            // grid spanning [r*/2, min(2 r*, 0.95)] — fine near balance
            let lo = (r_star * 0.5).max(0.01);
            let hi = (r_star * 2.0).min(0.95);
            if lo >= hi {
                break;
            }
            let r = lo + (hi - lo) * k as f64 / self.ratio_steps as f64;
            let p = Placement::Split { cpu_frac: r };
            let t = self.model.placement_latency(op, p, ctx, snap);
            if best_split.as_ref().map_or(true, |&(_, bt)| t < bt) {
                best_split = Some((p, t));
            }
        }
        if let Some((p, t)) = best_split {
            if t < single_t * (1.0 - self.min_gain) {
                best_single = p;
            }
        }
        best_single
    }
}

impl Partitioner for CodlPartitioner {
    fn name(&self) -> &str {
        "codl"
    }

    /// Greedy front-to-back pass (CoDL partitions operators one chain at a
    /// time). The external `CostModel` is ignored by design: CoDL plans
    /// with its own offline latency predictors.
    fn partition(
        &self,
        g: &ModelGraph,
        _model: &dyn CostModel,
        snap: &Snapshot,
    ) -> Result<Plan> {
        let mut placements = Vec::with_capacity(g.num_ops());
        let mut out_cpu = vec![INPUT_CPU_FRAC; g.num_ops()];
        let mut prev: Option<Placement> = None;
        let mut pred_latency = 0.0;
        for (i, op) in g.ops.iter().enumerate() {
            let input_cpu_fracs: Vec<f64> = if op.inputs.is_empty() {
                vec![INPUT_CPU_FRAC; op.in_shapes.len()]
            } else {
                op.inputs.iter().map(|&j| out_cpu[j]).collect()
            };
            let (new_run_cpu, new_run_gpu) = match prev {
                None => (true, true),
                Some(p) => (!p.uses(Proc::Cpu), !p.uses(Proc::Gpu)),
            };
            let ctx = ExecCtx {
                input_cpu_fracs,
                new_run_cpu,
                new_run_gpu,
                concurrent: false,
            };
            let choice = self.choose(op, &ctx, snap);
            pred_latency += self.model.placement_latency(op, choice, &ctx, snap);
            out_cpu[i] = choice.frac_on(Proc::Cpu);
            prev = Some(choice);
            placements.push(choice);
        }
        Ok(Plan {
            placements,
            predicted: PlanCost {
                latency_s: pred_latency,
                ..Default::default()
            },
            policy: "codl".into(),
        })
    }
}

/// CoDL never predicts energy; expose its latency model as a [`CostModel`]
/// (energy = 0) for code that wants to inspect its view of the world.
impl CostModel for CodlLatencyModel {
    fn predict(
        &self,
        op: &OpNode,
        placement: Placement,
        ctx: &ExecCtx,
        snap: &Snapshot,
    ) -> OpCost {
        let l = self.placement_latency(op, placement, ctx, snap);
        OpCost {
            latency_s: l,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::partition::plan::evaluate;
    use crate::soc::device::{Device, DeviceConfig};
    use crate::workload::WorkloadCondition;

    fn frozen(cond: WorkloadCondition) -> Device {
        let mut d = Device::new(DeviceConfig {
            noise_sigma: 0.0,
            drift_sigma: 0.0,
            ..DeviceConfig::snapdragon_855()
        });
        let mut c = cond.spec;
        c.cpu_bg_sigma = 0.0;
        c.cpu_burst = 0.0;
        c.gpu_bg_sigma = 0.0;
        c.gpu_burst = 0.0;
        c.drift_sigma = 0.0;
        d.apply_condition(&c);
        d
    }

    #[test]
    fn codl_splits_heavy_convs() {
        let g = zoo::yolov2();
        let d = frozen(WorkloadCondition::moderate());
        let plan = CodlPartitioner::default()
            .partition(&g, &d, &d.snapshot())
            .unwrap();
        let n_split = plan
            .placements
            .iter()
            .filter(|p| matches!(p, Placement::Split { .. }))
            .count();
        assert!(n_split >= 5, "CoDL only split {n_split} ops");
    }

    #[test]
    fn codl_beats_pure_gpu_latency_under_calm_conditions() {
        // with bursts frozen, CoDL's model matches the device → its
        // latency-optimal split must beat single-processor execution
        let g = zoo::yolov2();
        let d = frozen(WorkloadCondition::moderate());
        let snap = d.snapshot();
        let plan = CodlPartitioner::default().partition(&g, &d, &snap).unwrap();
        let codl = evaluate(&g, &plan.placements, &d, &snap);
        let gpu = evaluate(&g, &vec![Placement::GPU; g.num_ops()], &d, &snap);
        assert!(
            codl.latency_s < gpu.latency_s,
            "codl {} vs gpu {}",
            codl.latency_s,
            gpu.latency_s
        );
    }

    #[test]
    fn balance_ratio_reasonable() {
        let g = zoo::yolov2();
        let d = frozen(WorkloadCondition::moderate());
        let snap = d.snapshot();
        let p = CodlPartitioner::default();
        let op = &g.ops[2]; // heavy conv
        let r = p.balance_ratio(op, &snap);
        assert!((0.02..0.5).contains(&r), "ratio {r}");
    }

    #[test]
    fn codl_ratio_shrinks_under_high_condition() {
        let g = zoo::yolov2();
        let p = CodlPartitioner::default();
        let op = &g.ops[2];
        let d_mod = frozen(WorkloadCondition::moderate());
        let d_high = frozen(WorkloadCondition::high());
        let r_mod = p.balance_ratio(op, &d_mod.snapshot());
        let r_high = p.balance_ratio(op, &d_high.snapshot());
        assert!(
            r_high < r_mod,
            "high-condition ratio {r_high} not below moderate {r_mod}"
        );
    }

    #[test]
    fn codl_is_energy_oblivious() {
        let g = zoo::yolov2();
        let d = frozen(WorkloadCondition::moderate());
        let snap = d.snapshot();
        let plan = CodlPartitioner::default().partition(&g, &d, &snap).unwrap();
        // its own prediction carries no energy estimate
        assert_eq!(plan.predicted.energy_j, 0.0);
        assert!(plan.predicted.latency_s > 0.0);
    }
}
