//! AdaOper's energy-aware operator partitioner (paper §2.2).
//!
//! A bottom-up, iterative dynamic program over the operator list in
//! topological order. The DP state after op *i* is the placement of every
//! op whose output is still *live* (needed by a later op) — for chains
//! that is just op *i*, for YOLOv2's passthrough or ResNet blocks at most
//! two ops — so only a rolling column of states is stored (the paper's
//! space optimization: "storing only those states").
//!
//! Because energy and latency are jointly optimized (EDP or
//! energy-under-SLO), each DP state carries a *Pareto set* of
//! (energy, latency) points instead of a scalar; dominated points are
//! pruned and the set is thinned to `latency_buckets` points (the
//! discretized latency lattice). The final objective is applied once, over
//! the terminal Pareto sets.
//!
//! Candidate placements per op: CPU, GPU, and a grid of CoDL-style
//! intra-op split ratios — so AdaOper's search space *contains* CoDL-like
//! co-execution and the single-processor baselines as special cases.

use anyhow::Result;
use std::collections::BTreeMap;

use crate::graph::{ModelGraph, OpId};
use crate::profiler::CostModel;
use crate::soc::device::{ExecCtx, Snapshot};
use crate::soc::{Placement, Proc};

use super::plan::{Objective, Partitioner, Plan, PlanCost, INPUT_CPU_FRAC};

/// Default intra-op split grid (CPU fractions).
pub const DEFAULT_SPLITS: [f64; 3] = [0.08, 0.15, 0.25];

/// The AdaOper dynamic-programming partitioner.
#[derive(Debug, Clone)]
pub struct DpPartitioner {
    /// Optimization objective of the solve.
    pub objective: Objective,
    /// Candidate placements considered per op.
    pub choices: Vec<Placement>,
    /// Pareto-frontier thinning width per DP state.
    pub latency_buckets: usize,
}

impl DpPartitioner {
    /// Build with the default candidate set (CPU, GPU, split grid).
    pub fn new(objective: Objective) -> Self {
        let mut choices = vec![Placement::CPU, Placement::GPU];
        choices.extend(DEFAULT_SPLITS.iter().map(|&r| Placement::Split { cpu_frac: r }));
        DpPartitioner {
            objective,
            choices,
            latency_buckets: 64,
        }
    }

    /// Restrict the candidate set (ablations; e.g. no splits).
    pub fn with_choices(mut self, choices: Vec<Placement>) -> Self {
        assert!(!choices.is_empty());
        self.choices = choices;
        self
    }

    /// Override the Pareto-thinning width (accuracy/runtime trade).
    pub fn with_buckets(mut self, buckets: usize) -> Self {
        assert!(buckets >= 2);
        self.latency_buckets = buckets;
        self
    }

    /// Solve for a full model.
    pub fn solve(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
    ) -> Result<Plan> {
        let sol = self.solve_range(g, model, snap, 0, g.num_ops(), &[], None)?;
        Ok(Plan {
            placements: sol.placements,
            predicted: sol.cost,
            policy: "adaoper".into(),
        })
    }

    /// Solve ops `[start, end)` with everything outside pinned to
    /// `pinned` (full-length placement slice; consulted for ids < start
    /// and ≥ end). `prev_out_cpu` optionally supplies the residency of op
    /// outputs produced before `start` (from the executed prefix).
    /// Returns placements for the *whole* graph (pinned parts copied) and
    /// the cost over `[start, n)` (window + fixed tail).
    pub fn solve_range(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
        start: usize,
        end: usize,
        pinned: &[Placement],
        prev_out_cpu: Option<&[f64]>,
    ) -> Result<RangeSolution> {
        let n = g.num_ops();
        assert!(start <= end && end <= n);
        if start == end {
            // nothing free — evaluate pinned tail directly
            let tail = self.eval_fixed(g, model, snap, start, pinned, prev_out_cpu);
            return Ok(RangeSolution {
                placements: pinned.to_vec(),
                cost: tail,
            });
        }
        let last_use = g.last_use();

        // Residency of pre-window outputs (default: walk not available →
        // derive from pinned placements; op inputs default to CPU).
        let base_out_cpu: Vec<f64> = match prev_out_cpu {
            Some(v) => v.to_vec(),
            None => (0..n)
                .map(|i| {
                    if i < start && !pinned.is_empty() {
                        pinned[i].frac_on(Proc::Cpu)
                    } else {
                        INPUT_CPU_FRAC
                    }
                })
                .collect(),
        };
        let prev_placement_before_start: Option<Placement> = if start > 0 && !pinned.is_empty()
        {
            Some(pinned[start - 1])
        } else {
            None
        };

        // ---- DP over ops[start..end)
        // State key: sorted (op, choice_idx) for frontier ops. Ops < start
        // are pinned and read from `base_out_cpu`, so they never enter keys.
        type Key = Vec<(u32, u8)>;
        // decision arena: (choice_idx, parent)
        let mut arena: Vec<(u8, u32)> = Vec::new();
        let mut states: BTreeMap<Key, Vec<Pt>> = BTreeMap::new();
        states.insert(
            Vec::new(),
            vec![Pt {
                e: 0.0,
                t: 0.0,
                back: u32::MAX,
            }],
        );

        for i in start..end {
            let op = &g.ops[i];
            let mut next: BTreeMap<Key, Vec<Pt>> = BTreeMap::new();
            for (key, pts) in &states {
                let lookup = |j: OpId| -> Option<Placement> {
                    key.iter()
                        .find(|&&(o, _)| o as usize == j)
                        .map(|&(_, c)| self.choices[c as usize])
                };
                for (ci, &choice) in self.choices.iter().enumerate() {
                    // context under this state
                    let input_cpu_fracs: Vec<f64> = if op.inputs.is_empty() {
                        vec![INPUT_CPU_FRAC; op.in_shapes.len()]
                    } else {
                        op.inputs
                            .iter()
                            .map(|&j| match lookup(j) {
                                Some(p) => p.frac_on(Proc::Cpu),
                                None => base_out_cpu[j],
                            })
                            .collect()
                    };
                    let prev = if i == start {
                        prev_placement_before_start
                    } else {
                        lookup(i - 1)
                    };
                    let (new_run_cpu, new_run_gpu) = match prev {
                        None => (true, true),
                        Some(p) => (!p.uses(Proc::Cpu), !p.uses(Proc::Gpu)),
                    };
                    let ctx = ExecCtx {
                        input_cpu_fracs,
                        new_run_cpu,
                        new_run_gpu,
                        concurrent: false,
                    };
                    let c = model.predict(op, choice, &ctx, snap);

                    // next frontier: in-window ops still live after i, + i
                    let mut nkey: Key = key
                        .iter()
                        .copied()
                        .filter(|&(o, _)| last_use[o as usize] > i)
                        .collect();
                    nkey.push((i as u32, ci as u8));
                    nkey.sort_unstable();

                    let slot = next.entry(nkey).or_default();
                    for pt in pts {
                        let back = arena.len() as u32;
                        arena.push((ci as u8, pt.back));
                        slot.push(Pt {
                            e: pt.e + c.energy_j,
                            t: pt.t + c.latency_s,
                            back,
                        });
                    }
                }
            }
            // prune each state's Pareto set
            for pts in next.values_mut() {
                prune(pts, self.latency_buckets);
            }
            states = next;
        }

        // ---- pick the best terminal point (adding the fixed tail cost,
        // which depends on the final frontier residency)
        let mut best: Option<(f64, Pt, PlanCost)> = None;
        for (key, pts) in &states {
            // residency after the window for the tail evaluation
            let mut out_cpu = base_out_cpu.clone();
            for &(o, c) in key {
                out_cpu[o as usize] = self.choices[c as usize].frac_on(Proc::Cpu);
            }
            // note: ops in the window but dead before `end` don't appear in
            // the key; the tail can't read them either (they're dead).
            let tail = if end < n {
                let prev_pl = key
                    .iter()
                    .find(|&&(o, _)| o as usize == end - 1)
                    .map(|&(_, c)| self.choices[c as usize]);
                self.eval_tail(g, model, snap, end, pinned, &out_cpu, prev_pl)
            } else {
                PlanCost::default()
            };
            for pt in pts {
                let e = pt.e + tail.energy_j;
                let t = pt.t + tail.latency_s;
                let s = self.objective.score(e, t);
                if best.as_ref().map_or(true, |(bs, _, _)| s < *bs) {
                    best = Some((
                        s,
                        *pt,
                        PlanCost {
                            energy_j: e,
                            latency_s: t,
                            transfer_s: 0.0,
                            transfer_j: 0.0,
                        },
                    ));
                }
            }
        }
        let (_, pt, cost) = best.expect("DP produced no states");

        // ---- reconstruct
        let mut placements: Vec<Placement> = if pinned.is_empty() {
            vec![Placement::GPU; n]
        } else {
            pinned.to_vec()
        };
        let mut back = pt.back;
        let mut i = end;
        while back != u32::MAX {
            i -= 1;
            let (ci, parent) = arena[back as usize];
            placements[i] = self.choices[ci as usize];
            back = parent;
        }
        debug_assert_eq!(i, start);
        Ok(RangeSolution { placements, cost })
    }

    /// Cost of the fixed ops `[from, n)` given post-window residencies.
    fn eval_tail(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
        from: usize,
        pinned: &[Placement],
        out_cpu: &[f64],
        prev_placement: Option<Placement>,
    ) -> PlanCost {
        let mut out_cpu = out_cpu.to_vec();
        let mut prev = prev_placement;
        let mut total = PlanCost::default();
        for i in from..g.num_ops() {
            let op = &g.ops[i];
            let placement = pinned[i];
            let input_cpu_fracs: Vec<f64> = if op.inputs.is_empty() {
                vec![INPUT_CPU_FRAC; op.in_shapes.len()]
            } else {
                op.inputs.iter().map(|&j| out_cpu[j]).collect()
            };
            let (new_run_cpu, new_run_gpu) = match prev {
                None => (true, true),
                Some(p) => (!p.uses(Proc::Cpu), !p.uses(Proc::Gpu)),
            };
            let ctx = ExecCtx {
                input_cpu_fracs,
                new_run_cpu,
                new_run_gpu,
                concurrent: false,
            };
            let c = model.predict(op, placement, &ctx, snap);
            total.energy_j += c.energy_j;
            total.latency_s += c.latency_s;
            total.transfer_s += c.transfer_s;
            total.transfer_j += c.transfer_j;
            out_cpu[i] = placement.frac_on(Proc::Cpu);
            prev = Some(placement);
        }
        total
    }

    fn eval_fixed(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
        from: usize,
        pinned: &[Placement],
        prev_out_cpu: Option<&[f64]>,
    ) -> PlanCost {
        let n = g.num_ops();
        let out_cpu: Vec<f64> = match prev_out_cpu {
            Some(v) => v.to_vec(),
            None => (0..n)
                .map(|i| {
                    if !pinned.is_empty() {
                        pinned[i].frac_on(Proc::Cpu)
                    } else {
                        INPUT_CPU_FRAC
                    }
                })
                .collect(),
        };
        let prev = if from > 0 && !pinned.is_empty() {
            Some(pinned[from - 1])
        } else {
            None
        };
        self.eval_tail(g, model, snap, from, pinned, &out_cpu, prev)
    }
}

/// Result of a (possibly windowed) DP solve.
#[derive(Debug, Clone)]
pub struct RangeSolution {
    /// Placements for the solved window.
    pub placements: Vec<Placement>,
    /// Cost over `[start, n)` (window + fixed tail), as predicted.
    pub cost: PlanCost,
}

/// Keep the Pareto-optimal (min energy per latency) subset, thinned to at
/// most `buckets` points.
fn prune<P: ParetoPoint>(pts: &mut Vec<P>, buckets: usize) {
    if pts.len() <= 1 {
        return;
    }
    // total_cmp: a NaN cost (e.g. from a degenerate model) must not panic
    // the solver; NaN points sort last and are pruned as dominated
    pts.sort_by(|a, b| a.t().total_cmp(&b.t()).then(a.e().total_cmp(&b.e())));
    let mut kept: Vec<P> = Vec::with_capacity(pts.len());
    let mut best_e = f64::INFINITY;
    for p in pts.iter() {
        if p.e() < best_e - 1e-15 {
            best_e = p.e();
            kept.push(*p);
        }
    }
    if kept.len() > buckets {
        // keep endpoints + evenly spaced interior points
        let mut thinned = Vec::with_capacity(buckets);
        for k in 0..buckets {
            let idx = k * (kept.len() - 1) / (buckets - 1);
            thinned.push(kept[idx]);
        }
        thinned.dedup_by(|a, b| a.t() == b.t() && a.e() == b.e());
        kept = thinned;
    }
    *pts = kept;
}

/// Internal trait so `prune` is testable.
trait ParetoPoint: Copy {
    fn e(&self) -> f64;
    fn t(&self) -> f64;
}

impl ParetoPoint for (f64, f64) {
    fn e(&self) -> f64 {
        self.0
    }
    fn t(&self) -> f64 {
        self.1
    }
}

/// A DP point: accumulated (energy, latency) plus its decision backpointer.
#[derive(Clone, Copy)]
struct Pt {
    e: f64,
    t: f64,
    back: u32,
}

impl ParetoPoint for Pt {
    fn e(&self) -> f64 {
        self.e
    }
    fn t(&self) -> f64 {
        self.t
    }
}

impl Partitioner for DpPartitioner {
    fn name(&self) -> &str {
        "adaoper"
    }

    fn partition(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
    ) -> Result<Plan> {
        self.solve(g, model, snap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::partition::plan::evaluate;
    use crate::soc::device::{Device, DeviceConfig};
    use crate::workload::WorkloadCondition;

    fn frozen_device(cond: WorkloadCondition) -> Device {
        let mut d = Device::new(DeviceConfig {
            noise_sigma: 0.0,
            drift_sigma: 0.0,
            ..DeviceConfig::snapdragon_855()
        });
        let mut c = cond.spec;
        c.cpu_bg_sigma = 0.0;
        c.cpu_burst = 0.0;
        c.gpu_bg_sigma = 0.0;
        c.gpu_burst = 0.0;
        c.drift_sigma = 0.0;
        d.apply_condition(&c);
        d
    }

    #[test]
    fn pareto_prune_removes_dominated() {
        let mut pts = vec![(1.0, 5.0), (2.0, 4.0), (3.0, 3.0), (2.5, 3.5), (4.0, 2.9)];
        prune(&mut pts, 64);
        // (2.5,3.5) dominated by (3.0,3.0)? no: 3.0>2.5 energy… sorted by t:
        // (4.0,2.9) (3.0,3.0) (2.5,3.5) (2.0,4.0) (1.0,5.0) — all strictly
        // decreasing energy → all kept
        assert_eq!(pts.len(), 5);
        let mut pts2 = vec![(1.0, 5.0), (1.5, 5.5), (2.0, 6.0)];
        prune(&mut pts2, 64);
        // (1.5,5.5) and (2.0,6.0) dominated by (1.0,5.0)
        assert_eq!(pts2.len(), 1);
    }

    #[test]
    fn pareto_prune_thins_to_buckets() {
        let mut pts: Vec<(f64, f64)> =
            (0..500).map(|i| (500.0 - i as f64, i as f64)).collect();
        prune(&mut pts, 16);
        assert!(pts.len() <= 16);
        // endpoints survive
        assert!(pts.iter().any(|p| p.1 == 0.0));
        assert!(pts.iter().any(|p| p.1 == 499.0));
    }

    #[test]
    fn dp_beats_all_baselines_on_its_objective() {
        let d = frozen_device(WorkloadCondition::moderate());
        let snap = d.snapshot();
        for obj in [
            Objective::MinEdp,
            Objective::MinLatency,
            Objective::MinEnergyUnderSlo { slo_s: 0.2 },
        ] {
            for g in [zoo::yolov2(), zoo::yolov2_tiny()] {
                let plan = DpPartitioner::new(obj).solve(&g, &d, &snap).unwrap();
                let dp_cost = evaluate(&g, &plan.placements, &d, &snap);
                for base in [Placement::CPU, Placement::GPU] {
                    let c = evaluate(&g, &vec![base; g.num_ops()], &d, &snap);
                    assert!(
                        obj.score(dp_cost.energy_j, dp_cost.latency_s)
                            <= obj.score(c.energy_j, c.latency_s) * 1.0001,
                        "{}: dp {:?} worse than {base:?} {:?} under {obj:?}",
                        g.name,
                        dp_cost,
                        c
                    );
                }
            }
        }
    }

    #[test]
    fn dp_prediction_matches_evaluate() {
        // the DP's internal accumulation must agree with the shared
        // evaluator (same ctx construction)
        let d = frozen_device(WorkloadCondition::moderate());
        let snap = d.snapshot();
        for g in [zoo::yolov2(), zoo::resnet18(), zoo::mobilenet_v1()] {
            let plan = DpPartitioner::new(Objective::MinEdp)
                .solve(&g, &d, &snap)
                .unwrap();
            let ev = evaluate(&g, &plan.placements, &d, &snap);
            assert!(
                (plan.predicted.energy_j / ev.energy_j - 1.0).abs() < 1e-9,
                "{}: {} vs {}",
                g.name,
                plan.predicted.energy_j,
                ev.energy_j
            );
            assert!((plan.predicted.latency_s / ev.latency_s - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn min_latency_dp_never_slower_than_pure_gpu() {
        let d = frozen_device(WorkloadCondition::high());
        let snap = d.snapshot();
        let g = zoo::yolov2();
        let plan = DpPartitioner::new(Objective::MinLatency)
            .solve(&g, &d, &snap)
            .unwrap();
        let dp = evaluate(&g, &plan.placements, &d, &snap);
        let gpu = evaluate(&g, &vec![Placement::GPU; g.num_ops()], &d, &snap);
        assert!(dp.latency_s <= gpu.latency_s * 1.0001);
    }

    #[test]
    fn slo_constraint_respected_when_feasible() {
        let d = frozen_device(WorkloadCondition::moderate());
        let snap = d.snapshot();
        let g = zoo::yolov2();
        // find an achievable SLO: pure-GPU latency × 1.1
        let gpu = evaluate(&g, &vec![Placement::GPU; g.num_ops()], &d, &snap);
        let slo = gpu.latency_s * 1.1;
        let plan = DpPartitioner::new(Objective::MinEnergyUnderSlo { slo_s: slo })
            .solve(&g, &d, &snap)
            .unwrap();
        let c = evaluate(&g, &plan.placements, &d, &snap);
        assert!(c.latency_s <= slo * 1.001, "{} > {}", c.latency_s, slo);
    }

    #[test]
    fn windowed_solve_only_changes_window() {
        let d = frozen_device(WorkloadCondition::moderate());
        let snap = d.snapshot();
        let g = zoo::yolov2();
        let base = vec![Placement::GPU; g.num_ops()];
        let dp = DpPartitioner::new(Objective::MinEdp);
        let sol = dp
            .solve_range(&g, &d, &snap, 5, 12, &base, None)
            .unwrap();
        for i in 0..g.num_ops() {
            if !(5..12).contains(&i) {
                assert_eq!(sol.placements[i], base[i], "op {i} changed outside window");
            }
        }
    }

    #[test]
    fn dag_models_solve_without_panic() {
        let d = frozen_device(WorkloadCondition::high());
        let snap = d.snapshot();
        for g in [zoo::yolov2(), zoo::resnet18()] {
            let plan = DpPartitioner::new(Objective::MinEdp).solve(&g, &d, &snap).unwrap();
            assert_eq!(plan.placements.len(), g.num_ops());
        }
    }
}
