//! AdaOper's energy-aware operator partitioner (paper §2.2).
//!
//! A bottom-up, iterative dynamic program over the operator list in
//! topological order. The DP state after op *i* is the placement of every
//! op whose output is still *live* (needed by a later op) — for chains
//! that is just op *i*, for YOLOv2's passthrough or ResNet blocks at most
//! two ops — so only a rolling column of states is stored (the paper's
//! space optimization: "storing only those states").
//!
//! Because energy and latency are jointly optimized (EDP or
//! energy-under-SLO), each DP state carries a *Pareto set* of
//! (energy, latency) points instead of a scalar; dominated points are
//! pruned and the set is thinned to `latency_buckets` points (the
//! discretized latency lattice). The final objective is applied once, over
//! the terminal Pareto sets.
//!
//! Candidate placements per op: CPU, GPU, and a grid of CoDL-style
//! intra-op split ratios — so AdaOper's search space *contains* CoDL-like
//! co-execution and the single-processor baselines as special cases.
//!
//! ## Two solver backends
//!
//! The DP core exists twice, selected by [`DpBackend`]:
//!
//! * [`DpBackend::Lattice`] (default) — the frontier op *set* of a column
//!   is identical for every state in it (it depends only on liveness, not
//!   on choices), so a state is encoded as a dense mixed-radix integer
//!   over the frontier ops' choice digits and a column is two flat,
//!   preallocated `Vec<Pt>` CSR buffers that ping-pong each op. The
//!   per-(state, choice) `input_cpu_fracs` allocation, the linear frontier
//!   `lookup` scans, and the per-op `BTreeMap` rebuilds of the reference
//!   solver are all replaced by precomputed index tables; every buffer
//!   lives in a reusable [`DpScratch`] (owned long-term by the
//!   repartition controller) so steady-state replans allocate nothing.
//!   Cost-model queries are memoized per column, keyed by the digits of
//!   the frontier ops the cost actually depends on (the op's in-window
//!   inputs plus its predecessor's run-start flags) — sound whenever the
//!   model opts in via [`CostModel::version`].
//! * [`DpBackend::Map`] — the original rolling
//!   `BTreeMap<frontier-key, Pareto set>` solver, kept verbatim as
//!   [`MapDpPartitioner`]: the readable specification of the DP, the
//!   differential-testing oracle (`tests/prop_dp_lattice.rs` drives both
//!   backends in lockstep and demands bit-identical plans and costs), and
//!   the "before" arm of `make bench-dp`.
//!
//! The two backends are *bit-identical* by construction: ascending dense
//! cell index reproduces the `BTreeMap`'s key iteration order, each
//! target slot receives its per-source runs in the reference append
//! order, and the natural-run merge used for pruning is exactly a stable
//! sort by (latency, energy) — see the invariant notes on
//! [`merge_prune_slot`].

use anyhow::Result;
use std::cmp::Ordering;
use std::collections::BTreeMap;

use crate::graph::{ModelGraph, OpId, OpNode};
use crate::profiler::CostModel;
use crate::soc::device::{ExecCtx, OpCost, Snapshot};
use crate::soc::{Placement, Proc};

use super::plan::{Objective, Partitioner, Plan, PlanCost, INPUT_CPU_FRAC};

/// Default intra-op split grid (CPU fractions).
pub const DEFAULT_SPLITS: [f64; 3] = [0.08, 0.15, 0.25];

/// Hard cap on a dense-lattice column: `choices^frontier_len` cells. A
/// solve whose liveness pattern would exceed this anywhere (pathological
/// fan-in with a huge candidate grid) falls back to the map solver, which
/// only materializes reachable states.
const LATTICE_CELL_CAP: usize = 1 << 14;

/// Which DP core a [`DpPartitioner`] runs. Both return bit-identical
/// plans and predicted costs; they differ only in speed and allocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DpBackend {
    /// Dense flattened-lattice solver (fast path, zero steady-state
    /// allocation when driven through a reused [`DpScratch`]).
    #[default]
    Lattice,
    /// Reference rolling-`BTreeMap` solver (pre-lattice implementation).
    Map,
}

/// The AdaOper dynamic-programming partitioner.
#[derive(Debug, Clone)]
pub struct DpPartitioner {
    /// Optimization objective of the solve.
    pub objective: Objective,
    /// Candidate placements considered per op.
    pub choices: Vec<Placement>,
    /// Pareto-frontier thinning width per DP state.
    pub latency_buckets: usize,
    /// DP core to run (defaults to the lattice).
    pub backend: DpBackend,
}

impl DpPartitioner {
    /// Build with the default candidate set (CPU, GPU, split grid).
    pub fn new(objective: Objective) -> Self {
        let mut choices = vec![Placement::CPU, Placement::GPU];
        choices.extend(DEFAULT_SPLITS.iter().map(|&r| Placement::Split { cpu_frac: r }));
        DpPartitioner {
            objective,
            choices,
            latency_buckets: 64,
            backend: DpBackend::default(),
        }
    }

    /// Restrict the candidate set (ablations; e.g. no splits).
    pub fn with_choices(mut self, choices: Vec<Placement>) -> Self {
        assert!(!choices.is_empty());
        self.choices = choices;
        self
    }

    /// Override the Pareto-thinning width (accuracy/runtime trade).
    pub fn with_buckets(mut self, buckets: usize) -> Self {
        assert!(buckets >= 2);
        self.latency_buckets = buckets;
        self
    }

    /// Select the DP core (A/B tests and the solver bench).
    pub fn with_backend(mut self, backend: DpBackend) -> Self {
        self.backend = backend;
        self
    }

    /// Solve for a full model.
    pub fn solve(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
    ) -> Result<Plan> {
        let mut scratch = DpScratch::default();
        self.solve_in(g, model, snap, &mut scratch)
    }

    /// Solve for a full model, reusing `scratch` across calls so the
    /// steady state allocates nothing.
    pub fn solve_in(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
        scratch: &mut DpScratch,
    ) -> Result<Plan> {
        let sol =
            self.solve_range_in(g, model, snap, 0, g.num_ops(), &[], None, scratch)?;
        Ok(Plan {
            placements: sol.placements,
            predicted: sol.cost,
            policy: "adaoper".into(),
        })
    }

    /// Solve ops `[start, end)` with everything outside pinned to
    /// `pinned` (full-length placement slice; consulted for ids < start
    /// and ≥ end). `prev_out_cpu` optionally supplies the residency of op
    /// outputs produced before `start` (from the executed prefix).
    /// Returns placements for the *whole* graph (pinned parts copied) and
    /// the cost over `[start, n)` (window + fixed tail).
    pub fn solve_range(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
        start: usize,
        end: usize,
        pinned: &[Placement],
        prev_out_cpu: Option<&[f64]>,
    ) -> Result<RangeSolution> {
        let mut scratch = DpScratch::default();
        self.solve_range_in(g, model, snap, start, end, pinned, prev_out_cpu, &mut scratch)
    }

    /// [`DpPartitioner::solve_range`] with caller-owned scratch; the
    /// repartition controller keeps one [`DpScratch`] alive so repeated
    /// window solves reuse every buffer.
    #[allow(clippy::too_many_arguments)]
    pub fn solve_range_in(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
        start: usize,
        end: usize,
        pinned: &[Placement],
        prev_out_cpu: Option<&[f64]>,
        scratch: &mut DpScratch,
    ) -> Result<RangeSolution> {
        let n = g.num_ops();
        assert!(start <= end && end <= n);
        if self.backend == DpBackend::Map {
            return self.map_solve_range(g, model, snap, start, end, pinned, prev_out_cpu);
        }
        if start == end {
            // nothing free — evaluate pinned tail directly
            let tail =
                self.eval_fixed_in(g, model, snap, start, pinned, prev_out_cpu, scratch);
            return Ok(RangeSolution {
                placements: pinned.to_vec(),
                cost: tail,
            });
        }
        let last_use = g.last_use();
        if !lattice_fits(&last_use, start, end, self.choices.len(), &mut scratch.new_f) {
            return self.map_solve_range(g, model, snap, start, end, pinned, prev_out_cpu);
        }
        self.lattice_solve_range(
            g,
            model,
            snap,
            start,
            end,
            pinned,
            prev_out_cpu,
            &last_use,
            scratch,
        )
    }

    /// The dense flattened-lattice DP core. Bit-identical to
    /// [`DpPartitioner::map_solve_range`]; see the module docs for the
    /// order-preservation argument.
    #[allow(clippy::too_many_arguments)]
    fn lattice_solve_range(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
        start: usize,
        end: usize,
        pinned: &[Placement],
        prev_out_cpu: Option<&[f64]>,
        last_use: &[usize],
        scratch: &mut DpScratch,
    ) -> Result<RangeSolution> {
        let n = g.num_ops();
        let k = self.choices.len();
        // predict memo is only sound when the model guarantees that equal
        // (inputs, version) imply equal outputs
        let memo = model.version().is_some();
        let sc = &mut *scratch;

        sc.arena.clear();
        sc.base_out.clear();
        match prev_out_cpu {
            Some(v) => sc.base_out.extend_from_slice(v),
            None => sc.base_out.extend((0..n).map(|i| {
                if i < start && !pinned.is_empty() {
                    pinned[i].frac_on(Proc::Cpu)
                } else {
                    INPUT_CPU_FRAC
                }
            })),
        }
        let prev_before_start: Option<Placement> = if start > 0 && !pinned.is_empty() {
            Some(pinned[start - 1])
        } else {
            None
        };

        // column before the window: one empty-frontier cell, origin point
        sc.prev_f.clear();
        sc.prev_off.clear();
        sc.prev_off.extend_from_slice(&[0, 1]);
        sc.prev_pts.clear();
        sc.prev_pts.push(Pt {
            e: 0.0,
            t: 0.0,
            back: u32::MAX,
        });

        for i in start..end {
            let op = &g.ops[i];

            // -- frontier bookkeeping: which previous-frontier positions
            // survive op i. Identical for every cell of the column (it
            // depends on liveness only), which is what makes the dense
            // encoding possible.
            sc.surv_pos.clear();
            sc.new_f.clear();
            for (p, &j) in sc.prev_f.iter().enumerate() {
                if last_use[j] > i {
                    sc.surv_pos.push(p as u8);
                    sc.new_f.push(j);
                }
            }
            sc.new_f.push(i);
            let m_prev = sc.prev_f.len();
            let prev_cells = sc.prev_off.len() - 1;
            let next_cells = k.pow(sc.surv_pos.len() as u32 + 1);

            // -- frontier positions the cost of op i depends on: its
            // in-window inputs plus op i-1 (run-start flags). Both are
            // provably on the previous frontier: an input j of i has
            // last_use[j] >= i > i-1, and i-1 has all consumers > i-1.
            sc.rel_pos.clear();
            if i > start {
                let p = sc.prev_f.binary_search(&(i - 1)).expect("i-1 live") as u8;
                sc.rel_pos.push(p);
            }
            for &j in &op.inputs {
                if j >= start {
                    let p = sc.prev_f.binary_search(&j).expect("input live") as u8;
                    if !sc.rel_pos.contains(&p) {
                        sc.rel_pos.push(p);
                    }
                }
            }
            sc.rel_pos.sort_unstable();

            // -- predict memo: one table entry per (relevant digit combo,
            // choice); the lattice revisits the same cost context once per
            // combination of the *irrelevant* frontier digits.
            if memo {
                let rel_cells = k.pow(sc.rel_pos.len() as u32);
                sc.cost_tab.clear();
                sc.mdigits.clear();
                sc.mdigits.resize(m_prev, 0);
                for _rel in 0..rel_cells {
                    for &choice in &self.choices {
                        let c = predict_one(
                            &self.choices,
                            op,
                            model,
                            snap,
                            &sc.base_out,
                            start,
                            i,
                            &sc.prev_f,
                            &sc.mdigits,
                            prev_before_start,
                            &mut sc.ctx,
                            choice,
                        );
                        sc.cost_tab.push((c.energy_j, c.latency_s));
                    }
                    advance_at(&mut sc.mdigits, &sc.rel_pos, k as u8);
                }
            }

            // -- pass 1: size every target slot so the column is one flat
            // CSR allocation-free fill
            sc.next_off.clear();
            sc.next_off.resize(next_cells + 1, 0);
            sc.digits.clear();
            sc.digits.resize(m_prev, 0);
            for s in 0..prev_cells {
                let len = sc.prev_off[s + 1] - sc.prev_off[s];
                if len > 0 {
                    let mut th = 0usize;
                    for &p in &sc.surv_pos {
                        th = th * k + sc.digits[p as usize] as usize;
                    }
                    for ci in 0..k {
                        sc.next_off[th * k + ci + 1] += len;
                    }
                }
                advance(&mut sc.digits, k as u8);
            }
            for t in 0..next_cells {
                sc.next_off[t + 1] += sc.next_off[t];
            }
            let total = sc.next_off[next_cells];
            sc.cursor.clear();
            sc.cursor.extend_from_slice(&sc.next_off[..next_cells]);
            sc.next_pts.clear();
            sc.next_pts.resize(
                total,
                Pt {
                    e: 0.0,
                    t: 0.0,
                    back: 0,
                },
            );

            // -- pass 2: shift every source Pareto set into its target
            // slots. Source cells are visited in ascending index order —
            // the reference solver's BTreeMap iteration order — so each
            // slot receives its per-source runs in exactly the reference
            // append order.
            sc.digits.clear();
            sc.digits.resize(m_prev, 0);
            for s in 0..prev_cells {
                let lo = sc.prev_off[s];
                let hi = sc.prev_off[s + 1];
                if lo < hi {
                    let mut th = 0usize;
                    for &p in &sc.surv_pos {
                        th = th * k + sc.digits[p as usize] as usize;
                    }
                    let mut rel = 0usize;
                    if memo {
                        for &p in &sc.rel_pos {
                            rel = rel * k + sc.digits[p as usize] as usize;
                        }
                    }
                    for (ci, &choice) in self.choices.iter().enumerate() {
                        let (de, dt) = if memo {
                            sc.cost_tab[rel * k + ci]
                        } else {
                            let c = predict_one(
                                &self.choices,
                                op,
                                model,
                                snap,
                                &sc.base_out,
                                start,
                                i,
                                &sc.prev_f,
                                &sc.digits,
                                prev_before_start,
                                &mut sc.ctx,
                                choice,
                            );
                            (c.energy_j, c.latency_s)
                        };
                        let slot = th * k + ci;
                        let mut cur = sc.cursor[slot];
                        // branchless inner loop: straight indexed
                        // shift-copy, `back` temporarily holds the parent
                        // (patched to an arena index if the point survives
                        // pruning)
                        for src in lo..hi {
                            let pt = sc.prev_pts[src];
                            sc.next_pts[cur] = Pt {
                                e: pt.e + de,
                                t: pt.t + dt,
                                back: pt.back,
                            };
                            cur += 1;
                        }
                        sc.cursor[slot] = cur;
                    }
                }
                advance(&mut sc.digits, k as u8);
            }

            // -- pass 3: prune each slot and write the pruned column back
            // into the `prev` buffers (they were fully consumed by pass 2)
            sc.prev_pts.clear();
            sc.prev_off.clear();
            sc.prev_off.push(0);
            for slot in 0..next_cells {
                let lo = sc.next_off[slot];
                let hi = sc.next_off[slot + 1];
                merge_prune_slot(
                    &sc.next_pts[lo..hi],
                    self.latency_buckets,
                    &mut sc.runs,
                    &mut sc.run_cur,
                    &mut sc.kept,
                );
                let ci = (slot % k) as u8;
                for p in &sc.kept {
                    let back = sc.arena.len() as u32;
                    sc.arena.push((ci, p.back));
                    sc.prev_pts.push(Pt {
                        e: p.e,
                        t: p.t,
                        back,
                    });
                }
                sc.prev_off.push(sc.prev_pts.len());
            }
            std::mem::swap(&mut sc.prev_f, &mut sc.new_f);
        }

        // ---- pick the best terminal point (adding the fixed tail cost,
        // which depends on the final frontier residency). Ascending cell
        // index is the reference solver's terminal key order.
        let mut best: Option<(f64, Pt, PlanCost)> = None;
        let cells = sc.prev_off.len() - 1;
        sc.digits.clear();
        sc.digits.resize(sc.prev_f.len(), 0);
        for s in 0..cells {
            let lo = sc.prev_off[s];
            let hi = sc.prev_off[s + 1];
            if lo < hi {
                // residency after the window for the tail evaluation
                sc.out_cpu.clear();
                sc.out_cpu.extend_from_slice(&sc.base_out);
                for (p, &j) in sc.prev_f.iter().enumerate() {
                    sc.out_cpu[j] = self.choices[sc.digits[p] as usize].frac_on(Proc::Cpu);
                }
                let tail = if end < n {
                    let prev_pl = sc
                        .prev_f
                        .iter()
                        .position(|&j| j == end - 1)
                        .map(|p| self.choices[sc.digits[p] as usize]);
                    self.eval_tail_in(
                        g,
                        model,
                        snap,
                        end,
                        pinned,
                        &mut sc.out_cpu,
                        prev_pl,
                        &mut sc.ctx,
                    )
                } else {
                    PlanCost::default()
                };
                for pt in &sc.prev_pts[lo..hi] {
                    let e = pt.e + tail.energy_j;
                    let t = pt.t + tail.latency_s;
                    let score = self.objective.score(e, t);
                    if best.as_ref().map_or(true, |(bs, _, _)| score < *bs) {
                        best = Some((
                            score,
                            *pt,
                            PlanCost {
                                energy_j: e,
                                latency_s: t,
                                transfer_s: 0.0,
                                transfer_j: 0.0,
                            },
                        ));
                    }
                }
            }
            advance(&mut sc.digits, k as u8);
        }
        let (_, pt, cost) = best.expect("DP produced no states");

        // ---- reconstruct
        let mut placements: Vec<Placement> = if pinned.is_empty() {
            vec![Placement::GPU; n]
        } else {
            pinned.to_vec()
        };
        let mut back = pt.back;
        let mut i = end;
        while back != u32::MAX {
            i -= 1;
            let (ci, parent) = sc.arena[back as usize];
            placements[i] = self.choices[ci as usize];
            back = parent;
        }
        debug_assert_eq!(i, start);
        Ok(RangeSolution { placements, cost })
    }

    /// The reference rolling-`BTreeMap` DP core (pre-lattice), kept
    /// verbatim as the differential-testing oracle and bench baseline.
    #[allow(clippy::too_many_arguments)]
    fn map_solve_range(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
        start: usize,
        end: usize,
        pinned: &[Placement],
        prev_out_cpu: Option<&[f64]>,
    ) -> Result<RangeSolution> {
        let n = g.num_ops();
        assert!(start <= end && end <= n);
        if start == end {
            // nothing free — evaluate pinned tail directly
            let tail = self.eval_fixed(g, model, snap, start, pinned, prev_out_cpu);
            return Ok(RangeSolution {
                placements: pinned.to_vec(),
                cost: tail,
            });
        }
        let last_use = g.last_use();

        // Residency of pre-window outputs (default: walk not available →
        // derive from pinned placements; op inputs default to CPU).
        let base_out_cpu: Vec<f64> = match prev_out_cpu {
            Some(v) => v.to_vec(),
            None => (0..n)
                .map(|i| {
                    if i < start && !pinned.is_empty() {
                        pinned[i].frac_on(Proc::Cpu)
                    } else {
                        INPUT_CPU_FRAC
                    }
                })
                .collect(),
        };
        let prev_placement_before_start: Option<Placement> = if start > 0 && !pinned.is_empty()
        {
            Some(pinned[start - 1])
        } else {
            None
        };

        // ---- DP over ops[start..end)
        // State key: sorted (op, choice_idx) for frontier ops. Ops < start
        // are pinned and read from `base_out_cpu`, so they never enter keys.
        type Key = Vec<(u32, u8)>;
        // decision arena: (choice_idx, parent)
        let mut arena: Vec<(u8, u32)> = Vec::new();
        let mut states: BTreeMap<Key, Vec<Pt>> = BTreeMap::new();
        states.insert(
            Vec::new(),
            vec![Pt {
                e: 0.0,
                t: 0.0,
                back: u32::MAX,
            }],
        );

        for i in start..end {
            let op = &g.ops[i];
            let mut next: BTreeMap<Key, Vec<Pt>> = BTreeMap::new();
            for (key, pts) in &states {
                let lookup = |j: OpId| -> Option<Placement> {
                    key.iter()
                        .find(|&&(o, _)| o as usize == j)
                        .map(|&(_, c)| self.choices[c as usize])
                };
                for (ci, &choice) in self.choices.iter().enumerate() {
                    // context under this state
                    let input_cpu_fracs: Vec<f64> = if op.inputs.is_empty() {
                        vec![INPUT_CPU_FRAC; op.in_shapes.len()]
                    } else {
                        op.inputs
                            .iter()
                            .map(|&j| match lookup(j) {
                                Some(p) => p.frac_on(Proc::Cpu),
                                None => base_out_cpu[j],
                            })
                            .collect()
                    };
                    let prev = if i == start {
                        prev_placement_before_start
                    } else {
                        lookup(i - 1)
                    };
                    let (new_run_cpu, new_run_gpu) = match prev {
                        None => (true, true),
                        Some(p) => (!p.uses(Proc::Cpu), !p.uses(Proc::Gpu)),
                    };
                    let ctx = ExecCtx {
                        input_cpu_fracs,
                        new_run_cpu,
                        new_run_gpu,
                        concurrent: false,
                    };
                    let c = model.predict(op, choice, &ctx, snap);

                    // next frontier: in-window ops still live after i, + i
                    let mut nkey: Key = key
                        .iter()
                        .copied()
                        .filter(|&(o, _)| last_use[o as usize] > i)
                        .collect();
                    nkey.push((i as u32, ci as u8));
                    nkey.sort_unstable();

                    let slot = next.entry(nkey).or_default();
                    for pt in pts {
                        let back = arena.len() as u32;
                        arena.push((ci as u8, pt.back));
                        slot.push(Pt {
                            e: pt.e + c.energy_j,
                            t: pt.t + c.latency_s,
                            back,
                        });
                    }
                }
            }
            // prune each state's Pareto set
            for pts in next.values_mut() {
                prune(pts, self.latency_buckets);
            }
            states = next;
        }

        // ---- pick the best terminal point (adding the fixed tail cost,
        // which depends on the final frontier residency)
        let mut best: Option<(f64, Pt, PlanCost)> = None;
        for (key, pts) in &states {
            // residency after the window for the tail evaluation
            let mut out_cpu = base_out_cpu.clone();
            for &(o, c) in key {
                out_cpu[o as usize] = self.choices[c as usize].frac_on(Proc::Cpu);
            }
            // note: ops in the window but dead before `end` don't appear in
            // the key; the tail can't read them either (they're dead).
            let tail = if end < n {
                let prev_pl = key
                    .iter()
                    .find(|&&(o, _)| o as usize == end - 1)
                    .map(|&(_, c)| self.choices[c as usize]);
                self.eval_tail(g, model, snap, end, pinned, &out_cpu, prev_pl)
            } else {
                PlanCost::default()
            };
            for pt in pts {
                let e = pt.e + tail.energy_j;
                let t = pt.t + tail.latency_s;
                let s = self.objective.score(e, t);
                if best.as_ref().map_or(true, |(bs, _, _)| s < *bs) {
                    best = Some((
                        s,
                        *pt,
                        PlanCost {
                            energy_j: e,
                            latency_s: t,
                            transfer_s: 0.0,
                            transfer_j: 0.0,
                        },
                    ));
                }
            }
        }
        let (_, pt, cost) = best.expect("DP produced no states");

        // ---- reconstruct
        let mut placements: Vec<Placement> = if pinned.is_empty() {
            vec![Placement::GPU; n]
        } else {
            pinned.to_vec()
        };
        let mut back = pt.back;
        let mut i = end;
        while back != u32::MAX {
            i -= 1;
            let (ci, parent) = arena[back as usize];
            placements[i] = self.choices[ci as usize];
            back = parent;
        }
        debug_assert_eq!(i, start);
        Ok(RangeSolution { placements, cost })
    }

    /// Cost of the fixed ops `[from, n)` given post-window residencies
    /// (map backend; allocates per op, kept verbatim for the baseline).
    fn eval_tail(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
        from: usize,
        pinned: &[Placement],
        out_cpu: &[f64],
        prev_placement: Option<Placement>,
    ) -> PlanCost {
        let mut out_cpu = out_cpu.to_vec();
        let mut prev = prev_placement;
        let mut total = PlanCost::default();
        for i in from..g.num_ops() {
            let op = &g.ops[i];
            let placement = pinned[i];
            let input_cpu_fracs: Vec<f64> = if op.inputs.is_empty() {
                vec![INPUT_CPU_FRAC; op.in_shapes.len()]
            } else {
                op.inputs.iter().map(|&j| out_cpu[j]).collect()
            };
            let (new_run_cpu, new_run_gpu) = match prev {
                None => (true, true),
                Some(p) => (!p.uses(Proc::Cpu), !p.uses(Proc::Gpu)),
            };
            let ctx = ExecCtx {
                input_cpu_fracs,
                new_run_cpu,
                new_run_gpu,
                concurrent: false,
            };
            let c = model.predict(op, placement, &ctx, snap);
            total.energy_j += c.energy_j;
            total.latency_s += c.latency_s;
            total.transfer_s += c.transfer_s;
            total.transfer_j += c.transfer_j;
            out_cpu[i] = placement.frac_on(Proc::Cpu);
            prev = Some(placement);
        }
        total
    }

    /// Allocation-free twin of [`DpPartitioner::eval_tail`]: mutates the
    /// caller's residency buffer in place and reuses one [`ExecCtx`].
    /// Numerically identical (same predict sequence and accumulation).
    #[allow(clippy::too_many_arguments)]
    fn eval_tail_in(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
        from: usize,
        pinned: &[Placement],
        out_cpu: &mut [f64],
        prev_placement: Option<Placement>,
        ctx: &mut ExecCtx,
    ) -> PlanCost {
        let mut prev = prev_placement;
        let mut total = PlanCost::default();
        for i in from..g.num_ops() {
            let op = &g.ops[i];
            let placement = pinned[i];
            ctx.input_cpu_fracs.clear();
            if op.inputs.is_empty() {
                ctx.input_cpu_fracs.resize(op.in_shapes.len(), INPUT_CPU_FRAC);
            } else {
                for &j in &op.inputs {
                    ctx.input_cpu_fracs.push(out_cpu[j]);
                }
            }
            let (new_run_cpu, new_run_gpu) = match prev {
                None => (true, true),
                Some(p) => (!p.uses(Proc::Cpu), !p.uses(Proc::Gpu)),
            };
            ctx.new_run_cpu = new_run_cpu;
            ctx.new_run_gpu = new_run_gpu;
            ctx.concurrent = false;
            let c = model.predict(op, placement, ctx, snap);
            total.energy_j += c.energy_j;
            total.latency_s += c.latency_s;
            total.transfer_s += c.transfer_s;
            total.transfer_j += c.transfer_j;
            out_cpu[i] = placement.frac_on(Proc::Cpu);
            prev = Some(placement);
        }
        total
    }

    fn eval_fixed(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
        from: usize,
        pinned: &[Placement],
        prev_out_cpu: Option<&[f64]>,
    ) -> PlanCost {
        let n = g.num_ops();
        let out_cpu: Vec<f64> = match prev_out_cpu {
            Some(v) => v.to_vec(),
            None => (0..n)
                .map(|i| {
                    if !pinned.is_empty() {
                        pinned[i].frac_on(Proc::Cpu)
                    } else {
                        INPUT_CPU_FRAC
                    }
                })
                .collect(),
        };
        let prev = if from > 0 && !pinned.is_empty() {
            Some(pinned[from - 1])
        } else {
            None
        };
        self.eval_tail(g, model, snap, from, pinned, &out_cpu, prev)
    }

    /// Scratch-buffer twin of [`DpPartitioner::eval_fixed`]: the
    /// residency vector is built in (and borrowed from) `scratch` instead
    /// of being reallocated on every fixed-tail evaluation.
    fn eval_fixed_in(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
        from: usize,
        pinned: &[Placement],
        prev_out_cpu: Option<&[f64]>,
        scratch: &mut DpScratch,
    ) -> PlanCost {
        let n = g.num_ops();
        let sc = &mut *scratch;
        sc.out_cpu.clear();
        match prev_out_cpu {
            Some(v) => sc.out_cpu.extend_from_slice(v),
            None => sc.out_cpu.extend((0..n).map(|i| {
                if !pinned.is_empty() {
                    pinned[i].frac_on(Proc::Cpu)
                } else {
                    INPUT_CPU_FRAC
                }
            })),
        }
        let prev = if from > 0 && !pinned.is_empty() {
            Some(pinned[from - 1])
        } else {
            None
        };
        self.eval_tail_in(g, model, snap, from, pinned, &mut sc.out_cpu, prev, &mut sc.ctx)
    }
}

/// The pre-lattice reference solver: a rolling `BTreeMap<frontier key,
/// Pareto set>` dynamic program. Kept as the readable specification of
/// the DP, as the differential-testing oracle the lattice backend must
/// match bit for bit, and as the "before" arm of `make bench-dp`.
#[derive(Debug, Clone)]
pub struct MapDpPartitioner(pub DpPartitioner);

impl MapDpPartitioner {
    /// Reference solver with the default candidate set.
    pub fn new(objective: Objective) -> Self {
        MapDpPartitioner(DpPartitioner::new(objective).with_backend(DpBackend::Map))
    }

    /// Solve for a full model; always runs the map core.
    pub fn solve(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
    ) -> Result<Plan> {
        let sol = self.solve_range(g, model, snap, 0, g.num_ops(), &[], None)?;
        Ok(Plan {
            placements: sol.placements,
            predicted: sol.cost,
            policy: "adaoper-map".into(),
        })
    }

    /// Windowed solve; see [`DpPartitioner::solve_range`]. Always runs
    /// the map core, whatever `self.0.backend` says.
    #[allow(clippy::too_many_arguments)]
    pub fn solve_range(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
        start: usize,
        end: usize,
        pinned: &[Placement],
        prev_out_cpu: Option<&[f64]>,
    ) -> Result<RangeSolution> {
        self.0
            .map_solve_range(g, model, snap, start, end, pinned, prev_out_cpu)
    }
}

impl Partitioner for MapDpPartitioner {
    fn name(&self) -> &str {
        "adaoper-map"
    }

    fn partition(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
    ) -> Result<Plan> {
        self.solve(g, model, snap)
    }
}

/// Reusable solver state for the lattice backend: the two CSR column
/// buffers, the decision arena, index/odometer tables, the predict memo,
/// and one [`ExecCtx`]. Owned long-term by the repartition controller so
/// repeated repartitions allocate nothing once the buffers have grown to
/// the working size; `DpScratch::default()` works for one-off solves.
#[derive(Debug, Clone)]
pub struct DpScratch {
    // decision arena: (choice_idx, parent) reconstruction links
    arena: Vec<(u8, u32)>,
    // frontier op ids (ascending) of the previous / next column
    prev_f: Vec<usize>,
    new_f: Vec<usize>,
    // previous-frontier positions surviving the current op / feeding its cost
    surv_pos: Vec<u8>,
    rel_pos: Vec<u8>,
    // mixed-radix odometers (cell enumeration / memo enumeration)
    digits: Vec<u8>,
    mdigits: Vec<u8>,
    // CSR columns: pruned previous column, pre-prune next column
    prev_off: Vec<usize>,
    prev_pts: Vec<Pt>,
    next_off: Vec<usize>,
    next_pts: Vec<Pt>,
    cursor: Vec<usize>,
    // predict memo: (energy_j, latency_s) per (relevant digits, choice)
    cost_tab: Vec<(f64, f64)>,
    // per-slot prune state: run starts, merge cursors, kept points
    runs: Vec<usize>,
    run_cur: Vec<usize>,
    kept: Vec<Pt>,
    // residency buffers: pre-window base, terminal/tail working copy
    base_out: Vec<f64>,
    out_cpu: Vec<f64>,
    // the one execution context reused for every cost-model query
    ctx: ExecCtx,
}

impl DpScratch {
    /// Fresh, empty scratch (buffers grow on first use, then get reused).
    pub fn new() -> Self {
        Self::default()
    }
}

impl Default for DpScratch {
    fn default() -> Self {
        DpScratch {
            arena: Vec::new(),
            prev_f: Vec::new(),
            new_f: Vec::new(),
            surv_pos: Vec::new(),
            rel_pos: Vec::new(),
            digits: Vec::new(),
            mdigits: Vec::new(),
            prev_off: Vec::new(),
            prev_pts: Vec::new(),
            next_off: Vec::new(),
            next_pts: Vec::new(),
            cursor: Vec::new(),
            cost_tab: Vec::new(),
            runs: Vec::new(),
            run_cur: Vec::new(),
            kept: Vec::new(),
            base_out: Vec::new(),
            out_cpu: Vec::new(),
            ctx: ExecCtx {
                input_cpu_fracs: Vec::new(),
                new_run_cpu: true,
                new_run_gpu: true,
                concurrent: false,
            },
        }
    }
}

/// True when every DP column of `[start, end)` fits the dense-lattice
/// cell cap (`choices^frontier_len` cells); `buf` is reused frontier
/// storage. Liveness — and therefore the answer — is independent of any
/// placement choice, so this can run before the solve.
fn lattice_fits(
    last_use: &[usize],
    start: usize,
    end: usize,
    k: usize,
    buf: &mut Vec<usize>,
) -> bool {
    buf.clear();
    for i in start..end {
        buf.retain(|&j| last_use[j] > i);
        buf.push(i);
        match k.checked_pow(buf.len() as u32) {
            Some(c) if c <= LATTICE_CELL_CAP => {}
            _ => return false,
        }
    }
    true
}

/// One cost-model query for op `i` under `choice`, with the placements of
/// the previous frontier ops `prev_f` given by `digits` (only positions
/// of in-window inputs and of op `i-1` are read, so a memo enumeration
/// may leave the other digits at zero). Builds the [`ExecCtx`] in place —
/// identical field by field to the reference solver's per-(state, choice)
/// context — and returns the model's prediction.
#[allow(clippy::too_many_arguments)]
#[inline]
fn predict_one(
    choices: &[Placement],
    op: &OpNode,
    model: &dyn CostModel,
    snap: &Snapshot,
    base_out_cpu: &[f64],
    start: usize,
    i: usize,
    prev_f: &[usize],
    digits: &[u8],
    prev_before_start: Option<Placement>,
    ctx: &mut ExecCtx,
    choice: Placement,
) -> OpCost {
    ctx.input_cpu_fracs.clear();
    if op.inputs.is_empty() {
        ctx.input_cpu_fracs.resize(op.in_shapes.len(), INPUT_CPU_FRAC);
    } else {
        for &j in &op.inputs {
            let frac = if j >= start {
                let p = prev_f.binary_search(&j).expect("input live");
                choices[digits[p] as usize].frac_on(Proc::Cpu)
            } else {
                base_out_cpu[j]
            };
            ctx.input_cpu_fracs.push(frac);
        }
    }
    let prev = if i == start {
        prev_before_start
    } else {
        let p = prev_f.binary_search(&(i - 1)).expect("i-1 live");
        Some(choices[digits[p] as usize])
    };
    let (new_run_cpu, new_run_gpu) = match prev {
        None => (true, true),
        Some(p) => (!p.uses(Proc::Cpu), !p.uses(Proc::Gpu)),
    };
    ctx.new_run_cpu = new_run_cpu;
    ctx.new_run_gpu = new_run_gpu;
    ctx.concurrent = false;
    model.predict(op, choice, ctx, snap)
}

/// Order used throughout pruning: latency first, then energy, via
/// `total_cmp` (a total order, so NaN costs cannot panic the solver).
#[inline]
fn cmp_pt(a: &Pt, b: &Pt) -> Ordering {
    a.t.total_cmp(&b.t).then(a.e.total_cmp(&b.e))
}

/// Advance a mixed-radix (base-`k`) odometer by one, least-significant
/// digit last — ascending odometer order is ascending cell index, which
/// is the reference solver's `BTreeMap` key order.
#[inline]
fn advance(digits: &mut [u8], k: u8) {
    for d in digits.iter_mut().rev() {
        *d += 1;
        if *d < k {
            return;
        }
        *d = 0;
    }
}

/// Advance only the digits at positions `pos` (enumerates the predict
/// memo over the cost-relevant frontier positions).
#[inline]
fn advance_at(digits: &mut [u8], pos: &[u8], k: u8) {
    for &p in pos.iter().rev() {
        let d = &mut digits[p as usize];
        *d += 1;
        if *d < k {
            return;
        }
        *d = 0;
    }
}

/// Pareto-prune one pre-prune lattice slot into `kept`, allocation- and
/// sort-free, with output *identical* to the reference path
/// (`prune(sort_by(t, e) → dominance filter → thinning)`):
///
/// * `seg` is a concatenation of per-source runs, and splitting it into
///   *maximal non-decreasing* runs by (t, e) then k-way merging with ties
///   broken toward the earlier run is natural merge sort — exactly a
///   stable sort by (t, e). (If two true source runs happen to
///   concatenate into one sorted run, treating them as one run emits the
///   same sequence, so detecting run boundaries by order alone is safe.)
/// * The dominance filter (`e < best_e - 1e-15`) is applied to the merged
///   stream in emission order, as `prune` applies it post-sort.
/// * Thinning indexes `kept[b * (len-1) / (buckets-1)]` — `prune`'s exact
///   formula — done in place (source index ≥ destination index always),
///   followed by the same value-equality dedup.
fn merge_prune_slot(
    seg: &[Pt],
    buckets: usize,
    runs: &mut Vec<usize>,
    run_cur: &mut Vec<usize>,
    kept: &mut Vec<Pt>,
) {
    kept.clear();
    if seg.is_empty() {
        return;
    }
    runs.clear();
    runs.push(0);
    for w in 1..seg.len() {
        if cmp_pt(&seg[w - 1], &seg[w]) == Ordering::Greater {
            runs.push(w);
        }
    }
    runs.push(seg.len());
    let nr = runs.len() - 1;
    let mut best_e = f64::INFINITY;
    if nr == 1 {
        // already sorted (the common case once columns are Pareto-thin)
        for p in seg {
            if p.e < best_e - 1e-15 {
                best_e = p.e;
                kept.push(*p);
            }
        }
    } else {
        run_cur.clear();
        run_cur.extend_from_slice(&runs[..nr]);
        loop {
            let mut r = usize::MAX;
            for q in 0..nr {
                if run_cur[q] < runs[q + 1]
                    && (r == usize::MAX
                        || cmp_pt(&seg[run_cur[q]], &seg[run_cur[r]]) == Ordering::Less)
                {
                    r = q;
                }
            }
            if r == usize::MAX {
                break;
            }
            let p = seg[run_cur[r]];
            run_cur[r] += 1;
            if p.e < best_e - 1e-15 {
                best_e = p.e;
                kept.push(p);
            }
        }
    }
    if kept.len() > buckets {
        // keep endpoints + evenly spaced interior points, in place
        let len = kept.len();
        for b in 0..buckets {
            kept[b] = kept[b * (len - 1) / (buckets - 1)];
        }
        kept.truncate(buckets);
        kept.dedup_by(|a, b| a.t == b.t && a.e == b.e);
    }
}

/// Result of a (possibly windowed) DP solve.
#[derive(Debug, Clone)]
pub struct RangeSolution {
    /// Placements for the solved window.
    pub placements: Vec<Placement>,
    /// Cost over `[start, n)` (window + fixed tail), as predicted.
    pub cost: PlanCost,
}

/// Keep the Pareto-optimal (min energy per latency) subset, thinned to at
/// most `buckets` points.
fn prune<P: ParetoPoint>(pts: &mut Vec<P>, buckets: usize) {
    if pts.len() <= 1 {
        return;
    }
    // total_cmp: a NaN cost (e.g. from a degenerate model) must not panic
    // the solver; NaN points sort last and are pruned as dominated
    pts.sort_by(|a, b| a.t().total_cmp(&b.t()).then(a.e().total_cmp(&b.e())));
    let mut kept: Vec<P> = Vec::with_capacity(pts.len());
    let mut best_e = f64::INFINITY;
    for p in pts.iter() {
        if p.e() < best_e - 1e-15 {
            best_e = p.e();
            kept.push(*p);
        }
    }
    if kept.len() > buckets {
        // keep endpoints + evenly spaced interior points
        let mut thinned = Vec::with_capacity(buckets);
        for k in 0..buckets {
            let idx = k * (kept.len() - 1) / (buckets - 1);
            thinned.push(kept[idx]);
        }
        thinned.dedup_by(|a, b| a.t() == b.t() && a.e() == b.e());
        kept = thinned;
    }
    *pts = kept;
}

/// Internal trait so `prune` is testable.
trait ParetoPoint: Copy {
    fn e(&self) -> f64;
    fn t(&self) -> f64;
}

impl ParetoPoint for (f64, f64) {
    fn e(&self) -> f64 {
        self.0
    }
    fn t(&self) -> f64 {
        self.1
    }
}

/// A DP point: accumulated (energy, latency) plus its decision backpointer.
#[derive(Debug, Clone, Copy)]
struct Pt {
    e: f64,
    t: f64,
    back: u32,
}

impl ParetoPoint for Pt {
    fn e(&self) -> f64 {
        self.e
    }
    fn t(&self) -> f64 {
        self.t
    }
}

impl Partitioner for DpPartitioner {
    fn name(&self) -> &str {
        "adaoper"
    }

    fn partition(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
    ) -> Result<Plan> {
        self.solve(g, model, snap)
    }

    fn partition_in(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
        scratch: &mut DpScratch,
    ) -> Result<Plan> {
        self.solve_in(g, model, snap, scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::partition::plan::evaluate;
    use crate::soc::device::{Device, DeviceConfig};
    use crate::workload::WorkloadCondition;

    fn frozen_device(cond: WorkloadCondition) -> Device {
        let mut d = Device::new(DeviceConfig {
            noise_sigma: 0.0,
            drift_sigma: 0.0,
            ..DeviceConfig::snapdragon_855()
        });
        let mut c = cond.spec;
        c.cpu_bg_sigma = 0.0;
        c.cpu_burst = 0.0;
        c.gpu_bg_sigma = 0.0;
        c.gpu_burst = 0.0;
        c.drift_sigma = 0.0;
        d.apply_condition(&c);
        d
    }

    fn assert_cost_bits_eq(a: &PlanCost, b: &PlanCost, what: &str) {
        assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits(), "{what}: energy");
        assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits(), "{what}: latency");
        assert_eq!(a.transfer_s.to_bits(), b.transfer_s.to_bits(), "{what}: transfer_s");
        assert_eq!(a.transfer_j.to_bits(), b.transfer_j.to_bits(), "{what}: transfer_j");
    }

    #[test]
    fn pareto_prune_removes_dominated() {
        let mut pts = vec![(1.0, 5.0), (2.0, 4.0), (3.0, 3.0), (2.5, 3.5), (4.0, 2.9)];
        prune(&mut pts, 64);
        // (2.5,3.5) dominated by (3.0,3.0)? no: 3.0>2.5 energy… sorted by t:
        // (4.0,2.9) (3.0,3.0) (2.5,3.5) (2.0,4.0) (1.0,5.0) — all strictly
        // decreasing energy → all kept
        assert_eq!(pts.len(), 5);
        let mut pts2 = vec![(1.0, 5.0), (1.5, 5.5), (2.0, 6.0)];
        prune(&mut pts2, 64);
        // (1.5,5.5) and (2.0,6.0) dominated by (1.0,5.0)
        assert_eq!(pts2.len(), 1);
    }

    #[test]
    fn pareto_prune_thins_to_buckets() {
        let mut pts: Vec<(f64, f64)> =
            (0..500).map(|i| (500.0 - i as f64, i as f64)).collect();
        prune(&mut pts, 16);
        assert!(pts.len() <= 16);
        // endpoints survive
        assert!(pts.iter().any(|p| p.1 == 0.0));
        assert!(pts.iter().any(|p| p.1 == 499.0));
    }

    #[test]
    fn merge_prune_matches_reference_prune() {
        // random-ish slots (shifted-run structure and adversarial ties)
        // must come out of the merge path exactly as out of sort+prune
        let mut state = 0x9e3779b97f4a7c15u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state >> 11) as f64 / (1u64 << 53) as f64
        };
        for buckets in [2, 4, 64] {
            for trial in 0..50 {
                // build a slot out of 1..=5 sorted runs, as pass 2 would
                let nruns = 1 + trial % 5;
                let mut seg: Vec<Pt> = Vec::new();
                for r in 0..nruns {
                    let mut run: Vec<Pt> = (0..(1 + (trial + r) % 7))
                        .map(|q| Pt {
                            // coarse grid → plenty of exact (t, e) ties
                            e: (next() * 8.0).floor(),
                            t: (next() * 8.0).floor(),
                            back: (r * 100 + q) as u32,
                        })
                        .collect();
                    run.sort_by(cmp_pt);
                    seg.extend(run);
                }
                let mut reference = seg.clone();
                prune(&mut reference, buckets);
                let (mut runs, mut cur, mut kept) = (Vec::new(), Vec::new(), Vec::new());
                merge_prune_slot(&seg, buckets, &mut runs, &mut cur, &mut kept);
                assert_eq!(kept.len(), reference.len(), "trial {trial} buckets {buckets}");
                for (a, b) in kept.iter().zip(&reference) {
                    assert_eq!(a.e.to_bits(), b.e.to_bits());
                    assert_eq!(a.t.to_bits(), b.t.to_bits());
                    // same surviving decision, not just same value
                    assert_eq!(a.back, b.back, "trial {trial} buckets {buckets}");
                }
            }
        }
    }

    #[test]
    fn lattice_cell_cap_guard() {
        // chain: every frontier is one op wide → any sane grid fits
        let chain: Vec<usize> = (1..=6).collect();
        let mut buf = Vec::new();
        assert!(lattice_fits(&chain, 0, 6, 5, &mut buf));
        // op 0 stays live to the end → two-wide frontier; 5^2 fits,
        // 200^2 exceeds the cap and must route to the map solver
        let skip = vec![6, 6, 3, 4, 5, 6];
        assert!(lattice_fits(&skip, 0, 6, 5, &mut buf));
        assert!(!lattice_fits(&skip, 0, 6, 200, &mut buf));
    }

    #[test]
    fn dp_beats_all_baselines_on_its_objective() {
        let d = frozen_device(WorkloadCondition::moderate());
        let snap = d.snapshot();
        for obj in [
            Objective::MinEdp,
            Objective::MinLatency,
            Objective::MinEnergyUnderSlo { slo_s: 0.2 },
        ] {
            for g in [zoo::yolov2(), zoo::yolov2_tiny()] {
                let plan = DpPartitioner::new(obj).solve(&g, &d, &snap).unwrap();
                let dp_cost = evaluate(&g, &plan.placements, &d, &snap);
                for base in [Placement::CPU, Placement::GPU] {
                    let c = evaluate(&g, &vec![base; g.num_ops()], &d, &snap);
                    assert!(
                        obj.score(dp_cost.energy_j, dp_cost.latency_s)
                            <= obj.score(c.energy_j, c.latency_s) * 1.0001,
                        "{}: dp {:?} worse than {base:?} {:?} under {obj:?}",
                        g.name,
                        dp_cost,
                        c
                    );
                }
            }
        }
    }

    #[test]
    fn dp_prediction_matches_evaluate() {
        // the DP's internal accumulation must agree with the shared
        // evaluator (same ctx construction)
        let d = frozen_device(WorkloadCondition::moderate());
        let snap = d.snapshot();
        for g in [zoo::yolov2(), zoo::resnet18(), zoo::mobilenet_v1()] {
            let plan = DpPartitioner::new(Objective::MinEdp)
                .solve(&g, &d, &snap)
                .unwrap();
            let ev = evaluate(&g, &plan.placements, &d, &snap);
            assert!(
                (plan.predicted.energy_j / ev.energy_j - 1.0).abs() < 1e-9,
                "{}: {} vs {}",
                g.name,
                plan.predicted.energy_j,
                ev.energy_j
            );
            assert!((plan.predicted.latency_s / ev.latency_s - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn min_latency_dp_never_slower_than_pure_gpu() {
        let d = frozen_device(WorkloadCondition::high());
        let snap = d.snapshot();
        let g = zoo::yolov2();
        let plan = DpPartitioner::new(Objective::MinLatency)
            .solve(&g, &d, &snap)
            .unwrap();
        let dp = evaluate(&g, &plan.placements, &d, &snap);
        let gpu = evaluate(&g, &vec![Placement::GPU; g.num_ops()], &d, &snap);
        assert!(dp.latency_s <= gpu.latency_s * 1.0001);
    }

    #[test]
    fn slo_constraint_respected_when_feasible() {
        let d = frozen_device(WorkloadCondition::moderate());
        let snap = d.snapshot();
        let g = zoo::yolov2();
        // find an achievable SLO: pure-GPU latency × 1.1
        let gpu = evaluate(&g, &vec![Placement::GPU; g.num_ops()], &d, &snap);
        let slo = gpu.latency_s * 1.1;
        let plan = DpPartitioner::new(Objective::MinEnergyUnderSlo { slo_s: slo })
            .solve(&g, &d, &snap)
            .unwrap();
        let c = evaluate(&g, &plan.placements, &d, &snap);
        assert!(c.latency_s <= slo * 1.001, "{} > {}", c.latency_s, slo);
    }

    #[test]
    fn windowed_solve_only_changes_window() {
        let d = frozen_device(WorkloadCondition::moderate());
        let snap = d.snapshot();
        let g = zoo::yolov2();
        let base = vec![Placement::GPU; g.num_ops()];
        let dp = DpPartitioner::new(Objective::MinEdp);
        let sol = dp
            .solve_range(&g, &d, &snap, 5, 12, &base, None)
            .unwrap();
        for i in 0..g.num_ops() {
            if !(5..12).contains(&i) {
                assert_eq!(sol.placements[i], base[i], "op {i} changed outside window");
            }
        }
    }

    #[test]
    fn dag_models_solve_without_panic() {
        let d = frozen_device(WorkloadCondition::high());
        let snap = d.snapshot();
        for g in [zoo::yolov2(), zoo::resnet18()] {
            let plan = DpPartitioner::new(Objective::MinEdp).solve(&g, &d, &snap).unwrap();
            assert_eq!(plan.placements.len(), g.num_ops());
        }
    }

    #[test]
    fn lattice_matches_map_bit_for_bit_on_full_solves() {
        for cond in [WorkloadCondition::moderate(), WorkloadCondition::high()] {
            let d = frozen_device(cond);
            let snap = d.snapshot();
            for obj in [
                Objective::MinEdp,
                Objective::MinLatency,
                Objective::MinEnergyUnderSlo { slo_s: 0.05 },
            ] {
                for g in [zoo::yolov2(), zoo::yolov2_tiny(), zoo::resnet18()] {
                    let lat = DpPartitioner::new(obj).solve(&g, &d, &snap).unwrap();
                    let map = MapDpPartitioner::new(obj).solve(&g, &d, &snap).unwrap();
                    assert_eq!(
                        lat.placements, map.placements,
                        "{} under {obj:?}: plans diverge",
                        g.name
                    );
                    assert_cost_bits_eq(&lat.predicted, &map.predicted, &g.name);
                }
            }
        }
    }

    #[test]
    fn lattice_matches_map_on_pinned_windows() {
        let d = frozen_device(WorkloadCondition::moderate());
        let snap = d.snapshot();
        let g = zoo::yolov2();
        let n = g.num_ops();
        let pinned: Vec<Placement> = (0..n)
            .map(|i| if i % 2 == 0 { Placement::GPU } else { Placement::CPU })
            .collect();
        let residency: Vec<f64> = (0..n).map(|i| (i % 3) as f64 * 0.5).collect();
        let lat = DpPartitioner::new(Objective::MinEdp);
        let map = MapDpPartitioner::new(Objective::MinEdp);
        for (start, end) in [(0, 5), (5, 12), (3, n), (0, n), (7, 7), (n, n)] {
            for prev in [None, Some(&residency[..])] {
                let a = lat
                    .solve_range(&g, &d, &snap, start, end, &pinned, prev)
                    .unwrap();
                let b = map
                    .solve_range(&g, &d, &snap, start, end, &pinned, prev)
                    .unwrap();
                assert_eq!(
                    a.placements, b.placements,
                    "window [{start},{end}) prev={} diverged",
                    prev.is_some()
                );
                assert_cost_bits_eq(&a.cost, &b.cost, &format!("window [{start},{end})"));
            }
        }
    }

    #[test]
    fn scratch_reuse_is_deterministic() {
        // a warm scratch (grown buffers, stale contents) must not change
        // any result
        let d = frozen_device(WorkloadCondition::high());
        let snap = d.snapshot();
        let dp = DpPartitioner::new(Objective::MinEdp);
        let mut scratch = DpScratch::new();
        for g in [zoo::yolov2(), zoo::resnet18(), zoo::yolov2_tiny()] {
            let cold = dp.solve(&g, &d, &snap).unwrap();
            let warm1 = dp.solve_in(&g, &d, &snap, &mut scratch).unwrap();
            let warm2 = dp.solve_in(&g, &d, &snap, &mut scratch).unwrap();
            assert_eq!(cold.placements, warm1.placements, "{}", g.name);
            assert_eq!(warm1.placements, warm2.placements, "{}", g.name);
            assert_cost_bits_eq(&cold.predicted, &warm1.predicted, &g.name);
            assert_cost_bits_eq(&warm1.predicted, &warm2.predicted, &g.name);
        }
    }
}
