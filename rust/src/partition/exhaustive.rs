//! Brute-force oracle: enumerate every placement combination and score it
//! with the shared evaluator. Exponential — usable only for small graphs —
//! and exists solely to property-test the DP's optimality.

use anyhow::{ensure, Result};

use crate::graph::ModelGraph;
use crate::profiler::CostModel;
use crate::soc::device::Snapshot;
use crate::soc::Placement;

use super::plan::{evaluate, Objective, Partitioner, Plan};

/// Exhaustive-search partitioner (oracle).
#[derive(Debug, Clone)]
pub struct ExhaustivePartitioner {
    /// Optimization objective of the search.
    pub objective: Objective,
    /// Candidate placements considered per op.
    pub choices: Vec<Placement>,
    /// Refuse graphs where `choices^n` exceeds this.
    pub max_combos: u64,
}

impl ExhaustivePartitioner {
    /// Build with a combo-count guard of 2e7.
    pub fn new(objective: Objective, choices: Vec<Placement>) -> Self {
        ExhaustivePartitioner {
            objective,
            choices,
            max_combos: 20_000_000,
        }
    }
}

impl Partitioner for ExhaustivePartitioner {
    fn name(&self) -> &str {
        "exhaustive"
    }

    fn partition(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
    ) -> Result<Plan> {
        let n = g.num_ops();
        let k = self.choices.len() as u64;
        let combos = k.checked_pow(n as u32).unwrap_or(u64::MAX);
        ensure!(
            combos <= self.max_combos,
            "exhaustive search infeasible: {k}^{n} combinations"
        );
        let mut placements = vec![self.choices[0]; n];
        let mut best: Option<(f64, Vec<Placement>, super::plan::PlanCost)> = None;
        let mut idx = vec![0usize; n];
        loop {
            for i in 0..n {
                placements[i] = self.choices[idx[i]];
            }
            let c = evaluate(g, &placements, model, snap);
            let s = self.objective.score(c.energy_j, c.latency_s);
            if best.as_ref().map_or(true, |(bs, _, _)| s < *bs) {
                best = Some((s, placements.clone(), c));
            }
            // odometer increment
            let mut carry = 0;
            loop {
                idx[carry] += 1;
                if idx[carry] < self.choices.len() {
                    break;
                }
                idx[carry] = 0;
                carry += 1;
                if carry == n {
                    let (_, placements, predicted) = best.unwrap();
                    return Ok(Plan {
                        placements,
                        predicted,
                        policy: "exhaustive".into(),
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::graph::{GraphBuilder, Src};
    use crate::graph::op::{ActKind, OpKind};
    use crate::graph::Shape;
    use crate::soc::device::{Device, DeviceConfig};
    use crate::workload::WorkloadCondition;

    fn tiny_chain(n: usize) -> crate::graph::ModelGraph {
        let mut b = GraphBuilder::new("chain", Shape::nchw(1, 8, 32, 32));
        let mut prev = Src::Input;
        for i in 0..n {
            let id = b.push(
                &format!("c{i}"),
                OpKind::Conv2d {
                    kernel: 3,
                    stride: 1,
                    pad: 1,
                    out_c: 8 + 8 * (i % 3),
                    groups: 1,
                    act: ActKind::Relu,
                },
                &[prev],
            );
            prev = Src::Op(id);
        }
        b.build()
    }

    fn frozen() -> Device {
        let mut d = Device::new(DeviceConfig {
            noise_sigma: 0.0,
            drift_sigma: 0.0,
            ..DeviceConfig::snapdragon_855()
        });
        let mut c = WorkloadCondition::moderate().spec;
        c.cpu_bg_sigma = 0.0;
        c.cpu_burst = 0.0;
        c.gpu_bg_sigma = 0.0;
        c.gpu_burst = 0.0;
        c.drift_sigma = 0.0;
        d.apply_condition(&c);
        d
    }

    #[test]
    fn finds_known_optimum_on_trivial_instance() {
        let g = tiny_chain(3);
        let d = frozen();
        let snap = d.snapshot();
        let ex = ExhaustivePartitioner::new(
            Objective::MinLatency,
            vec![Placement::CPU, Placement::GPU],
        );
        let plan = ex.partition(&g, &d, &snap).unwrap();
        // verify against manual enumeration of all 8 combos
        let mut best = f64::INFINITY;
        for mask in 0..8u32 {
            let pl: Vec<Placement> = (0..3)
                .map(|i| {
                    if mask >> i & 1 == 1 {
                        Placement::GPU
                    } else {
                        Placement::CPU
                    }
                })
                .collect();
            best = best.min(evaluate(&g, &pl, &d, &snap).latency_s);
        }
        assert!((plan.predicted.latency_s - best).abs() < 1e-12);
    }

    #[test]
    fn rejects_oversized_search() {
        let g = tiny_chain(40);
        let d = frozen();
        let ex = ExhaustivePartitioner::new(
            Objective::MinEdp,
            vec![Placement::CPU, Placement::GPU],
        );
        assert!(ex.partition(&g, &d, &d.snapshot()).is_err());
    }
}
