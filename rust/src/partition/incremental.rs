//! Incremental (windowed) repartitioning — the paper's fast-adaptation
//! path: "refining the redistribution of partial operators triggered by
//! fluctuations in energy consumption, rather than the entire model."
//!
//! When the profiler flags drift mid-plan, only a window of `W` operators
//! starting at the execution frontier is re-solved; everything already
//! executed is sunk cost and everything far downstream keeps its placement
//! (it will be revisited when the frontier reaches it). The windowed DP
//! pins the boundary states, so the patched plan stays consistent
//! (residency + dispatch runs) with both the executed prefix and the
//! retained tail.

use anyhow::Result;

use crate::graph::ModelGraph;
use crate::profiler::CostModel;
use crate::soc::device::Snapshot;

use super::dp::{DpPartitioner, DpScratch};
use super::plan::Plan;

/// Windowed repartitioner wrapping the DP.
#[derive(Debug, Clone)]
pub struct IncrementalRepartitioner {
    /// The DP solver used on each window.
    pub dp: DpPartitioner,
    /// Number of operators re-solved per trigger.
    pub window: usize,
}

impl IncrementalRepartitioner {
    /// Wrap a DP solver with a re-solve window of `window` ops.
    pub fn new(dp: DpPartitioner, window: usize) -> Self {
        assert!(window >= 1);
        IncrementalRepartitioner { dp, window }
    }

    /// Re-solve `[frontier, frontier+window)` of `plan` under the current
    /// cost model/state. `out_cpu` optionally carries the *actual*
    /// residency of already-produced outputs (from the executor).
    pub fn repartition(
        &self,
        g: &ModelGraph,
        plan: &Plan,
        frontier: usize,
        model: &dyn CostModel,
        snap: &Snapshot,
        out_cpu: Option<&[f64]>,
    ) -> Result<Plan> {
        let mut scratch = DpScratch::default();
        self.repartition_in(g, plan, frontier, model, snap, out_cpu, &mut scratch)
    }

    /// [`IncrementalRepartitioner::repartition`] with caller-owned solver
    /// scratch: the repartition controller keeps one [`DpScratch`] alive
    /// so steady-state window repairs allocate nothing.
    #[allow(clippy::too_many_arguments)]
    pub fn repartition_in(
        &self,
        g: &ModelGraph,
        plan: &Plan,
        frontier: usize,
        model: &dyn CostModel,
        snap: &Snapshot,
        out_cpu: Option<&[f64]>,
        scratch: &mut DpScratch,
    ) -> Result<Plan> {
        let n = g.num_ops();
        if frontier >= n {
            return Ok(plan.clone());
        }
        let end = (frontier + self.window).min(n);
        let sol = self.dp.solve_range_in(
            g,
            model,
            snap,
            frontier,
            end,
            &plan.placements,
            out_cpu,
            scratch,
        )?;
        Ok(Plan {
            placements: sol.placements,
            predicted: sol.cost,
            policy: plan.policy.clone(),
        })
    }

    /// Predicted cost of *keeping* the current plan from `frontier` on
    /// (the comparison baseline for repartition hysteresis).
    pub fn remaining_cost(
        &self,
        g: &ModelGraph,
        plan: &Plan,
        frontier: usize,
        model: &dyn CostModel,
        snap: &Snapshot,
        out_cpu: Option<&[f64]>,
    ) -> Result<crate::partition::plan::PlanCost> {
        let mut scratch = DpScratch::default();
        self.remaining_cost_in(g, plan, frontier, model, snap, out_cpu, &mut scratch)
    }

    /// [`IncrementalRepartitioner::remaining_cost`] with caller-owned
    /// solver scratch (see [`IncrementalRepartitioner::repartition_in`]).
    #[allow(clippy::too_many_arguments)]
    pub fn remaining_cost_in(
        &self,
        g: &ModelGraph,
        plan: &Plan,
        frontier: usize,
        model: &dyn CostModel,
        snap: &Snapshot,
        out_cpu: Option<&[f64]>,
        scratch: &mut DpScratch,
    ) -> Result<crate::partition::plan::PlanCost> {
        let sol = self.dp.solve_range_in(
            g,
            model,
            snap,
            frontier,
            frontier, // empty window → pure fixed-tail evaluation
            &plan.placements,
            out_cpu,
            scratch,
        )?;
        Ok(sol.cost)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::partition::plan::{evaluate, Objective};
    use crate::soc::device::{Device, DeviceConfig};
    use crate::soc::Placement;
    use crate::workload::WorkloadCondition;

    fn frozen(cond: WorkloadCondition) -> Device {
        let mut d = Device::new(DeviceConfig {
            noise_sigma: 0.0,
            drift_sigma: 0.0,
            ..DeviceConfig::snapdragon_855()
        });
        let mut c = cond.spec;
        c.cpu_bg_sigma = 0.0;
        c.cpu_burst = 0.0;
        c.gpu_bg_sigma = 0.0;
        c.gpu_burst = 0.0;
        c.drift_sigma = 0.0;
        d.apply_condition(&c);
        d
    }

    #[test]
    fn repartition_improves_stale_plan() {
        // Plan under moderate, then conditions switch to high: the window
        // repair at the frontier should not be worse than the stale plan
        // (as scored from the frontier on).
        let g = zoo::yolov2();
        let d_mod = frozen(WorkloadCondition::moderate());
        let dp = DpPartitioner::new(Objective::MinEdp);
        let stale = dp.solve(&g, &d_mod, &d_mod.snapshot()).unwrap();

        let d_high = frozen(WorkloadCondition::high());
        let snap = d_high.snapshot();
        let inc = IncrementalRepartitioner::new(dp.clone(), 8);
        let patched = inc
            .repartition(&g, &stale, 0, &d_high, &snap, None)
            .unwrap();
        let stale_cost = evaluate(&g, &stale.placements, &d_high, &snap);
        let patched_cost = evaluate(&g, &patched.placements, &d_high, &snap);
        assert!(
            patched_cost.edp() <= stale_cost.edp() * 1.0001,
            "patched {patched_cost:?} vs stale {stale_cost:?}"
        );
    }

    #[test]
    fn only_window_changes() {
        let g = zoo::yolov2();
        let d = frozen(WorkloadCondition::moderate());
        let snap = d.snapshot();
        let plan = Plan {
            placements: vec![Placement::GPU; g.num_ops()],
            predicted: Default::default(),
            policy: "test".into(),
        };
        let inc =
            IncrementalRepartitioner::new(DpPartitioner::new(Objective::MinEdp), 4);
        let patched = inc.repartition(&g, &plan, 10, &d, &snap, None).unwrap();
        for i in 0..g.num_ops() {
            if !(10..14).contains(&i) {
                assert_eq!(patched.placements[i], plan.placements[i], "op {i}");
            }
        }
    }

    #[test]
    fn frontier_past_end_is_noop() {
        let g = zoo::yolov2_tiny();
        let d = frozen(WorkloadCondition::moderate());
        let snap = d.snapshot();
        let plan = Plan {
            placements: vec![Placement::GPU; g.num_ops()],
            predicted: Default::default(),
            policy: "test".into(),
        };
        let inc =
            IncrementalRepartitioner::new(DpPartitioner::new(Objective::MinEdp), 4);
        let patched = inc
            .repartition(&g, &plan, g.num_ops(), &d, &snap, None)
            .unwrap();
        assert_eq!(patched.placements, plan.placements);
    }

    #[test]
    fn window_clamps_at_model_end() {
        let g = zoo::yolov2_tiny();
        let d = frozen(WorkloadCondition::high());
        let snap = d.snapshot();
        let plan = Plan {
            placements: vec![Placement::CPU; g.num_ops()],
            predicted: Default::default(),
            policy: "test".into(),
        };
        let inc =
            IncrementalRepartitioner::new(DpPartitioner::new(Objective::MinEdp), 100);
        let patched = inc
            .repartition(&g, &plan, g.num_ops() - 3, &d, &snap, None)
            .unwrap();
        assert_eq!(patched.placements.len(), g.num_ops());
        // prefix untouched
        for i in 0..g.num_ops() - 3 {
            assert_eq!(patched.placements[i], Placement::CPU);
        }
    }
}
