//! Energy-aware operator partitioning (paper §2.2 — the system's core
//! contribution) plus every comparator the evaluation needs.
//!
//! * [`plan`] — partition plans and the shared cost walker/evaluator that
//!   every policy and the coordinator agree on.
//! * [`dp`] — AdaOper's partitioner: bottom-up iterative dynamic program
//!   over the operator DAG frontier with Pareto (energy, latency) states,
//!   rolling storage (only the previous DP column is kept — the paper's
//!   space optimization), and latency-bucket pruning. Two bit-identical
//!   backends: the dense flattened-lattice fast path (default, zero
//!   steady-state allocation via [`dp::DpScratch`]) and the reference
//!   rolling-map solver kept as [`dp::MapDpPartitioner`].
//! * [`incremental`] — windowed repartitioning: on energy-drift triggers
//!   only a bounded window of operators around the execution frontier is
//!   re-solved (the paper's "redistribution of partial operators").
//! * [`codl`] — the CoDL baseline: per-operator latency-optimal CPU+GPU
//!   co-execution with a frequency-aware but burst-blind latency model.
//! * [`baselines`] — MACE-on-GPU, all-CPU, greedy-energy, random.
//! * [`exhaustive`] — brute-force oracle for optimality property tests.

pub mod baselines;
pub mod codl;
pub mod dp;
pub mod exhaustive;
pub mod incremental;
pub mod plan;

pub use dp::{DpBackend, DpPartitioner, DpScratch, MapDpPartitioner};
pub use plan::{evaluate, Objective, Partitioner, Plan, PlanCost};
