//! Partition plans and the shared plan evaluator.
//!
//! The evaluator is the single source of truth for "what does executing
//! this plan cost": the DP, the exhaustive oracle, every baseline and the
//! coordinator all walk plans through the same context construction
//! (input residency, dispatch-run boundaries), so their numbers are
//! directly comparable.

use crate::graph::{ModelGraph, OpId};
use crate::profiler::CostModel;
use crate::soc::device::{ExecCtx, OpCost, Snapshot};
use crate::soc::{Placement, Proc};

/// Optimization objective for planning.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Objective {
    /// Minimize energy × latency (performance per energy unit — the
    /// AdaOper default).
    MinEdp,
    /// Minimize energy subject to a latency SLO.
    MinEnergyUnderSlo {
        /// The latency bound, seconds.
        slo_s: f64,
    },
    /// Minimize latency (what CoDL optimizes).
    MinLatency,
}

impl Objective {
    /// Scalar score (lower = better) of an (energy, latency) point.
    /// SLO violations get an additive penalty so infeasible plans order
    /// behind every feasible one but remain comparable among themselves.
    pub fn score(&self, energy_j: f64, latency_s: f64) -> f64 {
        match *self {
            Objective::MinEdp => energy_j * latency_s,
            Objective::MinEnergyUnderSlo { slo_s } => {
                if latency_s <= slo_s {
                    energy_j
                } else {
                    energy_j + 1e6 * (latency_s - slo_s)
                }
            }
            Objective::MinLatency => latency_s,
        }
    }
}

/// A complete partition plan for one model.
#[derive(Debug, Clone)]
pub struct Plan {
    /// Placement per operator (indexed by `OpId`).
    pub placements: Vec<Placement>,
    /// Planner's own cost prediction.
    pub predicted: PlanCost,
    /// Which policy produced it (reporting).
    pub policy: String,
}

/// Aggregate cost of a plan (predicted or measured).
#[derive(Debug, Clone, Copy, Default)]
pub struct PlanCost {
    /// Dynamic energy, joules.
    pub energy_j: f64,
    /// End-to-end latency, seconds.
    pub latency_s: f64,
    /// Transfer time included in the latency, seconds.
    pub transfer_s: f64,
    /// Transfer energy included in the energy, joules.
    pub transfer_j: f64,
}

impl PlanCost {
    /// Energy-delay product (the AdaOper default score).
    pub fn edp(&self) -> f64 {
        self.energy_j * self.latency_s
    }
}

/// A partitioning policy.
pub trait Partitioner {
    /// Policy name (reports).
    fn name(&self) -> &str;
    /// Produce a full plan for `g` under the given cost model and state.
    fn partition(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
    ) -> anyhow::Result<Plan>;
    /// [`Partitioner::partition`] with caller-owned solver scratch, so
    /// policies that can reuse buffers (the lattice DP) allocate nothing
    /// on repeated replans. The default ignores the scratch — baselines
    /// have no reusable state.
    fn partition_in(
        &self,
        g: &ModelGraph,
        model: &dyn CostModel,
        snap: &Snapshot,
        scratch: &mut super::dp::DpScratch,
    ) -> anyhow::Result<Plan> {
        let _ = scratch;
        self.partition(g, model, snap)
    }
}

/// Walks a graph in topo order producing the per-op [`ExecCtx`] implied by
/// a placement assignment. Used by the evaluator, the DP transitions and
/// the coordinator's executor so they all agree.
pub struct CtxWalker<'g> {
    g: &'g ModelGraph,
    /// CPU-resident fraction of each op's output (filled as we walk).
    out_cpu: Vec<f64>,
    prev_placement: Option<Placement>,
}

/// Where the model input tensor starts. Camera/decoder buffers are
/// CPU-visible on phones, so graph inputs are fully CPU-resident.
pub const INPUT_CPU_FRAC: f64 = 1.0;

impl<'g> CtxWalker<'g> {
    /// Start a walk at op 0 with graph inputs CPU-resident.
    pub fn new(g: &'g ModelGraph) -> Self {
        CtxWalker {
            g,
            out_cpu: vec![INPUT_CPU_FRAC; g.num_ops()],
            prev_placement: None,
        }
    }

    /// Build the context for op `i` under `placement`, then record its
    /// residency. Must be called for i = 0, 1, 2, … in order.
    pub fn step(&mut self, i: OpId, placement: Placement) -> ExecCtx {
        let op = &self.g.ops[i];
        let input_cpu_fracs: Vec<f64> = if op.inputs.is_empty() {
            vec![INPUT_CPU_FRAC; op.in_shapes.len()]
        } else {
            op.inputs.iter().map(|&j| self.out_cpu[j]).collect()
        };
        let (new_run_cpu, new_run_gpu) = match self.prev_placement {
            None => (true, true),
            Some(prev) => (!prev.uses(Proc::Cpu), !prev.uses(Proc::Gpu)),
        };
        self.out_cpu[i] = placement.frac_on(Proc::Cpu);
        self.prev_placement = Some(placement);
        ExecCtx {
            input_cpu_fracs,
            new_run_cpu,
            new_run_gpu,
            concurrent: false,
        }
    }
}

/// Evaluate a placement assignment under a cost model. Ops execute
/// sequentially (single-request inference, the mobile-engine convention);
/// a `Split` op's two halves run concurrently inside the op.
pub fn evaluate(
    g: &ModelGraph,
    placements: &[Placement],
    model: &dyn CostModel,
    snap: &Snapshot,
) -> PlanCost {
    assert_eq!(placements.len(), g.num_ops());
    let mut walker = CtxWalker::new(g);
    let mut total = PlanCost::default();
    for (i, op) in g.ops.iter().enumerate() {
        let ctx = walker.step(i, placements[i]);
        let c: OpCost = model.predict(op, placements[i], &ctx, snap);
        total.energy_j += c.energy_j;
        total.latency_s += c.latency_s;
        total.transfer_s += c.transfer_s;
        total.transfer_j += c.transfer_j;
    }
    total
}

/// Evaluate a placement assignment for a *batch* of `batch` co-dispatched
/// requests: every op is priced through
/// [`crate::profiler::CostModel::predict_batch`] (transfer per member,
/// sub-linear compute growth, dispatch paid once), summed over the model.
/// The returned cost is the **full batch's** cost — divide `energy_j` by
/// `batch` for the per-request amortized figure; `latency_s` is what every
/// member experiences (batched requests complete together). With
/// `batch <= 1` this equals [`evaluate`].
pub fn evaluate_batched(
    g: &ModelGraph,
    placements: &[Placement],
    model: &dyn CostModel,
    snap: &Snapshot,
    batch: usize,
) -> PlanCost {
    assert_eq!(placements.len(), g.num_ops());
    let mut walker = CtxWalker::new(g);
    let mut total = PlanCost::default();
    for (i, op) in g.ops.iter().enumerate() {
        let ctx = walker.step(i, placements[i]);
        let c: OpCost = model.predict_batch(op, placements[i], &ctx, snap, batch.max(1));
        total.energy_j += c.energy_j;
        total.latency_s += c.latency_s;
        total.transfer_s += c.transfer_s;
        total.transfer_j += c.transfer_j;
    }
    total
}

/// Predicted latency of each op of a placement assignment, in execution
/// order, under the same context construction as [`evaluate`]. The
/// coordinator's scheduler builds per-request slack and backlog estimates
/// from the suffix sums of this vector.
pub fn per_op_latencies(
    g: &ModelGraph,
    placements: &[Placement],
    model: &dyn CostModel,
    snap: &Snapshot,
) -> Vec<f64> {
    assert_eq!(placements.len(), g.num_ops());
    let mut walker = CtxWalker::new(g);
    g.ops
        .iter()
        .enumerate()
        .map(|(i, op)| {
            let ctx = walker.step(i, placements[i]);
            model.predict(op, placements[i], &ctx, snap).latency_s
        })
        .collect()
}

/// Helper: uniform single-processor plan.
pub fn uniform_plan(g: &ModelGraph, p: Placement, policy: &str) -> Plan {
    Plan {
        placements: vec![p; g.num_ops()],
        predicted: PlanCost::default(),
        policy: policy.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::soc::device::{Device, DeviceConfig};
    use crate::workload::WorkloadCondition;

    fn dev() -> Device {
        let mut d = Device::new(DeviceConfig {
            noise_sigma: 0.0,
            drift_sigma: 0.0,
            ..DeviceConfig::snapdragon_855()
        });
        let mut c = WorkloadCondition::moderate().spec;
        c.cpu_bg_sigma = 0.0;
        c.cpu_burst = 0.0;
        c.gpu_bg_sigma = 0.0;
        c.gpu_burst = 0.0;
        c.drift_sigma = 0.0;
        d.apply_condition(&c);
        d
    }

    #[test]
    fn objective_scores() {
        assert_eq!(Objective::MinEdp.score(2.0, 3.0), 6.0);
        assert_eq!(Objective::MinLatency.score(2.0, 3.0), 3.0);
        let slo = Objective::MinEnergyUnderSlo { slo_s: 0.1 };
        assert_eq!(slo.score(2.0, 0.05), 2.0);
        assert!(slo.score(2.0, 0.2) > 1000.0);
    }

    #[test]
    fn all_gpu_beats_all_cpu_on_yolov2() {
        let g = zoo::yolov2();
        let d = dev();
        let snap = d.snapshot();
        let gpu = evaluate(&g, &vec![Placement::GPU; g.num_ops()], &d, &snap);
        let cpu = evaluate(&g, &vec![Placement::CPU; g.num_ops()], &d, &snap);
        assert!(gpu.latency_s < cpu.latency_s);
        assert!(gpu.energy_j < cpu.energy_j);
        // magnitudes sane: tens of ms, tens–hundreds of mJ
        assert!((0.02..0.5).contains(&gpu.latency_s), "{}", gpu.latency_s);
        assert!((0.01..2.0).contains(&gpu.energy_j), "{}", gpu.energy_j);
    }

    #[test]
    fn ping_pong_plan_pays_transfers() {
        let g = zoo::yolov2_tiny();
        let d = dev();
        let snap = d.snapshot();
        let alternating: Vec<Placement> = (0..g.num_ops())
            .map(|i| if i % 2 == 0 { Placement::CPU } else { Placement::GPU })
            .collect();
        let alt = evaluate(&g, &alternating, &d, &snap);
        let gpu = evaluate(&g, &vec![Placement::GPU; g.num_ops()], &d, &snap);
        assert!(alt.transfer_s > gpu.transfer_s);
        assert!(alt.latency_s > gpu.latency_s);
    }

    #[test]
    fn walker_first_op_pays_input_transfer_to_gpu() {
        let g = zoo::yolov2_tiny();
        let mut w = CtxWalker::new(&g);
        let ctx = w.step(0, Placement::GPU);
        assert_eq!(ctx.input_cpu_fracs, vec![1.0]); // camera buffer on CPU
        assert!(ctx.new_run_cpu && ctx.new_run_gpu);
    }

    #[test]
    fn walker_tracks_runs_and_residency() {
        let g = zoo::yolov2_tiny();
        let mut w = CtxWalker::new(&g);
        let _ = w.step(0, Placement::GPU);
        let c1 = w.step(1, Placement::GPU);
        assert!(!c1.new_run_gpu, "second GPU op continues the run");
        assert!(c1.new_run_cpu);
        assert_eq!(c1.input_cpu_fracs, vec![0.0]); // op0 output on GPU
        let c2 = w.step(2, Placement::CPU);
        assert!(c2.new_run_cpu);
        assert_eq!(c2.input_cpu_fracs, vec![0.0]);
    }

    #[test]
    fn walker_handles_skip_edges() {
        let g = zoo::yolov2();
        let mut w = CtxWalker::new(&g);
        let route_id = g.ops.iter().find(|o| o.name == "route").unwrap().id;
        let mut route_ctx = None;
        for i in 0..g.num_ops() {
            // everything on GPU except the reorg branch on CPU
            let p = if g.ops[i].name == "reorg" || g.ops[i].name == "conv21" {
                Placement::CPU
            } else {
                Placement::GPU
            };
            let ctx = w.step(i, p);
            if i == route_id {
                route_ctx = Some(ctx);
            }
        }
        let ctx = route_ctx.unwrap();
        // route consumes reorg (CPU) and conv20 (GPU)
        assert_eq!(ctx.input_cpu_fracs, vec![1.0, 0.0]);
    }

    #[test]
    fn evaluate_batched_amortizes_but_grows_latency() {
        let g = zoo::yolov2_tiny();
        let d = dev();
        let snap = d.snapshot();
        let p = vec![Placement::GPU; g.num_ops()];
        let one = evaluate_batched(&g, &p, &d, &snap, 1);
        let base = evaluate(&g, &p, &d, &snap);
        assert_eq!(one.latency_s.to_bits(), base.latency_s.to_bits());
        assert_eq!(one.energy_j.to_bits(), base.energy_j.to_bits());
        let four = evaluate_batched(&g, &p, &d, &snap, 4);
        assert!(four.latency_s > base.latency_s);
        assert!(four.latency_s < 4.0 * base.latency_s);
        assert!(four.energy_j / 4.0 < base.energy_j, "no amortization");
    }

    #[test]
    fn per_op_latencies_sum_matches_evaluate() {
        let g = zoo::yolov2_tiny();
        let d = dev();
        let snap = d.snapshot();
        let p = vec![Placement::GPU; g.num_ops()];
        let per = per_op_latencies(&g, &p, &d, &snap);
        assert_eq!(per.len(), g.num_ops());
        assert!(per.iter().all(|&l| l > 0.0));
        let sum: f64 = per.iter().sum();
        let total = evaluate(&g, &p, &d, &snap);
        assert!((sum - total.latency_s).abs() < 1e-9);
    }

    #[test]
    fn evaluate_is_deterministic() {
        let g = zoo::yolov2();
        let d = dev();
        let snap = d.snapshot();
        let p = vec![Placement::GPU; g.num_ops()];
        let a = evaluate(&g, &p, &d, &snap);
        let b = evaluate(&g, &p, &d, &snap);
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(a.latency_s, b.latency_s);
    }
}
