//! Offline calibration: sweep the device across operators × units ×
//! pinned states, record measured energy/latency, and fit *per-unit*
//! GBDT pairs (CPU latency/energy, GPU latency/energy).
//!
//! Per-unit modeling is the structure both CoDL's predictors and AdaOper's
//! profiler use: a split placement's cost is *composed* from the unit
//! models (max of unit times + sync, sum of unit energies) rather than
//! learned monolithically — far more sample-efficient, and it exposes the
//! energy/latency tradeoff smoothly across split ratios. Dispatch
//! overheads are measured separately (they are fixed per-unit constants on
//! a given engine build) and subtracted from the training targets, so the
//! GBDTs learn pure compute cost.
//!
//! This is the simulator-world equivalent of profiling a phone on a power
//! bench: drift is disabled (a rig is controlled), measurement noise is
//! not.

use crate::graph::{zoo, ModelGraph, OpNode};
use crate::soc::device::{ConditionSpec, Device, DeviceConfig, ExecCtx};
use crate::soc::{Placement, Proc};
use crate::util::Prng;

use super::features;
use super::gbdt::{Gbdt, GbdtParams};

/// One calibration record (single-unit execution, dispatch removed).
#[derive(Debug, Clone)]
pub struct Sample {
    /// Unit the sample executed on.
    pub proc: Proc,
    /// Operational feature vector (see [`crate::profiler::features`]).
    pub features: Vec<f32>,
    /// Compute-only energy, joules.
    pub energy_j: f64,
    /// Compute-only latency, seconds.
    pub latency_s: f64,
}

/// Per-unit fitted models (targets in log space).
#[derive(Debug, Clone)]
pub struct UnitModel {
    /// log-latency regressor.
    pub latency: Gbdt,
    /// log-energy regressor.
    pub energy: Gbdt,
}

/// The offline model pair for both units.
#[derive(Debug, Clone)]
pub struct OfflineModel {
    /// CPU-cluster models.
    pub cpu: UnitModel,
    /// GPU models.
    pub gpu: UnitModel,
}

/// Calibration sweep configuration.
#[derive(Debug, Clone)]
pub struct CalibConfig {
    /// Number of sweep samples to generate.
    pub samples: usize,
    /// Sweep seed.
    pub seed: u64,
    /// GBDT training hyperparameters.
    pub gbdt: GbdtParams,
}

impl Default for CalibConfig {
    fn default() -> Self {
        CalibConfig {
            samples: 6000,
            seed: 42,
            gbdt: GbdtParams::default(),
        }
    }
}

/// Models whose operators the sweep draws from.
pub fn calibration_models() -> Vec<ModelGraph> {
    vec![
        zoo::yolov2(),
        zoo::yolov2_tiny(),
        zoo::mobilenet_v1(),
        zoo::resnet18(),
    ]
}

/// Generate the sweep on the default (Snapdragon-855) device.
pub fn generate(cfg: &CalibConfig) -> Vec<Sample> {
    generate_on(cfg, &DeviceConfig::snapdragon_855())
}

/// Generate the sweep on a specific device parameterization (the fleet
/// layer calibrates each device class against its own hardware): each
/// sample pins a fresh device to a random state and measures one full
/// operator on one unit.
pub fn generate_on(cfg: &CalibConfig, dev_cfg: &DeviceConfig) -> Vec<Sample> {
    let models = calibration_models();
    let ops: Vec<&OpNode> = models.iter().flat_map(|m| m.ops.iter()).collect();
    let mut rng = Prng::new(cfg.seed);
    let cpu_freqs: Vec<f64> = dev_cfg.cpu_opps.points.iter().map(|p| p.freq_hz).collect();
    let gpu_freqs: Vec<f64> = dev_cfg.gpu_opps.points.iter().map(|p| p.freq_hz).collect();

    let mut out = Vec::with_capacity(cfg.samples);
    while out.len() < cfg.samples {
        let op = ops[rng.below(ops.len())];
        let proc = if rng.chance(0.5) { Proc::Cpu } else { Proc::Gpu };
        let placement = Placement::Single(proc);
        let spec = ConditionSpec {
            name: "calib",
            cpu_freq_hz: Some(*rng.choose(&cpu_freqs)),
            gpu_freq_hz: Some(*rng.choose(&gpu_freqs)),
            cpu_bg_mean: rng.range(0.0, 0.7),
            cpu_bg_sigma: 0.0,
            cpu_burst: 0.0,
            gpu_bg_mean: rng.range(0.0, 0.3),
            gpu_bg_sigma: 0.0,
            gpu_burst: 0.0,
            bw_ambient: rng.range(0.75, 1.0),
            drift_sigma: 0.0,
        };
        let mut dev = Device::new(DeviceConfig {
            seed: rng.next_u64(),
            ..dev_cfg.clone()
        });
        dev.apply_condition(&spec);
        // co-located inputs, continuing run → measured cost is compute +
        // dispatch_next; subtract the (known) dispatch constant.
        let need_cpu = placement.frac_on(Proc::Cpu);
        let mut ctx = ExecCtx::fresh(vec![need_cpu; op.in_shapes.len()]);
        ctx.new_run_cpu = false;
        ctx.new_run_gpu = false;
        let snap = dev.snapshot();
        let cost = dev.measure(op, placement, &ctx);
        let dispatch = match proc {
            Proc::Cpu => dev_cfg.cpu_compute.dispatch_next,
            Proc::Gpu => dev_cfg.gpu_compute.dispatch_next,
        };
        out.push(Sample {
            proc,
            features: features::extract(op, placement, &ctx, &snap),
            energy_j: cost.energy_j.max(1e-12),
            latency_s: (cost.latency_s - dispatch).max(1e-9),
        });
    }
    out
}

fn fit_unit(samples: &[Sample], proc: Proc, gbdt: &GbdtParams) -> UnitModel {
    let rows: Vec<&Sample> = samples.iter().filter(|s| s.proc == proc).collect();
    assert!(rows.len() > 100, "too few {proc} calibration samples");
    let x: Vec<Vec<f32>> = rows.iter().map(|s| s.features.clone()).collect();
    let yl: Vec<f64> = rows.iter().map(|s| s.latency_s.ln()).collect();
    let ye: Vec<f64> = rows.iter().map(|s| s.energy_j.ln()).collect();
    UnitModel {
        latency: Gbdt::fit(&x, &yl, gbdt),
        energy: Gbdt::fit(&x, &ye, gbdt),
    }
}

/// Fit both unit models from a sweep.
pub fn fit(samples: &[Sample], gbdt: &GbdtParams) -> OfflineModel {
    OfflineModel {
        cpu: fit_unit(samples, Proc::Cpu, gbdt),
        gpu: fit_unit(samples, Proc::Gpu, gbdt),
    }
}

/// Convenience: generate + fit on the default (Snapdragon-855) device.
pub fn calibrate(cfg: &CalibConfig) -> OfflineModel {
    calibrate_on(cfg, &DeviceConfig::snapdragon_855())
}

/// Convenience: generate + fit against a specific device parameterization
/// (per-class fleet calibration).
pub fn calibrate_on(cfg: &CalibConfig, dev_cfg: &DeviceConfig) -> OfflineModel {
    let samples = generate_on(cfg, dev_cfg);
    fit(&samples, &cfg.gbdt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mape;

    fn small_cfg() -> CalibConfig {
        CalibConfig {
            samples: 1500,
            seed: 9,
            gbdt: GbdtParams {
                trees: 60,
                ..Default::default()
            },
        }
    }

    #[test]
    fn sweep_covers_both_units() {
        let cfg = small_cfg();
        let s = generate(&cfg);
        assert_eq!(s.len(), cfg.samples);
        let n_cpu = s.iter().filter(|x| x.proc == Proc::Cpu).count();
        assert!(n_cpu > cfg.samples / 3 && n_cpu < 2 * cfg.samples / 3);
    }

    #[test]
    fn targets_positive_and_spread() {
        let s = generate(&small_cfg());
        assert!(s.iter().all(|x| x.energy_j > 0.0 && x.latency_s > 0.0));
        let max = s.iter().map(|x| x.energy_j).fold(0.0, f64::max);
        let min = s.iter().map(|x| x.energy_j).fold(f64::INFINITY, f64::min);
        assert!(max / min > 100.0, "energy range too narrow: {min}..{max}");
    }

    #[test]
    fn fitted_model_accurate_in_sample() {
        let cfg = small_cfg();
        let s = generate(&cfg);
        let m = fit(&s, &cfg.gbdt);
        let gpu_rows: Vec<&Sample> = s.iter().filter(|x| x.proc == Proc::Gpu).collect();
        let pred: Vec<f64> = gpu_rows
            .iter()
            .map(|x| m.gpu.energy.predict(&x.features).exp())
            .collect();
        let truth: Vec<f64> = gpu_rows.iter().map(|x| x.energy_j).collect();
        let e = mape(&pred, &truth);
        assert!(e < 20.0, "in-sample gpu energy MAPE {e}%");
    }

    #[test]
    fn fitted_model_generalizes() {
        let cfg = small_cfg();
        let s = generate(&cfg);
        let (train, test) = s.split_at(1200);
        let m = fit(train, &cfg.gbdt);
        let rows: Vec<&Sample> = test.iter().filter(|x| x.proc == Proc::Cpu).collect();
        let pred: Vec<f64> = rows
            .iter()
            .map(|x| m.cpu.latency.predict(&x.features).exp())
            .collect();
        let truth: Vec<f64> = rows.iter().map(|x| x.latency_s).collect();
        let e = mape(&pred, &truth);
        assert!(e < 30.0, "held-out cpu latency MAPE {e}%");
    }
}
