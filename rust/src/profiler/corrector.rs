//! Runtime correction of the offline model (paper §2.1's GRU stage).
//!
//! The corrector watches the stream of prediction residuals
//! `r_t = ln(observed / predicted)` and produces a multiplicative factor
//! applied to the next predictions. Two implementations:
//!
//! * [`GruCorrector`] — the paper's design: a GRU (authored in JAX, Pallas
//!   cell kernel, AOT-compiled to HLO) consumes the last `K` residuals
//!   plus device-state deltas and emits the predicted next log-residual.
//!   Inference runs through a boxed callback into the PJRT runtime so this
//!   module stays independent of `runtime/` (and testable without
//!   artifacts).
//! * [`EwmaCorrector`] — artifact-free fallback and ablation baseline:
//!   exponentially-weighted mean of residuals.

use crate::soc::device::Snapshot;
use crate::util::stats::Ewma;
use crate::util::RingBuffer;

/// A runtime residual-driven corrector.
pub trait Corrector {
    /// Record one observation: the residual of a prediction and the device
    /// state it was made under.
    fn observe(&mut self, log_ratio: f64, snap: &Snapshot);
    /// Multiplicative correction to apply to the next prediction.
    fn factor(&self) -> f64;
    /// Reset state (e.g. after a regime change handled elsewhere).
    fn reset(&mut self);
    /// Corrector name (reports).
    fn name(&self) -> &'static str;
}

/// EWMA fallback corrector.
#[derive(Debug, Clone)]
pub struct EwmaCorrector {
    ewma: Ewma,
    alpha: f64,
}

impl EwmaCorrector {
    /// Build with smoothing factor `alpha` (higher = faster tracking).
    pub fn new(alpha: f64) -> Self {
        EwmaCorrector {
            ewma: Ewma::new(alpha),
            alpha,
        }
    }
}

impl Default for EwmaCorrector {
    fn default() -> Self {
        // slow enough not to chase per-op measurement noise, fast enough
        // to track burst episodes (~10 ops)
        EwmaCorrector::new(0.12)
    }
}

impl Corrector for EwmaCorrector {
    fn observe(&mut self, log_ratio: f64, _snap: &Snapshot) {
        // clamp outliers (a single mis-measured op must not poison the state)
        self.ewma.push(log_ratio.clamp(-1.0, 1.0));
    }

    fn factor(&self) -> f64 {
        self.ewma.value().unwrap_or(0.0).exp()
    }

    fn reset(&mut self) {
        self.ewma = Ewma::new(self.alpha);
    }

    fn name(&self) -> &'static str {
        "ewma"
    }
}

/// Input features per time step fed to the GRU: the residual plus state
/// context. Must match `python/compile/model.py::GRU_IN_FEATURES`.
pub const GRU_IN_FEATURES: usize = 4;

/// Build the GRU's per-step input: [log_ratio, cpu_util, gpu_util, temp/100].
pub fn gru_step_features(log_ratio: f64, snap: &Snapshot) -> [f32; GRU_IN_FEATURES] {
    [
        log_ratio.clamp(-1.0, 1.0) as f32,
        snap.cpu_util as f32,
        snap.gpu_util as f32,
        (snap.temp_c / 100.0) as f32,
    ]
}

/// GRU inference callback: takes the `[K × GRU_IN_FEATURES]` window
/// (row-major, oldest first) and returns the predicted next log-residual.
pub type GruInferFn = Box<dyn FnMut(&[f32]) -> anyhow::Result<f32>>;

/// GRU-based corrector (the paper's). Holds the residual window and defers
/// the network evaluation to an injected callback (the PJRT runtime wires
/// the real artifact in; tests inject closures).
pub struct GruCorrector {
    window: RingBuffer<[f32; GRU_IN_FEATURES]>,
    k: usize,
    infer: GruInferFn,
    cached: f64,
    /// Fallback used until the window fills.
    warmup: EwmaCorrector,
}

impl GruCorrector {
    /// Build with residual-window length `k` and an inference closure.
    pub fn new(k: usize, infer: GruInferFn) -> Self {
        GruCorrector {
            window: RingBuffer::new(k),
            k,
            infer,
            cached: 0.0,
            warmup: EwmaCorrector::default(),
        }
    }

    fn window_flat(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.k * GRU_IN_FEATURES);
        for row in self.window.iter() {
            out.extend_from_slice(row);
        }
        out
    }
}

impl Corrector for GruCorrector {
    fn observe(&mut self, log_ratio: f64, snap: &Snapshot) {
        self.warmup.observe(log_ratio, snap);
        self.window.push(gru_step_features(log_ratio, snap));
        if self.window.is_full() {
            let flat = self.window_flat();
            match (self.infer)(&flat) {
                Ok(pred) => self.cached = pred.clamp(-1.0, 1.0) as f64,
                Err(e) => {
                    crate::log_warn!("gru inference failed ({e}); keeping last correction");
                }
            }
        }
    }

    fn factor(&self) -> f64 {
        if self.window.is_full() {
            self.cached.exp()
        } else {
            self.warmup.factor()
        }
    }

    fn reset(&mut self) {
        self.window.clear();
        self.cached = 0.0;
        self.warmup.reset();
    }

    fn name(&self) -> &'static str {
        "gru"
    }
}

/// No-op corrector (GBDT-only ablation arm).
#[derive(Debug, Clone, Default)]
pub struct NullCorrector;

impl Corrector for NullCorrector {
    fn observe(&mut self, _log_ratio: f64, _snap: &Snapshot) {}
    fn factor(&self) -> f64 {
        1.0
    }
    fn reset(&mut self) {}
    fn name(&self) -> &'static str {
        "none"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap() -> Snapshot {
        Snapshot {
            time_s: 0.0,
            cpu_freq_hz: 1.49e9,
            gpu_freq_hz: 499e6,
            cpu_util: 0.3,
            gpu_util: 0.1,
            temp_c: 40.0,
            bw_factor: 0.9,
        }
    }

    #[test]
    fn ewma_tracks_constant_bias() {
        let mut c = EwmaCorrector::default();
        for _ in 0..100 {
            c.observe(0.3, &snap()); // observed 35% above predicted
        }
        assert!((c.factor() - 0.3f64.exp()).abs() < 0.01);
    }

    #[test]
    fn ewma_neutral_before_data() {
        let c = EwmaCorrector::default();
        assert_eq!(c.factor(), 1.0);
    }

    #[test]
    fn ewma_clamps_outliers() {
        let mut c = EwmaCorrector::new(1.0); // full weight on latest
        c.observe(50.0, &snap());
        assert!(c.factor() <= 1.0f64.exp() + 1e-9);
    }

    #[test]
    fn gru_uses_warmup_until_full() {
        let mut c = GruCorrector::new(4, Box::new(|_| Ok(0.5)));
        c.observe(0.2, &snap());
        c.observe(0.2, &snap());
        // window not full → warmup EWMA drives the factor
        assert!(c.factor() < 0.5f64.exp() - 0.1);
        c.observe(0.2, &snap());
        c.observe(0.2, &snap());
        // full → GRU output (0.5) drives it
        assert!((c.factor() - 0.5f64.exp()).abs() < 1e-6);
    }

    #[test]
    fn gru_window_is_fifo_flat() {
        let seen = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        let seen2 = seen.clone();
        let mut c = GruCorrector::new(2, Box::new(move |w| {
            seen2.lock().unwrap().push(w.to_vec());
            Ok(0.0)
        }));
        for r in [0.1f64, 0.2, 0.3] {
            c.observe(r, &snap());
        }
        let calls = seen.lock().unwrap();
        // first call after fill: [0.1, 0.2]; second: [0.2, 0.3]
        assert_eq!(calls.len(), 2);
        assert!((calls[0][0] - 0.1).abs() < 1e-6);
        assert!((calls[1][0] - 0.2).abs() < 1e-6);
        assert!((calls[1][GRU_IN_FEATURES] - 0.3).abs() < 1e-6);
    }

    #[test]
    fn gru_inference_error_keeps_last() {
        let mut fail = false;
        let mut c = GruCorrector::new(
            1,
            Box::new(move |_| {
                if fail {
                    anyhow::bail!("dead")
                } else {
                    fail = true;
                    Ok(0.4)
                }
            }),
        );
        c.observe(0.0, &snap());
        let f1 = c.factor();
        c.observe(0.0, &snap()); // inference fails → keep cached
        assert_eq!(c.factor(), f1);
    }

    #[test]
    fn reset_clears_state() {
        let mut c = GruCorrector::new(2, Box::new(|_| Ok(0.9)));
        c.observe(0.1, &snap());
        c.observe(0.1, &snap());
        assert!(c.factor() > 1.0);
        c.reset();
        assert_eq!(c.factor(), 1.0);
    }

    #[test]
    fn null_is_identity() {
        let mut c = NullCorrector;
        c.observe(5.0, &snap());
        assert_eq!(c.factor(), 1.0);
    }
}
