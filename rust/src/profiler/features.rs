//! Feature extraction: (operator, placement, device state) → fixed-length
//! vector for the GBDT. Feature names are stable and documented; the
//! calibration sweep and runtime prediction must build identical layouts.

use crate::graph::op::OpKind;
use crate::graph::OpNode;
use crate::soc::device::{ExecCtx, Snapshot};
use crate::soc::{Placement, Proc};

/// Number of scalar features after the kind one-hot.
const NUM_SCALAR: usize = 14;

/// Total feature dimension.
pub const DIM: usize = OpKind::NUM_KINDS + NUM_SCALAR;

/// A fixed-length feature vector.
pub type FeatureVec = Vec<f32>;

/// Human-readable feature names (diagnostics, importance reports).
pub fn names() -> Vec<String> {
    let mut n: Vec<String> = (0..OpKind::NUM_KINDS).map(|k| format!("kind_{k}")).collect();
    n.extend(
        [
            "log_flops",
            "log_act_bytes",
            "log_weight_bytes",
            "arith_intensity",
            "cpu_frac",
            "is_split",
            "cpu_freq_ghz",
            "gpu_freq_ghz",
            "cpu_util",
            "gpu_util",
            "temp_c",
            "bw_factor",
            "new_run_cpu",
            "new_run_gpu",
        ]
        .iter()
        .map(|s| s.to_string()),
    );
    n
}

/// Build the feature vector.
pub fn extract(
    op: &OpNode,
    placement: Placement,
    ctx: &ExecCtx,
    snap: &Snapshot,
) -> FeatureVec {
    let mut f = vec![0.0f32; DIM];
    f[op.kind.kind_id()] = 1.0;
    let mut i = OpKind::NUM_KINDS;
    let mut push = |f: &mut Vec<f32>, v: f64| {
        f[i] = v as f32;
        i += 1;
    };
    push(&mut f, (op.flops as f64 + 1.0).ln());
    push(&mut f, (op.activation_bytes as f64 + 1.0).ln());
    push(&mut f, (op.weight_bytes as f64 + 1.0).ln());
    push(&mut f, op.arithmetic_intensity().min(1e4).ln_1p());
    push(&mut f, placement.frac_on(Proc::Cpu));
    push(
        &mut f,
        if matches!(placement, Placement::Split { .. }) {
            1.0
        } else {
            0.0
        },
    );
    push(&mut f, snap.cpu_freq_hz / 1e9);
    push(&mut f, snap.gpu_freq_hz / 1e9);
    push(&mut f, snap.cpu_util);
    push(&mut f, snap.gpu_util);
    push(&mut f, snap.temp_c / 100.0);
    push(&mut f, snap.bw_factor);
    push(&mut f, if ctx.new_run_cpu { 1.0 } else { 0.0 });
    push(&mut f, if ctx.new_run_gpu { 1.0 } else { 0.0 });
    debug_assert_eq!(i, DIM);
    f
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::soc::device::ExecCtx;

    fn snap() -> Snapshot {
        Snapshot {
            time_s: 0.0,
            cpu_freq_hz: 1.49e9,
            gpu_freq_hz: 499e6,
            cpu_util: 0.35,
            gpu_util: 0.08,
            temp_c: 45.0,
            bw_factor: 0.92,
        }
    }

    #[test]
    fn dim_matches_names() {
        assert_eq!(names().len(), DIM);
    }

    #[test]
    fn one_hot_set_correctly() {
        let g = zoo::yolov2();
        let op = &g.ops[0]; // conv 3×3
        let f = extract(op, Placement::CPU, &ExecCtx::fresh(vec![1.0]), &snap());
        let hot: Vec<usize> = (0..OpKind::NUM_KINDS).filter(|&k| f[k] == 1.0).collect();
        assert_eq!(hot, vec![op.kind.kind_id()]);
    }

    #[test]
    fn placement_features() {
        let g = zoo::yolov2();
        let op = &g.ops[0];
        let s = snap();
        let f_cpu = extract(op, Placement::CPU, &ExecCtx::fresh(vec![1.0]), &s);
        let f_split = extract(
            op,
            Placement::Split { cpu_frac: 0.3 },
            &ExecCtx::fresh(vec![1.0]),
            &s,
        );
        let base = OpKind::NUM_KINDS;
        assert_eq!(f_cpu[base + 4], 1.0); // cpu_frac
        assert_eq!(f_cpu[base + 5], 0.0); // is_split
        assert!((f_split[base + 4] - 0.3).abs() < 1e-6);
        assert_eq!(f_split[base + 5], 1.0);
    }

    #[test]
    fn snapshot_features_present() {
        let g = zoo::yolov2();
        let f = extract(
            &g.ops[0],
            Placement::GPU,
            &ExecCtx::fresh(vec![0.0]),
            &snap(),
        );
        let base = OpKind::NUM_KINDS;
        assert!((f[base + 6] - 1.49).abs() < 1e-6);
        assert!((f[base + 7] - 0.499).abs() < 1e-6);
        assert!((f[base + 8] - 0.35).abs() < 1e-6);
    }

    #[test]
    fn flops_feature_is_log() {
        let g = zoo::yolov2();
        let f = extract(
            &g.ops[0],
            Placement::GPU,
            &ExecCtx::fresh(vec![0.0]),
            &snap(),
        );
        let expect = (g.ops[0].flops as f64 + 1.0).ln() as f32;
        assert!((f[OpKind::NUM_KINDS] - expect).abs() < 1e-5);
    }
}
