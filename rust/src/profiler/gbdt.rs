//! Gradient-boosted regression trees, implemented from scratch (the paper's
//! offline energy model; no ML crates exist in the offline universe).
//!
//! Standard histogram GBDT with squared loss: features are quantile-binned
//! once (≤64 bins), each boosting round fits a depth-limited tree to the
//! current residuals using variance-reduction splits over bin histograms,
//! with shrinkage and per-tree row subsampling.

use crate::util::Prng;

/// Training hyperparameters.
#[derive(Debug, Clone)]
pub struct GbdtParams {
    /// Boosting rounds.
    pub trees: usize,
    /// Maximum tree depth.
    pub max_depth: usize,
    /// Learning rate (shrinkage).
    pub eta: f64,
    /// Per-tree row subsample fraction.
    pub subsample: f64,
    /// Minimum rows per leaf.
    pub min_leaf: usize,
    /// Quantile-binning resolution per feature.
    pub bins: usize,
    /// Subsampling seed.
    pub seed: u64,
}

impl Default for GbdtParams {
    fn default() -> Self {
        GbdtParams {
            trees: 120,
            max_depth: 5,
            eta: 0.1,
            subsample: 0.8,
            min_leaf: 12,
            bins: 64,
            seed: 7,
        }
    }
}

/// One tree node (array-encoded tree).
#[derive(Debug, Clone)]
enum Node {
    Split {
        feature: usize,
        /// go left when binned value ≤ bin
        bin: u8,
        left: usize,
        right: usize,
    },
    Leaf {
        value: f64,
    },
}

#[derive(Debug, Clone)]
struct Tree {
    nodes: Vec<Node>,
}

impl Tree {
    fn predict_binned(&self, row: &[u8]) -> f64 {
        let mut i = 0;
        loop {
            match self.nodes[i] {
                Node::Leaf { value } => return value,
                Node::Split {
                    feature,
                    bin,
                    left,
                    right,
                } => {
                    i = if row[feature] <= bin { left } else { right };
                }
            }
        }
    }
}

/// A fitted gradient-boosted model.
#[derive(Debug, Clone)]
pub struct Gbdt {
    base: f64,
    eta: f64,
    trees: Vec<Tree>,
    /// Per-feature ascending bin upper edges (len ≤ bins−1): value v maps
    /// to the first bin whose edge ≥ v.
    edges: Vec<Vec<f32>>,
}

impl Gbdt {
    /// Fit on `x` (n rows × d cols, row-major) and targets `y`.
    pub fn fit(x: &[Vec<f32>], y: &[f64], params: &GbdtParams) -> Gbdt {
        assert!(!x.is_empty());
        assert_eq!(x.len(), y.len());
        let d = x[0].len();
        let n = x.len();
        let mut rng = Prng::new(params.seed);

        // --- quantile binning
        let edges: Vec<Vec<f32>> = (0..d)
            .map(|j| {
                let mut col: Vec<f32> = x.iter().map(|r| r[j]).collect();
                col.sort_by(f32::total_cmp); // NaN-safe: sorts last
                col.dedup();
                if col.len() <= params.bins {
                    // distinct values fit: edges between consecutive values
                    col.windows(2).map(|w| (w[0] + w[1]) / 2.0).collect()
                } else {
                    (1..params.bins)
                        .map(|k| col[k * col.len() / params.bins])
                        .collect()
                }
            })
            .collect();
        let binned: Vec<Vec<u8>> = x
            .iter()
            .map(|row| {
                (0..d)
                    .map(|j| bin_value(&edges[j], row[j]))
                    .collect::<Vec<u8>>()
            })
            .collect();

        // --- boosting
        let base = y.iter().sum::<f64>() / n as f64;
        let mut pred = vec![base; n];
        let mut trees = Vec::with_capacity(params.trees);
        for _ in 0..params.trees {
            let residual: Vec<f64> = (0..n).map(|i| y[i] - pred[i]).collect();
            let rows: Vec<usize> = if params.subsample < 1.0 {
                (0..n)
                    .filter(|_| rng.chance(params.subsample))
                    .collect()
            } else {
                (0..n).collect()
            };
            if rows.len() < params.min_leaf * 2 {
                continue;
            }
            let tree = build_tree(&binned, &residual, &rows, &edges, params);
            for i in 0..n {
                pred[i] += params.eta * tree.predict_binned(&binned[i]);
            }
            trees.push(tree);
        }
        Gbdt {
            base,
            eta: params.eta,
            trees,
            edges,
        }
    }

    /// Predict a single row.
    pub fn predict(&self, row: &[f32]) -> f64 {
        let binned: Vec<u8> = (0..row.len())
            .map(|j| bin_value(&self.edges[j], row[j]))
            .collect();
        self.base
            + self.eta
                * self
                    .trees
                    .iter()
                    .map(|t| t.predict_binned(&binned))
                    .sum::<f64>()
    }

    /// Number of fitted trees.
    pub fn num_trees(&self) -> usize {
        self.trees.len()
    }

    /// Split-count feature importance (diagnostics).
    pub fn importance(&self, dim: usize) -> Vec<usize> {
        let mut imp = vec![0usize; dim];
        for t in &self.trees {
            for node in &t.nodes {
                if let Node::Split { feature, .. } = node {
                    imp[*feature] += 1;
                }
            }
        }
        imp
    }
}

fn bin_value(edges: &[f32], v: f32) -> u8 {
    // first edge ≥ v  (edges ascending, ≤ 255 edges)
    match edges.binary_search_by(|e| e.total_cmp(&v)) {
        Ok(i) => i as u8,
        Err(i) => i as u8,
    }
}

fn build_tree(
    binned: &[Vec<u8>],
    target: &[f64],
    rows: &[usize],
    edges: &[Vec<f32>],
    params: &GbdtParams,
) -> Tree {
    let mut nodes = Vec::new();
    // stack of (node index to fill, rows, depth)
    nodes.push(Node::Leaf { value: 0.0 });
    let mut stack = vec![(0usize, rows.to_vec(), 0usize)];
    while let Some((slot, rows, depth)) = stack.pop() {
        let sum: f64 = rows.iter().map(|&i| target[i]).sum();
        let mean = sum / rows.len() as f64;
        if depth >= params.max_depth || rows.len() < params.min_leaf * 2 {
            nodes[slot] = Node::Leaf { value: mean };
            continue;
        }
        match best_split(binned, target, &rows, edges, params) {
            None => {
                nodes[slot] = Node::Leaf { value: mean };
            }
            Some((feature, bin)) => {
                let (lrows, rrows): (Vec<usize>, Vec<usize>) =
                    rows.iter().partition(|&&i| binned[i][feature] <= bin);
                if lrows.len() < params.min_leaf || rrows.len() < params.min_leaf {
                    nodes[slot] = Node::Leaf { value: mean };
                    continue;
                }
                let li = nodes.len();
                nodes.push(Node::Leaf { value: 0.0 });
                let ri = nodes.len();
                nodes.push(Node::Leaf { value: 0.0 });
                nodes[slot] = Node::Split {
                    feature,
                    bin,
                    left: li,
                    right: ri,
                };
                stack.push((li, lrows, depth + 1));
                stack.push((ri, rrows, depth + 1));
            }
        }
    }
    Tree { nodes }
}

/// Best (feature, bin) by variance reduction, or None if no split helps.
fn best_split(
    binned: &[Vec<u8>],
    target: &[f64],
    rows: &[usize],
    edges: &[Vec<f32>],
    params: &GbdtParams,
) -> Option<(usize, u8)> {
    let d = edges.len();
    let n = rows.len() as f64;
    let total_sum: f64 = rows.iter().map(|&i| target[i]).sum();
    let parent_score = total_sum * total_sum / n;
    let mut best: Option<(usize, u8, f64)> = None;

    // reusable histograms
    let max_bins = params.bins;
    let mut hist_sum = vec![0.0f64; max_bins];
    let mut hist_cnt = vec![0usize; max_bins];

    for j in 0..d {
        let nbins = edges[j].len() + 1;
        if nbins < 2 {
            continue;
        }
        hist_sum[..nbins].fill(0.0);
        hist_cnt[..nbins].fill(0);
        for &i in rows {
            let b = binned[i][j] as usize;
            hist_sum[b] += target[i];
            hist_cnt[b] += 1;
        }
        let mut lsum = 0.0;
        let mut lcnt = 0usize;
        for b in 0..nbins - 1 {
            lsum += hist_sum[b];
            lcnt += hist_cnt[b];
            let rcnt = rows.len() - lcnt;
            if lcnt < params.min_leaf || rcnt < params.min_leaf {
                continue;
            }
            let rsum = total_sum - lsum;
            let score =
                lsum * lsum / lcnt as f64 + rsum * rsum / rcnt as f64 - parent_score;
            if score > best.map_or(1e-12, |(_, _, s)| s) {
                best = Some((j, b as u8, score));
            }
        }
    }
    best.map(|(j, b, _)| (j, b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::r2;
    use crate::util::Prng;

    fn gen_data(
        n: usize,
        f: impl Fn(&[f32]) -> f64,
        noise: f64,
        seed: u64,
    ) -> (Vec<Vec<f32>>, Vec<f64>) {
        let mut rng = Prng::new(seed);
        let x: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..4).map(|_| rng.f64() as f32).collect())
            .collect();
        let y: Vec<f64> = x
            .iter()
            .map(|r| f(r) + noise * rng.normal())
            .collect();
        (x, y)
    }

    #[test]
    fn fits_linear_function() {
        let (x, y) = gen_data(2000, |r| 3.0 * r[0] as f64 - 2.0 * r[1] as f64, 0.01, 1);
        let m = Gbdt::fit(&x, &y, &GbdtParams::default());
        let pred: Vec<f64> = x.iter().map(|r| m.predict(r)).collect();
        let r = r2(&pred, &y);
        assert!(r > 0.95, "r2 = {r}");
    }

    #[test]
    fn fits_nonlinear_interaction() {
        let (x, y) = gen_data(
            3000,
            |r| (r[0] as f64 * r[1] as f64 * 4.0) + (r[2] as f64).powi(2),
            0.02,
            2,
        );
        let m = Gbdt::fit(&x, &y, &GbdtParams::default());
        let pred: Vec<f64> = x.iter().map(|r| m.predict(r)).collect();
        let r = r2(&pred, &y);
        assert!(r > 0.9, "r2 = {r}");
    }

    #[test]
    fn generalizes_to_held_out() {
        let (x, y) = gen_data(4000, |r| 2.0 * r[0] as f64 + (r[1] as f64).sqrt(), 0.02, 3);
        let (xt, yt) = (&x[..3000], &y[..3000]);
        let (xv, yv) = (&x[3000..], &y[3000..]);
        let m = Gbdt::fit(xt, yt, &GbdtParams::default());
        let pred: Vec<f64> = xv.iter().map(|r| m.predict(r)).collect();
        let r = r2(&pred, yv);
        assert!(r > 0.9, "held-out r2 = {r}");
    }

    #[test]
    fn constant_target_predicts_constant() {
        let (x, _) = gen_data(500, |_| 0.0, 0.0, 4);
        let y = vec![5.5; 500];
        let m = Gbdt::fit(&x, &y, &GbdtParams::default());
        for r in x.iter().take(20) {
            assert!((m.predict(r) - 5.5).abs() < 1e-6);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = gen_data(800, |r| r[0] as f64, 0.05, 5);
        let a = Gbdt::fit(&x, &y, &GbdtParams::default());
        let b = Gbdt::fit(&x, &y, &GbdtParams::default());
        for r in x.iter().take(10) {
            assert_eq!(a.predict(r), b.predict(r));
        }
    }

    #[test]
    fn importance_identifies_relevant_feature() {
        let (x, y) = gen_data(2000, |r| 10.0 * r[2] as f64, 0.01, 6);
        let m = Gbdt::fit(&x, &y, &GbdtParams::default());
        let imp = m.importance(4);
        assert!(imp[2] > imp[0] && imp[2] > imp[1] && imp[2] > imp[3], "{imp:?}");
    }

    #[test]
    fn more_trees_fit_better() {
        let (x, y) = gen_data(1500, |r| (6.0 * r[0] as f64).sin(), 0.01, 7);
        let small = Gbdt::fit(
            &x,
            &y,
            &GbdtParams {
                trees: 5,
                ..Default::default()
            },
        );
        let big = Gbdt::fit(
            &x,
            &y,
            &GbdtParams {
                trees: 150,
                ..Default::default()
            },
        );
        let mse = |m: &Gbdt| {
            x.iter()
                .zip(&y)
                .map(|(r, t)| (m.predict(r) - t).powi(2))
                .sum::<f64>()
                / x.len() as f64
        };
        assert!(mse(&big) < mse(&small) * 0.6);
    }

    #[test]
    fn handles_constant_features() {
        let mut rng = Prng::new(8);
        let x: Vec<Vec<f32>> = (0..500)
            .map(|_| vec![1.0f32, rng.f64() as f32])
            .collect();
        let y: Vec<f64> = x.iter().map(|r| r[1] as f64).collect();
        let m = Gbdt::fit(&x, &y, &GbdtParams::default());
        let pred: Vec<f64> = x.iter().map(|r| m.predict(r)).collect();
        assert!(r2(&pred, &y) > 0.9);
    }
}
