//! Runtime energy profiler (paper §2.1).
//!
//! Two-stage estimator, exactly the paper's split:
//!
//! * **Offline** — a gradient-boosted-decision-tree regressor ([`gbdt`])
//!   fit on a calibration sweep ([`calibrate`]) over operators ×
//!   placements × device states, predicting per-op energy and latency
//!   from operational features ([`features`]).
//! * **Runtime** — a resource monitor ([`monitor`]) samples device state,
//!   and a GRU corrector ([`corrector`]) turns the recent history of
//!   prediction residuals into a multiplicative correction that tracks
//!   hidden dynamics (bursts, thermal/contention drift) no static model
//!   can see. The GRU itself is JAX/Pallas-authored, AOT-compiled, and
//!   executed through the PJRT runtime; a pure-rust EWMA corrector is the
//!   artifact-free fallback.
//!
//! [`profiler::EnergyProfiler`] composes the two and exposes the
//! [`CostModel`] trait that planning (the partitioner) consumes.

pub mod calibrate;
pub mod corrector;
pub mod features;
pub mod gbdt;
pub mod monitor;
pub mod profiler;

pub use corrector::{Corrector, EwmaCorrector};
pub use features::FeatureVec;
pub use gbdt::Gbdt;
pub use profiler::{CostModel, EnergyProfiler};
