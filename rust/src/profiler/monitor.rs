//! Resource monitor: periodic sampling of the observable device state
//! (the `/proc/stat` + hwmon analogue), with change detection that flags
//! condition switches (frequency repinning, utilization level shifts).

use crate::soc::device::Snapshot;
use crate::util::stats::Ewma;
use crate::util::RingBuffer;

/// A monitor over observable device state.
#[derive(Debug, Clone)]
pub struct ResourceMonitor {
    history: RingBuffer<Snapshot>,
    cpu_util_ewma: Ewma,
    gpu_util_ewma: Ewma,
    last: Option<Snapshot>,
    /// Set when the latest sample looks like a regime change.
    changed: bool,
    /// Relative frequency change that counts as a switch.
    freq_eps: f64,
    /// Absolute smoothed-utilization jump that counts as a switch.
    util_eps: f64,
}

impl Default for ResourceMonitor {
    fn default() -> Self {
        Self::new(128)
    }
}

impl ResourceMonitor {
    /// Build with a bounded snapshot history.
    pub fn new(history_len: usize) -> Self {
        ResourceMonitor {
            history: RingBuffer::new(history_len),
            cpu_util_ewma: Ewma::new(0.2),
            gpu_util_ewma: Ewma::new(0.2),
            last: None,
            changed: false,
            freq_eps: 0.02,
            util_eps: 0.12,
        }
    }

    /// Ingest a new sample.
    pub fn sample(&mut self, snap: Snapshot) {
        self.changed = false;
        if let Some(prev) = self.last {
            let freq_jump = (snap.cpu_freq_hz / prev.cpu_freq_hz - 1.0).abs() > self.freq_eps
                || (snap.gpu_freq_hz / prev.gpu_freq_hz - 1.0).abs() > self.freq_eps;
            let prev_util = self.cpu_util_ewma.value().unwrap_or(snap.cpu_util);
            let util_jump = (snap.cpu_util - prev_util).abs() > self.util_eps;
            self.changed = freq_jump || util_jump;
        }
        self.cpu_util_ewma.push(snap.cpu_util);
        self.gpu_util_ewma.push(snap.gpu_util);
        self.history.push(snap);
        self.last = Some(snap);
    }

    /// Latest raw sample.
    pub fn latest(&self) -> Option<Snapshot> {
        self.last
    }

    /// Smoothed CPU utilization.
    pub fn cpu_util_smooth(&self) -> f64 {
        self.cpu_util_ewma.value().unwrap_or(0.0)
    }

    /// Smoothed GPU utilization.
    pub fn gpu_util_smooth(&self) -> f64 {
        self.gpu_util_ewma.value().unwrap_or(0.0)
    }

    /// Did the most recent sample indicate a regime change?
    pub fn regime_changed(&self) -> bool {
        self.changed
    }

    /// Recent snapshots, oldest → newest.
    pub fn history(&self) -> Vec<Snapshot> {
        self.history.to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(cpu_freq: f64, cpu_util: f64) -> Snapshot {
        Snapshot {
            time_s: 0.0,
            cpu_freq_hz: cpu_freq,
            gpu_freq_hz: 499e6,
            cpu_util,
            gpu_util: 0.1,
            temp_c: 40.0,
            bw_factor: 0.9,
        }
    }

    #[test]
    fn detects_frequency_repin() {
        let mut m = ResourceMonitor::default();
        for _ in 0..10 {
            m.sample(snap(1.49e9, 0.35));
        }
        assert!(!m.regime_changed());
        m.sample(snap(0.88e9, 0.35));
        assert!(m.regime_changed());
    }

    #[test]
    fn detects_util_level_shift() {
        let mut m = ResourceMonitor::default();
        for _ in 0..30 {
            m.sample(snap(1.49e9, 0.30));
        }
        m.sample(snap(1.49e9, 0.65));
        assert!(m.regime_changed());
    }

    #[test]
    fn ignores_small_noise() {
        let mut m = ResourceMonitor::default();
        for i in 0..50 {
            m.sample(snap(1.49e9, 0.35 + 0.02 * ((i % 3) as f64 - 1.0)));
            if i > 0 {
                assert!(!m.regime_changed(), "false positive at {i}");
            }
        }
    }

    #[test]
    fn smoothing_converges() {
        let mut m = ResourceMonitor::default();
        for _ in 0..100 {
            m.sample(snap(1.49e9, 0.4));
        }
        assert!((m.cpu_util_smooth() - 0.4).abs() < 1e-6);
    }

    #[test]
    fn history_bounded() {
        let mut m = ResourceMonitor::new(8);
        for _ in 0..50 {
            m.sample(snap(1.49e9, 0.3));
        }
        assert_eq!(m.history().len(), 8);
    }
}
