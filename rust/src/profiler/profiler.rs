//! The composed runtime energy profiler: per-unit GBDT priors × per-unit
//! runtime corrections, composed analytically over placements (max of unit
//! times + sync for latency, sum for energy, plus known dispatch/transfer
//! constants). Implements [`CostModel`], the interface planning consumes.

use std::cell::RefCell;
use std::collections::HashMap;

use crate::graph::OpNode;
use crate::soc::device::{Device, ExecCtx, OpCost, Snapshot};
use crate::soc::latency::ComputeParams;
use crate::soc::transfer::{boundary_bytes, TransferParams};
use crate::soc::{Placement, Proc};
use crate::util::stats::Ewma;

use super::calibrate::OfflineModel;
use super::corrector::{Corrector, NullCorrector};
use super::features;

/// Anything that can predict the cost of executing an op under a placement
/// given the observable device state.
pub trait CostModel {
    /// Predicted cost of executing `op` under `placement` in context
    /// `ctx` at observable device state `snap`.
    fn predict(
        &self,
        op: &OpNode,
        placement: Placement,
        ctx: &ExecCtx,
        snap: &Snapshot,
    ) -> OpCost;

    /// Predicted cost of executing `op` once for a *batch* of `batch`
    /// co-dispatched requests (the full batch's cost, not per request).
    /// The default applies the analytic batch scaling
    /// ([`crate::batching::cost::scale_op_cost`]) to the single-request
    /// prediction; the device oracle overrides it with the exact batched
    /// ground truth.
    fn predict_batch(
        &self,
        op: &OpNode,
        placement: Placement,
        ctx: &ExecCtx,
        snap: &Snapshot,
        batch: usize,
    ) -> OpCost {
        crate::batching::cost::scale_op_cost(&self.predict(op, placement, ctx, snap), batch)
    }

    /// Version of the model's *internal* correction state: two calls to
    /// `predict` with identical arguments and identical versions are
    /// guaranteed to return identical costs, so callers may memoize
    /// predictions keyed on `(inputs, version)`. `None` (the default)
    /// means the model offers no such guarantee and callers must always
    /// recompute — behavior-preserving for models that never opt in.
    /// The lattice DP solver ([`crate::partition::DpPartitioner`]) is the
    /// main consumer: with a version present it builds a per-column
    /// predict memo instead of re-querying the model per DP state.
    fn version(&self) -> Option<u64> {
        None
    }
}

/// Oracle cost model: the device itself (planning with ground truth).
/// Used by benches as the profiler-quality upper bound only.
impl CostModel for Device {
    fn predict(
        &self,
        op: &OpNode,
        placement: Placement,
        ctx: &ExecCtx,
        _snap: &Snapshot,
    ) -> OpCost {
        self.expected_cost(op, placement, ctx)
    }

    fn predict_batch(
        &self,
        op: &OpNode,
        placement: Placement,
        ctx: &ExecCtx,
        _snap: &Snapshot,
        batch: usize,
    ) -> OpCost {
        self.expected_cost_batch(op, placement, ctx, batch)
    }
}

/// Per-unit runtime correction pair.
struct UnitCorrection {
    latency: Box<dyn Corrector>,
    energy: Box<dyn Corrector>,
}

/// Split synchronization overhead the profiler assumes (a calibration
/// constant, equal to the device's by construction of the rig).
const SPLIT_SYNC_S: f64 = 30e-6;

/// DRAM-bandwidth contention factor while both units co-execute one op —
/// a measurable device constant (the rig measures single-unit vs split
/// streaming rates once). `bw_factor` is a GBDT feature, so split costs
/// are predicted by querying the unit models under the contended state.
const SPLIT_BW_FACTOR: f64 = 0.78;

/// Memo key for a unit-base prediction: (op id, op flops, proc, new-run
/// flags). Valid only for one snapshot — the cache clears when the
/// observed device state changes (see `unit_base`).
type BaseKey = (usize, u64, u8);

/// Full-field snapshot identity for cache validity (time alone is not
/// enough: two fresh devices both start at t = 0).
fn snap_id(s: &Snapshot) -> [u64; 7] {
    [
        s.time_s.to_bits(),
        s.cpu_freq_hz.to_bits(),
        s.gpu_freq_hz.to_bits(),
        s.cpu_util.to_bits(),
        s.gpu_util.to_bits(),
        s.temp_c.to_bits(),
        s.bw_factor.to_bits(),
    ]
}

/// The paper's runtime energy profiler.
pub struct EnergyProfiler {
    offline: OfflineModel,
    corr: [UnitCorrection; 2], // indexed by Proc::index()
    transfer: TransferParams,
    /// GBDT evaluations dominate planning time; within one snapshot the
    /// unit-base costs of an op are constant, so the DP's thousands of
    /// `predict` calls collapse to a few hundred tree walks. ~10× faster
    /// repartition decisions (EXPERIMENTS.md §Perf).
    base_cache: RefCell<(Option<[u64; 7]>, HashMap<BaseKey, (f64, f64)>)>,
    /// EWMA of |energy log-residual at prediction time| — drift statistic.
    drift_stat: Ewma,
    /// Threshold above which `drifted()` reports true.
    pub drift_threshold: f64,
    observations: usize,
    /// Correction-state version ([`CostModel::version`]): bumped whenever
    /// any corrector factor actually changes value (bitwise) and on every
    /// correction reset. With [`NullCorrector`]s the factors are constant,
    /// so the version never moves and memoized predictions stay valid.
    version: u64,
}

impl EnergyProfiler {
    /// Build with explicit corrector constructors (GRU at runtime,
    /// EWMA fallback, Null for the offline-only ablation). The factory is
    /// called four times: (cpu,lat), (cpu,en), (gpu,lat), (gpu,en).
    pub fn with_correctors<F: FnMut() -> Box<dyn Corrector>>(
        offline: OfflineModel,
        mut make: F,
    ) -> Self {
        EnergyProfiler {
            offline,
            corr: [
                UnitCorrection {
                    latency: make(),
                    energy: make(),
                },
                UnitCorrection {
                    latency: make(),
                    energy: make(),
                },
            ],
            transfer: TransferParams::sd855(),
            base_cache: RefCell::new((None, HashMap::new())),
            drift_stat: Ewma::new(0.15),
            drift_threshold: 0.07,
            observations: 0,
            version: 0,
        }
    }

    /// Back-compat constructor: a single corrector pair applied to both
    /// units is wasteful; prefer [`Self::with_correctors`]. Kept for tests.
    pub fn new(
        offline: OfflineModel,
        energy_corr: Box<dyn Corrector>,
        latency_corr: Box<dyn Corrector>,
    ) -> Self {
        let mut prof = Self::offline_only(offline);
        prof.corr[0] = UnitCorrection {
            latency: latency_corr,
            energy: energy_corr,
        };
        prof
    }

    /// GBDT-only profiler (ablation arm: no runtime correction).
    pub fn offline_only(offline: OfflineModel) -> Self {
        Self::with_correctors(offline, || Box::new(NullCorrector))
    }

    fn unit_model(&self, p: Proc) -> &super::calibrate::UnitModel {
        match p {
            Proc::Cpu => &self.offline.cpu,
            Proc::Gpu => &self.offline.gpu,
        }
    }

    /// Predicted compute-only (latency, energy) of the *full* op on unit
    /// `p` under the observable state, including runtime correction.
    /// Memoized per snapshot (see `base_cache`).
    fn unit_base(
        &self,
        op: &OpNode,
        p: Proc,
        ctx: &ExecCtx,
        snap: &Snapshot,
        split: bool,
    ) -> (f64, f64) {
        let snap = if split {
            Snapshot {
                bw_factor: snap.bw_factor * SPLIT_BW_FACTOR,
                ..*snap
            }
        } else {
            *snap
        };
        let snap = &snap;
        let flags = (split as u8) << 3
            | (p.index() as u8) << 2
            | (ctx.new_run_cpu as u8) << 1
            | ctx.new_run_gpu as u8;
        let key: BaseKey = (op.id, op.flops, flags);
        // the split-adjusted bw is deterministic given the split flag (in
        // the key), so the adjusted snapshot's identity is equivalent to
        // the caller's
        let id = snap_id(snap);
        {
            let cache = self.base_cache.borrow();
            if cache.0 == Some(id) {
                if let Some(&(lat, en)) = cache.1.get(&key) {
                    return (lat, en);
                }
            }
        }
        // Features use the single-unit placement (what calibration saw).
        let f = features::extract(op, Placement::Single(p), ctx, snap);
        let m = self.unit_model(p);
        let c = &self.corr[p.index()];
        let lat = m.latency.predict(&f).exp() * c.latency.factor();
        let en = m.energy.predict(&f).exp() * c.energy.factor();
        let mut cache = self.base_cache.borrow_mut();
        if cache.0 != Some(id) {
            cache.0 = Some(id);
            cache.1.clear();
        }
        cache.1.insert(key, (lat, en));
        (lat, en)
    }

    /// Analytic transfer terms for inputs not resident where needed.
    fn transfer_terms(&self, op: &OpNode, placement: Placement, ctx: &ExecCtx) -> (f64, f64) {
        let need_cpu = placement.frac_on(Proc::Cpu);
        let mut t = 0.0;
        let mut e = 0.0;
        for (shape, &have) in op.in_shapes.iter().zip(&ctx.input_cpu_fracs) {
            let bytes = boundary_bytes(shape.bytes(), have, need_cpu);
            t += self.transfer.time(bytes);
            e += self.transfer.energy(bytes);
        }
        (t, e)
    }

    fn compose(
        &self,
        op: &OpNode,
        placement: Placement,
        ctx: &ExecCtx,
        snap: &Snapshot,
    ) -> OpCost {
        let (tt, te) = self.transfer_terms(op, placement, ctx);
        let split = matches!(placement, Placement::Split { .. });
        let mut cpu_busy = 0.0;
        let mut gpu_busy = 0.0;
        let mut energy = te;
        for p in Proc::ALL {
            let frac = placement.frac_on(p);
            if frac == 0.0 {
                continue;
            }
            let (base_lat, base_en) = self.unit_base(op, p, ctx, snap, split);
            let dispatch = match (p, ctx.new_run_cpu, ctx.new_run_gpu) {
                (Proc::Cpu, true, _) => ComputeParams::sd855_cpu().dispatch_first,
                (Proc::Cpu, false, _) => ComputeParams::sd855_cpu().dispatch_next,
                (Proc::Gpu, _, true) => ComputeParams::sd855_gpu().dispatch_first,
                (Proc::Gpu, _, false) => ComputeParams::sd855_gpu().dispatch_next,
            };
            let t = base_lat * frac + dispatch;
            energy += base_en * frac;
            match p {
                Proc::Cpu => cpu_busy = t,
                Proc::Gpu => gpu_busy = t,
            }
        }
        let sync = if split { SPLIT_SYNC_S } else { 0.0 };
        OpCost {
            latency_s: tt + cpu_busy.max(gpu_busy) + sync,
            energy_j: energy,
            cpu_busy_s: cpu_busy,
            gpu_busy_s: gpu_busy,
            transfer_s: tt,
            transfer_j: te,
        }
    }

    /// Record an observed execution: updates the correctors of the units
    /// the op ran on plus the drift statistic.
    pub fn observe(
        &mut self,
        op: &OpNode,
        placement: Placement,
        ctx: &ExecCtx,
        snap: &Snapshot,
        measured: &OpCost,
    ) {
        // Correction factors before the update, to detect whether this
        // observation actually moved any of them (NullCorrectors never
        // move — their memo version must stay put).
        let factors_before = self.correction_factors();
        // Residual of the prediction as made (pre-update correction).
        let pred = self.compose(op, placement, ctx, snap);
        let re_total = (measured.energy_j.max(1e-12) / pred.energy_j.max(1e-12))
            .ln()
            .clamp(-2.0, 2.0);
        self.drift_stat.push(re_total.abs());
        self.observations += 1;

        // Per-unit attribution. Single-unit ops are unambiguous; for split
        // ops each unit's busy time is separately observable (per-queue
        // completion timestamps — what CoDL/MACE runtimes expose), so the
        // latency correctors update from busy times and the energy
        // correctors use the same residual (energy ≈ busy time × unit
        // power at fixed state).
        let split = matches!(placement, Placement::Split { .. });
        for p in Proc::ALL {
            let frac = placement.frac_on(p);
            if frac == 0.0 {
                continue;
            }
            let dispatch = match (p, ctx.new_run_cpu, ctx.new_run_gpu) {
                (Proc::Cpu, true, _) => ComputeParams::sd855_cpu().dispatch_first,
                (Proc::Cpu, false, _) => ComputeParams::sd855_cpu().dispatch_next,
                (Proc::Gpu, _, true) => ComputeParams::sd855_gpu().dispatch_first,
                (Proc::Gpu, _, false) => ComputeParams::sd855_gpu().dispatch_next,
            };
            // uncorrected GBDT base under the (possibly contended) state,
            // so the corrector accumulates the full factor
            let snap_q = if split {
                Snapshot {
                    bw_factor: snap.bw_factor * SPLIT_BW_FACTOR,
                    ..*snap
                }
            } else {
                *snap
            };
            let f = features::extract(op, Placement::Single(p), ctx, &snap_q);
            let m = self.unit_model(p);
            let base_lat = m.latency.predict(&f).exp();
            let base_en = m.energy.predict(&f).exp();
            let (obs_busy, obs_en) = match placement {
                Placement::Single(_) => {
                    let (tt, te) = self.transfer_terms(op, placement, ctx);
                    (
                        (measured.latency_s - tt - dispatch).max(1e-9),
                        Some((measured.energy_j - te).max(1e-12)),
                    )
                }
                Placement::Split { .. } => {
                    let busy = match p {
                        Proc::Cpu => measured.cpu_busy_s,
                        Proc::Gpu => measured.gpu_busy_s,
                    };
                    ((busy - dispatch).max(1e-9), None)
                }
            };
            let rl = (obs_busy / (base_lat * frac)).ln().clamp(-2.0, 2.0);
            let re = match obs_en {
                Some(e) => (e / (base_en * frac)).ln().clamp(-2.0, 2.0),
                None => rl, // time residual as energy proxy for splits
            };
            let c = &mut self.corr[p.index()];
            c.latency.observe(rl, snap);
            c.energy.observe(re, snap);
        }
        // correction factors changed → cached bases are stale
        self.base_cache.borrow_mut().0 = None;
        if self.correction_factors() != factors_before {
            self.version += 1;
        }
    }

    /// Bitwise identity of the four correction factors (cpu/gpu ×
    /// latency/energy) — what [`CostModel::version`] tracks.
    fn correction_factors(&self) -> [u64; 4] {
        [
            self.corr[0].latency.factor().to_bits(),
            self.corr[0].energy.factor().to_bits(),
            self.corr[1].latency.factor().to_bits(),
            self.corr[1].energy.factor().to_bits(),
        ]
    }

    /// True when recent prediction residuals exceed the threshold — the
    /// repartitioning trigger (paper §2.2: "fluctuations in energy
    /// consumption").
    pub fn drifted(&self) -> bool {
        self.observations >= 4
            && self.drift_stat.value().unwrap_or(0.0) > self.drift_threshold
    }

    /// Current drift statistic (diagnostics).
    pub fn drift_stat(&self) -> f64 {
        self.drift_stat.value().unwrap_or(0.0)
    }

    /// Reset correctors (after acting on a regime change).
    pub fn reset_correction(&mut self) {
        for c in &mut self.corr {
            c.latency.reset();
            c.energy.reset();
        }
        self.base_cache.borrow_mut().0 = None;
        self.drift_stat = Ewma::new(0.15);
        self.observations = 0;
        // resets always invalidate memoized predictions, even when the
        // factors happen to land back on their previous values
        self.version += 1;
    }

    /// Name of the installed corrector (`ewma`, `gru`, `null`).
    pub fn corrector_name(&self) -> &'static str {
        self.corr[0].energy.name()
    }
}

impl CostModel for EnergyProfiler {
    fn predict(
        &self,
        op: &OpNode,
        placement: Placement,
        ctx: &ExecCtx,
        snap: &Snapshot,
    ) -> OpCost {
        self.compose(op, placement, ctx, snap)
    }

    fn version(&self) -> Option<u64> {
        Some(self.version)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::profiler::calibrate::{calibrate, CalibConfig};
    use crate::profiler::corrector::EwmaCorrector;
    use crate::profiler::gbdt::GbdtParams;
    use crate::soc::device::DeviceConfig;
    use crate::workload::WorkloadCondition;

    fn quick_model() -> OfflineModel {
        calibrate(&CalibConfig {
            samples: 2000,
            seed: 17,
            gbdt: GbdtParams {
                trees: 80,
                ..Default::default()
            },
        })
    }

    fn frozen_moderate() -> Device {
        let mut dev = Device::new(DeviceConfig {
            drift_sigma: 0.0,
            noise_sigma: 0.0,
            ..DeviceConfig::snapdragon_855()
        });
        let mut c = WorkloadCondition::moderate().spec;
        c.cpu_bg_sigma = 0.0;
        c.cpu_burst = 0.0;
        c.gpu_bg_sigma = 0.0;
        c.gpu_burst = 0.0;
        c.drift_sigma = 0.0;
        dev.apply_condition(&c);
        dev
    }

    #[test]
    fn prediction_close_to_device_truth_single_units() {
        let prof = EnergyProfiler::offline_only(quick_model());
        let dev = frozen_moderate();
        let g = zoo::yolov2();
        let snap = dev.snapshot();
        for placement in [Placement::GPU, Placement::CPU] {
            let mut errs = Vec::new();
            for op in g.ops.iter().filter(|o| o.flops > 1_000_000) {
                let mut ctx = ExecCtx::fresh(vec![
                    placement.frac_on(Proc::Cpu);
                    op.in_shapes.len()
                ]);
                ctx.new_run_cpu = false;
                ctx.new_run_gpu = false;
                let pred = prof.predict(op, placement, &ctx, &snap);
                let truth = dev.expected_cost(op, placement, &ctx);
                errs.push((pred.energy_j / truth.energy_j).ln().abs());
            }
            let mean_abs: f64 = errs.iter().sum::<f64>() / errs.len() as f64;
            assert!(mean_abs < 0.30, "{placement}: mean |log err| = {mean_abs}");
        }
    }

    #[test]
    fn split_prediction_tracks_device_composition() {
        let prof = EnergyProfiler::offline_only(quick_model());
        let dev = frozen_moderate();
        let g = zoo::yolov2();
        let snap = dev.snapshot();
        let op = &g.ops[14]; // conv9, heavy
        for r in [0.1, 0.2, 0.3] {
            let placement = Placement::Split { cpu_frac: r };
            let mut ctx = ExecCtx::fresh(vec![r; op.in_shapes.len()]);
            ctx.new_run_cpu = false;
            ctx.new_run_gpu = false;
            let pred = prof.predict(op, placement, &ctx, &snap);
            let truth = dev.expected_cost(op, placement, &ctx);
            let err = (pred.latency_s / truth.latency_s).ln().abs();
            assert!(err < 0.6, "r={r}: latency log err {err}");
        }
    }

    #[test]
    fn corrector_fixes_systematic_drift() {
        let mut prof =
            EnergyProfiler::with_correctors(quick_model(), || Box::new(EwmaCorrector::new(0.4)));
        let g = zoo::yolov2();
        let op = &g.ops[2];
        let mut ctx = ExecCtx::fresh(vec![0.0]);
        ctx.new_run_cpu = false;
        ctx.new_run_gpu = false;
        let dev = frozen_moderate();
        let snap = dev.snapshot();
        let base = prof.predict(op, Placement::GPU, &ctx, &snap);
        let err_before = (1.0f64 / 1.4).ln().abs();
        for _ in 0..30 {
            let measured = OpCost {
                energy_j: base.energy_j * 1.4,
                latency_s: base.latency_s * 1.4,
                ..Default::default()
            };
            prof.observe(op, Placement::GPU, &ctx, &snap, &measured);
        }
        let after = prof.predict(op, Placement::GPU, &ctx, &snap);
        let err_after = (after.energy_j / (base.energy_j * 1.4)).ln().abs();
        assert!(err_after < err_before * 0.4, "{err_before} → {err_after}");
    }

    #[test]
    fn corrections_are_per_unit() {
        let mut prof =
            EnergyProfiler::with_correctors(quick_model(), || Box::new(EwmaCorrector::new(0.5)));
        let g = zoo::yolov2();
        let op = &g.ops[2];
        let mut gpu_ctx = ExecCtx::fresh(vec![0.0]);
        gpu_ctx.new_run_cpu = false;
        gpu_ctx.new_run_gpu = false;
        let mut cpu_ctx = ExecCtx::fresh(vec![1.0]);
        cpu_ctx.new_run_cpu = false;
        cpu_ctx.new_run_gpu = false;
        let dev = frozen_moderate();
        let snap = dev.snapshot();
        let cpu_before = prof.predict(op, Placement::CPU, &cpu_ctx, &snap);
        let gpu_before = prof.predict(op, Placement::GPU, &gpu_ctx, &snap);
        // feed 2× drift on GPU only
        for _ in 0..20 {
            let measured = OpCost {
                energy_j: gpu_before.energy_j * 2.0,
                latency_s: gpu_before.latency_s * 2.0,
                ..Default::default()
            };
            prof.observe(op, Placement::GPU, &gpu_ctx, &snap, &measured);
        }
        let cpu_after = prof.predict(op, Placement::CPU, &cpu_ctx, &snap);
        let gpu_after = prof.predict(op, Placement::GPU, &gpu_ctx, &snap);
        assert!(gpu_after.energy_j > gpu_before.energy_j * 1.5);
        assert!((cpu_after.energy_j / cpu_before.energy_j - 1.0).abs() < 0.05);
    }

    #[test]
    fn drift_flag_raises_then_subsides() {
        let mut prof =
            EnergyProfiler::with_correctors(quick_model(), || Box::new(EwmaCorrector::new(0.3)));
        let g = zoo::yolov2();
        let op = &g.ops[2];
        let mut ctx = ExecCtx::fresh(vec![0.0]);
        ctx.new_run_cpu = false;
        ctx.new_run_gpu = false;
        let dev = frozen_moderate();
        let snap = dev.snapshot();
        let base = prof.predict(op, Placement::GPU, &ctx, &snap);
        let mut seen_drift = false;
        for i in 0..60 {
            let measured = OpCost {
                energy_j: base.energy_j * 2.0,
                latency_s: base.latency_s * 2.0,
                ..Default::default()
            };
            prof.observe(op, Placement::GPU, &ctx, &snap, &measured);
            if i >= 4 && i < 12 && prof.drifted() {
                seen_drift = true;
            }
        }
        assert!(seen_drift, "drift never flagged");
        assert!(!prof.drifted(), "drift stuck high: {}", prof.drift_stat());
    }

    #[test]
    fn transfer_terms_added_to_prediction() {
        let prof = EnergyProfiler::offline_only(quick_model());
        let g = zoo::yolov2();
        let op = &g.ops[2];
        let dev = frozen_moderate();
        let snap = dev.snapshot();
        let local = prof.predict(op, Placement::GPU, &ExecCtx::fresh(vec![0.0]), &snap);
        let cross = prof.predict(op, Placement::GPU, &ExecCtx::fresh(vec![1.0]), &snap);
        assert!(cross.latency_s > local.latency_s);
        assert!(cross.transfer_s > 0.0 && local.transfer_s == 0.0);
    }
}
