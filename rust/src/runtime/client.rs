//! The `xla` crate wrapper: one CPU PJRT client, compile-once executable
//! cache keyed by artifact name, f32 tensor round-trips.
//!
//! HLO **text** is the interchange format (see aot.py and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile`. The python side lowers
//! with `return_tuple=True`, so results are unwrapped with `to_tuple1`.
//!
//! The real implementation needs the `xla` crate, which is not part of the
//! offline crate universe; it is gated behind the `pjrt` cargo feature.
//! Without the feature an API-identical stub compiles instead: manifests
//! still load (the registry is pure rust), but compiling/executing an
//! artifact returns a descriptive error. Everything downstream
//! ([`super::session`], the live coordinator, benches, examples) only
//! exercises the execution path when `artifacts/manifest.txt` exists, so
//! default builds stay fully green.

// The feature cannot build until the dependency exists — fail with an
// instruction instead of an opaque unresolved-crate error. Remove this
// guard together with adding the `xla` dependency.
#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature requires a vendored `xla` crate: add it to \
     rust/Cargo.toml and delete this compile_error! in runtime/client.rs"
);

#[cfg(feature = "pjrt")]
mod real {
    use std::collections::HashMap;
    use std::path::Path;

    use anyhow::{anyhow, Context, Result};

    use crate::runtime::registry::{ArtifactEntry, Manifest};

    /// A compiled artifact ready to execute.
    pub struct Compiled {
        exe: xla::PjRtLoadedExecutable,
        /// Manifest record this executable was compiled from.
        pub entry: ArtifactEntry,
    }

    impl Compiled {
        /// Execute on a flat f32 input of `entry.in_shape`; returns the flat
        /// f32 output of `entry.out_shape`.
        pub fn run_f32(&self, input: &[f32]) -> Result<Vec<f32>> {
            anyhow::ensure!(
                input.len() == self.entry.in_elems(),
                "input len {} != expected {} for {}",
                input.len(),
                self.entry.in_elems(),
                self.entry.name
            );
            let dims: Vec<i64> = self.entry.in_shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(input)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape input for {}: {e:?}", self.entry.name))?;
            let result = self
                .exe
                .execute::<xla::Literal>(&[lit])
                .map_err(|e| anyhow!("execute {}: {e:?}", self.entry.name))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result for {}: {e:?}", self.entry.name))?;
            // aot.py lowers with return_tuple=True → 1-tuple
            let out = result
                .to_tuple1()
                .map_err(|e| anyhow!("untuple result for {}: {e:?}", self.entry.name))?;
            let v = out
                .to_vec::<f32>()
                .map_err(|e| anyhow!("read f32s for {}: {e:?}", self.entry.name))?;
            anyhow::ensure!(
                v.len() == self.entry.out_elems(),
                "output len {} != expected {} for {}",
                v.len(),
                self.entry.out_elems(),
                self.entry.name
            );
            Ok(v)
        }
    }

    /// The PJRT runtime: client + manifest + executable cache.
    pub struct Runtime {
        client: xla::PjRtClient,
        /// The parsed artifact manifest.
        pub manifest: Manifest,
        cache: HashMap<String, Compiled>,
    }

    impl Runtime {
        /// Create a CPU PJRT client and load the manifest from `dir`.
        pub fn new(dir: &Path) -> Result<Runtime> {
            let manifest = Manifest::load(dir)?;
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
            Ok(Runtime {
                client,
                manifest,
                cache: HashMap::new(),
            })
        }

        /// PJRT platform name (e.g. `cpu`).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (or fetch from cache) an artifact by manifest name.
        pub fn load(&mut self, name: &str) -> Result<&Compiled> {
            if !self.cache.contains_key(name) {
                let entry = self
                    .manifest
                    .get(name)
                    .ok_or_else(|| anyhow!("unknown artifact `{name}`"))?
                    .clone();
                let proto = xla::HloModuleProto::from_text_file(
                    entry
                        .path
                        .to_str()
                        .context("artifact path not valid UTF-8")?,
                )
                .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", entry.path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compiling {}: {e:?}", entry.name))?;
                crate::log_debug!("compiled artifact {}", entry.name);
                self.cache.insert(name.to_string(), Compiled { exe, entry });
            }
            Ok(&self.cache[name])
        }

        /// Compile every artifact with the given name prefix (warm-up).
        pub fn load_prefix(&mut self, prefix: &str) -> Result<usize> {
            let names: Vec<String> = self
                .manifest
                .with_prefix(prefix)
                .iter()
                .map(|e| e.name.clone())
                .collect();
            for n in &names {
                self.load(n)?;
            }
            Ok(names.len())
        }

        /// One-shot convenience: load + run.
        pub fn run_f32(&mut self, name: &str, input: &[f32]) -> Result<Vec<f32>> {
            self.load(name)?.run_f32(input)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::path::Path;

    use anyhow::{anyhow, Result};

    use crate::runtime::registry::{ArtifactEntry, Manifest};

    fn unavailable(what: &str) -> anyhow::Error {
        anyhow!(
            "PJRT runtime unavailable for `{what}`: adaoper was built without the \
             `pjrt` cargo feature (the `xla` crate is not in the offline crate set)"
        )
    }

    /// Stub counterpart of the compiled-artifact handle.
    pub struct Compiled {
        /// Manifest record this handle refers to.
        pub entry: ArtifactEntry,
    }

    impl Compiled {
        /// Always errors: built without the `pjrt` feature.
        pub fn run_f32(&self, _input: &[f32]) -> Result<Vec<f32>> {
            Err(unavailable(&self.entry.name))
        }
    }

    /// Stub runtime: manifests parse (pure rust), execution errors out.
    pub struct Runtime {
        /// The parsed artifact manifest.
        pub manifest: Manifest,
    }

    impl Runtime {
        /// Load the manifest from `dir` (no PJRT client in the stub).
        pub fn new(dir: &Path) -> Result<Runtime> {
            let manifest = Manifest::load(dir)?;
            Ok(Runtime { manifest })
        }

        /// Placeholder platform string.
        pub fn platform(&self) -> String {
            "unavailable (built without the `pjrt` feature)".to_string()
        }

        /// Always errors: built without the `pjrt` feature.
        pub fn load(&mut self, name: &str) -> Result<&Compiled> {
            Err(unavailable(name))
        }

        /// Always errors: built without the `pjrt` feature.
        pub fn load_prefix(&mut self, prefix: &str) -> Result<usize> {
            Err(unavailable(prefix))
        }

        /// Always errors: built without the `pjrt` feature.
        pub fn run_f32(&mut self, name: &str, _input: &[f32]) -> Result<Vec<f32>> {
            Err(unavailable(name))
        }
    }
}

#[cfg(feature = "pjrt")]
pub use real::{Compiled, Runtime};
#[cfg(not(feature = "pjrt"))]
pub use stub::{Compiled, Runtime};
