//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the rust hot path —
//! python is never on the request path.
//!
//! * [`registry`] — parses `artifacts/manifest.txt` into an artifact index.
//! * [`client`] — the `xla` crate wrapper: CPU PJRT client, compile-once
//!   executable cache, f32 tensor round-trips.
//! * [`session`] — higher-level handles: the per-op executor the live
//!   coordinator uses ([`session::ArtifactExecutor`]) and the GRU
//!   corrector inference function for the profiler.

pub mod client;
pub mod registry;
pub mod session;

pub use client::Runtime;
pub use registry::{ArtifactEntry, Manifest};
