//! Artifact manifest parsing.
//!
//! `aot.py` writes `manifest.txt` with one line per artifact:
//! `name file in_shape out_shape` (shapes as `1x3x64x64`). The manifest is
//! the contract between the python build path and the rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One artifact record.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    /// Manifest key (e.g. `tiny-exec/conv0`).
    pub name: String,
    /// HLO text file, relative to the manifest dir.
    pub path: PathBuf,
    /// Flat input shape.
    pub in_shape: Vec<usize>,
    /// Flat output shape.
    pub out_shape: Vec<usize>,
}

impl ArtifactEntry {
    /// Input element count.
    pub fn in_elems(&self) -> usize {
        self.in_shape.iter().product()
    }
    /// Output element count.
    pub fn out_elems(&self) -> usize {
        self.out_shape.iter().product()
    }
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    entries: BTreeMap<String, ArtifactEntry>,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

fn parse_shape(s: &str) -> Result<Vec<usize>> {
    s.split('x')
        .map(|p| {
            p.parse::<usize>()
                .with_context(|| format!("bad shape component `{p}` in `{s}`"))
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (separated out for tests).
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut entries = BTreeMap::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 4 {
                bail!("manifest line {}: expected 4 fields, got {}", ln + 1, parts.len());
            }
            let entry = ArtifactEntry {
                name: parts[0].to_string(),
                path: dir.join(parts[1]),
                in_shape: parse_shape(parts[2])?,
                out_shape: parse_shape(parts[3])?,
            };
            if entries.insert(entry.name.clone(), entry).is_some() {
                bail!("manifest line {}: duplicate name {}", ln + 1, parts[0]);
            }
        }
        if entries.is_empty() {
            bail!("manifest is empty");
        }
        Ok(Manifest {
            entries,
            dir: dir.to_path_buf(),
        })
    }

    /// Entry by manifest key.
    pub fn get(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.get(name)
    }

    /// All manifest keys, sorted.
    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(|s| s.as_str()).collect()
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the manifest has no entries.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// All entries whose name starts with `prefix` (e.g. `tiny-exec/`).
    pub fn with_prefix(&self, prefix: &str) -> Vec<&ArtifactEntry> {
        self.entries
            .values()
            .filter(|e| e.name.starts_with(prefix))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# name file in_shape out_shape
tiny-exec/conv1 tiny_exec_conv1.hlo.txt 1x3x64x64 1x8x64x64
tiny-exec/pool1 tiny_exec_pool1.hlo.txt 1x8x64x64 1x8x32x32
gru/predict gru.hlo.txt 8x4 1
";

    #[test]
    fn parses_entries() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.len(), 3);
        let c = m.get("tiny-exec/conv1").unwrap();
        assert_eq!(c.in_shape, vec![1, 3, 64, 64]);
        assert_eq!(c.out_shape, vec![1, 8, 64, 64]);
        assert_eq!(c.in_elems(), 3 * 64 * 64);
        assert!(c.path.ends_with("tiny_exec_conv1.hlo.txt"));
    }

    #[test]
    fn prefix_filter() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.with_prefix("tiny-exec/").len(), 2);
        assert_eq!(m.with_prefix("gru/").len(), 1);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("a b c", Path::new("/")).is_err());
        assert!(Manifest::parse("a b 1xq 2", Path::new("/")).is_err());
        assert!(Manifest::parse("", Path::new("/")).is_err());
        let dup = "a f 1 1\na f 1 1\n";
        assert!(Manifest::parse(dup, Path::new("/")).is_err());
    }

    #[test]
    fn loads_real_manifest_if_built() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.get("tiny-exec/conv1").is_some());
        assert!(m.get("gru/predict").is_some());
        assert!(m.get("tiny-exec/full").is_some());
    }
}
