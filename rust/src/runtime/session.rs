//! High-level runtime handles wiring artifacts into the coordinator and
//! profiler:
//!
//! * [`ArtifactExecutor`] — implements the live coordinator's
//!   [`OpExecutor`]: op name → `tiny-exec/<op>` artifact → PJRT execute.
//! * [`gru_infer_fn`] — wraps `gru/predict` as the profiler's
//!   [`GruInferFn`] so the GRU corrector runs the real AOT network.

use std::path::Path;

use anyhow::{ensure, Result};

use crate::coordinator::live::OpExecutor;
use crate::profiler::corrector::{GruInferFn, GRU_IN_FEATURES};

use super::client::Runtime;

/// Per-op PJRT executor over the `tiny-exec/*` artifacts.
pub struct ArtifactExecutor {
    rt: Runtime,
}

impl ArtifactExecutor {
    /// Load and compile every `tiny-exec/*` artifact up front.
    pub fn new(artifacts_dir: &Path) -> Result<ArtifactExecutor> {
        let mut rt = Runtime::new(artifacts_dir)?;
        rt.load_prefix("tiny-exec/")?; // compile everything up front
        Ok(ArtifactExecutor { rt })
    }

    /// The underlying PJRT runtime.
    pub fn runtime(&mut self) -> &mut Runtime {
        &mut self.rt
    }
}

impl OpExecutor for ArtifactExecutor {
    fn execute(&mut self, model: &str, op_name: &str, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        ensure!(
            inputs.len() == 1,
            "tiny-exec ops are single-input, got {}",
            inputs.len()
        );
        let name = format!("{model}/{op_name}");
        self.rt.run_f32(&name, &inputs[0])
    }
}

/// Build a [`GruInferFn`] over the `gru/predict` artifact. The returned
/// closure owns its own runtime (PJRT clients stay on their thread).
pub fn gru_infer_fn(artifacts_dir: &Path, window_len: usize) -> Result<GruInferFn> {
    let mut rt = Runtime::new(artifacts_dir)?;
    rt.load("gru/predict")?;
    let expect = window_len * GRU_IN_FEATURES;
    Ok(Box::new(move |window: &[f32]| -> Result<f32> {
        ensure!(
            window.len() == expect,
            "gru window len {} != expected {}",
            window.len(),
            expect
        );
        let out = rt.run_f32("gru/predict", window)?;
        ensure!(out.len() == 1, "gru output len {}", out.len());
        Ok(out[0])
    }))
}

#[cfg(test)]
mod tests {
    // PJRT-dependent tests live in rust/tests/integration_runtime.rs and
    // are skipped when artifacts/ has not been built.
}
