//! Span-carrying diagnostics for scenario specs.
//!
//! Every decode/validate failure names the offending section and key and,
//! when the key can be located in the source text, its 1-based line
//! number — so `adaoper scenario run broken.toml` prints
//! `scenario spec error at line 14: [stream.cam] rate_hz: must be > 0`
//! instead of a bare panic or a context-free message.

use std::fmt;

/// One diagnostic: where in the spec, and what is wrong.
#[derive(Debug, Clone)]
pub struct Diag {
    /// Section path (`scenario`, `stream.cam`, `expect`, …); empty for
    /// file-level problems.
    pub section: String,
    /// Offending key inside the section, when one is identifiable.
    pub key: Option<String>,
    /// 1-based line in the source text, when the span could be resolved.
    pub line: Option<usize>,
    /// Human-readable description of the problem.
    pub msg: String,
}

impl fmt::Display for Diag {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "scenario spec error")?;
        if let Some(line) = self.line {
            write!(f, " at line {line}")?;
        }
        write!(f, ": ")?;
        if !self.section.is_empty() {
            write!(f, "[{}]", self.section)?;
        }
        if let Some(key) = &self.key {
            if self.section.is_empty() {
                write!(f, "{key}")?;
            } else {
                write!(f, " {key}")?;
            }
        }
        if !self.section.is_empty() || self.key.is_some() {
            write!(f, ": ")?;
        }
        write!(f, "{}", self.msg)
    }
}

/// Build a diagnostic [`anyhow::Error`], resolving the span by scanning
/// `src` for the section header / key assignment.
pub fn spec_err(
    src: &str,
    section: &str,
    key: Option<&str>,
    msg: impl fmt::Display,
) -> anyhow::Error {
    let diag = Diag {
        section: section.to_string(),
        key: key.map(str::to_string),
        line: find_line(src, section, key),
        msg: msg.to_string(),
    };
    anyhow::anyhow!("{diag}")
}

/// Locate `key` inside `[section]` (or the section header itself when
/// `key` is `None`) in the TOML source. Returns a 1-based line number, or
/// `None` when the item does not literally appear (e.g. a *missing*
/// required key).
pub fn find_line(src: &str, section: &str, key: Option<&str>) -> Option<usize> {
    let mut current = String::new();
    for (i, raw) in src.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            if let Some(inner) = rest.strip_suffix(']') {
                current = inner.trim().to_string();
                if key.is_none() && current == section {
                    return Some(i + 1);
                }
            }
            continue;
        }
        if let Some(k) = key {
            if current == section && key_of(&line) == Some(k) {
                return Some(i + 1);
            }
        }
    }
    None
}

/// The bare key of a `key = value` line (quoted keys unsupported here —
/// the spec grammar only uses bare keys).
fn key_of(line: &str) -> Option<&str> {
    let eq = line.find('=')?;
    Some(line[..eq].trim())
}

/// `#` starts a comment unless inside a basic string (same rule as the
/// TOML parser).
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        match c {
            '\\' if in_str => escaped = !escaped,
            '"' if !escaped => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => escaped = false,
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    const SRC: &str = "\
# header comment
[scenario]
name = \"x\"
duration_s = 2.0

[stream.cam]
model = \"yolov2-tiny\"
rate_hz = 30.0
";

    #[test]
    fn finds_keys_and_sections() {
        assert_eq!(find_line(SRC, "scenario", None), Some(2));
        assert_eq!(find_line(SRC, "scenario", Some("duration_s")), Some(4));
        assert_eq!(find_line(SRC, "stream.cam", None), Some(6));
        assert_eq!(find_line(SRC, "stream.cam", Some("rate_hz")), Some(8));
        assert_eq!(find_line(SRC, "stream.cam", Some("missing")), None);
        assert_eq!(find_line(SRC, "nope", None), None);
    }

    #[test]
    fn display_names_section_key_and_line() {
        let e = spec_err(SRC, "stream.cam", Some("rate_hz"), "must be > 0");
        let s = e.to_string();
        assert!(s.contains("line 8"), "{s}");
        assert!(s.contains("[stream.cam]"), "{s}");
        assert!(s.contains("rate_hz"), "{s}");
        assert!(s.contains("must be > 0"), "{s}");
    }

    #[test]
    fn missing_key_still_names_it() {
        let e = spec_err(SRC, "scenario", Some("name_missing"), "required key is absent");
        let s = e.to_string();
        assert!(!s.contains("line"), "{s}");
        assert!(s.contains("name_missing"), "{s}");
    }
}
