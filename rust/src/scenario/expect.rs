//! `[expect]` metric assertions: bounds a scenario's report must satisfy.
//!
//! A spec declares bounds (`p95_ms_max = 400`, `miss_pct_max = 10`, …);
//! after the run, [`evaluate`] checks each bound against a [`Metrics`]
//! view extracted from the [`ServingReport`] (or fleet aggregate) and
//! returns per-bound pass/fail results — this is what turns any scenario
//! file into a regression test.

use crate::fleet::FleetReport;
use crate::metrics::ServingReport;

/// The metric a bound constrains, and its direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExpectKey {
    /// Median latency upper bound, milliseconds.
    P50MsMax,
    /// 95th-percentile latency upper bound, milliseconds.
    P95MsMax,
    /// 99th-percentile latency upper bound, milliseconds.
    P99MsMax,
    /// Deadline-miss percentage upper bound.
    MissPctMax,
    /// Energy-per-request upper bound, millijoules.
    MjPerReqMax,
    /// Completed-throughput lower bound, Hz.
    ThroughputHzMin,
    /// Plan-cache hit-rate lower bound, percent.
    CacheHitPctMin,
    /// Mean formed-batch-size lower bound.
    MeanBatchMin,
    /// Completed-request-count lower bound.
    RequestsMin,
    /// Shed-request-count upper bound (admission drops).
    ShedMax,
    /// Plan-decision-count lower bound (audit log; needs telemetry).
    DecisionsMin,
    /// Worst per-processor plan-residual regression upper bound,
    /// milliseconds (audit log; needs telemetry).
    WorstResidualMsMax,
    /// Health-alert-count upper bound (needs the health monitor).
    AlertsMax,
    /// Profiler-drift-escalation lower bound (needs the health monitor).
    DriftAlertsMin,
}

impl ExpectKey {
    /// Parse a spec key (`p95_ms_max`, …).
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "p50_ms_max" => ExpectKey::P50MsMax,
            "p95_ms_max" => ExpectKey::P95MsMax,
            "p99_ms_max" => ExpectKey::P99MsMax,
            "miss_pct_max" => ExpectKey::MissPctMax,
            "mj_per_req_max" => ExpectKey::MjPerReqMax,
            "throughput_hz_min" => ExpectKey::ThroughputHzMin,
            "cache_hit_pct_min" => ExpectKey::CacheHitPctMin,
            "mean_batch_min" => ExpectKey::MeanBatchMin,
            "requests_min" => ExpectKey::RequestsMin,
            "shed_max" => ExpectKey::ShedMax,
            "decisions_min" => ExpectKey::DecisionsMin,
            "worst_residual_ms_max" => ExpectKey::WorstResidualMsMax,
            "alerts_max" => ExpectKey::AlertsMax,
            "drift_alerts_min" => ExpectKey::DriftAlertsMin,
            _ => return None,
        })
    }

    /// Canonical spec spelling.
    pub fn name(&self) -> &'static str {
        match self {
            ExpectKey::P50MsMax => "p50_ms_max",
            ExpectKey::P95MsMax => "p95_ms_max",
            ExpectKey::P99MsMax => "p99_ms_max",
            ExpectKey::MissPctMax => "miss_pct_max",
            ExpectKey::MjPerReqMax => "mj_per_req_max",
            ExpectKey::ThroughputHzMin => "throughput_hz_min",
            ExpectKey::CacheHitPctMin => "cache_hit_pct_min",
            ExpectKey::MeanBatchMin => "mean_batch_min",
            ExpectKey::RequestsMin => "requests_min",
            ExpectKey::ShedMax => "shed_max",
            ExpectKey::DecisionsMin => "decisions_min",
            ExpectKey::WorstResidualMsMax => "worst_residual_ms_max",
            ExpectKey::AlertsMax => "alerts_max",
            ExpectKey::DriftAlertsMin => "drift_alerts_min",
        }
    }

    /// Every key, for error messages and docs.
    pub fn all() -> [ExpectKey; 14] {
        [
            ExpectKey::P50MsMax,
            ExpectKey::P95MsMax,
            ExpectKey::P99MsMax,
            ExpectKey::MissPctMax,
            ExpectKey::MjPerReqMax,
            ExpectKey::ThroughputHzMin,
            ExpectKey::CacheHitPctMin,
            ExpectKey::MeanBatchMin,
            ExpectKey::RequestsMin,
            ExpectKey::ShedMax,
            ExpectKey::DecisionsMin,
            ExpectKey::WorstResidualMsMax,
            ExpectKey::AlertsMax,
            ExpectKey::DriftAlertsMin,
        ]
    }

    /// True for `*_min` keys (bound is a floor, not a ceiling).
    pub fn is_lower_bound(&self) -> bool {
        matches!(
            self,
            ExpectKey::ThroughputHzMin
                | ExpectKey::CacheHitPctMin
                | ExpectKey::MeanBatchMin
                | ExpectKey::RequestsMin
                | ExpectKey::DecisionsMin
                | ExpectKey::DriftAlertsMin
        )
    }

    /// True for keys sourced from the plan-decision audit log — the
    /// scenario runner force-enables engine telemetry when a spec
    /// declares one, so the bound never fails just because the audit was
    /// off.
    pub fn needs_telemetry(&self) -> bool {
        matches!(self, ExpectKey::DecisionsMin | ExpectKey::WorstResidualMsMax)
    }

    /// True for keys sourced from the health monitor — the scenario
    /// runner enables a default `[health]` config when a spec declares
    /// one without the section, so the bound never fails just because
    /// the monitor was off.
    pub fn needs_health(&self) -> bool {
        matches!(self, ExpectKey::AlertsMax | ExpectKey::DriftAlertsMin)
    }

    /// Keys the fleet aggregate can satisfy (per-class histograms carry
    /// latency/energy/miss but no plan-cache, batch, or shed detail).
    pub fn fleet_supported(&self) -> bool {
        matches!(
            self,
            ExpectKey::P50MsMax
                | ExpectKey::P95MsMax
                | ExpectKey::P99MsMax
                | ExpectKey::MissPctMax
                | ExpectKey::MjPerReqMax
                | ExpectKey::RequestsMin
                | ExpectKey::ShedMax
        )
    }
}

/// One bound from an `[expect]` section.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExpectBound {
    /// Which metric, and whether the bound is a floor or ceiling.
    pub key: ExpectKey,
    /// The bound value, in the key's unit.
    pub bound: f64,
}

/// The outcome of checking one bound.
#[derive(Debug, Clone)]
pub struct CheckResult {
    /// Spec spelling of the bound key.
    pub key: &'static str,
    /// The declared bound.
    pub bound: f64,
    /// The observed value (NaN when the report lacks the metric).
    pub actual: f64,
    /// Whether the bound held.
    pub pass: bool,
}

impl CheckResult {
    /// One rendered line: `ok  p95_ms_max: 312.40 <= 400`.
    pub fn render(&self) -> String {
        let mark = if self.pass { "ok  " } else { "FAIL" };
        format!("{mark} {}: actual {:.4} vs bound {}", self.key, self.actual, self.bound)
    }
}

/// Uniform metric view over single-engine and fleet reports. `None`
/// means the underlying report does not carry that metric.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    /// Median latency, ms.
    pub p50_ms: Option<f64>,
    /// 95th-percentile latency, ms.
    pub p95_ms: Option<f64>,
    /// 99th-percentile latency, ms.
    pub p99_ms: Option<f64>,
    /// Deadline-miss percentage.
    pub miss_pct: Option<f64>,
    /// Energy per completed request, millijoules.
    pub mj_per_req: Option<f64>,
    /// Completed throughput, Hz.
    pub throughput_hz: Option<f64>,
    /// Plan-cache hit rate, percent.
    pub cache_hit_pct: Option<f64>,
    /// Mean formed batch size.
    pub mean_batch: Option<f64>,
    /// Completed request count.
    pub requests: Option<f64>,
    /// Requests shed by admission.
    pub shed: Option<f64>,
    /// Plan decisions recorded by the audit log.
    pub decisions: Option<f64>,
    /// Worst (most positive) per-processor plan residual, ms.
    pub worst_residual_ms: Option<f64>,
    /// Health alerts (state transitions) recorded by the monitor.
    pub alerts: Option<f64>,
    /// Profiler-drift escalations recorded by the monitor.
    pub drift_alerts: Option<f64>,
}

impl Metrics {
    /// Extract the view from a single-engine [`ServingReport`].
    pub fn of_report(r: &ServingReport) -> Metrics {
        Metrics {
            p50_ms: r.latency.as_ref().map(|l| l.p50 * 1e3),
            p95_ms: r.latency_hist.as_ref().and_then(|h| h.quantile(0.95)).map(|v| v * 1e3),
            p99_ms: r.latency.as_ref().map(|l| l.p99 * 1e3),
            miss_pct: Some(r.miss_rate * 100.0),
            mj_per_req: Some(r.j_per_inference * 1e3),
            throughput_hz: Some(r.throughput_hz),
            cache_hit_pct: r.plan_cache.as_ref().map(|c| c.hit_rate() * 100.0),
            mean_batch: r.batch.as_ref().map(|b| b.mean_size()),
            requests: Some(r.requests as f64),
            shed: r.sched.as_ref().map(|s| s.shed() as f64),
            decisions: r.telemetry.as_ref().map(|t| t.decisions as f64),
            worst_residual_ms: r.telemetry.as_ref().and_then(|t| t.worst_regression_ms),
            alerts: r.health.as_ref().map(|h| h.alerts as f64),
            drift_alerts: r.health.as_ref().map(|h| h.drift_alerts as f64),
        }
    }

    /// Extract the view from a fleet-wide aggregate. `latency_ms` codes
    /// "no samples" as NaN, which correctly fails any latency bound.
    pub fn of_fleet(r: &FleetReport) -> Metrics {
        let agg = &r.fleet;
        Metrics {
            p50_ms: Some(agg.latency_ms(0.50)),
            p95_ms: Some(agg.latency_ms(0.95)),
            p99_ms: Some(agg.latency_ms(0.99)),
            miss_pct: Some(agg.miss_rate() * 100.0),
            mj_per_req: Some(agg.j_per_request() * 1e3),
            requests: Some(agg.completed as f64),
            shed: Some(agg.shed as f64),
            ..Metrics::default()
        }
    }

    fn value(&self, key: ExpectKey) -> Option<f64> {
        match key {
            ExpectKey::P50MsMax => self.p50_ms,
            ExpectKey::P95MsMax => self.p95_ms,
            ExpectKey::P99MsMax => self.p99_ms,
            ExpectKey::MissPctMax => self.miss_pct,
            ExpectKey::MjPerReqMax => self.mj_per_req,
            ExpectKey::ThroughputHzMin => self.throughput_hz,
            ExpectKey::CacheHitPctMin => self.cache_hit_pct,
            ExpectKey::MeanBatchMin => self.mean_batch,
            ExpectKey::RequestsMin => self.requests,
            ExpectKey::ShedMax => self.shed,
            ExpectKey::DecisionsMin => self.decisions,
            ExpectKey::WorstResidualMsMax => self.worst_residual_ms,
            ExpectKey::AlertsMax => self.alerts,
            ExpectKey::DriftAlertsMin => self.drift_alerts,
        }
    }
}

/// Check every bound against the metric view. A bound whose metric the
/// report lacks fails with `actual = NaN` — a spec asserting on a metric
/// the run never produced is a spec bug worth surfacing, not a pass.
pub fn evaluate(m: &Metrics, bounds: &[ExpectBound]) -> Vec<CheckResult> {
    bounds
        .iter()
        .map(|b| match m.value(b.key) {
            None => CheckResult { key: b.key.name(), bound: b.bound, actual: f64::NAN, pass: false },
            Some(actual) => {
                let pass = if b.key.is_lower_bound() { actual >= b.bound } else { actual <= b.bound };
                CheckResult { key: b.key.name(), bound: b.bound, actual, pass }
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_name_round_trip() {
        for key in ExpectKey::all() {
            assert_eq!(ExpectKey::parse(key.name()), Some(key));
        }
        assert_eq!(ExpectKey::parse("bogus"), None);
    }

    #[test]
    fn bounds_respect_direction() {
        let m = Metrics { p95_ms: Some(300.0), requests: Some(50.0), ..Metrics::default() };
        let checks = evaluate(
            &m,
            &[
                ExpectBound { key: ExpectKey::P95MsMax, bound: 400.0 },
                ExpectBound { key: ExpectKey::P95MsMax, bound: 200.0 },
                ExpectBound { key: ExpectKey::RequestsMin, bound: 10.0 },
                ExpectBound { key: ExpectKey::RequestsMin, bound: 100.0 },
            ],
        );
        assert_eq!(checks.iter().map(|c| c.pass).collect::<Vec<_>>(), [true, false, true, false]);
    }

    #[test]
    fn missing_metric_fails_loudly() {
        let m = Metrics::default();
        let checks = evaluate(&m, &[ExpectBound { key: ExpectKey::CacheHitPctMin, bound: 1.0 }]);
        assert!(!checks[0].pass);
        assert!(checks[0].actual.is_nan());
    }
}
