//! Lower a validated [`ScenarioSpec`] into executable engine state.
//!
//! Lowering is deliberately mechanical: every run-relevant knob in the
//! spec maps onto exactly one field of [`EngineConfig`] /
//! [`FleetRunConfig`], so a spec pins a run as completely as hand-written
//! code does. [`fingerprint`] renders the lowered config through
//! [`TraceMeta::header_line`] — the same line a recorded trace starts
//! with — giving a cheap equality witness for the round-trip tests
//! (`EngineConfig` intentionally has no `PartialEq`).

use anyhow::{bail, Result};

use crate::coordinator::engine::EngineConfig;
use crate::coordinator::{AdmissionPolicy, StreamSpec};
use crate::fleet::FleetRunConfig;
use crate::metrics::TraceMeta;
use crate::partition::plan::Objective;
use crate::scenario::expect::ExpectBound;
use crate::scenario::spec::{ObjectiveDef, ScenarioSpec};
use crate::workload::Arrival;

/// A spec lowered to runnable form.
#[derive(Debug, Clone)]
pub struct Lowered {
    /// Scenario name, for run output.
    pub name: String,
    /// Single-engine configuration (authoritative even in fleet mode for
    /// the shared knobs: seed, duration, policy, scheduler, …).
    pub cfg: EngineConfig,
    /// Streams in `[scenario].streams` order (ids 0..n); empty in fleet
    /// mode.
    pub streams: Vec<StreamSpec>,
    /// Metric assertions to evaluate after the run.
    pub expect: Vec<ExpectBound>,
    /// Present when the spec carries a `[fleet]` section.
    pub fleet: Option<FleetRunConfig>,
}

/// Lower a spec. Assumes [`validate`](crate::scenario::validate::validate)
/// already passed; residual impossibilities (unknown model despite
/// validation) still error rather than panic.
pub fn lower(spec: &ScenarioSpec) -> Result<Lowered> {
    let mut cfg = EngineConfig {
        policy: spec.policy,
        objective: objective(&spec.objective),
        condition: spec.condition,
        duration_s: spec.duration_s,
        seed: spec.seed,
        scheduler: spec.scheduler,
        admission: AdmissionPolicy::from_kind(spec.admission, spec.queue_limit.unwrap_or(0)),
        ..EngineConfig::default()
    };
    cfg.calib.samples = spec.calib.samples;
    cfg.calib.seed = spec.calib.seed;
    cfg.calib.gbdt.trees = spec.calib.trees;
    cfg.batching.policy = spec.batching.policy;
    cfg.batching.max = spec.batching.max;
    cfg.batching.wait_s = spec.batching.wait_ms / 1e3;
    cfg.plan_cache.capacity = spec.plan_cache.capacity;
    cfg.plan_cache.util_bucket = spec.plan_cache.util_bucket;
    cfg.plan_cache.freq_bucket_hz = spec.plan_cache.freq_bucket_mhz * 1e6;
    cfg.health = spec.health.clone();

    let mut timeline: Vec<_> = spec.timeline.iter().map(|t| (t.at_s, t.condition)).collect();
    timeline.sort_by(|a, b| a.0.total_cmp(&b.0));
    cfg.condition_timeline = timeline;

    let mut streams = Vec::new();
    for (id, name) in spec.stream_names.iter().enumerate() {
        let Some(def) = spec.streams.iter().find(|s| &s.name == name) else {
            bail!("stream `{name}` has no [stream.{name}] section (spec not validated?)");
        };
        let Some(model) = crate::graph::zoo::by_name(&def.model) else {
            bail!("[stream.{name}] model `{}` is not in the zoo (spec not validated?)", def.model);
        };
        let Some(arrival) = Arrival::parse(&def.arrival, def.rate_hz, def.jitter.unwrap_or(0.0))
        else {
            bail!(
                "[stream.{name}] arrival `{}` is not a known kind (spec not validated?)",
                def.arrival
            );
        };
        streams.push(StreamSpec::new(id, model, arrival, def.slo_ms / 1e3));
    }

    let fleet = spec.fleet.as_ref().map(|f| FleetRunConfig {
        devices: f.devices,
        threads: f.threads,
        seed: spec.seed,
        duration_s: spec.duration_s,
        policy: spec.policy,
        scheduler: spec.scheduler,
        admission: cfg.admission,
        batching: cfg.batching.clone(),
        calib: cfg.calib.clone(),
        health: cfg.health.clone(),
        ..FleetRunConfig::default()
    });

    Ok(Lowered { name: spec.name.clone(), cfg, streams, expect: spec.expect.clone(), fleet })
}

fn objective(def: &ObjectiveDef) -> Objective {
    match def {
        ObjectiveDef::MinEdp => Objective::MinEdp,
        ObjectiveDef::MinLatency => Objective::MinLatency,
        ObjectiveDef::MinEnergySlo { slo_ms } => {
            Objective::MinEnergyUnderSlo { slo_s: slo_ms / 1e3 }
        }
    }
}

/// A deterministic one-line digest of everything lowering produced: the
/// trace header of the lowered config plus the lowered stream set. Two
/// `Lowered` values with equal fingerprints run identically.
pub fn fingerprint(l: &Lowered) -> String {
    let meta = TraceMeta::of(&l.cfg, &l.streams);
    match &l.fleet {
        None => meta.header_line(),
        Some(f) => format!(
            "{} fleet(devices={},threads={})",
            meta.header_line(),
            f.devices,
            f.threads
        ),
    }
}
