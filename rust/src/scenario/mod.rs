//! Declarative scenario specs and trace replay: any serving setup — and
//! any recorded trace — becomes a regression test.
//!
//! The layer has two entry points:
//!
//! * **Specs** (`adaoper scenario run x.toml`): a TOML file declares the
//!   full run — policy/scheduler/admission, per-stream arrival processes
//!   and SLOs, a condition timeline (thermal/background-load regime
//!   changes mid-run), calibration/batching/plan-cache knobs, optionally
//!   a `[fleet]` section — plus `[expect]` metric bounds (p95, miss
//!   rate, mJ/req, cache hit rate, …) that turn the run into a pass/fail
//!   check. The pipeline is layered parse ([`crate::config::toml`]) →
//!   decode ([`spec`]) → validate ([`validate`]) → lower ([`lower`]) →
//!   run ([`runner`]); inconsistent specs are rejected with
//!   span-carrying diagnostics ([`diag`]), never panics.
//!
//! * **Replay** (`adaoper replay trace.jsonl`): a JSONL trace recorded
//!   by [`crate::metrics::TraceObserver::with_meta`] opens with a header
//!   carrying the recording run's full config; [`replay`] reconstructs
//!   it, feeds the recorded arrivals back through the sim kernel
//!   ([`crate::coordinator::Engine::run_replay`]), and checks the
//!   replayed report row against the recorded one byte for byte.
//!
//! A minimal spec:
//!
//! ```toml
//! [scenario]
//! name = "edf-under-load"
//! duration_s = 2.0
//! seed = 17
//! scheduler = "edf"
//! streams = ["cam"]
//!
//! [stream.cam]
//! model = "yolov2-tiny"
//! arrival = "poisson"
//! rate_hz = 30.0
//! slo_ms = 250.0
//!
//! [expect]
//! requests_min = 1
//! miss_pct_max = 100.0
//! ```

pub mod diag;
pub mod expect;
pub mod lower;
pub mod replay;
pub mod runner;
pub mod spec;
pub mod validate;

pub use diag::Diag;
pub use expect::{CheckResult, ExpectBound, ExpectKey, Metrics};
pub use lower::{fingerprint, lower, Lowered};
pub use replay::{replay_path, replay_str, ReplayOutcome};
pub use runner::{run_path, run_str, ScenarioOutcome};
pub use spec::ScenarioSpec;

use anyhow::Result;

/// Decode and validate a scenario spec from TOML source: the one-call
/// front door (`decode` + `validate`).
pub fn parse_spec(src: &str) -> Result<ScenarioSpec> {
    let spec = spec::decode(src)?;
    validate::validate(&spec, src)?;
    Ok(spec)
}

/// [`parse_spec`] for a file on disk.
pub fn parse_spec_file(path: &std::path::Path) -> Result<ScenarioSpec> {
    let src = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading scenario spec {}: {e}", path.display()))?;
    parse_spec(&src)
}
