//! Re-run a recorded JSONL trace through the sim kernel.
//!
//! A trace written by [`TraceObserver::with_meta`] opens with a
//! `trace_header` line carrying the full [`EngineConfig`] and stream set
//! of the recording run, and (optionally) closes with a `report` line
//! carrying the recorded [`ServingReport::row`]. Replay reconstructs the
//! config bit-for-bit from the header (floats are printed
//! shortest-round-trip, so `parse` recovers the exact bits), feeds the
//! recorded arrival population back through
//! [`Engine::run_replay`](crate::coordinator::Engine::run_replay), and
//! compares the replayed row against the recorded one — turning any
//! captured trace into a regression test.
//!
//! [`TraceObserver::with_meta`]: crate::metrics::TraceObserver::with_meta
//! [`EngineConfig`]: crate::coordinator::EngineConfig
//! [`ServingReport::row`]: crate::metrics::ServingReport::row

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::schema::{
    AdmissionKind, BatchPolicyKind, ConditionKind, PolicyKind, SchedulerKind,
};
use crate::coordinator::engine::{EngineConfig, PlannerInfo};
use crate::coordinator::{AdmissionPolicy, Engine, Request, StreamSpec};
use crate::partition::plan::Objective;
use crate::util::json::Json;
use crate::workload::Arrival;

/// The result of replaying a trace.
#[derive(Debug, Clone)]
pub struct ReplayOutcome {
    /// Report row produced by the replayed run.
    pub row: String,
    /// Report row recorded in the trace trailer, when present.
    pub recorded_row: Option<String>,
    /// Number of recorded arrivals fed back through the kernel.
    pub arrivals: usize,
}

impl ReplayOutcome {
    /// `Some(true)` when the replayed row matches the recorded one
    /// byte for byte; `None` when the trace carried no report trailer.
    pub fn matches(&self) -> Option<bool> {
        self.recorded_row.as_ref().map(|r| r == &self.row)
    }
}

/// Replay a trace given as JSONL text.
pub fn replay_str(jsonl: &str) -> Result<ReplayOutcome> {
    let mut header: Option<Json> = None;
    let mut recorded_row: Option<String> = None;
    let mut arrivals: Vec<Request> = Vec::new();

    for (i, line) in jsonl.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let obj = Json::parse(line).with_context(|| format!("trace line {}", i + 1))?;
        match obj.get("event").and_then(Json::as_str) {
            Some("trace_header") => {
                if header.is_some() {
                    bail!("trace line {}: duplicate trace_header", i + 1);
                }
                if !arrivals.is_empty() {
                    bail!("trace line {}: trace_header after request records", i + 1);
                }
                header = Some(obj);
            }
            Some("report") => {
                recorded_row = Some(obj.need_str("row")?.to_string());
            }
            // telemetry event lines (opt-in kernel/audit stream) and health
            // alerts carry no arrival state: replay re-derives everything
            // from the header
            Some(
                "batch_close" | "monitor_tick" | "replan" | "plan_decision" | "stage_timers"
                | "alert",
            ) => {}
            Some(other) => bail!("trace line {}: unknown event `{other}`", i + 1),
            None => {
                let req = Request {
                    id: obj.need_usize("id").with_context(|| format!("trace line {}", i + 1))?,
                    stream: obj
                        .need_usize("stream")
                        .with_context(|| format!("trace line {}", i + 1))?,
                    arrival_s: obj
                        .need_f64("arrival_s")
                        .with_context(|| format!("trace line {}", i + 1))?,
                    deadline_s: obj
                        .need_f64("deadline_s")
                        .with_context(|| format!("trace line {}", i + 1))?,
                };
                arrivals.push(req);
            }
        }
    }

    let Some(header) = header else {
        bail!(
            "trace has no trace_header line — record it with `adaoper serve --trace` \
             (TraceObserver::with_meta), headerless traces cannot be replayed"
        );
    };
    let (cfg, streams) = reconstruct(&header)?;

    let mut engine = Engine::new(cfg);
    let report = engine.run_replay(&streams, &arrivals, &mut [])?;
    Ok(ReplayOutcome { row: report.row(), recorded_row, arrivals: arrivals.len() })
}

/// Replay a trace file.
pub fn replay_path(path: &Path) -> Result<ReplayOutcome> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    replay_str(&text).with_context(|| format!("replaying trace {}", path.display()))
}

/// Rebuild the recording run's [`EngineConfig`] and stream set from the
/// `trace_header` object.
pub fn reconstruct(h: &Json) -> Result<(EngineConfig, Vec<StreamSpec>)> {
    let version = h.need_u64("version")?;
    if version != 1 {
        bail!("unsupported trace version {version} (this build replays version 1)");
    }

    let mut cfg = EngineConfig {
        policy: PolicyKind::parse(h.need_str("policy")?)?,
        objective: parse_objective(h.need_str("objective")?)?,
        condition: ConditionKind::parse(h.need_str("condition")?)?,
        duration_s: h.need_f64("duration_s")?,
        seed: h.need_u64("seed")?,
        window: h.need_usize("window")?,
        cooldown_ops: h.need_usize("cooldown_ops")?,
        monitor_period_s: h.need_f64("monitor_period_s")?,
        planner_info: match h.need_str("planner_info")? {
            "profiler" => PlannerInfo::Profiler,
            "oracle" => PlannerInfo::Oracle,
            other => bail!("unknown planner_info `{other}` in trace header"),
        },
        use_corrector: h.need_bool("use_corrector")?,
        scheduler: SchedulerKind::parse(h.need_str("scheduler")?)?,
        admission: AdmissionPolicy::from_kind(
            AdmissionKind::parse(h.need_str("admission")?)?,
            h.need_usize("queue_limit")?,
        ),
        ..EngineConfig::default()
    };

    cfg.batching.policy = BatchPolicyKind::parse(h.need_str("batch_policy")?)?;
    cfg.batching.max = h.need_usize("batch_max")?;
    cfg.batching.wait_s = h.need_f64("batch_wait_s")?;
    // optional marker (headers predating telemetry omit it); telemetry
    // never changes the virtual timeline, so the replayed row matches the
    // recorded one either way
    cfg.telemetry = h.get("telemetry").and_then(Json::as_bool).unwrap_or(false);
    // optional health config (headers predating the health layer omit it);
    // the monitor is write-only observation, but the reconstructed config
    // must match so the replayed report row — including its health
    // section — stays byte-identical to the recorded one
    cfg.health = match h.get("health") {
        None => None,
        Some(hc) => Some(crate::metrics::HealthConfig {
            fast_window_s: hc.need_f64("fast_window_s")?,
            slow_window_s: hc.need_f64("slow_window_s")?,
            slo_target: hc.need_f64("slo_target")?,
            burn_warn: hc.need_f64("burn_warn")?,
            burn_critical: hc.need_f64("burn_critical")?,
            energy_budget_mj: hc.need_f64("energy_budget_mj")?,
            drift_warn: hc.need_f64("drift_warn")?,
            drift_critical: hc.need_f64("drift_critical")?,
            queue_warn: hc.need_usize("queue_warn")?,
            queue_critical: hc.need_usize("queue_critical")?,
            clear_ratio: hc.need_f64("clear_ratio")?,
            min_samples: hc.need_u64("min_samples")?,
        }),
    };

    let calib = h.get("calib").ok_or_else(|| anyhow::anyhow!("trace header missing `calib`"))?;
    cfg.calib.samples = calib.need_usize("samples")?;
    cfg.calib.seed = calib.need_u64("seed")?;
    cfg.calib.gbdt.trees = calib.need_usize("trees")?;
    cfg.calib.gbdt.max_depth = calib.need_usize("max_depth")?;
    cfg.calib.gbdt.eta = calib.need_f64("eta")?;
    cfg.calib.gbdt.subsample = calib.need_f64("subsample")?;
    cfg.calib.gbdt.min_leaf = calib.need_usize("min_leaf")?;
    cfg.calib.gbdt.bins = calib.need_usize("bins")?;
    cfg.calib.gbdt.seed = calib.need_u64("gbdt_seed")?;

    let pc =
        h.get("plan_cache").ok_or_else(|| anyhow::anyhow!("trace header missing `plan_cache`"))?;
    cfg.plan_cache.capacity = pc.need_usize("capacity")?;
    cfg.plan_cache.freq_bucket_hz = pc.need_f64("freq_bucket_hz")?;
    cfg.plan_cache.util_bucket = pc.need_f64("util_bucket")?;
    cfg.plan_cache.temp_bucket_c = pc.need_f64("temp_bucket_c")?;
    cfg.plan_cache.bw_bucket = pc.need_f64("bw_bucket")?;

    let mut timeline = Vec::new();
    for entry in h.need_arr("timeline")? {
        timeline
            .push((entry.need_f64("at_s")?, ConditionKind::parse(entry.need_str("condition")?)?));
    }
    cfg.condition_timeline = timeline;

    let mut streams = Vec::new();
    for (i, s) in h.need_arr("streams")?.iter().enumerate() {
        let id = s.need_usize("id")?;
        if id != i {
            bail!("trace header stream {i} carries id {id} (ids must be their index)");
        }
        let model_name = s.need_str("model")?;
        let Some(model) = crate::graph::zoo::by_name(model_name) else {
            bail!("trace header stream {i} names unknown model `{model_name}`");
        };
        let arrival = parse_arrival(
            s.get("arrival")
                .ok_or_else(|| anyhow::anyhow!("trace header stream {i} missing `arrival`"))?,
        )?;
        streams.push(StreamSpec::new(id, model, arrival, s.need_f64("slo_s")?));
    }

    Ok((cfg, streams))
}

fn parse_objective(s: &str) -> Result<Objective> {
    if let Some(slo) = s.strip_prefix("min-energy-slo:") {
        let slo_s: f64 =
            slo.parse().with_context(|| format!("bad objective slo in trace header: `{s}`"))?;
        return Ok(Objective::MinEnergyUnderSlo { slo_s });
    }
    match s {
        "min-edp" => Ok(Objective::MinEdp),
        "min-latency" => Ok(Objective::MinLatency),
        other => bail!("unknown objective `{other}` in trace header"),
    }
}

fn parse_arrival(a: &Json) -> Result<Arrival> {
    match a.need_str("kind")? {
        "poisson" => Ok(Arrival::Poisson { hz: a.need_f64("hz")? }),
        "periodic" => {
            Ok(Arrival::Periodic { hz: a.need_f64("hz")?, jitter: a.need_f64("jitter")? })
        }
        "mmpp" => Ok(Arrival::Mmpp {
            hz_low: a.need_f64("hz_low")?,
            hz_high: a.need_f64("hz_high")?,
            dwell_low_s: a.need_f64("dwell_low_s")?,
            dwell_high_s: a.need_f64("dwell_high_s")?,
        }),
        other => bail!("unknown arrival kind `{other}` in trace header"),
    }
}
