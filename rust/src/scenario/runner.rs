//! Execute a scenario spec end to end: parse → validate → lower → run →
//! evaluate `[expect]` bounds. This is the engine behind
//! `adaoper scenario run` and the `make scenarios` CI gate.

use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::Engine;
use crate::scenario::expect::{evaluate, CheckResult, Metrics};
use crate::scenario::lower::lower;
use crate::scenario::parse_spec;

/// Everything a scenario run produced.
#[derive(Debug, Clone)]
pub struct ScenarioOutcome {
    /// Scenario name from the spec.
    pub name: String,
    /// The report row (single-engine) or rendered fleet report.
    pub row: String,
    /// Per-bound `[expect]` results (empty when the spec has none).
    pub checks: Vec<CheckResult>,
}

impl ScenarioOutcome {
    /// True when every `[expect]` bound held.
    pub fn passed(&self) -> bool {
        self.checks.iter().all(|c| c.pass)
    }

    /// Multi-line human-readable rendering.
    pub fn render(&self) -> String {
        let mut out = format!("scenario {}\n{}\n", self.name, self.row);
        for c in &self.checks {
            out.push_str("  ");
            out.push_str(&c.render());
            out.push('\n');
        }
        if self.checks.is_empty() {
            out.push_str("  (no [expect] bounds declared)\n");
        } else if self.passed() {
            out.push_str(&format!("  PASS ({} bounds)\n", self.checks.len()));
        } else {
            let failed = self.checks.iter().filter(|c| !c.pass).count();
            out.push_str(&format!("  FAIL ({failed}/{} bounds violated)\n", self.checks.len()));
        }
        out
    }
}

/// Run a spec given as TOML source text.
pub fn run_str(src: &str) -> Result<ScenarioOutcome> {
    let spec = parse_spec(src)?;
    let mut lowered = lower(&spec)?;
    // audit-sourced bounds (decisions_min, worst_residual_ms_max) need
    // the plan-decision log; enable it rather than failing on a missing
    // metric. Telemetry never perturbs the virtual timeline, so every
    // other bound sees identical numbers either way.
    if lowered.expect.iter().any(|b| b.key.needs_telemetry()) {
        lowered.cfg.telemetry = true;
    }
    // likewise for health-sourced bounds (alerts_max, drift_alerts_min):
    // a spec asserting on alerts without a [health] section gets the
    // default monitor config instead of a guaranteed-NaN failure
    if lowered.expect.iter().any(|b| b.key.needs_health()) && lowered.cfg.health.is_none() {
        lowered.cfg.health = Some(crate::metrics::HealthConfig::default());
    }
    let (row, metrics) = match &lowered.fleet {
        Some(fleet_cfg) => {
            let report = crate::fleet::run_fleet(fleet_cfg)?;
            (report.render(), Metrics::of_fleet(&report))
        }
        None => {
            let mut engine = Engine::new(lowered.cfg.clone());
            let report = engine.run(&lowered.streams)?;
            (report.row(), Metrics::of_report(&report))
        }
    };
    let checks = evaluate(&metrics, &lowered.expect);
    Ok(ScenarioOutcome { name: lowered.name, row, checks })
}

/// Run a spec file.
pub fn run_path(path: &Path) -> Result<ScenarioOutcome> {
    let src = std::fs::read_to_string(path)
        .with_context(|| format!("reading scenario spec {}", path.display()))?;
    run_str(&src).with_context(|| format!("running scenario spec {}", path.display()))
}

/// Every `*.toml` under `dir`, sorted by file name — the iteration order
/// of `adaoper scenario run <dir>`.
pub fn spec_files(dir: &Path) -> Result<Vec<std::path::PathBuf>> {
    let mut files = Vec::new();
    for entry in
        std::fs::read_dir(dir).with_context(|| format!("listing scenario dir {}", dir.display()))?
    {
        let path = entry?.path();
        if path.extension().is_some_and(|e| e == "toml") {
            files.push(path);
        }
    }
    files.sort();
    Ok(files)
}
