//! Typed scenario spec: the decoded form of a `*.toml` scenario file.
//!
//! [`decode`] turns parsed TOML into a [`ScenarioSpec`], rejecting
//! unknown sections/keys and mistyped values with span-carrying
//! diagnostics ([`crate::scenario::diag`]). Shape errors (wrong type,
//! unknown enum spelling, unknown key) are caught here; cross-field
//! semantic errors (dangling stream refs, overlapping timelines, …) are
//! the job of [`crate::scenario::validate`].
//!
//! [`ScenarioSpec::emit`] writes the spec back out as canonical TOML such
//! that `decode(parse(spec.emit()))` reproduces the spec field-for-field
//! — the round-trip property the `scenario_roundtrip` test leans on.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::schema::{
    AdmissionKind, BatchPolicyKind, ConditionKind, PolicyKind, SchedulerKind,
};
use crate::config::toml::Value;
use crate::metrics::HealthConfig;
use crate::scenario::diag::spec_err;
use crate::scenario::expect::{ExpectBound, ExpectKey};

/// Optimisation objective as spelled in a spec file.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ObjectiveDef {
    /// Minimise the energy-delay product (default).
    MinEdp,
    /// Minimise latency regardless of energy.
    MinLatency,
    /// Minimise energy subject to a latency ceiling.
    MinEnergySlo {
        /// The latency ceiling in milliseconds.
        slo_ms: f64,
    },
}

impl ObjectiveDef {
    /// Canonical spelling for `objective =` lines.
    pub fn name(&self) -> &'static str {
        match self {
            ObjectiveDef::MinEdp => "min-edp",
            ObjectiveDef::MinLatency => "min-latency",
            ObjectiveDef::MinEnergySlo { .. } => "min-energy-slo",
        }
    }
}

/// One `[stream.<name>]` section.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamDef {
    /// Section name; referenced from `[scenario].streams`.
    pub name: String,
    /// Model zoo key (`yolov2-tiny`, `mobilenetv1`, …).
    pub model: String,
    /// Arrival process kind: `poisson`, `periodic`, or `mmpp`.
    pub arrival: String,
    /// Mean arrival rate in Hz.
    pub rate_hz: f64,
    /// Periodic jitter fraction; only meaningful for `periodic`.
    pub jitter: Option<f64>,
    /// Per-request deadline in milliseconds.
    pub slo_ms: f64,
}

/// One `[timeline.<label>]` section: a condition change at a point in
/// simulated time.
#[derive(Debug, Clone, PartialEq)]
pub struct TimelineDef {
    /// Section label (documentation only; uniqueness enforced by TOML).
    pub label: String,
    /// Simulated time of the regime change, seconds from start.
    pub at_s: f64,
    /// Condition the device switches to.
    pub condition: ConditionKind,
}

/// `[calib]` — offline profiler calibration knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct CalibDef {
    /// Synthetic calibration samples to draw.
    pub samples: usize,
    /// Calibration PRNG seed.
    pub seed: u64,
    /// GBDT ensemble size.
    pub trees: usize,
}

impl Default for CalibDef {
    fn default() -> Self {
        let d = crate::profiler::calibrate::CalibConfig::default();
        CalibDef { samples: d.samples, seed: d.seed, trees: d.gbdt.trees }
    }
}

/// `[batching]` — dynamic batch formation knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchDef {
    /// Formation policy.
    pub policy: BatchPolicyKind,
    /// Maximum batch size.
    pub max: usize,
    /// Maximum formation wait in milliseconds.
    pub wait_ms: f64,
}

impl Default for BatchDef {
    fn default() -> Self {
        let d = crate::batching::BatchConfig::default();
        BatchDef { policy: d.policy, max: d.max, wait_ms: d.wait_s * 1e3 }
    }
}

/// `[plan_cache]` — partition plan cache knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheDef {
    /// Cache capacity (0 disables caching).
    pub capacity: usize,
    /// Utilisation quantisation bucket width.
    pub util_bucket: f64,
    /// Frequency quantisation bucket width in MHz.
    pub freq_bucket_mhz: f64,
}

impl Default for CacheDef {
    fn default() -> Self {
        let d = crate::coordinator::PlanCacheConfig::default();
        CacheDef {
            capacity: d.capacity,
            util_bucket: d.util_bucket,
            freq_bucket_mhz: d.freq_bucket_hz / 1e6,
        }
    }
}

/// `[fleet]` — when present, the scenario runs through the fleet
/// simulator (device-class zoo) instead of a single engine.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetDef {
    /// Number of simulated devices.
    pub devices: usize,
    /// Worker threads for the sharded runner.
    pub threads: usize,
}

/// A fully decoded scenario spec.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    /// Scenario name (required, non-empty).
    pub name: String,
    /// Simulated duration in seconds.
    pub duration_s: f64,
    /// Engine seed — the single source of all run randomness.
    pub seed: u64,
    /// Partition policy under test.
    pub policy: PolicyKind,
    /// Optimisation objective.
    pub objective: ObjectiveDef,
    /// Dispatch scheduler.
    pub scheduler: SchedulerKind,
    /// Admission policy kind.
    pub admission: AdmissionKind,
    /// Per-stream queue bound; only valid with `admission = "bounded"`.
    pub queue_limit: Option<usize>,
    /// Initial workload condition.
    pub condition: ConditionKind,
    /// Ordered stream references — defines stream ids 0..n.
    pub stream_names: Vec<String>,
    /// Decoded `[stream.*]` sections (file order).
    pub streams: Vec<StreamDef>,
    /// Decoded `[timeline.*]` sections (file order; lowered sorted).
    pub timeline: Vec<TimelineDef>,
    /// Calibration knobs.
    pub calib: CalibDef,
    /// Batching knobs.
    pub batching: BatchDef,
    /// Plan cache knobs.
    pub plan_cache: CacheDef,
    /// Fleet-mode switch.
    pub fleet: Option<FleetDef>,
    /// `[health]` — streaming health monitor (SLO burn-rate, energy
    /// budget, drift, queue-depth alerting). `None` keeps the engine
    /// alert-free and every output byte-identical to a health-less build.
    pub health: Option<HealthConfig>,
    /// `[expect]` metric assertions.
    pub expect: Vec<ExpectBound>,
}

const ROOT_SECTIONS: &[&str] = &[
    "scenario", "calib", "batching", "plan_cache", "stream", "timeline", "fleet", "health",
    "expect",
];
const SCENARIO_KEYS: &[&str] = &[
    "name", "duration_s", "seed", "policy", "objective", "objective_slo_ms", "scheduler",
    "admission", "queue_limit", "condition", "streams",
];
const STREAM_KEYS: &[&str] = &["model", "arrival", "rate_hz", "jitter", "slo_ms"];
const TIMELINE_KEYS: &[&str] = &["at_s", "condition"];
const CALIB_KEYS: &[&str] = &["samples", "seed", "trees"];
const BATCH_KEYS: &[&str] = &["policy", "max", "wait_ms"];
const CACHE_KEYS: &[&str] = &["capacity", "util_bucket", "freq_bucket_mhz"];
const FLEET_KEYS: &[&str] = &["devices", "threads"];
const HEALTH_KEYS: &[&str] = &[
    "fast_window_s", "slow_window_s", "slo_target", "burn_warn", "burn_critical",
    "energy_budget_mj", "drift_warn", "drift_critical", "queue_warn", "queue_critical",
    "clear_ratio", "min_samples",
];

/// Decode TOML source into a [`ScenarioSpec`]. Shape errors carry spans;
/// call [`crate::scenario::validate::validate`] afterwards for semantic
/// checks (or use [`crate::scenario::parse_spec`] which does both).
pub fn decode(src: &str) -> Result<ScenarioSpec> {
    let root = crate::config::toml::parse(src)?;
    let root = root
        .as_table()
        .ok_or_else(|| spec_err(src, "", None, "spec root is not a table"))?;

    for key in root.keys() {
        if !ROOT_SECTIONS.contains(&key.as_str()) {
            return Err(spec_err(
                src,
                key,
                None,
                format!("unknown section (expected one of {})", ROOT_SECTIONS.join(", ")),
            ));
        }
    }

    let scen = section(src, root, "scenario", true)?
        .expect("required section checked above");
    check_keys(src, scen, "scenario", SCENARIO_KEYS)?;

    let name = need_str(src, scen, "scenario", "name")?;
    let duration_s = need_f64(src, scen, "scenario", "duration_s")?;
    let seed = opt_u64(src, scen, "scenario", "seed", 7)?;
    let policy = parse_kind(src, scen, "scenario", "policy", "adaoper", PolicyKind::parse)?;
    let scheduler = parse_kind(src, scen, "scenario", "scheduler", "fifo", SchedulerKind::parse)?;
    let admission =
        parse_kind(src, scen, "scenario", "admission", "admit-all", AdmissionKind::parse)?;
    let condition = parse_kind(src, scen, "scenario", "condition", "moderate", ConditionKind::parse)?;
    let queue_limit = match scen.get("queue_limit") {
        Some(v) => Some(usize_of(src, "scenario", "queue_limit", v)?),
        None => None,
    };
    let objective = decode_objective(src, scen)?;
    let stream_names = match scen.get("streams") {
        None => Vec::new(),
        Some(v) => {
            let arr = v.as_array().ok_or_else(|| {
                spec_err(src, "scenario", Some("streams"), "must be an array of stream names")
            })?;
            let mut names = Vec::new();
            for item in arr {
                let s = item.as_str().ok_or_else(|| {
                    spec_err(src, "scenario", Some("streams"), "stream names must be strings")
                })?;
                names.push(s.to_string());
            }
            names
        }
    };

    let calib = match section(src, root, "calib", false)? {
        None => CalibDef::default(),
        Some(t) => {
            check_keys(src, t, "calib", CALIB_KEYS)?;
            let d = CalibDef::default();
            CalibDef {
                samples: opt_usize(src, t, "calib", "samples", d.samples)?,
                seed: opt_u64(src, t, "calib", "seed", d.seed)?,
                trees: opt_usize(src, t, "calib", "trees", d.trees)?,
            }
        }
    };

    let batching = match section(src, root, "batching", false)? {
        None => BatchDef::default(),
        Some(t) => {
            check_keys(src, t, "batching", BATCH_KEYS)?;
            let d = BatchDef::default();
            BatchDef {
                policy: parse_kind(src, t, "batching", "policy", d.policy.name(), BatchPolicyKind::parse)?,
                max: opt_usize(src, t, "batching", "max", d.max)?,
                wait_ms: opt_f64(src, t, "batching", "wait_ms", d.wait_ms)?,
            }
        }
    };

    let plan_cache = match section(src, root, "plan_cache", false)? {
        None => CacheDef::default(),
        Some(t) => {
            check_keys(src, t, "plan_cache", CACHE_KEYS)?;
            let d = CacheDef::default();
            CacheDef {
                capacity: opt_usize(src, t, "plan_cache", "capacity", d.capacity)?,
                util_bucket: opt_f64(src, t, "plan_cache", "util_bucket", d.util_bucket)?,
                freq_bucket_mhz: opt_f64(src, t, "plan_cache", "freq_bucket_mhz", d.freq_bucket_mhz)?,
            }
        }
    };

    let fleet = match section(src, root, "fleet", false)? {
        None => None,
        Some(t) => {
            check_keys(src, t, "fleet", FLEET_KEYS)?;
            Some(FleetDef {
                devices: opt_usize(src, t, "fleet", "devices", 10)?,
                threads: opt_usize(src, t, "fleet", "threads", 4)?,
            })
        }
    };

    let health = match section(src, root, "health", false)? {
        None => None,
        Some(t) => {
            check_keys(src, t, "health", HEALTH_KEYS)?;
            let d = HealthConfig::default();
            Some(HealthConfig {
                fast_window_s: opt_f64(src, t, "health", "fast_window_s", d.fast_window_s)?,
                slow_window_s: opt_f64(src, t, "health", "slow_window_s", d.slow_window_s)?,
                slo_target: opt_f64(src, t, "health", "slo_target", d.slo_target)?,
                burn_warn: opt_f64(src, t, "health", "burn_warn", d.burn_warn)?,
                burn_critical: opt_f64(src, t, "health", "burn_critical", d.burn_critical)?,
                energy_budget_mj: opt_f64(src, t, "health", "energy_budget_mj", d.energy_budget_mj)?,
                drift_warn: opt_f64(src, t, "health", "drift_warn", d.drift_warn)?,
                drift_critical: opt_f64(src, t, "health", "drift_critical", d.drift_critical)?,
                queue_warn: opt_usize(src, t, "health", "queue_warn", d.queue_warn)?,
                queue_critical: opt_usize(src, t, "health", "queue_critical", d.queue_critical)?,
                clear_ratio: opt_f64(src, t, "health", "clear_ratio", d.clear_ratio)?,
                min_samples: opt_u64(src, t, "health", "min_samples", d.min_samples)?,
            })
        }
    };

    let mut streams = Vec::new();
    if let Some(group) = root.get("stream") {
        let tables = group.as_table().ok_or_else(|| {
            spec_err(src, "stream", None, "must be a group of [stream.<name>] sections")
        })?;
        for (sname, sub) in tables {
            let sect = format!("stream.{sname}");
            let t = sub
                .as_table()
                .ok_or_else(|| spec_err(src, &sect, None, "must be a table"))?;
            check_keys(src, t, &sect, STREAM_KEYS)?;
            let jitter = match t.get("jitter") {
                Some(v) => Some(f64_of(src, &sect, "jitter", v)?),
                None => None,
            };
            streams.push(StreamDef {
                name: sname.clone(),
                model: need_str(src, t, &sect, "model")?,
                arrival: need_str(src, t, &sect, "arrival")?,
                rate_hz: need_f64(src, t, &sect, "rate_hz")?,
                jitter,
                slo_ms: need_f64(src, t, &sect, "slo_ms")?,
            });
        }
    }

    let mut timeline = Vec::new();
    if let Some(group) = root.get("timeline") {
        let tables = group.as_table().ok_or_else(|| {
            spec_err(src, "timeline", None, "must be a group of [timeline.<label>] sections")
        })?;
        for (label, sub) in tables {
            let sect = format!("timeline.{label}");
            let t = sub
                .as_table()
                .ok_or_else(|| spec_err(src, &sect, None, "must be a table"))?;
            check_keys(src, t, &sect, TIMELINE_KEYS)?;
            timeline.push(TimelineDef {
                label: label.clone(),
                at_s: need_f64(src, t, &sect, "at_s")?,
                condition: parse_kind(src, t, &sect, "condition", "", ConditionKind::parse)?,
            });
        }
    }

    let mut expect = Vec::new();
    if let Some(v) = root.get("expect") {
        let t = v
            .as_table()
            .ok_or_else(|| spec_err(src, "expect", None, "must be a table of bounds"))?;
        for (key, val) in t {
            let ek = ExpectKey::parse(key).ok_or_else(|| {
                spec_err(
                    src,
                    "expect",
                    Some(key),
                    format!(
                        "unknown expectation (expected one of {})",
                        ExpectKey::all().iter().map(|k| k.name()).collect::<Vec<_>>().join(", ")
                    ),
                )
            })?;
            let bound = f64_of(src, "expect", key, val)?;
            expect.push(ExpectBound { key: ek, bound });
        }
    }

    Ok(ScenarioSpec {
        name,
        duration_s,
        seed,
        policy,
        objective,
        scheduler,
        admission,
        queue_limit,
        condition,
        stream_names,
        streams,
        timeline,
        calib,
        batching,
        plan_cache,
        fleet,
        health,
        expect,
    })
}

fn decode_objective(src: &str, scen: &BTreeMap<String, Value>) -> Result<ObjectiveDef> {
    let name = opt_str(src, scen, "scenario", "objective", "min-edp")?;
    let slo_ms = scen.get("objective_slo_ms");
    match name.as_str() {
        "min-edp" | "edp" => match slo_ms {
            None => Ok(ObjectiveDef::MinEdp),
            Some(_) => Err(spec_err(
                src,
                "scenario",
                Some("objective_slo_ms"),
                "only valid with objective = \"min-energy-slo\"",
            )),
        },
        "min-latency" | "latency" => match slo_ms {
            None => Ok(ObjectiveDef::MinLatency),
            Some(_) => Err(spec_err(
                src,
                "scenario",
                Some("objective_slo_ms"),
                "only valid with objective = \"min-energy-slo\"",
            )),
        },
        "min-energy-slo" | "energy-slo" => {
            let v = slo_ms.ok_or_else(|| {
                spec_err(
                    src,
                    "scenario",
                    Some("objective_slo_ms"),
                    "required when objective = \"min-energy-slo\"",
                )
            })?;
            Ok(ObjectiveDef::MinEnergySlo { slo_ms: f64_of(src, "scenario", "objective_slo_ms", v)? })
        }
        other => Err(spec_err(
            src,
            "scenario",
            Some("objective"),
            format!("unknown objective `{other}` (expected min-edp, min-latency, or min-energy-slo)"),
        )),
    }
}

fn section<'a>(
    src: &str,
    root: &'a BTreeMap<String, Value>,
    name: &str,
    required: bool,
) -> Result<Option<&'a BTreeMap<String, Value>>> {
    match root.get(name) {
        None if required => Err(spec_err(src, name, None, "required section is missing")),
        None => Ok(None),
        Some(v) => v
            .as_table()
            .map(Some)
            .ok_or_else(|| spec_err(src, name, None, "must be a table")),
    }
}

fn check_keys(
    src: &str,
    table: &BTreeMap<String, Value>,
    sect: &str,
    allowed: &[&str],
) -> Result<()> {
    for key in table.keys() {
        if !allowed.contains(&key.as_str()) {
            return Err(spec_err(
                src,
                sect,
                Some(key),
                format!("unknown key (expected one of {})", allowed.join(", ")),
            ));
        }
    }
    Ok(())
}

fn f64_of(src: &str, sect: &str, key: &str, v: &Value) -> Result<f64> {
    v.as_float()
        .ok_or_else(|| spec_err(src, sect, Some(key), "must be a number"))
}

fn usize_of(src: &str, sect: &str, key: &str, v: &Value) -> Result<usize> {
    let i = v
        .as_int()
        .ok_or_else(|| spec_err(src, sect, Some(key), "must be an integer"))?;
    usize::try_from(i).map_err(|_| spec_err(src, sect, Some(key), "must be non-negative"))
}

fn need_str(src: &str, t: &BTreeMap<String, Value>, sect: &str, key: &str) -> Result<String> {
    match t.get(key) {
        None => Err(spec_err(src, sect, Some(key), "required key is missing")),
        Some(v) => v
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| spec_err(src, sect, Some(key), "must be a string")),
    }
}

fn need_f64(src: &str, t: &BTreeMap<String, Value>, sect: &str, key: &str) -> Result<f64> {
    match t.get(key) {
        None => Err(spec_err(src, sect, Some(key), "required key is missing")),
        Some(v) => f64_of(src, sect, key, v),
    }
}

fn opt_f64(
    src: &str,
    t: &BTreeMap<String, Value>,
    sect: &str,
    key: &str,
    default: f64,
) -> Result<f64> {
    match t.get(key) {
        None => Ok(default),
        Some(v) => f64_of(src, sect, key, v),
    }
}

fn opt_usize(
    src: &str,
    t: &BTreeMap<String, Value>,
    sect: &str,
    key: &str,
    default: usize,
) -> Result<usize> {
    match t.get(key) {
        None => Ok(default),
        Some(v) => usize_of(src, sect, key, v),
    }
}

fn opt_u64(
    src: &str,
    t: &BTreeMap<String, Value>,
    sect: &str,
    key: &str,
    default: u64,
) -> Result<u64> {
    match t.get(key) {
        None => Ok(default),
        Some(v) => {
            let i = v
                .as_int()
                .ok_or_else(|| spec_err(src, sect, Some(key), "must be an integer"))?;
            u64::try_from(i).map_err(|_| spec_err(src, sect, Some(key), "must be non-negative"))
        }
    }
}

fn opt_str(
    src: &str,
    t: &BTreeMap<String, Value>,
    sect: &str,
    key: &str,
    default: &str,
) -> Result<String> {
    match t.get(key) {
        None => Ok(default.to_string()),
        Some(v) => v
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| spec_err(src, sect, Some(key), "must be a string")),
    }
}

fn parse_kind<K>(
    src: &str,
    t: &BTreeMap<String, Value>,
    sect: &str,
    key: &str,
    default: &str,
    parse: impl Fn(&str) -> Result<K>,
) -> Result<K> {
    let spelled = match t.get(key) {
        None if default.is_empty() => {
            return Err(spec_err(src, sect, Some(key), "required key is missing"));
        }
        None => default.to_string(),
        Some(v) => v
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| spec_err(src, sect, Some(key), "must be a string"))?,
    };
    parse(&spelled).map_err(|e| spec_err(src, sect, Some(key), e))
}

impl ScenarioSpec {
    /// Write the spec back out as canonical TOML. Every field is emitted
    /// explicitly (including values that match defaults) so that
    /// `decode(emit())` reproduces the spec exactly.
    pub fn emit(&self) -> String {
        let mut out = String::new();
        let p = |out: &mut String, s: String| {
            out.push_str(&s);
            out.push('\n');
        };

        p(&mut out, "[scenario]".into());
        p(&mut out, format!("name = \"{}\"", self.name));
        p(&mut out, format!("duration_s = {}", float(self.duration_s)));
        p(&mut out, format!("seed = {}", self.seed));
        p(&mut out, format!("policy = \"{}\"", self.policy.name()));
        p(&mut out, format!("objective = \"{}\"", self.objective.name()));
        if let ObjectiveDef::MinEnergySlo { slo_ms } = self.objective {
            p(&mut out, format!("objective_slo_ms = {}", float(slo_ms)));
        }
        p(&mut out, format!("scheduler = \"{}\"", self.scheduler.name()));
        p(&mut out, format!("admission = \"{}\"", self.admission.name()));
        if let Some(limit) = self.queue_limit {
            p(&mut out, format!("queue_limit = {limit}"));
        }
        p(&mut out, format!("condition = \"{}\"", self.condition.name()));
        let names: Vec<String> =
            self.stream_names.iter().map(|n| format!("\"{n}\"")).collect();
        p(&mut out, format!("streams = [{}]", names.join(", ")));

        p(&mut out, String::new());
        p(&mut out, "[calib]".into());
        p(&mut out, format!("samples = {}", self.calib.samples));
        p(&mut out, format!("seed = {}", self.calib.seed));
        p(&mut out, format!("trees = {}", self.calib.trees));

        p(&mut out, String::new());
        p(&mut out, "[batching]".into());
        p(&mut out, format!("policy = \"{}\"", self.batching.policy.name()));
        p(&mut out, format!("max = {}", self.batching.max));
        p(&mut out, format!("wait_ms = {}", float(self.batching.wait_ms)));

        p(&mut out, String::new());
        p(&mut out, "[plan_cache]".into());
        p(&mut out, format!("capacity = {}", self.plan_cache.capacity));
        p(&mut out, format!("util_bucket = {}", float(self.plan_cache.util_bucket)));
        p(
            &mut out,
            format!("freq_bucket_mhz = {}", float(self.plan_cache.freq_bucket_mhz)),
        );

        for s in &self.streams {
            p(&mut out, String::new());
            p(&mut out, format!("[stream.{}]", s.name));
            p(&mut out, format!("model = \"{}\"", s.model));
            p(&mut out, format!("arrival = \"{}\"", s.arrival));
            p(&mut out, format!("rate_hz = {}", float(s.rate_hz)));
            if let Some(j) = s.jitter {
                p(&mut out, format!("jitter = {}", float(j)));
            }
            p(&mut out, format!("slo_ms = {}", float(s.slo_ms)));
        }

        for t in &self.timeline {
            p(&mut out, String::new());
            p(&mut out, format!("[timeline.{}]", t.label));
            p(&mut out, format!("at_s = {}", float(t.at_s)));
            p(&mut out, format!("condition = \"{}\"", t.condition.name()));
        }

        if let Some(f) = &self.fleet {
            p(&mut out, String::new());
            p(&mut out, "[fleet]".into());
            p(&mut out, format!("devices = {}", f.devices));
            p(&mut out, format!("threads = {}", f.threads));
        }

        if let Some(h) = &self.health {
            p(&mut out, String::new());
            p(&mut out, "[health]".into());
            p(&mut out, format!("fast_window_s = {}", float(h.fast_window_s)));
            p(&mut out, format!("slow_window_s = {}", float(h.slow_window_s)));
            p(&mut out, format!("slo_target = {}", float(h.slo_target)));
            p(&mut out, format!("burn_warn = {}", float(h.burn_warn)));
            p(&mut out, format!("burn_critical = {}", float(h.burn_critical)));
            p(&mut out, format!("energy_budget_mj = {}", float(h.energy_budget_mj)));
            p(&mut out, format!("drift_warn = {}", float(h.drift_warn)));
            p(&mut out, format!("drift_critical = {}", float(h.drift_critical)));
            p(&mut out, format!("queue_warn = {}", h.queue_warn));
            p(&mut out, format!("queue_critical = {}", h.queue_critical));
            p(&mut out, format!("clear_ratio = {}", float(h.clear_ratio)));
            p(&mut out, format!("min_samples = {}", h.min_samples));
        }

        if !self.expect.is_empty() {
            p(&mut out, String::new());
            p(&mut out, "[expect]".into());
            for b in &self.expect {
                p(&mut out, format!("{} = {}", b.key.name(), float(b.bound)));
            }
        }

        out
    }
}

/// Render a float so the TOML parser reads back the identical bits.
/// Rust's shortest-round-trip `Display` guarantees `parse(format!("{x}"))
/// == x`; integral values print without a dot, which the spec layer
/// accepts (`as_float` takes integers too).
fn float(x: f64) -> String {
    format!("{x}")
}
