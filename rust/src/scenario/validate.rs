//! Semantic validation of a decoded [`ScenarioSpec`].
//!
//! [`decode`](crate::scenario::spec::decode) guarantees shape (known
//! keys, right types, known enum spellings); this pass rejects specs
//! that are well-formed but inconsistent: dangling or duplicate stream
//! refs, orphan stream sections, overlapping timeline entries, rate or
//! jitter values outside their domain, unsatisfiable SLOs, and fleet
//! specs that ask for single-engine-only features. Every rejection is a
//! span-carrying diagnostic, never a panic.

use std::collections::BTreeSet;

use anyhow::Result;

use crate::config::schema::AdmissionKind;
use crate::scenario::diag::spec_err;
use crate::scenario::spec::{ObjectiveDef, ScenarioSpec};

/// Deadlines below this are unsatisfiable: even the smallest zoo model's
/// best partition on the fastest simulated SoC needs more than a
/// millisecond end-to-end, so such a spec can only ever report 100% miss.
pub const MIN_SLO_MS: f64 = 1.0;

/// Validate cross-field consistency. `src` is the original TOML text,
/// used only to resolve diagnostic spans.
pub fn validate(spec: &ScenarioSpec, src: &str) -> Result<()> {
    if spec.name.trim().is_empty() {
        return Err(spec_err(src, "scenario", Some("name"), "must not be empty"));
    }
    if !(spec.duration_s > 0.0 && spec.duration_s.is_finite()) {
        return Err(spec_err(src, "scenario", Some("duration_s"), "must be a finite value > 0"));
    }
    if let ObjectiveDef::MinEnergySlo { slo_ms } = spec.objective {
        if !(slo_ms > 0.0 && slo_ms.is_finite()) {
            return Err(spec_err(
                src,
                "scenario",
                Some("objective_slo_ms"),
                "must be a finite value > 0",
            ));
        }
    }

    match (spec.admission, spec.queue_limit) {
        (AdmissionKind::Bounded, Some(limit)) if limit < 1 => {
            return Err(spec_err(src, "scenario", Some("queue_limit"), "must be >= 1"));
        }
        (AdmissionKind::Bounded, None) => {
            return Err(spec_err(
                src,
                "scenario",
                Some("queue_limit"),
                "required when admission = \"bounded\"",
            ));
        }
        (_, Some(_)) if spec.admission != AdmissionKind::Bounded => {
            return Err(spec_err(
                src,
                "scenario",
                Some("queue_limit"),
                "only valid with admission = \"bounded\"",
            ));
        }
        _ => {}
    }

    validate_streams(spec, src)?;
    validate_timeline(spec, src)?;
    validate_knobs(spec, src)?;
    validate_health(spec, src)?;
    validate_fleet(spec, src)?;

    for b in &spec.expect {
        if !b.bound.is_finite() || b.bound < 0.0 {
            return Err(spec_err(
                src,
                "expect",
                Some(b.key.name()),
                "bound must be a finite value >= 0",
            ));
        }
    }
    Ok(())
}

fn validate_streams(spec: &ScenarioSpec, src: &str) -> Result<()> {
    if spec.fleet.is_none() && spec.stream_names.is_empty() {
        return Err(spec_err(
            src,
            "scenario",
            Some("streams"),
            "at least one stream is required (or add a [fleet] section)",
        ));
    }

    let defined: BTreeSet<&str> = spec.streams.iter().map(|s| s.name.as_str()).collect();
    let mut seen = BTreeSet::new();
    for name in &spec.stream_names {
        if !seen.insert(name.as_str()) {
            return Err(spec_err(
                src,
                "scenario",
                Some("streams"),
                format!("stream `{name}` is listed twice"),
            ));
        }
        if !defined.contains(name.as_str()) {
            return Err(spec_err(
                src,
                "scenario",
                Some("streams"),
                format!("references undefined stream `{name}` (no [stream.{name}] section)"),
            ));
        }
    }
    for s in &spec.streams {
        let sect = format!("stream.{}", s.name);
        if !spec.stream_names.iter().any(|n| n == &s.name) {
            return Err(spec_err(
                src,
                &sect,
                None,
                "defined but not listed in [scenario].streams",
            ));
        }
        if crate::graph::zoo::by_name(&s.model).is_none() {
            return Err(spec_err(
                src,
                &sect,
                Some("model"),
                format!(
                    "unknown model `{}` (expected one of {})",
                    s.model,
                    crate::graph::zoo::names().join(", ")
                ),
            ));
        }
        if !matches!(s.arrival.as_str(), "poisson" | "periodic" | "mmpp") {
            return Err(spec_err(
                src,
                &sect,
                Some("arrival"),
                format!("unknown arrival kind `{}` (expected poisson, periodic, or mmpp)", s.arrival),
            ));
        }
        if !(s.rate_hz > 0.0 && s.rate_hz.is_finite()) {
            return Err(spec_err(src, &sect, Some("rate_hz"), "must be a finite value > 0"));
        }
        match s.jitter {
            Some(_) if s.arrival != "periodic" => {
                return Err(spec_err(
                    src,
                    &sect,
                    Some("jitter"),
                    "only valid for arrival = \"periodic\"",
                ));
            }
            Some(j) if !(0.0..=1.0).contains(&j) => {
                return Err(spec_err(src, &sect, Some("jitter"), "must be within [0, 1]"));
            }
            _ => {}
        }
        if !s.slo_ms.is_finite() || s.slo_ms < MIN_SLO_MS {
            return Err(spec_err(
                src,
                &sect,
                Some("slo_ms"),
                format!("unsatisfiable SLO: must be >= {MIN_SLO_MS} ms"),
            ));
        }
    }
    Ok(())
}

fn validate_timeline(spec: &ScenarioSpec, src: &str) -> Result<()> {
    for t in &spec.timeline {
        let sect = format!("timeline.{}", t.label);
        if !t.at_s.is_finite() || t.at_s < 0.0 || t.at_s >= spec.duration_s {
            return Err(spec_err(
                src,
                &sect,
                Some("at_s"),
                format!("must lie within [0, duration_s) = [0, {})", spec.duration_s),
            ));
        }
    }
    let mut sorted: Vec<_> = spec.timeline.iter().collect();
    sorted.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
    for pair in sorted.windows(2) {
        if pair[0].at_s == pair[1].at_s {
            return Err(spec_err(
                src,
                &format!("timeline.{}", pair[1].label),
                Some("at_s"),
                format!(
                    "overlaps [timeline.{}]: two regime changes at t = {} s",
                    pair[0].label, pair[0].at_s
                ),
            ));
        }
    }
    Ok(())
}

fn validate_knobs(spec: &ScenarioSpec, src: &str) -> Result<()> {
    if spec.calib.samples < 1 {
        return Err(spec_err(src, "calib", Some("samples"), "must be >= 1"));
    }
    if spec.calib.trees < 1 {
        return Err(spec_err(src, "calib", Some("trees"), "must be >= 1"));
    }
    if spec.batching.max < 1 {
        return Err(spec_err(src, "batching", Some("max"), "must be >= 1"));
    }
    if !(spec.batching.wait_ms >= 0.0 && spec.batching.wait_ms.is_finite()) {
        return Err(spec_err(src, "batching", Some("wait_ms"), "must be a finite value >= 0"));
    }
    if !(spec.plan_cache.util_bucket > 0.0 && spec.plan_cache.util_bucket.is_finite()) {
        return Err(spec_err(src, "plan_cache", Some("util_bucket"), "must be a finite value > 0"));
    }
    if !(spec.plan_cache.freq_bucket_mhz > 0.0 && spec.plan_cache.freq_bucket_mhz.is_finite()) {
        return Err(spec_err(
            src,
            "plan_cache",
            Some("freq_bucket_mhz"),
            "must be a finite value > 0",
        ));
    }
    Ok(())
}

fn validate_health(spec: &ScenarioSpec, src: &str) -> Result<()> {
    let Some(h) = &spec.health else { return Ok(()) };
    let finite_pos = |v: f64| v > 0.0 && v.is_finite();
    if !finite_pos(h.fast_window_s) {
        return Err(spec_err(src, "health", Some("fast_window_s"), "must be a finite value > 0"));
    }
    if !finite_pos(h.slow_window_s) {
        return Err(spec_err(src, "health", Some("slow_window_s"), "must be a finite value > 0"));
    }
    if h.fast_window_s >= h.slow_window_s {
        return Err(spec_err(
            src,
            "health",
            Some("fast_window_s"),
            "must be shorter than slow_window_s (the slow window confirms the fast one)",
        ));
    }
    if !finite_pos(h.slo_target) || h.slo_target > 1.0 {
        return Err(spec_err(src, "health", Some("slo_target"), "must be within (0, 1]"));
    }
    if !finite_pos(h.burn_warn) {
        return Err(spec_err(src, "health", Some("burn_warn"), "must be a finite value > 0"));
    }
    if !(h.burn_critical > h.burn_warn && h.burn_critical.is_finite()) {
        return Err(spec_err(src, "health", Some("burn_critical"), "must be > burn_warn"));
    }
    if !(h.energy_budget_mj >= 0.0 && h.energy_budget_mj.is_finite()) {
        return Err(spec_err(
            src,
            "health",
            Some("energy_budget_mj"),
            "must be a finite value >= 0 (0 disables the energy rule)",
        ));
    }
    if !finite_pos(h.drift_warn) {
        return Err(spec_err(src, "health", Some("drift_warn"), "must be a finite value > 0"));
    }
    if !(h.drift_critical > h.drift_warn && h.drift_critical.is_finite()) {
        return Err(spec_err(src, "health", Some("drift_critical"), "must be > drift_warn"));
    }
    if h.queue_warn < 1 {
        return Err(spec_err(src, "health", Some("queue_warn"), "must be >= 1"));
    }
    if h.queue_critical <= h.queue_warn {
        return Err(spec_err(src, "health", Some("queue_critical"), "must be > queue_warn"));
    }
    if !(h.clear_ratio > 0.0 && h.clear_ratio < 1.0) {
        return Err(spec_err(
            src,
            "health",
            Some("clear_ratio"),
            "must lie strictly within (0, 1) for the hysteresis gap to exist",
        ));
    }
    if h.min_samples < 1 {
        return Err(spec_err(src, "health", Some("min_samples"), "must be >= 1"));
    }
    Ok(())
}

fn validate_fleet(spec: &ScenarioSpec, src: &str) -> Result<()> {
    let Some(fleet) = &spec.fleet else { return Ok(()) };
    if fleet.devices < 1 {
        return Err(spec_err(src, "fleet", Some("devices"), "must be >= 1"));
    }
    if fleet.threads < 1 {
        return Err(spec_err(src, "fleet", Some("threads"), "must be >= 1"));
    }
    if let Some(s) = spec.streams.first() {
        return Err(spec_err(
            src,
            &format!("stream.{}", s.name),
            None,
            "fleet scenarios use the built-in per-class workload mix; remove [stream.*] sections",
        ));
    }
    if !spec.stream_names.is_empty() {
        return Err(spec_err(
            src,
            "scenario",
            Some("streams"),
            "fleet scenarios use the built-in per-class workload mix; remove the streams list",
        ));
    }
    if let Some(t) = spec.timeline.first() {
        return Err(spec_err(
            src,
            &format!("timeline.{}", t.label),
            None,
            "condition timelines are not supported in fleet scenarios",
        ));
    }
    for b in &spec.expect {
        if !b.key.fleet_supported() {
            return Err(spec_err(
                src,
                "expect",
                Some(b.key.name()),
                "not available from the fleet aggregate (single-engine scenarios only)",
            ));
        }
    }
    Ok(())
}
