//! Slab-style buffer arena for per-request pipeline state.
//!
//! Every admitted request carries an `out_cpu: Vec<f64>` (the CPU-resident
//! fraction of each op's output, one slot per op). Before the arena, the
//! admission stage allocated a fresh vector per request and completion
//! dropped it — one heap round-trip per request, millions per fleet
//! campaign. [`RequestArena`] keeps the freed buffers and hands them back
//! on the next admission.
//!
//! **Byte-safety:** a recycled buffer is `clear()`ed and then
//! `resize(len, fill)`ed, so every slot the borrower can observe is
//! freshly written — state can never leak from the previous occupant,
//! regardless of the buffer's prior length or contents. The
//! arena-recycling suite (`rust/tests/arena_recycle.rs`) pins this by
//! transplanting a deliberately polluted arena between engines and
//! asserting byte-identical reports.

/// Recycling pool of `Vec<f64>` buffers for per-request state.
#[derive(Debug, Default)]
pub struct RequestArena {
    free: Vec<Vec<f64>>,
    allocated: usize,
    recycled: usize,
}

impl RequestArena {
    /// Empty arena.
    pub fn new() -> RequestArena {
        RequestArena::default()
    }

    /// Hand out a buffer of exactly `len` slots, every slot set to
    /// `fill`. Reuses a pooled buffer when one is available.
    pub fn alloc(&mut self, len: usize, fill: f64) -> Vec<f64> {
        self.allocated += 1;
        match self.free.pop() {
            Some(mut v) => {
                self.recycled += 1;
                v.clear();
                v.resize(len, fill);
                v
            }
            None => vec![fill; len],
        }
    }

    /// Return a buffer to the pool for reuse.
    pub fn recycle(&mut self, v: Vec<f64>) {
        self.free.push(v);
    }

    /// Lifetime counters: `(buffers handed out, of which recycled)`.
    pub fn stats(&self) -> (usize, usize) {
        (self.allocated, self.recycled)
    }

    /// Buffers currently sitting in the pool.
    pub fn pooled(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recycled_buffer_is_fully_overwritten() {
        let mut arena = RequestArena::new();
        let mut dirty = arena.alloc(5, 0.9);
        dirty[3] = f64::NAN; // pollute
        arena.recycle(dirty);
        // shorter, longer, and equal-length reuses all come back clean
        for len in [2usize, 8, 5] {
            let v = arena.alloc(len, 0.25);
            assert_eq!(v, vec![0.25; len]);
            arena.recycle(v);
        }
        assert_eq!(arena.stats(), (4, 3));
    }

    #[test]
    fn counters_track_fresh_vs_recycled() {
        let mut arena = RequestArena::new();
        let a = arena.alloc(3, 1.0);
        let b = arena.alloc(3, 1.0);
        assert_eq!(arena.stats(), (2, 0));
        arena.recycle(a);
        arena.recycle(b);
        assert_eq!(arena.pooled(), 2);
        let _c = arena.alloc(1, 0.0);
        assert_eq!(arena.stats(), (3, 1));
        assert_eq!(arena.pooled(), 1);
    }
}
