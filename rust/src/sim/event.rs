//! Typed simulation events.
//!
//! Every state change the serving kernel makes is described by one
//! [`Event`]: a request arriving (and the admission verdict on it), an
//! operator being dispatched to processors, an operator completing, the
//! resource monitor sampling the device, or a re-plan being adopted.
//! Events are what [`super::queue::EventQueue`] schedules and what
//! [`super::observer::SimObserver`]s receive — scenarios, traces, and the
//! fleet layer all consume the kernel through this vocabulary instead of
//! reaching into engine internals.

use crate::coordinator::repartition::Trigger;
use crate::coordinator::request::Request;
use crate::metrics::health::Alert;
use crate::soc::Placement;

/// One simulation event, stamped with virtual-time fields.
#[derive(Debug, Clone)]
pub enum Event {
    /// A request reached the admission controller.
    Arrival {
        /// The arriving request.
        req: Request,
        /// Whether admission accepted it into the queue (`false` = shed).
        /// Meaningful only on events *delivered* to observers; arrivals
        /// still resident in the [`super::queue::EventQueue`] carry
        /// `false` as a pending verdict — the engine rebuilds the event
        /// with the real verdict at admission.
        admitted: bool,
    },
    /// One operator of an active request was dispatched to processors.
    OpDispatch {
        /// Owning request id.
        request: usize,
        /// Owning stream id.
        stream: usize,
        /// Operator index within the model.
        op: usize,
        /// Virtual time the operator started executing.
        start_s: f64,
        /// Placement the operator actually ran with (plan or override).
        placement: Placement,
    },
    /// A dispatched operator finished executing.
    OpComplete {
        /// Owning request id.
        request: usize,
        /// Owning stream id.
        stream: usize,
        /// Operator index within the model.
        op: usize,
        /// Virtual time the operator finished.
        end_s: f64,
        /// Measured operator latency, seconds.
        latency_s: f64,
        /// Measured dynamic energy, joules.
        energy_j: f64,
    },
    /// The resource monitor sampled the device.
    MonitorTick {
        /// Virtual time of the sample.
        t_s: f64,
        /// Whether the sample flagged a regime change.
        regime_changed: bool,
    },
    /// A re-plan was adopted for one stream.
    RegimeReplan {
        /// Stream whose plan changed.
        stream: usize,
        /// Virtual time of adoption.
        t_s: f64,
        /// What triggered the re-plan (drift fast path or regime change).
        trigger: Trigger,
        /// Virtual decision time charged to the CPU timeline, seconds.
        decision_s: f64,
    },
    /// A forming batch closed and dispatched (see [`crate::batching`]).
    /// Emitted for every batched dispatch (size > 1) and for held-then-
    /// closed singletons (wait > 0); plain unbatched dispatches stay
    /// silent so non-batching runs see an unchanged event stream.
    BatchClose {
        /// Owning stream of every member.
        stream: usize,
        /// Frontier operator index the batch dispatched.
        op: usize,
        /// Virtual time the batch closed (its dispatch start).
        t_s: f64,
        /// Requests dispatched together.
        size: usize,
        /// Formation wait: close time minus the moment the frontier first
        /// became dispatchable, seconds.
        wait_s: f64,
    },
    /// A health rule changed state (see [`crate::metrics::health`]).
    /// Emitted only on runs with the health monitor configured; the
    /// matching typed hook is [`super::observer::SimObserver::on_alert`].
    Alert {
        /// The state transition, with its rule, signal, and threshold.
        alert: Alert,
    },
}

/// Discriminant of an [`Event`], for counting and display.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// [`Event::Arrival`].
    Arrival,
    /// [`Event::OpDispatch`].
    OpDispatch,
    /// [`Event::OpComplete`].
    OpComplete,
    /// [`Event::MonitorTick`].
    MonitorTick,
    /// [`Event::RegimeReplan`].
    RegimeReplan,
    /// [`Event::BatchClose`].
    BatchClose,
    /// [`Event::Alert`].
    Alert,
}

impl EventKind {
    /// Stable lower-case name (trace output).
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Arrival => "arrival",
            EventKind::OpDispatch => "op_dispatch",
            EventKind::OpComplete => "op_complete",
            EventKind::MonitorTick => "monitor_tick",
            EventKind::RegimeReplan => "regime_replan",
            EventKind::BatchClose => "batch_close",
            EventKind::Alert => "alert",
        }
    }
}

impl Event {
    /// The event's discriminant.
    pub fn kind(&self) -> EventKind {
        match self {
            Event::Arrival { .. } => EventKind::Arrival,
            Event::OpDispatch { .. } => EventKind::OpDispatch,
            Event::OpComplete { .. } => EventKind::OpComplete,
            Event::MonitorTick { .. } => EventKind::MonitorTick,
            Event::RegimeReplan { .. } => EventKind::RegimeReplan,
            Event::BatchClose { .. } => EventKind::BatchClose,
            Event::Alert { .. } => EventKind::Alert,
        }
    }

    /// The virtual time the event describes.
    pub fn time_s(&self) -> f64 {
        match self {
            Event::Arrival { req, .. } => req.arrival_s,
            Event::OpDispatch { start_s, .. } => *start_s,
            Event::OpComplete { end_s, .. } => *end_s,
            Event::MonitorTick { t_s, .. } => *t_s,
            Event::RegimeReplan { t_s, .. } => *t_s,
            Event::BatchClose { t_s, .. } => *t_s,
            Event::Alert { alert } => alert.t_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize, t: f64) -> Request {
        Request {
            id,
            stream: 0,
            arrival_s: t,
            deadline_s: t + 0.1,
        }
    }

    #[test]
    fn kinds_and_times() {
        let ev = Event::Arrival {
            req: req(3, 1.25),
            admitted: true,
        };
        assert_eq!(ev.kind(), EventKind::Arrival);
        assert_eq!(ev.time_s(), 1.25);
        assert_eq!(ev.kind().name(), "arrival");
        let ev = Event::MonitorTick {
            t_s: 2.0,
            regime_changed: false,
        };
        assert_eq!(ev.kind(), EventKind::MonitorTick);
        assert_eq!(ev.time_s(), 2.0);
        let ev = Event::BatchClose {
            stream: 0,
            op: 0,
            t_s: 3.5,
            size: 4,
            wait_s: 0.002,
        };
        assert_eq!(ev.kind(), EventKind::BatchClose);
        assert_eq!(ev.time_s(), 3.5);
        assert_eq!(ev.kind().name(), "batch_close");
        let ev = Event::Alert {
            alert: crate::metrics::health::Alert {
                t_s: 4.25,
                rule: "slo_burn",
                stream: Some(0),
                prev: crate::metrics::health::HealthState::Ok,
                state: crate::metrics::health::HealthState::Warn,
                signal: 2.0,
                threshold: 1.0,
            },
        };
        assert_eq!(ev.kind(), EventKind::Alert);
        assert_eq!(ev.time_s(), 4.25);
        assert_eq!(ev.kind().name(), "alert");
    }
}
