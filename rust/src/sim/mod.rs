//! Deterministic discrete-event simulation kernel.
//!
//! The serving engine ([`crate::coordinator::engine`]) drives a
//! two-resource op-level list scheduler over the simulated SoC. This
//! module is the kernel it composes on:
//!
//! * [`event`] — the typed event vocabulary (`Arrival`, `OpDispatch`,
//!   `OpComplete`, `MonitorTick`, `RegimeReplan`).
//! * [`queue`] — the `(time, seq)`-keyed [`queue::EventQueue`]: a
//!   calendar (bucketed) queue, O(1) amortized for the near-future
//!   events that dominate serving, with NaN-safe ([`f64::total_cmp`])
//!   min-ordering and push-order tie-breaking. The binary-heap
//!   predecessor survives as [`queue::BinaryHeapQueue`], the reference
//!   side of the differential property suite
//!   (`rust/tests/prop_event_queue.rs`).
//! * [`arena`] — the [`arena::RequestArena`] buffer pool recycling
//!   per-request `out_cpu` state across admissions (no hot-loop
//!   allocations; byte-safety pinned by `rust/tests/arena_recycle.rs`).
//! * [`observer`] — the [`observer::SimObserver`] hook surface
//!   (`on_event` / `on_request_done`) plus [`observer::EventCounters`].
//!   Adding a scenario means adding an observer.
//! * [`stages`] — the five composable stages `Engine::run` drives:
//!   arrival source, admission, dispatch, execution, monitor.
//!
//! ## Delivery semantics (why this kernel replays the legacy loop)
//!
//! The device clock is *piecewise*: it only advances when an op is
//! dispatched. The kernel therefore schedules the genuinely-future
//! timeline (arrivals) through the [`queue::EventQueue`] and delivers the
//! dispatch-coupled events at their causal points:
//!
//! * **Arrivals** pop from the queue. While no request is active the next
//!   arrival pops unconditionally; while a dispatch is pending an arrival
//!   preempts it only when *strictly* earlier than the dispatch start
//!   (equal-time arrivals wait — the legacy admission rule).
//! * **MonitorTick** is due at `last sample + period` but delivered at
//!   the first dispatch whose time advance reaches the due point:
//!   sampling mid-idle would read device snapshots the legacy engine
//!   never took, breaking bit-identical replay.
//! * **OpDispatch/OpComplete/RegimeReplan** are emitted to observers at
//!   execution, completion (`start + latency`), and re-plan adoption.
//!
//! Golden replay of this contract is pinned by
//! `rust/tests/golden_determinism.rs`.

pub mod arena;
pub mod event;
pub mod observer;
pub mod queue;
pub mod stages;
pub mod timers;

pub use arena::RequestArena;
pub use event::{Event, EventKind};
pub use observer::{EventCounters, SimObserver};
pub use queue::{BinaryHeapQueue, EventQueue};
pub use timers::{Stage, StageTimers};
pub use stages::{
    Active, AdmissionStage, ArrivalSource, Decision, DispatchStage, ExecStage, MonitorStage,
    PlanTable,
};
