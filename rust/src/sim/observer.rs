//! Observer hooks on the simulation kernel.
//!
//! A [`SimObserver`] receives every [`Event`] the kernel delivers plus a
//! per-request completion hook. This is the extension seam the tentpole
//! refactor introduces: *adding a scenario means adding an observer*.
//! The trace writer ([`crate::metrics::trace::TraceObserver`]), the fleet
//! runner's per-device probe, and the experiment sweeps all consume the
//! engine through this trait instead of poking report internals.

use crate::coordinator::request::RequestOutcome;
use crate::metrics::health::Alert;

use super::event::Event;

/// Receives kernel events during a serving run.
///
/// Both hooks have empty defaults so an observer only implements what it
/// needs. Observers must not assume events arrive in globally sorted
/// virtual time: the kernel delivers them in *causal* order (a monitor
/// tick fires at the dispatch that crossed its due time; an op completes
/// immediately after it dispatches, at `start + latency`).
pub trait SimObserver {
    /// Called once per delivered event.
    fn on_event(&mut self, _event: &Event) {}

    /// Called once per completed request, after its final
    /// [`Event::OpComplete`].
    fn on_request_done(&mut self, _outcome: &RequestOutcome, _met_deadline: bool) {}

    /// Called once per batch close alongside the corresponding
    /// [`Event::BatchClose`] — a typed convenience hook so batching
    /// scenarios need not destructure the event. Never called on runs with
    /// batching disabled.
    fn on_batch(&mut self, _stream: usize, _op: usize, _size: usize, _wait_s: f64) {}

    /// Called once per health-rule state transition alongside the
    /// corresponding [`Event::Alert`] — the typed hook for alert
    /// consumers. Never called on runs without the health monitor.
    fn on_alert(&mut self, _alert: &Alert) {}
}

/// Broadcast one event to every observer.
pub fn emit(observers: &mut [&mut dyn SimObserver], event: &Event) {
    for o in observers.iter_mut() {
        o.on_event(event);
    }
}

/// Broadcast one request completion to every observer.
pub fn emit_done(
    observers: &mut [&mut dyn SimObserver],
    outcome: &RequestOutcome,
    met_deadline: bool,
) {
    for o in observers.iter_mut() {
        o.on_request_done(outcome, met_deadline);
    }
}

/// Broadcast one batch close to every observer (the typed hook; the
/// engine additionally emits the matching [`Event::BatchClose`]).
pub fn emit_batch(
    observers: &mut [&mut dyn SimObserver],
    stream: usize,
    op: usize,
    size: usize,
    wait_s: f64,
) {
    for o in observers.iter_mut() {
        o.on_batch(stream, op, size, wait_s);
    }
}

/// Broadcast one health alert to every observer (the typed hook; the
/// engine additionally emits the matching [`Event::Alert`]).
pub fn emit_alert(observers: &mut [&mut dyn SimObserver], alert: &Alert) {
    for o in observers.iter_mut() {
        o.on_alert(alert);
    }
}

/// Event tallies — the workhorse observer the experiment sweeps and the
/// fleet runner build on.
#[derive(Debug, Clone, Copy, Default)]
pub struct EventCounters {
    /// Requests that reached admission ([`Event::Arrival`] count).
    pub offered: usize,
    /// Arrivals admitted into the queue.
    pub admitted: usize,
    /// Arrivals rejected at admission (any reason).
    pub shed: usize,
    /// Operators dispatched.
    pub op_dispatches: usize,
    /// Operators completed.
    pub op_completes: usize,
    /// Monitor samples taken.
    pub monitor_ticks: usize,
    /// Monitor samples that flagged a regime change.
    pub regime_changes: usize,
    /// Re-plans adopted (drift + regime, cached or solved).
    pub replans: usize,
    /// Requests that completed.
    pub completed: usize,
    /// Completed requests that missed their deadline.
    pub deadline_misses: usize,
    /// Batched dispatches observed: [`Event::BatchClose`] events with
    /// more than one member (held-then-closed singletons are excluded, so
    /// these tallies match `BatchStats::batched_dispatches` and the fleet
    /// merge stays consistent across aggregation paths).
    pub batch_closes: usize,
    /// Requests dispatched inside those batched dispatches.
    pub batched_requests: usize,
    /// Health-rule state transitions ([`Event::Alert`] count); always 0
    /// on runs without the health monitor.
    pub alerts: usize,
}

impl EventCounters {
    /// Deadline-miss rate over completed requests (0 when none completed).
    pub fn miss_rate(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.deadline_misses as f64 / self.completed as f64
        }
    }
}

impl SimObserver for EventCounters {
    fn on_event(&mut self, event: &Event) {
        match event {
            Event::Arrival { admitted, .. } => {
                self.offered += 1;
                if *admitted {
                    self.admitted += 1;
                } else {
                    self.shed += 1;
                }
            }
            Event::OpDispatch { .. } => self.op_dispatches += 1,
            Event::OpComplete { .. } => self.op_completes += 1,
            Event::MonitorTick { regime_changed, .. } => {
                self.monitor_ticks += 1;
                if *regime_changed {
                    self.regime_changes += 1;
                }
            }
            Event::RegimeReplan { .. } => self.replans += 1,
            Event::BatchClose { size, .. } => {
                if *size > 1 {
                    self.batch_closes += 1;
                    self.batched_requests += size;
                }
            }
            Event::Alert { .. } => self.alerts += 1,
        }
    }

    fn on_request_done(&mut self, _outcome: &RequestOutcome, met_deadline: bool) {
        self.completed += 1;
        if !met_deadline {
            self.deadline_misses += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Request;

    fn outcome(arrival: f64, finish: f64, deadline: f64) -> RequestOutcome {
        RequestOutcome {
            request: Request {
                id: 0,
                stream: 0,
                arrival_s: arrival,
                deadline_s: deadline,
            },
            start_s: arrival,
            finish_s: finish,
            energy_j: 0.0,
        }
    }

    #[test]
    fn counters_tally_events() {
        let mut c = EventCounters::default();
        c.on_event(&Event::Arrival {
            req: Request {
                id: 0,
                stream: 0,
                arrival_s: 0.0,
                deadline_s: 1.0,
            },
            admitted: true,
        });
        c.on_event(&Event::Arrival {
            req: Request {
                id: 1,
                stream: 0,
                arrival_s: 0.1,
                deadline_s: 1.1,
            },
            admitted: false,
        });
        c.on_event(&Event::MonitorTick {
            t_s: 0.2,
            regime_changed: true,
        });
        c.on_event(&Event::BatchClose {
            stream: 0,
            op: 0,
            t_s: 0.3,
            size: 3,
            wait_s: 0.001,
        });
        // a held-then-closed singleton must not count as a batched dispatch
        c.on_event(&Event::BatchClose {
            stream: 0,
            op: 0,
            t_s: 0.4,
            size: 1,
            wait_s: 0.004,
        });
        c.on_event(&Event::Alert {
            alert: crate::metrics::health::Alert {
                t_s: 0.5,
                rule: "queue_depth",
                stream: None,
                prev: crate::metrics::health::HealthState::Ok,
                state: crate::metrics::health::HealthState::Warn,
                signal: 9.0,
                threshold: 8.0,
            },
        });
        assert_eq!((c.offered, c.admitted, c.shed), (2, 1, 1));
        assert_eq!((c.monitor_ticks, c.regime_changes), (1, 1));
        assert_eq!((c.batch_closes, c.batched_requests), (1, 3));
        assert_eq!(c.alerts, 1);
        c.on_request_done(&outcome(0.0, 0.5, 1.0), true);
        c.on_request_done(&outcome(0.1, 2.0, 1.1), false);
        assert_eq!((c.completed, c.deadline_misses), (2, 1));
        assert!((c.miss_rate() - 0.5).abs() < 1e-12);
        assert_eq!(EventCounters::default().miss_rate(), 0.0);
    }
}
