//! Deterministic discrete-event queue.
//!
//! [`EventQueue`] is a **calendar queue**: a bucketed timing wheel over a
//! window of "days" (buckets) starting at `year_start`, each `width`
//! virtual seconds wide, with an overflow list for events outside the
//! window. Near-future events — the serving kernel's entire live
//! population once arrivals are seeded — push and pop in O(1) amortized,
//! versus the binary heap's O(log n) per operation.
//!
//! The observable contract is identical to the heap it replaced (kept
//! below as [`BinaryHeapQueue`] for the differential property suite,
//! `rust/tests/prop_event_queue.rs`): entries pop in ascending
//! `(time, seq)` order, where `seq` is a monotonic push counter — events
//! scheduled for the *same* time pop in push order. Time comparison uses
//! [`f64::total_cmp`], so a NaN timestamp cannot panic the kernel — it
//! sorts after every finite time and drains last, exactly like the
//! NaN-safe arrival sort the legacy engine used.
//!
//! ## Why the order is preserved exactly
//!
//! * Each bucket (and the overflow) is kept sorted **descending** by
//!   `(total_cmp(time), seq)` with the minimum at the tail, so popping a
//!   bucket's minimum is `Vec::pop`.
//! * The day mapping `t ↦ ⌊(t − year_start)/width⌋` is monotone
//!   non-decreasing in `t` (IEEE-754 subtraction, division by a positive
//!   width, and truncation are all monotone), so
//!   (bucket, time, seq) order ≡ global (time, seq) order.
//! * Whether a time is bucketable is a pure function of `t` under the
//!   current window geometry, so equal-time entries always land on the
//!   same side of the bucket/overflow split and their `seq` tie-break is
//!   never divided across it.
//! * Every pop/peek compares the bucket minimum against the overflow
//!   minimum with a forward `total_cmp`, which also orders `-inf` (a
//!   non-bucketable time that sorts *before* all finite times) correctly.
//!
//! ## Window management
//!
//! Far-future events (≥ the window horizon), non-finite times, and NaN go
//! to the sorted overflow list. When the buckets drain but finite
//! overflow events remain, the calendar **re-anchors**: all entries are
//! redistributed into a fresh window starting at the earliest finite
//! time, with `width = span / population` and a power-of-two bucket count
//! covering ~2× the span — so a drain cycle re-anchors O(1) times. A push
//! before `year_start` (replay tooling may do this; the engine never
//! does) triggers the same rebuild anchored at the pushed time.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::event::Event;

/// One scheduled entry: the event plus its `(time, seq)` key.
#[derive(Debug, Clone)]
struct Entry {
    time_s: f64,
    seq: u64,
    event: Event,
}

/// Forward key order: ascending `(total_cmp(time), seq)`. `seq` is unique,
/// so distinct entries never compare equal.
fn cmp_entry(a: &Entry, b: &Entry) -> Ordering {
    a.time_s.total_cmp(&b.time_s).then(a.seq.cmp(&b.seq))
}

/// Insert into a descending-sorted vec (minimum at the tail), preserving
/// the order. Binary search; no equal keys exist (`seq` is unique).
fn insert_desc(v: &mut Vec<Entry>, e: Entry) {
    let i = v.partition_point(|x| cmp_entry(x, &e) == Ordering::Greater);
    v.insert(i, e);
}

/// Buckets on a fresh queue (before the first re-anchor).
const INIT_BUCKETS: usize = 64;
/// Bucket width on a fresh queue, seconds.
const INIT_WIDTH: f64 = 1e-3;
/// Narrowest bucket a rebuild may choose (guards a zero-span population).
const MIN_WIDTH: f64 = 1e-9;
/// Bucket-count bounds for a rebuild.
const MIN_BUCKETS: usize = 64;
/// Upper bound on buckets (memory guard for huge populations).
const MAX_BUCKETS: usize = 65_536;

/// Deterministic `(time, seq)`-ordered calendar queue. See the module
/// docs for the ordering contract and window management.
#[derive(Debug)]
pub struct CalendarQueue {
    /// The day buckets, each sorted descending with its minimum at the
    /// tail. Invariant: every bucket below `cursor` is empty.
    buckets: Vec<Vec<Entry>>,
    /// Start of the bucket window (inclusive), virtual seconds.
    year_start: f64,
    /// Width of one bucket, virtual seconds (> 0).
    width: f64,
    /// First possibly-non-empty bucket.
    cursor: usize,
    /// Entries currently held in `buckets`.
    in_buckets: usize,
    /// Out-of-window entries (far-future, non-finite, NaN), sorted
    /// descending with the minimum at the tail.
    overflow: Vec<Entry>,
    /// Monotonic push counter (the tie-break key).
    next_seq: u64,
}

/// The event queue the kernel schedules on (the calendar implementation).
pub type EventQueue = CalendarQueue;

impl Default for CalendarQueue {
    fn default() -> Self {
        CalendarQueue::new()
    }
}

impl CalendarQueue {
    /// Empty queue with the initial window geometry.
    pub fn new() -> CalendarQueue {
        let mut buckets = Vec::with_capacity(INIT_BUCKETS);
        buckets.resize_with(INIT_BUCKETS, Vec::new);
        CalendarQueue {
            buckets,
            year_start: 0.0,
            width: INIT_WIDTH,
            cursor: 0,
            in_buckets: 0,
            overflow: Vec::new(),
            next_seq: 0,
        }
    }

    /// Schedule `event` at `time_s`. Ties at equal `time_s` pop in push
    /// order.
    pub fn push(&mut self, time_s: f64, event: Event) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.insert(Entry {
            time_s,
            seq,
            event,
        });
    }

    /// Pop the earliest entry as `(time, event)`.
    pub fn pop(&mut self) -> Option<(f64, Event)> {
        self.settle();
        let from_bucket = match (self.in_buckets > 0, self.overflow.last()) {
            (false, None) => return None,
            (true, None) => true,
            (false, Some(_)) => false,
            (true, Some(o)) => {
                let b = self.buckets[self.cursor].last().expect("cursor settled");
                // finite overflow times sit at/after the horizon, so the
                // bucket side wins; a -inf/-NaN overflow time wins here
                cmp_entry(b, o) == Ordering::Less
            }
        };
        let e = if from_bucket {
            self.in_buckets -= 1;
            self.buckets[self.cursor].pop().expect("cursor settled")
        } else {
            self.overflow.pop().expect("checked non-empty")
        };
        Some((e.time_s, e.event))
    }

    /// Scheduled time of the earliest entry, if any. (`&mut`: peeking may
    /// advance the cursor or re-anchor the window; the contents and their
    /// order never change.)
    pub fn peek_time(&mut self) -> Option<f64> {
        self.peek_entry().map(|e| e.time_s)
    }

    /// Scheduled time of the earliest entry *if* it is an arrival (the
    /// kernel's preemption rule only looks at arrivals).
    pub fn peek_arrival_time(&mut self) -> Option<f64> {
        match self.peek_entry() {
            Some(Entry {
                time_s,
                event: Event::Arrival { .. },
                ..
            }) => Some(*time_s),
            _ => None,
        }
    }

    /// Scheduled entries remaining.
    pub fn len(&self) -> usize {
        self.in_buckets + self.overflow.len()
    }

    /// Whether no entries remain.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Window horizon (exclusive upper bound of the bucketable range).
    fn horizon(&self) -> f64 {
        self.year_start + self.buckets.len() as f64 * self.width
    }

    /// Whether `t` belongs in a bucket under the current geometry.
    fn bucketable(&self, t: f64) -> bool {
        t.is_finite() && t >= self.year_start && t < self.horizon()
    }

    /// Day index of a bucketable time. The clamp only guards float
    /// rounding at the horizon edge; it preserves monotonicity.
    fn day_of(&self, t: f64) -> usize {
        (((t - self.year_start) / self.width) as usize).min(self.buckets.len() - 1)
    }

    fn insert(&mut self, e: Entry) {
        if e.time_s.is_finite() && e.time_s < self.year_start {
            // a past-window time re-anchors the calendar so the window
            // always starts at the earliest schedulable instant
            self.rebuild(e.time_s);
        }
        if self.bucketable(e.time_s) {
            let idx = self.day_of(e.time_s);
            if idx < self.cursor {
                // rewind onto the newly occupied day (every bucket below
                // the old cursor is empty, so the invariant holds)
                self.cursor = idx;
            }
            insert_desc(&mut self.buckets[idx], e);
            self.in_buckets += 1;
        } else {
            insert_desc(&mut self.overflow, e);
        }
    }

    /// Restore "front of the queue is reachable": re-anchor when only
    /// finite overflow entries remain, then advance the cursor to the
    /// first non-empty bucket.
    fn settle(&mut self) {
        while self.in_buckets == 0 {
            match self.overflow.last() {
                // the earliest remaining time is finite but out of
                // window: re-anchor the calendar there (the rebuild
                // always buckets at least that entry, so this loop
                // terminates)
                Some(e) if e.time_s.is_finite() => {
                    let t = e.time_s;
                    self.rebuild(t);
                }
                // empty, or only non-finite times remain (they drain
                // straight from the overflow)
                _ => break,
            }
        }
        if self.in_buckets > 0 {
            while self.buckets[self.cursor].is_empty() {
                self.cursor += 1;
            }
        }
    }

    fn peek_entry(&mut self) -> Option<&Entry> {
        self.settle();
        let b = if self.in_buckets > 0 {
            self.buckets[self.cursor].last()
        } else {
            None
        };
        match (b, self.overflow.last()) {
            (None, None) => None,
            (Some(b), None) => Some(b),
            (None, Some(o)) => Some(o),
            (Some(b), Some(o)) => Some(if cmp_entry(b, o) == Ordering::Less { b } else { o }),
        }
    }

    /// Redistribute every entry into a fresh window anchored at
    /// `anchor_hint` (or earlier, if an existing entry precedes it):
    /// `width = span / finite population`, power-of-two bucket count
    /// covering ~2× the span.
    fn rebuild(&mut self, anchor_hint: f64) {
        let mut all: Vec<Entry> = Vec::with_capacity(self.len());
        for b in &mut self.buckets {
            all.append(b);
        }
        all.append(&mut self.overflow);
        self.in_buckets = 0;

        let mut finite = 0usize;
        let mut min_t = anchor_hint;
        let mut max_t = anchor_hint;
        for e in &all {
            if e.time_s.is_finite() {
                finite += 1;
                min_t = min_t.min(e.time_s);
                max_t = max_t.max(e.time_s);
            }
        }
        self.width = ((max_t - min_t) / finite.max(1) as f64).max(MIN_WIDTH);
        let nbuckets = (finite.max(1) * 2)
            .next_power_of_two()
            .clamp(MIN_BUCKETS, MAX_BUCKETS);
        self.buckets.clear();
        self.buckets.resize_with(nbuckets, Vec::new);
        self.year_start = min_t;
        self.cursor = 0;

        // distribute in descending (time, seq) order: appending then
        // keeps every bucket (and the overflow) sorted with its minimum
        // at the tail
        all.sort_unstable_by(|a, b| cmp_entry(b, a));
        for e in all {
            if self.bucketable(e.time_s) {
                let idx = self.day_of(e.time_s);
                self.buckets[idx].push(e);
                self.in_buckets += 1;
            } else {
                self.overflow.push(e);
            }
        }
    }
}

/// The binary-heap predecessor of [`CalendarQueue`], kept as the
/// reference implementation for the differential property suite
/// (`rust/tests/prop_event_queue.rs`): same API, same `(time, seq)`
/// contract, trivially correct by construction of [`BinaryHeap`].
#[derive(Debug, Default)]
pub struct BinaryHeapQueue {
    heap: BinaryHeap<HeapEntry>,
    next_seq: u64,
}

/// Heap entry with the reversed order ([`BinaryHeap`] is a max-heap).
#[derive(Debug)]
struct HeapEntry(Entry);

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.0.seq == other.0.seq
    }
}

impl Eq for HeapEntry {}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: earliest (time, seq) on top
        cmp_entry(&other.0, &self.0)
    }
}

impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl BinaryHeapQueue {
    /// Empty queue.
    pub fn new() -> BinaryHeapQueue {
        BinaryHeapQueue::default()
    }

    /// Schedule `event` at `time_s`. Ties at equal `time_s` pop in push
    /// order.
    pub fn push(&mut self, time_s: f64, event: Event) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(HeapEntry(Entry {
            time_s,
            seq,
            event,
        }));
    }

    /// Pop the earliest entry as `(time, event)`.
    pub fn pop(&mut self) -> Option<(f64, Event)> {
        self.heap.pop().map(|e| (e.0.time_s, e.0.event))
    }

    /// Scheduled time of the earliest entry, if any.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.0.time_s)
    }

    /// Scheduled time of the earliest entry *if* it is an arrival.
    pub fn peek_arrival_time(&self) -> Option<f64> {
        match self.heap.peek() {
            Some(HeapEntry(Entry {
                time_s,
                event: Event::Arrival { .. },
                ..
            })) => Some(*time_s),
            _ => None,
        }
    }

    /// Scheduled entries remaining.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether no entries remain.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Request;

    fn arrival(id: usize, t: f64) -> Event {
        Event::Arrival {
            req: Request {
                id,
                stream: 0,
                arrival_s: t,
                deadline_s: t + 1.0,
            },
            admitted: false,
        }
    }

    fn pop_id(q: &mut EventQueue) -> usize {
        match q.pop() {
            Some((_, Event::Arrival { req, .. })) => req.id,
            other => panic!("expected arrival, got {other:?}"),
        }
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, arrival(3, 3.0));
        q.push(1.0, arrival(1, 1.0));
        q.push(2.0, arrival(2, 2.0));
        assert_eq!(q.len(), 3);
        assert_eq!(pop_id(&mut q), 1);
        assert_eq!(pop_id(&mut q), 2);
        assert_eq!(pop_id(&mut q), 3);
        assert!(q.is_empty());
        assert!(q.pop().is_none());
    }

    #[test]
    fn equal_times_tie_break_by_push_order() {
        let mut q = EventQueue::new();
        for id in 0..8 {
            q.push(1.5, arrival(id, 1.5));
        }
        for id in 0..8 {
            assert_eq!(pop_id(&mut q), id, "seq tie-break broke FIFO order");
        }
    }

    #[test]
    fn nan_times_sort_last_without_panicking() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, arrival(9, f64::NAN));
        q.push(1e12, arrival(1, 1e12));
        q.push(0.0, arrival(0, 0.0));
        assert_eq!(pop_id(&mut q), 0);
        assert_eq!(pop_id(&mut q), 1);
        // the NaN entry drains last instead of poisoning the ordering
        assert_eq!(pop_id(&mut q), 9);
    }

    #[test]
    fn interleaved_push_pop_keeps_order() {
        let mut q = EventQueue::new();
        q.push(2.0, arrival(2, 2.0));
        q.push(1.0, arrival(1, 1.0));
        assert_eq!(pop_id(&mut q), 1);
        q.push(0.5, arrival(0, 0.5));
        assert_eq!(pop_id(&mut q), 0);
        assert_eq!(pop_id(&mut q), 2);
    }

    #[test]
    fn peek_does_not_consume() {
        let mut q = EventQueue::new();
        q.push(4.0, arrival(4, 4.0));
        q.push(2.0, arrival(2, 2.0));
        assert_eq!(q.peek_time(), Some(2.0));
        assert_eq!(q.peek_arrival_time(), Some(2.0));
        assert_eq!(q.len(), 2);
        assert_eq!(pop_id(&mut q), 2);
    }

    #[test]
    fn peek_arrival_ignores_non_arrivals() {
        let mut q = EventQueue::new();
        q.push(
            1.0,
            Event::MonitorTick {
                t_s: 1.0,
                regime_changed: false,
            },
        );
        q.push(2.0, arrival(2, 2.0));
        assert_eq!(q.peek_time(), Some(1.0));
        assert_eq!(q.peek_arrival_time(), None, "front is a tick, not an arrival");
    }

    #[test]
    fn mixed_event_kinds_share_one_timeline() {
        let mut q = EventQueue::new();
        q.push(
            0.2,
            Event::MonitorTick {
                t_s: 0.2,
                regime_changed: false,
            },
        );
        q.push(0.1, arrival(1, 0.1));
        q.push(
            0.3,
            Event::OpDispatch {
                request: 1,
                stream: 0,
                op: 0,
                start_s: 0.3,
                placement: crate::soc::Placement::CPU,
            },
        );
        let kinds: Vec<_> = std::iter::from_fn(|| q.pop())
            .map(|(_, ev)| ev.kind())
            .collect();
        use crate::sim::event::EventKind::*;
        assert_eq!(kinds, vec![Arrival, MonitorTick, OpDispatch]);
    }

    // -- calendar-specific coverage --------------------------------------

    #[test]
    fn far_future_entries_migrate_from_overflow_in_order() {
        let mut q = EventQueue::new();
        // far past the initial 64 × 1 ms window: lands in the overflow,
        // then the first pop re-anchors the calendar there
        q.push(5_000.0, arrival(2, 5_000.0));
        q.push(0.01, arrival(0, 0.01));
        q.push(4_999.0, arrival(1, 4_999.0));
        assert_eq!(q.len(), 3);
        assert_eq!(pop_id(&mut q), 0);
        assert_eq!(pop_id(&mut q), 1);
        assert_eq!(pop_id(&mut q), 2);
        assert!(q.is_empty());
    }

    #[test]
    fn past_window_push_rewinds_the_calendar() {
        let mut q = EventQueue::new();
        q.push(100.0, arrival(1, 100.0));
        assert_eq!(q.peek_time(), Some(100.0)); // re-anchors at 100
        q.push(1.0, arrival(0, 1.0)); // before the new year_start
        assert_eq!(pop_id(&mut q), 0);
        assert_eq!(pop_id(&mut q), 1);
        // negative times too
        q.push(0.5, arrival(3, 0.5));
        q.push(-2.0, arrival(2, -2.0));
        assert_eq!(pop_id(&mut q), 2);
        assert_eq!(pop_id(&mut q), 3);
    }

    #[test]
    fn infinities_sort_by_total_cmp() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, arrival(3, f64::NAN));
        q.push(f64::INFINITY, arrival(2, f64::INFINITY));
        q.push(0.0, arrival(1, 0.0));
        q.push(f64::NEG_INFINITY, arrival(0, f64::NEG_INFINITY));
        for want in 0..4 {
            assert_eq!(pop_id(&mut q), want);
        }
    }

    #[test]
    fn equal_times_keep_push_order_across_a_rebuild() {
        let mut q = EventQueue::new();
        // all beyond the initial horizon → overflow; the rebuild on first
        // pop must not disturb the seq tie-break
        for id in 0..16 {
            q.push(77.7, arrival(id, 77.7));
        }
        q.push(76.0, arrival(100, 76.0));
        assert_eq!(pop_id(&mut q), 100);
        for id in 0..16 {
            assert_eq!(pop_id(&mut q), id, "rebuild broke the seq tie-break");
        }
    }

    #[test]
    fn matches_binary_heap_reference_on_a_mixed_workload() {
        let mut cal = EventQueue::new();
        let mut heap = BinaryHeapQueue::new();
        let times = [
            0.3, 0.1, 0.1, 7.0, 0.2, f64::NAN, 0.1, 1e9, 0.2, -1.0, 0.15, 0.15,
        ];
        for (id, &t) in times.iter().enumerate() {
            cal.push(t, arrival(id, t));
            heap.push(t, arrival(id, t));
            if id % 3 == 2 {
                let a = cal.pop().map(|(t, e)| (t.to_bits(), e.kind()));
                let b = heap.pop().map(|(t, e)| (t.to_bits(), e.kind()));
                assert_eq!(a, b);
                assert_eq!(cal.peek_time().map(f64::to_bits),
                           heap.peek_time().map(f64::to_bits));
            }
        }
        while !heap.is_empty() {
            assert_eq!(cal.len(), heap.len());
            let (ta, ea) = cal.pop().unwrap();
            let (tb, eb) = heap.pop().unwrap();
            assert_eq!(ta.to_bits(), tb.to_bits());
            assert_eq!(ea.kind(), eb.kind());
        }
        assert!(cal.is_empty());
    }
}
