//! Deterministic discrete-event queue.
//!
//! A min-heap of [`Event`]s keyed by `(time, seq)`: earlier scheduled
//! times pop first, and events scheduled for the *same* time pop in push
//! order (`seq` is a monotonically increasing counter). Time comparison
//! uses [`f64::total_cmp`], so a NaN timestamp cannot panic the kernel —
//! it sorts after every finite time and drains last, exactly like the
//! NaN-safe arrival sort the legacy engine used.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::event::Event;

/// One scheduled entry: the event plus its `(time, seq)` key.
#[derive(Debug, Clone)]
struct Entry {
    time_s: f64,
    seq: u64,
    event: Event,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}

impl Eq for Entry {}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want the earliest
        // (time, seq) on top
        other
            .time_s
            .total_cmp(&self.time_s)
            .then(other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic `(time, seq)`-ordered event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    next_seq: u64,
}

impl EventQueue {
    /// Empty queue.
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    /// Schedule `event` at `time_s`. Ties at equal `time_s` pop in push
    /// order.
    pub fn push(&mut self, time_s: f64, event: Event) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Entry { time_s, seq, event });
    }

    /// Pop the earliest entry as `(time, event)`.
    pub fn pop(&mut self) -> Option<(f64, Event)> {
        self.heap.pop().map(|e| (e.time_s, e.event))
    }

    /// Scheduled time of the earliest entry, if any.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time_s)
    }

    /// Scheduled time of the earliest entry *if* it is an arrival (the
    /// kernel's preemption rule only looks at arrivals).
    pub fn peek_arrival_time(&self) -> Option<f64> {
        match self.heap.peek() {
            Some(Entry {
                time_s,
                event: Event::Arrival { .. },
                ..
            }) => Some(*time_s),
            _ => None,
        }
    }

    /// Scheduled entries remaining.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether no entries remain.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Request;

    fn arrival(id: usize, t: f64) -> Event {
        Event::Arrival {
            req: Request {
                id,
                stream: 0,
                arrival_s: t,
                deadline_s: t + 1.0,
            },
            admitted: false,
        }
    }

    fn pop_id(q: &mut EventQueue) -> usize {
        match q.pop() {
            Some((_, Event::Arrival { req, .. })) => req.id,
            other => panic!("expected arrival, got {other:?}"),
        }
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, arrival(3, 3.0));
        q.push(1.0, arrival(1, 1.0));
        q.push(2.0, arrival(2, 2.0));
        assert_eq!(q.len(), 3);
        assert_eq!(pop_id(&mut q), 1);
        assert_eq!(pop_id(&mut q), 2);
        assert_eq!(pop_id(&mut q), 3);
        assert!(q.is_empty());
        assert!(q.pop().is_none());
    }

    #[test]
    fn equal_times_tie_break_by_push_order() {
        let mut q = EventQueue::new();
        for id in 0..8 {
            q.push(1.5, arrival(id, 1.5));
        }
        for id in 0..8 {
            assert_eq!(pop_id(&mut q), id, "seq tie-break broke FIFO order");
        }
    }

    #[test]
    fn nan_times_sort_last_without_panicking() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, arrival(9, f64::NAN));
        q.push(1e12, arrival(1, 1e12));
        q.push(0.0, arrival(0, 0.0));
        assert_eq!(pop_id(&mut q), 0);
        assert_eq!(pop_id(&mut q), 1);
        // the NaN entry drains last instead of poisoning the ordering
        assert_eq!(pop_id(&mut q), 9);
    }

    #[test]
    fn interleaved_push_pop_keeps_order() {
        let mut q = EventQueue::new();
        q.push(2.0, arrival(2, 2.0));
        q.push(1.0, arrival(1, 1.0));
        assert_eq!(pop_id(&mut q), 1);
        q.push(0.5, arrival(0, 0.5));
        assert_eq!(pop_id(&mut q), 0);
        assert_eq!(pop_id(&mut q), 2);
    }

    #[test]
    fn peek_does_not_consume() {
        let mut q = EventQueue::new();
        q.push(4.0, arrival(4, 4.0));
        q.push(2.0, arrival(2, 2.0));
        assert_eq!(q.peek_time(), Some(2.0));
        assert_eq!(q.peek_arrival_time(), Some(2.0));
        assert_eq!(q.len(), 2);
        assert_eq!(pop_id(&mut q), 2);
    }

    #[test]
    fn peek_arrival_ignores_non_arrivals() {
        let mut q = EventQueue::new();
        q.push(
            1.0,
            Event::MonitorTick {
                t_s: 1.0,
                regime_changed: false,
            },
        );
        q.push(2.0, arrival(2, 2.0));
        assert_eq!(q.peek_time(), Some(1.0));
        assert_eq!(q.peek_arrival_time(), None, "front is a tick, not an arrival");
    }

    #[test]
    fn mixed_event_kinds_share_one_timeline() {
        let mut q = EventQueue::new();
        q.push(
            0.2,
            Event::MonitorTick {
                t_s: 0.2,
                regime_changed: false,
            },
        );
        q.push(0.1, arrival(1, 0.1));
        q.push(
            0.3,
            Event::OpDispatch {
                request: 1,
                stream: 0,
                op: 0,
                start_s: 0.3,
                placement: crate::soc::Placement::CPU,
            },
        );
        let kinds: Vec<_> = std::iter::from_fn(|| q.pop())
            .map(|(_, ev)| ev.kind())
            .collect();
        use crate::sim::event::EventKind::*;
        assert_eq!(kinds, vec![Arrival, MonitorTick, OpDispatch]);
    }
}
